//! End-to-end driver: nearest-neighbor DTW classification over the
//! synthetic UCR-style archive with every headline bound — the workload
//! the whole paper optimizes.
//!
//! ```sh
//! cargo run --release --example nn_benchmark -- [tiny|small|paper] [take] [repeats]
//! ```
//!
//! For each dataset (recommended window ≥ 1): 1-NN classify the test set
//! under both search orders with LB_KEOGH / LB_IMPROVED / LB_PETITJEAN /
//! LB_WEBB, reporting accuracy (identical across bounds — the bounds are
//! exact screens), wall time, pruning power, and the win/loss + total
//! ratios the paper's §6.2 quotes. The run is recorded in EXPERIMENTS.md.

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::data::Dataset;
use dtw_bounds::delta::Squared;
use dtw_bounds::experiments::nn_timing::{nn_timing, win_loss_ratio, TimedBound};
use dtw_bounds::experiments::with_recommended_window;
use dtw_bounds::metrics::format_duration;
use dtw_bounds::search::SearchStrategy;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scale = args
        .first()
        .and_then(|s| Scale::parse(s))
        .unwrap_or(Scale::Small);
    let archive = generate_archive(&ArchiveSpec::new(scale, 2021));
    let datasets: Vec<&Dataset> = with_recommended_window(&archive);
    let take: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(datasets.len());
    let datasets = &datasets[..take.min(datasets.len())];
    let repeats: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(3);
    let windows: Vec<usize> = datasets.iter().map(|d| d.window).collect();

    println!(
        "archive: {:?}, {} datasets with recommended w >= 1 (of {}), repeats = {repeats}",
        scale,
        datasets.len(),
        archive.len()
    );

    let bounds = [
        TimedBound::Fixed(BoundKind::Keogh),
        TimedBound::Fixed(BoundKind::Improved),
        TimedBound::Fixed(BoundKind::Petitjean),
        TimedBound::Fixed(BoundKind::Webb),
    ];

    for mode in [SearchStrategy::RandomOrder, SearchStrategy::Sorted] {
        println!("\n== {mode} search (Algorithm {}) ==", match mode {
            SearchStrategy::RandomOrder => 3,
            _ => 4,
        });
        let cols = nn_timing::<Squared>(datasets, &windows, &bounds, mode, repeats, 2021);
        let mean_acc: f64 = cols[0].cells.iter().map(|c| c.accuracy).sum::<f64>()
            / cols[0].cells.len() as f64;
        println!("mean 1-NN accuracy: {mean_acc:.3} (identical across bounds)");
        for c in &cols {
            println!("  {:<16} total {}", c.label, format_duration(c.total()));
        }
        // The paper's headline pairings.
        for (a, b) in [(3usize, 0usize), (3, 1), (2, 1), (2, 0)] {
            let (w, l, r) = win_loss_ratio(&cols[a], &cols[b]);
            println!(
                "  {} vs {}: {w}/{l} wins, total-time ratio {r:.2}",
                cols[a].label, cols[b].label
            );
        }
    }
    println!("\ndone; see EXPERIMENTS.md for the recorded reference run.");
}
