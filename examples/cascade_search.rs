//! Cascading bounds under early abandoning (paper §8) — how much work
//! each screening stage saves in random-order NN search.
//!
//! ```sh
//! cargo run --release --example cascade_search
//! ```
//!
//! Runs Algorithm 3 on one synthetic dataset with a ladder of bounds of
//! increasing tightness and prints, per bound: candidates pruned by the
//! bound alone, DTW computations started, DTW computations abandoned
//! early, and wall time — the tightness/cost trade the paper is about.

use std::time::Instant;

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::delta::Squared;
use dtw_bounds::index::DtwIndex;
use dtw_bounds::metrics::Table;
use dtw_bounds::search::classify::classify_dataset;
use dtw_bounds::search::SearchStrategy;

fn main() {
    let archive = generate_archive(&ArchiveSpec::new(Scale::Small, 7));
    // Pick the largest windowed dataset for a meaningful workload.
    let ds = archive
        .iter()
        .filter(|d| d.window >= 1)
        .max_by_key(|d| d.train.len() * d.series_len())
        .expect("archive has windowed datasets");
    println!(
        "dataset {} — l={}, train={}, test={}, classes={}, w={}",
        ds.name,
        ds.series_len(),
        ds.train.len(),
        ds.test.len(),
        ds.num_classes(),
        ds.window
    );
    let index = DtwIndex::builder_from_dataset(ds)
        .window(ds.window)
        .strategy(SearchStrategy::RandomOrder)
        .build()
        .expect("dataset series share one length");
    let total_pairs = ds.test.len() * index.len();

    let ladder = [
        BoundKind::KimFL,
        BoundKind::Keogh,
        BoundKind::Enhanced(8),
        BoundKind::Improved,
        BoundKind::Webb,
        BoundKind::Petitjean,
        BoundKind::Cascade,
    ];

    let mut table = Table::new(vec![
        "bound", "pruned by LB", "DTW started", "DTW abandoned", "time ms", "accuracy",
    ]);
    for bound in ladder {
        let started = Instant::now();
        let out = classify_dataset::<Squared>(ds, &index.with_bound(bound), 99);
        let ms = started.elapsed().as_secs_f64() * 1e3;
        table.row(vec![
            bound.name(),
            format!("{} ({:.0}%)", out.stats.pruned, 100.0 * out.stats.pruned as f64 / total_pairs as f64),
            out.stats.dtw_calls.to_string(),
            out.stats.dtw_abandoned.to_string(),
            format!("{ms:.1}"),
            format!("{:.3}", out.accuracy),
        ]);
    }
    println!("\n{}", table.to_markdown());
    println!("{total_pairs} query-candidate pairs total. Tighter bounds prune more;");
    println!("the cascade gets LB_Webb's pruning at near-LB_KimFL cost on easy candidates.");
}
