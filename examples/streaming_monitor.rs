//! Streaming pattern monitor — the gesture/sensor-matching scenario the
//! paper's introduction motivates.
//!
//! ```sh
//! cargo run --release --example streaming_monitor
//! ```
//!
//! A reference library of labelled patterns (e.g. gestures) is prepared
//! offline. A continuous sensor stream arrives; every hop we take the
//! latest window, z-normalize it, and ask: *is this within DTW distance τ
//! of any known pattern?* `LB_WEBB` screens the library so most windows
//! never touch DTW — the exact deployment pattern of §1's applications.

use std::time::Instant;

use dtw_bounds::bounds::{BoundKind, PreparedSeries, Scratch};
use dtw_bounds::data::rng::Rng;
use dtw_bounds::data::znorm::znormalized;
use dtw_bounds::delta::Squared;
use dtw_bounds::dtw::dtw_ea;
use dtw_bounds::search::PreparedTrainSet;

const PATTERN_LEN: usize = 128;
const N_PATTERNS: usize = 64;
const W: usize = 6;
const HOP: usize = 8;
const STREAM_LEN: usize = 40_000;
const TAU: f64 = 18.0; // match threshold on z-normalized windows

fn make_pattern(rng: &mut Rng) -> Vec<f64> {
    // Smooth random pattern: sum of a few sinusoids.
    let k = rng.int_range(2, 5);
    let params: Vec<(f64, f64, f64)> = (0..k)
        .map(|_| (rng.uniform_range(0.3, 2.0), rng.uniform_range(0.02, 0.3), rng.uniform() * 6.28))
        .collect();
    znormalized(
        &(0..PATTERN_LEN)
            .map(|i| params.iter().map(|(a, f, p)| a * (f * i as f64 + p).sin()).sum())
            .collect::<Vec<f64>>(),
    )
}

fn main() {
    let mut rng = Rng::seeded(404);
    // Reference library, prepared once (envelopes precomputed offline).
    let patterns: Vec<Vec<f64>> = (0..N_PATTERNS).map(|_| make_pattern(&mut rng)).collect();
    let library = PreparedTrainSet {
        labels: (0..N_PATTERNS as u32).collect(),
        series: patterns.iter().map(|p| PreparedSeries::prepare(p.clone(), W)).collect(),
        w: W,
    };

    // Sensor stream: noise with occasional embedded (warped) patterns.
    let mut stream = Vec::with_capacity(STREAM_LEN);
    let mut embedded = Vec::new();
    while stream.len() < STREAM_LEN {
        if rng.uniform() < 0.08 && stream.len() + PATTERN_LEN < STREAM_LEN {
            let id = rng.below(N_PATTERNS);
            embedded.push((stream.len(), id));
            // mild amplitude jitter + noise
            let scale = 1.0 + 0.1 * rng.normal();
            for &v in &patterns[id] {
                stream.push(scale * v + 0.15 * rng.normal());
            }
        } else {
            let run = rng.int_range(20, 100);
            for _ in 0..run {
                stream.push(rng.normal() * 0.8);
            }
        }
    }

    println!(
        "library: {N_PATTERNS} patterns x {PATTERN_LEN}; stream: {} samples, {} embedded occurrences",
        stream.len(),
        embedded.len()
    );

    let mut scratch = Scratch::new(PATTERN_LEN);
    let mut windows = 0usize;
    let mut lb_pruned_all = 0usize;
    let mut dtw_calls = 0usize;
    let mut detections = Vec::new();
    let started = Instant::now();

    let mut pos = 0;
    while pos + PATTERN_LEN <= stream.len() {
        windows += 1;
        let q = znormalized(&stream[pos..pos + PATTERN_LEN]);
        let pq = PreparedSeries::prepare(q, W);
        // Screen the whole library with LB_Webb at threshold tau; DTW only
        // on candidates the bound cannot reject.
        let mut best: Option<(usize, f64)> = None;
        let mut survivors = 0usize;
        for (ti, t) in library.series.iter().enumerate() {
            let cutoff = best.map(|(_, d)| d).unwrap_or(TAU);
            let lb = BoundKind::Webb.compute::<Squared>(&pq, t, W, cutoff, &mut scratch);
            if lb >= cutoff {
                continue;
            }
            survivors += 1;
            dtw_calls += 1;
            let d = dtw_ea::<Squared>(&pq.values, &t.values, W, cutoff);
            if d < cutoff {
                best = Some((ti, d));
            }
        }
        lb_pruned_all += library.series.len() - survivors;
        if let Some((id, d)) = best {
            if std::env::var("DTWB_DEBUG").is_ok() {
                let near = embedded.iter().map(|&(e, _)| (pos as i64 - e as i64)).min_by_key(|v| v.abs());
                eprintln!("detect pos={pos} id={id} d={d:.1} nearest-embed-delta={near:?}");
            }
            detections.push((pos, id, d));
            pos += PATTERN_LEN; // skip past the match
        } else {
            pos += HOP;
        }
    }
    let elapsed = started.elapsed();

    // Score detections against ground truth: an *event* hit is a
    // detection within one hop of an embedded occurrence; an *identity*
    // hit additionally matches the pattern id.
    let mut event_hits = 0;
    let mut id_hits = 0;
    for &(dpos, did, _) in &detections {
        if embedded.iter().any(|&(epos, _)| dpos.abs_diff(epos) <= HOP) {
            event_hits += 1;
        }
        if embedded.iter().any(|&(epos, eid)| eid == did && dpos.abs_diff(epos) <= HOP) {
            id_hits += 1;
        }
    }

    println!("windows examined:   {windows}");
    println!(
        "LB pruned:          {lb_pruned_all} / {} candidate pairs ({:.1}%)",
        windows * N_PATTERNS,
        100.0 * lb_pruned_all as f64 / (windows * N_PATTERNS) as f64
    );
    println!("DTW computations:   {dtw_calls}");
    println!(
        "detections:         {} — {} event hits, {} exact-id hits, {} embedded occurrences",
        detections.len(),
        event_hits,
        id_hits,
        embedded.len()
    );
    println!(
        "throughput:         {:.0} windows/s ({:.2} ms/window)",
        windows as f64 / elapsed.as_secs_f64(),
        elapsed.as_secs_f64() * 1e3 / windows as f64
    );
    assert!(
        event_hits * 10 >= embedded.len() * 6,
        "detector missed too many embedded events: {event_hits}/{}",
        embedded.len()
    );
}
