//! Streaming pattern monitor — the gesture/sensor-matching scenario the
//! paper's introduction motivates, on the real `stream` subsystem.
//!
//! ```sh
//! cargo run --release --example streaming_monitor
//! ```
//!
//! A reference library of labelled patterns (e.g. gestures) is indexed
//! offline into a [`DtwIndex`]. A continuous sensor stream arrives;
//! [`SubsequenceSearcher`] slides a pattern-length window every hop,
//! z-normalizes it, and asks: *is this within DTW distance τ of any known
//! pattern?* The `LB_KIM_FL → LB_KEOGH → LB_WEBB` cascade screens the
//! library so most window × pattern pairs never touch DTW — the exact
//! deployment pattern of §1's applications, with per-stage prune
//! statistics to show where the screening happens.

use std::time::Instant;

use dtw_bounds::data::rng::Rng;
use dtw_bounds::data::synthetic::{embed_stream, sinusoid_pattern};
use dtw_bounds::delta::Squared;
use dtw_bounds::index::DtwIndex;
use dtw_bounds::stream::{StreamMatch, SubsequenceOptions};

const PATTERN_LEN: usize = 128;
const N_PATTERNS: usize = 64;
const W: usize = 6;
const HOP: usize = 8;
const STREAM_LEN: usize = 40_000;
const TAU: f64 = 18.0; // match threshold on z-normalized windows

/// Merge overlapping raw detections into episodes, keeping each
/// episode's best (lowest-distance) match — successive hops across one
/// embedded occurrence all fire, and should count once. The merge window
/// anchors on the *previous raw detection* (not the episode's best
/// match, whose start can jump) so a gap of one window length always
/// starts a new episode.
fn episodes(detections: &[StreamMatch]) -> Vec<StreamMatch> {
    let mut out: Vec<StreamMatch> = Vec::new();
    let mut prev_start: Option<u64> = None;
    for &m in detections {
        match (prev_start, out.last_mut()) {
            (Some(prev), Some(best)) if m.start < prev + PATTERN_LEN as u64 => {
                if m.distance < best.distance {
                    *best = m;
                }
            }
            _ => out.push(m),
        }
        prev_start = Some(m.start);
    }
    out
}

fn main() {
    let mut rng = Rng::seeded(404);
    // Reference library, indexed once (envelopes precomputed offline).
    let patterns: Vec<Vec<f64>> =
        (0..N_PATTERNS).map(|_| sinusoid_pattern(&mut rng, PATTERN_LEN)).collect();
    let index = DtwIndex::builder(patterns.clone())
        .labels((0..N_PATTERNS as u32).collect())
        .window(W)
        .build()
        .expect("patterns share one length");

    // Sensor stream: noise with occasional embedded (jittered) patterns,
    // plus the ground truth of where they were embedded.
    let (stream, embedded) = embed_stream(&mut rng, &patterns, STREAM_LEN, 0.08, 0.1, 0.15);

    println!(
        "library: {N_PATTERNS} patterns x {PATTERN_LEN}; stream: {} samples, {} embedded occurrences",
        stream.len(),
        embedded.len()
    );

    // The subsystem under demonstration: threshold mode, z-normalized
    // windows, the default KimFL -> Keogh -> Webb cascade.
    let mut searcher = index
        .subsequence(SubsequenceOptions::threshold(TAU).with_hop(HOP).with_znorm(true))
        .expect("valid options");

    let started = Instant::now();
    let mut detections: Vec<StreamMatch> = Vec::new();
    for &v in &stream {
        if let Some(m) = searcher.push::<Squared>(v) {
            if std::env::var("DTWB_DEBUG").is_ok() {
                let near = embedded
                    .iter()
                    .map(|&(e, _)| m.start as i64 - e as i64)
                    .min_by_key(|v| v.abs());
                eprintln!(
                    "detect pos={} id={} d={:.1} nearest-embed-delta={near:?}",
                    m.start, m.neighbor, m.distance
                );
            }
            detections.push(m);
        }
    }
    let elapsed = started.elapsed();
    let report = searcher.finish();
    let stats = &report.stats;

    // Score merged episodes against ground truth: an *event* hit is an
    // episode within one hop of an embedded occurrence; an *identity* hit
    // additionally matches the pattern id.
    let episodes = episodes(&detections);
    let mut event_hits = 0;
    let mut id_hits = 0;
    for m in &episodes {
        let dpos = m.start as usize;
        if embedded.iter().any(|&(epos, _)| dpos.abs_diff(epos) <= HOP) {
            event_hits += 1;
        }
        if embedded
            .iter()
            .any(|&(epos, eid)| eid == m.neighbor && dpos.abs_diff(epos) <= HOP)
        {
            id_hits += 1;
        }
    }

    println!("windows examined:   {}", stats.windows);
    for st in &stats.stages {
        let label = format!("{} stage:", st.bound.name());
        println!(
            "{label:<20}{} pruned of {} pairs ({:.1}%)",
            st.pruned,
            stats.candidates,
            100.0 * st.pruned as f64 / stats.candidates.max(1) as f64
        );
    }
    println!(
        "cascade total:      {} / {} pairs pruned ({:.1}%)",
        stats.pruned(),
        stats.candidates,
        100.0 * stats.prune_rate()
    );
    println!("DTW computations:   {} ({} abandoned)", stats.dtw_calls, stats.dtw_abandoned);
    println!(
        "detections:         {} raw -> {} episodes — {} event hits, {} exact-id hits, {} embedded",
        detections.len(),
        episodes.len(),
        event_hits,
        id_hits,
        embedded.len()
    );
    println!(
        "throughput:         {:.0} samples/s ({:.0} windows/s, {:.2} ms/window)",
        stream.len() as f64 / elapsed.as_secs_f64(),
        stats.windows as f64 / elapsed.as_secs_f64(),
        elapsed.as_secs_f64() * 1e3 / stats.windows.max(1) as f64
    );
    assert!(
        event_hits * 10 >= embedded.len() * 6,
        "detector missed too many embedded events: {event_hits}/{}",
        embedded.len()
    );
    assert!(
        stats.pruned() * 2 > stats.candidates,
        "cascade pruned under half the pairs: {}/{}",
        stats.pruned(),
        stats.candidates
    );
}
