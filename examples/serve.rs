//! Serving end-to-end: a shared `DtwIndex`, router + batched prefilter
//! backend, measured under concurrent client load.
//!
//! ```sh
//! cargo run --release --example serve                   # native backend
//! cargo run --release --example serve -- --k 3          # k-NN requests
//! DTWB_BACKEND=none cargo run --release --example serve # scalar only
//! DTWB_BACKEND=pjrt cargo run --release --example serve \
//!     --features pjrt                                   # XLA (needs `make artifacts`)
//! ```
//!
//! Boots the TCP server on an ephemeral port over one synthetic dataset,
//! fires concurrent client connections at it (each request asking for
//! the `--k` nearest neighbors through the line protocol's `k=<n>;`
//! prefix), and reports exactness, latency percentiles and throughput
//! for both the scalar and batched paths.
//!
//! The full line protocol — including the `stream=<params>;samples`
//! subsequence-search extension — is specified with worked
//! request/response examples in `docs/protocol.md`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::coordinator::server::Server;
use dtw_bounds::coordinator::{NnEngine, Router};
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::delta::Squared;
use dtw_bounds::index::DtwIndex;
use dtw_bounds::metrics::Summary;
use dtw_bounds::runtime::BackendKind;
use dtw_bounds::search::knn::{knn_brute_force, KnnParams};

const CLIENTS: usize = 4;
const QUERIES_PER_CLIENT: usize = 32;

/// Attach the PJRT backend (feature `pjrt`; needs `make artifacts`).
#[cfg(feature = "pjrt")]
fn attach_pjrt(engine: &mut NnEngine) {
    use dtw_bounds::runtime::{default_artifacts_dir, XlaRuntime};
    let artifacts = default_artifacts_dir();
    if !artifacts.join("manifest.tsv").exists() {
        eprintln!("no artifacts (run `make artifacts`): scalar path only");
        return;
    }
    match XlaRuntime::cpu() {
        Ok(rt) => {
            match engine.attach_batch_lb(&rt, &artifacts, 32) {
                Ok(()) => eprintln!("batched prefilter: pjrt"),
                Err(e) => eprintln!("no batched path: {e:#}"),
            }
            std::mem::forget(rt);
        }
        Err(e) => eprintln!("PJRT unavailable: {e:#}"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn attach_pjrt(_engine: &mut NnEngine) {
    eprintln!("pjrt backend requested but built without --features pjrt; scalar path only");
}

fn main() {
    // `--k N`: how many neighbors every request asks for.
    let args: Vec<String> = std::env::args().skip(1).collect();
    let k = args
        .iter()
        .position(|a| a == "--k")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(1)
        .max(1);

    let archive = generate_archive(&ArchiveSpec::new(Scale::Small, 2021));
    // A dataset that fits the compiled artifact shapes (n<=256, l<=512).
    let ds = archive
        .iter()
        .filter(|d| d.window >= 1 && d.train.len() <= 256 && d.series_len() <= 512)
        .max_by_key(|d| d.train.len())
        .expect("suitable dataset");
    println!(
        "dataset {}: l={}, train={}, w={}, k={k}",
        ds.name,
        ds.series_len(),
        ds.train.len(),
        ds.window
    );

    // Backend from DTWB_BACKEND (native | pjrt | none); default native.
    let backend = match std::env::var("DTWB_BACKEND") {
        Ok(s) => BackendKind::parse(&s).unwrap_or_else(|| {
            eprintln!("DTWB_BACKEND={s:?} not recognized (native|pjrt|none); using native");
            BackendKind::Native
        }),
        Err(_) => BackendKind::Native,
    };

    // One shared index; the router's dispatch thread builds its searcher
    // (and non-Send backend) from a cheap handle.
    let index = DtwIndex::builder_from_dataset(ds)
        .bound(BoundKind::Webb)
        .backend(BackendKind::None) // attached per kind below
        .max_batch(32)
        .build()
        .expect("dataset series share one length");
    let factory_index = index.clone();
    let router = Arc::new(Router::spawn(
        move || {
            let mut engine = NnEngine::from_index(factory_index);
            match backend {
                BackendKind::None => eprintln!("scalar path only"),
                BackendKind::Native => {
                    engine.attach_native();
                    eprintln!("batched prefilter: native");
                }
                BackendKind::Pjrt => attach_pjrt(&mut engine),
            }
            engine
        },
        32,
    ));
    let server = Server::spawn("127.0.0.1:0", router.clone()).expect("bind");
    let addr = server.addr();
    println!("server on {addr}; {CLIENTS} clients x {QUERIES_PER_CLIENT} queries\n");

    // Ground truth for exactness checks: the k nearest distances.
    let truth: Vec<Vec<f64>> = ds
        .test
        .iter()
        .map(|q| {
            knn_brute_force::<Squared>(&q.values, index.train(), &KnnParams::k(k))
                .0
                .iter()
                .map(|r| r.distance)
                .collect()
        })
        .collect();

    let started = Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let queries: Vec<(usize, Vec<f64>)> = (0..QUERIES_PER_CLIENT)
            .map(|kq| {
                let qi = (c * QUERIES_PER_CLIENT + kq) % ds.test.len();
                (qi, ds.test[qi].values.clone())
            })
            .collect();
        handles.push(std::thread::spawn(move || {
            let conn = TcpStream::connect(addr).expect("connect");
            let mut writer = conn.try_clone().unwrap();
            let mut lines = BufReader::new(conn).lines();
            let mut out = Vec::new();
            for (qi, q) in queries {
                let csv: Vec<String> = q.iter().map(|v| v.to_string()).collect();
                let line = if k == 1 {
                    format!("{}\n", csv.join(","))
                } else {
                    format!("k={k};{}\n", csv.join(","))
                };
                let t0 = Instant::now();
                writer.write_all(line.as_bytes()).unwrap();
                let resp = lines.next().unwrap().unwrap();
                out.push((qi, t0.elapsed().as_secs_f64() * 1e3, resp));
            }
            out
        }));
    }

    let mut latencies = Vec::new();
    let mut batched = 0usize;
    let mut total = 0usize;
    for h in handles {
        for (qi, ms, resp) in h.join().unwrap() {
            total += 1;
            latencies.push(ms);
            if resp.contains("path=batched") {
                batched += 1;
            }
            // Exactness: parse the distances and compare with brute force.
            let dists: Vec<f64> = if k == 1 {
                resp.split_whitespace()
                    .find_map(|f| f.strip_prefix("dist=").map(|v| v.parse().unwrap()))
                    .into_iter()
                    .collect()
            } else {
                resp.split_whitespace()
                    .find_map(|f| f.strip_prefix("neighbors="))
                    .expect("neighbors field")
                    .split(',')
                    .map(|triple| triple.rsplit(':').next().unwrap().parse().unwrap())
                    .collect()
            };
            assert_eq!(dists.len(), truth[qi].len(), "wrong neighbor count for query {qi}");
            for (got, want) in dists.iter().zip(truth[qi].iter()) {
                assert!(
                    (got - want).abs() < 1e-6 * want.max(1.0),
                    "inexact answer for query {qi}: {got} vs {want}"
                );
            }
        }
    }
    let wall = started.elapsed();
    let s = Summary::of(&latencies);
    let mut lat = latencies.clone();
    println!("served {total} queries (k={k}), all exact");
    println!("  batched path: {batched}/{total}");
    println!(
        "  latency ms: mean {:.2} ± {:.2}, p50 {:.2}, p99 {:.2}",
        s.mean,
        s.std,
        Summary::percentile(&mut lat, 50.0),
        Summary::percentile(&mut lat, 99.0)
    );
    println!(
        "  throughput: {:.0} queries/s (wall {:.2}s)",
        total as f64 / wall.as_secs_f64(),
        wall.as_secs_f64()
    );
    server.shutdown();
}
