//! Quickstart: the paper's running example (Figure 3) end to end.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Computes windowed DTW for the two example series, then every lower
//! bound in the crate, demonstrating the tightness/cost ladder and the
//! core invariant `λ ≤ DTW`.

use dtw_bounds::bounds::{BoundKind, PreparedSeries, Scratch};
use dtw_bounds::delta::Squared;
use dtw_bounds::dtw::{cost_matrix, dtw, warping_path};

fn main() {
    // Figure 3 of the paper.
    let a = vec![-1.0, 1.0, -1.0, 4.0, -2.0, 1.0, 1.0, 1.0, -1.0, 0.0, 1.0];
    let b = vec![1.0, -1.0, 1.0, -1.0, -1.0, -4.0, -4.0, -1.0, 1.0, 0.0, -1.0];
    let w = 1;

    let d = dtw::<Squared>(&a, &b, w);
    println!("DTW_w={w}(A, B) = {d}  (paper Figure 3; its caption's 52 is an arithmetic slip)");

    let m = cost_matrix::<Squared>(&a, &b, w);
    let path = warping_path(&m);
    println!("optimal warping path ({} alignments):", path.len());
    let rendered: Vec<String> =
        path.iter().map(|&(i, j)| format!("({},{})", i + 1, j + 1)).collect();
    println!("  {}", rendered.join(" "));

    println!("\nlower bounds (query = A, candidate = B):");
    let q = PreparedSeries::prepare(a.clone(), w);
    let t = PreparedSeries::prepare(b.clone(), w);
    let mut scratch = Scratch::new(a.len());
    for &bound in BoundKind::ALL {
        let lb = bound.compute::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
        let tightness = lb / d;
        assert!(lb <= d, "invariant violated");
        println!("  {:<22} {:>8.2}   tightness {:.3}", bound.name(), lb, tightness);
    }

    println!("\nall bounds <= DTW — invariant holds. Run `cargo bench` for the paper's tables.");
}
