//! Quickstart: the `DtwIndex` facade end to end, then the paper's
//! running example (Figure 3) on the low-level API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Part 1 indexes a synthetic dataset and runs exact k-NN queries with
//! per-stage pruning counts. Part 2 computes windowed DTW for the two
//! Figure-3 series and every lower bound in the crate, demonstrating the
//! tightness/cost ladder and the core invariant `λ ≤ DTW`.

use dtw_bounds::bounds::{BoundKind, PreparedSeries, Scratch};
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::delta::Squared;
use dtw_bounds::dtw::{cost_matrix, dtw, warping_path};
use dtw_bounds::index::{DtwIndex, Query, QueryOptions};
use dtw_bounds::search::SearchStrategy;

fn main() {
    // ----- Part 1: the primary API -------------------------------------
    let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 2021))[0];
    let index = DtwIndex::builder_from_dataset(ds)
        .bound(BoundKind::Webb)
        .strategy(SearchStrategy::Sorted)
        .build()
        .expect("dataset series share one length");
    println!(
        "indexed {}: {} series of length {}, w={}, bound={}, strategy={}",
        ds.name,
        index.len(),
        ds.series_len(),
        index.window(),
        index.bound(),
        index.strategy()
    );

    let k = 3;
    let mut searcher = index.searcher();
    for (qi, q) in ds.test.iter().take(4).enumerate() {
        let out = searcher.query::<Squared>(&Query::new(q.values.clone()).with_k(k));
        let rendered: Vec<String> = out
            .neighbors
            .iter()
            .map(|n| format!("#{} (label {}, d={:.3})", n.index, n.label, n.distance))
            .collect();
        println!(
            "  q{qi}: {}  [{} of {} candidates pruned by {}]",
            rendered.join("  "),
            out.stats.pruned,
            index.len(),
            index.bound()
        );
    }

    // Typed options: an abandon threshold turns k-NN into "anything
    // within tau?" — the streaming/monitoring regime.
    let probe = &ds.test[0];
    let nn = index.knn::<Squared>(&probe.values, 1);
    let tau = nn.neighbors[0].distance * 1.5;
    let within = index.query::<Squared>(
        &Query::new(probe.values.clone()).with_options(QueryOptions::k(10).with_abandon_at(tau)),
    );
    println!(
        "  {} neighbors within tau={:.3} of q0 (of {} indexed)",
        within.neighbors.len(),
        tau,
        index.len()
    );

    // ----- Part 2: the low-level API (paper Figure 3) ------------------
    let a = vec![-1.0, 1.0, -1.0, 4.0, -2.0, 1.0, 1.0, 1.0, -1.0, 0.0, 1.0];
    let b = vec![1.0, -1.0, 1.0, -1.0, -1.0, -4.0, -4.0, -1.0, 1.0, 0.0, -1.0];
    let w = 1;

    let d = dtw::<Squared>(&a, &b, w);
    println!("\nDTW_w={w}(A, B) = {d}  (paper Figure 3; its caption's 52 is an arithmetic slip)");

    let m = cost_matrix::<Squared>(&a, &b, w);
    let path = warping_path(&m);
    println!("optimal warping path ({} alignments):", path.len());
    let rendered: Vec<String> =
        path.iter().map(|&(i, j)| format!("({},{})", i + 1, j + 1)).collect();
    println!("  {}", rendered.join(" "));

    println!("\nlower bounds (query = A, candidate = B):");
    let q = PreparedSeries::prepare(a.clone(), w);
    let t = PreparedSeries::prepare(b.clone(), w);
    let mut scratch = Scratch::new(a.len());
    for &bound in BoundKind::ALL {
        let lb = bound.compute::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
        let tightness = lb / d;
        assert!(lb <= d, "invariant violated");
        println!("  {:<22} {:>8.2}   tightness {:.3}", bound.name(), lb, tightness);
    }

    println!("\nall bounds <= DTW — invariant holds. Run `cargo bench` for the paper's tables.");
}
