//! Kill-and-restart integration test: SIGKILL the real serving binary
//! mid-session and prove that a mutation acked under `--wal always`
//! survives into the restarted process (the end-to-end half of the
//! `rust/tests/recovery.rs` property suite — real kernel, real files,
//! real sockets, a real dead process).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

/// Bind-then-drop to reserve an ephemeral port for the server. (A tiny
/// race window before the server rebinds it — acceptable for a test.)
fn free_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    format!("127.0.0.1:{}", addr.port())
}

/// Connect with retries while the freshly spawned server comes up.
fn connect(addr: &str) -> TcpStream {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match TcpStream::connect(addr) {
            Ok(conn) => return conn,
            Err(e) => {
                assert!(Instant::now() < deadline, "connect {addr}: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// One request, one reply (the protocol is strictly line-per-line).
fn ask(conn: &mut TcpStream, line: &str) -> String {
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut reply = String::new();
    BufReader::new(conn.try_clone().unwrap()).read_line(&mut reply).unwrap();
    reply.trim_end().to_string()
}

fn spawn_serve(bin: &str, addr: &str, snap: &Path) -> Child {
    Command::new(bin)
        .args(["serve", addr, "--snapshot"])
        .arg(snap)
        .args(["--wal", "always"])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .stdin(Stdio::null())
        .spawn()
        .expect("spawn serve")
}

#[test]
fn sigkilled_server_recovers_acked_inserts_from_the_wal() {
    let bin = env!("CARGO_BIN_EXE_dtw-bounds");
    let dir = std::env::temp_dir().join(format!("dtwb_crash_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let snap = dir.join("idx.snap");

    // Build a snapshot to anchor the WAL, then serve from it.
    let built = Command::new(bin)
        .args(["index", "build", "--scale", "tiny", "--out"])
        .arg(&snap)
        .stdout(Stdio::null())
        .status()
        .expect("run index build");
    assert!(built.success(), "index build failed");

    let addr = free_addr();
    let mut server = spawn_serve(bin, &addr, &snap);
    let mut conn = connect(&addr);
    assert_eq!(ask(&mut conn, "PING"), "PONG");

    // Learn the indexed series length from a deliberate length error,
    // then insert a probe series; the ack implies the WAL fsync ran.
    let err = ask(&mut conn, "insert=7;0.0,0.0");
    let len: usize = err
        .split("expected ")
        .nth(1)
        .and_then(|s| s.split_whitespace().next())
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("no length in {err:?}"));
    let probe: Vec<String> = (0..len).map(|i| format!("{}.25", i)).collect();
    let probe = probe.join(",");
    let ack = ask(&mut conn, &format!("insert=42;{probe}"));
    assert!(ack.starts_with("inserted id="), "{ack}");
    let hit = ask(&mut conn, &probe);
    assert!(hit.starts_with("label=42 dist=0.000000"), "{hit}");
    let stats = ask(&mut conn, "stats=;");
    assert!(stats.contains(" wal_records=1"), "append logged before ack: {stats}");

    // SIGKILL: no flush, no shutdown handler, no goodbye.
    drop(conn);
    server.kill().expect("kill serve");
    server.wait().expect("reap serve");

    // Restart from the same snapshot + WAL: the acked insert is back,
    // found at distance exactly zero.
    let addr = free_addr();
    let mut server = spawn_serve(bin, &addr, &snap);
    let mut conn = connect(&addr);
    let hit = ask(&mut conn, &probe);
    assert!(
        hit.starts_with("label=42 dist=0.000000"),
        "acked insert lost across SIGKILL: {hit}"
    );
    let stats = ask(&mut conn, "stats=;");
    assert!(stats.contains(" wal_records=1"), "replayed log stays open: {stats}");

    drop(conn);
    server.kill().ok();
    server.wait().ok();
    std::fs::remove_dir_all(&dir).ok();
}
