//! Persistence & sharding property suite — the PR's acceptance
//! contract:
//!
//! * **Round trip**: `load(save(idx))` produces **bit-identical** k-NN
//!   and streaming-subsequence results to the in-memory index, across
//!   shard counts, z-norm policies and thread counts.
//! * **Shard parity**: `DtwIndexBuilder::shards(n)` produces
//!   bit-identical results to the serial unsharded index for every
//!   shard count × thread count in the grid {1, 2, 3, 7} × {1, 4},
//!   on the scalar, parallel, batched and streaming paths.
//! * **Typed rejection**: non-snapshot files, truncation, bit
//!   corruption, future versions and missing paths each fail with
//!   their own [`SnapshotError`] variant — never a panic.
//! * **Cold start**: a server stack holding only the snapshot answers
//!   queries identically to one built from the raw dataset.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

use dtw_bounds::coordinator::{Router, Server};
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::data::Dataset;
use dtw_bounds::delta::Squared;
use dtw_bounds::index::{DtwIndex, QueryOptions, SnapshotError};
use dtw_bounds::stream::{StreamMatch, SubsequenceOptions};

fn dataset(seed: u64) -> Dataset {
    generate_archive(&ArchiveSpec::new(Scale::Tiny, seed))[0].clone()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dtwb_persist_{}_{name}", std::process::id()))
}

/// `(index, distance)` pairs — the bit-exact comparison currency.
fn knn_pairs(index: &DtwIndex, query: &[f64], k: usize) -> Vec<(usize, f64)> {
    index
        .knn::<Squared>(query, k)
        .neighbors
        .iter()
        .map(|n| (n.index, n.distance))
        .collect()
}

/// A sample stream with one exact copy of an indexed series between
/// far-away filler: deterministic matches for stream parity checks.
fn stream_samples(index: &DtwIndex) -> Vec<f64> {
    let mut samples = vec![1e3; 7];
    samples.extend_from_slice(&index.train().series[0].values);
    samples.extend(vec![-1e3; 5]);
    samples.extend_from_slice(&index.train().series[1].values);
    samples.extend(vec![1e3; 7]);
    samples
}

fn stream_matches(index: &DtwIndex, samples: &[f64], threads: usize) -> Vec<StreamMatch> {
    index
        .subsequence_scan::<Squared>(
            samples,
            SubsequenceOptions::threshold(1e-6).with_threads(threads),
        )
        .expect("valid stream options")
        .matches
}

#[test]
fn snapshot_round_trip_is_bit_equal_on_every_path() {
    let ds = dataset(301);
    for &(shards, znorm) in &[(1usize, false), (3, false), (2, true)] {
        let index = DtwIndex::builder_from_dataset(&ds)
            .shards(shards)
            .znormalize(znorm)
            .build()
            .unwrap();
        let path = tmp(&format!("roundtrip_s{shards}_z{znorm}.snap"));
        index.save(&path).unwrap();
        let loaded = DtwIndex::load(&path).unwrap();
        assert_eq!(loaded.shard_count(), index.shard_count());
        assert_eq!(loaded.znormalizes(), znorm);

        // k-NN bit-equality, serial and threaded.
        for q in ds.test.iter().take(4) {
            for k in [1usize, 3] {
                assert_eq!(
                    knn_pairs(&index, &q.values, k),
                    knn_pairs(&loaded, &q.values, k),
                    "shards={shards} znorm={znorm} k={k}"
                );
                assert_eq!(
                    knn_pairs(&index.with_threads(4), &q.values, k),
                    knn_pairs(&loaded.with_threads(4), &q.values, k),
                    "threaded shards={shards} znorm={znorm} k={k}"
                );
            }
        }

        // Streaming subsequence search bit-equality.
        let samples = stream_samples(&index);
        assert_eq!(
            stream_matches(&index, &samples, 1),
            stream_matches(&loaded, &samples, 1),
            "stream shards={shards} znorm={znorm}"
        );
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn sharded_equals_serial_across_the_grid() {
    let ds = dataset(302);
    let baseline = DtwIndex::builder_from_dataset(&ds).build().unwrap();
    let samples = stream_samples(&baseline);
    let base_stream = stream_matches(&baseline, &samples, 1);
    for shards in [1usize, 2, 3, 7] {
        let sharded = DtwIndex::builder_from_dataset(&ds).shards(shards).build().unwrap();
        for threads in [1usize, 4] {
            let handle = sharded.with_threads(threads);
            for q in ds.test.iter().take(4) {
                for k in [1usize, 3] {
                    assert_eq!(
                        knn_pairs(&handle, &q.values, k),
                        knn_pairs(&baseline, &q.values, k),
                        "shards={shards} threads={threads} k={k}"
                    );
                }
            }
            assert_eq!(
                stream_matches(&sharded, &samples, threads),
                base_stream,
                "stream shards={shards} threads={threads}"
            );
        }
    }
}

#[test]
fn sharded_batched_prefilter_equals_serial() {
    let ds = dataset(303);
    let queries: Vec<Vec<f64>> = ds.test.iter().map(|s| s.values.clone()).collect();
    assert!(queries.len() > 1, "need a real batch");
    let baseline = DtwIndex::builder_from_dataset(&ds).build().unwrap();
    let mut base_searcher = baseline.searcher();
    let base: Vec<Vec<(usize, f64)>> = base_searcher
        .query_batch::<Squared>(&queries, &QueryOptions::k(3))
        .iter()
        .map(|o| o.neighbors.iter().map(|n| (n.index, n.distance)).collect())
        .collect();
    for shards in [2usize, 3, 7] {
        let sharded = DtwIndex::builder_from_dataset(&ds).shards(shards).build().unwrap();
        let mut searcher = sharded.searcher();
        let outs = searcher.query_batch::<Squared>(&queries, &QueryOptions::k(3));
        for (qi, out) in outs.iter().enumerate() {
            assert!(out.batched, "shards={shards} q{qi}");
            let got: Vec<(usize, f64)> =
                out.neighbors.iter().map(|n| (n.index, n.distance)).collect();
            assert_eq!(got, base[qi], "batched shards={shards} q{qi}");
        }
    }
}

#[test]
fn storeless_index_saves_through_a_transient_partition() {
    // Single shard + non-store backend: the builder skips the flat-store
    // copy, so save() must materialize one transiently — and the loaded
    // index must answer bit-equal anyway.
    let ds = dataset(306);
    let index = DtwIndex::builder_from_dataset(&ds)
        .backend(dtw_bounds::runtime::BackendKind::None)
        .build()
        .unwrap();
    assert_eq!(index.shard_count(), 0, "store-less configuration");
    let path = tmp("storeless.snap");
    index.save(&path).unwrap();
    let loaded = DtwIndex::load(&path).unwrap();
    assert_eq!(loaded.shard_count(), 1);
    assert_eq!(loaded.backend(), dtw_bounds::runtime::BackendKind::None);
    for q in ds.test.iter().take(3) {
        assert_eq!(knn_pairs(&index, &q.values, 3), knn_pairs(&loaded, &q.values, 3));
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn empty_index_round_trips() {
    let index = DtwIndex::builder(Vec::new()).build().unwrap();
    let path = tmp("empty.snap");
    index.save(&path).unwrap();
    let loaded = DtwIndex::load(&path).unwrap();
    assert!(loaded.is_empty());
    assert_eq!(loaded.shard_count(), 0);
    assert!(loaded.knn::<Squared>(&[1.0, 2.0], 3).neighbors.is_empty());
    std::fs::remove_file(&path).ok();
}

#[test]
fn malformed_snapshots_are_rejected_with_typed_errors() {
    let ds = dataset(304);
    let index = DtwIndex::builder_from_dataset(&ds).shards(2).build().unwrap();
    let path = tmp("victim.snap");
    index.save(&path).unwrap();
    let good = std::fs::read(&path).unwrap();

    // Missing file → Io.
    let missing = tmp("does_not_exist.snap");
    assert!(matches!(DtwIndex::load(&missing), Err(SnapshotError::Io(_))));
    assert!(matches!(
        dtw_bounds::index::snapshot::inspect(&missing),
        Err(SnapshotError::Io(_))
    ));

    // Not a snapshot at all → BadMagic.
    let bad_magic = tmp("bad_magic.snap");
    std::fs::write(&bad_magic, b"GARBAGE!plus some trailing bytes").unwrap();
    assert!(matches!(DtwIndex::load(&bad_magic), Err(SnapshotError::BadMagic)));

    // Future version → UnsupportedVersion (reported before checksums).
    let mut future = good.clone();
    future[8..12].copy_from_slice(&99u32.to_le_bytes());
    let future_path = tmp("future.snap");
    std::fs::write(&future_path, &future).unwrap();
    assert!(matches!(
        DtwIndex::load(&future_path),
        Err(SnapshotError::UnsupportedVersion { found: 99, .. })
    ));

    // Truncation → Truncated (length check precedes the checksum).
    for cut in [good.len() / 2, good.len() - 1, 20, 5] {
        let t = tmp("truncated.snap");
        std::fs::write(&t, &good[..cut]).unwrap();
        assert!(
            matches!(DtwIndex::load(&t), Err(SnapshotError::Truncated { .. })),
            "cut={cut}"
        );
        std::fs::remove_file(&t).ok();
    }

    // Bit corruption anywhere in the body → ChecksumMismatch.
    for &pos in &[28usize, good.len() / 2, good.len() - 1] {
        let mut corrupt = good.clone();
        corrupt[pos] ^= 0x20;
        let c = tmp("corrupt.snap");
        std::fs::write(&c, &corrupt).unwrap();
        assert!(
            matches!(DtwIndex::load(&c), Err(SnapshotError::ChecksumMismatch { .. })),
            "pos={pos}"
        );
        std::fs::remove_file(&c).ok();
    }

    // Every variant renders a distinct, human-readable message.
    let msgs: Vec<String> = vec![
        SnapshotError::BadMagic.to_string(),
        SnapshotError::UnsupportedVersion { found: 9, supported: 1 }.to_string(),
        SnapshotError::Truncated { context: "body" }.to_string(),
        SnapshotError::ChecksumMismatch { stored: 1, computed: 2 }.to_string(),
        SnapshotError::Corrupt("x".into()).to_string(),
    ];
    for (i, a) in msgs.iter().enumerate() {
        for b in msgs.iter().skip(i + 1) {
            assert_ne!(a, b);
        }
    }

    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&bad_magic).ok();
    std::fs::remove_file(&future_path).ok();
}

/// The acceptance criterion's cold-start half, in-process: a serving
/// stack holding **only the snapshot** answers a TCP query identically
/// to the stack built from the raw dataset.
#[test]
fn snapshot_cold_start_serves_identical_answers() {
    let ds = dataset(305);
    let built = DtwIndex::builder_from_dataset(&ds).shards(2).build().unwrap();
    let path = tmp("cold_start.snap");
    built.save(&path).unwrap();
    let q: Vec<String> = ds.test[0].values.iter().map(|v| v.to_string()).collect();
    let line = format!("k=3;{}\n", q.join(","));

    let ask = |index: DtwIndex| -> String {
        let router = Arc::new(Router::spawn_index(index));
        let server = Server::spawn("127.0.0.1:0", router).unwrap();
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(line.as_bytes()).unwrap();
        let mut reader = BufReader::new(conn);
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        drop(reader);
        server.shutdown();
        // Strip the timing-bearing tail.
        reply.split(" path=").next().unwrap().to_string()
    };

    // The cold-start index comes from the file alone — `built` (and the
    // dataset) are gone from its lineage.
    let cold = DtwIndex::load(&path).unwrap();
    assert_eq!(ask(cold), ask(built), "cold start answers bit-equal k-NN");
    std::fs::remove_file(&path).ok();
}
