//! CLI integration tests — drive the real binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dtw-bounds"))
}

#[test]
fn info_runs() {
    let out = bin().arg("info").output().expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("dtw-bounds"));
    assert!(text.contains("LB_Webb"), "{text}");
}

#[test]
fn info_lists_screening_backends() {
    let out = bin().arg("info").output().expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("backends: native (default)"), "{text}");
}

#[test]
fn serve_rejects_unknown_backend() {
    let out = bin()
        .args(["serve", "--scale", "tiny", "--backend", "tpu", "127.0.0.1:0"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--backend"), "{err}");
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("frobnicate").output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn gen_archive_writes_ucr_layout() {
    let tmp = std::env::temp_dir().join(format!("dtwb_cli_{}", std::process::id()));
    let out = bin()
        .args(["gen-archive", "--scale", "tiny", "--out"])
        .arg(&tmp)
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let entries: Vec<_> = std::fs::read_dir(&tmp).unwrap().collect();
    assert_eq!(entries.len(), 10);
    assert!(tmp.join("Synth00").join("Synth00_TRAIN.tsv").exists());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn tightness_tiny_take_two() {
    let out = bin()
        .args(["tightness", "--scale", "tiny", "--take", "2", "--bounds", "keogh,webb"])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LB_Keogh") && text.contains("LB_Webb"));
    assert!(text.contains("tighter on"));
}

#[test]
fn knn_subcommand_prints_neighbors() {
    let out = bin()
        .args(["knn", "--scale", "tiny", "--k", "3", "--queries", "2", "--bound", "webb"])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("k=3"), "{text}");
    assert!(text.contains("q0"), "{text}");
    assert!(text.contains("d="), "{text}");
}

#[test]
fn knn_threads_flag_prints_identical_neighbors() {
    // --threads only moves latency; the printed neighbor lines (indices,
    // labels, distances) must be identical to the serial run.
    let run = |threads: &str| {
        let out = bin()
            .args([
                "knn", "--scale", "tiny", "--k", "3", "--queries", "2", "--threads", threads,
            ])
            .output()
            .expect("spawn");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout).to_string();
        // Keep only the per-query neighbor payloads (strip the header
        // and the timing-bearing tail of each line).
        text.lines()
            .filter(|l| l.starts_with('q'))
            .map(|l| l.split(" | ").next().unwrap_or(l).to_string())
            .collect::<Vec<_>>()
    };
    let serial = run("1");
    assert!(!serial.is_empty());
    assert_eq!(run("4"), serial, "thread-count invariance");
}

#[test]
fn knn_rejects_zero_k_and_bad_strategy() {
    let out = bin().args(["knn", "--scale", "tiny", "--k", "0"]).output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--k"));

    let out = bin()
        .args(["knn", "--scale", "tiny", "--strategy", "quantum"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--strategy"));
}

#[test]
fn serve_rejects_zero_k() {
    let out = bin()
        .args(["serve", "--scale", "tiny", "--k", "0", "127.0.0.1:0"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--k"));
}

#[test]
fn stream_demo_reports_stages() {
    let out = bin()
        .args([
            "stream", "--scale", "tiny", "--demo", "3000", "--demo-seed", "7", "--tau",
            "60", "--hop", "4",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("samples=3000"), "{text}");
    assert!(text.contains("windows="), "{text}");
    assert!(text.contains("stage LB_KimFL"), "{text}");
    assert!(text.contains("stage LB_Webb"), "{text}");
    assert!(text.contains("dtw: calls="), "{text}");
}

#[test]
fn stream_reads_samples_from_file() {
    // 1-NN of a constant stream: zero windows match a tiny tau, but the
    // pass itself must succeed and count windows.
    let tmp = std::env::temp_dir().join(format!("dtwb_stream_{}.txt", std::process::id()));
    let samples: Vec<String> = (0..400).map(|i| format!("{}", (i % 7) as f64)).collect();
    std::fs::write(&tmp, samples.join("\n")).unwrap();
    let out = bin()
        .args(["stream", "--scale", "tiny", "--tau", "0.000001", "--input"])
        .arg(&tmp)
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("samples=400"), "{text}");
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn stream_requires_a_mode_and_valid_cascade() {
    let out = bin().args(["stream", "--scale", "tiny", "--demo", "500"]).output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--tau"));

    let out = bin()
        .args([
            "stream", "--scale", "tiny", "--demo", "500", "--tau", "5", "--cascade",
            "kim,bogus",
        ])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown bound"));
}

#[test]
fn index_build_then_inspect_round_trips() {
    let snap = std::env::temp_dir().join(format!("dtwb_cli_idx_{}.snap", std::process::id()));
    let out = bin()
        .args(["index", "build", "--scale", "tiny", "--shards", "2", "--znorm", "--out"])
        .arg(&snap)
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("shards=2"), "{text}");
    assert!(text.contains("saved"), "{text}");

    let out = bin().args(["index", "inspect"]).arg(&snap).output().expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    // The writer always emits the current snapshot version (3 since the
    // generation pair landed); older versions are read-compat only.
    assert!(text.contains("version=3"), "{text}");
    assert!(text.contains("shards=2"), "{text}");
    assert!(text.contains("znorm=true"), "{text}");
    assert!(text.contains("checksum=0x"), "{text}");
    assert!(text.lines().any(|l| l.starts_with("series_len=")), "{text}");
    // The host's active SIMD dispatch, not a stored snapshot field.
    assert!(
        text.lines().any(|l| l == format!("isa={}", dtw_bounds::simd::isa_name())),
        "{text}"
    );
    std::fs::remove_file(&snap).ok();
}

#[test]
fn index_inspect_reports_distinct_nonpanicking_errors() {
    // Malformed path: a clean io error, exit code 1, no panic.
    let out = bin()
        .args(["index", "inspect", "/definitely/missing/idx.snap"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(1), "clean exit, not a panic abort");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("snapshot") && err.contains("io:"), "{err}");

    // Malformed header: a distinct bad-magic error.
    let junk = std::env::temp_dir().join(format!("dtwb_cli_junk_{}.snap", std::process::id()));
    std::fs::write(&junk, b"this is not a snapshot file").unwrap();
    let out = bin().args(["index", "inspect"]).arg(&junk).output().expect("spawn");
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(1));
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bad magic"), "{err}");
    std::fs::remove_file(&junk).ok();

    // Unknown sub-action and missing --out are argument errors.
    let out = bin().args(["index", "frobnicate"]).output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("build|inspect"));
    let out = bin().args(["index", "build", "--scale", "tiny"]).output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--out"));
}

#[test]
fn serve_snapshot_rejects_bad_files_with_distinct_errors() {
    let out = bin()
        .args(["serve", "--snapshot", "/definitely/missing/idx.snap", "127.0.0.1:0"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert_eq!(out.status.code(), Some(1), "clean exit, not a panic abort");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--snapshot") && err.contains("io:"), "{err}");

    let junk = std::env::temp_dir().join(format!("dtwb_cli_sjunk_{}.snap", std::process::id()));
    std::fs::write(&junk, b"GARBAGE!GARBAGE!GARBAGE!GARBAGE!").unwrap();
    let out = bin()
        .args(["serve", "--snapshot"])
        .arg(&junk)
        .arg("127.0.0.1:0")
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("bad magic"), "{err}");
    std::fs::remove_file(&junk).ok();
}

#[test]
fn serve_snapshot_cold_starts_and_answers() {
    use std::io::{BufRead, BufReader, Write};

    // Build the snapshot with the real binary…
    let snap = std::env::temp_dir().join(format!("dtwb_cli_cold_{}.snap", std::process::id()));
    let out = bin()
        .args(["index", "build", "--scale", "tiny", "--shards", "2", "--out"])
        .arg(&snap)
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // …learn the series length from its header…
    let out = bin().args(["index", "inspect"]).arg(&snap).output().expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout).to_string();
    let l: usize = text
        .lines()
        .find_map(|line| line.strip_prefix("series_len="))
        .expect("inspect prints series_len")
        .parse()
        .unwrap();

    // …then cold-start `serve --snapshot` on an ephemeral port and query
    // it without ever touching the raw dataset.
    let mut child = bin()
        .args(["serve", "--snapshot"])
        .arg(&snap)
        .arg("127.0.0.1:0")
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .expect("spawn serve");
    let mut reader = BufReader::new(child.stdout.take().expect("piped stdout"));
    let mut addr = None;
    for _ in 0..10 {
        let mut line = String::new();
        if reader.read_line(&mut line).unwrap_or(0) == 0 {
            break;
        }
        if let Some(pos) = line.rfind(" on ") {
            addr = Some(line[pos + 4..].trim().to_string());
            break;
        }
    }
    let addr = addr.expect("serve printed its bound address");
    let mut conn = std::net::TcpStream::connect(&addr).expect("connect to cold server");
    let series = vec!["0.25"; l].join(",");
    conn.write_all(format!("PING\nk=3;{series}\n").as_bytes()).unwrap();
    let mut lines = BufReader::new(conn).lines();
    assert_eq!(lines.next().unwrap().unwrap(), "PONG");
    let reply = lines.next().unwrap().unwrap();
    assert!(reply.starts_with("k=3 neighbors="), "{reply}");

    child.kill().ok();
    child.wait().ok();
    std::fs::remove_file(&snap).ok();
}

#[test]
fn sweep_single_fraction_smoke() {
    let out = bin()
        .args([
            "sweep",
            "--scale",
            "tiny",
            "--take",
            "2",
            "--frac",
            "0.05",
            "--repeats",
            "1",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LB_Webb vs LB_Keogh"));
    assert!(text.contains("w = 5%"));
}
