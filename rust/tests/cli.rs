//! CLI integration tests — drive the real binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dtw-bounds"))
}

#[test]
fn info_runs() {
    let out = bin().arg("info").output().expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("dtw-bounds"));
    assert!(text.contains("LB_Webb"), "{text}");
}

#[test]
fn info_lists_screening_backends() {
    let out = bin().arg("info").output().expect("spawn");
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("backends: native (default)"), "{text}");
}

#[test]
fn serve_rejects_unknown_backend() {
    let out = bin()
        .args(["serve", "--scale", "tiny", "--backend", "tpu", "127.0.0.1:0"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--backend"), "{err}");
}

#[test]
fn unknown_command_fails_with_message() {
    let out = bin().arg("frobnicate").output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn gen_archive_writes_ucr_layout() {
    let tmp = std::env::temp_dir().join(format!("dtwb_cli_{}", std::process::id()));
    let out = bin()
        .args(["gen-archive", "--scale", "tiny", "--out"])
        .arg(&tmp)
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let entries: Vec<_> = std::fs::read_dir(&tmp).unwrap().collect();
    assert_eq!(entries.len(), 10);
    assert!(tmp.join("Synth00").join("Synth00_TRAIN.tsv").exists());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn tightness_tiny_take_two() {
    let out = bin()
        .args(["tightness", "--scale", "tiny", "--take", "2", "--bounds", "keogh,webb"])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LB_Keogh") && text.contains("LB_Webb"));
    assert!(text.contains("tighter on"));
}

#[test]
fn knn_subcommand_prints_neighbors() {
    let out = bin()
        .args(["knn", "--scale", "tiny", "--k", "3", "--queries", "2", "--bound", "webb"])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("k=3"), "{text}");
    assert!(text.contains("q0"), "{text}");
    assert!(text.contains("d="), "{text}");
}

#[test]
fn knn_threads_flag_prints_identical_neighbors() {
    // --threads only moves latency; the printed neighbor lines (indices,
    // labels, distances) must be identical to the serial run.
    let run = |threads: &str| {
        let out = bin()
            .args([
                "knn", "--scale", "tiny", "--k", "3", "--queries", "2", "--threads", threads,
            ])
            .output()
            .expect("spawn");
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        let text = String::from_utf8_lossy(&out.stdout).to_string();
        // Keep only the per-query neighbor payloads (strip the header
        // and the timing-bearing tail of each line).
        text.lines()
            .filter(|l| l.starts_with('q'))
            .map(|l| l.split(" | ").next().unwrap_or(l).to_string())
            .collect::<Vec<_>>()
    };
    let serial = run("1");
    assert!(!serial.is_empty());
    assert_eq!(run("4"), serial, "thread-count invariance");
}

#[test]
fn knn_rejects_zero_k_and_bad_strategy() {
    let out = bin().args(["knn", "--scale", "tiny", "--k", "0"]).output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--k"));

    let out = bin()
        .args(["knn", "--scale", "tiny", "--strategy", "quantum"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--strategy"));
}

#[test]
fn serve_rejects_zero_k() {
    let out = bin()
        .args(["serve", "--scale", "tiny", "--k", "0", "127.0.0.1:0"])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--k"));
}

#[test]
fn stream_demo_reports_stages() {
    let out = bin()
        .args([
            "stream", "--scale", "tiny", "--demo", "3000", "--demo-seed", "7", "--tau",
            "60", "--hop", "4",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("samples=3000"), "{text}");
    assert!(text.contains("windows="), "{text}");
    assert!(text.contains("stage LB_KimFL"), "{text}");
    assert!(text.contains("stage LB_Webb"), "{text}");
    assert!(text.contains("dtw: calls="), "{text}");
}

#[test]
fn stream_reads_samples_from_file() {
    // 1-NN of a constant stream: zero windows match a tiny tau, but the
    // pass itself must succeed and count windows.
    let tmp = std::env::temp_dir().join(format!("dtwb_stream_{}.txt", std::process::id()));
    let samples: Vec<String> = (0..400).map(|i| format!("{}", (i % 7) as f64)).collect();
    std::fs::write(&tmp, samples.join("\n")).unwrap();
    let out = bin()
        .args(["stream", "--scale", "tiny", "--tau", "0.000001", "--input"])
        .arg(&tmp)
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("samples=400"), "{text}");
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn stream_requires_a_mode_and_valid_cascade() {
    let out = bin().args(["stream", "--scale", "tiny", "--demo", "500"]).output().expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--tau"));

    let out = bin()
        .args([
            "stream", "--scale", "tiny", "--demo", "500", "--tau", "5", "--cascade",
            "kim,bogus",
        ])
        .output()
        .expect("spawn");
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown bound"));
}

#[test]
fn sweep_single_fraction_smoke() {
    let out = bin()
        .args([
            "sweep",
            "--scale",
            "tiny",
            "--take",
            "2",
            "--frac",
            "0.05",
            "--repeats",
            "1",
        ])
        .output()
        .expect("spawn");
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("LB_Webb vs LB_Keogh"));
    assert!(text.contains("w = 5%"));
}
