//! Cross-module integration tests: archive → index facade → search →
//! coordinator → batched screening backends (native always; PJRT behind
//! the `pjrt` feature when artifacts exist).

use std::sync::Arc;

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::coordinator::{NnEngine, Router};
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::data::ucr;
use dtw_bounds::delta::Squared;
use dtw_bounds::experiments::{self, with_recommended_window};
use dtw_bounds::index::DtwIndex;
use dtw_bounds::search::classify::classify_dataset;
use dtw_bounds::search::knn::{knn_brute_force, KnnParams};
use dtw_bounds::search::{PreparedTrainSet, SearchStrategy};

fn brute_distance(q: &[f64], train: &PreparedTrainSet) -> f64 {
    knn_brute_force::<Squared>(q, train, &KnnParams::default()).0[0].distance
}

#[test]
fn archive_roundtrips_through_ucr_format() {
    let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 1000));
    let tmp = std::env::temp_dir().join(format!("dtwb_it_{}", std::process::id()));
    for ds in archive.iter().take(3) {
        ucr::save_dataset(&tmp.join(&ds.name), ds).unwrap();
    }
    let back = ucr::load_archive(&tmp, false).unwrap();
    assert_eq!(back.len(), 3);
    for (orig, loaded) in archive.iter().zip(back.iter()) {
        assert_eq!(orig.train.len(), loaded.train.len());
        assert_eq!(orig.test.len(), loaded.test.len());
        // Values survive the 6-decimal text format.
        for (a, b) in orig.train[0].values.iter().zip(loaded.train[0].values.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn every_bound_classifies_identically_across_strategies() {
    let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 2000));
    let ds = &with_recommended_window(&archive)[0];
    let index = DtwIndex::builder_from_dataset(ds).window(ds.window).build().unwrap();
    let baseline = classify_dataset::<Squared>(
        ds,
        &index.with_bound(BoundKind::KimFL).with_strategy(SearchStrategy::RandomOrder),
        3,
    );
    for &bound in BoundKind::ALL {
        for strategy in [SearchStrategy::RandomOrder, SearchStrategy::Sorted] {
            let out = classify_dataset::<Squared>(
                ds,
                &index.with_bound(bound).with_strategy(strategy),
                3,
            );
            assert_eq!(out.accuracy, baseline.accuracy, "{bound} {strategy}");
        }
    }
}

#[test]
fn tightness_experiment_full_tiny_archive() {
    let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 3000));
    let datasets = with_recommended_window(&archive);
    let bounds =
        vec![BoundKind::Keogh, BoundKind::Improved, BoundKind::Petitjean, BoundKind::Webb];
    let res = experiments::tightness_experiment::<Squared>(&datasets, &bounds);
    assert_eq!(res.rows.len(), datasets.len());
    // Paper headline on means: Petitjean >= Improved >= Keogh everywhere.
    let (ck, ci, cp) = (
        res.col(BoundKind::Keogh).unwrap(),
        res.col(BoundKind::Improved).unwrap(),
        res.col(BoundKind::Petitjean).unwrap(),
    );
    for (name, _, t) in &res.rows {
        assert!(t[ci] >= t[ck] - 1e-12, "{name}");
        // Petitjean vs Improved: paper admits rare LR-path corner cases,
        // but on dataset *means* it should dominate.
        assert!(t[cp] >= t[ci] - 1e-3, "{name}: {} vs {}", t[cp], t[ci]);
    }
}

#[test]
fn router_under_concurrent_load() {
    let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 4000));
    let ds = archive[0].clone();
    let w = ds.window.max(1);
    let train = PreparedTrainSet::from_dataset(&ds, w);
    let ds2 = ds.clone();
    let router = Arc::new(Router::spawn(move || NnEngine::new(&ds2, w, BoundKind::Webb), 8));

    let mut handles = Vec::new();
    for (qi, q) in ds.test.iter().take(6).cloned().enumerate() {
        let router = router.clone();
        handles.push(std::thread::spawn(move || (qi, router.query(q.values))));
    }
    for h in handles {
        let (qi, resp) = h.join().unwrap();
        assert_eq!(resp.result.distance, brute_distance(&ds.test[qi].values, &train));
    }
}

/// Acceptance: the default-build engine answers batched queries via the
/// native backend with results identical to the scalar Algorithm-4 path.
#[test]
fn native_backend_matches_scalar_algorithm4() {
    use dtw_bounds::coordinator::EnginePath;
    use dtw_bounds::runtime::NativeBatchLb;

    let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 6000));
    for ds in archive.iter().take(3) {
        let w = ds.window.max(1);
        let mut scalar_engine = NnEngine::new(ds, w, BoundKind::Keogh);
        let mut batch_engine =
            NnEngine::with_backend(ds, w, BoundKind::Keogh, Box::new(NativeBatchLb::new()));
        assert_eq!(batch_engine.backend_name(), Some("native"));

        let queries: Vec<Vec<f64>> = ds.test.iter().map(|s| s.values.clone()).collect();
        assert!(queries.len() > 1, "{}: need a real batch", ds.name);
        let batched = batch_engine.query_batch(&queries);
        for (resp, q) in batched.iter().zip(queries.iter()) {
            assert_eq!(resp.path, EnginePath::Batched, "{}", ds.name);
            let scalar = scalar_engine.query_one(q);
            assert_eq!(
                resp.result.distance, scalar.result.distance,
                "{}: batched vs scalar distance",
                ds.name
            );
        }
    }
}

/// Full three-layer path on the default build: synthetic data → shared
/// index → router → native batched prefilter → exact k-NN.
#[test]
fn three_layer_batched_search_native() {
    use dtw_bounds::index::QueryOptions;
    use dtw_bounds::runtime::BackendKind;

    let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 5000));
    let ds = archive[0].clone();
    let index = DtwIndex::builder_from_dataset(&ds)
        .bound(BoundKind::Keogh)
        .backend(BackendKind::Native)
        .max_batch(8)
        .build()
        .unwrap();
    let router = Arc::new(Router::spawn_index(index.clone()));
    // Async-submit so real batches can form; mixed k across the batch.
    let rxs: Vec<_> = ds
        .test
        .iter()
        .take(8)
        .enumerate()
        .map(|(i, q)| {
            router.query_async_with(q.values.clone(), QueryOptions::k(1 + (i % 2) * 4))
        })
        .collect();
    for (i, (rx, q)) in rxs.into_iter().zip(ds.test.iter()).enumerate() {
        let resp = rx.recv().unwrap();
        let k = 1 + (i % 2) * 4;
        let (truth, _) = knn_brute_force::<Squared>(&q.values, index.train(), &KnnParams::k(k));
        let want: Vec<f64> = truth.iter().map(|r| r.distance).collect();
        assert_eq!(resp.distances(), want, "k={k}");
    }
}

/// The hot path never reallocates a pre-sized scratch: pin the buffer
/// capacities across every bound over many pairs. (The same invariant is
/// debug-asserted inside `BoundKind::compute` after every call.)
#[cfg(debug_assertions)]
#[test]
fn scratch_hot_path_is_allocation_free() {
    use dtw_bounds::bounds::{PreparedSeries, Scratch};

    let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 7000));
    let ds = &archive[0];
    let w = ds.window.max(2);
    let l = ds.series_len();
    let train = PreparedTrainSet::from_dataset(ds, w);
    let mut scratch = Scratch::new(l);
    let caps = scratch.capacities();

    for q in ds.test.iter().take(3) {
        let pq = PreparedSeries::prepare(q.values.clone(), w);
        for t in train.series.iter().take(10) {
            for &bound in BoundKind::ALL {
                let _ = bound.compute::<Squared>(&pq, t, w, f64::INFINITY, &mut scratch);
                // Also exercise the early-abandon path.
                let _ = bound.compute::<Squared>(&pq, t, w, 1e-3, &mut scratch);
            }
        }
    }
    assert_eq!(
        scratch.capacities(),
        caps,
        "a bound kernel reallocated the pre-sized scratch"
    );
}

/// Full three-layer path: synthetic data → XLA batched prefilter →
/// exact NN — needs `make artifacts` plus a real (non-stub) xla crate.
#[cfg(feature = "pjrt")]
#[test]
fn three_layer_batched_search_when_artifacts_present() {
    let dir = dtw_bounds::runtime::default_artifacts_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 5000));
    // Pick a dataset that fits the largest compiled shape.
    let ds = archive
        .iter()
        .find(|d| d.series_len() <= 512 && d.train.len() <= 256)
        .expect("tiny archive fits");
    let w = ds.window.max(1);
    let train = PreparedTrainSet::from_dataset(ds, w);

    if dtw_bounds::runtime::XlaRuntime::cpu().is_err() {
        eprintln!("skipping: PJRT unavailable (stub xla build?)");
        return;
    }
    let ds2 = ds.clone();
    let dir2 = dir.clone();
    let router = Arc::new(Router::spawn(
        move || {
            let mut engine = NnEngine::new(&ds2, w, BoundKind::Keogh);
            let rt = dtw_bounds::runtime::XlaRuntime::cpu().unwrap();
            engine.attach_batch_lb(&rt, &dir2, 8).unwrap();
            std::mem::forget(rt);
            engine
        },
        8,
    ));
    // Async-submit so a real batch forms.
    let rxs: Vec<_> = ds
        .test
        .iter()
        .take(8)
        .map(|q| router.query_async(q.values.clone()))
        .collect();
    let mut batched = 0;
    for (rx, q) in rxs.into_iter().zip(ds.test.iter()) {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.best().unwrap().distance, brute_distance(&q.values, &train));
        if resp.batched {
            batched += 1;
        }
    }
    // At least some queries should have ridden the XLA batch.
    assert!(batched >= 1, "no query used the batched path");
}
