//! k-NN facade property tests: `DtwIndex::knn` must return exactly the
//! k smallest DTW distances that brute force finds, for every strategy,
//! several k and several windows, over random synthetic archives.

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::delta::Squared;
use dtw_bounds::index::{DtwIndex, Query, QueryOptions};
use dtw_bounds::search::knn::{knn_brute_force, KnnParams};
use dtw_bounds::search::SearchStrategy;

/// The k smallest distances by exhaustive search (the test oracle).
fn oracle(index: &DtwIndex, q: &[f64], k: usize) -> Vec<f64> {
    let (truth, _) = knn_brute_force::<Squared>(q, index.train(), &KnnParams::k(k));
    truth.iter().map(|r| r.distance).collect()
}

#[test]
fn knn_matches_brute_force_across_k_windows_and_strategies() {
    for seed in [101u64, 202] {
        let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, seed));
        for ds in archive.iter().take(2) {
            let l = ds.series_len();
            for w in [1usize, ds.window.max(2), (l / 5).max(3)] {
                let base = DtwIndex::builder_from_dataset(ds)
                    .window(w)
                    .bound(BoundKind::Webb)
                    .build()
                    .unwrap();
                for &strategy in SearchStrategy::ALL {
                    let index = base.with_strategy(strategy);
                    let mut searcher = index.searcher();
                    for q in ds.test.iter().take(3) {
                        for k in [1usize, 3, 10] {
                            let want = oracle(&base, &q.values, k);
                            assert_eq!(
                                want.len(),
                                k.min(index.len()),
                                "oracle size (k={k}, n={})",
                                index.len()
                            );
                            let out = searcher
                                .query_values::<Squared>(&q.values, &QueryOptions::k(k));
                            assert_eq!(
                                out.distances(),
                                want,
                                "{} w={w} k={k} strategy={strategy}",
                                ds.name
                            );
                            // Neighbors come back sorted ascending.
                            assert!(out
                                .neighbors
                                .windows(2)
                                .all(|p| p[0].distance <= p[1].distance));
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn knn_convenience_equals_searcher_path() {
    let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 303))[1];
    let index = DtwIndex::builder_from_dataset(ds).build().unwrap();
    let q = &ds.test[0].values;
    let a = index.knn::<Squared>(q, 5);
    let b = index.query::<Squared>(&Query::new(q.clone()).with_k(5));
    assert_eq!(a.distances(), b.distances());
    assert_eq!(a.distances(), oracle(&index, q, 5));
}

#[test]
fn batched_backend_knn_matches_brute_force() {
    let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 404))[0];
    let index = DtwIndex::builder_from_dataset(ds)
        .bound(BoundKind::Keogh)
        .strategy(SearchStrategy::SortedPrecomputed)
        .build()
        .unwrap();
    let mut searcher = index.searcher();
    assert_eq!(searcher.backend_name(), Some("native"));
    let queries: Vec<Vec<f64>> = ds.test.iter().map(|s| s.values.clone()).collect();
    assert!(queries.len() > 1, "need a real batch");
    for k in [1usize, 3, 10] {
        let outs = searcher.query_batch::<Squared>(&queries, &QueryOptions::k(k));
        for (out, q) in outs.iter().zip(queries.iter()) {
            assert!(out.batched, "k={k} should ride the native prefilter");
            assert_eq!(out.distances(), oracle(&index, q, k), "batched k={k}");
        }
    }
}

/// The executor determinism contract: multi-threaded k-NN returns the
/// *identical* neighbor set — same indices, same bit-exact distances —
/// as single-threaded search, at every k and thread count, including
/// with a threshold and self-match exclusion in play.
#[test]
fn parallel_knn_is_identical_to_serial_at_every_k_and_thread_count() {
    let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 606))[0];
    let base = DtwIndex::builder_from_dataset(ds).bound(BoundKind::Webb).build().unwrap();
    let pairs = |out: &dtw_bounds::index::QueryOutcome| -> Vec<(usize, f64)> {
        out.neighbors.iter().map(|n| (n.index, n.distance)).collect()
    };
    for q in ds.test.iter().take(3) {
        for k in [1usize, 3, 10, base.len()] {
            // Plain, thresholded, and excluded variants.
            let tau = oracle(&base, &q.values, 3).last().copied().unwrap_or(f64::INFINITY);
            let variants = [
                QueryOptions::k(k),
                QueryOptions::k(k).with_abandon_at(tau),
                QueryOptions::k(k).with_exclude(0),
            ];
            for (vi, opts) in variants.iter().enumerate() {
                let serial = base.searcher().query_values::<Squared>(&q.values, opts);
                for threads in [2usize, 3, 4, 8] {
                    let index = base.with_threads(threads);
                    let out = index.searcher().query_values::<Squared>(&q.values, opts);
                    assert_eq!(
                        pairs(&out),
                        pairs(&serial),
                        "k={k} threads={threads} variant={vi}"
                    );
                }
            }
        }
    }
    // Per-query override beats the index default, same contract.
    let q = &ds.test[0].values;
    let serial = base.knn::<Squared>(q, 5);
    let via_opts = base
        .searcher()
        .query_values::<Squared>(q, &QueryOptions::k(5).with_threads(4));
    assert_eq!(pairs(&via_opts), pairs(&serial), "QueryOptions::with_threads");
}

#[test]
fn deprecated_1nn_shims_agree_with_the_facade() {
    #![allow(deprecated)]
    use dtw_bounds::bounds::{PreparedSeries, Scratch};
    use dtw_bounds::search::nn::nn_sorted;
    use dtw_bounds::search::PreparedTrainSet;

    let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 505))[0];
    let w = ds.window.max(1);
    let train = PreparedTrainSet::from_dataset(ds, w);
    let index = DtwIndex::builder_from_dataset(ds).window(w).build().unwrap();
    let mut scratch = Scratch::default();
    let (mut bb, mut ib) = (Vec::new(), Vec::new());
    for q in ds.test.iter().take(5) {
        let pq = PreparedSeries::prepare(q.values.clone(), w);
        let (legacy, _) = nn_sorted::<Squared>(
            &pq,
            &train,
            BoundKind::Webb,
            &mut scratch,
            &mut bb,
            &mut ib,
        );
        let facade = index.knn::<Squared>(&q.values, 1);
        assert_eq!(legacy.distance, facade.neighbors[0].distance);
    }
}
