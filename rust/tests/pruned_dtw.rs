//! Exactness property tests for the pruned exact-DTW kernel
//! (`dtw_ea_pruned`) — the kernel behind every search path since the
//! hardware-speed hot-paths PR.
//!
//! Contract, over random series / windows / cutoffs / tails:
//!
//! 1. a **finite** result is bit-equal to `dtw` (the unpruned truth);
//! 2. `INFINITY` is returned **only** when the true distance exceeds
//!    the cutoff (pruning may abandon earlier than `dtw_ea`, never
//!    wrongly);
//! 3. both hold with the `LB_KEOGH` cumulative-lower-bound tail
//!    attached, and the tail's head equals the full `LB_KEOGH` bound.

use dtw_bounds::bounds::{keogh, PreparedSeries};
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::{Absolute, Squared};
use dtw_bounds::dtw::{dtw, dtw_ea, dtw_ea_pruned};

fn random_series(rng: &mut Rng, n: usize) -> Vec<f64> {
    // Mix random walks (realistic, prunable) and white noise (hostile).
    if rng.below(2) == 0 {
        let mut v = 0.0;
        (0..n)
            .map(|_| {
                v += rng.normal() * 0.5;
                v
            })
            .collect()
    } else {
        (0..n).map(|_| rng.normal() * 2.0).collect()
    }
}

#[test]
fn pruned_is_exact_for_random_series_windows_and_cutoffs() {
    let mut rng = Rng::seeded(0x9A12);
    for trial in 0..300 {
        let n = rng.int_range(2, 70);
        let a = random_series(&mut rng, n);
        let b = random_series(&mut rng, n);
        let w = rng.below(n + 3);
        let truth = dtw::<Squared>(&a, &b, w);
        // Cutoffs straddling the truth, including exact equality.
        for mult in [0.0, 0.3, 0.8, 1.0, 1.2, 4.0] {
            let cutoff = truth * mult;
            let got = dtw_ea_pruned::<Squared>(&a, &b, w, cutoff, None);
            if got.is_finite() {
                assert_eq!(got, truth, "trial={trial} w={w} mult={mult}: finite must be exact");
                assert!(truth <= cutoff, "trial={trial}: finite implies within cutoff");
            } else {
                assert!(
                    truth > cutoff,
                    "trial={trial} w={w} mult={mult}: INFINITY only above the cutoff \
                     (truth={truth}, cutoff={cutoff})"
                );
            }
        }
        // Infinite cutoff must reproduce dtw bit-exactly.
        assert_eq!(dtw_ea_pruned::<Squared>(&a, &b, w, f64::INFINITY, None), truth);
    }
}

#[test]
fn pruned_with_keogh_tail_is_exact_and_tail_heads_the_bound() {
    let mut rng = Rng::seeded(0x9A13);
    for trial in 0..200 {
        let n = rng.int_range(3, 60);
        let a = random_series(&mut rng, n);
        let b = random_series(&mut rng, n);
        let w = rng.below(n);
        let t = PreparedSeries::prepare(b.clone(), w);
        let mut tail = Vec::new();
        let lb = keogh::lb_keogh_tail::<Squared>(&a, &t.lo, &t.up, &mut tail);
        let truth = dtw::<Squared>(&a, &b, w);
        assert!(lb <= truth + 1e-9, "trial={trial}: the tail head is a valid lower bound");
        for mult in [0.2, 0.9, 1.0, 1.1, 3.0] {
            let cutoff = truth * mult;
            let got = dtw_ea_pruned::<Squared>(&a, &b, w, cutoff, Some(&tail));
            if got.is_finite() {
                assert_eq!(got, truth, "trial={trial} w={w} mult={mult} (with tail)");
            } else {
                assert!(truth > cutoff, "trial={trial} w={w} mult={mult} (with tail)");
            }
        }
    }
}

#[test]
fn pruned_agrees_with_dtw_ea_semantics_under_absolute_delta() {
    let mut rng = Rng::seeded(0x9A14);
    for _ in 0..120 {
        let n = rng.int_range(2, 40);
        let a = random_series(&mut rng, n);
        let b = random_series(&mut rng, n);
        let w = rng.below(n + 1);
        let truth = dtw::<Absolute>(&a, &b, w);
        for mult in [0.5, 1.0, 2.0] {
            let cutoff = truth * mult;
            let ea = dtw_ea::<Absolute>(&a, &b, w, cutoff);
            let pruned = dtw_ea_pruned::<Absolute>(&a, &b, w, cutoff, None);
            // Wherever dtw_ea returns a *useful* (<= cutoff) finite
            // value, the pruned kernel returns the same bits; where
            // dtw_ea abandons, pruning must abandon too (it is
            // strictly more aggressive, never less correct).
            if ea.is_finite() && ea <= cutoff {
                assert_eq!(pruned, ea);
            }
            if ea.is_infinite() {
                assert!(pruned.is_infinite());
            }
        }
    }
}

#[test]
fn unequal_lengths_and_degenerate_windows() {
    let mut rng = Rng::seeded(0x9A15);
    for _ in 0..80 {
        let la = rng.int_range(1, 25);
        let lb = rng.int_range(1, 25);
        let a = random_series(&mut rng, la);
        let b = random_series(&mut rng, lb);
        for w in [0usize, 1, 5, 100] {
            let truth = dtw::<Squared>(&a, &b, w);
            for cutoff in [truth * 0.5, truth, truth * 1.5 + 1e-9] {
                let got = dtw_ea_pruned::<Squared>(&a, &b, w, cutoff, None);
                if got.is_finite() {
                    assert_eq!(got, truth, "la={la} lb={lb} w={w}");
                } else {
                    assert!(truth > cutoff, "la={la} lb={lb} w={w}");
                }
            }
        }
    }
}
