//! Property tests for the streaming subsequence-search subsystem:
//! [`dtw_bounds::stream::SubsequenceSearcher`] must agree with a
//! brute-force sliding-window DTW oracle, for every cascade, in
//! threshold and top-k modes, with and without per-window
//! z-normalization — and the incremental envelope maintainer must
//! reproduce the batch envelopes over stream-sized inputs.
//!
//! Equality contract: **bit-equal** distances without z-normalization.
//! With it, the searcher normalizes from `StreamBuffer`'s O(1) rolling
//! moments (the satellite perf fix), which drift from the oracle's
//! per-window rescan by a few ulps — so z-norm comparisons pin the same
//! match set (starts + neighbors) and distances to 1e-9 relative, with
//! τ placed at a midpoint between oracle distances so no window can
//! flip across the threshold on ulp noise. Thread-count invariance is
//! pinned exactly: serial and parallel sweeps return identical matches.

use dtw_bounds::bounds::envelope::{envelopes, StreamingEnvelope};
use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::rng::Rng;
use dtw_bounds::data::synthetic::embed_stream;
use dtw_bounds::data::znorm::znormalized;
use dtw_bounds::delta::Squared;
use dtw_bounds::dtw::dtw;
use dtw_bounds::index::DtwIndex;
use dtw_bounds::stream::{SubsequenceOptions, DEFAULT_CASCADE};

/// A small random pattern library indexed at window `w`.
fn library(rng: &mut Rng, n: usize, m: usize, w: usize) -> DtwIndex {
    let series: Vec<Vec<f64>> = (0..n)
        .map(|_| {
            // Smooth-ish random walk so bounds have something to prune.
            let mut v = 0.0;
            (0..m)
                .map(|_| {
                    v += rng.normal() * 0.5;
                    v
                })
                .collect()
        })
        .collect();
    DtwIndex::builder(series)
        .labels((0..n as u32).collect())
        .window(w)
        .build()
        .expect("one shared length")
}

/// A noise stream with a few (noisy) library members embedded.
fn noisy_stream(rng: &mut Rng, index: &DtwIndex, len: usize) -> Vec<f64> {
    let patterns: Vec<Vec<f64>> =
        index.train().series.iter().map(|s| s.values.clone()).collect();
    embed_stream(rng, &patterns, len, 0.15, 0.0, 0.1).0
}

/// Brute force: the exact nearest indexed series of every hop-grid
/// window (full DTW, no bounds, no cutoffs). Returns
/// `(start, neighbor, distance)` per window.
fn oracle(index: &DtwIndex, samples: &[f64], hop: usize, znorm: bool) -> Vec<(u64, usize, f64)> {
    let m = index.train().series[0].len();
    let w = index.window();
    let mut out = Vec::new();
    let mut start = 0usize;
    while start + m <= samples.len() {
        if start % hop == 0 {
            let win: Vec<f64> = if znorm {
                znormalized(&samples[start..start + m])
            } else {
                samples[start..start + m].to_vec()
            };
            let mut best = (usize::MAX, f64::INFINITY);
            for (ti, t) in index.train().series.iter().enumerate() {
                let d = dtw::<Squared>(&win, &t.values, w);
                if d < best.1 {
                    best = (ti, d);
                }
            }
            out.push((start as u64, best.0, best.1));
        }
        start += 1;
    }
    out
}

/// A τ that no distance can straddle under ulp drift: the midpoint of
/// the sorted oracle distances around `pos`, falling back to a strict
/// scaling when every later distance ties.
fn midpoint_tau(sorted: &[f64], pos: usize) -> f64 {
    let lo = sorted[pos];
    match sorted[pos..].iter().find(|&&d| d > lo) {
        Some(&hi) => (lo + hi) / 2.0,
        None => lo * 1.5 + 1e-6,
    }
}

/// Compare match lists: starts and neighbors exact, distances within
/// `tol` relative (tol = 0.0 demands bit-equality).
fn assert_matches_close(got: &[(u64, usize, f64)], want: &[(u64, usize, f64)], tol: f64, ctx: &str) {
    assert_eq!(
        got.iter().map(|&(s, n, _)| (s, n)).collect::<Vec<_>>(),
        want.iter().map(|&(s, n, _)| (s, n)).collect::<Vec<_>>(),
        "{ctx}: match set (start, neighbor)"
    );
    for (&(s, _, gd), &(_, _, wd)) in got.iter().zip(want.iter()) {
        if tol == 0.0 {
            assert_eq!(gd, wd, "{ctx}: start {s}");
        } else {
            assert!(
                (gd - wd).abs() <= tol * wd.abs().max(1.0),
                "{ctx}: start {s}: {gd} vs {wd}"
            );
        }
    }
}

/// Cascades to exercise: the default, each family alone, a tightest-last
/// stack, and the §8 composites.
fn cascades() -> Vec<Vec<BoundKind>> {
    vec![
        DEFAULT_CASCADE.to_vec(),
        vec![BoundKind::KimFL],
        vec![BoundKind::Keogh],
        vec![BoundKind::Webb],
        vec![BoundKind::Improved],
        vec![BoundKind::KimFL, BoundKind::Keogh, BoundKind::Webb, BoundKind::Petitjean],
        vec![BoundKind::UcrCascade, BoundKind::WebbEnhanced(3)],
    ]
}

#[test]
fn threshold_mode_matches_oracle_for_every_cascade() {
    let mut rng = Rng::seeded(8101);
    for trial in 0..4 {
        let (n, m, w) = (5 + trial % 3, 20 + 3 * trial, 1 + trial % 4);
        let index = library(&mut rng, n, m, w);
        let samples = noisy_stream(&mut rng, &index, 400);
        for &hop in &[1usize, 3] {
            for &znorm in &[false, true] {
                let truth = oracle(&index, &samples, hop, znorm);
                // A tau with matches on both sides: around the median
                // nearest distance across windows. With z-norm the
                // searcher's rolling-moment distances drift by ulps, so
                // tau sits at a midpoint no distance can straddle.
                let mut ds: Vec<f64> = truth.iter().map(|&(_, _, d)| d).collect();
                ds.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
                let tau =
                    if znorm { midpoint_tau(&ds, ds.len() / 2) } else { ds[ds.len() / 2] };
                let tol = if znorm { 1e-9 } else { 0.0 };
                let want: Vec<(u64, usize, f64)> =
                    truth.iter().copied().filter(|&(_, _, d)| d < tau).collect();
                assert!(!want.is_empty(), "degenerate tau t={trial} hop={hop}");

                for cascade in cascades() {
                    let opts = SubsequenceOptions::threshold(tau)
                        .with_hop(hop)
                        .with_znorm(znorm)
                        .with_cascade(cascade.clone());
                    let report = index
                        .subsequence_scan::<Squared>(&samples, opts)
                        .expect("valid options");
                    let got: Vec<(u64, usize, f64)> = report
                        .matches
                        .iter()
                        .map(|m| (m.start, m.neighbor, m.distance))
                        .collect();
                    let names: Vec<String> =
                        cascade.iter().map(|b| b.name()).collect();
                    let ctx = format!(
                        "t={trial} hop={hop} znorm={znorm} cascade={}",
                        names.join("->")
                    );
                    assert_matches_close(&got, &want, tol, &ctx);
                    assert_eq!(report.stats.windows as usize, truth.len());
                    assert_eq!(report.stats.matches as usize, want.len());
                }
            }
        }
    }
}

#[test]
fn top_k_mode_matches_oracle() {
    let mut rng = Rng::seeded(8202);
    for trial in 0..3 {
        let index = library(&mut rng, 6, 24, 2);
        let samples = noisy_stream(&mut rng, &index, 350);
        for &znorm in &[false, true] {
            let mut truth = oracle(&index, &samples, 1, znorm);
            // Oracle top-k: ascending (distance, start).
            truth.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap().then(a.0.cmp(&b.0)));
            for &k in &[1usize, 3, 7] {
                let report = index
                    .subsequence_scan::<Squared>(
                        &samples,
                        SubsequenceOptions::top_k(k).with_znorm(znorm),
                    )
                    .expect("valid options");
                let got: Vec<(u64, f64)> =
                    report.matches.iter().map(|m| (m.start, m.distance)).collect();
                let want: Vec<(u64, f64)> =
                    truth.iter().take(k).map(|&(s, _, d)| (s, d)).collect();
                // Same windows in the same order; distances bit-equal
                // without z-norm, 1e-9 relative with it (rolling-moment
                // normalization — see the module docs).
                assert_eq!(
                    got.iter().map(|&(s, _)| s).collect::<Vec<_>>(),
                    want.iter().map(|&(s, _)| s).collect::<Vec<_>>(),
                    "t={trial} k={k} znorm={znorm}"
                );
                for (&(s, gd), &(_, wd)) in got.iter().zip(want.iter()) {
                    if znorm {
                        assert!(
                            (gd - wd).abs() <= 1e-9 * wd.abs().max(1.0),
                            "t={trial} k={k} start={s}: {gd} vs {wd}"
                        );
                    } else {
                        assert_eq!(gd, wd, "t={trial} k={k} start={s}");
                    }
                }
            }
        }
    }
}

#[test]
fn top_k_under_threshold_combines_both_cutoffs() {
    let mut rng = Rng::seeded(8303);
    let index = library(&mut rng, 6, 24, 2);
    let samples = noisy_stream(&mut rng, &index, 300);
    let mut truth = oracle(&index, &samples, 1, false);
    truth.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap().then(a.0.cmp(&b.0)));
    // A tau between the 2nd and ~10th best window, so k=5 is capped by
    // whichever windows clear it.
    let tau = truth[truth.len().min(10) - 1].2;
    let want: Vec<(u64, f64)> = truth
        .iter()
        .filter(|&&(_, _, d)| d < tau)
        .take(5)
        .map(|&(s, _, d)| (s, d))
        .collect();
    let report = index
        .subsequence_scan::<Squared>(
            &samples,
            SubsequenceOptions::top_k(5).with_threshold(tau),
        )
        .expect("valid options");
    let got: Vec<(u64, f64)> =
        report.matches.iter().map(|m| (m.start, m.distance)).collect();
    assert_eq!(got, want);
    assert!(report.matches.iter().all(|m| m.distance < tau));
}

#[test]
fn parallel_window_scoring_matches_serial_exactly() {
    // Thread-count invariance is pinned *bit-exactly* (same normalized
    // windows, same pruned-DTW kernel — only scheduling differs), in
    // both modes, with and without z-norm.
    let mut rng = Rng::seeded(8909);
    let index = library(&mut rng, 6, 24, 2);
    let samples = noisy_stream(&mut rng, &index, 350);
    let serial_truth = |opts: SubsequenceOptions| {
        index.subsequence_scan::<Squared>(&samples, opts.with_threads(1)).unwrap()
    };
    for &znorm in &[false, true] {
        // Derive a τ with matches on both sides from an unpruned serial
        // pass (top-k never fills, so every window's nearest lands).
        let all = serial_truth(SubsequenceOptions::top_k(100_000).with_znorm(znorm));
        let ds: Vec<f64> = all.matches.iter().map(|m| m.distance).collect();
        assert!(!ds.is_empty());
        let tau = ds[ds.len() / 2].max(1e-9);
        let base = serial_truth(SubsequenceOptions::threshold(tau).with_znorm(znorm));
        let want: Vec<(u64, usize, f64)> =
            base.matches.iter().map(|m| (m.start, m.neighbor, m.distance)).collect();
        for threads in [2usize, 4, 8] {
            let report = index
                .subsequence_scan::<Squared>(
                    &samples,
                    SubsequenceOptions::threshold(tau).with_znorm(znorm).with_threads(threads),
                )
                .unwrap();
            let got: Vec<(u64, usize, f64)> =
                report.matches.iter().map(|m| (m.start, m.neighbor, m.distance)).collect();
            assert_eq!(got, want, "threshold threads={threads} znorm={znorm}");
            assert_eq!(report.stats.windows, base.stats.windows);
        }
        // Top-k mode too (the k-th best cutoff feeds the atomic).
        let base_k = serial_truth(SubsequenceOptions::top_k(5).with_znorm(znorm));
        let want_k: Vec<(u64, f64)> =
            base_k.matches.iter().map(|m| (m.start, m.distance)).collect();
        for threads in [2usize, 4] {
            let report = index
                .subsequence_scan::<Squared>(
                    &samples,
                    SubsequenceOptions::top_k(5).with_znorm(znorm).with_threads(threads),
                )
                .unwrap();
            let got: Vec<(u64, f64)> =
                report.matches.iter().map(|m| (m.start, m.distance)).collect();
            assert_eq!(got, want_k, "top-k threads={threads} znorm={znorm}");
        }
    }
}

#[test]
fn per_stage_stats_are_consistent() {
    let mut rng = Rng::seeded(8404);
    let index = library(&mut rng, 8, 32, 3);
    let samples = noisy_stream(&mut rng, &index, 500);
    let report = index
        .subsequence_scan::<Squared>(&samples, SubsequenceOptions::threshold(1.0))
        .expect("valid options");
    let s = &report.stats;
    assert_eq!(s.samples as usize, samples.len());
    assert_eq!(s.windows, (samples.len() - 32 + 1) as u64);
    assert_eq!(s.candidates, s.windows * index.len() as u64);
    // Stage 0 sees every pair; later stages see what survived.
    assert_eq!(s.stages.len(), 3, "default cascade");
    assert_eq!(s.stages[0].lb_calls, s.candidates);
    for i in 1..s.stages.len() {
        assert_eq!(
            s.stages[i].lb_calls,
            s.stages[i - 1].lb_calls - s.stages[i - 1].pruned,
            "stage {i} sees stage {}'s survivors",
            i - 1
        );
    }
    let last = &s.stages[s.stages.len() - 1];
    assert_eq!(s.dtw_calls, last.lb_calls - last.pruned);
    // The aggregate view adds up.
    let agg = s.to_search_stats();
    assert_eq!(agg.pruned as u64, s.pruned());
    assert_eq!(agg.dtw_calls as u64, s.dtw_calls);
    assert_eq!(
        agg.lb_calls as u64,
        s.stages.iter().map(|st| st.lb_calls).sum::<u64>()
    );
}

#[test]
fn drain_matches_preserves_threshold_results() {
    // Periodic draining (the unbounded-monitor pattern) must not change
    // what is matched — threshold-mode cutoffs ignore the retained set.
    let mut rng = Rng::seeded(8808);
    let index = library(&mut rng, 4, 16, 2);
    let samples = noisy_stream(&mut rng, &index, 300);
    let truth = oracle(&index, &samples, 1, false);
    let mut ds: Vec<f64> = truth.iter().map(|&(_, _, d)| d).collect();
    ds.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let tau = ds[ds.len() / 2];

    let mut searcher = index.subsequence(SubsequenceOptions::threshold(tau)).unwrap();
    let mut drained = Vec::new();
    for &v in &samples {
        let _ = searcher.push::<Squared>(v);
        if searcher.matches().len() >= 4 {
            drained.extend(searcher.drain_matches());
        }
    }
    assert!(searcher.matches().len() < 4, "retention stayed bounded");
    drained.extend(searcher.finish().matches);

    let want: Vec<(u64, f64)> =
        truth.iter().filter(|&&(_, _, d)| d < tau).map(|&(s, _, d)| (s, d)).collect();
    let got: Vec<(u64, f64)> = drained.iter().map(|m| (m.start, m.distance)).collect();
    assert_eq!(got, want);
}

#[test]
fn constant_streams_and_windows_are_handled() {
    // Constant windows z-normalize to all-zeros (the UCR convention);
    // the searcher must stay exact and never panic on zero variance.
    let mut rng = Rng::seeded(8505);
    let index = library(&mut rng, 4, 16, 2);
    let samples = vec![3.25; 120];
    for &znorm in &[false, true] {
        let truth = oracle(&index, &samples, 1, znorm);
        let tau = truth.iter().map(|&(_, _, d)| d).fold(f64::INFINITY, f64::min) * 1.5;
        let report = index
            .subsequence_scan::<Squared>(
                &samples,
                SubsequenceOptions::threshold(tau.max(1e-9)).with_znorm(znorm),
            )
            .expect("valid options");
        let want: Vec<(u64, f64)> = truth
            .iter()
            .filter(|&&(_, _, d)| d < tau.max(1e-9))
            .map(|&(s, _, d)| (s, d))
            .collect();
        let got: Vec<(u64, f64)> =
            report.matches.iter().map(|m| (m.start, m.distance)).collect();
        assert_eq!(got, want, "znorm={znorm}");
    }
}

#[test]
fn streaming_envelope_handles_stream_scale_inputs() {
    // The unit tests in bounds::envelope pin bit-equality on small
    // series; this exercises a long stream in one pass.
    let mut rng = Rng::seeded(8606);
    let s: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
    for &w in &[0usize, 5, 64] {
        let (lo_b, up_b) = envelopes(&s, w);
        let mut env = StreamingEnvelope::new(w);
        let (mut lo_s, mut up_s) = (Vec::new(), Vec::new());
        env.compute_into(&s, &mut lo_s, &mut up_s);
        assert_eq!(lo_s, lo_b, "w={w}");
        assert_eq!(up_s, up_b, "w={w}");
    }
}

#[test]
fn searcher_rejects_inconsistent_options() {
    let mut rng = Rng::seeded(8707);
    let index = library(&mut rng, 3, 12, 1);
    assert!(index.subsequence(SubsequenceOptions::default()).is_err(), "no mode");
    assert!(
        index.subsequence(SubsequenceOptions::threshold(1.0).with_hop(0)).is_err(),
        "hop 0"
    );
    assert!(
        index
            .subsequence(SubsequenceOptions::threshold(1.0).with_cascade(Vec::new()))
            .is_err(),
        "empty cascade"
    );
    assert!(index.subsequence(SubsequenceOptions::top_k(0)).is_err(), "k = 0");
    let empty = DtwIndex::builder(Vec::new()).build().unwrap();
    assert!(empty.subsequence(SubsequenceOptions::threshold(1.0)).is_err(), "empty index");
}
