//! Documentation link-check: every relative markdown link in README.md,
//! ARCHITECTURE.md, docs/protocol.md and docs/benchmarks.md must
//! resolve to a real file or directory, and every `--bench <name>` /
//! `--example <name>` mentioned in those documents must exist as a
//! registered target file. Keeps the architecture/protocol/bench docs
//! from silently rotting as the tree moves.

use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is rust/; the documents live one level up.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("workspace root").to_path_buf()
}

/// The documents under contract. ARCHITECTURE.md and the docs/ files
/// are themselves deliverables — their absence is a failure, not a skip.
fn documents() -> Vec<PathBuf> {
    let root = repo_root();
    vec![
        root.join("README.md"),
        root.join("ARCHITECTURE.md"),
        root.join("docs/protocol.md"),
        root.join("docs/benchmarks.md"),
    ]
}

/// Extract the targets of inline markdown links `](target)`.
fn link_targets(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let bytes = text.as_bytes();
    let mut i = 0;
    while i + 1 < bytes.len() {
        if bytes[i] == b']' && bytes[i + 1] == b'(' {
            if let Some(end) = text[i + 2..].find(')') {
                out.push(text[i + 2..i + 2 + end].to_string());
                i += 2 + end;
                continue;
            }
        }
        i += 1;
    }
    out
}

#[test]
fn markdown_file_links_resolve() {
    for doc in documents() {
        let text = std::fs::read_to_string(&doc)
            .unwrap_or_else(|e| panic!("missing document {}: {e}", doc.display()));
        let dir = doc.parent().unwrap();
        for target in link_targets(&text) {
            let target = target.trim();
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
                || target.starts_with('#')
                || target.is_empty()
            {
                continue;
            }
            // Drop an in-file anchor, keep the path part.
            let path_part = target.split('#').next().unwrap();
            let resolved = dir.join(path_part);
            assert!(
                resolved.exists(),
                "{}: broken link {target:?} (resolved {})",
                doc.display(),
                resolved.display()
            );
        }
    }
}

/// `cargo bench --bench X` / `cargo run --example X` names quoted in the
/// docs must exist as target source files (they are registered by path
/// in rust/Cargo.toml, which itself points at these files).
#[test]
fn cargo_target_names_in_docs_exist() {
    let root = repo_root();
    let mut checked = 0;
    for doc in documents() {
        let text = std::fs::read_to_string(&doc)
            .unwrap_or_else(|e| panic!("missing document {}: {e}", doc.display()));
        let mut tokens = text.split_whitespace().peekable();
        while let Some(tok) = tokens.next() {
            let dir = match tok {
                "--bench" => "benches",
                "--example" => "examples",
                _ => continue,
            };
            let name = match tokens.peek() {
                Some(n) => n.trim_matches(|c: char| !c.is_alphanumeric() && c != '_'),
                None => continue,
            };
            if name.is_empty() {
                continue;
            }
            let file = root.join(dir).join(format!("{name}.rs"));
            assert!(
                file.exists(),
                "{}: `{tok} {name}` names a missing target ({})",
                doc.display(),
                file.display()
            );
            checked += 1;
        }
    }
    assert!(checked > 0, "the docs should mention at least one bench/example target");
}

/// The tier-1 and bench commands quoted in README must reference real
/// Cargo targets: every `[[bench]]`/`[[example]]` path in rust/Cargo.toml
/// must exist on disk (the registration file is the docs' ground truth).
#[test]
fn cargo_toml_target_paths_exist() {
    let root = repo_root();
    let manifest = std::fs::read_to_string(root.join("rust/Cargo.toml")).unwrap();
    let mut checked = 0;
    for line in manifest.lines() {
        let line = line.trim();
        if let Some(rest) = line.strip_prefix("path = ") {
            let rel = rest.trim_matches('"');
            let resolved = root.join("rust").join(rel);
            assert!(resolved.exists(), "rust/Cargo.toml: missing target path {rel:?}");
            checked += 1;
        }
    }
    assert!(checked >= 10, "expected the bench/example registrations, saw {checked}");
}
