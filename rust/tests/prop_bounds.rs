//! Property-test suite for the bound family.
//!
//! The offline build has no `proptest`, so this is a hand-rolled
//! equivalent (DESIGN.md §5): thousands of seeded random cases per
//! invariant, with **shrinking by truncation** — on failure, the harness
//! retries ever-shorter prefixes of the offending pair and reports the
//! smallest still-failing case.

use dtw_bounds::bounds::{BoundKind, PreparedSeries, Scratch};
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::{Absolute, Delta, Squared};
use dtw_bounds::dtw::dtw;

/// Generator for adversarial series pairs: mixes smooth, noisy, spiky,
/// constant and offset regimes — the corners where envelope bounds break
/// if mis-implemented.
fn gen_pair(rng: &mut Rng, n: usize) -> (Vec<f64>, Vec<f64>) {
    let style = rng.below(5);
    let mut mk = |rng: &mut Rng| -> Vec<f64> {
        match style {
            0 => (0..n).map(|_| rng.normal()).collect(),
            1 => {
                // smooth random walk
                let mut v = 0.0;
                (0..n)
                    .map(|_| {
                        v += rng.normal() * 0.2;
                        v
                    })
                    .collect()
            }
            2 => {
                // mostly flat with spikes
                (0..n)
                    .map(|_| if rng.uniform() < 0.1 { rng.normal() * 10.0 } else { 0.0 })
                    .collect()
            }
            3 => {
                // constant + tiny jitter
                let c = rng.normal();
                (0..n).map(|_| c + rng.normal() * 1e-6).collect()
            }
            _ => {
                // sinusoid with random phase/scale
                let phase = rng.uniform() * 6.28;
                let freq = rng.uniform_range(0.05, 0.8);
                let amp = rng.uniform_range(0.1, 5.0);
                (0..n).map(|i| amp * (freq * i as f64 + phase).sin()).collect()
            }
        }
    };
    (mk(rng), mk(rng))
}

/// Check one invariant over many random cases; shrink by truncation on
/// failure.
fn check_cases<F>(cases: usize, seed: u64, min_len: usize, mut f: F)
where
    F: FnMut(&[f64], &[f64], usize) -> Result<(), String>,
{
    let mut rng = Rng::seeded(seed);
    for case in 0..cases {
        let n = rng.int_range(min_len, 120);
        let (a, b) = gen_pair(&mut rng, n);
        let w = rng.below(n + 4); // occasionally > l: must clamp safely
        if let Err(msg) = f(&a, &b, w) {
            // Shrink: shortest prefix (>= min_len) that still fails.
            let mut best = (a.clone(), b.clone(), msg.clone());
            let mut len = n;
            while len > min_len {
                len -= 1;
                let (ta, tb) = (&a[..len], &b[..len]);
                if let Err(m) = f(ta, tb, w) {
                    best = (ta.to_vec(), tb.to_vec(), m);
                }
            }
            panic!(
                "case {case} failed (shrunk to len {}): {}\nA = {:?}\nB = {:?}\nw = {w}",
                best.0.len(),
                best.2,
                best.0,
                best.1
            );
        }
    }
}

fn assert_bound_le_dtw<D: Delta>(
    bound: BoundKind,
    a: &[f64],
    b: &[f64],
    w: usize,
    scratch: &mut Scratch,
) -> Result<(), String> {
    let q = PreparedSeries::prepare(a.to_vec(), w);
    let t = PreparedSeries::prepare(b.to_vec(), w);
    let lb = bound.compute::<D>(&q, &t, w, f64::INFINITY, scratch);
    let d = dtw::<D>(a, b, w);
    let tol = 1e-9 * d.abs().max(1.0);
    if lb > d + tol {
        return Err(format!("{bound}: lb {lb} > dtw {d} (delta {})", D::NAME));
    }
    if lb < 0.0 {
        return Err(format!("{bound}: negative bound {lb}"));
    }
    Ok(())
}

#[test]
fn every_bound_is_a_lower_bound_squared() {
    let mut scratch = Scratch::default();
    check_cases(1500, 0xB0B0, 1, |a, b, w| {
        for &bound in BoundKind::ALL {
            assert_bound_le_dtw::<Squared>(bound, a, b, w, &mut scratch)?;
        }
        Ok(())
    });
}

#[test]
fn every_bound_is_a_lower_bound_absolute() {
    let mut scratch = Scratch::default();
    check_cases(800, 0xABBA, 1, |a, b, w| {
        for &bound in BoundKind::ALL {
            assert_bound_le_dtw::<Absolute>(bound, a, b, w, &mut scratch)?;
        }
        Ok(())
    });
}

#[test]
fn early_abandoned_bounds_stay_below_full_value() {
    // For every bound: compute full, then recompute with a cutoff below
    // it; the partial value must exceed the cutoff but never the full.
    let mut scratch = Scratch::default();
    check_cases(400, 0xCAFE, 2, |a, b, w| {
        let q = PreparedSeries::prepare(a.to_vec(), w);
        let t = PreparedSeries::prepare(b.to_vec(), w);
        for &bound in BoundKind::ALL {
            let full = bound.compute::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            for frac in [0.25, 0.5, 0.9] {
                let cut = full * frac;
                let part = bound.compute::<Squared>(&q, &t, w, cut, &mut scratch);
                if part > cut {
                    if part > full + 1e-9 {
                        return Err(format!("{bound}: partial {part} > full {full}"));
                    }
                } else if (part - full).abs() > 1e-9 {
                    return Err(format!(
                        "{bound}: returned {part} <= cutoff {cut} but full is {full}"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn provable_tightness_orderings() {
    // Pointwise-provable dominances:
    //   Improved >= Keogh, Petitjean_NoLR >= Improved, Webb_NoLR >= Keogh,
    //   WebbEnhanced^k >= Enhanced^k, Webb >= WebbEnhanced^3 (paths beat
    //   bands of depth 3), KimFL <= every LR-path bound's endpoints part.
    let mut scratch = Scratch::default();
    check_cases(700, 0xD00D, 1, |a, b, w| {
        let q = PreparedSeries::prepare(a.to_vec(), w);
        let t = PreparedSeries::prepare(b.to_vec(), w);
        let get = |k: BoundKind, s: &mut Scratch| k.compute::<Squared>(&q, &t, w, f64::INFINITY, s);
        let keogh = get(BoundKind::Keogh, &mut scratch);
        let improved = get(BoundKind::Improved, &mut scratch);
        let pj_nolr = get(BoundKind::PetitjeanNoLr, &mut scratch);
        let webb_nolr = get(BoundKind::WebbNoLr, &mut scratch);
        let tol = 1e-9;
        if improved < keogh - tol {
            return Err(format!("improved {improved} < keogh {keogh}"));
        }
        if pj_nolr < improved - tol {
            return Err(format!("petitjean_nolr {pj_nolr} < improved {improved}"));
        }
        if webb_nolr < keogh - tol {
            return Err(format!("webb_nolr {webb_nolr} < keogh {keogh}"));
        }
        for k in [1usize, 3, 8] {
            let e = get(BoundKind::Enhanced(k), &mut scratch);
            let we = get(BoundKind::WebbEnhanced(k), &mut scratch);
            if we < e - tol {
                return Err(format!("webb_enhanced{k} {we} < enhanced{k} {e}"));
            }
        }
        if a.len() >= 8 {
            let webb = get(BoundKind::Webb, &mut scratch);
            let we3 = get(BoundKind::WebbEnhanced(3), &mut scratch);
            if webb < we3 - tol {
                return Err(format!("webb {webb} < webb_enhanced3 {we3}"));
            }
        }
        Ok(())
    });
}

#[test]
fn keogh_shrinks_as_window_grows_and_all_bound_dtw_at_each_w() {
    // Envelopes widen with w, so LB_KEOGH is provably non-increasing in w.
    // The multi-part bounds (Improved/Petitjean/Webb) are *not* monotone
    // in w — the projection-envelope second pass can grow with the window
    // (observed on spiky series) — so for those we only re-assert the
    // per-window lower-bound invariant against the matching DTW.
    let mut rng = Rng::seeded(0xF00D);
    let mut scratch = Scratch::default();
    for _ in 0..120 {
        let n = rng.int_range(8, 80);
        let (a, b) = gen_pair(&mut rng, n);
        let mut last_keogh = f64::INFINITY;
        for w in [0usize, 1, 2, 4, 8, 16] {
            if w >= n {
                break;
            }
            let q = PreparedSeries::prepare(a.clone(), w);
            let t = PreparedSeries::prepare(b.clone(), w);
            let keogh =
                BoundKind::Keogh.compute::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(
                keogh <= last_keogh + 1e-9,
                "keogh grew with window: w={w} lb={keogh} prev={last_keogh}"
            );
            last_keogh = keogh;
            let d = dtw::<Squared>(&a, &b, w);
            for &bound in &[BoundKind::Improved, BoundKind::Petitjean, BoundKind::Webb] {
                let lb = bound.compute::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
                assert!(lb <= d + 1e-9 * d.max(1.0), "{bound} w={w}: {lb} > {d}");
            }
        }
    }
}

#[test]
fn every_bound_holds_across_window_grid() {
    // Every `BoundKind::ALL` entry — including the §8 cascade variants
    // `Cascade`, `KeoghRev` and `UcrCascade` — must never exceed
    // `dtw::<Squared>` on randomized pairs, re-checked at each of several
    // explicit windows (the random-`w` suites above cannot guarantee
    // coverage of any particular window for any particular pair).
    let windows: &[usize] = &[0, 1, 2, 3, 5, 8, 13, 21, 34];
    for &probe in &[BoundKind::Cascade, BoundKind::KeoghRev, BoundKind::UcrCascade] {
        assert!(BoundKind::ALL.contains(&probe), "{probe} missing from BoundKind::ALL");
    }
    let mut rng = Rng::seeded(0x5EED);
    let mut scratch = Scratch::default();
    for _ in 0..150 {
        let n = rng.int_range(4, 100);
        let (a, b) = gen_pair(&mut rng, n);
        for &w in windows {
            if w > n {
                break;
            }
            let q = PreparedSeries::prepare(a.clone(), w);
            let t = PreparedSeries::prepare(b.clone(), w);
            let d = dtw::<Squared>(&a, &b, w);
            let tol = 1e-9 * d.abs().max(1.0);
            for &bound in BoundKind::ALL {
                let lb = bound.compute::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
                assert!(lb <= d + tol, "{bound} w={w} n={n}: lb {lb} > dtw {d}");
                assert!(lb >= 0.0, "{bound} w={w} n={n}: negative bound {lb}");
            }
        }
    }
}

#[test]
fn identical_series_bound_to_zero() {
    let mut rng = Rng::seeded(0x1DE);
    let mut scratch = Scratch::default();
    for _ in 0..100 {
        let n = rng.int_range(1, 60);
        let (a, _) = gen_pair(&mut rng, n);
        let w = rng.below(n);
        let q = PreparedSeries::prepare(a.clone(), w);
        for &bound in BoundKind::ALL {
            let lb = bound.compute::<Squared>(&q, &q, w, f64::INFINITY, &mut scratch);
            assert_eq!(lb, 0.0, "{bound} non-zero on identical series");
        }
    }
}
