//! Live-mutation property suite — the PR's acceptance contract:
//!
//! * **Cold-rebuild equivalence**: after *any* interleaving of
//!   `insert` / `delete` / `compact`, the live index answers scalar
//!   k-NN, batched and streaming-subsequence queries **bit-identically**
//!   to a cold-built index over the same logical series set, across the
//!   grid shards {1, 3} × clusters {0, 4} × threads {1, 4}.
//! * **Tombstone exclusion**: a deleted series never appears in any
//!   result, before or after compaction.
//! * **Generation rollback**: a saved generation snapshot restores the
//!   exact pre-mutation answers when loaded back (`load=` = rollback),
//!   and a failed load leaves the current index serving.
//! * **Counter conservation**: every delta-shard candidate a search
//!   touches is accounted for — `delta_scanned = delta_pruned +
//!   delta_dtw` — on the k-NN and stream paths alike.

use dtw_bounds::coordinator::NnEngine;
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::data::Dataset;
use dtw_bounds::delta::Squared;
use dtw_bounds::index::{DtwIndex, QueryOptions, QueryOutcome};
use dtw_bounds::stream::SubsequenceOptions;

fn dataset(seed: u64) -> Dataset {
    generate_archive(&ArchiveSpec::new(Scale::Tiny, seed))[0].clone()
}

/// Deterministic splitmix-style generator — interleavings must be
/// reproducible across runs and platforms.
fn next_rand(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

/// The bit-exact comparison currency for k-NN outcomes.
fn pairs(o: &QueryOutcome) -> Vec<(usize, u32, f64)> {
    o.neighbors.iter().map(|n| (n.index, n.label, n.distance)).collect()
}

/// The logical mirror the live engine must always agree with: plain
/// `(values, label)` rows mutated by index, rebuilt cold on demand.
struct Mirror {
    rows: Vec<(Vec<f64>, u32)>,
    window: usize,
    shards: usize,
    clusters: usize,
    threads: usize,
}

impl Mirror {
    fn build(&self) -> DtwIndex {
        let series: Vec<Vec<f64>> = self.rows.iter().map(|(v, _)| v.clone()).collect();
        let labels: Vec<u32> = self.rows.iter().map(|&(_, l)| l).collect();
        let mut b = DtwIndex::builder(series)
            .labels(labels)
            .window(self.window)
            .znormalize(false)
            .shards(self.shards)
            .threads(self.threads);
        if self.clusters > 0 {
            b = b.clusters(self.clusters);
        }
        b.build().expect("mirror series share one length")
    }
}

/// Compare the live engine against a cold rebuild of its mirror on all
/// three search paths.
fn assert_matches_cold(engine: &mut NnEngine, mirror: &Mirror, queries: &[Vec<f64>], tag: &str) {
    let cold = mirror.build();
    let mut cold_engine = NnEngine::from_index(cold);
    // Both sides carry the batched prefilter so multi-query batches
    // exercise the backend path, not just scalar fallback.
    cold_engine.attach_native();

    for q in queries {
        for k in [1usize, 3] {
            let a = engine.query_with(q, &QueryOptions::k(k));
            let b = cold_engine.query_with(q, &QueryOptions::k(k));
            assert_eq!(pairs(&a), pairs(&b), "{tag}: scalar k={k}");
        }
    }

    let items: Vec<(Vec<f64>, QueryOptions)> =
        queries.iter().map(|q| (q.clone(), QueryOptions::k(2))).collect();
    let live_outs = engine.query_batch_with(&items);
    let cold_outs = cold_engine.query_batch_with(&items);
    for (i, (a, b)) in live_outs.iter().zip(cold_outs.iter()).enumerate() {
        assert_eq!(pairs(a), pairs(b), "{tag}: batched item {i}");
    }

    // Stream sweep: filler around two query windows, top-3 matches.
    let mut samples = vec![1e3; 5];
    samples.extend_from_slice(&queries[0]);
    samples.extend(vec![-1e3; 4]);
    samples.extend_from_slice(&queries[1 % queries.len()]);
    let a = engine
        .query_stream(&samples, SubsequenceOptions::top_k(3))
        .expect("valid stream options");
    let b = cold_engine
        .query_stream(&samples, SubsequenceOptions::top_k(3))
        .expect("valid stream options");
    assert_eq!(a.matches, b.matches, "{tag}: stream");
    assert_eq!(a.stats.windows, b.stats.windows, "{tag}: stream windows");
}

#[test]
fn random_mutation_interleavings_match_cold_rebuild_across_the_grid() {
    let ds = dataset(501);
    let w = ds.window.max(1);
    let queries: Vec<Vec<f64>> =
        ds.test.iter().take(3).map(|s| s.values.clone()).collect();
    // Insertion donors: test-split series, cycled.
    let donors: Vec<Vec<f64>> = ds.test.iter().map(|s| s.values.clone()).collect();

    for &shards in &[1usize, 3] {
        for &clusters in &[0usize, 4] {
            for &threads in &[1usize, 4] {
                let tag = format!("shards={shards} clusters={clusters} threads={threads}");
                let mut mirror = Mirror {
                    rows: ds
                        .train
                        .iter()
                        .map(|s| (s.values.clone(), s.label))
                        .collect(),
                    window: w,
                    shards,
                    clusters,
                    threads,
                };
                let mut engine = NnEngine::from_index(mirror.build());
                engine.attach_native();

                let mut rng = 0x5EED_0000 + (shards * 100 + clusters * 10 + threads) as u64;
                let mut next_donor = 0usize;
                for step in 0..10 {
                    let roll = next_rand(&mut rng) % 10;
                    if roll < 4 {
                        let values = donors[next_donor % donors.len()].clone();
                        let label = 100 + next_donor as u32;
                        next_donor += 1;
                        let id = engine.insert(label, values.clone()).unwrap();
                        assert_eq!(id, mirror.rows.len(), "{tag}: insert id, step {step}");
                        mirror.rows.push((values, label));
                    } else if roll < 7 && mirror.rows.len() > 2 {
                        let id = (next_rand(&mut rng) as usize) % mirror.rows.len();
                        engine.delete(id).unwrap();
                        mirror.rows.remove(id);
                    } else {
                        engine.compact().unwrap();
                    }
                    assert_eq!(engine.logical_len(), mirror.rows.len(), "{tag}, step {step}");
                    // Compare at a few checkpoints (every step would be
                    // O(steps) cold rebuilds per grid point).
                    if step % 4 == 3 {
                        assert_matches_cold(&mut engine, &mirror, &queries, &tag);
                    }
                }
                // Always compare the final state, then once more after a
                // closing compaction folds whatever is still pending.
                assert_matches_cold(&mut engine, &mirror, &queries, &tag);
                engine.compact().unwrap();
                assert_eq!(engine.delta_len(), 0, "{tag}");
                assert_matches_cold(&mut engine, &mirror, &queries, &format!("{tag} compacted"));
            }
        }
    }
}

#[test]
fn tombstoned_series_never_appear_in_results() {
    let ds = dataset(502);
    let w = ds.window.max(1);
    let series: Vec<Vec<f64>> = ds.train.iter().map(|s| s.values.clone()).collect();
    let labels: Vec<u32> = ds.train.iter().map(|s| s.label).collect();
    let index = DtwIndex::builder(series.clone())
        .labels(labels)
        .window(w)
        .znormalize(false)
        .build()
        .unwrap();
    let mut engine = NnEngine::from_index(index);

    // Delete physical series 2 (logical 2, nothing deleted before it):
    // querying its own values must no longer return a 0-distance hit at
    // it, even with k covering the whole index.
    let victim = series[2].clone();
    let before = engine.query_with(&victim, &QueryOptions::k(1));
    assert_eq!(before.neighbors[0].distance, 0.0, "sanity: self-match first");
    engine.delete(2).unwrap();

    let k_all = engine.logical_len();
    let out = engine.query_with(&victim, &QueryOptions::k(k_all));
    assert_eq!(out.neighbors.len(), k_all, "k covers every surviving series");
    for n in &out.neighbors {
        assert!(
            n.distance > 0.0,
            "tombstoned series leaked back into the results pre-compaction"
        );
    }
    // A stream window equal to the victim: its best match must be a
    // surviving series, strictly above zero.
    let mut samples = vec![1e3; 3];
    samples.extend_from_slice(&victim);
    samples.extend(vec![-1e3; 3]);
    let report = engine.query_stream(&samples, SubsequenceOptions::top_k(1)).unwrap();
    assert!(report.matches[0].distance > 0.0, "stream resurrects the tombstone");

    // Post-compaction the same holds (the series is physically gone).
    engine.compact().unwrap();
    assert_eq!(engine.index().len(), series.len() - 1);
    let out = engine.query_with(&victim, &QueryOptions::k(k_all));
    for n in &out.neighbors {
        assert!(n.distance > 0.0, "tombstoned series survived compaction");
    }
}

#[test]
fn generation_snapshots_roll_back_to_exact_pre_mutation_results() {
    let ds = dataset(503);
    let index = DtwIndex::builder_from_dataset(&ds).build().unwrap();
    let mut engine = NnEngine::from_index(index);
    let q = ds.test[0].values.clone();
    let want = pairs(&engine.query_with(&q, &QueryOptions::k(3)));

    let base = std::env::temp_dir()
        .join(format!("dtwb_live_gen_{}.snap", std::process::id()));
    let (g0_path, bytes) = engine.save_generation(&base).unwrap();
    assert!(bytes > 0);
    assert!(g0_path.to_string_lossy().ends_with(".g0"), "{g0_path:?}");

    // Mutate and compact into generation 1; answers change shape.
    engine.insert(77, ds.test[1].values.clone()).unwrap();
    engine.delete(0).unwrap();
    engine.compact().unwrap();
    assert_eq!(engine.generation(), 1);
    let (g1_path, _) = engine.save_generation(&base).unwrap();
    assert!(g1_path.to_string_lossy().ends_with(".g1"), "{g1_path:?}");
    assert_ne!(g0_path, g1_path, "each generation keeps its own file");
    let info = engine.generations();
    assert_eq!(info.generation, 1);
    assert_eq!(info.parent, 0);
    assert_eq!(
        info.saved.iter().map(|&(g, _)| g).collect::<Vec<_>>(),
        vec![0, 1],
        "both snapshots recorded as rollback targets"
    );

    // A failed load leaves the current generation serving…
    let missing = std::env::temp_dir().join("dtwb_live_gen_missing.snap");
    assert!(DtwIndex::load(&missing).is_err());
    assert_eq!(engine.generation(), 1, "failed load must not disturb the engine");

    // …and loading generation 0 is an exact rollback.
    let g0 = DtwIndex::load(&g0_path).unwrap();
    assert_eq!(g0.generation(), 0);
    engine.replace_index(g0);
    let got = pairs(&engine.query_with(&q, &QueryOptions::k(3)));
    assert_eq!(got, want, "rollback restores the pre-mutation answers exactly");

    std::fs::remove_file(&g0_path).ok();
    std::fs::remove_file(&g1_path).ok();
}

#[test]
fn delta_counters_are_conserved_on_knn_and_stream_paths() {
    let ds = dataset(504);
    let index = DtwIndex::builder_from_dataset(&ds).znormalize(false).build().unwrap();
    let mut engine = NnEngine::from_index(index);
    for (i, s) in ds.test.iter().take(3).enumerate() {
        engine.insert(200 + i as u32, s.values.clone()).unwrap();
    }
    engine.delete(1).unwrap();

    // Scalar k-NN: every pending insert is scanned exactly once, and
    // each scan ends in exactly one of {pruned, DTW}.
    let out = engine.query_with(&ds.test[3].values, &QueryOptions::k(3));
    assert_eq!(out.stats.delta_scanned, 3, "one scan per delta entry");
    assert_eq!(
        out.stats.delta_scanned,
        out.stats.delta_pruned + out.stats.delta_dtw,
        "every scanned delta candidate is either pruned or DTW'd"
    );
    assert!(out.stats.dtw_calls >= out.stats.delta_dtw, "delta DTW is a subset");

    // Batched path: conservation per outcome.
    let items: Vec<(Vec<f64>, QueryOptions)> = ds
        .test
        .iter()
        .skip(3)
        .take(3)
        .map(|s| (s.values.clone(), QueryOptions::k(2)))
        .collect();
    for (i, o) in engine.query_batch_with(&items).iter().enumerate() {
        assert_eq!(o.stats.delta_scanned, 3, "batched item {i}");
        assert_eq!(
            o.stats.delta_scanned,
            o.stats.delta_pruned + o.stats.delta_dtw,
            "batched item {i}"
        );
    }

    // Stream path: one scan per delta entry per evaluated window.
    let mut samples = vec![1e3; 4];
    samples.extend_from_slice(&ds.test[3].values);
    samples.extend(vec![-1e3; 4]);
    let report = engine.query_stream(&samples, SubsequenceOptions::top_k(2)).unwrap();
    let s = &report.stats;
    assert_eq!(
        s.delta_scanned,
        s.windows * 3,
        "each window's sweep visits all three delta entries"
    );
    assert_eq!(
        s.delta_scanned,
        s.delta_pruned + s.delta_dtw,
        "stream delta scans are conserved"
    );
    assert!(s.dtw_calls >= s.delta_dtw);
}

#[test]
fn mass_tombstoning_with_k_beyond_survivors_truncates_like_cold_rebuild() {
    let ds = dataset(505);
    let w = ds.window.max(1);
    let n = ds.train.len();
    let mut mirror = Mirror {
        rows: ds.train.iter().map(|s| (s.values.clone(), s.label)).collect(),
        window: w,
        shards: 1,
        clusters: 0,
        threads: 1,
    };
    let mut engine = NnEngine::from_index(mirror.build());
    engine.attach_native();

    // Tombstone all but three base series (front-loaded: repeatedly
    // deleting logical id 0 shifts every survivor's id down each time).
    for _ in 0..n - 3 {
        engine.delete(0).unwrap();
        mirror.rows.remove(0);
    }
    assert_eq!(engine.logical_len(), 3);

    // k far beyond the survivor count: exactly the survivors come back,
    // bit-identical to a cold rebuild over the same three rows.
    let q = ds.test[0].values.clone();
    let out = engine.query_with(&q, &QueryOptions::k(n + 5));
    assert_eq!(out.neighbors.len(), 3, "k > survivors truncates to the survivors");
    let cold = NnEngine::from_index(mirror.build())
        .query_with(&q, &QueryOptions::k(n + 5));
    assert_eq!(pairs(&out), pairs(&cold), "mass tombstoning: scalar over-ask");
    assert_eq!(
        out.stats.delta_scanned,
        out.stats.delta_pruned + out.stats.delta_dtw,
        "conservation with an all-tombstone-heavy base"
    );
    // Full-path agreement (scalar, batched, stream) in the same state.
    let queries: Vec<Vec<f64>> = ds.test.iter().take(2).map(|s| s.values.clone()).collect();
    assert_matches_cold(&mut engine, &mirror, &queries, "mass tombstoning");

    // Compaction physically drops the tombstones and answers still agree.
    engine.compact().unwrap();
    assert_eq!(engine.index().len(), 3);
    assert_matches_cold(&mut engine, &mirror, &queries, "mass tombstoning compacted");
}

#[test]
fn delta_only_engine_with_fully_tombstoned_base_matches_cold_rebuild() {
    let ds = dataset(506);
    let w = ds.window.max(1);
    // Start from a deliberately tiny base of two series…
    let base: Vec<Vec<f64>> = ds.train.iter().take(2).map(|s| s.values.clone()).collect();
    let base_labels: Vec<u32> = ds.train.iter().take(2).map(|s| s.label).collect();
    let index = DtwIndex::builder(base)
        .labels(base_labels)
        .window(w)
        .znormalize(false)
        .build()
        .unwrap();
    let mut engine = NnEngine::from_index(index);
    engine.attach_native();
    let mut mirror = Mirror {
        rows: ds.train.iter().take(2).map(|s| (s.values.clone(), s.label)).collect(),
        window: w,
        shards: 1,
        clusters: 0,
        threads: 1,
    };

    // …insert four delta rows, then tombstone the entire base: every
    // surviving row now lives in the delta shard.
    for (i, s) in ds.test.iter().take(4).enumerate() {
        let id = engine.insert(300 + i as u32, s.values.clone()).unwrap();
        assert_eq!(id, mirror.rows.len());
        mirror.rows.push((s.values.clone(), 300 + i as u32));
    }
    engine.delete(0).unwrap();
    mirror.rows.remove(0);
    engine.delete(0).unwrap();
    mirror.rows.remove(0);
    assert_eq!(engine.logical_len(), 4, "only the delta rows survive");

    let q = ds.test[5 % ds.test.len()].values.clone();
    let out = engine.query_with(&q, &QueryOptions::k(3));
    assert_eq!(out.stats.delta_scanned, 4, "all survivors are delta entries");
    assert_eq!(out.stats.delta_scanned, out.stats.delta_pruned + out.stats.delta_dtw);
    let queries: Vec<Vec<f64>> = ds.test.iter().take(2).map(|s| s.values.clone()).collect();
    assert_matches_cold(&mut engine, &mirror, &queries, "delta-only");

    // Compacting a fully-tombstoned base folds the delta into the new
    // base exactly.
    engine.compact().unwrap();
    assert_eq!(engine.delta_len(), 0);
    assert_eq!(engine.index().len(), 4);
    assert_matches_cold(&mut engine, &mirror, &queries, "delta-only compacted");
}

#[test]
fn over_ask_exceeding_base_size_via_tombstone_compensation_stays_exact() {
    let ds = dataset(507);
    let w = ds.window.max(1);
    let n = ds.train.len();
    let mut mirror = Mirror {
        rows: ds.train.iter().map(|s| (s.values.clone(), s.label)).collect(),
        window: w,
        shards: 3,
        clusters: 4,
        threads: 1,
    };
    let mut engine = NnEngine::from_index(mirror.build());
    engine.attach_native();

    // Tombstone more than half the base, keeping 4 survivors, so any
    // internal "fetch k + |tombstones|" compensation overshoots the
    // physical base size: k + |T| = 4 + (n - 4) = n > base survivors.
    let tombstones = n - 4;
    for _ in 0..tombstones {
        engine.delete(0).unwrap();
        mirror.rows.remove(0);
    }
    assert_eq!(engine.logical_len(), 4);

    // k equal to the survivor count: the full (exact) ranking of
    // everything that is left, bit-identical to the cold rebuild.
    let q = ds.test[0].values.clone();
    let out = engine.query_with(&q, &QueryOptions::k(4));
    assert_eq!(out.neighbors.len(), 4);
    let cold = NnEngine::from_index(mirror.build()).query_with(&q, &QueryOptions::k(4));
    assert_eq!(pairs(&out), pairs(&cold), "over-ask with |T| >= survivors");
    let queries: Vec<Vec<f64>> = ds.test.iter().take(2).map(|s| s.values.clone()).collect();
    assert_matches_cold(&mut engine, &mirror, &queries, "over-ask");
}
