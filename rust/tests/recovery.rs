//! Crash-recovery property suite: enumerate **every** crash point of the
//! durability layer's write/fsync/rename sequences under deterministic
//! fault injection ([`dtw_bounds::io::FaultFs`]) and prove the recovery
//! contract:
//!
//! * the snapshot save is atomic at the published path — after a crash
//!   at any op, the path holds the complete pre-save bytes or the
//!   complete post-save bytes, never a hybrid, and always loads;
//! * a WAL-logged mutation acked after its fsync survives power loss
//!   (`DropUnsynced`), and recovery from any append crash point yields
//!   exactly the acked prefix or acked-plus-in-flight — bit-equal (by
//!   k-NN fingerprint) to a cold rebuild that applied the same prefix;
//! * compact's log rotation recovers, from every crash point, a state
//!   bit-equal to the uninterrupted run (pre- and post-rotation are the
//!   same logical index);
//! * fsync policies bound the loss window exactly: `every:<n>` loses at
//!   most the unsynced tail, `never` still survives process death.
//!
//! The crash points are discovered, not hard-coded: a clean run records
//! the op trace, then each test re-runs the identical history once per
//! `(op, crash style, torn-write variant)` triple.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use dtw_bounds::coordinator::NnEngine;
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::data::Dataset;
use dtw_bounds::index::snapshot::{load_with, save_with};
use dtw_bounds::index::DtwIndex;
use dtw_bounds::io::{CrashStyle, FaultFs, FaultPlan};
use dtw_bounds::live::{FsyncPolicy, WalOp};

fn tiny(seed: u64) -> (Dataset, DtwIndex) {
    let ds = generate_archive(&ArchiveSpec::new(Scale::Tiny, seed))[0].clone();
    let index = DtwIndex::builder_from_dataset(&ds).build().unwrap();
    (ds, index)
}

fn anchor() -> PathBuf {
    PathBuf::from("served.snap")
}

fn engine_on(fs: &FaultFs, index: DtwIndex) -> NnEngine {
    let mut engine = NnEngine::from_index(index);
    engine.set_fs(Arc::new(fs.clone()));
    engine
}

/// Exact-answer fingerprint: winner index, label, and the raw f64 bits
/// of the distance for each probe — the bit-equality oracle.
fn fingerprint(engine: &mut NnEngine, queries: &[Vec<f64>]) -> Vec<(usize, u32, u64)> {
    queries
        .iter()
        .map(|q| {
            let r = engine.query_one(q);
            (r.result.nn_index, r.result.label, r.result.distance.to_bits())
        })
        .collect()
}

fn apply(engine: &mut NnEngine, op: &WalOp) {
    match op {
        WalOp::Insert { label, values } => {
            engine.insert(*label, values.clone()).unwrap();
        }
        WalOp::Delete { id } => engine.delete(*id as usize).unwrap(),
    }
}

/// The fingerprint of `index` with the first `k` of `ops` applied
/// through a fresh, never-crashed engine (no fs, no WAL).
fn prefix_fingerprint(
    index: &DtwIndex,
    ops: &[WalOp],
    k: usize,
    queries: &[Vec<f64>],
) -> Vec<(usize, u32, u64)> {
    let mut cold = NnEngine::from_index(index.clone());
    for op in &ops[..k] {
        apply(&mut cold, op);
    }
    fingerprint(&mut cold, queries)
}

/// A probe series of the index's length, distinct per `k`.
fn series(m: usize, k: usize) -> Vec<f64> {
    (0..m).map(|i| i as f64 * 0.25 + k as f64).collect()
}

fn seed_snapshot(fs: &FaultFs, index: &DtwIndex, target: &Path) {
    save_with(index, target, fs).unwrap();
}

#[test]
fn every_snapshot_save_crash_point_recovers_pre_or_post() {
    let (_, old) = tiny(90);
    let (_, new) = tiny(91);
    let target = anchor();

    // Clean run pins the crash-point space and the post-state bytes.
    let clean = FaultFs::new();
    save_with(&old, &target, &clean).unwrap();
    let pre_bytes = clean.get(&target).unwrap();
    let start = clean.op_count();
    save_with(&new, &target, &clean).unwrap();
    let post_bytes = clean.get(&target).unwrap();
    let save_ops = clean.op_count() - start;
    assert_eq!(save_ops, 8, "create + 5 writes + sync + rename");
    assert_ne!(pre_bytes, post_bytes, "the two indexes must differ");

    let mut runs = 0;
    for crash_at in start..start + save_ops {
        // `put` does not trace, so re-running over a seeded pre-state
        // keeps the same op indices as the clean second save.
        let crash_at = crash_at - start;
        for style in [CrashStyle::KeepAll, CrashStyle::DropUnsynced] {
            for torn in [0usize, 1, 7] {
                let plan = if torn == 0 {
                    FaultPlan::fail_op(crash_at)
                } else {
                    FaultPlan::torn_write(crash_at, torn)
                };
                let fs = FaultFs::with_plan(plan);
                fs.put(&target, &pre_bytes);
                save_with(&new, &target, &fs)
                    .expect_err("the planned op must fail the save");
                assert!(fs.crashed(), "crash_at={crash_at} fired");

                let disk = fs.restart(style);
                let got = disk
                    .get(&target)
                    .expect("the published path never disappears");
                assert!(
                    got == pre_bytes || got == post_bytes,
                    "crash_at={crash_at} style={style:?} torn={torn}: \
                     hybrid bytes at the published path"
                );
                // Whichever state survived, it loads cleanly.
                load_with(&target, &disk).expect("recovered snapshot loads");
                runs += 1;
            }
        }
    }
    assert_eq!(runs, save_ops * 2 * 3, "full crash-point coverage");
}

#[test]
fn acked_after_fsync_mutations_survive_power_loss_bit_equal() {
    let (ds, index) = tiny(92);
    let m = index.train().series[0].values.len();
    let ramp = series(m, 0);
    let queries: Vec<Vec<f64>> = ds
        .test
        .iter()
        .take(3)
        .map(|s| s.values.clone())
        .chain([ramp.clone()])
        .collect();
    let target = anchor();

    let fs = FaultFs::new();
    seed_snapshot(&fs, &index, &target);
    let mut live = engine_on(&fs, index.clone());
    let replay = live.enable_wal(&target, FsyncPolicy::Always).unwrap();
    assert_eq!(replay.records, 0, "fresh anchor, empty log");
    live.insert(7, ramp.clone()).unwrap();
    live.delete(0).unwrap();
    let want = fingerprint(&mut live, &queries);

    // Power loss: everything unsynced is gone. Both mutations were
    // fsynced before their ack, so both survive.
    let disk = fs.restart(CrashStyle::DropUnsynced);
    let mut revived = engine_on(&disk, load_with(&target, &disk).unwrap());
    let replay = revived.enable_wal(&target, FsyncPolicy::Always).unwrap();
    assert_eq!(replay.records, 2, "both acked mutations replayed");
    assert!(!replay.truncated, "fsync=always leaves no torn tail to drop");
    assert_eq!(fingerprint(&mut revived, &queries), want, "recovery is bit-equal");

    // And the whole WAL path is bit-equal to a cold rebuild that never
    // saw a snapshot, a log, or a crash.
    let ops = [WalOp::Insert { label: 7, values: ramp }, WalOp::Delete { id: 0 }];
    assert_eq!(
        prefix_fingerprint(&index, &ops, 2, &queries),
        want,
        "wal replay == cold rebuild"
    );
}

#[test]
fn every_wal_append_crash_point_recovers_acked_or_acked_plus_in_flight() {
    let (ds, index) = tiny(93);
    let m = index.train().series[0].values.len();
    let ops = [
        WalOp::Insert { label: 100, values: series(m, 1) },
        WalOp::Insert { label: 101, values: series(m, 2) },
        WalOp::Delete { id: 0 },
    ];
    let queries: Vec<Vec<f64>> = ds
        .test
        .iter()
        .take(2)
        .map(|s| s.values.clone())
        .chain((1..=2).map(|k| series(m, k)))
        .collect();
    let target = anchor();

    // Clean run: pin the append region's op extent.
    let clean = FaultFs::new();
    seed_snapshot(&clean, &index, &target);
    let mut engine = engine_on(&clean, index.clone());
    engine.enable_wal(&target, FsyncPolicy::Always).unwrap();
    let setup_ops = clean.op_count();
    for op in &ops {
        apply(&mut engine, op);
    }
    let append_ops = clean.op_count() - setup_ops;
    assert_eq!(append_ops, 2 * ops.len(), "each fsync=always append is write + sync");

    // Ground truth for every possible recovered prefix.
    let fp: Vec<_> =
        (0..=ops.len()).map(|k| prefix_fingerprint(&index, &ops, k, &queries)).collect();

    for crash_at in setup_ops..setup_ops + append_ops {
        for style in [CrashStyle::KeepAll, CrashStyle::DropUnsynced] {
            for torn in [0usize, 5] {
                let plan = if torn == 0 {
                    FaultPlan::fail_op(crash_at)
                } else {
                    FaultPlan::torn_write(crash_at, torn)
                };
                let fs = FaultFs::with_plan(plan);
                seed_snapshot(&fs, &index, &target);
                let mut engine = engine_on(&fs, index.clone());
                engine.enable_wal(&target, FsyncPolicy::Always).unwrap();

                // Replay the history; after the crash point fires, every
                // further mutation must be refused (not half-applied).
                let mut acked = 0usize;
                let mut alive = true;
                for op in &ops {
                    let outcome = match op {
                        WalOp::Insert { label, values } => {
                            engine.insert(*label, values.clone()).map(|_| ())
                        }
                        WalOp::Delete { id } => engine.delete(*id as usize),
                    };
                    match outcome {
                        Ok(()) => {
                            assert!(alive, "no acks after a failed mutation");
                            acked += 1;
                        }
                        Err(_) => alive = false,
                    }
                }
                assert!(acked < ops.len(), "the crash must refuse something");

                let disk = fs.restart(style);
                let mut revived = engine_on(&disk, load_with(&target, &disk).unwrap());
                let replay =
                    revived.enable_wal(&target, FsyncPolicy::Always).unwrap();
                let recovered = replay.records as usize;
                let ctx = format!("crash_at={crash_at} style={style:?} torn={torn}");
                assert!(
                    recovered == acked || recovered == acked + 1,
                    "{ctx}: recovered {recovered}, acked {acked} — \
                     not a pre-or-post state"
                );
                assert_eq!(
                    fingerprint(&mut revived, &queries),
                    fp[recovered],
                    "{ctx}: recovered state is not bit-equal to the \
                     first {recovered} mutations"
                );
                if style == CrashStyle::DropUnsynced {
                    // Power loss with fsync=always: *exactly* the acked
                    // set — the in-flight record was never durable.
                    assert_eq!(recovered, acked, "{ctx}");
                }
            }
        }
    }
}

#[test]
fn every_compact_rotation_crash_point_recovers_bit_equal() {
    let (ds, index) = tiny(94);
    let m = index.train().series[0].values.len();
    let ramp = series(m, 3);
    let ops =
        [WalOp::Insert { label: 9, values: ramp.clone() }, WalOp::Delete { id: 1 }];
    let queries: Vec<Vec<f64>> = ds
        .test
        .iter()
        .take(3)
        .map(|s| s.values.clone())
        .chain([ramp])
        .collect();
    let target = anchor();

    // The one logical state every recovery must reproduce.
    let want = prefix_fingerprint(&index, &ops, 2, &queries);

    // Clean run: pin the rotation's op extent and post state.
    let clean = FaultFs::new();
    seed_snapshot(&clean, &index, &target);
    let mut engine = engine_on(&clean, index.clone());
    engine.enable_wal(&target, FsyncPolicy::Always).unwrap();
    for op in &ops {
        apply(&mut engine, op);
    }
    let start = clean.op_count();
    engine.compact().unwrap();
    let rotation_ops = clean.op_count() - start;
    assert_eq!(
        rotation_ops,
        2 + 8 + 1,
        "new log (create + sync), snapshot save (8), remove old log"
    );
    let old_log = dtw_bounds::live::wal::wal_path(&target, 0);
    let new_log = dtw_bounds::live::wal::wal_path(&target, 1);
    assert!(clean.get(&old_log).is_none(), "superseded log removed");
    assert!(clean.get(&new_log).unwrap().is_empty(), "fresh empty log for gen 1");
    assert_eq!(load_with(&target, &clean).unwrap().generation(), 1);

    for crash_at in start..start + rotation_ops {
        for style in [CrashStyle::KeepAll, CrashStyle::DropUnsynced] {
            for torn in [0usize, 3] {
                let plan = if torn == 0 {
                    FaultPlan::fail_op(crash_at)
                } else {
                    FaultPlan::torn_write(crash_at, torn)
                };
                let fs = FaultFs::with_plan(plan);
                seed_snapshot(&fs, &index, &target);
                let mut engine = engine_on(&fs, index.clone());
                engine.enable_wal(&target, FsyncPolicy::Always).unwrap();
                for op in &ops {
                    apply(&mut engine, op);
                }
                let compacted = engine.compact();
                if crash_at == start + rotation_ops - 1 {
                    // Removing the superseded log is best-effort: the
                    // new state is already durable, so this op's failure
                    // is not an error (the orphan can never replay).
                    assert!(compacted.is_ok(), "remove is best-effort");
                } else {
                    assert!(compacted.is_err(), "crash_at={crash_at} fails compact");
                }

                let disk = fs.restart(style);
                let base = load_with(&target, &disk).expect("anchor always loads");
                let generation = base.generation();
                let ctx = format!("crash_at={crash_at} style={style:?} torn={torn}");
                assert!(
                    generation == 0 || generation == 1,
                    "{ctx}: impossible generation {generation}"
                );
                let mut revived = engine_on(&disk, base);
                let replay =
                    revived.enable_wal(&target, FsyncPolicy::Always).unwrap();
                let expected_records = if generation == 1 { 0 } else { 2 };
                assert_eq!(replay.records, expected_records, "{ctx}");
                assert_eq!(
                    fingerprint(&mut revived, &queries),
                    want,
                    "{ctx}: pre- and post-rotation are the same logical \
                     state, so every recovery must be bit-equal"
                );
            }
        }
    }
}

#[test]
fn fsync_window_bounds_the_loss_to_the_unsynced_tail_only() {
    let (ds, index) = tiny(95);
    let m = index.train().series[0].values.len();
    let ops = [
        WalOp::Insert { label: 1, values: series(m, 1) },
        WalOp::Insert { label: 2, values: series(m, 2) },
        WalOp::Insert { label: 3, values: series(m, 3) },
        WalOp::Delete { id: 0 },
    ];
    let queries: Vec<Vec<f64>> = ds
        .test
        .iter()
        .take(2)
        .map(|s| s.values.clone())
        .chain((1..=3).map(|k| series(m, k)))
        .collect();
    let fp: Vec<_> =
        (0..=ops.len()).map(|k| prefix_fingerprint(&index, &ops, k, &queries)).collect();
    let target = anchor();

    // every:3 — records 1-3 are synced as a batch; record 4 is only in
    // the page cache when the plug is pulled.
    let policy = FsyncPolicy::parse("every:3").unwrap();
    let fs = FaultFs::new();
    seed_snapshot(&fs, &index, &target);
    let mut engine = engine_on(&fs, index.clone());
    engine.enable_wal(&target, policy).unwrap();
    for op in &ops {
        apply(&mut engine, op);
    }

    // Process death (the kernel holds the bytes): all four acks survive.
    let killed = fs.restart(CrashStyle::KeepAll);
    let mut revived = engine_on(&killed, load_with(&target, &killed).unwrap());
    let replay = revived.enable_wal(&target, policy).unwrap();
    assert_eq!(replay.records, 4, "process death loses nothing");
    assert_eq!(fingerprint(&mut revived, &queries), fp[4]);

    // Power loss: exactly the synced prefix — the documented `every:n`
    // loss window, never a torn or hybrid state.
    let powerless = fs.restart(CrashStyle::DropUnsynced);
    let mut revived = engine_on(&powerless, load_with(&target, &powerless).unwrap());
    let replay = revived.enable_wal(&target, policy).unwrap();
    assert_eq!(replay.records, 3, "the unsynced fourth record is gone");
    assert!(!replay.truncated, "loss lands on a record boundary");
    assert_eq!(fingerprint(&mut revived, &queries), fp[3]);
}
