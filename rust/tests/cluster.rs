//! Property tests for the cluster-pruning layer: an index built with
//! `clusters > 0` must return **bit-identical** results to the flat
//! (clusterless) index on every search path — scalar k-NN, the batched
//! native prefilter, and the streaming subsequence scan — at every
//! cluster count, shard count and thread count. Cluster-level skipping
//! is a pure work filter (merged-envelope containment makes the cluster
//! bound a valid lower bound for every member), so nothing about the
//! answers may change: same neighbor indices, same raw distance bits,
//! same tie-breaking.

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::Squared;
use dtw_bounds::index::{DtwIndex, QueryOptions, QueryOutcome};
use dtw_bounds::search::SearchStrategy;
use dtw_bounds::stream::SubsequenceOptions;

/// Smooth random-walk series around a per-family offset so the pool has
/// real cluster structure (some clusters prune, some don't).
fn family_series(rng: &mut Rng, n: usize, l: usize, families: usize) -> Vec<Vec<f64>> {
    (0..n)
        .map(|i| {
            let mut v = 3.0 * (i % families.max(1)) as f64;
            (0..l)
                .map(|_| {
                    v += rng.normal() * 0.4;
                    v
                })
                .collect()
        })
        .collect()
}

fn pairs(out: &QueryOutcome) -> Vec<(usize, u64)> {
    // Compare raw distance bits: "bit-equal" literally.
    out.neighbors.iter().map(|n| (n.index, n.distance.to_bits())).collect()
}

/// The grid the whole file sweeps: every cluster count × shard count ×
/// thread count, against the flat (clusters = 0) serial baseline.
const CLUSTER_GRID: [usize; 4] = [0, 1, 2, 5];
const SHARD_GRID: [usize; 2] = [1, 3];
const THREAD_GRID: [usize; 2] = [1, 4];

#[test]
fn clustered_scalar_knn_is_bit_equal_to_flat() {
    let mut rng = Rng::seeded(0xC0DE);
    let train = family_series(&mut rng, 60, 40, 6);
    let queries = family_series(&mut rng, 5, 40, 6);
    let w = 4;

    let flat = DtwIndex::builder(train.clone())
        .window(w)
        .bound(BoundKind::Webb)
        .build()
        .expect("one shared length");
    let mut flat_searcher = flat.searcher();

    for q in &queries {
        for k in [1usize, 3, 10] {
            // Plain, thresholded, and excluded variants — the cutoff
            // interacts with cluster skipping, so pin all three.
            let tau = flat_searcher
                .query_values::<Squared>(q, &QueryOptions::k(3))
                .distances()
                .last()
                .copied()
                .unwrap_or(f64::INFINITY);
            let variants = [
                QueryOptions::k(k),
                QueryOptions::k(k).with_abandon_at(tau),
                QueryOptions::k(k).with_exclude(7),
            ];
            for (vi, opts) in variants.iter().enumerate() {
                let want = pairs(&flat_searcher.query_values::<Squared>(q, opts));
                for &clusters in &CLUSTER_GRID {
                    for &shards in &SHARD_GRID {
                        for &threads in &THREAD_GRID {
                            let index = DtwIndex::builder(train.clone())
                                .window(w)
                                .bound(BoundKind::Webb)
                                .shards(shards)
                                .clusters(clusters)
                                .threads(threads)
                                .build()
                                .expect("one shared length");
                            assert_eq!(index.has_clusters(), clusters > 0);
                            let out =
                                index.searcher().query_values::<Squared>(q, opts);
                            assert_eq!(
                                pairs(&out),
                                want,
                                "k={k} variant={vi} clusters={clusters} \
                                 shards={shards} threads={threads}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn clustered_batched_prefilter_is_bit_equal_to_flat() {
    let mut rng = Rng::seeded(0xBA7C);
    let train = family_series(&mut rng, 48, 32, 5);
    let queries = family_series(&mut rng, 6, 32, 5);
    let w = 3;

    let build = |clusters: usize, shards: usize, threads: usize| {
        DtwIndex::builder(train.clone())
            .window(w)
            .bound(BoundKind::Keogh)
            .strategy(SearchStrategy::SortedPrecomputed)
            .shards(shards)
            .clusters(clusters)
            .threads(threads)
            .build()
            .expect("one shared length")
    };

    let flat = build(0, 1, 1);
    let mut flat_searcher = flat.searcher();
    assert_eq!(flat_searcher.backend_name(), Some("native"));
    for k in [1usize, 4] {
        let opts = QueryOptions::k(k);
        let want: Vec<Vec<(usize, u64)>> = flat_searcher
            .query_batch::<Squared>(&queries, &opts)
            .iter()
            .map(pairs)
            .collect();
        for &clusters in &CLUSTER_GRID {
            for &shards in &SHARD_GRID {
                for &threads in &THREAD_GRID {
                    let index = build(clusters, shards, threads);
                    let outs =
                        index.searcher().query_batch::<Squared>(&queries, &opts);
                    assert!(
                        outs.iter().all(|o| o.batched),
                        "batch must ride the native prefilter"
                    );
                    let got: Vec<Vec<(usize, u64)>> = outs.iter().map(pairs).collect();
                    assert_eq!(
                        got, want,
                        "k={k} clusters={clusters} shards={shards} threads={threads}"
                    );
                }
            }
        }
    }
}

#[test]
fn clustered_stream_scan_is_bit_equal_to_flat() {
    let mut rng = Rng::seeded(0x57E4);
    let patterns = family_series(&mut rng, 8, 24, 4);
    let mut samples = Vec::new();
    for _ in 0..12 {
        let p = &patterns[rng.below(patterns.len())];
        samples.extend(p.iter().map(|v| v + rng.normal() * 0.05));
    }
    let w = 2;

    let build = |clusters: usize, shards: usize, threads: usize| {
        DtwIndex::builder(patterns.clone())
            .window(w)
            .shards(shards)
            .clusters(clusters)
            .threads(threads)
            .build()
            .expect("one shared length")
    };

    let flat = build(0, 1, 1);
    // Threshold with matches on both sides, plus a top-k sweep: both
    // modes drive the window cutoff differently.
    let probe = flat
        .subsequence_scan::<Squared>(&samples, SubsequenceOptions::top_k(5))
        .expect("valid options");
    let tau = probe.matches.last().map(|m| m.distance * 1.001).unwrap_or(1.0);
    let modes =
        [SubsequenceOptions::threshold(tau).with_hop(3), SubsequenceOptions::top_k(4)];

    for (mi, mode) in modes.iter().enumerate() {
        let want = flat
            .subsequence_scan::<Squared>(&samples, mode.clone())
            .expect("valid options");
        let want_matches: Vec<(u64, usize, u64)> = want
            .matches
            .iter()
            .map(|m| (m.start, m.neighbor, m.distance.to_bits()))
            .collect();
        assert!(!want_matches.is_empty(), "degenerate mode {mi}");
        for &clusters in &CLUSTER_GRID {
            for &shards in &SHARD_GRID {
                for &threads in &THREAD_GRID {
                    let index = build(clusters, shards, threads);
                    let got = index
                        .subsequence_scan::<Squared>(
                            &samples,
                            mode.clone().with_threads(threads),
                        )
                        .expect("valid options");
                    let got_matches: Vec<(u64, usize, u64)> = got
                        .matches
                        .iter()
                        .map(|m| (m.start, m.neighbor, m.distance.to_bits()))
                        .collect();
                    assert_eq!(
                        got_matches, want_matches,
                        "mode={mi} clusters={clusters} shards={shards} threads={threads}"
                    );
                }
            }
        }
    }
}

/// Degenerate pool: every candidate identical. Farthest-first seeding
/// then sees zero proxy distances everywhere; with `clusters = n` every
/// member becomes its own singleton pivot and nothing may panic, loop,
/// or change the (tie-broken lowest-index) answer.
#[test]
fn all_identical_series_with_singleton_clusters_is_sound() {
    let series: Vec<Vec<f64>> = vec![vec![1.5; 16]; 9];
    let q = vec![1.5f64; 16];
    let flat = DtwIndex::builder(series.clone()).window(2).build().unwrap();
    let want = pairs(&flat.searcher().query_values::<Squared>(&q, &QueryOptions::k(4)));
    // k=4 nearest of identical series: distance 0, lowest indices win.
    assert_eq!(want.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    for &clusters in &[1usize, 3, 9, 50] {
        for &shards in &SHARD_GRID {
            let index = DtwIndex::builder(series.clone())
                .window(2)
                .shards(shards)
                .clusters(clusters)
                .build()
                .unwrap();
            assert!(index.has_clusters());
            let out = index.searcher().query_values::<Squared>(&q, &QueryOptions::k(4));
            assert_eq!(pairs(&out), want, "clusters={clusters} shards={shards}");
        }
    }
}

#[test]
fn clusters_auto_builds_and_answers_exactly() {
    let mut rng = Rng::seeded(0xA070);
    let train = family_series(&mut rng, 50, 28, 5);
    let q = family_series(&mut rng, 1, 28, 5).pop().unwrap();
    let flat = DtwIndex::builder(train.clone()).window(3).build().unwrap();
    let want = pairs(&flat.searcher().query_values::<Squared>(&q, &QueryOptions::k(5)));
    let auto = DtwIndex::builder(train)
        .window(3)
        .shards(2)
        .clusters_auto()
        .build()
        .unwrap();
    assert!(auto.has_clusters(), "auto must pick a nonzero cluster count here");
    assert!(auto.clusters() > 0);
    let out = auto.searcher().query_values::<Squared>(&q, &QueryOptions::k(5));
    assert_eq!(pairs(&out), want);
}

/// Cluster counters only move when clusters exist, and cluster-pruned
/// members never also show up in the per-candidate counters.
#[test]
fn cluster_counters_are_consistent() {
    let mut rng = Rng::seeded(0x5747);
    let train = family_series(&mut rng, 80, 32, 8);
    let n = train.len();
    let q = family_series(&mut rng, 1, 32, 8).pop().unwrap();

    let flat = DtwIndex::builder(train.clone()).window(3).build().unwrap();
    let f = flat.searcher().query_values::<Squared>(&q, &QueryOptions::k(1));
    assert_eq!(f.stats.cluster_lb_calls, 0);
    assert_eq!(f.stats.clusters_pruned, 0);
    assert_eq!(f.stats.cluster_members_pruned, 0);

    let clustered =
        DtwIndex::builder(train).window(3).shards(2).clusters(8).build().unwrap();
    let c = clustered.searcher().query_values::<Squared>(&q, &QueryOptions::k(1));
    assert!(c.stats.cluster_lb_calls > 0, "cluster bounds must be evaluated");
    assert!(c.stats.cluster_members_pruned >= c.stats.clusters_pruned);
    // Every candidate is accounted for exactly once: computed exactly
    // (including the cutoff-free seed candidates), pruned by its own
    // bound, or skipped wholesale with its cluster.
    assert_eq!(c.stats.dtw_calls + c.stats.pruned + c.stats.cluster_members_pruned, n);
    assert_eq!(pairs(&c), pairs(&f));
}

#[test]
fn snapshot_round_trip_preserves_clustered_answers() {
    let mut rng = Rng::seeded(0x54A9);
    let train = family_series(&mut rng, 40, 24, 4);
    let queries = family_series(&mut rng, 3, 24, 4);
    let index = DtwIndex::builder(train)
        .window(3)
        .shards(3)
        .clusters(4)
        .threads(2)
        .build()
        .unwrap();
    let path = std::env::temp_dir()
        .join(format!("dtwb_cluster_roundtrip_{}.snap", std::process::id()));
    index.save(&path).expect("write snapshot");
    let loaded = DtwIndex::load(&path).expect("read snapshot");
    std::fs::remove_file(&path).ok();

    assert!(loaded.has_clusters(), "clusters must survive the round trip");
    assert_eq!(loaded.clusters(), index.clusters());
    for q in &queries {
        let a = index.searcher().query_values::<Squared>(q, &QueryOptions::k(5));
        let b = loaded.searcher().query_values::<Squared>(q, &QueryOptions::k(5));
        assert_eq!(pairs(&a), pairs(&b));
        // The loaded index still cluster-prunes (not silently flat).
        assert!(b.stats.cluster_lb_calls > 0);
    }
}
