//! Differential suite for the runtime-dispatched SIMD kernels.
//!
//! The contract under test (rust/src/simd/mod.rs): every vector
//! kernel is **bit-equal** to the scalar lane-protocol reference —
//! not merely close. These tests exercise every ISA the running CPU
//! can dispatch to via [`dtw_bounds::simd::for_isa`], in one process,
//! independent of the cached global selection; the CI leg that reruns
//! the whole suite under `DTW_FORCE_ISA=scalar` covers the dispatched
//! paths from the other side.
//!
//! Inputs are deliberately hostile: signed zeros, subnormals,
//! `1e12`-magnitude values (whose squared deltas reach `1e24`), and
//! unaligned sub-slices (offset-by-one views of the backing
//! allocations, so the vector bodies run at every 16/32-byte phase).

use dtw_bounds::bounds::{keogh, BoundKind, PreparedSeries, Scratch};
use dtw_bounds::data::rng::Rng;
use dtw_bounds::delta::{Absolute, Delta, Squared};
use dtw_bounds::dtw::{dtw, dtw_ea_pruned};
use dtw_bounds::simd::{self, scalar, Isa, Kernels};

/// Body lengths around every lane boundary (0..=17) plus three sizes
/// with a large multiple-of-4 body and each tail phase.
fn sizes() -> Vec<usize> {
    (0..=17).chain([63, 64, 65]).collect()
}

/// A hostile value: zeros of both signs, subnormals, huge magnitudes,
/// and ordinary normal deviates.
fn stress_value(rng: &mut Rng) -> f64 {
    match rng.below(10) {
        0 => 0.0,
        1 => -0.0,
        2 => 5e-324,              // smallest positive subnormal
        3 => -1.0e-308,           // negative subnormal
        4 => 1.0e12 * rng.normal(),
        5 => -1.0e12,
        _ => rng.normal(),
    }
}

fn stress_series(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| stress_value(rng)).collect()
}

/// A valid envelope (`lo[i] <= up[i]` pointwise) centered on an
/// *independent* stress series, so the query is out of range — on
/// either side — at a large fraction of indices.
fn stress_envelope(rng: &mut Rng, n: usize) -> (Vec<f64>, Vec<f64>) {
    let base = stress_series(rng, n);
    let lo: Vec<f64> = base.iter().map(|&b| b - stress_value(rng).abs()).collect();
    let up: Vec<f64> = base.iter().map(|&b| b + stress_value(rng).abs()).collect();
    (lo, up)
}

/// Offset-by-one view: same data, different 16/32-byte phase.
fn unaligned(v: &[f64]) -> &[f64] {
    &v[1..]
}

fn assert_bits(context: &str, got: f64, want: f64) {
    assert_eq!(
        got.to_bits(),
        want.to_bits(),
        "{context}: got {got:e} ({:#x}), scalar reference {want:e} ({:#x})",
        got.to_bits(),
        want.to_bits()
    );
}

fn assert_slice_bits(context: &str, got: &[f64], want: &[f64]) {
    assert_eq!(got.len(), want.len(), "{context}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        assert_eq!(
            g.to_bits(),
            w.to_bits(),
            "{context}: lane {i} diverges: got {g:e}, scalar reference {w:e}"
        );
    }
}

/// Every vtable entry, every available ISA, every size and tail phase,
/// aligned and unaligned: bit-equal to the scalar vtable.
#[test]
fn every_kernel_is_bit_equal_to_scalar_on_every_available_isa() {
    let mut rng = Rng::seeded(0x51D0);
    let scalar_k = simd::for_isa(Isa::Scalar).unwrap();
    let isas = simd::available();
    assert!(isas.contains(&Isa::Scalar));

    for n in sizes() {
        // One extra leading element so `unaligned` keeps length `n`.
        let a = stress_series(&mut rng, n + 1);
        let (lo, up) = stress_envelope(&mut rng, n + 1);
        let cuts = {
            let full = (scalar_k.keogh_sq_sum)(&a[..n], &lo[..n], &up[..n]);
            [f64::INFINITY, 0.0, 1e-3, 1.0, 1e25, 0.5 * full]
        };

        for &isa in &isas {
            let k = simd::for_isa(isa).unwrap();
            for (aa, ll, uu, phase) in [
                (&a[..n], &lo[..n], &up[..n], "aligned"),
                (unaligned(&a), unaligned(&lo), unaligned(&up), "unaligned"),
            ] {
                let ctx = |name: &str| format!("{isa}/{name}/n={n}/{phase}");

                assert_bits(
                    &ctx("keogh_sq_sum"),
                    (k.keogh_sq_sum)(aa, ll, uu),
                    (scalar_k.keogh_sq_sum)(aa, ll, uu),
                );
                assert_bits(
                    &ctx("keogh_abs_sum"),
                    (k.keogh_abs_sum)(aa, ll, uu),
                    (scalar_k.keogh_abs_sum)(aa, ll, uu),
                );
                for cut in cuts {
                    assert_bits(
                        &format!("{}/cut={cut:e}", ctx("keogh_sq_ea")),
                        (k.keogh_sq_ea)(aa, ll, uu, cut),
                        (scalar_k.keogh_sq_ea)(aa, ll, uu, cut),
                    );
                    assert_bits(
                        &format!("{}/cut={cut:e}", ctx("keogh_abs_ea")),
                        (k.keogh_abs_ea)(aa, ll, uu, cut),
                        (scalar_k.keogh_abs_ea)(aa, ll, uu, cut),
                    );
                }

                let mut got = vec![0.0; aa.len()];
                let mut want = vec![0.0; aa.len()];
                (k.clamp)(aa, ll, uu, &mut got);
                (scalar_k.clamp)(aa, ll, uu, &mut want);
                assert_slice_bits(&ctx("clamp"), &got, &want);

                if !aa.is_empty() {
                    let mut got = vec![0.0; aa.len() - 1];
                    let mut want = vec![0.0; aa.len() - 1];
                    (k.pair_min)(aa, &mut got);
                    (scalar_k.pair_min)(aa, &mut want);
                    assert_slice_bits(&ctx("pair_min"), &got, &want);
                }

                let mut got = ll.to_vec();
                let mut want = ll.to_vec();
                (k.min_merge)(&mut got, uu);
                (scalar_k.min_merge)(&mut want, uu);
                assert_slice_bits(&ctx("min_merge"), &got, &want);

                let mut got = uu.to_vec();
                let mut want = uu.to_vec();
                (k.max_merge)(&mut got, ll);
                (scalar_k.max_merge)(&mut want, ll);
                assert_slice_bits(&ctx("max_merge"), &got, &want);
            }
        }
    }
}

/// `lb_keogh_flat` — the dispatching entry every screening path goes
/// through — is bit-equal to the generic scalar lane reference at the
/// *active* (natively selected) ISA, for both monomorphised deltas,
/// with and without abandoning.
#[test]
fn lb_keogh_flat_matches_the_scalar_lane_reference_bitwise() {
    let mut rng = Rng::seeded(0xF1A7);
    for n in sizes() {
        let a = stress_series(&mut rng, n);
        let (lo, up) = stress_envelope(&mut rng, n);

        let full_sq = keogh::lb_keogh_flat::<Squared>(&a, &lo, &up, f64::INFINITY);
        assert_bits(
            &format!("flat/squared/n={n}"),
            full_sq,
            scalar::keogh_sum::<Squared>(&a, &lo, &up),
        );
        let full_abs = keogh::lb_keogh_flat::<Absolute>(&a, &lo, &up, f64::INFINITY);
        assert_bits(
            &format!("flat/absolute/n={n}"),
            full_abs,
            scalar::keogh_sum::<Absolute>(&a, &lo, &up),
        );

        for cut in [0.0, 1e-3, 0.5 * full_sq, full_sq, 1e25] {
            assert_bits(
                &format!("flat-ea/squared/n={n}/cut={cut:e}"),
                keogh::lb_keogh_flat::<Squared>(&a, &lo, &up, cut),
                scalar::keogh_ea::<Squared>(&a, &lo, &up, cut),
            );
            assert_bits(
                &format!("flat-ea/absolute/n={n}/cut={cut:e}"),
                keogh::lb_keogh_flat::<Absolute>(&a, &lo, &up, cut),
                scalar::keogh_ea::<Absolute>(&a, &lo, &up, cut),
            );
        }
        // A non-abandoned EA run returns the full sum bit-identically.
        assert_bits(
            &format!("flat-ea-noabandon/n={n}"),
            keogh::lb_keogh_flat::<Squared>(&a, &lo, &up, f64::MAX),
            full_sq,
        );
    }
}

fn check_all_bounds<D: Delta>(rng: &mut Rng, trial: usize) {
    let n = rng.int_range(16, 48);
    let qv = stress_series(rng, n);
    let tv = stress_series(rng, n);
    let w = rng.below(n);
    let t = PreparedSeries::prepare(tv.clone(), w);
    let truth = dtw::<D>(&qv, &tv, w);
    let mut scratch = Scratch::new(n);
    for kind in BoundKind::ALL {
        if !kind.is_valid_for::<D>() {
            continue;
        }
        let q = kind.prepare_query(qv.clone(), w);
        let lb = kind.compute::<D>(&q, &t, w, f64::INFINITY, &mut scratch);
        assert!(
            lb <= truth + 1e-9 * (1.0 + truth.abs()),
            "trial={trial} {}: bound {lb:e} exceeds DTW {truth:e} (n={n}, w={w})",
            kind.name()
        );
        // Same call, same dispatch: bit-for-bit reproducible.
        let again = kind.compute::<D>(&q, &t, w, f64::INFINITY, &mut scratch);
        assert_bits(&format!("trial={trial} {} rerun", kind.name()), again, lb);
    }
}

/// Every `BoundKind` (including the new `ImprovedCascade`) stays a
/// valid lower bound and is deterministic under the active dispatch,
/// on hostile inputs. Run once per delta; the `DTW_FORCE_ISA=scalar`
/// CI leg repeats this with dispatch pinned off, so a kernel that
/// drifted from scalar would show up as a cross-leg divergence.
#[test]
fn every_bound_kind_is_a_valid_deterministic_lower_bound_on_stress_inputs() {
    let mut rng = Rng::seeded(0xB0B0);
    for trial in 0..40 {
        check_all_bounds::<Squared>(&mut rng, trial);
        check_all_bounds::<Absolute>(&mut rng, trial);
    }
}

/// The pruned DTW kernel (whose live-range inner loop now runs on the
/// `pair_min` prepass) keeps its contract on hostile inputs: a finite
/// result is bit-equal to [`dtw`], and `INFINITY` comes back exactly
/// when the true distance exceeds the cutoff.
#[test]
fn pruned_dtw_stays_bit_equal_to_full_dtw_on_stress_inputs() {
    let mut rng = Rng::seeded(0xDA7A);
    for n in [1usize, 2, 3, 5, 9, 16, 17, 33, 64, 65] {
        for _ in 0..4 {
            let a = stress_series(&mut rng, n);
            let b = stress_series(&mut rng, n);
            for w in [0, 1, 3, n] {
                let truth = dtw::<Squared>(&a, &b, w);
                let t = PreparedSeries::prepare(b.clone(), w);
                let mut tail = Vec::new();
                keogh::lb_keogh_tail::<Squared>(&a, &t.lo, &t.up, &mut tail);
                for mult in [0.25, 0.9, 1.0, 1.5] {
                    let cutoff = truth * mult;
                    for tl in [None, Some(tail.as_slice())] {
                        let got = dtw_ea_pruned::<Squared>(&a, &b, w, cutoff, tl);
                        if got.is_finite() {
                            assert_bits(
                                &format!("pruned/n={n}/w={w}/mult={mult}"),
                                got,
                                truth,
                            );
                            assert!(truth <= cutoff, "finite result above the cutoff");
                        } else {
                            assert!(
                                truth > cutoff,
                                "pruned/n={n}/w={w}/mult={mult}: spurious INFINITY \
                                 (truth {truth:e} <= cutoff {cutoff:e})"
                            );
                        }
                    }
                }
                // Unequal lengths exercise the asymmetric live ranges.
                if n > 1 {
                    let short = &b[..n - 1];
                    let truth = dtw::<Squared>(&a, short, w.max(1));
                    let got =
                        dtw_ea_pruned::<Squared>(&a, short, w.max(1), truth, None);
                    assert_bits(&format!("pruned-uneq/n={n}/w={w}"), got, truth);
                }
            }
        }
    }
}

/// The dispatch surface itself: name round-trips, availability, and
/// the active vtable's self-consistency.
#[test]
fn dispatch_surface_is_consistent() {
    for &isa in Isa::ALL {
        assert_eq!(Isa::parse(isa.name()), Some(isa));
        assert_eq!(Isa::parse(&isa.name().to_ascii_uppercase()), Some(isa));
        assert_eq!(format!("{isa}"), isa.name());
    }
    assert_eq!(Isa::parse("m4-matrix-coprocessor"), None);

    let isas = simd::available();
    assert!(isas.contains(&Isa::Scalar), "scalar must always be dispatchable");
    assert!(isas.contains(&simd::active_isa()), "the active ISA must be available");
    for isa in isas {
        let k: &'static Kernels = simd::for_isa(isa).unwrap();
        assert_eq!(k.isa, isa, "vtable self-reports a different ISA");
    }
    assert_eq!(simd::kernels().isa, simd::active_isa());
    assert_eq!(simd::isa_name(), simd::active_isa().name());
}
