//! Z-normalization — standard preprocessing for UCR-style evaluation.
//!
//! Each series is shifted/scaled to zero mean and unit variance. Constant
//! series map to all-zeros (the UCR convention) rather than NaN.

/// Z-normalize in place. Constant series become all-zeros.
pub fn znormalize(values: &mut [f64]) {
    let n = values.len();
    if n == 0 {
        return;
    }
    let mean = values.iter().sum::<f64>() / n as f64;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n as f64;
    if var <= 1e-24 {
        values.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    let inv_sd = 1.0 / var.sqrt();
    values.iter_mut().for_each(|v| *v = (*v - mean) * inv_sd);
}

/// Allocating convenience wrapper.
pub fn znormalized(values: &[f64]) -> Vec<f64> {
    let mut out = values.to_vec();
    znormalize(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_mean_unit_variance() {
        let v = znormalized(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let mean: f64 = v.iter().sum::<f64>() / v.len() as f64;
        let var: f64 = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 1e-12);
        assert!((var - 1.0).abs() < 1e-12);
    }

    #[test]
    fn constant_series_becomes_zeros() {
        assert_eq!(znormalized(&[3.0, 3.0, 3.0]), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn empty_is_noop() {
        let mut v: Vec<f64> = vec![];
        znormalize(&mut v);
        assert!(v.is_empty());
    }

    #[test]
    fn idempotent() {
        let a = znormalized(&[0.3, -1.2, 4.5, 2.2, -0.7]);
        let b = znormalized(&a);
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }
}
