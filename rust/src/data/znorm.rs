//! Z-normalization — standard preprocessing for UCR-style evaluation.
//!
//! Each series is shifted/scaled to zero mean and unit variance. Constant
//! series map to all-zeros (the UCR convention) rather than NaN.

/// Z-normalize in place. Constant series become all-zeros.
pub fn znormalize(values: &mut [f64]) {
    if values.is_empty() {
        return;
    }
    let n = values.len() as f64;
    let mean = values.iter().sum::<f64>() / n;
    let var = values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
    znormalize_with_moments(values, mean, var);
}

/// Allocating convenience wrapper.
pub fn znormalized(values: &[f64]) -> Vec<f64> {
    let mut out = values.to_vec();
    znormalize(&mut out);
    out
}

/// Z-normalize in place with **precomputed** moments — for callers that
/// already maintain the window mean/variance incrementally (the stream
/// searcher reuses `StreamBuffer`'s O(1) rolling moments instead of
/// rescanning every surviving window). Uses the same constant-series
/// guard as [`znormalize`]; rolling moments drift from the rescanned
/// ones by a few ulps over long streams, so results agree with
/// [`znormalize`] to ~1e-9, not bitwise.
pub fn znormalize_with_moments(values: &mut [f64], mean: f64, variance: f64) {
    if values.is_empty() {
        return;
    }
    if variance <= 1e-24 {
        values.iter_mut().for_each(|v| *v = 0.0);
        return;
    }
    let inv_sd = 1.0 / variance.sqrt();
    values.iter_mut().for_each(|v| *v = (*v - mean) * inv_sd);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_mean_unit_variance() {
        let v = znormalized(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        let mean: f64 = v.iter().sum::<f64>() / v.len() as f64;
        let var: f64 = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 1e-12);
        assert!((var - 1.0).abs() < 1e-12);
    }

    #[test]
    fn constant_series_becomes_zeros() {
        assert_eq!(znormalized(&[3.0, 3.0, 3.0]), vec![0.0, 0.0, 0.0]);
    }

    #[test]
    fn empty_is_noop() {
        let mut v: Vec<f64> = vec![];
        znormalize(&mut v);
        assert!(v.is_empty());
    }

    #[test]
    fn with_moments_matches_rescan_when_given_exact_moments() {
        let raw = [0.3, -1.2, 4.5, 2.2, -0.7];
        let n = raw.len() as f64;
        let mean = raw.iter().sum::<f64>() / n;
        let var = raw.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
        let mut a = raw.to_vec();
        znormalize(&mut a);
        let mut b = raw.to_vec();
        znormalize_with_moments(&mut b, mean, var);
        assert_eq!(a, b, "identical moments give identical output");
        let mut c = vec![5.5; 4];
        znormalize_with_moments(&mut c, 5.5, 0.0);
        assert_eq!(c, vec![0.0; 4], "constant guard");
    }

    #[test]
    fn idempotent() {
        let a = znormalized(&[0.3, -1.2, 4.5, 2.2, -0.7]);
        let b = znormalized(&a);
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }
}
