//! Deterministic pseudo-random numbers.
//!
//! The offline build environment carries no `rand` crate, so we implement
//! the public-domain **SplitMix64** (seeding) and **xoshiro256\*\***
//! (generation) algorithms directly. Every experiment in this repository
//! is seeded, making archives, query orders and property tests
//! bit-reproducible across runs.

/// xoshiro256** generator seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child generator (for per-dataset streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seeded(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit value (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method,
    /// simple modulo is fine at our scales but we debias anyway).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // 128-bit multiply keeps the bias below 2^-64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn int_range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index according to non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::seeded(1234);
        let mut b = Rng::seeded(1234);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seeded(1235);
        assert_ne!(Rng::seeded(1234).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::seeded(5);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::seeded(6);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seeded(77);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::seeded(8);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut rng = Rng::seeded(9);
        let w = [0.0, 1.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[rng.weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[2] > counts[1] * 5);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::seeded(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
