//! Synthetic UCR-style archive generator.
//!
//! The real UCR-85 "bakeoff" archive is not redistributable, so the
//! experiment suite runs on a generated stand-in (DESIGN.md §4). Each
//! dataset draws its own *shape parameters* — series length, class count,
//! split sizes, smoothness, noise, intra-class warp — spanning the ranges
//! of the real archive, then generates per-class smooth prototypes
//! (random Fourier features) and instances as **time-warped, noised,
//! amplitude-jittered** copies. This produces exactly the structure lower
//! bounds feed on: smooth envelopes, intra-class warping inside a window,
//! and class-dependent nearest neighbors.
//!
//! Everything is seeded: the same [`ArchiveSpec`] reproduces the same
//! archive bit-for-bit, and datasets get independent RNG streams so
//! changing the count does not reshuffle earlier datasets.

use super::rng::Rng;
use super::znorm::znormalize;
use super::{Dataset, Labeled};

/// Size preset for a generated archive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// 10 tiny datasets — unit/integration tests.
    Tiny,
    /// 85 small datasets — the default experiment suite on this container.
    Small,
    /// 85 datasets with UCR-like magnitudes — the headline run.
    Paper,
}

impl Scale {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "tiny" => Some(Scale::Tiny),
            "small" => Some(Scale::Small),
            "paper" | "full" => Some(Scale::Paper),
            _ => None,
        }
    }
}

/// Generation parameters for one dataset.
#[derive(Debug, Clone)]
pub struct DatasetParams {
    /// Dataset name.
    pub name: String,
    /// Series length ℓ.
    pub len: usize,
    /// Number of classes.
    pub classes: usize,
    /// Training set size.
    pub train: usize,
    /// Test set size.
    pub test: usize,
    /// Fourier harmonics in each class prototype (smoothness: fewer =
    /// smoother).
    pub harmonics: usize,
    /// Max local time-warp as a fraction of ℓ (intra-class variation the
    /// warping window exists to absorb).
    pub warp: f64,
    /// AR(1) noise amplitude relative to signal.
    pub noise: f64,
    /// AR(1) autocorrelation of the noise.
    pub noise_rho: f64,
    /// Recommended warping window (elements), mirroring the archive's
    /// published best-accuracy windows.
    pub window: usize,
}

/// Archive-level generation spec.
#[derive(Debug, Clone)]
pub struct ArchiveSpec {
    /// Number of datasets.
    pub n_datasets: usize,
    /// Master seed.
    pub seed: u64,
    /// Size preset.
    pub scale: Scale,
}

impl ArchiveSpec {
    /// The default suite used throughout `benches/` and EXPERIMENTS.md.
    pub fn new(scale: Scale, seed: u64) -> Self {
        let n_datasets = match scale {
            Scale::Tiny => 10,
            Scale::Small | Scale::Paper => 85,
        };
        ArchiveSpec { n_datasets, seed, scale }
    }

    /// Sample per-dataset parameters (deterministic in `seed` and index).
    pub fn dataset_params(&self, idx: usize) -> DatasetParams {
        let mut rng = Rng::seeded(self.seed ^ (idx as u64).wrapping_mul(0x9E3779B97F4A7C15));
        // Ranges chosen so the DTW-cost/bound-cost ratio spans the same
        // regime as the UCR-85 (where DTW dominates and tight bounds pay;
        // see EXPERIMENTS.md "calibration"). `Paper` approaches the real
        // archive's magnitudes; `Small` keeps full-suite runs tractable on
        // one core while preserving the regime.
        let (len_lo, len_hi, tr_lo, tr_hi, te_lo, te_hi) = match self.scale {
            Scale::Tiny => (24, 64, 8, 16, 6, 12),
            Scale::Small => (128, 768, 32, 128, 16, 48),
            Scale::Paper => (256, 2048, 64, 512, 40, 200),
        };
        // Log-uniform lengths mirror the UCR spread (many short, few long).
        let len = (f64::exp(rng.uniform_range((len_lo as f64).ln(), (len_hi as f64).ln())))
            .round() as usize;
        let classes = match rng.below(10) {
            0..=5 => rng.int_range(2, 4),  // most UCR datasets have few classes
            6..=8 => rng.int_range(4, 12),
            _ => rng.int_range(12, 40),
        };
        let train = rng.int_range(tr_lo, tr_hi).max(classes * 2);
        let test = rng.int_range(te_lo, te_hi);
        let harmonics = rng.int_range(2, 10);
        let warp = rng.uniform_range(0.01, 0.08);
        let noise = rng.uniform_range(0.05, 0.45);
        let noise_rho = rng.uniform_range(0.0, 0.9);
        // Recommended windows: the paper notes 60/85 datasets have w ≥ 1.
        // We mirror that: ~30% get 0, the rest 2%–25% of ℓ (the UCR-85's
        // LOOCV-optimal windows span this range).
        let window = if rng.uniform() < 0.3 {
            0
        } else {
            ((len as f64 * rng.uniform_range(0.02, 0.25)).round() as usize).max(1)
        };
        DatasetParams {
            name: format!("Synth{idx:02}"),
            len,
            classes,
            train,
            test,
            harmonics,
            warp,
            noise,
            noise_rho,
            window,
        }
    }
}

/// A smooth prototype: random Fourier features with `1/h` amplitude decay.
struct Prototype {
    amp: Vec<f64>,
    phase: Vec<f64>,
}

impl Prototype {
    fn sample(rng: &mut Rng, harmonics: usize) -> Self {
        let amp = (1..=harmonics)
            .map(|h| rng.normal() / (h as f64).sqrt())
            .collect();
        let phase = (0..harmonics)
            .map(|_| rng.uniform_range(0.0, std::f64::consts::TAU))
            .collect();
        Prototype { amp, phase }
    }

    /// Evaluate at continuous position `x ∈ [0, 1]`.
    fn eval(&self, x: f64) -> f64 {
        self.amp
            .iter()
            .zip(self.phase.iter())
            .enumerate()
            .map(|(i, (a, p))| a * ((i + 1) as f64 * std::f64::consts::TAU * x + p).sin())
            .sum()
    }
}

/// Generate one instance of a prototype: smooth monotone time warp +
/// AR(1) noise + amplitude/offset jitter, then z-normalized.
fn generate_instance(proto: &Prototype, p: &DatasetParams, rng: &mut Rng) -> Vec<f64> {
    let n = p.len;
    // Monotone warp: jittered anchors, piecewise-linear in between.
    let n_anchors = 5;
    let mut anchors = vec![0.0f64; n_anchors + 1];
    for (k, a) in anchors.iter_mut().enumerate() {
        let base = k as f64 / n_anchors as f64;
        let jitter = if k == 0 || k == n_anchors {
            0.0
        } else {
            rng.uniform_range(-p.warp, p.warp)
        };
        *a = (base + jitter).clamp(0.0, 1.0);
    }
    anchors.sort_by(|a, b| a.partial_cmp(b).unwrap());

    let scale = 1.0 + 0.2 * rng.normal();
    let offset = 0.15 * rng.normal();
    let mut noise = 0.0;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let t = i as f64 / (n - 1).max(1) as f64;
        // Piecewise-linear warp of t through the anchors.
        let seg = ((t * n_anchors as f64) as usize).min(n_anchors - 1);
        let seg_t = t * n_anchors as f64 - seg as f64;
        let tau = anchors[seg] + (anchors[seg + 1] - anchors[seg]) * seg_t;
        noise = p.noise_rho * noise + rng.normal() * p.noise * (1.0 - p.noise_rho * p.noise_rho).sqrt();
        out.push(scale * proto.eval(tau) + offset + noise);
    }
    znormalize(&mut out);
    out
}

/// Generate one dataset from its parameters (deterministic in `rng`).
pub fn generate_dataset(p: &DatasetParams, rng: &mut Rng) -> Dataset {
    let protos: Vec<Prototype> =
        (0..p.classes).map(|_| Prototype::sample(rng, p.harmonics)).collect();
    let gen_split = |count: usize, rng: &mut Rng| -> Vec<Labeled> {
        (0..count)
            .map(|i| {
                // Round-robin then random fill keeps every class populated.
                let label = if i < p.classes { i } else { rng.below(p.classes) } as u32;
                Labeled {
                    label,
                    values: generate_instance(&protos[label as usize], p, rng),
                }
            })
            .collect()
    };
    let train = gen_split(p.train, rng);
    let test = gen_split(p.test, rng);
    Dataset { name: p.name.clone(), train, test, window: p.window }
}

/// Generate the full archive for a spec.
pub fn generate_archive(spec: &ArchiveSpec) -> Vec<Dataset> {
    (0..spec.n_datasets)
        .map(|idx| {
            let p = spec.dataset_params(idx);
            let mut rng =
                Rng::seeded(spec.seed ^ 0xA5A5_5A5A ^ (idx as u64).wrapping_mul(0x2545F4914F6CDD1D));
            generate_dataset(&p, &mut rng)
        })
        .collect()
}

/// A smooth z-normalized random pattern (sum of a few sinusoids) — the
/// reference-library shape used by the streaming-monitor scenario
/// (`examples/streaming_monitor.rs`, the `dtw-bench` stream scenario).
pub fn sinusoid_pattern(rng: &mut Rng, len: usize) -> Vec<f64> {
    let k = rng.int_range(2, 5);
    let params: Vec<(f64, f64, f64)> = (0..k)
        .map(|_| (rng.uniform_range(0.3, 2.0), rng.uniform_range(0.02, 0.3), rng.uniform() * 6.28))
        .collect();
    let mut out: Vec<f64> = (0..len)
        .map(|i| params.iter().map(|(a, f, p)| a * (f * i as f64 + p).sin()).sum())
        .collect();
    znormalize(&mut out);
    out
}

/// A z-normalized Gaussian random walk — the classic "hard to index"
/// family: no periodic structure, so envelope bounds stay informative
/// only through the window term. Used by the bench-suite dataset
/// families (`dtw-bench`). Deterministic in `rng`.
pub fn random_walk_series(rng: &mut Rng, len: usize) -> Vec<f64> {
    let mut level = 0.0;
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        level += rng.normal();
        out.push(level);
    }
    znormalize(&mut out);
    out
}

/// An adversarial worst-case-warping series: short constant runs of
/// alternating sign (run length 1–4) with jittered amplitude. The high
/// frequency content makes Keogh-style envelopes span nearly the full
/// value range, so lower bounds go slack and searches degrade toward
/// brute force — the stress case for prune-rate claims. Deterministic
/// in `rng`.
pub fn adversarial_warp_series(rng: &mut Rng, len: usize) -> Vec<f64> {
    let mut out = Vec::with_capacity(len);
    let mut sign = if rng.uniform() < 0.5 { 1.0 } else { -1.0 };
    while out.len() < len {
        let run = rng.int_range(1, 4);
        let amp = rng.uniform_range(0.6, 1.4);
        for _ in 0..run {
            if out.len() == len {
                break;
            }
            out.push(sign * amp + 0.05 * rng.normal());
        }
        sign = -sign;
    }
    znormalize(&mut out);
    out
}

/// A synthetic sensor stream for subsequence-search workloads:
/// background Gaussian noise (runs of 20–100 samples, σ = 0.8) with
/// occasional noisy copies of `patterns` embedded.
///
/// * `embed_prob` — per-decision probability of embedding an occurrence;
/// * `amp_jitter` — the copy is scaled by `1 + amp_jitter·N(0,1)`;
/// * `noise_sd` — per-sample additive noise on the embedded copy.
///
/// Returns the stream (exactly `len` samples) and the ground-truth
/// `(position, pattern index)` of every embedded occurrence. All
/// patterns must share one length. Deterministic in `rng`.
pub fn embed_stream(
    rng: &mut Rng,
    patterns: &[Vec<f64>],
    len: usize,
    embed_prob: f64,
    amp_jitter: f64,
    noise_sd: f64,
) -> (Vec<f64>, Vec<(usize, usize)>) {
    assert!(!patterns.is_empty(), "embed_stream needs at least one pattern");
    let m = patterns[0].len();
    let mut stream = Vec::with_capacity(len + m);
    let mut embedded = Vec::new();
    while stream.len() < len {
        if rng.uniform() < embed_prob && stream.len() + m < len {
            let id = rng.below(patterns.len());
            embedded.push((stream.len(), id));
            let scale = 1.0 + amp_jitter * rng.normal();
            for &v in &patterns[id] {
                stream.push(scale * v + noise_sd * rng.normal());
            }
        } else {
            for _ in 0..rng.int_range(20, 100) {
                stream.push(rng.normal() * 0.8);
            }
        }
    }
    stream.truncate(len);
    (stream, embedded)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let spec = ArchiveSpec::new(Scale::Tiny, 7);
        let a = generate_archive(&spec);
        let b = generate_archive(&spec);
        assert_eq!(a.len(), 10);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.train[0].values, y.train[0].values);
            assert_eq!(x.test.len(), y.test.len());
        }
        let c = generate_archive(&ArchiveSpec::new(Scale::Tiny, 8));
        assert_ne!(a[0].train[0].values, c[0].train[0].values);
    }

    #[test]
    fn embed_stream_is_deterministic_and_truthful() {
        let mut prng = Rng::seeded(42);
        let patterns: Vec<Vec<f64>> =
            (0..3).map(|_| sinusoid_pattern(&mut prng, 32)).collect();
        assert!(patterns.iter().all(|p| p.len() == 32));
        let mut r1 = Rng::seeded(7);
        let (s1, e1) = embed_stream(&mut r1, &patterns, 2000, 0.3, 0.1, 0.1);
        let mut r2 = Rng::seeded(7);
        let (s2, e2) = embed_stream(&mut r2, &patterns, 2000, 0.3, 0.1, 0.1);
        assert_eq!(s1, s2, "deterministic in the rng");
        assert_eq!(e1, e2);
        assert_eq!(s1.len(), 2000);
        assert!(!e1.is_empty(), "0.3 embed probability over ~30 decisions");
        assert!(e1.iter().all(|&(pos, id)| pos + 32 <= 2000 && id < 3));
    }

    #[test]
    fn walk_and_adversarial_generators_are_seeded_and_normalized() {
        for gen in [random_walk_series, adversarial_warp_series] {
            let a = gen(&mut Rng::seeded(31), 200);
            let b = gen(&mut Rng::seeded(31), 200);
            let c = gen(&mut Rng::seeded(32), 200);
            assert_eq!(a, b, "deterministic in the seed");
            assert_ne!(a, c, "distinct seeds diverge");
            assert_eq!(a.len(), 200);
            let mean: f64 = a.iter().sum::<f64>() / 200.0;
            let var: f64 = a.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / 200.0;
            assert!(mean.abs() < 1e-9);
            assert!((var - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn adversarial_series_oscillates() {
        // Sign flips every 1–4 samples: at least len/8 crossings.
        let s = adversarial_warp_series(&mut Rng::seeded(5), 400);
        let crossings = s.windows(2).filter(|w| (w[0] >= 0.0) != (w[1] >= 0.0)).count();
        assert!(crossings >= 50, "only {crossings} sign changes in 400 samples");
    }

    #[test]
    fn shapes_are_consistent() {
        let spec = ArchiveSpec::new(Scale::Tiny, 42);
        for ds in generate_archive(&spec) {
            let l = ds.series_len();
            assert!(l >= 24);
            assert!(ds.train.iter().all(|s| s.values.len() == l));
            assert!(ds.test.iter().all(|s| s.values.len() == l));
            assert!(ds.num_classes() >= 2);
            assert!(ds.window <= l);
            // Every class is populated in train.
            let k = ds.num_classes();
            for c in 0..k as u32 {
                assert!(ds.train.iter().any(|s| s.label == c), "class {c} empty");
            }
        }
    }

    #[test]
    fn series_are_znormalized() {
        let spec = ArchiveSpec::new(Scale::Tiny, 3);
        let ds = &generate_archive(&spec)[0];
        for s in ds.train.iter().take(5) {
            let n = s.values.len() as f64;
            let mean: f64 = s.values.iter().sum::<f64>() / n;
            let var: f64 = s.values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
            assert!(mean.abs() < 1e-9);
            assert!((var - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn same_class_is_closer_on_average() {
        // The class structure must be learnable, otherwise NN search is
        // meaningless: average intra-class DTW < average inter-class DTW.
        use crate::delta::Squared;
        use crate::dtw::dtw;
        let spec = ArchiveSpec::new(Scale::Tiny, 11);
        let ds = &generate_archive(&spec)[1];
        let w = (ds.series_len() / 10).max(1);
        let (mut intra, mut inter) = ((0.0, 0usize), (0.0, 0usize));
        for (i, a) in ds.train.iter().enumerate() {
            for b in ds.train.iter().skip(i + 1) {
                let d = dtw::<Squared>(&a.values, &b.values, w);
                if a.label == b.label {
                    intra = (intra.0 + d, intra.1 + 1);
                } else {
                    inter = (inter.0 + d, inter.1 + 1);
                }
            }
        }
        let intra_mean = intra.0 / intra.1.max(1) as f64;
        let inter_mean = inter.0 / inter.1.max(1) as f64;
        assert!(
            intra_mean < inter_mean,
            "intra {intra_mean} >= inter {inter_mean}"
        );
    }

    #[test]
    fn archive_has_window_diversity() {
        let spec = ArchiveSpec::new(Scale::Small, 2021);
        let params: Vec<_> = (0..spec.n_datasets).map(|i| spec.dataset_params(i)).collect();
        let zeros = params.iter().filter(|p| p.window == 0).count();
        let nonzero = params.len() - zeros;
        assert!(zeros >= 10, "too few zero-window datasets: {zeros}");
        assert!(nonzero >= 40, "too few windowed datasets: {nonzero}");
        // Length diversity
        let min_len = params.iter().map(|p| p.len).min().unwrap();
        let max_len = params.iter().map(|p| p.len).max().unwrap();
        assert!(max_len > 2 * min_len);
    }
}
