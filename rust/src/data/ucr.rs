//! UCR time-series archive loader.
//!
//! The UCR archive stores each dataset as two delimited text files,
//! `<Name>_TRAIN.tsv` / `<Name>_TEST.tsv` (tab-separated in the 2018
//! release, comma-separated in older ones): one series per line, first
//! field the class label, remaining fields the values.
//!
//! [`load_dataset`] reads one dataset; [`load_archive`] walks a directory
//! of dataset subdirectories (the archive layout) and loads everything.
//! Labels are remapped to dense `0..k` integers; values are optionally
//! z-normalized (the archive ships mostly-normalized data, but older
//! datasets are raw — normalizing is idempotent and standard practice).

use std::fs;
use std::io::{BufRead, BufReader};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::znorm::znormalize;
use super::{Dataset, Labeled};

/// Parse one UCR line (tab, comma or space separated).
fn parse_line(line: &str) -> Result<(f64, Vec<f64>)> {
    let mut fields = line
        .split(|c: char| c == '\t' || c == ',' || c == ' ')
        .filter(|f| !f.is_empty());
    let label: f64 = fields
        .next()
        .context("empty line")?
        .parse()
        .context("unparsable label")?;
    let values: Vec<f64> = fields
        .map(|f| f.parse::<f64>().context("unparsable value"))
        .collect::<Result<_>>()?;
    if values.is_empty() {
        bail!("series with no values");
    }
    Ok((label, values))
}

/// Read one `_TRAIN`/`_TEST` file into labelled series.
fn read_split(path: &Path, znorm: bool) -> Result<Vec<(f64, Vec<f64>)>> {
    let file = fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in BufReader::new(file).lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (label, mut values) =
            parse_line(&line).with_context(|| format!("{}:{}", path.display(), ln + 1))?;
        if znorm {
            znormalize(&mut values);
        }
        out.push((label, values));
    }
    if out.is_empty() {
        bail!("{}: no series", path.display());
    }
    Ok(out)
}

/// Load one dataset directory (`<dir>/<name>_TRAIN.tsv` etc.).
///
/// `window` is the recommended warping window in elements; the archive
/// publishes it as a percentage per dataset — pass the resolved value, or
/// compute one with [`crate::search::loocv`].
pub fn load_dataset(dir: &Path, name: &str, window: usize, znorm: bool) -> Result<Dataset> {
    let find = |suffix: &str| -> Result<Vec<(f64, Vec<f64>)>> {
        for ext in ["tsv", "txt", "csv"] {
            let p = dir.join(format!("{name}_{suffix}.{ext}"));
            if p.exists() {
                return read_split(&p, znorm);
            }
        }
        bail!("no {name}_{suffix}.(tsv|txt|csv) under {}", dir.display())
    };
    let train_raw = find("TRAIN")?;
    let test_raw = find("TEST")?;

    // Dense label remap shared across splits.
    let mut labels: Vec<i64> = train_raw
        .iter()
        .chain(test_raw.iter())
        .map(|(l, _)| l.round() as i64)
        .collect();
    labels.sort_unstable();
    labels.dedup();
    let to_dense = |l: f64| -> u32 {
        labels.binary_search(&(l.round() as i64)).expect("label seen above") as u32
    };

    let convert = |raw: Vec<(f64, Vec<f64>)>| -> Vec<Labeled> {
        raw.into_iter()
            .map(|(l, values)| Labeled { label: to_dense(l), values })
            .collect()
    };
    Ok(Dataset {
        name: name.to_string(),
        train: convert(train_raw),
        test: convert(test_raw),
        window,
    })
}

/// Walk an archive directory (`<root>/<DatasetName>/<DatasetName>_TRAIN.tsv`)
/// and load every dataset found, sorted by name. Windows default to 0 and
/// should be set by the caller (e.g. via LOOCV).
pub fn load_archive(root: &Path, znorm: bool) -> Result<Vec<Dataset>> {
    let mut names: Vec<String> = fs::read_dir(root)
        .with_context(|| format!("read_dir {}", root.display()))?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().is_dir())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    let mut out = Vec::new();
    for name in names {
        let dir = root.join(&name);
        match load_dataset(&dir, &name, 0, znorm) {
            Ok(ds) => out.push(ds),
            Err(e) => log::warn!("skipping {name}: {e:#}"),
        }
    }
    Ok(out)
}

/// Write a dataset back out in UCR `.tsv` format (used to export the
/// synthetic archive so the Python layer reads the identical bytes).
pub fn save_dataset(dir: &Path, ds: &Dataset) -> Result<()> {
    fs::create_dir_all(dir)?;
    let write_split = |suffix: &str, rows: &[Labeled]| -> Result<()> {
        let mut s = String::new();
        for r in rows {
            s.push_str(&r.label.to_string());
            for v in &r.values {
                s.push('\t');
                s.push_str(&format!("{v:.6}"));
            }
            s.push('\n');
        }
        fs::write(dir.join(format!("{}_{suffix}.tsv", ds.name)), s)?;
        Ok(())
    };
    write_split("TRAIN", &ds.train)?;
    write_split("TEST", &ds.test)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_line_variants() {
        let (l, v) = parse_line("2\t1.5\t-0.25\t3").unwrap();
        assert_eq!(l, 2.0);
        assert_eq!(v, vec![1.5, -0.25, 3.0]);
        let (l, v) = parse_line("1,0.5,0.25").unwrap();
        assert_eq!((l, v.len()), (1.0, 2));
        let (l, _) = parse_line("-1  0.5  0.25").unwrap();
        assert_eq!(l, -1.0);
        assert!(parse_line("").is_err());
        assert!(parse_line("1").is_err());
        assert!(parse_line("x\t1").is_err());
    }

    #[test]
    fn roundtrip_save_load() {
        let tmp = std::env::temp_dir().join(format!("dtwb_ucr_test_{}", std::process::id()));
        let ds = Dataset {
            name: "Toy".into(),
            train: vec![
                Labeled { label: 0, values: vec![0.0, 1.0, 2.0] },
                Labeled { label: 1, values: vec![2.0, 1.0, 0.0] },
            ],
            test: vec![Labeled { label: 1, values: vec![1.0, 1.0, 0.0] }],
            window: 1,
        };
        save_dataset(&tmp, &ds).unwrap();
        let back = load_dataset(&tmp, "Toy", 1, false).unwrap();
        assert_eq!(back.train.len(), 2);
        assert_eq!(back.test.len(), 1);
        assert_eq!(back.train[0].values, vec![0.0, 1.0, 2.0]);
        assert_eq!(back.train[1].label, 1);
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn label_remap_is_dense() {
        let tmp = std::env::temp_dir().join(format!("dtwb_ucr_test2_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("X_TRAIN.tsv"), "5\t1\t2\n-1\t0\t1\n5\t2\t3\n").unwrap();
        std::fs::write(tmp.join("X_TEST.tsv"), "-1\t1\t1\n").unwrap();
        let ds = load_dataset(&tmp, "X", 0, false).unwrap();
        let mut labels: Vec<u32> = ds.train.iter().map(|s| s.label).collect();
        labels.sort_unstable();
        assert_eq!(labels, vec![0, 1, 1]);
        assert_eq!(ds.test[0].label, 0);
        std::fs::remove_dir_all(&tmp).ok();
    }
}
