//! Data substrate: UCR-format archives, z-normalization, deterministic
//! randomness and the synthetic archive generator.
//!
//! The paper evaluates on the 85-dataset "bakeoff" version of the UCR
//! archive. That archive is not redistributable and this build environment
//! has no network, so [`synthetic`] generates an 85-dataset stand-in whose
//! per-dataset shape statistics (series length, class count, train/test
//! sizes, smoothness, intra-class warp) span the published ranges of the
//! real archive — see `DESIGN.md` §4 for the substitution argument. The
//! [`ucr`] loader reads the real archive's `.tsv` format, so dropping
//! `UCRArchive_2018/` into `data/` runs every experiment on real data
//! unchanged.

pub mod rng;
pub mod synthetic;
pub mod ucr;
pub mod znorm;

/// A labelled time series.
#[derive(Debug, Clone, PartialEq)]
pub struct Labeled {
    /// Class label (UCR labels are small integers; we normalize to u32).
    pub label: u32,
    /// The series values.
    pub values: Vec<f64>,
}

/// A train/test split of labelled series — one UCR dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Dataset name (e.g. `Synth07` or `FordB`).
    pub name: String,
    /// Training series.
    pub train: Vec<Labeled>,
    /// Test (query) series.
    pub test: Vec<Labeled>,
    /// The archive's recommended warping window (absolute, in elements).
    /// Derived by LOOCV on the training set, like the UCR archive does.
    pub window: usize,
}

impl Dataset {
    /// Series length ℓ (uniform within a dataset).
    pub fn series_len(&self) -> usize {
        self.train.first().map(|s| s.values.len()).unwrap_or(0)
    }

    /// Number of distinct classes.
    pub fn num_classes(&self) -> usize {
        let mut labels: Vec<u32> = self.train.iter().map(|s| s.label).collect();
        labels.sort_unstable();
        labels.dedup();
        labels.len()
    }

    /// Window as a fraction of series length, rounded **up** like the
    /// paper's §6.3 sweep ("we round fractional values up in order to
    /// avoid windows of size zero").
    pub fn window_fraction(&self, frac: f64) -> usize {
        let l = self.series_len() as f64;
        (l * frac).ceil() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_fraction_rounds_up() {
        let d = Dataset {
            name: "t".into(),
            train: vec![Labeled { label: 0, values: vec![0.0; 150] }],
            test: vec![],
            window: 1,
        };
        assert_eq!(d.window_fraction(0.01), 2); // 1.5 → 2
        assert_eq!(d.window_fraction(0.10), 15);
        assert_eq!(d.window_fraction(0.20), 30);
    }

    #[test]
    fn num_classes_dedups() {
        let mk = |l| Labeled { label: l, values: vec![0.0] };
        let d = Dataset {
            name: "t".into(),
            train: vec![mk(1), mk(2), mk(1), mk(7)],
            test: vec![],
            window: 0,
        };
        assert_eq!(d.num_classes(), 3);
    }
}
