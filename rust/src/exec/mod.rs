//! Dependency-free parallel execution: a scoped thread-pool
//! ([`Executor`]) with a dynamically-chunked work queue ([`WorkQueue`]).
//!
//! The serving stack's hot loops — candidate screening inside one k-NN
//! query, query rows inside one batched prefilter execution, candidate
//! scoring inside one stream window — are all embarrassingly parallel
//! over an index range with *uneven* per-item cost (early abandoning
//! makes some candidates 100× cheaper than others). The executor
//! therefore hands workers *chunks* off a shared atomic counter rather
//! than a static partition: fast workers steal the tail.
//!
//! Workers are **scoped std threads** spawned per [`Executor::run`]
//! call (no persistent pool, no channels, no dependencies): borrowing
//! the enclosing stack frame is what lets kernels share the query,
//! training set and output buffers without `Arc`-wrapping anything.
//! Spawn cost is a few tens of microseconds — negligible against the
//! multi-millisecond searches this parallelizes; single-item or
//! single-thread workloads run inline on the caller's thread, so
//! `threads = 1` is byte-identical to not using the executor at all.
//!
//! ## Example
//!
//! ```
//! use dtw_bounds::exec::Executor;
//! use std::sync::atomic::{AtomicU64, Ordering};
//!
//! let exec = Executor::new(4);
//! let sum = AtomicU64::new(0);
//! exec.run(1000, 64, |_worker, queue| {
//!     let mut local = 0u64;
//!     while let Some(range) = queue.next_chunk() {
//!         local += range.map(|i| i as u64).sum::<u64>();
//!     }
//!     sum.fetch_add(local, Ordering::Relaxed);
//! });
//! assert_eq!(sum.into_inner(), 999 * 1000 / 2);
//! ```

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// A dynamically-chunked index queue over `0..n`: workers pull disjoint
/// ranges until the queue drains.
#[derive(Debug)]
pub struct WorkQueue {
    next: AtomicUsize,
    n: usize,
    chunk: usize,
}

impl WorkQueue {
    /// A queue over `0..n` handing out chunks of (at most) `chunk`.
    pub fn new(n: usize, chunk: usize) -> WorkQueue {
        WorkQueue { next: AtomicUsize::new(0), n, chunk: chunk.max(1) }
    }

    /// The next unclaimed range, or `None` when the queue is drained.
    #[inline]
    pub fn next_chunk(&self) -> Option<Range<usize>> {
        let start = self.next.fetch_add(self.chunk, Ordering::Relaxed);
        if start >= self.n {
            return None;
        }
        Some(start..(start + self.chunk).min(self.n))
    }

    /// Total items in the queue.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when the queue covers no items.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

/// A scoped thread-pool with a fixed thread-count knob.
///
/// Cheap to construct (it is just the knob); each [`Executor::run`]
/// spawns scoped workers that may borrow the caller's stack. See the
/// module docs for the design rationale.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Executor {
    threads: usize,
}

impl Executor {
    /// An executor over `threads` workers. `0` selects the machine's
    /// available parallelism (falling back to 1 when unknown).
    pub fn new(threads: usize) -> Executor {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Executor { threads }
    }

    /// A serial executor (everything runs inline).
    pub fn serial() -> Executor {
        Executor { threads: 1 }
    }

    /// The resolved worker count.
    #[inline]
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `body(worker_id, queue)` on up to [`Executor::threads`]
    /// workers over a [`WorkQueue`] of `n` items in chunks of `chunk`.
    ///
    /// Each worker is invoked **once** (set up thread-local scratch
    /// there, then pull chunks in a loop); worker ids are dense in
    /// `0..workers`. With one effective worker the body runs inline on
    /// the caller's thread — no spawn, no synchronization. Panicking
    /// workers surface as exactly **one** resumed panic on the caller's
    /// thread after every worker has joined, so an enclosing
    /// `catch_unwind` (the router's request isolation) always contains
    /// the failure.
    pub fn run<F>(&self, n: usize, chunk: usize, body: F)
    where
        F: Fn(usize, &WorkQueue) + Sync,
    {
        let queue = WorkQueue::new(n, chunk);
        // No point spawning workers that could never claim a chunk.
        let workers = self.threads.min(n.div_ceil(chunk.max(1))).max(1);
        if workers == 1 {
            body(0, &queue);
            return;
        }
        // Catch each worker's panic and resume only the first, once,
        // after the scope joins. Letting panics cross the scope raw can
        // panic-while-panicking (the caller's inline body unwinding
        // while a joined worker also panicked), which **aborts the
        // process** — fatal to a serving router whose catch_unwind
        // isolation assumes panics stay unwindable.
        let first_panic: std::sync::Mutex<Option<Box<dyn std::any::Any + Send>>> =
            std::sync::Mutex::new(None);
        let guarded = |wid: usize, queue: &WorkQueue| {
            let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                body(wid, queue)
            }));
            if let Err(payload) = attempt {
                let mut slot =
                    first_panic.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
                slot.get_or_insert(payload);
            }
        };
        let guarded = &guarded;
        let queue = &queue;
        std::thread::scope(|scope| {
            for wid in 1..workers {
                scope.spawn(move || guarded(wid, queue));
            }
            guarded(0, queue);
        });
        let payload =
            first_panic.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner());
        if let Some(payload) = payload {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Default for Executor {
    fn default() -> Executor {
        Executor::serial()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Mutex;

    #[test]
    fn covers_every_index_exactly_once() {
        for &threads in &[1usize, 2, 3, 8] {
            for &(n, chunk) in &[(0usize, 4usize), (1, 4), (7, 3), (100, 1), (100, 7), (5, 100)] {
                let exec = Executor::new(threads);
                let seen = Mutex::new(vec![0u32; n]);
                exec.run(n, chunk, |_wid, queue| {
                    while let Some(range) = queue.next_chunk() {
                        let mut seen = seen.lock().unwrap();
                        for i in range {
                            seen[i] += 1;
                        }
                    }
                });
                let seen = seen.into_inner().unwrap();
                assert!(
                    seen.iter().all(|&c| c == 1),
                    "threads={threads} n={n} chunk={chunk}: {seen:?}"
                );
            }
        }
    }

    #[test]
    fn worker_ids_are_dense_and_bounded() {
        let exec = Executor::new(4);
        let max_wid = AtomicU64::new(0);
        exec.run(1000, 1, |wid, queue| {
            max_wid.fetch_max(wid as u64, Ordering::Relaxed);
            while queue.next_chunk().is_some() {}
        });
        assert!(max_wid.into_inner() < 4);
    }

    #[test]
    fn zero_resolves_to_available_parallelism() {
        assert!(Executor::new(0).threads() >= 1);
        assert_eq!(Executor::serial().threads(), 1);
        assert_eq!(Executor::default().threads(), 1);
    }

    #[test]
    fn panicking_workers_surface_as_one_caller_panic() {
        // Every worker panics (the worst case: caller's inline body
        // unwinding while joined workers also panicked). That must
        // reach us as a single unwindable panic — never an abort.
        let exec = Executor::new(4);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            exec.run(1000, 1, |wid, _queue| {
                panic!("worker {wid} down");
            });
        }));
        let payload = caught.expect_err("the panic must propagate");
        let msg = payload.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("down"), "original payload preserved: {msg}");
    }

    #[test]
    fn single_worker_runs_inline() {
        // Inline execution must happen on the calling thread.
        let caller = std::thread::current().id();
        let exec = Executor::serial();
        exec.run(10, 4, |wid, queue| {
            assert_eq!(wid, 0);
            assert_eq!(std::thread::current().id(), caller);
            while queue.next_chunk().is_some() {}
        });
    }
}
