//! The [`LbBackend`] abstraction — *one* interface for every batched
//! `LB_KEOGH` screening implementation (pure Rust, PJRT/XLA, and future
//! GPU or sharded backends plug in here).
//!
//! Contract, honoured by every implementation:
//!
//! 1. **Prepare once** — candidate envelopes arrive as
//!    [`PreparedSeries`], computed once per training set (the paper's
//!    experimental protocol: envelope preparation is off the query path).
//! 2. **Bound matrix** — [`LbBackend::compute_into`] fills a flat
//!    row-major [`BoundMatrix`] with `out[q][t] ≤ DTW_w(queries[q],
//!    train[t])` for δ = squared difference. An entry may be *partial*
//!    (early-abandoned) once it exceeds `cutoffs[q]`: a partial sum of
//!    non-negative allowances is still a valid lower bound, so
//!    downstream search stays exact. The matrix is caller-owned and
//!    reused across calls — the batch hot path allocates nothing per
//!    execution.
//! 3. **Rank** — [`LbBackend::rank_into`] argsorts each query's row
//!    ascending: the candidate visiting order of the paper's
//!    Algorithm 4.
//! 4. **Shards** — backends that can screen straight off a shard's flat
//!    [`crate::bounds::store::EnvelopeStore`] rows advertise it with
//!    [`LbBackend::supports_stores`] and implement
//!    [`LbBackend::compute_sharded_into`]: each shard's rows fill its
//!    own column block of the same [`BoundMatrix`], so a sharded index
//!    is screened **without re-concatenating** its stores (and without
//!    the backend keeping a private envelope copy). The matrix — and
//!    therefore the search — is bit-identical to the unsharded path.

use crate::bounds::store::ShardStore;
use crate::bounds::PreparedSeries;

/// A flat row-major `queries × candidates` bound matrix: one
/// allocation, reused across batch executions (`row(q)` is the per-query
/// view the sorted walk consumes). Indexing with `m[q]` yields the row,
/// so `m[q][t]` reads like the old nested-`Vec` layout.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BoundMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl BoundMatrix {
    /// An empty matrix (no allocation until first use).
    pub fn new() -> BoundMatrix {
        BoundMatrix::default()
    }

    /// Reshape to `rows × cols`, zero-filled, reusing the allocation
    /// when it is already large enough.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Number of rows (queries). Named `len` to mirror the nested-`Vec`
    /// layout this replaced.
    #[inline]
    pub fn len(&self) -> usize {
        self.rows
    }

    /// True when the matrix has no rows.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Number of columns (candidates).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `q` as a slice (one bound per candidate).
    #[inline]
    pub fn row(&self, q: usize) -> &[f64] {
        &self.data[q * self.cols..(q + 1) * self.cols]
    }

    /// Mutable row `q`.
    #[inline]
    pub fn row_mut(&mut self, q: usize) -> &mut [f64] {
        &mut self.data[q * self.cols..(q + 1) * self.cols]
    }

    /// Iterate over rows.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f64]> {
        self.data.chunks_exact(self.cols.max(1)).take(self.rows)
    }

    /// The flat row-major storage.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// The flat row-major storage, mutable (rows are disjoint
    /// `cols`-sized windows — what the parallel fill writes through).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }
}

impl std::ops::Index<usize> for BoundMatrix {
    type Output = [f64];
    #[inline]
    fn index(&self, q: usize) -> &[f64] {
        self.row(q)
    }
}

/// Result of [`LbBackend::rank`]: the bound matrix plus, per query, the
/// candidate indices in ascending-bound order. Reused across batches via
/// [`LbBackend::rank_into`].
#[derive(Debug, Clone, Default)]
pub struct Ranking {
    /// `bounds[q][t]`: `LB_KEOGH` of query `q` vs candidate `t`
    /// (possibly a partial, early-abandoned sum — still a lower bound).
    pub bounds: BoundMatrix,
    /// `order[q]`: candidate indices sorted by ascending `bounds[q]`.
    pub order: Vec<Vec<usize>>,
}

/// Argsort every row of `bounds` ascending into `order` (reusing its
/// allocations) — the shared tail of [`LbBackend::rank_into`] and
/// [`LbBackend::rank_sharded_into`].
fn argsort_rows(bounds: &BoundMatrix, order: &mut Vec<Vec<usize>>) {
    let nq = bounds.len();
    order.truncate(nq);
    while order.len() < nq {
        order.push(Vec::new());
    }
    for (q, ord) in order.iter_mut().enumerate() {
        let row = bounds.row(q);
        ord.clear();
        ord.extend(0..row.len());
        ord.sort_unstable_by(|&a, &b| {
            row[a].partial_cmp(&row[b]).expect("bounds are never NaN")
        });
    }
}

/// A batched `LB_KEOGH` screening backend.
///
/// Backends are owned by one engine and called from one thread (PJRT
/// handles are not `Send`, so the trait deliberately does not require
/// it); the engine itself lives inside the router's dispatch thread.
/// Backends may fan work out internally (see
/// [`super::NativeBatchLb::with_threads`]).
pub trait LbBackend {
    /// Short name for logs and the CLI (`native`, `pjrt`, …).
    fn name(&self) -> &'static str;

    /// True when the backend can score `batch` queries against `rows`
    /// candidates of series length `len`. Fixed-shape backends (AOT
    /// artifacts) reject workloads larger than their compiled shape.
    fn supports(&self, batch: usize, rows: usize, len: usize) -> bool;

    /// Whether [`LbBackend::compute_into`] honours per-query `cutoffs`
    /// (row early-abandoning). Branch-free fused backends return
    /// `false`, and the engine then skips paying for seed DTWs that
    /// would buy nothing. Defaults to `true`.
    fn uses_cutoffs(&self) -> bool {
        true
    }

    /// Fill `out` (reshaped to `queries.len() × train.len()`) with the
    /// bound matrix `out[q][t] = LB_KEOGH(queries[q], train[t])` under
    /// the squared-difference δ.
    ///
    /// `cutoffs[q]` is the per-query best-so-far DTW distance
    /// (`f64::INFINITY` disables abandoning); backends may leave partial
    /// sums above it. All series must share one length.
    fn compute_into(
        &mut self,
        queries: &[&[f64]],
        train: &[PreparedSeries],
        cutoffs: &[f64],
        out: &mut BoundMatrix,
    ) -> anyhow::Result<()>;

    /// Allocating convenience over [`LbBackend::compute_into`].
    fn compute(
        &mut self,
        queries: &[&[f64]],
        train: &[PreparedSeries],
        cutoffs: &[f64],
    ) -> anyhow::Result<BoundMatrix> {
        let mut out = BoundMatrix::new();
        self.compute_into(queries, train, cutoffs, &mut out)?;
        Ok(out)
    }

    /// Compute the matrix into `out.bounds`, then argsort each query's
    /// row ascending into `out.order` — the visiting order of
    /// Algorithm 4. Reuses `out`'s allocations across batches; the
    /// facade's batched path consumes this (the per-query walk happens
    /// in `search::knn::knn_sorted_precomputed`).
    fn rank_into(
        &mut self,
        queries: &[&[f64]],
        train: &[PreparedSeries],
        cutoffs: &[f64],
        out: &mut Ranking,
    ) -> anyhow::Result<()> {
        self.compute_into(queries, train, cutoffs, &mut out.bounds)?;
        argsort_rows(&out.bounds, &mut out.order);
        Ok(())
    }

    /// True when the backend can screen a sharded index straight off its
    /// flat [`crate::bounds::store::EnvelopeStore`] rows
    /// ([`LbBackend::compute_sharded_into`]). Defaults to `false`;
    /// callers with shards then fall back to the [`PreparedSeries`]
    /// entry points, which compute the identical matrix.
    fn supports_stores(&self) -> bool {
        false
    }

    /// Fill `out` (reshaped to `queries.len() × Σ shard sizes`) with the
    /// bound matrix, screening each shard's flat envelope rows directly:
    /// shard `s` fills the column block `s.range()` of every query row,
    /// so no concatenated envelope copy is ever materialized. Shards
    /// must be contiguous (`shard[i].start() == shard[i-1].range().end`,
    /// first start 0) and share the query length. The resulting matrix
    /// is **bit-identical** to [`LbBackend::compute_into`] over the same
    /// candidates in global order.
    ///
    /// Only meaningful when [`LbBackend::supports_stores`] is `true`;
    /// the default errs.
    fn compute_sharded_into(
        &mut self,
        _queries: &[&[f64]],
        _shards: &[ShardStore],
        _cutoffs: &[f64],
        _out: &mut BoundMatrix,
    ) -> anyhow::Result<()> {
        anyhow::bail!("backend {} has no flat-store screening path", self.name())
    }

    /// [`LbBackend::rank_into`] over a sharded index: compute the matrix
    /// via [`LbBackend::compute_sharded_into`], then argsort each query's
    /// row ascending over the **global** candidate ids.
    fn rank_sharded_into(
        &mut self,
        queries: &[&[f64]],
        shards: &[ShardStore],
        cutoffs: &[f64],
        out: &mut Ranking,
    ) -> anyhow::Result<()> {
        self.compute_sharded_into(queries, shards, cutoffs, &mut out.bounds)?;
        argsort_rows(&out.bounds, &mut out.order);
        Ok(())
    }

    /// Allocating convenience over [`LbBackend::rank_into`].
    fn rank(
        &mut self,
        queries: &[&[f64]],
        train: &[PreparedSeries],
        cutoffs: &[f64],
    ) -> anyhow::Result<Ranking> {
        let mut out = Ranking::default();
        self.rank_into(queries, train, cutoffs, &mut out)?;
        Ok(out)
    }
}

/// Which screening backend the CLI / server should attach.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// No batched screening — scalar Algorithm 4 per query.
    None,
    /// [`super::NativeBatchLb`]: the default, dependency-free pure-Rust
    /// backend.
    Native,
    /// The PJRT/XLA artifact backend (requires the `pjrt` cargo
    /// feature and AOT artifacts from `python/compile/aot.py`).
    Pjrt,
}

impl BackendKind {
    /// CLI spellings accepted by [`BackendKind::parse`].
    pub const CHOICES: &'static [&'static str] = &["native", "pjrt", "none"];

    /// Parse a CLI spelling (case-insensitive; accepts a few aliases).
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "rust" => Some(BackendKind::Native),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            "none" | "scalar" | "off" => Some(BackendKind::None),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::None => "none",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for (s, k) in [
            ("native", BackendKind::Native),
            ("RUST", BackendKind::Native),
            ("pjrt", BackendKind::Pjrt),
            ("xla", BackendKind::Pjrt),
            ("none", BackendKind::None),
            ("off", BackendKind::None),
        ] {
            assert_eq!(BackendKind::parse(s), Some(k), "{s}");
            if BackendKind::parse(k.name()) != Some(k) {
                panic!("canonical name {} does not re-parse", k.name());
            }
        }
        assert_eq!(BackendKind::parse("tpu"), None);
        for c in BackendKind::CHOICES {
            assert!(BackendKind::parse(c).is_some(), "{c}");
        }
    }

    #[test]
    fn bound_matrix_shapes_and_rows() {
        let mut m = BoundMatrix::new();
        assert!(m.is_empty());
        m.reset(2, 3);
        assert_eq!((m.len(), m.cols()), (2, 3));
        m.row_mut(0).copy_from_slice(&[3.0, 1.0, 2.0]);
        m.row_mut(1).copy_from_slice(&[0.0, 5.0, 4.0]);
        assert_eq!(&m[0], &[3.0, 1.0, 2.0]);
        assert_eq!(m[1][1], 5.0);
        let rows: Vec<&[f64]> = m.iter_rows().collect();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], &[0.0, 5.0, 4.0]);
        // Reset reuses the allocation and re-zeroes.
        m.reset(1, 2);
        assert_eq!(&m[0], &[0.0, 0.0]);
    }

    /// A backend that returns a fixed matrix — exercises the provided
    /// `rank` argsort and the reusable `rank_into` path.
    struct Fixed(Vec<Vec<f64>>);

    impl LbBackend for Fixed {
        fn name(&self) -> &'static str {
            "fixed"
        }
        fn supports(&self, _b: usize, _n: usize, _l: usize) -> bool {
            true
        }
        fn compute_into(
            &mut self,
            _queries: &[&[f64]],
            _train: &[PreparedSeries],
            _cutoffs: &[f64],
            out: &mut BoundMatrix,
        ) -> anyhow::Result<()> {
            let cols = self.0.first().map(|r| r.len()).unwrap_or(0);
            out.reset(self.0.len(), cols);
            for (q, row) in self.0.iter().enumerate() {
                out.row_mut(q).copy_from_slice(row);
            }
            Ok(())
        }
    }

    #[test]
    fn default_rank_sorts_ascending() {
        let mut be = Fixed(vec![vec![3.0, 1.0, 2.0], vec![0.0, 5.0, 4.0]]);
        assert!(be.uses_cutoffs(), "cutoff support is the default");
        let r = be.rank(&[], &[], &[]).unwrap();
        assert_eq!(r.order, vec![vec![1, 2, 0], vec![0, 2, 1]]);
        assert_eq!(r.bounds[0][r.order[0][0]], 1.0);

        // rank_into reuses buffers across calls.
        let mut reused = Ranking::default();
        be.rank_into(&[], &[], &[], &mut reused).unwrap();
        assert_eq!(reused.order, r.order);
        be.0 = vec![vec![1.0, 0.0]];
        be.rank_into(&[], &[], &[], &mut reused).unwrap();
        assert_eq!(reused.order, vec![vec![1, 0]]);
        assert_eq!(reused.bounds.len(), 1);
    }

    #[test]
    fn store_screening_is_opt_in() {
        // Backends that never implemented the flat-store path advertise
        // that, and the sharded entry points fail loudly instead of
        // silently screening nothing.
        let mut be = Fixed(vec![vec![1.0, 2.0]]);
        assert!(!be.supports_stores());
        let mut m = BoundMatrix::new();
        assert!(be.compute_sharded_into(&[], &[], &[], &mut m).is_err());
        let mut r = Ranking::default();
        assert!(be.rank_sharded_into(&[], &[], &[], &mut r).is_err());
    }
}
