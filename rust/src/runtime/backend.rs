//! The [`LbBackend`] abstraction — *one* interface for every batched
//! `LB_KEOGH` screening implementation (pure Rust, PJRT/XLA, and future
//! GPU or sharded backends plug in here).
//!
//! Contract, honoured by every implementation:
//!
//! 1. **Prepare once** — candidate envelopes arrive as
//!    [`PreparedSeries`], computed once per training set (the paper's
//!    experimental protocol: envelope preparation is off the query path).
//! 2. **Bound matrix** — [`LbBackend::compute`] returns `out[q][t]` with
//!    `out[q][t] ≤ DTW_w(queries[q], train[t])` for δ = squared
//!    difference. An entry may be *partial* (early-abandoned) once it
//!    exceeds `cutoffs[q]`: a partial sum of non-negative allowances is
//!    still a valid lower bound, so downstream search stays exact.
//! 3. **Rank** — [`LbBackend::rank`] argsorts each query's row ascending:
//!    the candidate visiting order of the paper's Algorithm 4.

use crate::bounds::PreparedSeries;

/// Result of [`LbBackend::rank`]: the bound matrix plus, per query, the
/// candidate indices in ascending-bound order.
#[derive(Debug, Clone, Default)]
pub struct Ranking {
    /// `bounds[q][t]`: `LB_KEOGH` of query `q` vs candidate `t`
    /// (possibly a partial, early-abandoned sum — still a lower bound).
    pub bounds: Vec<Vec<f64>>,
    /// `order[q]`: candidate indices sorted by ascending `bounds[q]`.
    pub order: Vec<Vec<usize>>,
}

/// A batched `LB_KEOGH` screening backend.
///
/// Backends are owned by one engine and called from one thread (PJRT
/// handles are not `Send`, so the trait deliberately does not require
/// it); the engine itself lives inside the router's dispatch thread.
pub trait LbBackend {
    /// Short name for logs and the CLI (`native`, `pjrt`, …).
    fn name(&self) -> &'static str;

    /// True when the backend can score `batch` queries against `rows`
    /// candidates of series length `len`. Fixed-shape backends (AOT
    /// artifacts) reject workloads larger than their compiled shape.
    fn supports(&self, batch: usize, rows: usize, len: usize) -> bool;

    /// Whether [`LbBackend::compute`] honours per-query `cutoffs` (row
    /// early-abandoning). Branch-free fused backends return `false`, and
    /// the engine then skips paying for seed DTWs that would buy
    /// nothing. Defaults to `true`.
    fn uses_cutoffs(&self) -> bool {
        true
    }

    /// Compute the bound matrix `out[q][t] = LB_KEOGH(queries[q],
    /// train[t])` under the squared-difference δ.
    ///
    /// `cutoffs[q]` is the per-query best-so-far DTW distance
    /// (`f64::INFINITY` disables abandoning); backends may return partial
    /// sums above it. All series must share one length.
    fn compute(
        &mut self,
        queries: &[&[f64]],
        train: &[PreparedSeries],
        cutoffs: &[f64],
    ) -> anyhow::Result<Vec<Vec<f64>>>;

    /// Compute the matrix, then argsort each query's row ascending — the
    /// visiting order of Algorithm 4. Provided for all backends; the
    /// facade's batched path consumes this (the per-query walk happens in
    /// `search::knn::knn_sorted_precomputed`).
    fn rank(
        &mut self,
        queries: &[&[f64]],
        train: &[PreparedSeries],
        cutoffs: &[f64],
    ) -> anyhow::Result<Ranking> {
        let bounds = self.compute(queries, train, cutoffs)?;
        let order = bounds
            .iter()
            .map(|row| {
                let mut idx: Vec<usize> = (0..row.len()).collect();
                idx.sort_unstable_by(|&a, &b| {
                    row[a].partial_cmp(&row[b]).expect("bounds are never NaN")
                });
                idx
            })
            .collect();
        Ok(Ranking { bounds, order })
    }
}

/// Which screening backend the CLI / server should attach.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// No batched screening — scalar Algorithm 4 per query.
    None,
    /// [`super::NativeBatchLb`]: the default, dependency-free pure-Rust
    /// backend.
    Native,
    /// The PJRT/XLA artifact backend (requires the `pjrt` cargo
    /// feature and AOT artifacts from `python/compile/aot.py`).
    Pjrt,
}

impl BackendKind {
    /// CLI spellings accepted by [`BackendKind::parse`].
    pub const CHOICES: &'static [&'static str] = &["native", "pjrt", "none"];

    /// Parse a CLI spelling (case-insensitive; accepts a few aliases).
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "rust" => Some(BackendKind::Native),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            "none" | "scalar" | "off" => Some(BackendKind::None),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::None => "none",
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for (s, k) in [
            ("native", BackendKind::Native),
            ("RUST", BackendKind::Native),
            ("pjrt", BackendKind::Pjrt),
            ("xla", BackendKind::Pjrt),
            ("none", BackendKind::None),
            ("off", BackendKind::None),
        ] {
            assert_eq!(BackendKind::parse(s), Some(k), "{s}");
            if BackendKind::parse(k.name()) != Some(k) {
                panic!("canonical name {} does not re-parse", k.name());
            }
        }
        assert_eq!(BackendKind::parse("tpu"), None);
        for c in BackendKind::CHOICES {
            assert!(BackendKind::parse(c).is_some(), "{c}");
        }
    }

    /// A backend that returns a fixed matrix — exercises the provided
    /// `rank` argsort.
    struct Fixed(Vec<Vec<f64>>);

    impl LbBackend for Fixed {
        fn name(&self) -> &'static str {
            "fixed"
        }
        fn supports(&self, _b: usize, _n: usize, _l: usize) -> bool {
            true
        }
        fn compute(
            &mut self,
            _queries: &[&[f64]],
            _train: &[PreparedSeries],
            _cutoffs: &[f64],
        ) -> anyhow::Result<Vec<Vec<f64>>> {
            Ok(self.0.clone())
        }
    }

    #[test]
    fn default_rank_sorts_ascending() {
        let mut be = Fixed(vec![vec![3.0, 1.0, 2.0], vec![0.0, 5.0, 4.0]]);
        assert!(be.uses_cutoffs(), "cutoff support is the default");
        let r = be.rank(&[], &[], &[]).unwrap();
        assert_eq!(r.order, vec![vec![1, 2, 0], vec![0, 2, 1]]);
        assert_eq!(r.bounds[0][r.order[0][0]], 1.0);
    }
}
