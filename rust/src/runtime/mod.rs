//! Batched lower-bound runtime — the pluggable screening backends.
//!
//! The hot path of the serving stack is the *batched prefilter*: given a
//! query batch `Q[b,ℓ]` and a training set's envelopes, compute the full
//! bound matrix `out[q, t] = LB_KEOGH(Q_q, T_t)`, then rank candidates
//! per query so the engine runs exact DTW on survivors only — the batch
//! analogue of the paper's sorted search (Algorithm 4).
//!
//! * [`backend`] — the [`LbBackend`] trait every screening backend
//!   implements, plus [`BackendKind`] for CLI selection. This is the seam
//!   future scaling work (sharding, GPU, multi-node) plugs into.
//! * [`native`] — [`NativeBatchLb`]: the **default** backend. Pure Rust,
//!   dependency-free, streaming a flat 64-byte-aligned SoA envelope
//!   store ([`crate::bounds::store::EnvelopeStore`]) with the
//!   runtime-dispatched SIMD kernel ([`crate::simd`]: AVX2/SSE2/NEON,
//!   4-lane scalar fallback — identical bits at every ISA),
//!   early-abandoning against per-query cutoffs, and
//!   optionally scoring query rows in parallel
//!   ([`NativeBatchLb::with_threads`]). Results land in a reusable flat
//!   [`BoundMatrix`] — no per-call nested allocation.
//! * [`client`] / [`batch_lb`] (cargo feature `pjrt`) — the PJRT/XLA
//!   backend: loads AOT-compiled artifacts produced by the Python build
//!   layer (`python/compile/aot.py`; the hot inner loop is the Pallas
//!   kernel) and scores a whole batch in one XLA execution. Python is
//!   never on the query path.
//!
//! Artifact manifests ([`read_manifest`]) are parsed feature-independently
//! so `dtw-bounds info` can report on-disk artifacts in any build.
//!
//! ## Example
//!
//! One backend execution screens a whole batch: the bound matrix plus
//! each query's candidates in ascending-bound order (Algorithm 4's
//! visiting order):
//!
//! ```
//! use dtw_bounds::bounds::PreparedSeries;
//! use dtw_bounds::runtime::{LbBackend, NativeBatchLb};
//!
//! let w = 1;
//! let train = vec![
//!     PreparedSeries::prepare(vec![0.0, 0.0, 0.0, 0.0], w),
//!     PreparedSeries::prepare(vec![5.0, 5.0, 5.0, 5.0], w),
//! ];
//! let q = [0.1, 0.1, 0.1, 0.1];
//! let mut backend = NativeBatchLb::new();
//! assert!(backend.supports(1, train.len(), q.len()));
//! let ranking = backend.rank(&[&q[..]], &train, &[f64::INFINITY])?;
//! assert_eq!(ranking.order[0][0], 0, "the near candidate screens first");
//! assert!(ranking.bounds[0][0] < ranking.bounds[0][1]);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod backend;
pub mod native;

#[cfg(feature = "pjrt")]
pub mod batch_lb;
#[cfg(feature = "pjrt")]
pub mod client;

pub use backend::{BackendKind, BoundMatrix, LbBackend, Ranking};
pub use native::NativeBatchLb;

#[cfg(feature = "pjrt")]
pub use batch_lb::BatchLb;
#[cfg(feature = "pjrt")]
pub use client::{LoadedComputation, XlaRuntime};

use std::path::{Path, PathBuf};

/// Default artifacts directory (relative to the repo root / CWD).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("DTW_BOUNDS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// An entry in `artifacts/manifest.tsv` (written by `aot.py`):
/// `name`, compiled batch/rows/length, and the HLO file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Artifact kind, e.g. `lb_keogh`.
    pub name: String,
    /// Compiled query-batch size.
    pub batch: usize,
    /// Compiled training rows.
    pub rows: usize,
    /// Compiled series length.
    pub len: usize,
    /// HLO text file (relative to the manifest).
    pub file: String,
}

/// Parse `manifest.tsv`: one artifact per line,
/// `name<TAB>batch<TAB>rows<TAB>len<TAB>file`. Lines starting with `#`
/// are comments.
pub fn read_manifest(dir: &Path) -> anyhow::Result<Vec<ManifestEntry>> {
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() != 5 {
            anyhow::bail!("{}:{}: expected 5 fields, got {}", path.display(), ln + 1, f.len());
        }
        out.push(ManifestEntry {
            name: f[0].to_string(),
            batch: f[1].parse()?,
            rows: f[2].parse()?,
            len: f[3].parse()?,
            file: f[4].to_string(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse() {
        let tmp = std::env::temp_dir().join(format!("dtwb_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(
            tmp.join("manifest.tsv"),
            "# comment\nlb_keogh\t8\t64\t128\tlb_keogh_8x64x128.hlo.txt\n",
        )
        .unwrap();
        let m = read_manifest(&tmp).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].name, "lb_keogh");
        assert_eq!((m[0].batch, m[0].rows, m[0].len), (8, 64, 128));
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn manifest_missing_is_error() {
        let tmp = std::env::temp_dir().join("dtwb_definitely_missing_dir");
        assert!(read_manifest(&tmp).is_err());
    }
}
