//! PJRT runtime — loads the AOT-compiled XLA artifacts produced by the
//! Python build layer (`python/compile/aot.py`) and executes them from
//! Rust. Python never runs on the query path.
//!
//! * [`client`] — thin wrapper over the `xla` crate: CPU `PjRtClient`,
//!   HLO-**text** loading (`xla_extension` 0.5.1 rejects jax ≥ 0.5
//!   serialized protos; text round-trips — see `/opt/xla-example`),
//!   compile-once / execute-many.
//! * [`batch_lb`] — the batched `LB_KEOGH` prefilter: one XLA execution
//!   scores a whole query-batch against the whole training matrix
//!   (envelopes precomputed), which the coordinator uses to rank
//!   candidates before running exact DTW on survivors — the batch
//!   analogue of the paper's sorted search (Algorithm 4).

pub mod batch_lb;
pub mod client;

pub use batch_lb::BatchLb;
pub use client::{LoadedComputation, XlaRuntime};

use std::path::{Path, PathBuf};

/// Default artifacts directory (relative to the repo root / CWD).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("DTW_BOUNDS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// An entry in `artifacts/manifest.tsv` (written by `aot.py`):
/// `name`, compiled batch/rows/length, and the HLO file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Artifact kind, e.g. `lb_keogh`.
    pub name: String,
    /// Compiled query-batch size.
    pub batch: usize,
    /// Compiled training rows.
    pub rows: usize,
    /// Compiled series length.
    pub len: usize,
    /// HLO text file (relative to the manifest).
    pub file: String,
}

/// Parse `manifest.tsv`: one artifact per line,
/// `name<TAB>batch<TAB>rows<TAB>len<TAB>file`. Lines starting with `#`
/// are comments.
pub fn read_manifest(dir: &Path) -> anyhow::Result<Vec<ManifestEntry>> {
    let path = dir.join("manifest.tsv");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() != 5 {
            anyhow::bail!("{}:{}: expected 5 fields, got {}", path.display(), ln + 1, f.len());
        }
        out.push(ManifestEntry {
            name: f[0].to_string(),
            batch: f[1].parse()?,
            rows: f[2].parse()?,
            len: f[3].parse()?,
            file: f[4].to_string(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse() {
        let tmp = std::env::temp_dir().join(format!("dtwb_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(
            tmp.join("manifest.tsv"),
            "# comment\nlb_keogh\t8\t64\t128\tlb_keogh_8x64x128.hlo.txt\n",
        )
        .unwrap();
        let m = read_manifest(&tmp).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].name, "lb_keogh");
        assert_eq!((m[0].batch, m[0].rows, m[0].len), (8, 64, 128));
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn manifest_missing_is_error() {
        let tmp = std::env::temp_dir().join("dtwb_definitely_missing_dir");
        assert!(read_manifest(&tmp).is_err());
    }
}
