//! Batched `LB_KEOGH` prefilter on XLA.
//!
//! The artifact (see `python/compile/model.py`) computes, for a query
//! batch `Q[b,ℓ]` and a training set's envelopes `Lo[n,ℓ]`, `Up[n,ℓ]`,
//! the full bound matrix
//!
//! ```text
//! out[q, t] = Σ_i  (Q[q,i] − Up[t,i])²  if Q[q,i] > Up[t,i]
//!             (Q[q,i] − Lo[t,i])²  if Q[q,i] < Lo[t,i]
//!             0                    otherwise
//! ```
//!
//! in one XLA execution (the hot inner loop is the Pallas kernel at L1).
//! The coordinator uses the matrix to rank candidates per query, then runs
//! exact DTW on survivors — the batch analogue of Algorithm 4.
//!
//! Shapes are fixed at AOT time; [`BatchLb`] pads smaller workloads:
//! * queries: padded with zeros (extra rows ignored);
//! * training rows: padded with `Lo = -BIG, Up = +BIG` so padded rows
//!   bound to 0 and sort last;
//! * length: padded with `Q = 0` inside `[-BIG, BIG]` envelopes, adding
//!   exactly 0 to every bound.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::backend::{BoundMatrix, LbBackend};
use super::client::{LoadedComputation, XlaRuntime};
use super::{read_manifest, ManifestEntry};
use crate::bounds::PreparedSeries;

const BIG: f32 = 1e30;

/// A compiled batched-LB executable with its static shape.
pub struct BatchLb {
    exe: LoadedComputation,
    /// Compiled (batch, rows, len).
    pub shape: (usize, usize, usize),
    // Reused packing buffers (§Perf O4): padding + f64→f32 conversion
    // allocated once per compiled shape instead of per call.
    buf_q: Vec<f32>,
    buf_lo: Vec<f32>,
    buf_up: Vec<f32>,
}

impl BatchLb {
    /// Load the best-fitting `lb_keogh` artifact from `dir` for workloads
    /// of at most (`batch`, `rows`, `len`). Picks the smallest compiled
    /// shape that fits; errors when none fits.
    pub fn load(rt: &XlaRuntime, dir: &Path, batch: usize, rows: usize, len: usize) -> Result<Self> {
        let manifest = read_manifest(dir)?;
        let mut candidates: Vec<&ManifestEntry> = manifest
            .iter()
            .filter(|e| e.name == "lb_keogh" && e.batch >= batch && e.rows >= rows && e.len >= len)
            .collect();
        if candidates.is_empty() {
            bail!(
                "no lb_keogh artifact fits (batch={batch}, rows={rows}, len={len}); \
                 available: {:?}; run `make artifacts`",
                manifest.iter().map(|e| (e.batch, e.rows, e.len)).collect::<Vec<_>>()
            );
        }
        candidates.sort_by_key(|e| e.batch * e.rows * e.len);
        let chosen = candidates[0];
        let exe = rt
            .load_hlo_text(&dir.join(&chosen.file))
            .with_context(|| format!("load artifact {}", chosen.file))?;
        log::info!(
            "batch_lb: loaded {} (b={}, n={}, l={})",
            chosen.file,
            chosen.batch,
            chosen.rows,
            chosen.len
        );
        let (cb, cn, cl) = (chosen.batch, chosen.rows, chosen.len);
        Ok(BatchLb {
            exe,
            shape: (cb, cn, cl),
            buf_q: vec![0.0; cb * cl],
            buf_lo: vec![-BIG; cn * cl],
            buf_up: vec![BIG; cn * cl],
        })
    }

    /// Compute the `queries.len() × train_lo.len()` LB_Keogh matrix.
    ///
    /// All series must share one length ≤ compiled `len`; `queries` and
    /// the training envelopes are padded up to the compiled shape.
    pub fn compute_matrix(
        &mut self,
        queries: &[&[f64]],
        train_lo: &[&[f64]],
        train_up: &[&[f64]],
    ) -> Result<Vec<Vec<f64>>> {
        let (cb, cn, cl) = self.shape;
        let nq = queries.len();
        let nt = train_lo.len();
        if nq == 0 || nt == 0 {
            return Ok(vec![vec![]; nq]);
        }
        let l = queries[0].len();
        if nq > cb || nt > cn || l > cl {
            bail!("workload ({nq},{nt},{l}) exceeds compiled shape ({cb},{cn},{cl})");
        }
        debug_assert!(train_lo.iter().all(|s| s.len() == l));
        debug_assert!(train_up.len() == nt);

        // Pack + pad to f32 into the reused buffers. Rows beyond the
        // workload retain their padding values from construction / the
        // previous call's reset below.
        self.buf_q[..cb * cl].fill(0.0);
        for (r, s) in queries.iter().enumerate() {
            for (i, &v) in s.iter().enumerate() {
                self.buf_q[r * cl + i] = v as f32;
            }
        }
        for r in 0..nt {
            for i in 0..l {
                self.buf_lo[r * cl + i] = train_lo[r][i] as f32;
                self.buf_up[r * cl + i] = train_up[r][i] as f32;
            }
            // Padding columns keep [-BIG, BIG] → contribute 0.
            for i in l..cl {
                self.buf_lo[r * cl + i] = -BIG;
                self.buf_up[r * cl + i] = BIG;
            }
        }
        for r in nt..cn {
            self.buf_lo[r * cl..(r + 1) * cl].fill(-BIG);
            self.buf_up[r * cl..(r + 1) * cl].fill(BIG);
        }

        let outs = self.exe.execute_f32(&[
            (&self.buf_q, &[cb, cl]),
            (&self.buf_lo, &[cn, cl]),
            (&self.buf_up, &[cn, cl]),
        ])?;
        let m = &outs[0];
        anyhow::ensure!(m.len() == cb * cn, "unexpected output size {}", m.len());
        Ok((0..nq)
            .map(|r| (0..nt).map(|c| m[r * cn + c] as f64).collect())
            .collect())
    }
}

impl LbBackend for BatchLb {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn supports(&self, batch: usize, rows: usize, len: usize) -> bool {
        let (cb, cn, cl) = self.shape;
        batch <= cb && rows <= cn && len <= cl
    }

    /// The XLA kernel is branch-free: cutoffs cannot shorten rows, so
    /// the engine should not pay to compute them.
    fn uses_cutoffs(&self) -> bool {
        false
    }

    /// One XLA execution for the whole batch. The kernel is branch-free,
    /// so `cutoffs` cannot shorten rows — they are accepted (trait
    /// contract) and ignored.
    fn compute_into(
        &mut self,
        queries: &[&[f64]],
        train: &[PreparedSeries],
        _cutoffs: &[f64],
        out: &mut BoundMatrix,
    ) -> Result<()> {
        let lo_refs: Vec<&[f64]> = train.iter().map(|t| t.lo.as_slice()).collect();
        let up_refs: Vec<&[f64]> = train.iter().map(|t| t.up.as_slice()).collect();
        let m = self.compute_matrix(queries, &lo_refs, &up_refs)?;
        out.reset(queries.len(), train.len());
        for (q, row) in m.iter().enumerate() {
            out.row_mut(q).copy_from_slice(row);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{keogh, PreparedSeries};
    use crate::data::rng::Rng;
    use crate::delta::Squared;
    use crate::runtime::default_artifacts_dir;

    /// Requires `make artifacts`; skips (with a note) when absent so
    /// `cargo test` works pre-AOT.
    #[test]
    fn matches_scalar_keogh_when_artifact_present() {
        let dir = default_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let rt = match XlaRuntime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e:#})");
                return;
            }
        };
        let w = 3usize;
        let l = 64usize;
        let mut rng = Rng::seeded(4242);
        let queries: Vec<Vec<f64>> = (0..4).map(|_| (0..l).map(|_| rng.normal()).collect()).collect();
        let train: Vec<PreparedSeries> = (0..6)
            .map(|_| PreparedSeries::prepare((0..l).map(|_| rng.normal()).collect(), w))
            .collect();

        let mut blb = BatchLb::load(&rt, &dir, queries.len(), train.len(), l).unwrap();
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let lo_refs: Vec<&[f64]> = train.iter().map(|t| t.lo.as_slice()).collect();
        let up_refs: Vec<&[f64]> = train.iter().map(|t| t.up.as_slice()).collect();
        let m = blb.compute_matrix(&q_refs, &lo_refs, &up_refs).unwrap();

        for (qi, q) in queries.iter().enumerate() {
            for (ti, t) in train.iter().enumerate() {
                let scalar = keogh::lb_keogh::<Squared>(q, t, f64::INFINITY);
                let batched = m[qi][ti];
                let tol = 1e-4 * scalar.max(1.0);
                assert!(
                    (scalar - batched).abs() < tol,
                    "q{qi} t{ti}: scalar {scalar} vs batched {batched}"
                );
            }
        }
    }
}
