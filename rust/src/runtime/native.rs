//! [`NativeBatchLb`] — the default pure-Rust batched `LB_KEOGH` backend.
//!
//! Scores a whole query batch against a whole training set with a
//! kernel whose full sums are **bit-identical** to the lane-protocol
//! scalar reference ([`crate::simd::scalar::keogh_sum`]) at every ISA
//! the runtime dispatcher ([`crate::simd`]) selects — the matrix is
//! byte-identical whether the host runs AVX2, NEON, SSE2 or forced
//! scalar. (Relative to the sequential per-query bridge
//! [`keogh::lb_keogh`] the sums differ only by fp reassociation.)
//! Three batch-level optimisations on top of the kernel:
//!
//! * **Flat SoA envelopes** — on first contact with a training set the
//!   backend packs its envelopes into an
//!   [`EnvelopeStore`](crate::bounds::store::EnvelopeStore): all `lo`
//!   rows contiguous, then all `up` rows, one 64-byte-aligned
//!   allocation. The inner kernel ([`keogh::lb_keogh_flat`], 4-lane
//!   unrolled) streams two sequential rows per pair instead of
//!   pointer-chasing per-candidate `Vec`s. The store is cached across
//!   calls (an index's training set is immutable).
//! * **Flat output** — results land in a caller-provided row-major
//!   [`BoundMatrix`]; the batch hot path performs no per-call
//!   `Vec<Vec<f64>>` allocation.
//! * **Early-abandon rows** — with a finite `cutoffs[q]` (the engine
//!   seeds it with the query's DTW distance to its first candidate), a
//!   row's accumulation stops as soon as it exceeds the cutoff. The
//!   partial sum is still a valid lower bound, so sorted search stays
//!   exact; candidates that would be pruned anyway never pay the full
//!   `O(ℓ)` scan.
//!
//! With [`NativeBatchLb::with_threads`] `> 1`, query rows are scored in
//! parallel on an [`Executor`] — rows are independent, so the bound
//! matrix is byte-identical at every thread count.

use anyhow::{ensure, Result};

use crate::bounds::store::{EnvelopeStore, ShardStore};
use crate::bounds::{keogh, PreparedSeries};
use crate::delta::Squared;
use crate::exec::Executor;

use super::backend::{BoundMatrix, LbBackend};

/// Queries per work-queue chunk when the row fill runs parallel: small
/// enough to balance uneven early-abandon costs, large enough to
/// amortize the queue pop.
const QUERY_CHUNK: usize = 2;

/// Raw base pointer into the flat output matrix, shared across workers.
/// Sound because the work queue hands every query row to exactly one
/// worker, and row windows `[q*nt, (q+1)*nt)` are disjoint.
struct RowsPtr(*mut f64);
unsafe impl Send for RowsPtr {}
unsafe impl Sync for RowsPtr {}

/// The pure-Rust batched `LB_KEOGH` backend (always available; no
/// artifacts, no external runtime).
#[derive(Debug, Clone, Default)]
pub struct NativeBatchLb {
    exec: Executor,
    store: EnvelopeStore,
    /// Identity of the training slice the store was built from:
    /// `(ptr, len, series_len, window, fingerprint)` — the fingerprint
    /// folds per-series envelope spot values so that a *different*
    /// training set reallocated at the same address (same shape) still
    /// misses the cache. O(n) to recheck per call, vs O(n·ℓ) to rebuild.
    store_key: Option<(usize, usize, usize, usize, u64)>,
}

/// Order-sensitive FNV-style fold over every series' first lower- and
/// last upper-envelope values (bit patterns, so NaN/−0.0 are exact).
fn train_fingerprint(train: &[PreparedSeries]) -> u64 {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for (t, s) in train.iter().enumerate() {
        let a = s.lo.first().map(|v| v.to_bits()).unwrap_or(0);
        let b = s.up.last().map(|v| v.to_bits()).unwrap_or(0);
        h = (h ^ a.wrapping_add(t as u64)).wrapping_mul(FNV_PRIME);
        h = (h ^ b).wrapping_mul(FNV_PRIME);
    }
    h
}

impl NativeBatchLb {
    /// Backend with serial row fill.
    pub fn new() -> NativeBatchLb {
        NativeBatchLb { exec: Executor::serial(), store: EnvelopeStore::new(), store_key: None }
    }

    /// Backend scoring query rows on `threads` workers (`0` = machine
    /// parallelism, `1` = serial). The matrix is identical at every
    /// thread count — rows are independent.
    pub fn with_threads(threads: usize) -> NativeBatchLb {
        NativeBatchLb { exec: Executor::new(threads), ..NativeBatchLb::new() }
    }

    /// Compatibility constructor from the cache-blocked era: the block
    /// knob is gone (the SoA store made candidate blocking moot — every
    /// pair streams two contiguous rows), so this is `new()`.
    #[deprecated(since = "0.5.0", note = "blocking is obsolete under the SoA store; use new()")]
    pub fn with_block(_block: usize) -> NativeBatchLb {
        NativeBatchLb::new()
    }

    /// The worker count the row fill uses.
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// Ensure the SoA envelope store mirrors `train`, rebuilding on
    /// first contact or when the training slice changed.
    fn ensure_store(&mut self, train: &[PreparedSeries], l: usize) {
        let w = train.first().map(|t| t.w).unwrap_or(0);
        let key = (train.as_ptr() as usize, train.len(), l, w, train_fingerprint(train));
        if self.store_key != Some(key) {
            self.store.rebuild(train);
            self.store_key = Some(key);
        }
    }
}

impl LbBackend for NativeBatchLb {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, batch: usize, rows: usize, len: usize) -> bool {
        // No compiled shape: any non-degenerate workload fits.
        batch > 0 && rows > 0 && len > 0
    }

    fn compute_into(
        &mut self,
        queries: &[&[f64]],
        train: &[PreparedSeries],
        cutoffs: &[f64],
        out: &mut BoundMatrix,
    ) -> Result<()> {
        if queries.is_empty() || train.is_empty() {
            out.reset(queries.len(), 0);
            return Ok(());
        }
        let l = queries[0].len();
        ensure!(queries.iter().all(|q| q.len() == l), "queries must share one length");
        ensure!(
            train.iter().all(|t| t.len() == l),
            "training series must match the query length {l}"
        );
        ensure!(cutoffs.len() == queries.len(), "one cutoff per query");

        self.ensure_store(train, l);
        let store = &self.store;
        let nq = queries.len();
        let nt = train.len();
        out.reset(nq, nt);

        // Workers fill disjoint rows of the flat output through a raw
        // base pointer (row q = out[q*nt .. (q+1)*nt]); the work queue
        // hands every q to exactly one worker, so writes never overlap.
        let rows = RowsPtr(out.as_mut_slice().as_mut_ptr());
        let rows = &rows;

        self.exec.run(nq, QUERY_CHUNK, move |_wid, queue| {
            while let Some(range) = queue.next_chunk() {
                for q in range {
                    let query = queries[q];
                    let cut = cutoffs[q];
                    // Safety: q is claimed by this worker alone; the row
                    // window [q*nt, (q+1)*nt) is in-bounds (out was reset
                    // to nq*nt above) and disjoint from every other q's.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(rows.0.add(q * nt), nt)
                    };
                    for (t, slot) in row.iter_mut().enumerate() {
                        *slot = keogh::lb_keogh_flat::<Squared>(
                            query,
                            store.lo_row(t),
                            store.up_row(t),
                            cut,
                        );
                    }
                }
            }
        });
        Ok(())
    }

    fn supports_stores(&self) -> bool {
        true
    }

    fn compute_sharded_into(
        &mut self,
        queries: &[&[f64]],
        shards: &[ShardStore],
        cutoffs: &[f64],
        out: &mut BoundMatrix,
    ) -> Result<()> {
        let nt: usize = shards.last().map(|s| s.range().end).unwrap_or(0);
        if queries.is_empty() || nt == 0 {
            out.reset(queries.len(), 0);
            return Ok(());
        }
        let l = queries[0].len();
        ensure!(queries.iter().all(|q| q.len() == l), "queries must share one length");
        ensure!(cutoffs.len() == queries.len(), "one cutoff per query");
        let mut next = 0usize;
        for s in shards {
            ensure!(
                s.start() == next,
                "shards must be contiguous: shard starts at {}, expected {next}",
                s.start()
            );
            ensure!(
                s.is_empty() || s.store().series_len() == l,
                "shard series length {} must match the query length {l}",
                s.store().series_len()
            );
            next = s.range().end;
        }

        let nq = queries.len();
        out.reset(nq, nt);

        // Same disjoint-row scheme as `compute_into`; each worker walks
        // the shard list per row, filling the shard's own column block
        // straight off its flat store — the shards are never copied into
        // one concatenated allocation.
        let rows = RowsPtr(out.as_mut_slice().as_mut_ptr());
        let rows = &rows;

        self.exec.run(nq, QUERY_CHUNK, move |_wid, queue| {
            while let Some(range) = queue.next_chunk() {
                for q in range {
                    let query = queries[q];
                    let cut = cutoffs[q];
                    // Safety: q is claimed by this worker alone; the row
                    // window [q*nt, (q+1)*nt) is in-bounds (out was reset
                    // to nq*nt above) and disjoint from every other q's.
                    let row = unsafe {
                        std::slice::from_raw_parts_mut(rows.0.add(q * nt), nt)
                    };
                    for s in shards {
                        let store = s.store();
                        let block = &mut row[s.start()..s.range().end];
                        match s.clusters() {
                            // Cluster-pruned fill: one merged-envelope
                            // bound per cluster; clusters it proves past
                            // the cutoff never touch their members' rows.
                            // The cluster bound is ≤ every member's own
                            // LB_KEOGH (envelope containment), so writing
                            // it into the member columns keeps every
                            // column a valid lower bound — the sorted
                            // walk stays exact, the skipped members just
                            // sort pessimistically.
                            Some(cl) if cut.is_finite() => {
                                let env = cl.env();
                                for c in 0..cl.len() {
                                    let clb = keogh::lb_keogh_flat::<Squared>(
                                        query,
                                        env.lo_row(c),
                                        env.up_row(c),
                                        cut,
                                    );
                                    if clb > cut {
                                        for &m in cl.members_of(c) {
                                            block[m as usize] = clb;
                                        }
                                    } else {
                                        for &m in cl.members_of(c) {
                                            let t = m as usize;
                                            block[t] = keogh::lb_keogh_flat::<Squared>(
                                                query,
                                                store.lo_row(t),
                                                store.up_row(t),
                                                cut,
                                            );
                                        }
                                    }
                                }
                            }
                            // No clusters (or an infinite cutoff, where
                            // nothing can be pruned): plain contiguous
                            // fill off the flat store.
                            _ => {
                                for (t, slot) in block.iter_mut().enumerate() {
                                    *slot = keogh::lb_keogh_flat::<Squared>(
                                        query,
                                        store.lo_row(t),
                                        store.up_row(t),
                                        cut,
                                    );
                                }
                            }
                        }
                    }
                }
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn workload(
        nq: usize,
        nt: usize,
        l: usize,
        w: usize,
        seed: u64,
    ) -> (Vec<Vec<f64>>, Vec<PreparedSeries>) {
        let mut rng = Rng::seeded(seed);
        let queries: Vec<Vec<f64>> =
            (0..nq).map(|_| (0..l).map(|_| rng.normal()).collect()).collect();
        let train: Vec<PreparedSeries> = (0..nt)
            .map(|_| PreparedSeries::prepare((0..l).map(|_| rng.normal()).collect(), w))
            .collect();
        (queries, train)
    }

    #[test]
    fn matches_scalar_kernel_exactly() {
        let (queries, train) = workload(5, 37, 64, 3, 0xBEEF);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let cutoffs = vec![f64::INFINITY; queries.len()];
        let mut be = NativeBatchLb::new();
        let m = be.compute(&q_refs, &train, &cutoffs).unwrap();
        for (qi, q) in queries.iter().enumerate() {
            for (ti, t) in train.iter().enumerate() {
                // Bit-equal to the lane-protocol scalar reference (which
                // every SIMD vtable reproduces exactly); the sequential
                // bridge differs only by reassociation.
                let lane = crate::simd::scalar::keogh_sum::<Squared>(q, &t.lo, &t.up);
                assert_eq!(m[qi][ti], lane, "q{qi} t{ti}");
                let bridge = keogh::lb_keogh::<Squared>(q, t, f64::INFINITY);
                assert!(
                    (m[qi][ti] - bridge).abs() <= 1e-9 * (1.0 + bridge.abs()),
                    "q{qi} t{ti}: {} vs bridge {bridge}",
                    m[qi][ti]
                );
            }
        }
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let (queries, train) = workload(9, 41, 96, 4, 0x7EAD);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        // Mixed finite/infinite cutoffs exercise the abandon path too.
        let cutoffs: Vec<f64> =
            (0..queries.len()).map(|i| if i % 2 == 0 { f64::INFINITY } else { 40.0 }).collect();
        let baseline = NativeBatchLb::new().compute(&q_refs, &train, &cutoffs).unwrap();
        for threads in [2usize, 3, 8] {
            let m = NativeBatchLb::with_threads(threads)
                .compute(&q_refs, &train, &cutoffs)
                .unwrap();
            assert_eq!(m, baseline, "threads={threads}");
        }
    }

    #[test]
    fn abandoned_entries_exceed_cutoff_but_not_full() {
        let (queries, train) = workload(3, 20, 80, 4, 0xFADE);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let inf = vec![f64::INFINITY; queries.len()];
        let mut be = NativeBatchLb::new();
        let full = be.compute(&q_refs, &train, &inf).unwrap();
        // Cut each query at half its median bound: plenty of abandons.
        let cutoffs: Vec<f64> = full
            .iter_rows()
            .map(|row| {
                let mut v = row.to_vec();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v[v.len() / 2] * 0.5
            })
            .collect();
        let part = be.compute(&q_refs, &train, &cutoffs).unwrap();
        for qi in 0..queries.len() {
            for ti in 0..train.len() {
                let (p, f) = (part[qi][ti], full[qi][ti]);
                assert!(p <= f + 1e-12, "partial {p} above full {f}");
                if p < f {
                    // Abandoned: must have crossed the cutoff first.
                    assert!(p > cutoffs[qi], "q{qi} t{ti}: {p} <= cutoff {}", cutoffs[qi]);
                }
            }
        }
    }

    #[test]
    fn store_rebuilds_when_training_set_changes() {
        let (queries, train_a) = workload(2, 6, 32, 2, 0xA);
        let (_, train_b) = workload(2, 6, 32, 2, 0xB);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let cutoffs = vec![f64::INFINITY; 2];
        let mut be = NativeBatchLb::new();
        let ma = be.compute(&q_refs, &train_a, &cutoffs).unwrap();
        let mb = be.compute(&q_refs, &train_b, &cutoffs).unwrap();
        // Fresh backends agree: the cached store tracked the switch.
        let ma2 = NativeBatchLb::new().compute(&q_refs, &train_a, &cutoffs).unwrap();
        let mb2 = NativeBatchLb::new().compute(&q_refs, &train_b, &cutoffs).unwrap();
        assert_eq!(ma, ma2);
        assert_eq!(mb, mb2);
        assert_ne!(ma, mb, "different training sets must differ");
    }

    #[test]
    fn rank_orders_bounds_ascending() {
        let (queries, train) = workload(2, 25, 32, 2, 0x04DE4);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let cutoffs = vec![f64::INFINITY; queries.len()];
        let mut be = NativeBatchLb::new();
        let r = be.rank(&q_refs, &train, &cutoffs).unwrap();
        for (row, order) in r.bounds.iter_rows().zip(r.order.iter()) {
            for pair in order.windows(2) {
                assert!(row[pair[0]] <= row[pair[1]]);
            }
        }
    }

    #[test]
    fn length_mismatch_is_an_error() {
        let (queries, mut train) = workload(2, 3, 16, 1, 0xE44);
        train.push(PreparedSeries::prepare(vec![0.0; 17], 1));
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let mut be = NativeBatchLb::new();
        assert!(be.compute(&q_refs, &train, &[f64::INFINITY; 2]).is_err());
    }

    #[test]
    fn sharded_matrix_is_bit_equal_to_monolithic() {
        use crate::bounds::store::partition_shards;
        let (queries, train) = workload(6, 23, 48, 3, 0x54A2);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        // Mixed cutoffs: the abandon path must agree too (same kernel,
        // same rows, same order — identical partial sums).
        let cutoffs: Vec<f64> =
            (0..queries.len()).map(|i| if i % 2 == 0 { f64::INFINITY } else { 30.0 }).collect();
        let mono = NativeBatchLb::new().compute(&q_refs, &train, &cutoffs).unwrap();
        for shards in [1usize, 2, 3, 7] {
            let parts = partition_shards(&train, shards);
            for threads in [1usize, 3] {
                let mut be = NativeBatchLb::with_threads(threads);
                assert!(be.supports_stores());
                let mut m = BoundMatrix::new();
                be.compute_sharded_into(&q_refs, &parts, &cutoffs, &mut m).unwrap();
                assert_eq!(m, mono, "shards={shards} threads={threads}");
                let mut r = super::super::Ranking::default();
                be.rank_sharded_into(&q_refs, &parts, &cutoffs, &mut r).unwrap();
                for (row, order) in r.bounds.iter_rows().zip(r.order.iter()) {
                    for pair in order.windows(2) {
                        assert!(row[pair[0]] <= row[pair[1]]);
                    }
                }
            }
        }
    }

    #[test]
    fn clustered_shards_fill_valid_pessimistic_bounds() {
        // Clustered sharded fill: every column stays a valid LB_KEOGH
        // lower bound — either the member's own bound (bit-equal to the
        // monolithic fill) or, for a pruned cluster, the cluster's
        // merged-envelope bound, which exceeds the cutoff and is ≤ the
        // member's full bound by envelope containment.
        let (queries, _) = workload(4, 0, 48, 3, 0xC10);
        let mut rng = Rng::seeded(0xC11);
        let raw: Vec<Vec<f64>> =
            (0..30).map(|_| (0..48).map(|_| rng.normal()).collect()).collect();
        let index = crate::index::DtwIndex::builder(raw)
            .window(3)
            .shards(3)
            .clusters(4)
            .build()
            .unwrap();
        assert!(index.has_clusters());
        let train = &index.train().series;
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let inf = vec![f64::INFINITY; queries.len()];
        let full = NativeBatchLb::new().compute(&q_refs, train, &inf).unwrap();
        // Finite cutoffs low enough to skip clusters.
        let cutoffs: Vec<f64> = full
            .iter_rows()
            .map(|row| {
                let mut v = row.to_vec();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v[v.len() / 4]
            })
            .collect();
        let mut baseline = BoundMatrix::new();
        NativeBatchLb::new()
            .compute_sharded_into(&q_refs, index.shards(), &cutoffs, &mut baseline)
            .unwrap();
        for qi in 0..queries.len() {
            for ti in 0..train.len() {
                let (p, f) = (baseline[qi][ti], full[qi][ti]);
                assert!(p <= f + 1e-12, "q{qi} t{ti}: partial {p} above full {f}");
                if p < f {
                    assert!(p > cutoffs[qi], "q{qi} t{ti}: {p} <= cutoff {}", cutoffs[qi]);
                }
            }
        }
        // Thread count must not change a single bit.
        for threads in [2usize, 3] {
            let mut m = BoundMatrix::new();
            NativeBatchLb::with_threads(threads)
                .compute_sharded_into(&q_refs, index.shards(), &cutoffs, &mut m)
                .unwrap();
            assert_eq!(m, baseline, "threads={threads}");
        }
        // Infinite cutoffs disable cluster skipping: bit-equal to the
        // monolithic full fill.
        let mut m = BoundMatrix::new();
        NativeBatchLb::new()
            .compute_sharded_into(&q_refs, index.shards(), &inf, &mut m)
            .unwrap();
        assert_eq!(m, full);
    }

    #[test]
    fn sharded_rejects_gapped_shards_and_bad_lengths() {
        use crate::bounds::store::{partition_shards, ShardStore};
        let (queries, train) = workload(2, 8, 16, 1, 0x9A1);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let cutoffs = vec![f64::INFINITY; 2];
        let mut be = NativeBatchLb::new();
        let mut m = BoundMatrix::new();
        // Gap: second shard pretends to start past the first's end.
        let parts = partition_shards(&train, 2);
        let gapped = vec![
            parts[0].clone(),
            ShardStore::new(parts[0].len() + 1, parts[1].store().clone()),
        ];
        assert!(be.compute_sharded_into(&q_refs, &gapped, &cutoffs, &mut m).is_err());
        // Length mismatch between shard rows and queries.
        let short: Vec<Vec<f64>> = queries.iter().map(|q| q[..q.len() - 1].to_vec()).collect();
        let short_refs: Vec<&[f64]> = short.iter().map(|v| v.as_slice()).collect();
        assert!(be.compute_sharded_into(&short_refs, &parts, &cutoffs, &mut m).is_err());
    }
}
