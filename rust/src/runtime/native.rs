//! [`NativeBatchLb`] — the default pure-Rust batched `LB_KEOGH` backend.
//!
//! Scores a whole query batch against a whole training set with the same
//! scalar kernel the per-query path uses ([`keogh::lb_keogh`]), so its
//! values are **bit-identical** to Algorithm 4's screening values. Two
//! batch-level optimisations on top of the kernel:
//!
//! * **Cache blocking over candidates** — candidates are processed in
//!   blocks of [`NativeBatchLb::block`]; within a block the sweep is
//!   query-major, so each candidate's envelope pair (`lo`/`up` — the only
//!   per-pair data the kernel touches) stays cache-resident across every
//!   query in the batch instead of being streamed `batch` times.
//! * **Early-abandon rows** — with a finite `cutoffs[q]` (the engine
//!   seeds it with the query's DTW distance to its first candidate), a
//!   row's accumulation stops as soon as it exceeds the cutoff. The
//!   partial sum is still a valid lower bound, so sorted search stays
//!   exact; candidates that would be pruned anyway never pay the full
//!   `O(ℓ)` scan.

use anyhow::{ensure, Result};

use crate::bounds::{keogh, PreparedSeries};
use crate::delta::Squared;

use super::backend::LbBackend;

/// Default candidates per cache block: a block's envelopes cost
/// `2 · ℓ · 8 · block` bytes, so 16 keeps even ℓ = 512 within 128 KiB —
/// L2-resident on any current core.
const DEFAULT_BLOCK: usize = 16;

/// The pure-Rust batched `LB_KEOGH` backend (always available; no
/// artifacts, no external runtime).
#[derive(Debug, Clone)]
pub struct NativeBatchLb {
    block: usize,
}

impl NativeBatchLb {
    /// Backend with the default block size.
    pub fn new() -> NativeBatchLb {
        NativeBatchLb { block: DEFAULT_BLOCK }
    }

    /// Backend with an explicit candidate block size (≥ 1) — a
    /// benchmarking knob.
    pub fn with_block(block: usize) -> NativeBatchLb {
        NativeBatchLb { block: block.max(1) }
    }

    /// The candidate block size.
    pub fn block(&self) -> usize {
        self.block
    }
}

impl Default for NativeBatchLb {
    fn default() -> Self {
        NativeBatchLb::new()
    }
}

impl LbBackend for NativeBatchLb {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports(&self, batch: usize, rows: usize, len: usize) -> bool {
        // No compiled shape: any non-degenerate workload fits.
        batch > 0 && rows > 0 && len > 0
    }

    fn compute(
        &mut self,
        queries: &[&[f64]],
        train: &[PreparedSeries],
        cutoffs: &[f64],
    ) -> Result<Vec<Vec<f64>>> {
        if queries.is_empty() || train.is_empty() {
            return Ok(vec![Vec::new(); queries.len()]);
        }
        let l = queries[0].len();
        ensure!(queries.iter().all(|q| q.len() == l), "queries must share one length");
        ensure!(
            train.iter().all(|t| t.len() == l),
            "training series must match the query length {l}"
        );
        ensure!(cutoffs.len() == queries.len(), "one cutoff per query");

        let mut out = vec![vec![0.0; train.len()]; queries.len()];
        for (bi, block) in train.chunks(self.block).enumerate() {
            let base = bi * self.block;
            for (qi, q) in queries.iter().enumerate() {
                let cut = cutoffs[qi];
                let row = &mut out[qi];
                for (j, t) in block.iter().enumerate() {
                    row[base + j] = keogh::lb_keogh::<Squared>(q, t, cut);
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    fn workload(
        nq: usize,
        nt: usize,
        l: usize,
        w: usize,
        seed: u64,
    ) -> (Vec<Vec<f64>>, Vec<PreparedSeries>) {
        let mut rng = Rng::seeded(seed);
        let queries: Vec<Vec<f64>> =
            (0..nq).map(|_| (0..l).map(|_| rng.normal()).collect()).collect();
        let train: Vec<PreparedSeries> = (0..nt)
            .map(|_| PreparedSeries::prepare((0..l).map(|_| rng.normal()).collect(), w))
            .collect();
        (queries, train)
    }

    #[test]
    fn matches_scalar_kernel_exactly() {
        let (queries, train) = workload(5, 37, 64, 3, 0xBEEF);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let cutoffs = vec![f64::INFINITY; queries.len()];
        let mut be = NativeBatchLb::with_block(4); // force several blocks
        let m = be.compute(&q_refs, &train, &cutoffs).unwrap();
        for (qi, q) in queries.iter().enumerate() {
            for (ti, t) in train.iter().enumerate() {
                let scalar = keogh::lb_keogh::<Squared>(q, t, f64::INFINITY);
                assert_eq!(m[qi][ti], scalar, "q{qi} t{ti}");
            }
        }
    }

    #[test]
    fn abandoned_entries_exceed_cutoff_but_not_full() {
        let (queries, train) = workload(3, 20, 80, 4, 0xFADE);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let inf = vec![f64::INFINITY; queries.len()];
        let mut be = NativeBatchLb::new();
        let full = be.compute(&q_refs, &train, &inf).unwrap();
        // Cut each query at half its median bound: plenty of abandons.
        let cutoffs: Vec<f64> = full
            .iter()
            .map(|row| {
                let mut v = row.clone();
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v[v.len() / 2] * 0.5
            })
            .collect();
        let part = be.compute(&q_refs, &train, &cutoffs).unwrap();
        for qi in 0..queries.len() {
            for ti in 0..train.len() {
                let (p, f) = (part[qi][ti], full[qi][ti]);
                assert!(p <= f + 1e-12, "partial {p} above full {f}");
                if p < f {
                    // Abandoned: must have crossed the cutoff first.
                    assert!(p > cutoffs[qi], "q{qi} t{ti}: {p} <= cutoff {}", cutoffs[qi]);
                }
            }
        }
    }

    #[test]
    fn block_size_does_not_change_results() {
        let (queries, train) = workload(4, 33, 48, 2, 0xB10C);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let cutoffs = vec![f64::INFINITY; queries.len()];
        let baseline = NativeBatchLb::with_block(1).compute(&q_refs, &train, &cutoffs).unwrap();
        for block in [2, 7, 16, 64] {
            let m = NativeBatchLb::with_block(block).compute(&q_refs, &train, &cutoffs).unwrap();
            assert_eq!(m, baseline, "block={block}");
        }
    }

    #[test]
    fn rank_orders_bounds_ascending() {
        let (queries, train) = workload(2, 25, 32, 2, 0x04DE4);
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let cutoffs = vec![f64::INFINITY; queries.len()];
        let mut be = NativeBatchLb::new();
        let r = be.rank(&q_refs, &train, &cutoffs).unwrap();
        for (row, order) in r.bounds.iter().zip(r.order.iter()) {
            for pair in order.windows(2) {
                assert!(row[pair[0]] <= row[pair[1]]);
            }
        }
    }

    #[test]
    fn length_mismatch_is_an_error() {
        let (queries, mut train) = workload(2, 3, 16, 1, 0xE44);
        train.push(PreparedSeries::prepare(vec![0.0; 17], 1));
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let mut be = NativeBatchLb::new();
        assert!(be.compute(&q_refs, &train, &[f64::INFINITY; 2]).is_err());
    }
}
