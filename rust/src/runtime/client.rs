//! PJRT client wrapper: compile-once / execute-many over HLO text.

use std::path::Path;

use anyhow::{Context, Result};

/// A PJRT client (CPU) plus helpers to load and run AOT artifacts.
pub struct XlaRuntime {
    client: xla::PjRtClient,
}

impl XlaRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        log::info!(
            "PJRT client up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(XlaRuntime { client })
    }

    /// Platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** file (the interchange format — serialized
    /// jax ≥ 0.5 protos are rejected by xla_extension 0.5.1) and compile
    /// it for this client.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedComputation> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(LoadedComputation { exe })
    }

    /// Compile an in-memory computation (used by tests and the
    /// builder-based fallback kernels).
    pub fn compile(&self, comp: &xla::XlaComputation) -> Result<LoadedComputation> {
        Ok(LoadedComputation { exe: self.client.compile(comp).context("compile")? })
    }
}

/// A compiled executable with convenience f32 I/O.
pub struct LoadedComputation {
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedComputation {
    /// Execute with f32 tensor inputs (`(data, dims)` pairs). Returns the
    /// flattened f32 outputs. Artifacts are lowered with
    /// `return_tuple=True`, so a 1-output program yields one vector.
    pub fn execute_f32(&self, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                lit.reshape(&dims_i64).context("reshape input literal")
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).context("execute")?;
        let out_lit = result[0][0].to_literal_sync().context("fetch output")?;
        // Outputs arrive as a tuple (return_tuple=True at lowering).
        let elements = out_lit.to_tuple().context("untuple output")?;
        let mut out = Vec::with_capacity(elements.len());
        for e in elements {
            out.push(e.to_vec::<f32>().context("output to f32 vec")?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build (p0 + p1) with the XlaBuilder — exercises compile/execute
    /// without needing artifacts on disk.
    #[test]
    fn builder_roundtrip() {
        // With the vendored stub (offline build) the client cannot come
        // up; skip rather than fail — the test is for real PJRT builds.
        let rt = match XlaRuntime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e:#})");
                return;
            }
        };
        let b = xla::XlaBuilder::new("add");
        let shape = xla::Shape::array::<f32>(vec![2, 2]);
        let p0 = b.parameter_s(0, &shape, "x").unwrap();
        let p1 = b.parameter_s(1, &shape, "y").unwrap();
        let sum = p0.add_(&p1).unwrap();
        let comp = b.tuple(&[sum]).unwrap().build().unwrap();
        let exe = rt.compile(&comp).unwrap();
        let out = exe
            .execute_f32(&[(&[1.0, 2.0, 3.0, 4.0], &[2, 2]), (&[10.0, 20.0, 30.0, 40.0], &[2, 2])])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], vec![11.0, 22.0, 33.0, 44.0]);
    }

    #[test]
    fn load_missing_artifact_fails_cleanly() {
        let rt = match XlaRuntime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e:#})");
                return;
            }
        };
        assert!(rt.load_hlo_text(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }
}
