//! Minimal command-line flag parsing (the offline build has no `clap`;
//! DESIGN.md §5). Supports `--key value`, `--key=value` and bare flags.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional argument (the subcommand).
    pub command: Option<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(key.to_string(), v);
                } else {
                    args.options.insert(key.to_string(), "true".to_string());
                }
            } else if args.command.is_none() {
                args.command = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the real process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Raw option lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Parsed numeric/typed option with default; panics with a clear
    /// message on unparsable input (CLI boundary).
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Boolean flag (present, `=true`, or `=1`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated list option.
    pub fn list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key)
            .map(|v| v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("tightness --scale small --repeats 3 --verbose");
        assert_eq!(a.command.as_deref(), Some("tightness"));
        assert_eq!(a.str_or("scale", "x"), "small");
        assert_eq!(a.parse_or::<usize>("repeats", 1), 3);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form_and_lists() {
        let a = parse("sweep --frac=0.01,0.1 --out=/tmp/x");
        assert_eq!(a.list("frac").unwrap(), vec!["0.01", "0.1"]);
        assert_eq!(a.str_or("out", ""), "/tmp/x");
    }

    #[test]
    fn positional_args() {
        let a = parse("serve 127.0.0.1:9000 --bound webb");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.positional, vec!["127.0.0.1:9000"]);
    }

    #[test]
    fn defaults() {
        let a = parse("info");
        assert_eq!(a.parse_or::<f64>("x", 2.5), 2.5);
        assert_eq!(a.str_or("y", "def"), "def");
        assert!(a.list("z").is_none());
    }
}
