//! Dynamic Time Warping — the measure the lower bounds screen for.
//!
//! Implements the paper's Equations (1)–(2): windowed DTW over two series
//! with a Sakoe–Chiba band of half-width `w` (an element `A_i` may only be
//! aligned with `B_j` when `|i-j| ≤ w`).
//!
//! Four entry points:
//! * [`dtw`] — the plain measure, `O(ℓ·w)` time, `O(ℓ)` memory;
//! * [`dtw_ea`] — early-abandoning variant used inside nearest-neighbor
//!   search: returns `f64::INFINITY` as soon as every cell of a DP row
//!   exceeds the cutoff (the distance to the best candidate so far);
//! * [`dtw_ea_pruned`] — the PrunedDTW/UCR-suite kernel behind every
//!   search path: additionally *skips* DP cells whose prefix cost
//!   already proves any path through them exceeds the cutoff (the live
//!   column range shrinks from both sides per row), and accepts an
//!   optional cumulative-lower-bound tail array that tightens both the
//!   per-cell pruning threshold and the per-row abandon test. Finite
//!   results are bit-equal to [`dtw`]; `INFINITY` is returned exactly
//!   when the true distance exceeds the cutoff.
//! * [`cost_matrix`] / [`warping_path`] — full-matrix variants used by
//!   tests and the figure generators (e.g. the Figure 3/4 example).

use crate::delta::Delta;

/// Clamp a window to the valid range for series of lengths `la`, `lb`.
///
/// A window of `ℓ-1` (or larger) is unconstrained. For unequal lengths the
/// window must be at least `|la-lb|` for any warping path to exist; we
/// raise it to that minimum, matching common practice.
#[inline]
pub fn effective_window(la: usize, lb: usize, w: usize) -> usize {
    let max_len = la.max(lb);
    let min_w = la.abs_diff(lb);
    w.clamp(min_w, max_len.saturating_sub(1).max(min_w))
}

/// Windowed DTW distance `DTW_w(A, B)` (paper Eq. 2).
///
/// `w` is the Sakoe–Chiba half-window; `w ≥ ℓ-1` computes unconstrained
/// DTW. Works for unequal-length series (the window is raised to at least
/// the length difference so a path exists).
///
/// ```
/// use dtw_bounds::{delta::Squared, dtw::dtw};
/// let a = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
/// let b = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];
/// assert_eq!(dtw::<Squared>(&a, &b, 1), 53.0); // Figure 3 (caption's 52 is a typo)
/// ```
pub fn dtw<D: Delta>(a: &[f64], b: &[f64], w: usize) -> f64 {
    dtw_ea::<D>(a, b, w, f64::INFINITY)
}

/// Early-abandoning windowed DTW.
///
/// Identical to [`dtw`] but returns `f64::INFINITY` as soon as the minimum
/// over a completed DP row exceeds `cutoff` — at that point every warping
/// path must cost more than `cutoff`, so the caller (nearest-neighbor
/// search) can discard this candidate. Pass `f64::INFINITY` to disable.
pub fn dtw_ea<D: Delta>(a: &[f64], b: &[f64], w: usize, cutoff: f64) -> f64 {
    // Monomorphize on "is abandoning active": with an infinite cutoff
    // the row-min fold over row 0 and the per-cell `v < row_min` updates
    // are pure overhead (they can never trigger), so they are compiled
    // out entirely on the `dtw`/seed-DTW path.
    if cutoff.is_infinite() {
        dtw_ea_core::<D, false>(a, b, w, f64::INFINITY)
    } else {
        dtw_ea_core::<D, true>(a, b, w, cutoff)
    }
}

#[inline(always)]
fn dtw_ea_core<D: Delta, const EA: bool>(a: &[f64], b: &[f64], w: usize, cutoff: f64) -> f64 {
    let la = a.len();
    let lb = b.len();
    assert!(la > 0 && lb > 0, "dtw: empty series");
    let w = effective_window(la, lb, w);

    // Rolling rows over B with a left sentinel column: `row[j+1]` holds
    // cell (i, j), `row[band-left]` is INFINITY. The sentinel removes all
    // `j == 0` branches from the inner loop; `left` (the cell just
    // written) is carried in a register. The `diag`/`up` pair-min carries
    // no serial dependence, so it runs as a vectorised prepass on the
    // runtime-dispatched SIMD vtable ([`crate::simd`]), staged into the
    // row's own cells (every slot is overwritten by the serial sweep);
    // the sweep then pays one load, one δ and one min per cell. Cell
    // values are nonnegative-or-INFINITY with no NaNs and no -0.0, so
    // the select-form `min` is bit-identical to `f64::min` and results
    // are unchanged at every ISA. (§Perf O1 in EXPERIMENTS.md.)
    let kn = crate::simd::kernels();
    let mut prev = vec![f64::INFINITY; lb + 1];
    let mut curr = vec![f64::INFINITY; lb + 1];

    // Row 0: cumulative costs along the top band.
    let jhi0 = w.min(lb - 1);
    prev[1] = D::delta(a[0], b[0]);
    for j in 1..=jhi0 {
        prev[j + 1] = prev[j] + D::delta(a[0], b[j]);
    }
    if la == 1 {
        return prev[lb];
    }
    // Row-0 costs are nondecreasing (prefix sums of δ ≥ 0), so the row
    // minimum is the first cell — no O(w) fold needed even when active.
    if EA && prev[1] > cutoff {
        return f64::INFINITY;
    }

    for i in 1..la {
        let ai = a[i];
        let jlo = i.saturating_sub(w);
        let jhi = (i + w).min(lb - 1);
        // Sentinel to the left of the band.
        curr[jlo] = f64::INFINITY;
        let mut left = f64::INFINITY;
        let mut row_min = f64::INFINITY;
        {
            // prev[jlo..jhi+2] covers (diag, up) pairs for j in jlo..=jhi.
            // Vectorised prepass: crow[k] = min(diag, up) for every cell,
            // then the serial sweep folds in `left` and overwrites.
            let prow = &prev[jlo..jhi + 2];
            let crow = &mut curr[jlo + 1..jhi + 2];
            let brow = &b[jlo..=jhi];
            (kn.pair_min)(prow, crow);
            for (k, &bj) in brow.iter().enumerate() {
                let v = D::delta(ai, bj) + crate::simd::scalar::min_sel(crow[k], left);
                crow[k] = v;
                left = v;
                if EA && v < row_min {
                    row_min = v;
                }
            }
        }
        if EA && row_min > cutoff {
            return f64::INFINITY;
        }
        std::mem::swap(&mut prev, &mut curr);
        // Cell above the band's top edge may be read as `up` next row and
        // was not written this row (band top moves by at most one).
        if jhi + 2 <= lb {
            prev[jhi + 2] = f64::INFINITY;
        }
    }
    prev[lb]
}

/// Pruned early-abandoning windowed DTW — the kernel behind every search
/// path (PrunedDTW, Silva & Batista 2016; the UCR-suite `cb` trick,
/// Rakthanmanon et al. 2012; TC-DTW, arXiv:2101.07731).
///
/// Beyond [`dtw_ea`]'s row-min abandoning, this kernel *prunes* DP
/// cells: a cell whose prefix cost plus the remaining-rows lower bound
/// exceeds `cutoff` cannot lie on any path that beats `cutoff`, so it is
/// treated as `INFINITY` and the live column range shrinks from both
/// sides as the cutoff tightens. Rows whose live range empties abandon
/// immediately.
///
/// `tail`, when provided, must have length `a.len() + 1` with `tail[i]`
/// a lower bound on the total cost contributed by rows `i..` of any
/// warping path and `tail[a.len()] == 0`, such that each per-row
/// increment `tail[i] - tail[i+1]` never exceeds `δ(a[i], b[j])` for any
/// in-window `j` — exactly what
/// [`crate::bounds::keogh::lb_keogh_tail`] produces from the candidate's
/// envelopes. The tail tightens every pruning threshold from `cutoff`
/// to `cutoff - tail[i+1]`.
///
/// ## Contract (pinned by `rust/tests/pruned_dtw.rs`)
///
/// * A finite result is **bit-equal** to [`dtw`] (every surviving cell
///   computes the identical value: a pruned neighbor can never win a
///   `min` that a surviving cell takes).
/// * `INFINITY` is returned **exactly** when `DTW_w(a, b) > cutoff` —
///   possibly in cases where [`dtw_ea`] still returned a (useless)
///   finite value above the cutoff.
pub fn dtw_ea_pruned<D: Delta>(
    a: &[f64],
    b: &[f64],
    w: usize,
    cutoff: f64,
    tail: Option<&[f64]>,
) -> f64 {
    let la = a.len();
    let lb = b.len();
    assert!(la > 0 && lb > 0, "dtw: empty series");
    if cutoff.is_infinite() {
        // Nothing can be pruned; take the branch-free kernel.
        return dtw_ea_core::<D, false>(a, b, w, f64::INFINITY);
    }
    if let Some(t) = tail {
        assert_eq!(t.len(), la + 1, "tail must have one entry per row plus a zero sentinel");
    }
    let tail_at = |i: usize| tail.map(|t| t[i]).unwrap_or(0.0);
    let w = effective_window(la, lb, w);

    // Same rolling-row + left-sentinel layout as `dtw_ea`, including the
    // vectorised `diag`/`up` pair-min prepass over the live range (every
    // prepass slot is overwritten below: survivors by `v`, pruned cells
    // by INFINITY, the early-break tail by the backfill loop).
    // Additionally tracked per row:
    //   sc — first live (unpruned) column of the previous row;
    //   ec — last  live column of the previous row.
    // Cells left of `max(jlo, sc)` cannot be reached (all three
    // predecessors pruned), and once the running `left` is pruned and
    // `j > ec` no later cell of the row can be reached either.
    let mut prev = vec![f64::INFINITY; lb + 1];
    let mut curr = vec![f64::INFINITY; lb + 1];

    // Row 0: nondecreasing prefix sums — prune at the first crossing.
    let thresh0 = cutoff - tail_at(1);
    let jhi0 = w.min(lb - 1);
    let mut ec = usize::MAX; // last live column of row 0 (MAX = none)
    let mut acc = D::delta(a[0], b[0]);
    let mut j = 0usize;
    while j <= jhi0 {
        if acc > thresh0 {
            break;
        }
        prev[j + 1] = acc;
        ec = j;
        j += 1;
        if j <= jhi0 {
            acc += D::delta(a[0], b[j]);
        }
    }
    if ec == usize::MAX {
        // Cell (0,0) already exceeds the budget; every path crosses it.
        return f64::INFINITY;
    }
    if la == 1 {
        let v = prev[lb];
        return if v > cutoff { f64::INFINITY } else { v };
    }
    let kn = crate::simd::kernels();
    let mut sc = 0usize;

    for i in 1..la {
        let ai = a[i];
        let jlo = i.saturating_sub(w);
        let jhi = (i + w).min(lb - 1);
        let thresh = cutoff - tail_at(i + 1);
        let js = jlo.max(sc);
        // Cells in [jlo, js) are unreachable this row; mark them pruned
        // so the next row's diag/up reads see INFINITY (cheap: the range
        // is only ever as wide as the pruning that produced it).
        for cell in curr[jlo..js + 1].iter_mut() {
            *cell = f64::INFINITY;
        }
        // Vectorised prepass over the live range: curr[j+1] temporarily
        // holds min(diag, up) for j in js..=jhi.
        (kn.pair_min)(&prev[js..jhi + 2], &mut curr[js + 1..jhi + 2]);
        let mut left = f64::INFINITY;
        let mut sc_next = usize::MAX;
        let mut ec_next = usize::MAX;
        let mut j = js;
        while j <= jhi {
            // Once past the previous row's live range with a pruned
            // `left`, no later cell of this row is reachable.
            if j > ec.saturating_add(1) && left.is_infinite() {
                break;
            }
            let v =
                D::delta(ai, b[j]) + crate::simd::scalar::min_sel(curr[j + 1], left);
            if v > thresh {
                curr[j + 1] = f64::INFINITY;
                left = f64::INFINITY;
            } else {
                curr[j + 1] = v;
                left = v;
                if sc_next == usize::MAX {
                    sc_next = j;
                }
                ec_next = j;
            }
            j += 1;
        }
        // Cells not visited (early break) must read as pruned next row.
        for cell in curr[j + 1..jhi + 2].iter_mut() {
            *cell = f64::INFINITY;
        }
        if sc_next == usize::MAX {
            // The whole row pruned: every path now exceeds the cutoff.
            return f64::INFINITY;
        }
        sc = sc_next;
        ec = ec_next;
        std::mem::swap(&mut prev, &mut curr);
        // Cell above the band's top edge may be read as `up` next row.
        if jhi + 2 <= lb {
            prev[jhi + 2] = f64::INFINITY;
        }
    }
    let v = prev[lb];
    // With pruning, a finite value above the cutoff may reflect a
    // detour around pruned cells rather than the true distance; the
    // true distance provably exceeds the cutoff in that case.
    if v > cutoff {
        f64::INFINITY
    } else {
        v
    }
}

/// Full banded cost matrix `D_w` (paper Figure 4). Cells outside the
/// window hold `f64::INFINITY`. Intended for tests, teaching and figure
/// generation — `O(ℓ²)` memory.
pub fn cost_matrix<D: Delta>(a: &[f64], b: &[f64], w: usize) -> Vec<Vec<f64>> {
    let la = a.len();
    let lb = b.len();
    assert!(la > 0 && lb > 0, "cost_matrix: empty series");
    let w = effective_window(la, lb, w);
    let mut m = vec![vec![f64::INFINITY; lb]; la];
    for i in 0..la {
        let jlo = i.saturating_sub(w);
        let jhi = (i + w).min(lb - 1);
        for j in jlo..=jhi {
            let d = D::delta(a[i], b[j]);
            let best = if i == 0 && j == 0 {
                0.0
            } else {
                let diag = if i > 0 && j > 0 { m[i - 1][j - 1] } else { f64::INFINITY };
                let left = if j > 0 { m[i][j - 1] } else { f64::INFINITY };
                let up = if i > 0 { m[i - 1][j] } else { f64::INFINITY };
                diag.min(left).min(up)
            };
            m[i][j] = d + best;
        }
    }
    m
}

/// Extract one minimal-cost warping path from a cost matrix produced by
/// [`cost_matrix`]. Returns 0-based `(i, j)` alignments from `(0,0)` to
/// `(ℓ_A-1, ℓ_B-1)`. Ties prefer the diagonal (standard convention).
pub fn warping_path(m: &[Vec<f64>]) -> Vec<(usize, usize)> {
    let la = m.len();
    let lb = m[0].len();
    let mut path = Vec::with_capacity(la + lb);
    let (mut i, mut j) = (la - 1, lb - 1);
    path.push((i, j));
    while i > 0 || j > 0 {
        let diag = if i > 0 && j > 0 { m[i - 1][j - 1] } else { f64::INFINITY };
        let up = if i > 0 { m[i - 1][j] } else { f64::INFINITY };
        let left = if j > 0 { m[i][j - 1] } else { f64::INFINITY };
        if diag <= up && diag <= left {
            i -= 1;
            j -= 1;
        } else if up <= left {
            i -= 1;
        } else {
            j -= 1;
        }
        path.push((i, j));
    }
    path.reverse();
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{Absolute, Squared};

    /// The paper's running example (Figures 3 and 4).
    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    #[test]
    fn figure3_dtw_is_53() {
        // The paper's Figure 3 caption reports 52, but the DP over the
        // stated recurrence (Eq. 2) gives 53; two independent
        // implementations agree (see EXPERIMENTS.md "Paper discrepancies").
        assert_eq!(dtw::<Squared>(&A, &B, 1), 53.0);
    }

    #[test]
    fn figure4_cost_matrix_corner() {
        let m = cost_matrix::<Squared>(&A, &B, 1);
        assert_eq!(m[10][10], 53.0);
        // Window: cell (0, 2) is outside w=1.
        assert!(m[0][2].is_infinite());
        assert_eq!(m[0][0], 4.0); // (-1-1)^2
    }

    #[test]
    fn identity_is_zero() {
        for w in [0, 1, 3, 10, 100] {
            assert_eq!(dtw::<Squared>(&A, &A, w), 0.0);
            assert_eq!(dtw::<Absolute>(&B, &B, w), 0.0);
        }
    }

    #[test]
    fn window_zero_is_lockstep() {
        // w = 0 forces the diagonal: sum of pointwise deltas.
        let expect: f64 = A.iter().zip(B.iter()).map(|(x, y)| (x - y) * (x - y)).sum();
        assert_eq!(dtw::<Squared>(&A, &B, 0), expect);
    }

    #[test]
    fn monotone_nonincreasing_in_window() {
        let mut last = f64::INFINITY;
        for w in 0..A.len() {
            let d = dtw::<Squared>(&A, &B, w);
            assert!(d <= last + 1e-12, "w={w}: {d} > {last}");
            last = d;
        }
    }

    #[test]
    fn symmetric() {
        for w in [0, 1, 2, 5, 10] {
            let ab = dtw::<Squared>(&A, &B, w);
            let ba = dtw::<Squared>(&B, &A, w);
            assert!((ab - ba).abs() < 1e-12);
        }
    }

    #[test]
    fn unequal_lengths() {
        let a = [0.0, 1.0, 2.0, 3.0];
        let b = [0.0, 1.0, 1.5, 2.0, 3.0];
        // Path exists even with w=0 thanks to the raised window.
        let d = dtw::<Absolute>(&a, &b, 0);
        assert!(d.is_finite());
        let d5 = dtw::<Absolute>(&a, &b, 5);
        assert!(d5 <= d + 1e-12);
    }

    #[test]
    fn early_abandon_triggers() {
        let full = dtw::<Squared>(&A, &B, 1);
        assert_eq!(dtw_ea::<Squared>(&A, &B, 1, full + 1.0), full);
        // Any cutoff below the true distance must abandon or still return
        // a value above the cutoff; our row-min rule guarantees INFINITY
        // for cutoffs below the smallest row minimum along the way.
        assert!(dtw_ea::<Squared>(&A, &B, 1, 0.5).is_infinite());
    }

    #[test]
    fn early_abandon_equals_full_when_not_triggered() {
        for w in [0, 1, 3] {
            let full = dtw::<Squared>(&A, &B, w);
            assert_eq!(dtw_ea::<Squared>(&A, &B, w, f64::INFINITY), full);
            assert_eq!(dtw_ea::<Squared>(&A, &B, w, full), full); // row_min > cutoff is strict
        }
    }

    #[test]
    fn pruned_matches_plain_dtw_or_abandons_correctly() {
        // Dense grid of cutoffs around the true distance: finite results
        // must be bit-equal to `dtw`, INFINITY only above the cutoff.
        for w in [0usize, 1, 2, 5, 10] {
            let full = dtw::<Squared>(&A, &B, w);
            for mult in [0.0, 0.25, 0.5, 0.9, 0.999, 1.0, 1.001, 1.5, 10.0] {
                let cutoff = full * mult;
                let got = dtw_ea_pruned::<Squared>(&A, &B, w, cutoff, None);
                if full > cutoff {
                    assert!(got.is_infinite(), "w={w} mult={mult}: {got}");
                } else {
                    assert_eq!(got, full, "w={w} mult={mult}");
                }
            }
            assert_eq!(dtw_ea_pruned::<Squared>(&A, &B, w, f64::INFINITY, None), full);
        }
    }

    #[test]
    fn pruned_with_keogh_tail_stays_exact() {
        use crate::bounds::{keogh, PreparedSeries};
        for w in [0usize, 1, 2, 5] {
            let t = PreparedSeries::prepare(B.to_vec(), w);
            let mut tail = Vec::new();
            let lb = keogh::lb_keogh_tail::<Squared>(&A, &t.lo, &t.up, &mut tail);
            let full = dtw::<Squared>(&A, &B, w);
            assert!(lb <= full + 1e-9, "tail[0] is a valid lower bound");
            for cutoff in [full * 0.5, full, full * 2.0] {
                let got = dtw_ea_pruned::<Squared>(&A, &B, w, cutoff, Some(&tail));
                if full > cutoff {
                    assert!(got.is_infinite(), "w={w} cutoff={cutoff}");
                } else {
                    assert_eq!(got, full, "w={w} cutoff={cutoff}");
                }
            }
        }
    }

    #[test]
    fn pruned_single_row_and_lockstep_edges() {
        let a = [1.5];
        let b = [0.5, 1.0, 2.0];
        let full = dtw::<Absolute>(&a, &b, 5);
        assert_eq!(dtw_ea_pruned::<Absolute>(&a, &b, 5, full + 1.0, None), full);
        assert!(dtw_ea_pruned::<Absolute>(&a, &b, 5, full * 0.5, None).is_infinite());
        // w = 0 forces the diagonal.
        let full0 = dtw::<Squared>(&A, &B, 0);
        assert_eq!(dtw_ea_pruned::<Squared>(&A, &B, 0, full0, None), full0);
        assert!(dtw_ea_pruned::<Squared>(&A, &B, 0, full0 * 0.99, None).is_infinite());
    }

    #[test]
    fn path_is_valid_and_costs_match() {
        for w in [1usize, 2, 10] {
            let m = cost_matrix::<Squared>(&A, &B, w);
            let p = warping_path(&m);
            assert_eq!(*p.first().unwrap(), (0, 0));
            assert_eq!(*p.last().unwrap(), (10, 10));
            // continuity/monotonicity + window
            for k in 1..p.len() {
                let (i0, j0) = p[k - 1];
                let (i1, j1) = p[k];
                assert!((i1 == i0 || i1 == i0 + 1) && (j1 == j0 || j1 == j0 + 1));
                assert!((i1, j1) != (i0, j0));
                assert!(i1.abs_diff(j1) <= w);
            }
            let cost: f64 = p.iter().map(|&(i, j)| (A[i] - B[j]).powi(2)).sum();
            assert!((cost - dtw::<Squared>(&A, &B, w)).abs() < 1e-9);
        }
    }

    #[test]
    fn matches_naive_quadratic_reference() {
        // Cross-check the rolling-array kernel against the O(l^2) matrix.
        let xs: Vec<f64> = (0..40).map(|i| ((i * 7919) % 23) as f64 * 0.25 - 2.0).collect();
        let ys: Vec<f64> = (0..40).map(|i| ((i * 104729) % 19) as f64 * 0.3 - 2.5).collect();
        for w in [0, 1, 2, 5, 13, 39] {
            let m = cost_matrix::<Squared>(&xs, &ys, w);
            assert!(
                (dtw::<Squared>(&xs, &ys, w) - m[39][39]).abs() < 1e-9,
                "w={w}"
            );
        }
    }

    #[test]
    fn effective_window_clamps() {
        assert_eq!(effective_window(10, 10, 100), 9);
        assert_eq!(effective_window(10, 10, 3), 3);
        assert_eq!(effective_window(4, 9, 0), 5);
        assert_eq!(effective_window(1, 1, 0), 0);
    }
}
