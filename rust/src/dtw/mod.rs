//! Dynamic Time Warping — the measure the lower bounds screen for.
//!
//! Implements the paper's Equations (1)–(2): windowed DTW over two series
//! with a Sakoe–Chiba band of half-width `w` (an element `A_i` may only be
//! aligned with `B_j` when `|i-j| ≤ w`).
//!
//! Three entry points:
//! * [`dtw`] — the plain measure, `O(ℓ·w)` time, `O(ℓ)` memory;
//! * [`dtw_ea`] — early-abandoning variant used inside nearest-neighbor
//!   search: returns `f64::INFINITY` as soon as every cell of a DP row
//!   exceeds the cutoff (the distance to the best candidate so far);
//! * [`cost_matrix`] / [`warping_path`] — full-matrix variants used by
//!   tests and the figure generators (e.g. the Figure 3/4 example).

use crate::delta::Delta;

/// Clamp a window to the valid range for series of lengths `la`, `lb`.
///
/// A window of `ℓ-1` (or larger) is unconstrained. For unequal lengths the
/// window must be at least `|la-lb|` for any warping path to exist; we
/// raise it to that minimum, matching common practice.
#[inline]
pub fn effective_window(la: usize, lb: usize, w: usize) -> usize {
    let max_len = la.max(lb);
    let min_w = la.abs_diff(lb);
    w.clamp(min_w, max_len.saturating_sub(1).max(min_w))
}

/// Windowed DTW distance `DTW_w(A, B)` (paper Eq. 2).
///
/// `w` is the Sakoe–Chiba half-window; `w ≥ ℓ-1` computes unconstrained
/// DTW. Works for unequal-length series (the window is raised to at least
/// the length difference so a path exists).
///
/// ```
/// use dtw_bounds::{delta::Squared, dtw::dtw};
/// let a = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
/// let b = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];
/// assert_eq!(dtw::<Squared>(&a, &b, 1), 53.0); // Figure 3 (caption's 52 is a typo)
/// ```
pub fn dtw<D: Delta>(a: &[f64], b: &[f64], w: usize) -> f64 {
    dtw_ea::<D>(a, b, w, f64::INFINITY)
}

/// Early-abandoning windowed DTW.
///
/// Identical to [`dtw`] but returns `f64::INFINITY` as soon as the minimum
/// over a completed DP row exceeds `cutoff` — at that point every warping
/// path must cost more than `cutoff`, so the caller (nearest-neighbor
/// search) can discard this candidate. Pass `f64::INFINITY` to disable.
pub fn dtw_ea<D: Delta>(a: &[f64], b: &[f64], w: usize, cutoff: f64) -> f64 {
    let la = a.len();
    let lb = b.len();
    assert!(la > 0 && lb > 0, "dtw: empty series");
    let w = effective_window(la, lb, w);

    // Rolling rows over B with a left sentinel column: `row[j+1]` holds
    // cell (i, j), `row[band-left]` is INFINITY. The sentinel removes all
    // `j == 0` branches from the inner loop; `left` (the cell just
    // written) is carried in a register, so each cell costs two loads
    // (`diag`, `up`), one δ and three mins. (§Perf O1 in EXPERIMENTS.md.)
    let mut prev = vec![f64::INFINITY; lb + 1];
    let mut curr = vec![f64::INFINITY; lb + 1];

    // Row 0: cumulative costs along the top band.
    let jhi0 = w.min(lb - 1);
    prev[1] = D::delta(a[0], b[0]);
    for j in 1..=jhi0 {
        prev[j + 1] = prev[j] + D::delta(a[0], b[j]);
    }
    if la == 1 {
        return prev[lb];
    }
    if prev[1..=jhi0 + 1].iter().cloned().fold(f64::INFINITY, f64::min) > cutoff {
        return f64::INFINITY;
    }

    for i in 1..la {
        let ai = a[i];
        let jlo = i.saturating_sub(w);
        let jhi = (i + w).min(lb - 1);
        // Sentinel to the left of the band.
        curr[jlo] = f64::INFINITY;
        let mut left = f64::INFINITY;
        let mut row_min = f64::INFINITY;
        {
            // prev[jlo..jhi+2] covers (diag, up) pairs for j in jlo..=jhi.
            let prow = &prev[jlo..jhi + 2];
            let crow = &mut curr[jlo + 1..jhi + 2];
            let brow = &b[jlo..=jhi];
            for (k, &bj) in brow.iter().enumerate() {
                let diag = prow[k];
                let up = prow[k + 1];
                let v = D::delta(ai, bj) + diag.min(up).min(left);
                crow[k] = v;
                left = v;
                if v < row_min {
                    row_min = v;
                }
            }
        }
        if row_min > cutoff {
            return f64::INFINITY;
        }
        std::mem::swap(&mut prev, &mut curr);
        // Cell above the band's top edge may be read as `up` next row and
        // was not written this row (band top moves by at most one).
        if jhi + 2 <= lb {
            prev[jhi + 2] = f64::INFINITY;
        }
    }
    prev[lb]
}

/// Full banded cost matrix `D_w` (paper Figure 4). Cells outside the
/// window hold `f64::INFINITY`. Intended for tests, teaching and figure
/// generation — `O(ℓ²)` memory.
pub fn cost_matrix<D: Delta>(a: &[f64], b: &[f64], w: usize) -> Vec<Vec<f64>> {
    let la = a.len();
    let lb = b.len();
    assert!(la > 0 && lb > 0, "cost_matrix: empty series");
    let w = effective_window(la, lb, w);
    let mut m = vec![vec![f64::INFINITY; lb]; la];
    for i in 0..la {
        let jlo = i.saturating_sub(w);
        let jhi = (i + w).min(lb - 1);
        for j in jlo..=jhi {
            let d = D::delta(a[i], b[j]);
            let best = if i == 0 && j == 0 {
                0.0
            } else {
                let diag = if i > 0 && j > 0 { m[i - 1][j - 1] } else { f64::INFINITY };
                let left = if j > 0 { m[i][j - 1] } else { f64::INFINITY };
                let up = if i > 0 { m[i - 1][j] } else { f64::INFINITY };
                diag.min(left).min(up)
            };
            m[i][j] = d + best;
        }
    }
    m
}

/// Extract one minimal-cost warping path from a cost matrix produced by
/// [`cost_matrix`]. Returns 0-based `(i, j)` alignments from `(0,0)` to
/// `(ℓ_A-1, ℓ_B-1)`. Ties prefer the diagonal (standard convention).
pub fn warping_path(m: &[Vec<f64>]) -> Vec<(usize, usize)> {
    let la = m.len();
    let lb = m[0].len();
    let mut path = Vec::with_capacity(la + lb);
    let (mut i, mut j) = (la - 1, lb - 1);
    path.push((i, j));
    while i > 0 || j > 0 {
        let diag = if i > 0 && j > 0 { m[i - 1][j - 1] } else { f64::INFINITY };
        let up = if i > 0 { m[i - 1][j] } else { f64::INFINITY };
        let left = if j > 0 { m[i][j - 1] } else { f64::INFINITY };
        if diag <= up && diag <= left {
            i -= 1;
            j -= 1;
        } else if up <= left {
            i -= 1;
        } else {
            j -= 1;
        }
        path.push((i, j));
    }
    path.reverse();
    path
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{Absolute, Squared};

    /// The paper's running example (Figures 3 and 4).
    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    #[test]
    fn figure3_dtw_is_53() {
        // The paper's Figure 3 caption reports 52, but the DP over the
        // stated recurrence (Eq. 2) gives 53; two independent
        // implementations agree (see EXPERIMENTS.md "Paper discrepancies").
        assert_eq!(dtw::<Squared>(&A, &B, 1), 53.0);
    }

    #[test]
    fn figure4_cost_matrix_corner() {
        let m = cost_matrix::<Squared>(&A, &B, 1);
        assert_eq!(m[10][10], 53.0);
        // Window: cell (0, 2) is outside w=1.
        assert!(m[0][2].is_infinite());
        assert_eq!(m[0][0], 4.0); // (-1-1)^2
    }

    #[test]
    fn identity_is_zero() {
        for w in [0, 1, 3, 10, 100] {
            assert_eq!(dtw::<Squared>(&A, &A, w), 0.0);
            assert_eq!(dtw::<Absolute>(&B, &B, w), 0.0);
        }
    }

    #[test]
    fn window_zero_is_lockstep() {
        // w = 0 forces the diagonal: sum of pointwise deltas.
        let expect: f64 = A.iter().zip(B.iter()).map(|(x, y)| (x - y) * (x - y)).sum();
        assert_eq!(dtw::<Squared>(&A, &B, 0), expect);
    }

    #[test]
    fn monotone_nonincreasing_in_window() {
        let mut last = f64::INFINITY;
        for w in 0..A.len() {
            let d = dtw::<Squared>(&A, &B, w);
            assert!(d <= last + 1e-12, "w={w}: {d} > {last}");
            last = d;
        }
    }

    #[test]
    fn symmetric() {
        for w in [0, 1, 2, 5, 10] {
            let ab = dtw::<Squared>(&A, &B, w);
            let ba = dtw::<Squared>(&B, &A, w);
            assert!((ab - ba).abs() < 1e-12);
        }
    }

    #[test]
    fn unequal_lengths() {
        let a = [0.0, 1.0, 2.0, 3.0];
        let b = [0.0, 1.0, 1.5, 2.0, 3.0];
        // Path exists even with w=0 thanks to the raised window.
        let d = dtw::<Absolute>(&a, &b, 0);
        assert!(d.is_finite());
        let d5 = dtw::<Absolute>(&a, &b, 5);
        assert!(d5 <= d + 1e-12);
    }

    #[test]
    fn early_abandon_triggers() {
        let full = dtw::<Squared>(&A, &B, 1);
        assert_eq!(dtw_ea::<Squared>(&A, &B, 1, full + 1.0), full);
        // Any cutoff below the true distance must abandon or still return
        // a value above the cutoff; our row-min rule guarantees INFINITY
        // for cutoffs below the smallest row minimum along the way.
        assert!(dtw_ea::<Squared>(&A, &B, 1, 0.5).is_infinite());
    }

    #[test]
    fn early_abandon_equals_full_when_not_triggered() {
        for w in [0, 1, 3] {
            let full = dtw::<Squared>(&A, &B, w);
            assert_eq!(dtw_ea::<Squared>(&A, &B, w, f64::INFINITY), full);
            assert_eq!(dtw_ea::<Squared>(&A, &B, w, full), full); // row_min > cutoff is strict
        }
    }

    #[test]
    fn path_is_valid_and_costs_match() {
        for w in [1usize, 2, 10] {
            let m = cost_matrix::<Squared>(&A, &B, w);
            let p = warping_path(&m);
            assert_eq!(*p.first().unwrap(), (0, 0));
            assert_eq!(*p.last().unwrap(), (10, 10));
            // continuity/monotonicity + window
            for k in 1..p.len() {
                let (i0, j0) = p[k - 1];
                let (i1, j1) = p[k];
                assert!((i1 == i0 || i1 == i0 + 1) && (j1 == j0 || j1 == j0 + 1));
                assert!((i1, j1) != (i0, j0));
                assert!(i1.abs_diff(j1) <= w);
            }
            let cost: f64 = p.iter().map(|&(i, j)| (A[i] - B[j]).powi(2)).sum();
            assert!((cost - dtw::<Squared>(&A, &B, w)).abs() < 1e-9);
        }
    }

    #[test]
    fn matches_naive_quadratic_reference() {
        // Cross-check the rolling-array kernel against the O(l^2) matrix.
        let xs: Vec<f64> = (0..40).map(|i| ((i * 7919) % 23) as f64 * 0.25 - 2.0).collect();
        let ys: Vec<f64> = (0..40).map(|i| ((i * 104729) % 19) as f64 * 0.3 - 2.5).collect();
        for w in [0, 1, 2, 5, 13, 39] {
            let m = cost_matrix::<Squared>(&xs, &ys, w);
            assert!(
                (dtw::<Squared>(&xs, &ys, w) - m[39][39]).abs() < 1e-9,
                "w={w}"
            );
        }
    }

    #[test]
    fn effective_window_clamps() {
        assert_eq!(effective_window(10, 10, 100), 9);
        assert_eq!(effective_window(10, 10, 3), 3);
        assert_eq!(effective_window(4, 9, 0), 5);
        assert_eq!(effective_window(1, 1, 0), 0);
    }
}
