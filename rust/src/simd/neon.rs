//! NEON kernel set for aarch64.
//!
//! Mirrors the SSE2 layout exactly: two 128-bit accumulators hold
//! lanes `[l0, l1]` and `[l2, l3]`, reduced as `(l0 + l2) +
//! (l1 + l3)` — the scalar protocol order. Every selection is built
//! from `vcltq_f64`/`vcgtq_f64` + `vbslq_f64`; ARM's native
//! `vminq_f64`/`vmaxq_f64` are deliberately avoided because their
//! IEEE minNum semantics diverge from x86 `minpd` (and from the
//! scalar `min_sel`) on signed zeros.
#![deny(unsafe_op_in_unsafe_fn)]

use core::arch::aarch64::*;

use super::scalar;
use super::{Isa, Kernels};
use crate::delta::{Absolute, Squared};

/// `if a < b { a } else { b }` per lane (minpd semantics).
///
/// # Safety
/// Requires NEON (guaranteed: this vtable is installed only after
/// `is_aarch64_feature_detected!("neon")`).
#[inline(always)]
unsafe fn vmin_sel(a: float64x2_t, b: float64x2_t) -> float64x2_t {
    // SAFETY: register-only NEON ops.
    unsafe { vbslq_f64(vcltq_f64(a, b), a, b) }
}

/// `if a > b { a } else { b }` per lane (maxpd semantics).
///
/// # Safety
/// Requires NEON.
#[inline(always)]
unsafe fn vmax_sel(a: float64x2_t, b: float64x2_t) -> float64x2_t {
    // SAFETY: register-only NEON ops.
    unsafe { vbslq_f64(vcgtq_f64(a, b), a, b) }
}

/// Two LB_Keogh difference lanes: `v - up` where `v > up`, `lo - v`
/// where `v < lo`, else `+0.0` — the select nesting reproduces the
/// scalar if/else-if (masks disjoint under the envelope invariant,
/// NaN lanes fall through to `0.0`).
///
/// # Safety
/// Requires NEON; `pa`, `pl`, `pu` readable for two `f64`s.
#[inline(always)]
unsafe fn diff2(pa: *const f64, pl: *const f64, pu: *const f64) -> float64x2_t {
    // SAFETY: caller guarantees both lanes are in bounds.
    unsafe {
        let v = vld1q_f64(pa);
        let l = vld1q_f64(pl);
        let u = vld1q_f64(pu);
        let inner = vbslq_f64(vcltq_f64(v, l), vsubq_f64(l, v), vdupq_n_f64(0.0));
        vbslq_f64(vcgtq_f64(v, u), vsubq_f64(v, u), inner)
    }
}

/// Two squared-delta LB_Keogh terms.
///
/// # Safety
/// As [`diff2`].
#[inline(always)]
unsafe fn term2_sq(pa: *const f64, pl: *const f64, pu: *const f64) -> float64x2_t {
    // SAFETY: as `diff2`.
    unsafe {
        let d = diff2(pa, pl, pu);
        vmulq_f64(d, d)
    }
}

/// Reduce `[l0+l2, l1+l3]` to the scalar-protocol total.
///
/// # Safety
/// Requires NEON.
#[inline(always)]
unsafe fn reduce(s: float64x2_t) -> f64 {
    // SAFETY: register-only lane extracts.
    unsafe { vgetq_lane_f64::<0>(s) + vgetq_lane_f64::<1>(s) }
}

macro_rules! keogh_neon {
    ($sum:ident, $sum_impl:ident, $ea:ident, $ea_impl:ident, $term2:ident, $d:ty) => {
        /// # Safety
        /// Requires NEON; slice lengths per the vtable contract.
        #[target_feature(enable = "neon")]
        unsafe fn $sum_impl(a: &[f64], lo: &[f64], up: &[f64]) -> f64 {
            debug_assert!(lo.len() >= a.len() && up.len() >= a.len());
            let n = a.len();
            let n4 = n - (n % 4);
            // SAFETY: body loads touch [i, i+4) with i+4 <= n4 <=
            // every slice length; tail reads i < n. acc01 = [l0, l1],
            // acc23 = [l2, l3]; reduction is (l0+l2) + (l1+l3).
            unsafe {
                let (pa, pl, pu) = (a.as_ptr(), lo.as_ptr(), up.as_ptr());
                let mut acc01 = vdupq_n_f64(0.0);
                let mut acc23 = vdupq_n_f64(0.0);
                let mut i = 0usize;
                while i < n4 {
                    acc01 = vaddq_f64(acc01, $term2(pa.add(i), pl.add(i), pu.add(i)));
                    acc23 = vaddq_f64(acc23, $term2(pa.add(i + 2), pl.add(i + 2), pu.add(i + 2)));
                    i += 4;
                }
                let mut total = reduce(vaddq_f64(acc01, acc23));
                while i < n {
                    total += scalar::term::<$d>(*pa.add(i), *pl.add(i), *pu.add(i));
                    i += 1;
                }
                total
            }
        }

        fn $sum(a: &[f64], lo: &[f64], up: &[f64]) -> f64 {
            // SAFETY: reachable only via the NEON vtable, installed
            // after runtime detection; lengths debug-asserted inside.
            unsafe { $sum_impl(a, lo, up) }
        }

        /// # Safety
        /// Requires NEON; slice lengths per the vtable contract.
        #[target_feature(enable = "neon")]
        unsafe fn $ea_impl(a: &[f64], lo: &[f64], up: &[f64], abandon_at: f64) -> f64 {
            debug_assert!(lo.len() >= a.len() && up.len() >= a.len());
            let n = a.len();
            let n4 = n - (n % 4);
            // SAFETY: bounds as in the sum variant; reduce-and-test
            // once per 4-element group, never in the tail.
            unsafe {
                let (pa, pl, pu) = (a.as_ptr(), lo.as_ptr(), up.as_ptr());
                let mut acc01 = vdupq_n_f64(0.0);
                let mut acc23 = vdupq_n_f64(0.0);
                let mut i = 0usize;
                while i < n4 {
                    acc01 = vaddq_f64(acc01, $term2(pa.add(i), pl.add(i), pu.add(i)));
                    acc23 = vaddq_f64(acc23, $term2(pa.add(i + 2), pl.add(i + 2), pu.add(i + 2)));
                    i += 4;
                    let t = reduce(vaddq_f64(acc01, acc23));
                    if t > abandon_at {
                        return t;
                    }
                }
                let mut total = reduce(vaddq_f64(acc01, acc23));
                while i < n {
                    total += scalar::term::<$d>(*pa.add(i), *pl.add(i), *pu.add(i));
                    i += 1;
                }
                total
            }
        }

        fn $ea(a: &[f64], lo: &[f64], up: &[f64], abandon_at: f64) -> f64 {
            // SAFETY: reachable only via the detected NEON vtable.
            unsafe { $ea_impl(a, lo, up, abandon_at) }
        }
    };
}

keogh_neon!(keogh_sq_sum_neon, keogh_sq_sum_neon_impl, keogh_sq_ea_neon, keogh_sq_ea_neon_impl, term2_sq, Squared);
keogh_neon!(keogh_abs_sum_neon, keogh_abs_sum_neon_impl, keogh_abs_ea_neon, keogh_abs_ea_neon_impl, diff2, Absolute);

/// # Safety
/// Requires NEON; length preconditions debug-asserted.
#[target_feature(enable = "neon")]
unsafe fn clamp_neon_impl(v: &[f64], lo: &[f64], up: &[f64], out: &mut [f64]) {
    debug_assert!(lo.len() >= v.len() && up.len() >= v.len() && out.len() >= v.len());
    let n = v.len();
    let n2 = n - (n % 2);
    // SAFETY: [i, i+2) with i+2 <= n2 <= every length; scalar tail.
    // `out` never aliases the inputs (&mut exclusivity).
    unsafe {
        let (pv, pl, pu) = (v.as_ptr(), lo.as_ptr(), up.as_ptr());
        let po = out.as_mut_ptr();
        let mut i = 0usize;
        while i < n2 {
            let x = vmax_sel(vld1q_f64(pv.add(i)), vld1q_f64(pl.add(i)));
            vst1q_f64(po.add(i), vmin_sel(x, vld1q_f64(pu.add(i))));
            i += 2;
        }
        while i < n {
            *po.add(i) = scalar::min_sel(scalar::max_sel(*pv.add(i), *pl.add(i)), *pu.add(i));
            i += 1;
        }
    }
}

fn clamp_neon(v: &[f64], lo: &[f64], up: &[f64], out: &mut [f64]) {
    // SAFETY: reachable only via the detected NEON vtable.
    unsafe { clamp_neon_impl(v, lo, up, out) }
}

/// # Safety
/// Requires NEON; `src.len() == out.len() + 1`.
#[target_feature(enable = "neon")]
unsafe fn pair_min_neon_impl(src: &[f64], out: &mut [f64]) {
    debug_assert_eq!(src.len(), out.len() + 1);
    let n = out.len();
    let n2 = n - (n % 2);
    // SAFETY: offset load reads src[k+1..k+3], k+3 <= n2+1 <= src.len().
    unsafe {
        let ps = src.as_ptr();
        let po = out.as_mut_ptr();
        let mut k = 0usize;
        while k < n2 {
            vst1q_f64(po.add(k), vmin_sel(vld1q_f64(ps.add(k)), vld1q_f64(ps.add(k + 1))));
            k += 2;
        }
        while k < n {
            *po.add(k) = scalar::min_sel(*ps.add(k), *ps.add(k + 1));
            k += 1;
        }
    }
}

fn pair_min_neon(src: &[f64], out: &mut [f64]) {
    // SAFETY: reachable only via the detected NEON vtable.
    unsafe { pair_min_neon_impl(src, out) }
}

/// # Safety
/// Requires NEON; `v.len() >= acc.len()`.
#[target_feature(enable = "neon")]
unsafe fn min_merge_neon_impl(acc: &mut [f64], v: &[f64]) {
    debug_assert!(v.len() >= acc.len());
    let n = acc.len();
    let n2 = n - (n % 2);
    // SAFETY: [i, i+2) with i+2 <= n2 <= both lengths; scalar tail.
    unsafe {
        let pa = acc.as_mut_ptr();
        let pv = v.as_ptr();
        let mut i = 0usize;
        while i < n2 {
            vst1q_f64(pa.add(i), vmin_sel(vld1q_f64(pa.add(i)), vld1q_f64(pv.add(i))));
            i += 2;
        }
        while i < n {
            *pa.add(i) = scalar::min_sel(*pa.add(i), *pv.add(i));
            i += 1;
        }
    }
}

fn min_merge_neon(acc: &mut [f64], v: &[f64]) {
    // SAFETY: reachable only via the detected NEON vtable.
    unsafe { min_merge_neon_impl(acc, v) }
}

/// # Safety
/// Requires NEON; `v.len() >= acc.len()`.
#[target_feature(enable = "neon")]
unsafe fn max_merge_neon_impl(acc: &mut [f64], v: &[f64]) {
    debug_assert!(v.len() >= acc.len());
    let n = acc.len();
    let n2 = n - (n % 2);
    // SAFETY: as `min_merge_neon_impl`.
    unsafe {
        let pa = acc.as_mut_ptr();
        let pv = v.as_ptr();
        let mut i = 0usize;
        while i < n2 {
            vst1q_f64(pa.add(i), vmax_sel(vld1q_f64(pa.add(i)), vld1q_f64(pv.add(i))));
            i += 2;
        }
        while i < n {
            *pa.add(i) = scalar::max_sel(*pa.add(i), *pv.add(i));
            i += 1;
        }
    }
}

fn max_merge_neon(acc: &mut [f64], v: &[f64]) {
    // SAFETY: reachable only via the detected NEON vtable.
    unsafe { max_merge_neon_impl(acc, v) }
}

pub(crate) static KERNELS: Kernels = Kernels {
    isa: Isa::Neon,
    keogh_sq_sum: keogh_sq_sum_neon,
    keogh_sq_ea: keogh_sq_ea_neon,
    keogh_abs_sum: keogh_abs_sum_neon,
    keogh_abs_ea: keogh_abs_ea_neon,
    clamp: clamp_neon,
    pair_min: pair_min_neon,
    min_merge: min_merge_neon,
    max_merge: max_merge_neon,
};
