//! Portable lane-protocol reference kernels.
//!
//! These are the semantics every vector path in this module is pinned
//! to, bit for bit (see the module docs for the protocol). They are
//! also the always-available fallback vtable, and the generic-`D`
//! entry points used when a caller's `Delta` has no monomorphised
//! vtable slot (`DeltaId::Other`).
#![deny(unsafe_op_in_unsafe_fn)]

use crate::delta::{Absolute, Delta, Squared};

/// Hardware select-min: `if a < b { a } else { b }`. Exactly what
/// x86 `minpd` computes — the *second* operand wins on ties (±0.0)
/// and NaN. Not `f64::min`, which is NaN-propagating-from-either-side
/// and sign-aware on zeros.
#[inline(always)]
pub fn min_sel(a: f64, b: f64) -> f64 {
    if a < b {
        a
    } else {
        b
    }
}

/// Hardware select-max: `if a > b { a } else { b }` (x86 `maxpd`).
#[inline(always)]
pub fn max_sel(a: f64, b: f64) -> f64 {
    if a > b {
        a
    } else {
        b
    }
}

/// One LB_Keogh term: the per-element envelope violation under `D`.
/// Out-of-range on either side contributes `D::delta` against the
/// violated envelope row; inside (or NaN) contributes exactly `0.0`.
#[inline(always)]
pub(crate) fn term<D: Delta>(v: f64, lo: f64, up: f64) -> f64 {
    if v > up {
        D::delta(v, up)
    } else if v < lo {
        D::delta(v, lo)
    } else {
        0.0
    }
}

/// Full LB_Keogh sum under the 4-lane protocol: lane `j` accumulates
/// indices `i ≡ j (mod 4)` over the body, lanes reduce as
/// `(l0 + l2) + (l1 + l3)`, then tail elements are added in index
/// order. Generic over `D`; the vtable entries below monomorphise it.
///
/// Requires `lo.len() >= a.len()` and `up.len() >= a.len()`.
pub fn keogh_sum<D: Delta>(a: &[f64], lo: &[f64], up: &[f64]) -> f64 {
    debug_assert!(lo.len() >= a.len() && up.len() >= a.len());
    let n = a.len();
    let n4 = n - (n % 4);
    let (mut l0, mut l1, mut l2, mut l3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i < n4 {
        l0 += term::<D>(a[i], lo[i], up[i]);
        l1 += term::<D>(a[i + 1], lo[i + 1], up[i + 1]);
        l2 += term::<D>(a[i + 2], lo[i + 2], up[i + 2]);
        l3 += term::<D>(a[i + 3], lo[i + 3], up[i + 3]);
        i += 4;
    }
    let mut total = (l0 + l2) + (l1 + l3);
    while i < n {
        total += term::<D>(a[i], lo[i], up[i]);
        i += 1;
    }
    total
}

/// Early-abandoning LB_Keogh under the 4-lane protocol: after each
/// 4-element group the lanes are reduced (same order as
/// [`keogh_sum`]) and the partial tested with strict
/// `total > abandon_at`; on abandonment the reduced partial — a valid
/// lower bound — is returned. The tail never tests. A non-abandoned
/// run returns bit-identically to [`keogh_sum`].
///
/// Requires `lo.len() >= a.len()` and `up.len() >= a.len()`.
pub fn keogh_ea<D: Delta>(a: &[f64], lo: &[f64], up: &[f64], abandon_at: f64) -> f64 {
    debug_assert!(lo.len() >= a.len() && up.len() >= a.len());
    let n = a.len();
    let n4 = n - (n % 4);
    let (mut l0, mut l1, mut l2, mut l3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i < n4 {
        l0 += term::<D>(a[i], lo[i], up[i]);
        l1 += term::<D>(a[i + 1], lo[i + 1], up[i + 1]);
        l2 += term::<D>(a[i + 2], lo[i + 2], up[i + 2]);
        l3 += term::<D>(a[i + 3], lo[i + 3], up[i + 3]);
        i += 4;
        let t = (l0 + l2) + (l1 + l3);
        if t > abandon_at {
            return t;
        }
    }
    let mut total = (l0 + l2) + (l1 + l3);
    while i < n {
        total += term::<D>(a[i], lo[i], up[i]);
        i += 1;
    }
    total
}

fn keogh_sq_sum(a: &[f64], lo: &[f64], up: &[f64]) -> f64 {
    keogh_sum::<Squared>(a, lo, up)
}

fn keogh_sq_ea(a: &[f64], lo: &[f64], up: &[f64], abandon_at: f64) -> f64 {
    keogh_ea::<Squared>(a, lo, up, abandon_at)
}

fn keogh_abs_sum(a: &[f64], lo: &[f64], up: &[f64]) -> f64 {
    keogh_sum::<Absolute>(a, lo, up)
}

fn keogh_abs_ea(a: &[f64], lo: &[f64], up: &[f64], abandon_at: f64) -> f64 {
    keogh_ea::<Absolute>(a, lo, up, abandon_at)
}

/// `out[i] = min_sel(max_sel(v[i], lo[i]), up[i])` — clamp `v` into
/// the envelope in select form (bit-identical to `maxpd` + `minpd`).
///
/// Requires `lo`, `up` and `out` at least `v.len()` long.
pub fn clamp_into(v: &[f64], lo: &[f64], up: &[f64], out: &mut [f64]) {
    debug_assert!(lo.len() >= v.len() && up.len() >= v.len() && out.len() >= v.len());
    for i in 0..v.len() {
        out[i] = min_sel(max_sel(v[i], lo[i]), up[i]);
    }
}

/// `out[k] = min_sel(src[k], src[k + 1])` — adjacent-pair minima, the
/// vectorisable half of the DTW row recurrence `min(diag, up)`.
///
/// Requires `src.len() == out.len() + 1`.
pub fn pair_min_into(src: &[f64], out: &mut [f64]) {
    debug_assert_eq!(src.len(), out.len() + 1);
    for k in 0..out.len() {
        out[k] = min_sel(src[k], src[k + 1]);
    }
}

/// `acc[i] = min_sel(acc[i], v[i])` (the incoming value wins ties —
/// `minpd(acc, v)` semantics).
///
/// Requires `v.len() >= acc.len()`.
pub fn min_merge_into(acc: &mut [f64], v: &[f64]) {
    debug_assert!(v.len() >= acc.len());
    for (a, &x) in acc.iter_mut().zip(v) {
        *a = min_sel(*a, x);
    }
}

/// `acc[i] = max_sel(acc[i], v[i])` (`maxpd(acc, v)` semantics).
///
/// Requires `v.len() >= acc.len()`.
pub fn max_merge_into(acc: &mut [f64], v: &[f64]) {
    debug_assert!(v.len() >= acc.len());
    for (a, &x) in acc.iter_mut().zip(v) {
        *a = max_sel(*a, x);
    }
}

/// The always-available scalar vtable: the reference every vector
/// path is differentially tested against.
pub(crate) static KERNELS: super::Kernels = super::Kernels {
    isa: super::Isa::Scalar,
    keogh_sq_sum,
    keogh_sq_ea,
    keogh_abs_sum,
    keogh_abs_ea,
    clamp: clamp_into,
    pair_min: pair_min_into,
    min_merge: min_merge_into,
    max_merge: max_merge_into,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_min_max_take_second_operand_on_ties() {
        // ±0.0 compare equal, so `<`/`>` are false and the second
        // operand must win — the property NEON's vminq would violate.
        assert_eq!(min_sel(0.0, -0.0).to_bits(), (-0.0f64).to_bits());
        assert_eq!(min_sel(-0.0, 0.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(max_sel(0.0, -0.0).to_bits(), (-0.0f64).to_bits());
        assert_eq!(min_sel(f64::NAN, 1.0), 1.0);
        assert_eq!(max_sel(f64::NAN, 1.0), 1.0);
    }

    #[test]
    fn ea_without_abandonment_matches_full_sum_bitwise() {
        let a: Vec<f64> = (0..13).map(|i| (i as f64 * 0.37).sin() * 3.0).collect();
        let lo: Vec<f64> = a.iter().map(|v| v - 0.25).collect();
        let up: Vec<f64> = a.iter().map(|v| v + 0.125).collect();
        let full = keogh_sum::<Squared>(&a, &lo, &up);
        let ea = keogh_ea::<Squared>(&a, &lo, &up, f64::INFINITY);
        assert_eq!(full.to_bits(), ea.to_bits());
    }

    #[test]
    fn abandoned_partial_is_a_lower_bound_of_the_full_sum() {
        let a: Vec<f64> = (0..32).map(|i| i as f64).collect();
        let lo = vec![0.0; 32];
        let up = vec![0.0; 32];
        let full = keogh_sum::<Squared>(&a, &lo, &up);
        let part = keogh_ea::<Squared>(&a, &lo, &up, 10.0);
        assert!(part > 10.0 && part <= full);
    }

    #[test]
    fn pair_min_and_clamp_agree_with_naive_loops() {
        let src = [3.0, 1.0, f64::INFINITY, 2.0, 2.0];
        let mut out = [0.0; 4];
        pair_min_into(&src, &mut out);
        assert_eq!(out, [1.0, 1.0, 2.0, 2.0]);
        let v = [-5.0, 0.5, 9.0];
        let lo = [0.0, 0.0, 0.0];
        let up = [1.0, 1.0, 1.0];
        let mut proj = [0.0; 3];
        clamp_into(&v, &lo, &up, &mut proj);
        assert_eq!(proj, [0.0, 0.5, 1.0]);
    }
}
