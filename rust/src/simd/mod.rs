//! Runtime-dispatched SIMD kernels for the screening hot path.
//!
//! Every kernel in this module comes in one scalar and (where the
//! target supports it) one or more vector flavours, collected behind
//! the [`Kernels`] vtable. The active vtable is selected **once per
//! process** (cached in a `OnceLock`) from runtime CPU feature
//! detection — `is_x86_feature_detected!("avx2")` on x86-64,
//! `is_aarch64_feature_detected!("neon")` on aarch64 — so a single
//! portable binary benefits without `-C target-cpu=native`. The
//! `DTW_FORCE_ISA=scalar|sse2|avx2|neon` environment variable
//! overrides the choice (for differential testing and benchmarking);
//! an unavailable or unrecognised value logs a warning and falls back
//! to native detection.
//!
//! # The bit-equality contract
//!
//! Scalar and vector paths must agree **bit for bit**, not just to
//! within rounding. Two rules make that possible:
//!
//! 1. **Reductions follow the 4-lane protocol.** A summing kernel
//!    keeps four fixed accumulators `l0..l3`, where lane `j` sums the
//!    terms at indices `i ≡ j (mod 4)` over the body `n4 = 4⌋n/4⌊`,
//!    and reduces them in the documented order `(l0 + l2) + (l1 + l3)`.
//!    Tail elements (`i >= n4`) are added to the reduced total one by
//!    one, in index order. Early-abandon variants reduce and test
//!    `total > abandon_at` once per 4-element group, returning the
//!    reduced total on abandonment, and never test inside the tail.
//!    AVX2 holds `[l0, l1, l2, l3]` in one 256-bit register and
//!    reduces low-half + high-half then lane0 + lane1; SSE2/NEON hold
//!    `[l0, l1]` and `[l2, l3]` in two 128-bit registers and reduce
//!    pairwise the same way — all three produce the scalar order
//!    exactly. Widening to 8 lanes requires restating the scalar
//!    reference to 8 accumulators in the same change.
//! 2. **Selections use hardware select semantics.** `min`/`max`/
//!    `clamp` are defined as `min_sel(a, b) = if a < b { a } else
//!    { b }` and `max_sel(a, b) = if a > b { a } else { b }` — exactly
//!    what `minpd`/`maxpd` compute (the second operand wins on ties,
//!    ±0.0, and NaN). NEON must build the same select from
//!    `vcltq_f64`/`vcgtq_f64` + `vbslq_f64`; ARM's native
//!    `vminq_f64`/`vmaxq_f64` follow IEEE `minNum` semantics and
//!    diverge on signed zeros, so they are banned here.
//!
//! Elementwise kernels (clamp / pairwise-min / envelope merge) have no
//! accumulator, so rule 2 alone pins them; only the LB_Keogh sums need
//! the lane protocol.
//!
//! # Unsafe boundary
//!
//! All `unsafe` SIMD code in the crate lives under `rust/src/simd/`,
//! compiled with `deny(unsafe_op_in_unsafe_fn)`. Kernels use unaligned
//! loads throughout — the 64-byte alignment of `EnvelopeStore` rows is
//! a performance property, never a safety precondition — so the only
//! preconditions are the slice-length relations stated on each kernel,
//! checked with `debug_assert!` at every entry point, plus the CPU
//! feature itself, which is guaranteed by construction: a vector
//! vtable is only reachable after the matching runtime detection
//! succeeded.
#![deny(unsafe_op_in_unsafe_fn)]

use std::sync::OnceLock;

pub mod scalar;

#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

/// An instruction-set architecture a kernel set can target.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Portable Rust; the reference the vector paths are pinned to.
    Scalar,
    /// 128-bit x86 vectors (part of the x86-64 baseline).
    Sse2,
    /// 256-bit x86 vectors (runtime-detected).
    Avx2,
    /// 128-bit aarch64 vectors.
    Neon,
}

impl Isa {
    /// All ISAs this build knows about (not necessarily available).
    pub const ALL: &'static [Isa] = &[Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Neon];

    /// Stable lowercase name, as accepted by `DTW_FORCE_ISA`.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Parse a `DTW_FORCE_ISA` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Isa> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Isa::Scalar),
            "sse2" => Some(Isa::Sse2),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

impl std::fmt::Display for Isa {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The kernel vtable: one function pointer per hot inner loop, all
/// obeying the bit-equality contract in the module docs.
///
/// Length preconditions (debug-asserted by every implementation):
/// the `keogh_*` kernels require `lo.len() >= a.len()` and
/// `up.len() >= a.len()`; `clamp` requires `lo`, `up` and `out` at
/// least `v.len()` long; `pair_min` requires
/// `src.len() == out.len() + 1`; the merges require `v.len() >=
/// acc.len()`. The Keogh kernels additionally assume the envelope
/// invariant `lo[i] <= up[i]` pointwise (guaranteed by
/// `envelopes_into` and by merged cluster envelopes) — with it the
/// `v > up` / `v < lo` branch masks are disjoint, which the vector
/// paths exploit.
pub struct Kernels {
    /// Which ISA this vtable's entries are compiled for.
    pub isa: Isa,
    /// Full LB_Keogh sum, squared delta, no abandon checks.
    pub keogh_sq_sum: fn(&[f64], &[f64], &[f64]) -> f64,
    /// Early-abandoning LB_Keogh, squared delta: tests the reduced
    /// total against `abandon_at` once per 4-element group.
    pub keogh_sq_ea: fn(&[f64], &[f64], &[f64], f64) -> f64,
    /// Full LB_Keogh sum, absolute delta.
    pub keogh_abs_sum: fn(&[f64], &[f64], &[f64]) -> f64,
    /// Early-abandoning LB_Keogh, absolute delta.
    pub keogh_abs_ea: fn(&[f64], &[f64], &[f64], f64) -> f64,
    /// `out[i] = min_sel(max_sel(v[i], lo[i]), up[i])` — the
    /// LB_Improved projection fill.
    pub clamp: fn(&[f64], &[f64], &[f64], &mut [f64]),
    /// `out[k] = min_sel(src[k], src[k + 1])` — the DTW per-row
    /// `min(diag, up)` prepass.
    pub pair_min: fn(&[f64], &mut [f64]),
    /// `acc[i] = min_sel(acc[i], v[i])` — merged-envelope lower rows.
    pub min_merge: fn(&mut [f64], &[f64]),
    /// `acc[i] = max_sel(acc[i], v[i])` — merged-envelope upper rows.
    pub max_merge: fn(&mut [f64], &[f64]),
}

/// The kernel set for `isa`, if this build targets it **and** the
/// running CPU supports it. `Scalar` always succeeds; on x86-64 so
/// does `Sse2` (baseline). Lets differential tests exercise every
/// available ISA in one process, independent of the cached global
/// selection.
pub fn for_isa(isa: Isa) -> Option<&'static Kernels> {
    match isa {
        Isa::Scalar => Some(&scalar::KERNELS),
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => Some(&x86::SSE2),
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => {
            if std::arch::is_x86_feature_detected!("avx2") {
                Some(&x86::AVX2)
            } else {
                None
            }
        }
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => {
            if std::arch::is_aarch64_feature_detected!("neon") {
                Some(&neon::KERNELS)
            } else {
                None
            }
        }
        #[allow(unreachable_patterns)]
        _ => None,
    }
}

/// Every ISA available on the running CPU, scalar first.
pub fn available() -> Vec<Isa> {
    Isa::ALL.iter().copied().filter(|&isa| for_isa(isa).is_some()).collect()
}

/// Best native kernel set for the running CPU.
fn best_available() -> &'static Kernels {
    #[cfg(target_arch = "x86_64")]
    {
        if let Some(k) = for_isa(Isa::Avx2) {
            return k;
        }
        if let Some(k) = for_isa(Isa::Sse2) {
            return k;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if let Some(k) = for_isa(Isa::Neon) {
            return k;
        }
    }
    &scalar::KERNELS
}

fn select() -> &'static Kernels {
    if let Ok(forced) = std::env::var("DTW_FORCE_ISA") {
        match Isa::parse(&forced).and_then(for_isa) {
            Some(k) => return k,
            None => {
                log::warn!(
                    "DTW_FORCE_ISA={forced:?} is not recognised or not available on this CPU; \
                     falling back to native detection"
                );
            }
        }
    }
    best_available()
}

static ACTIVE: OnceLock<&'static Kernels> = OnceLock::new();

/// The process-wide kernel vtable. Selected on first call (runtime
/// feature detection, `DTW_FORCE_ISA` override) and cached; every hot
/// path goes through this single indirection.
pub fn kernels() -> &'static Kernels {
    ACTIVE.get_or_init(select)
}

/// The ISA of the active kernel set.
pub fn active_isa() -> Isa {
    kernels().isa
}

/// Stable name of the active ISA, for `stats=`, `index inspect`,
/// `info`, and bench-report metadata.
pub fn isa_name() -> &'static str {
    active_isa().name()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_available() {
        assert!(for_isa(Isa::Scalar).is_some());
        assert_eq!(for_isa(Isa::Scalar).unwrap().isa, Isa::Scalar);
        assert!(available().contains(&Isa::Scalar));
    }

    #[test]
    fn isa_names_round_trip() {
        for &isa in Isa::ALL {
            assert_eq!(Isa::parse(isa.name()), Some(isa));
            assert_eq!(Isa::parse(&isa.name().to_ascii_uppercase()), Some(isa));
        }
        assert_eq!(Isa::parse("avx512"), None);
    }

    #[test]
    fn every_available_vtable_reports_its_own_isa() {
        for isa in available() {
            assert_eq!(for_isa(isa).unwrap().isa, isa);
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn sse2_is_baseline_on_x86_64() {
        assert!(for_isa(Isa::Sse2).is_some());
    }

    #[test]
    fn active_isa_is_available() {
        assert!(available().contains(&active_isa()));
        assert_eq!(isa_name(), active_isa().name());
    }
}
