//! SSE2 and AVX2 kernel sets for x86-64.
//!
//! SSE2 is part of the x86-64 baseline, so the [`SSE2`] vtable is
//! unconditionally available; [`AVX2`] is only handed out by
//! `for_isa`/`best_available` after `is_x86_feature_detected!("avx2")`
//! succeeds. Both widths implement the 4-lane protocol documented in
//! the module docs: AVX2 carries `[l0, l1, l2, l3]` in one 256-bit
//! accumulator, SSE2 carries `[l0, l1]` + `[l2, l3]` in two 128-bit
//! accumulators, and both reduce as `(l0 + l2) + (l1 + l3)` — exactly
//! the scalar order. Selections compile to `minpd`/`maxpd`, whose
//! semantics the scalar `min_sel`/`max_sel` restate.
//!
//! All loads and stores are unaligned (`loadu`/`storeu`); the SoA
//! envelope rows happen to be 64-byte aligned, which helps throughput
//! but is never relied on for soundness. The only safety
//! preconditions are the slice-length relations debug-asserted at
//! each entry.
#![deny(unsafe_op_in_unsafe_fn)]

use core::arch::x86_64::*;

use super::scalar;
use super::{Isa, Kernels};
use crate::delta::{Absolute, Squared};

/// Two LB_Keogh terms (squared delta) from unaligned loads.
///
/// # Safety
/// `pa`, `pl`, `pu` must each be readable for two `f64`s.
#[inline(always)]
unsafe fn term2_sq(pa: *const f64, pl: *const f64, pu: *const f64) -> __m128d {
    // SAFETY: caller guarantees both lanes are in bounds. The
    // `v > up` / `v < lo` masks are disjoint (envelope invariant
    // lo <= up), so OR-combining the masked differences reproduces
    // the scalar if/else-if exactly; NaN lanes fail both compares
    // and contribute +0.0, as in the scalar term.
    unsafe {
        let v = _mm_loadu_pd(pa);
        let l = _mm_loadu_pd(pl);
        let u = _mm_loadu_pd(pu);
        let du = _mm_and_pd(_mm_cmpgt_pd(v, u), _mm_sub_pd(v, u));
        let dl = _mm_and_pd(_mm_cmplt_pd(v, l), _mm_sub_pd(l, v));
        let d = _mm_or_pd(du, dl);
        _mm_mul_pd(d, d)
    }
}

/// Two LB_Keogh terms (absolute delta); see [`term2_sq`].
///
/// # Safety
/// `pa`, `pl`, `pu` must each be readable for two `f64`s.
#[inline(always)]
unsafe fn term2_abs(pa: *const f64, pl: *const f64, pu: *const f64) -> __m128d {
    // SAFETY: as `term2_sq`; the masked differences are already the
    // non-negative |v - bound| values, bit-equal to `Absolute::delta`.
    unsafe {
        let v = _mm_loadu_pd(pa);
        let l = _mm_loadu_pd(pl);
        let u = _mm_loadu_pd(pu);
        let du = _mm_and_pd(_mm_cmpgt_pd(v, u), _mm_sub_pd(v, u));
        let dl = _mm_and_pd(_mm_cmplt_pd(v, l), _mm_sub_pd(l, v));
        _mm_or_pd(du, dl)
    }
}

/// Four LB_Keogh terms (squared delta), 256-bit.
///
/// # Safety
/// Requires AVX2; `pa`, `pl`, `pu` readable for four `f64`s.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn term4_sq(pa: *const f64, pl: *const f64, pu: *const f64) -> __m256d {
    // SAFETY: caller guarantees four lanes in bounds and AVX2 present;
    // mask logic as in `term2_sq`, `_CMP_{GT,LT}_OQ` are the ordered
    // non-signalling compares matching scalar `>` / `<`.
    unsafe {
        let v = _mm256_loadu_pd(pa);
        let l = _mm256_loadu_pd(pl);
        let u = _mm256_loadu_pd(pu);
        let du = _mm256_and_pd(_mm256_cmp_pd::<_CMP_GT_OQ>(v, u), _mm256_sub_pd(v, u));
        let dl = _mm256_and_pd(_mm256_cmp_pd::<_CMP_LT_OQ>(v, l), _mm256_sub_pd(l, v));
        let d = _mm256_or_pd(du, dl);
        _mm256_mul_pd(d, d)
    }
}

/// Four LB_Keogh terms (absolute delta), 256-bit; see [`term4_sq`].
///
/// # Safety
/// Requires AVX2; `pa`, `pl`, `pu` readable for four `f64`s.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn term4_abs(pa: *const f64, pl: *const f64, pu: *const f64) -> __m256d {
    // SAFETY: as `term4_sq`.
    unsafe {
        let v = _mm256_loadu_pd(pa);
        let l = _mm256_loadu_pd(pl);
        let u = _mm256_loadu_pd(pu);
        let du = _mm256_and_pd(_mm256_cmp_pd::<_CMP_GT_OQ>(v, u), _mm256_sub_pd(v, u));
        let dl = _mm256_and_pd(_mm256_cmp_pd::<_CMP_LT_OQ>(v, l), _mm256_sub_pd(l, v));
        _mm256_or_pd(du, dl)
    }
}

/// Reduce `[l0+l2, l1+l3]` to the scalar-protocol total.
///
/// # Safety
/// SSE2 (baseline).
#[inline(always)]
unsafe fn reduce128(s: __m128d) -> f64 {
    // SAFETY: register-only ops.
    unsafe { _mm_cvtsd_f64(s) + _mm_cvtsd_f64(_mm_unpackhi_pd(s, s)) }
}

macro_rules! keogh_sse2 {
    ($sum:ident, $sum_impl:ident, $ea:ident, $ea_impl:ident, $term2:ident, $d:ty) => {
        /// # Safety
        /// Slice lengths per the vtable contract (debug-asserted).
        unsafe fn $sum_impl(a: &[f64], lo: &[f64], up: &[f64]) -> f64 {
            debug_assert!(lo.len() >= a.len() && up.len() >= a.len());
            let n = a.len();
            let n4 = n - (n % 4);
            // SAFETY: body loads touch [i, i+4) with i+4 <= n4 <=
            // a.len() <= lo.len(), up.len(); tail reads single
            // elements at i < n. acc01 holds lanes [l0, l1], acc23
            // holds [l2, l3]; the reduction is (l0+l2) + (l1+l3).
            unsafe {
                let (pa, pl, pu) = (a.as_ptr(), lo.as_ptr(), up.as_ptr());
                let mut acc01 = _mm_setzero_pd();
                let mut acc23 = _mm_setzero_pd();
                let mut i = 0usize;
                while i < n4 {
                    acc01 = _mm_add_pd(acc01, $term2(pa.add(i), pl.add(i), pu.add(i)));
                    acc23 = _mm_add_pd(acc23, $term2(pa.add(i + 2), pl.add(i + 2), pu.add(i + 2)));
                    i += 4;
                }
                let mut total = reduce128(_mm_add_pd(acc01, acc23));
                while i < n {
                    total += scalar::term::<$d>(*pa.add(i), *pl.add(i), *pu.add(i));
                    i += 1;
                }
                total
            }
        }

        fn $sum(a: &[f64], lo: &[f64], up: &[f64]) -> f64 {
            // SAFETY: SSE2 is unconditionally available on x86-64;
            // length preconditions are debug-asserted inside.
            unsafe { $sum_impl(a, lo, up) }
        }

        /// # Safety
        /// Slice lengths per the vtable contract (debug-asserted).
        unsafe fn $ea_impl(a: &[f64], lo: &[f64], up: &[f64], abandon_at: f64) -> f64 {
            debug_assert!(lo.len() >= a.len() && up.len() >= a.len());
            let n = a.len();
            let n4 = n - (n % 4);
            // SAFETY: bounds as in the sum variant. The partial is
            // reduced and tested once per 4-element group, exactly
            // like the scalar protocol; the tail never tests.
            unsafe {
                let (pa, pl, pu) = (a.as_ptr(), lo.as_ptr(), up.as_ptr());
                let mut acc01 = _mm_setzero_pd();
                let mut acc23 = _mm_setzero_pd();
                let mut i = 0usize;
                while i < n4 {
                    acc01 = _mm_add_pd(acc01, $term2(pa.add(i), pl.add(i), pu.add(i)));
                    acc23 = _mm_add_pd(acc23, $term2(pa.add(i + 2), pl.add(i + 2), pu.add(i + 2)));
                    i += 4;
                    let t = reduce128(_mm_add_pd(acc01, acc23));
                    if t > abandon_at {
                        return t;
                    }
                }
                let mut total = reduce128(_mm_add_pd(acc01, acc23));
                while i < n {
                    total += scalar::term::<$d>(*pa.add(i), *pl.add(i), *pu.add(i));
                    i += 1;
                }
                total
            }
        }

        fn $ea(a: &[f64], lo: &[f64], up: &[f64], abandon_at: f64) -> f64 {
            // SAFETY: SSE2 baseline; lengths debug-asserted inside.
            unsafe { $ea_impl(a, lo, up, abandon_at) }
        }
    };
}

keogh_sse2!(keogh_sq_sum_sse2, keogh_sq_sum_sse2_impl, keogh_sq_ea_sse2, keogh_sq_ea_sse2_impl, term2_sq, Squared);
keogh_sse2!(keogh_abs_sum_sse2, keogh_abs_sum_sse2_impl, keogh_abs_ea_sse2, keogh_abs_ea_sse2_impl, term2_abs, Absolute);

macro_rules! keogh_avx2 {
    ($sum:ident, $sum_impl:ident, $ea:ident, $ea_impl:ident, $term4:ident, $d:ty) => {
        /// # Safety
        /// Requires AVX2; slice lengths per the vtable contract.
        #[target_feature(enable = "avx2")]
        unsafe fn $sum_impl(a: &[f64], lo: &[f64], up: &[f64]) -> f64 {
            debug_assert!(lo.len() >= a.len() && up.len() >= a.len());
            let n = a.len();
            let n4 = n - (n % 4);
            // SAFETY: body loads touch [i, i+4) with i+4 <= n4 <=
            // every slice length; tail reads i < n. acc holds
            // [l0, l1, l2, l3]; low half + high half gives
            // [l0+l2, l1+l3], then lane0 + lane1 — the scalar order.
            unsafe {
                let (pa, pl, pu) = (a.as_ptr(), lo.as_ptr(), up.as_ptr());
                let mut acc = _mm256_setzero_pd();
                let mut i = 0usize;
                while i < n4 {
                    acc = _mm256_add_pd(acc, $term4(pa.add(i), pl.add(i), pu.add(i)));
                    i += 4;
                }
                let s = _mm_add_pd(_mm256_castpd256_pd128(acc), _mm256_extractf128_pd::<1>(acc));
                let mut total = reduce128(s);
                while i < n {
                    total += scalar::term::<$d>(*pa.add(i), *pl.add(i), *pu.add(i));
                    i += 1;
                }
                total
            }
        }

        fn $sum(a: &[f64], lo: &[f64], up: &[f64]) -> f64 {
            // SAFETY: this wrapper is only reachable through the AVX2
            // vtable, which `for_isa`/`best_available` install solely
            // after `is_x86_feature_detected!("avx2")` succeeded.
            unsafe { $sum_impl(a, lo, up) }
        }

        /// # Safety
        /// Requires AVX2; slice lengths per the vtable contract.
        #[target_feature(enable = "avx2")]
        unsafe fn $ea_impl(a: &[f64], lo: &[f64], up: &[f64], abandon_at: f64) -> f64 {
            debug_assert!(lo.len() >= a.len() && up.len() >= a.len());
            let n = a.len();
            let n4 = n - (n % 4);
            // SAFETY: bounds as in the sum variant; reduce-and-test
            // once per group, never in the tail (scalar protocol).
            unsafe {
                let (pa, pl, pu) = (a.as_ptr(), lo.as_ptr(), up.as_ptr());
                let mut acc = _mm256_setzero_pd();
                let mut i = 0usize;
                while i < n4 {
                    acc = _mm256_add_pd(acc, $term4(pa.add(i), pl.add(i), pu.add(i)));
                    i += 4;
                    let s =
                        _mm_add_pd(_mm256_castpd256_pd128(acc), _mm256_extractf128_pd::<1>(acc));
                    let t = reduce128(s);
                    if t > abandon_at {
                        return t;
                    }
                }
                let s = _mm_add_pd(_mm256_castpd256_pd128(acc), _mm256_extractf128_pd::<1>(acc));
                let mut total = reduce128(s);
                while i < n {
                    total += scalar::term::<$d>(*pa.add(i), *pl.add(i), *pu.add(i));
                    i += 1;
                }
                total
            }
        }

        fn $ea(a: &[f64], lo: &[f64], up: &[f64], abandon_at: f64) -> f64 {
            // SAFETY: reachable only via the detected AVX2 vtable.
            unsafe { $ea_impl(a, lo, up, abandon_at) }
        }
    };
}

keogh_avx2!(keogh_sq_sum_avx2, keogh_sq_sum_avx2_impl, keogh_sq_ea_avx2, keogh_sq_ea_avx2_impl, term4_sq, Squared);
keogh_avx2!(keogh_abs_sum_avx2, keogh_abs_sum_avx2_impl, keogh_abs_ea_avx2, keogh_abs_ea_avx2_impl, term4_abs, Absolute);

// ---- Elementwise kernels (no accumulator: select semantics alone pin
// ---- them; minpd/maxpd ARE min_sel/max_sel in hardware).

fn clamp_sse2(v: &[f64], lo: &[f64], up: &[f64], out: &mut [f64]) {
    debug_assert!(lo.len() >= v.len() && up.len() >= v.len() && out.len() >= v.len());
    let n = v.len();
    let n2 = n - (n % 2);
    // SAFETY: SSE2 baseline; vector ops touch [i, i+2) with i+2 <= n2
    // <= every slice length, tail single elements at i < n. `out`
    // never aliases the inputs (&mut exclusivity).
    unsafe {
        let (pv, pl, pu) = (v.as_ptr(), lo.as_ptr(), up.as_ptr());
        let po = out.as_mut_ptr();
        let mut i = 0usize;
        while i < n2 {
            let x = _mm_max_pd(_mm_loadu_pd(pv.add(i)), _mm_loadu_pd(pl.add(i)));
            _mm_storeu_pd(po.add(i), _mm_min_pd(x, _mm_loadu_pd(pu.add(i))));
            i += 2;
        }
        while i < n {
            *po.add(i) = scalar::min_sel(scalar::max_sel(*pv.add(i), *pl.add(i)), *pu.add(i));
            i += 1;
        }
    }
}

fn pair_min_sse2(src: &[f64], out: &mut [f64]) {
    debug_assert_eq!(src.len(), out.len() + 1);
    let n = out.len();
    let n2 = n - (n % 2);
    // SAFETY: SSE2 baseline; the offset load reads src[k+1..k+3] with
    // k+3 <= n2+1 <= src.len(); `out` never aliases `src`.
    unsafe {
        let ps = src.as_ptr();
        let po = out.as_mut_ptr();
        let mut k = 0usize;
        while k < n2 {
            let m = _mm_min_pd(_mm_loadu_pd(ps.add(k)), _mm_loadu_pd(ps.add(k + 1)));
            _mm_storeu_pd(po.add(k), m);
            k += 2;
        }
        while k < n {
            *po.add(k) = scalar::min_sel(*ps.add(k), *ps.add(k + 1));
            k += 1;
        }
    }
}

fn min_merge_sse2(acc: &mut [f64], v: &[f64]) {
    debug_assert!(v.len() >= acc.len());
    let n = acc.len();
    let n2 = n - (n % 2);
    // SAFETY: SSE2 baseline; [i, i+2) with i+2 <= n2 <= both lengths.
    unsafe {
        let pa = acc.as_mut_ptr();
        let pv = v.as_ptr();
        let mut i = 0usize;
        while i < n2 {
            _mm_storeu_pd(pa.add(i), _mm_min_pd(_mm_loadu_pd(pa.add(i)), _mm_loadu_pd(pv.add(i))));
            i += 2;
        }
        while i < n {
            *pa.add(i) = scalar::min_sel(*pa.add(i), *pv.add(i));
            i += 1;
        }
    }
}

fn max_merge_sse2(acc: &mut [f64], v: &[f64]) {
    debug_assert!(v.len() >= acc.len());
    let n = acc.len();
    let n2 = n - (n % 2);
    // SAFETY: as `min_merge_sse2`.
    unsafe {
        let pa = acc.as_mut_ptr();
        let pv = v.as_ptr();
        let mut i = 0usize;
        while i < n2 {
            _mm_storeu_pd(pa.add(i), _mm_max_pd(_mm_loadu_pd(pa.add(i)), _mm_loadu_pd(pv.add(i))));
            i += 2;
        }
        while i < n {
            *pa.add(i) = scalar::max_sel(*pa.add(i), *pv.add(i));
            i += 1;
        }
    }
}

/// # Safety
/// Requires AVX2; length preconditions debug-asserted.
#[target_feature(enable = "avx2")]
unsafe fn clamp_avx2_impl(v: &[f64], lo: &[f64], up: &[f64], out: &mut [f64]) {
    debug_assert!(lo.len() >= v.len() && up.len() >= v.len() && out.len() >= v.len());
    let n = v.len();
    let n4 = n - (n % 4);
    // SAFETY: [i, i+4) with i+4 <= n4 <= every length; scalar tail.
    unsafe {
        let (pv, pl, pu) = (v.as_ptr(), lo.as_ptr(), up.as_ptr());
        let po = out.as_mut_ptr();
        let mut i = 0usize;
        while i < n4 {
            let x = _mm256_max_pd(_mm256_loadu_pd(pv.add(i)), _mm256_loadu_pd(pl.add(i)));
            _mm256_storeu_pd(po.add(i), _mm256_min_pd(x, _mm256_loadu_pd(pu.add(i))));
            i += 4;
        }
        while i < n {
            *po.add(i) = scalar::min_sel(scalar::max_sel(*pv.add(i), *pl.add(i)), *pu.add(i));
            i += 1;
        }
    }
}

fn clamp_avx2(v: &[f64], lo: &[f64], up: &[f64], out: &mut [f64]) {
    // SAFETY: reachable only via the detected AVX2 vtable.
    unsafe { clamp_avx2_impl(v, lo, up, out) }
}

/// # Safety
/// Requires AVX2; `src.len() == out.len() + 1`.
#[target_feature(enable = "avx2")]
unsafe fn pair_min_avx2_impl(src: &[f64], out: &mut [f64]) {
    debug_assert_eq!(src.len(), out.len() + 1);
    let n = out.len();
    let n4 = n - (n % 4);
    // SAFETY: offset load reads src[k+1..k+5], k+5 <= n4+1 <= src.len().
    unsafe {
        let ps = src.as_ptr();
        let po = out.as_mut_ptr();
        let mut k = 0usize;
        while k < n4 {
            let m = _mm256_min_pd(_mm256_loadu_pd(ps.add(k)), _mm256_loadu_pd(ps.add(k + 1)));
            _mm256_storeu_pd(po.add(k), m);
            k += 4;
        }
        while k < n {
            *po.add(k) = scalar::min_sel(*ps.add(k), *ps.add(k + 1));
            k += 1;
        }
    }
}

fn pair_min_avx2(src: &[f64], out: &mut [f64]) {
    // SAFETY: reachable only via the detected AVX2 vtable.
    unsafe { pair_min_avx2_impl(src, out) }
}

/// # Safety
/// Requires AVX2; `v.len() >= acc.len()`.
#[target_feature(enable = "avx2")]
unsafe fn min_merge_avx2_impl(acc: &mut [f64], v: &[f64]) {
    debug_assert!(v.len() >= acc.len());
    let n = acc.len();
    let n4 = n - (n % 4);
    // SAFETY: [i, i+4) with i+4 <= n4 <= both lengths; scalar tail.
    unsafe {
        let pa = acc.as_mut_ptr();
        let pv = v.as_ptr();
        let mut i = 0usize;
        while i < n4 {
            _mm256_storeu_pd(
                pa.add(i),
                _mm256_min_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pv.add(i))),
            );
            i += 4;
        }
        while i < n {
            *pa.add(i) = scalar::min_sel(*pa.add(i), *pv.add(i));
            i += 1;
        }
    }
}

fn min_merge_avx2(acc: &mut [f64], v: &[f64]) {
    // SAFETY: reachable only via the detected AVX2 vtable.
    unsafe { min_merge_avx2_impl(acc, v) }
}

/// # Safety
/// Requires AVX2; `v.len() >= acc.len()`.
#[target_feature(enable = "avx2")]
unsafe fn max_merge_avx2_impl(acc: &mut [f64], v: &[f64]) {
    debug_assert!(v.len() >= acc.len());
    let n = acc.len();
    let n4 = n - (n % 4);
    // SAFETY: as `min_merge_avx2_impl`.
    unsafe {
        let pa = acc.as_mut_ptr();
        let pv = v.as_ptr();
        let mut i = 0usize;
        while i < n4 {
            _mm256_storeu_pd(
                pa.add(i),
                _mm256_max_pd(_mm256_loadu_pd(pa.add(i)), _mm256_loadu_pd(pv.add(i))),
            );
            i += 4;
        }
        while i < n {
            *pa.add(i) = scalar::max_sel(*pa.add(i), *pv.add(i));
            i += 1;
        }
    }
}

fn max_merge_avx2(acc: &mut [f64], v: &[f64]) {
    // SAFETY: reachable only via the detected AVX2 vtable.
    unsafe { max_merge_avx2_impl(acc, v) }
}

pub(crate) static SSE2: Kernels = Kernels {
    isa: Isa::Sse2,
    keogh_sq_sum: keogh_sq_sum_sse2,
    keogh_sq_ea: keogh_sq_ea_sse2,
    keogh_abs_sum: keogh_abs_sum_sse2,
    keogh_abs_ea: keogh_abs_ea_sse2,
    clamp: clamp_sse2,
    pair_min: pair_min_sse2,
    min_merge: min_merge_sse2,
    max_merge: max_merge_sse2,
};

pub(crate) static AVX2: Kernels = Kernels {
    isa: Isa::Avx2,
    keogh_sq_sum: keogh_sq_sum_avx2,
    keogh_sq_ea: keogh_sq_ea_avx2,
    keogh_abs_sum: keogh_abs_sum_avx2,
    keogh_abs_ea: keogh_abs_ea_avx2,
    clamp: clamp_avx2,
    pair_min: pair_min_avx2,
    min_merge: min_merge_avx2,
    max_merge: max_merge_avx2,
};

#[cfg(test)]
mod tests {
    use super::super::{for_isa, Isa};

    /// Deterministic value streams covering sign flips, subnormals,
    /// huge magnitudes, and exact ties.
    fn stream(seed: u64, n: usize) -> Vec<f64> {
        let mut s = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1;
        (0..n)
            .map(|i| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let u = ((s >> 33) as f64) / ((1u64 << 31) as f64) - 1.0;
                match i % 7 {
                    0 => u * 1e12,
                    1 => u * 1e-308, // subnormal territory
                    2 => 0.0,
                    3 => -0.0,
                    _ => u * 3.0,
                }
            })
            .collect()
    }

    fn envelopes(a: &[f64]) -> (Vec<f64>, Vec<f64>) {
        let lo: Vec<f64> = a.iter().map(|v| v - 0.5).collect();
        let up: Vec<f64> = a.iter().map(|v| v + 0.25).collect();
        (lo, up)
    }

    fn check_vtable(isa: Isa) {
        let Some(k) = for_isa(isa) else { return };
        let s = for_isa(Isa::Scalar).unwrap();
        for n in (0..=17).chain([63, 64, 65]) {
            let a = stream(n as u64 + 1, n);
            let (lo, up) = envelopes(&stream(n as u64 + 77, n));
            let cuts = [f64::INFINITY, 0.0, 1e-3, 1.0, 1e25];
            for &cut in &cuts {
                assert_eq!(
                    (k.keogh_sq_ea)(&a, &lo, &up, cut).to_bits(),
                    (s.keogh_sq_ea)(&a, &lo, &up, cut).to_bits(),
                    "{isa} keogh_sq_ea n={n} cut={cut}"
                );
                assert_eq!(
                    (k.keogh_abs_ea)(&a, &lo, &up, cut).to_bits(),
                    (s.keogh_abs_ea)(&a, &lo, &up, cut).to_bits(),
                    "{isa} keogh_abs_ea n={n} cut={cut}"
                );
            }
            assert_eq!(
                (k.keogh_sq_sum)(&a, &lo, &up).to_bits(),
                (s.keogh_sq_sum)(&a, &lo, &up).to_bits(),
                "{isa} keogh_sq_sum n={n}"
            );
            assert_eq!(
                (k.keogh_abs_sum)(&a, &lo, &up).to_bits(),
                (s.keogh_abs_sum)(&a, &lo, &up).to_bits(),
                "{isa} keogh_abs_sum n={n}"
            );
            let mut got = vec![0.0; n];
            let mut want = vec![0.0; n];
            (k.clamp)(&a, &lo, &up, &mut got);
            (s.clamp)(&a, &lo, &up, &mut want);
            assert_eq!(bits(&got), bits(&want), "{isa} clamp n={n}");
            if n > 0 {
                let src = stream(n as u64 + 5, n + 1);
                (k.pair_min)(&src, &mut got);
                (s.pair_min)(&src, &mut want);
                assert_eq!(bits(&got), bits(&want), "{isa} pair_min n={n}");
            }
            let v = stream(n as u64 + 9, n);
            let mut ka = a.clone();
            let mut sa = a.clone();
            (k.min_merge)(&mut ka, &v);
            (s.min_merge)(&mut sa, &v);
            assert_eq!(bits(&ka), bits(&sa), "{isa} min_merge n={n}");
            let mut ka = a.clone();
            let mut sa = a;
            (k.max_merge)(&mut ka, &v);
            (s.max_merge)(&mut sa, &v);
            assert_eq!(bits(&ka), bits(&sa), "{isa} max_merge n={n}");
        }
    }

    fn bits(v: &[f64]) -> Vec<u64> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn sse2_matches_scalar_bitwise() {
        check_vtable(Isa::Sse2);
    }

    #[test]
    fn avx2_matches_scalar_bitwise_when_available() {
        check_vtable(Isa::Avx2);
    }
}
