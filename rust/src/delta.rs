//! Pairwise cost functions `δ(a, b)` used by DTW and its lower bounds.
//!
//! The paper considers two common functions, `δ(a,b) = (a-b)²` and
//! `δ(a,b) = |a-b|`, and classifies bounds by the assumptions they place
//! on δ:
//!
//! * `LB_KEOGH` / `LB_IMPROVED` / `LB_ENHANCED` / `LB_WEBB*` only require
//!   that δ increases monotonically with `|a-b|`
//!   ([`Delta::MONOTONE_IN_ABS_DIFF`]).
//! * `LB_PETITJEAN` / `LB_WEBB` / `LB_WEBB_ENHANCED` additionally require
//!   the *triangle-adjustment* property (paper, Theorems 1 and 2):
//!   for all `x, y` with `a ≤ x ≤ y ≤ b` (or the mirrored ordering),
//!   `δ(a,b) ≥ δ(a,y) + δ(b,x) − δ(x,y)`
//!   ([`Delta::TRIANGLE_ADJUSTMENT`]). Both `|a-b|` and `(a-b)²` satisfy
//!   it; `|a-b|^p` for large `p` does not in general.
//!
//! δ is dispatched statically (a zero-sized type parameter) so the hot
//! loops monomorphize; [`DeltaKind`] provides dynamic selection at the CLI
//! boundary.

/// Identifies a δ for which the [`crate::simd`] vtable carries
/// monomorphised kernel entries. Kernel call sites match on
/// [`Delta::ID`] (a const, so the branch folds away) to pick the
/// vectorised entry; `Other` δs fall back to the generic scalar
/// lane-protocol reference, which obeys the same bit-equality
/// contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeltaId {
    /// `δ(a,b) = (a-b)²` — [`Squared`].
    Squared,
    /// `δ(a,b) = |a-b|` — [`Absolute`].
    Absolute,
    /// Any other δ: no vectorised kernel, generic scalar path.
    Other,
}

/// A pairwise cost function between two series elements.
///
/// Implementations are zero-sized marker types so that DTW and bound
/// kernels monomorphize with the δ computation inlined.
pub trait Delta: Copy + Send + Sync + 'static {
    /// Human-readable name, e.g. `"squared"`.
    const NAME: &'static str;

    /// Which SIMD vtable slot (if any) implements this δ; defaults to
    /// [`DeltaId::Other`] so external δ impls keep working unchanged.
    const ID: DeltaId = DeltaId::Other;

    /// δ increases monotonically with `|a-b|`. Required by every bound in
    /// this crate; all provided δ satisfy it.
    const MONOTONE_IN_ABS_DIFF: bool;

    /// The paper's Theorem 1/2 side condition:
    /// `∀ x,y: a ≤ x ≤ y ≤ b ∨ a ≥ x ≥ y ≥ b ⇒ δ(a,b) ≥ δ(a,y) + δ(b,x) − δ(x,y)`.
    ///
    /// `LB_PETITJEAN`, `LB_WEBB` and `LB_WEBB_ENHANCED` are only valid
    /// lower bounds when this holds.
    const TRIANGLE_ADJUSTMENT: bool;

    /// The cost of aligning elements `a` and `b`.
    fn delta(a: f64, b: f64) -> f64;
}

/// `δ(a,b) = (a-b)²` — the paper's experimental choice (§6: "We use
/// δ = (A_i − B_j)²").
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Squared;

impl Delta for Squared {
    const NAME: &'static str = "squared";
    const ID: DeltaId = DeltaId::Squared;
    const MONOTONE_IN_ABS_DIFF: bool = true;
    const TRIANGLE_ADJUSTMENT: bool = true;

    #[inline(always)]
    fn delta(a: f64, b: f64) -> f64 {
        let d = a - b;
        d * d
    }
}

/// `δ(a,b) = |a-b|` — the Manhattan / L1 element cost.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Absolute;

impl Delta for Absolute {
    const NAME: &'static str = "absolute";
    const ID: DeltaId = DeltaId::Absolute;
    const MONOTONE_IN_ABS_DIFF: bool = true;
    const TRIANGLE_ADJUSTMENT: bool = true;

    #[inline(always)]
    fn delta(a: f64, b: f64) -> f64 {
        (a - b).abs()
    }
}

/// `δ(a,b) = √|a-b|` — a monotone δ *without* the triangle-adjustment
/// property (concave powers `|d|^p`, `p < 1`, violate it; convex powers
/// satisfy it). It exercises the `LB_WEBB*` path (which stays a valid
/// bound for any δ monotone in `|a-b|`) and the validity flags;
/// `LB_WEBB`/`LB_PETITJEAN` are not sound for it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SqrtAbs;

impl Delta for SqrtAbs {
    const NAME: &'static str = "sqrt-abs";
    const MONOTONE_IN_ABS_DIFF: bool = true;
    const TRIANGLE_ADJUSTMENT: bool = false;

    #[inline(always)]
    fn delta(a: f64, b: f64) -> f64 {
        (a - b).abs().sqrt()
    }
}

/// Runtime-selectable δ for the CLI / config layer. Experiment drivers
/// match on this once at the top and call monomorphized kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeltaKind {
    /// [`Squared`]
    Squared,
    /// [`Absolute`]
    Absolute,
}

impl DeltaKind {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "squared" | "sq" | "l2" => Some(Self::Squared),
            "absolute" | "abs" | "l1" => Some(Self::Absolute),
            _ => None,
        }
    }

    /// Name of the selected δ.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Squared => Squared::NAME,
            Self::Absolute => Absolute::NAME,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squared_basics() {
        assert_eq!(Squared::delta(3.0, 1.0), 4.0);
        assert_eq!(Squared::delta(1.0, 3.0), 4.0);
        assert_eq!(Squared::delta(2.0, 2.0), 0.0);
    }

    #[test]
    fn absolute_basics() {
        assert_eq!(Absolute::delta(3.0, 1.0), 2.0);
        assert_eq!(Absolute::delta(1.0, 3.0), 2.0);
        assert_eq!(Absolute::delta(-1.0, 1.0), 2.0);
    }

    /// Exhaustively check the triangle-adjustment property on a grid for
    /// the two δ the paper uses, and find a violation for `Cubed`.
    fn triangle_holds<D: Delta>(a: f64, x: f64, y: f64, b: f64) -> bool {
        D::delta(a, b) + 1e-12 >= D::delta(a, y) + D::delta(b, x) - D::delta(x, y)
    }

    #[test]
    fn triangle_adjustment_grid() {
        let grid: Vec<f64> = (-8..=8).map(|v| v as f64 * 0.5).collect();
        let mut sqrt_violation = false;
        for &a in &grid {
            for &x in &grid {
                for &y in &grid {
                    for &b in &grid {
                        let ordered = (a <= x && x <= y && y <= b) || (a >= x && x >= y && y >= b);
                        if !ordered {
                            continue;
                        }
                        assert!(triangle_holds::<Squared>(a, x, y, b), "sq {a} {x} {y} {b}");
                        assert!(triangle_holds::<Absolute>(a, x, y, b), "abs {a} {x} {y} {b}");
                        if !triangle_holds::<SqrtAbs>(a, x, y, b) {
                            sqrt_violation = true;
                        }
                    }
                }
            }
        }
        assert!(sqrt_violation, "SqrtAbs unexpectedly satisfies the property on the grid");
    }

    #[test]
    fn delta_kind_parse() {
        assert_eq!(DeltaKind::parse("squared"), Some(DeltaKind::Squared));
        assert_eq!(DeltaKind::parse("L1"), Some(DeltaKind::Absolute));
        assert_eq!(DeltaKind::parse("nope"), None);
    }
}
