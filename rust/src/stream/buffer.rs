//! [`StreamBuffer`] — a fixed-capacity ring over the most recent samples
//! of an unbounded stream, with O(1) rolling first/second moments.
//!
//! The buffer is the memory of [`super::SubsequenceSearcher`]: it holds
//! exactly one window's worth of samples (the subsequence length) and can
//! materialize the current window in chronological order without ever
//! reallocating. The rolling mean/std accessors are incremental
//! (subtract-evicted / add-arrived) and therefore O(1) per sample; the
//! searcher's per-window z-normalization consumes them through
//! [`StreamBuffer::stable_moments`] (into
//! `data::znorm::znormalize_with_moments`) instead of paying an `O(m)`
//! moment rescan per surviving window. `stable_moments` guards the
//! O(1) identity: it falls back to an exact centered two-pass when
//! cancellation would eat the variance (large DC offsets) and
//! periodically refreshes the rolling sums to shed eviction drift, so
//! normalized values (and therefore reported distances) agree with
//! treating each window as a standalone series to ~1e-9 relative on
//! well-conditioned data — and stay *correct* (via the fallback) on
//! ill-conditioned data. The *search itself* is exact either way:
//! every cascade stage and every DTW call sees the same normalized
//! window.

/// Fixed-capacity ring buffer over the latest `capacity` stream samples,
/// with O(1) rolling mean/variance of the buffered window.
#[derive(Debug, Clone)]
pub struct StreamBuffer {
    cap: usize,
    /// Ring storage; chronological order is `buf[head..] ++ buf[..head]`
    /// once full, plain `buf[..]` before that.
    buf: Vec<f64>,
    /// Next slot to overwrite once the ring is full.
    head: usize,
    /// Total samples ever pushed.
    pushed: u64,
    /// Rolling sum over the buffered samples (incremental; see module docs).
    sum: f64,
    /// Rolling sum of squares over the buffered samples.
    sumsq: f64,
    /// `pushed` count at which [`StreamBuffer::stable_moments`] next
    /// refreshes the rolling sums from the ring (bounds eviction drift
    /// to one window's worth of updates).
    refresh_at: u64,
}

impl StreamBuffer {
    /// A buffer holding the latest `capacity` samples (`capacity ≥ 1`).
    pub fn new(capacity: usize) -> StreamBuffer {
        assert!(capacity > 0, "StreamBuffer capacity must be >= 1");
        StreamBuffer {
            cap: capacity,
            buf: Vec::with_capacity(capacity),
            head: 0,
            pushed: 0,
            sum: 0.0,
            sumsq: 0.0,
            refresh_at: 0,
        }
    }

    /// The window length this buffer holds.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Samples currently buffered (`min(pushed, capacity)`).
    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True before the first sample arrives.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// True once a full window is buffered.
    #[inline]
    pub fn is_full(&self) -> bool {
        self.buf.len() == self.cap
    }

    /// Total samples ever pushed.
    #[inline]
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Append the next stream sample, evicting the oldest once full.
    pub fn push(&mut self, v: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            let evicted = self.buf[self.head];
            self.sum -= evicted;
            self.sumsq -= evicted * evicted;
            self.buf[self.head] = v;
            self.head += 1;
            if self.head == self.cap {
                self.head = 0;
            }
        }
        self.sum += v;
        self.sumsq += v * v;
        self.pushed += 1;
    }

    /// Rolling mean of the buffered samples (O(1); drifts by ulps over
    /// very long streams — see module docs).
    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.sum / self.buf.len() as f64
    }

    /// Rolling population variance of the buffered samples (O(1),
    /// clamped at zero against rounding).
    pub fn variance(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        let n = self.buf.len() as f64;
        let m = self.sum / n;
        (self.sumsq / n - m * m).max(0.0)
    }

    /// Rolling standard deviation of the buffered samples.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// `(mean, variance)` of the buffered window, **numerically
    /// guarded** — the form the search path's z-normalization consumes.
    ///
    /// The O(1) `Σx²/n − mean²` identity cancels catastrophically when
    /// the window's DC offset dominates its spread (samples around 1e8
    /// with unit variance leave *no* correct bits), and the incremental
    /// evict/add updates drift over long streams. This accessor
    /// therefore (a) falls back to an exact centered two-pass when the
    /// identity's result carries too few of `Σx²`'s bits, and (b)
    /// refreshes the rolling sums from the ring once per window's worth
    /// of pushes — bounding drift to one window of updates. Amortized
    /// O(1) per sample for well-conditioned data; gracefully degrades
    /// to the (always-correct) rescan when the data is ill-conditioned.
    pub fn stable_moments(&mut self) -> (f64, f64) {
        let n = self.buf.len();
        if n == 0 {
            return (0.0, 0.0);
        }
        let nf = n as f64;
        if self.pushed < self.refresh_at {
            let mean = self.sum / nf;
            let var = (self.sumsq / nf - mean * mean).max(0.0);
            // Well-conditioned: the spread retains at least ~13 of
            // Σx²/n's significant decimal digits' worth of headroom.
            if self.sumsq == 0.0 || var * nf > 1e-4 * self.sumsq.abs() {
                return (mean, var);
            }
        }
        // Exact centered two-pass; refresh the rolling sums while the
        // ring is in hand (sheds accumulated eviction drift).
        let mut sum = 0.0;
        for &v in &self.buf {
            sum += v;
        }
        let mean = sum / nf;
        let mut centered = 0.0;
        let mut sumsq = 0.0;
        for &v in &self.buf {
            centered += (v - mean) * (v - mean);
            sumsq += v * v;
        }
        self.sum = sum;
        self.sumsq = sumsq;
        self.refresh_at = self.pushed + self.cap as u64;
        (mean, centered / nf)
    }

    /// Materialize the buffered samples in chronological (arrival) order
    /// into `out` (cleared first; no allocation once `out` has capacity).
    pub fn copy_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn fills_then_slides() {
        let mut b = StreamBuffer::new(3);
        assert!(b.is_empty());
        for v in [1.0, 2.0, 3.0] {
            b.push(v);
        }
        assert!(b.is_full());
        let mut w = Vec::new();
        b.copy_into(&mut w);
        assert_eq!(w, vec![1.0, 2.0, 3.0]);
        b.push(4.0);
        b.copy_into(&mut w);
        assert_eq!(w, vec![2.0, 3.0, 4.0]);
        b.push(5.0);
        b.push(6.0);
        b.push(7.0);
        b.copy_into(&mut w);
        assert_eq!(w, vec![5.0, 6.0, 7.0]);
        assert_eq!(b.pushed(), 7);
    }

    #[test]
    fn partial_window_order() {
        let mut b = StreamBuffer::new(4);
        b.push(9.0);
        b.push(8.0);
        let mut w = Vec::new();
        b.copy_into(&mut w);
        assert_eq!(w, vec![9.0, 8.0]);
        assert_eq!(b.len(), 2);
        assert!(!b.is_full());
    }

    #[test]
    fn rolling_moments_track_recomputed() {
        let mut rng = Rng::seeded(321);
        let mut b = StreamBuffer::new(32);
        let mut w = Vec::new();
        for i in 0..5_000 {
            b.push(rng.normal() * 3.0 + 1.0);
            if i % 97 == 0 {
                b.copy_into(&mut w);
                let n = w.len() as f64;
                let mean = w.iter().sum::<f64>() / n;
                let var = w.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
                assert!((b.mean() - mean).abs() < 1e-9, "mean drift at {i}");
                assert!((b.variance() - var).abs() < 1e-9, "variance drift at {i}");
            }
        }
    }

    #[test]
    fn stable_moments_survive_large_dc_offset_and_long_streams() {
        // Samples around 1e8 with unit variance: the naive Σx²/n − μ²
        // identity has no correct bits left; stable_moments must stay
        // within ~1e-6 of the exact centered two-pass anyway, over a
        // stream long enough to accumulate real eviction drift.
        let mut rng = Rng::seeded(777);
        let mut b = StreamBuffer::new(64);
        let mut w = Vec::new();
        for i in 0..50_000 {
            b.push(1e8 + rng.normal());
            if i >= 64 && i % 501 == 0 {
                let (mean, var) = b.stable_moments();
                b.copy_into(&mut w);
                let n = w.len() as f64;
                let true_mean = w.iter().sum::<f64>() / n;
                let true_var =
                    w.iter().map(|v| (v - true_mean) * (v - true_mean)).sum::<f64>() / n;
                assert!(
                    (mean - true_mean).abs() <= 1e-6 * true_mean.abs().max(1.0),
                    "mean at {i}: {mean} vs {true_mean}"
                );
                assert!(
                    (var - true_var).abs() <= 1e-6 * true_var.max(1.0),
                    "variance at {i}: {var} vs {true_var}"
                );
                assert!(var >= 0.0);
            }
        }
    }

    #[test]
    fn stable_moments_match_rolling_on_centered_data() {
        let mut rng = Rng::seeded(778);
        let mut b = StreamBuffer::new(32);
        for _ in 0..500 {
            b.push(rng.normal());
        }
        let (mean, var) = b.stable_moments();
        assert!((mean - b.mean()).abs() < 1e-9);
        assert!((var - b.variance()).abs() < 1e-9);
    }

    #[test]
    fn constant_window_has_zero_variance() {
        let mut b = StreamBuffer::new(8);
        for _ in 0..20 {
            b.push(2.5);
        }
        assert_eq!(b.mean(), 2.5);
        assert!(b.variance() < 1e-12);
    }
}
