//! [`StreamBuffer`] — a fixed-capacity ring over the most recent samples
//! of an unbounded stream, with O(1) rolling first/second moments.
//!
//! The buffer is the memory of [`super::SubsequenceSearcher`]: it holds
//! exactly one window's worth of samples (the subsequence length) and can
//! materialize the current window in chronological order without ever
//! reallocating. The rolling mean/std accessors are incremental
//! (subtract-evicted / add-arrived) and therefore O(1) per sample; they
//! exist for monitoring and cheap prefilters. **Search-path
//! z-normalization deliberately recomputes the moments from the
//! materialized window instead** (`data::znorm::znormalize`), because the
//! incremental sums drift by a few ulps over long streams and the
//! searcher's contract is bit-equality with a batch oracle over the same
//! window.

/// Fixed-capacity ring buffer over the latest `capacity` stream samples,
/// with O(1) rolling mean/variance of the buffered window.
#[derive(Debug, Clone)]
pub struct StreamBuffer {
    cap: usize,
    /// Ring storage; chronological order is `buf[head..] ++ buf[..head]`
    /// once full, plain `buf[..]` before that.
    buf: Vec<f64>,
    /// Next slot to overwrite once the ring is full.
    head: usize,
    /// Total samples ever pushed.
    pushed: u64,
    /// Rolling sum over the buffered samples (incremental; see module docs).
    sum: f64,
    /// Rolling sum of squares over the buffered samples.
    sumsq: f64,
}

impl StreamBuffer {
    /// A buffer holding the latest `capacity` samples (`capacity ≥ 1`).
    pub fn new(capacity: usize) -> StreamBuffer {
        assert!(capacity > 0, "StreamBuffer capacity must be >= 1");
        StreamBuffer {
            cap: capacity,
            buf: Vec::with_capacity(capacity),
            head: 0,
            pushed: 0,
            sum: 0.0,
            sumsq: 0.0,
        }
    }

    /// The window length this buffer holds.
    #[inline]
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Samples currently buffered (`min(pushed, capacity)`).
    #[inline]
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True before the first sample arrives.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// True once a full window is buffered.
    #[inline]
    pub fn is_full(&self) -> bool {
        self.buf.len() == self.cap
    }

    /// Total samples ever pushed.
    #[inline]
    pub fn pushed(&self) -> u64 {
        self.pushed
    }

    /// Append the next stream sample, evicting the oldest once full.
    pub fn push(&mut self, v: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(v);
        } else {
            let evicted = self.buf[self.head];
            self.sum -= evicted;
            self.sumsq -= evicted * evicted;
            self.buf[self.head] = v;
            self.head += 1;
            if self.head == self.cap {
                self.head = 0;
            }
        }
        self.sum += v;
        self.sumsq += v * v;
        self.pushed += 1;
    }

    /// Rolling mean of the buffered samples (O(1); drifts by ulps over
    /// very long streams — see module docs).
    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.sum / self.buf.len() as f64
    }

    /// Rolling population variance of the buffered samples (O(1),
    /// clamped at zero against rounding).
    pub fn variance(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        let n = self.buf.len() as f64;
        let m = self.sum / n;
        (self.sumsq / n - m * m).max(0.0)
    }

    /// Rolling standard deviation of the buffered samples.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Materialize the buffered samples in chronological (arrival) order
    /// into `out` (cleared first; no allocation once `out` has capacity).
    pub fn copy_into(&self, out: &mut Vec<f64>) {
        out.clear();
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;

    #[test]
    fn fills_then_slides() {
        let mut b = StreamBuffer::new(3);
        assert!(b.is_empty());
        for v in [1.0, 2.0, 3.0] {
            b.push(v);
        }
        assert!(b.is_full());
        let mut w = Vec::new();
        b.copy_into(&mut w);
        assert_eq!(w, vec![1.0, 2.0, 3.0]);
        b.push(4.0);
        b.copy_into(&mut w);
        assert_eq!(w, vec![2.0, 3.0, 4.0]);
        b.push(5.0);
        b.push(6.0);
        b.push(7.0);
        b.copy_into(&mut w);
        assert_eq!(w, vec![5.0, 6.0, 7.0]);
        assert_eq!(b.pushed(), 7);
    }

    #[test]
    fn partial_window_order() {
        let mut b = StreamBuffer::new(4);
        b.push(9.0);
        b.push(8.0);
        let mut w = Vec::new();
        b.copy_into(&mut w);
        assert_eq!(w, vec![9.0, 8.0]);
        assert_eq!(b.len(), 2);
        assert!(!b.is_full());
    }

    #[test]
    fn rolling_moments_track_recomputed() {
        let mut rng = Rng::seeded(321);
        let mut b = StreamBuffer::new(32);
        let mut w = Vec::new();
        for i in 0..5_000 {
            b.push(rng.normal() * 3.0 + 1.0);
            if i % 97 == 0 {
                b.copy_into(&mut w);
                let n = w.len() as f64;
                let mean = w.iter().sum::<f64>() / n;
                let var = w.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / n;
                assert!((b.mean() - mean).abs() < 1e-9, "mean drift at {i}");
                assert!((b.variance() - var).abs() < 1e-9, "variance drift at {i}");
            }
        }
    }

    #[test]
    fn constant_window_has_zero_variance() {
        let mut b = StreamBuffer::new(8);
        for _ in 0..20 {
            b.push(2.5);
        }
        assert_eq!(b.mean(), 2.5);
        assert!(b.variance() < 1e-12);
    }
}
