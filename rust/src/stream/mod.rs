//! Streaming subsequence search — sliding exact-DTW matching of an
//! indexed pattern library over an unbounded sample stream.
//!
//! This is the paper's motivating deployment (§1: gesture and sensor
//! matching) turned into a subsystem: the lower bounds exist so that
//! *most windows never touch DTW*. A [`SubsequenceSearcher`] slides a
//! fixed-length window (the indexed series length) over incoming
//! samples; each window on the hop grid is screened against every
//! indexed series by a **cascade** of bounds (default
//! `LB_KIM_FL → LB_KEOGH → LB_WEBB`, cheapest first — the §8 cascade
//! idea applied across the whole bound family), and only survivors run
//! early-abandoning DTW. Matching is exact in both modes:
//!
//! * **threshold** — report every window whose nearest indexed series is
//!   within DTW distance τ (the monitoring regime);
//! * **top-k** — keep the `k` best-matching windows of the whole stream
//!   (the ad-hoc "find the closest occurrences" regime).
//!
//! The pieces:
//!
//! * [`StreamBuffer`] — a ring over the latest window with O(1) rolling
//!   moments;
//! * [`SubsequenceSearcher`] — the sliding cascade searcher, built from
//!   any [`crate::index::DtwIndex`] via
//!   [`crate::index::DtwIndex::subsequence`]; per-window envelope
//!   preparation is lazy — it runs only when a cascade stage actually
//!   needs query-side envelopes (the incremental
//!   [`crate::bounds::envelope::StreamingEnvelope`] serves true
//!   sample-at-a-time consumers and is property-tested bit-equal to the
//!   batch routine the searcher uses);
//! * [`StreamStats`] / [`StageStats`] — per-stage prune counters,
//!   convertible to the crate-wide
//!   [`crate::search::nn::SearchStats`] currency;
//! * [`StreamReport`] — matches + statistics + busy time.
//!
//! ```
//! use dtw_bounds::delta::Squared;
//! use dtw_bounds::index::DtwIndex;
//! use dtw_bounds::stream::SubsequenceOptions;
//!
//! // Index one known pattern...
//! let pattern = vec![0.0, 1.0, 2.0, 3.0, 2.0, 1.0, 0.0, -1.0];
//! let index = DtwIndex::builder(vec![pattern.clone()]).window(1).build()?;
//!
//! // ...and stream noise with the pattern embedded at position 10.
//! let mut stream = vec![9.0; 10];
//! stream.extend_from_slice(&pattern);
//! stream.extend(std::iter::repeat(9.0).take(10));
//!
//! let mut searcher = index.subsequence(SubsequenceOptions::threshold(0.5))?;
//! let matches = searcher.scan::<Squared>(&stream);
//! assert_eq!(matches.len(), 1);
//! assert_eq!((matches[0].start, matches[0].distance), (10, 0.0));
//!
//! let report = searcher.finish();
//! assert_eq!(report.stats.windows, 21); // 28 samples, window 8, hop 1
//! assert!(report.stats.pruned() > 0, "the cascade did real screening");
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! The serving layer exposes the same search per request through the
//! line protocol's `stream=` extension (see `docs/protocol.md`), the CLI
//! through `dtw-bounds stream`, and `examples/streaming_monitor.rs`
//! drives the full monitoring scenario.

mod buffer;
mod search;

pub use buffer::StreamBuffer;
pub use search::{
    StageStats, StreamMatch, StreamReport, StreamStats, SubsequenceOptions,
    SubsequenceSearcher, DEFAULT_CASCADE,
};
