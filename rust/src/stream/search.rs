//! [`SubsequenceSearcher`] — cascaded-bound subsequence search over a
//! sample stream, plus its option/result/statistics types.
//!
//! The per-window screening sums and the pruned exact-DTW kernel run on
//! the runtime-dispatched SIMD vtable ([`crate::simd`]); dispatch is
//! bit-transparent, so window admissions, tie-breaks and statistics are
//! identical at every ISA (and under `DTW_FORCE_ISA=scalar`).

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::bounds::envelope::envelopes_into;
use crate::bounds::{keogh, BoundKind, PreparedSeries, Scratch};
use crate::data::znorm::znormalize_with_moments;
use crate::delta::Delta;
use crate::dtw::dtw_ea_pruned;
use crate::exec::Executor;
use crate::index::DtwIndex;
use crate::search::knn::chunk_shard_ranges;
use crate::search::nn::SearchStats;
use crate::search::PreparedTrainSet;

use super::StreamBuffer;

/// Candidates per work-queue chunk when window scoring runs parallel.
const STREAM_CHUNK: usize = 8;

/// The default screening cascade: constant-time `LB_KIM_FL`, then
/// `LB_KEOGH` (candidate envelopes only — no per-window preparation),
/// then `LB_WEBB` (triggers the lazy per-window envelope preparation).
pub const DEFAULT_CASCADE: &[BoundKind] = &[BoundKind::KimFL, BoundKind::Keogh, BoundKind::Webb];

/// Knobs for a subsequence search. At least one of the `threshold` /
/// `top_k` fields must be set (otherwise every window would trivially
/// "match").
#[derive(Debug, Clone, PartialEq)]
pub struct SubsequenceOptions {
    /// Match threshold τ: a window matches when its nearest indexed
    /// series is at DTW distance `< τ`. `None` disables the threshold
    /// (top-k mode only).
    pub threshold: Option<f64>,
    /// Keep only the `k` globally best windows (smallest nearest-neighbor
    /// distance); results come from [`SubsequenceSearcher::finish`].
    pub top_k: Option<usize>,
    /// Stride between evaluated window starts (`≥ 1`; 1 = every sample).
    pub hop: usize,
    /// Z-normalize each window before matching; `None` inherits the
    /// index-level policy set at build time.
    pub znorm: Option<bool>,
    /// The screening cascade, cheapest first; `None` uses
    /// [`DEFAULT_CASCADE`]. Stage values accumulate by `max`, so any
    /// sequence of valid bounds is sound.
    pub cascade: Option<Vec<BoundKind>>,
    /// Worker threads for per-window candidate scoring (`0` = machine
    /// parallelism, `1` = serial); `None` inherits the index-level
    /// [`crate::index::DtwIndexBuilder::threads`] setting. Matches are
    /// identical at every thread count; per-stage work counters are
    /// scheduling-dependent when parallel.
    pub threads: Option<usize>,
}

impl Default for SubsequenceOptions {
    fn default() -> Self {
        SubsequenceOptions {
            threshold: None,
            top_k: None,
            hop: 1,
            znorm: None,
            cascade: None,
            threads: None,
        }
    }
}

impl SubsequenceOptions {
    /// Threshold mode: report every window within DTW distance `tau`.
    pub fn threshold(tau: f64) -> SubsequenceOptions {
        SubsequenceOptions { threshold: Some(tau), ..SubsequenceOptions::default() }
    }

    /// Top-k mode: keep the `k` best-matching windows of the stream.
    pub fn top_k(k: usize) -> SubsequenceOptions {
        SubsequenceOptions { top_k: Some(k), ..SubsequenceOptions::default() }
    }

    /// Set (or tighten) the match threshold τ.
    pub fn with_threshold(mut self, tau: f64) -> SubsequenceOptions {
        self.threshold = Some(tau);
        self
    }

    /// Keep only the `k` globally best windows.
    pub fn with_top_k(mut self, k: usize) -> SubsequenceOptions {
        self.top_k = Some(k);
        self
    }

    /// Evaluate windows every `hop` samples.
    pub fn with_hop(mut self, hop: usize) -> SubsequenceOptions {
        self.hop = hop;
        self
    }

    /// Override the index-level z-normalization policy.
    pub fn with_znorm(mut self, znorm: bool) -> SubsequenceOptions {
        self.znorm = Some(znorm);
        self
    }

    /// Replace the screening cascade (cheapest stage first).
    pub fn with_cascade(mut self, cascade: Vec<BoundKind>) -> SubsequenceOptions {
        self.cascade = Some(cascade);
        self
    }

    /// Score each window's candidates on `threads` workers.
    pub fn with_threads(mut self, threads: usize) -> SubsequenceOptions {
        self.threads = Some(threads);
        self
    }
}

/// One matched window: where it starts in the stream, which indexed
/// series it matched, and the exact DTW distance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamMatch {
    /// Stream position of the window's first sample.
    pub start: u64,
    /// Index of the nearest indexed series.
    pub neighbor: usize,
    /// Its label.
    pub label: u32,
    /// The exact DTW distance between the (optionally z-normalized)
    /// window and that series.
    pub distance: f64,
}

/// Per-stage counters of the screening cascade.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageStats {
    /// Which bound this stage runs.
    pub bound: BoundKind,
    /// Evaluations of this stage.
    pub lb_calls: u64,
    /// Candidates this stage rejected (they never reached later stages).
    pub pruned: u64,
}

/// Work counters for a whole stream: per-stage cascade pruning plus the
/// DTW tail — the streaming analogue of [`SearchStats`].
#[derive(Debug, Clone, PartialEq)]
pub struct StreamStats {
    /// Samples pushed.
    pub samples: u64,
    /// Windows evaluated (after the hop filter).
    pub windows: u64,
    /// Window × candidate pairs considered.
    pub candidates: u64,
    /// Per-stage counters, in cascade order.
    pub stages: Vec<StageStats>,
    /// Full DTW computations started.
    pub dtw_calls: u64,
    /// DTW computations abandoned early.
    pub dtw_abandoned: u64,
    /// Windows that produced a match.
    pub matches: u64,
    /// Cluster-level merged-envelope bound evaluations (only nonzero
    /// when the index carries clusters).
    pub cluster_lb_calls: u64,
    /// Whole clusters skipped because their merged-envelope bound
    /// reached the window-entry cutoff.
    pub clusters_pruned: u64,
    /// Window × candidate pairs skipped via cluster pruning — they
    /// never reached the cascade, so no stage counts them.
    pub cluster_members_pruned: u64,
    /// Delta-shard candidates visited by a live overlay's append-log
    /// continuation (zero without an overlay). Every visited entry is
    /// also accounted in exactly one of `delta_pruned` / `delta_dtw`.
    pub delta_scanned: u64,
    /// Delta-shard candidates rejected by some cascade stage (each also
    /// counts in that stage's `pruned`).
    pub delta_pruned: u64,
    /// Delta-shard candidates that reached the exact DTW kernel (subset
    /// of `dtw_calls`).
    pub delta_dtw: u64,
}

impl StreamStats {
    fn new(cascade: &[BoundKind]) -> StreamStats {
        StreamStats {
            samples: 0,
            windows: 0,
            candidates: 0,
            stages: cascade
                .iter()
                .map(|&bound| StageStats { bound, lb_calls: 0, pruned: 0 })
                .collect(),
            dtw_calls: 0,
            dtw_abandoned: 0,
            matches: 0,
            cluster_lb_calls: 0,
            clusters_pruned: 0,
            cluster_members_pruned: 0,
            delta_scanned: 0,
            delta_pruned: 0,
            delta_dtw: 0,
        }
    }

    /// Candidates rejected by the cascade alone (any stage).
    pub fn pruned(&self) -> u64 {
        self.stages.iter().map(|s| s.pruned).sum()
    }

    /// Fraction of window × candidate pairs the cascade rejected.
    pub fn prune_rate(&self) -> f64 {
        self.pruned() as f64 / (self.candidates.max(1)) as f64
    }

    /// Collapse into the [`SearchStats`] currency the rest of the crate
    /// (and [`crate::index::QueryOutcome`]) reports.
    pub fn to_search_stats(&self) -> SearchStats {
        SearchStats {
            lb_calls: self.stages.iter().map(|s| s.lb_calls).sum::<u64>() as usize,
            pruned: self.pruned() as usize,
            dtw_calls: self.dtw_calls as usize,
            dtw_abandoned: self.dtw_abandoned as usize,
            cluster_lb_calls: self.cluster_lb_calls as usize,
            clusters_pruned: self.clusters_pruned as usize,
            cluster_members_pruned: self.cluster_members_pruned as usize,
            delta_scanned: self.delta_scanned as usize,
            delta_pruned: self.delta_pruned as usize,
            delta_dtw: self.delta_dtw as usize,
        }
    }
}

/// Everything a finished stream pass returns: the matches (stream order
/// in threshold mode, ascending distance in top-k mode), the per-stage
/// work counters, and the accumulated search-side busy time.
#[derive(Debug, Clone)]
pub struct StreamReport {
    /// The matched windows.
    pub matches: Vec<StreamMatch>,
    /// Per-stage cascade counters.
    pub stats: StreamStats,
    /// Time spent evaluating windows (excludes idle time between samples).
    pub busy: Duration,
}

impl StreamReport {
    /// The aggregate [`SearchStats`] view of [`StreamReport::stats`].
    pub fn search_stats(&self) -> SearchStats {
        self.stats.to_search_stats()
    }
}

/// Streaming subsequence search: slide an index-length window over an
/// unbounded sample stream and report every window (or the top-k
/// windows) whose exact DTW distance to some indexed series beats the
/// threshold.
///
/// Built by [`DtwIndex::subsequence`]. Feed samples with
/// [`SubsequenceSearcher::push`] (or [`SubsequenceSearcher::scan`] for a
/// whole slice); collect results and statistics with
/// [`SubsequenceSearcher::finish`].
///
/// Every window evaluation is **exact**: the cascade stages are valid
/// lower bounds evaluated cheapest-first with early abandoning against
/// the current cutoff (threshold, intra-window best, and in top-k mode
/// the k-th best window so far), and survivors run early-abandoning DTW.
/// Use one [`Delta`] per stream — the cutoff state is only meaningful
/// under a single δ.
pub struct SubsequenceSearcher {
    index: DtwIndex,
    /// Effective threshold (`f64::INFINITY` when unset).
    tau: f64,
    top_k: Option<usize>,
    hop: u64,
    znorm: bool,
    cascade: Vec<BoundKind>,
    /// Window length = indexed series length.
    m: usize,
    /// Warping window (from the index).
    w: usize,
    buffer: StreamBuffer,
    /// Reusable per-window preparation (values + envelopes, lazily filled).
    pq: PreparedSeries,
    envs_ready: bool,
    /// Scratch for the discarded halves of the envelope-of-envelope pass.
    tmp: Vec<f64>,
    scratch: Scratch,
    /// Candidate-scoring executor (serial by default).
    exec: Executor,
    /// One scratch per parallel worker, allocated once at construction.
    par_scratch: Vec<Mutex<Scratch>>,
    /// Precomputed parallel work ranges (shard-aligned chunks of the
    /// candidate ids; empty when the sweep runs serial) — the candidate
    /// set and shard partition are fixed at construction, so the
    /// per-window hot path allocates nothing for them.
    work_ranges: Vec<Range<usize>>,
    /// True when the index carries a cluster-pruning layer.
    has_clusters: bool,
    /// Per-candidate skip mask (global candidate ids), refilled by the
    /// cluster prepass before each window's sweep. Keeping a mask —
    /// instead of reordering the sweep by cluster — preserves the flat
    /// ascending visit order, and with it the serial sweep's
    /// lowest-index tie-breaking, so clustered matches stay bit-equal
    /// to clusterless ones.
    cluster_mask: Vec<bool>,
    /// Live-overlay delta entries `(label, prepared series)` in append
    /// order — evaluated by a serial cascade continuation after the base
    /// sweep of every window (empty without an overlay). Their ids
    /// extend the physical space (`base_len + offset`) until the final
    /// logical remap at emission.
    ov_delta: Vec<(u32, PreparedSeries)>,
    /// Live-overlay tombstone mask over the base candidates (all-false
    /// without an overlay): tombstoned series are skipped by both
    /// sweeps, exactly as a cold rebuild would never contain them.
    ov_dead: Vec<bool>,
    /// `ov_dead_rank[i]` = tombstones strictly below physical `i` — the
    /// physical→logical shift applied to an emitted base neighbor.
    ov_dead_rank: Vec<usize>,
    /// Surviving base candidates (`index.len()` without an overlay).
    ov_survivors: usize,
    matches: Vec<StreamMatch>,
    stats: StreamStats,
    busy: Duration,
}

impl SubsequenceSearcher {
    /// Build a searcher over `index` — see [`DtwIndex::subsequence`].
    pub fn new(index: &DtwIndex, opts: SubsequenceOptions) -> Result<SubsequenceSearcher> {
        if index.is_empty() {
            bail!("subsequence search needs a non-empty index");
        }
        if opts.threshold.is_none() && opts.top_k.is_none() {
            bail!("set a threshold and/or top_k (otherwise every window matches)");
        }
        if opts.top_k == Some(0) {
            bail!("top_k must be >= 1");
        }
        if opts.hop == 0 {
            bail!("hop must be >= 1");
        }
        let cascade = match opts.cascade {
            Some(c) if c.is_empty() => bail!("cascade must have at least one stage"),
            Some(c) => c,
            None => DEFAULT_CASCADE.to_vec(),
        };
        let m = index.train().series[0].len();
        let w = index.window();
        let stats = StreamStats::new(&cascade);
        let exec = Executor::new(opts.threads.unwrap_or(index.threads()));
        let par_scratch: Vec<Mutex<Scratch>> = if exec.threads() > 1 {
            (0..exec.threads()).map(|_| Mutex::new(Scratch::new(m))).collect()
        } else {
            Vec::new()
        };
        // Parallel fan-out unit: shard-aligned chunks of the candidate
        // ids (whole-range chunks for an unsharded index). Fixed for the
        // searcher's lifetime, so built once here.
        let work_ranges: Vec<Range<usize>> = if exec.threads() > 1 {
            let shard_ranges: Vec<Range<usize>> = if index.shard_count() > 1 {
                index.shards().iter().map(|s| s.range()).collect()
            } else {
                vec![0..index.len()]
            };
            chunk_shard_ranges(&shard_ranges, STREAM_CHUNK)
        } else {
            Vec::new()
        };
        Ok(SubsequenceSearcher {
            tau: opts.threshold.unwrap_or(f64::INFINITY),
            top_k: opts.top_k,
            hop: opts.hop as u64,
            znorm: opts.znorm.unwrap_or(index.znormalizes()),
            cascade,
            m,
            w,
            buffer: StreamBuffer::new(m),
            pq: PreparedSeries {
                values: Vec::with_capacity(m),
                w,
                lo: Vec::with_capacity(m),
                up: Vec::with_capacity(m),
                lo_of_up: Vec::with_capacity(m),
                up_of_lo: Vec::with_capacity(m),
            },
            envs_ready: false,
            tmp: Vec::with_capacity(m),
            scratch: Scratch::new(m),
            exec,
            par_scratch,
            work_ranges,
            has_clusters: index.has_clusters(),
            cluster_mask: vec![false; index.len()],
            ov_delta: Vec::new(),
            ov_dead: vec![false; index.len()],
            ov_dead_rank: vec![0; index.len()],
            ov_survivors: index.len(),
            matches: Vec::new(),
            stats,
            index: index.clone(),
            busy: Duration::ZERO,
        })
    }

    /// The index being matched against.
    pub fn index(&self) -> &DtwIndex {
        &self.index
    }

    /// Install a live-mutation overlay: `delta` entries (append order,
    /// all window-length) and a tombstone mask over the base candidates.
    ///
    /// With the overlay, every window's sweep skips tombstoned base
    /// series, continues over the delta entries with the same cascade
    /// (serial, ascending append order — the exact tail a cold rebuild's
    /// serial sweep would run, since delta ids follow every base id),
    /// and emits matches in the gap-free **logical** id space. Both
    /// remaps are strictly monotone, so `(distance, id)` tie-breaking is
    /// preserved and matches stay bit-identical to a cold rebuild over
    /// the same logical series set.
    pub(crate) fn set_overlay(&mut self, delta: Vec<(u32, PreparedSeries)>, dead: Vec<bool>) {
        debug_assert_eq!(dead.len(), self.index.len());
        debug_assert!(delta.iter().all(|(_, s)| s.len() == self.m));
        let mut rank = vec![0usize; dead.len()];
        let mut seen = 0usize;
        for (i, &d) in dead.iter().enumerate() {
            rank[i] = seen;
            if d {
                seen += 1;
            }
        }
        self.ov_survivors = dead.len() - seen;
        self.ov_dead = dead;
        self.ov_dead_rank = rank;
        self.ov_delta = delta;
    }

    /// The sliding-window (= indexed series) length.
    pub fn window_len(&self) -> usize {
        self.m
    }

    /// Stride between evaluated window starts.
    pub fn hop(&self) -> usize {
        self.hop as usize
    }

    /// Work counters so far.
    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }

    /// Matches recorded so far (threshold mode: stream order; top-k mode:
    /// the current top set, ascending by distance).
    pub fn matches(&self) -> &[StreamMatch] {
        &self.matches
    }

    /// Take the retained matches, leaving the searcher running with an
    /// empty set. Long-running threshold-mode monitors should call this
    /// periodically (or just consume [`SubsequenceSearcher::push`]'s
    /// return value and drain to discard): retained matches are the one
    /// part of the searcher whose memory grows with the stream. In top-k
    /// mode this resets the collected set (and therefore the k-th best
    /// cutoff) — usually only wanted between logical stream segments.
    pub fn drain_matches(&mut self) -> Vec<StreamMatch> {
        std::mem::take(&mut self.matches)
    }

    /// Feed the next sample. When this sample completes a window on the
    /// hop grid, the window is evaluated and its match (if any) returned.
    /// In top-k mode a returned match may later be evicted by better
    /// windows — [`SubsequenceSearcher::finish`] has the final set.
    ///
    /// Matches are also retained internally for
    /// [`SubsequenceSearcher::finish`]; on a genuinely unbounded
    /// threshold-mode stream, call
    /// [`SubsequenceSearcher::drain_matches`] periodically so that
    /// retention does not grow without bound.
    pub fn push<D: Delta>(&mut self, v: f64) -> Option<StreamMatch> {
        self.buffer.push(v);
        self.stats.samples += 1;
        let pushed = self.buffer.pushed();
        if pushed < self.m as u64 {
            return None;
        }
        let start = pushed - self.m as u64;
        if start % self.hop != 0 {
            return None;
        }
        self.eval_window::<D>(start)
    }

    /// Feed a whole slice, returning the matches produced along the way
    /// (threshold-mode emissions; empty in pure top-k mode until
    /// [`SubsequenceSearcher::finish`]).
    pub fn scan<D: Delta>(&mut self, samples: &[f64]) -> Vec<StreamMatch> {
        let mut out = Vec::new();
        for &v in samples {
            if let Some(m) = self.push::<D>(v) {
                out.push(m);
            }
        }
        out
    }

    /// Consume the searcher: final matches plus statistics.
    pub fn finish(self) -> StreamReport {
        StreamReport { matches: self.matches, stats: self.stats, busy: self.busy }
    }

    /// Current pruning cutoff: the threshold, sharpened in top-k mode by
    /// the k-th best window distance once k windows matched.
    fn cutoff(&self) -> f64 {
        match self.top_k {
            Some(k) if self.matches.len() >= k => {
                self.tau.min(self.matches[k - 1].distance)
            }
            _ => self.tau,
        }
    }

    /// Record a matched window under the active mode.
    fn admit(&mut self, m: StreamMatch) {
        match self.top_k {
            None => self.matches.push(m),
            Some(k) => {
                let pos = self.matches.partition_point(|x| x.distance <= m.distance);
                self.matches.insert(pos, m);
                self.matches.truncate(k);
            }
        }
    }

    /// Lazily compute the current window's envelopes (and envelopes of
    /// envelopes) — only when a cascade stage actually needs them.
    fn ensure_envelopes(&mut self) {
        if self.envs_ready {
            return;
        }
        // The window is a complete slice, so the batch routine (flat
        // index rings, no per-call allocation) is the right tool; the
        // incremental `StreamingEnvelope` exists for sample-at-a-time
        // consumers and is property-tested bit-equal to this.
        envelopes_into(&self.pq.values, self.w, &mut self.pq.lo, &mut self.pq.up);
        // Envelope-of-envelopes the same way; `tmp` takes the discarded
        // half of each pair.
        envelopes_into(&self.pq.up, self.w, &mut self.pq.lo_of_up, &mut self.tmp);
        envelopes_into(&self.pq.lo, self.w, &mut self.tmp, &mut self.pq.up_of_lo);
        self.envs_ready = true;
    }

    /// Evaluate the window starting at `start`: exact 1-NN over the index
    /// under the current cutoff, via the cascade.
    fn eval_window<D: Delta>(&mut self, start: u64) -> Option<StreamMatch> {
        let t0 = Instant::now();
        self.stats.windows += 1;
        self.buffer.copy_into(&mut self.pq.values);
        if self.znorm {
            // The ring buffer already maintains the window moments in
            // O(1) per sample — reuse them instead of rescanning every
            // surviving window. `stable_moments` guards the O(1)
            // identity against cancellation/drift (falling back to an
            // exact rescan only when the data is ill-conditioned);
            // exactness of the *search* is unaffected either way —
            // every stage and DTW sees the same normalized values.
            let (mean, var) = self.buffer.stable_moments();
            znormalize_with_moments(&mut self.pq.values, mean, var);
        }
        self.envs_ready = false;

        let train = Arc::clone(&self.index.train);
        // Logical candidates: base survivors + delta entries (tombstoned
        // series are skipped, not considered).
        self.stats.candidates += (self.ov_survivors + self.ov_delta.len()) as u64;
        self.cluster_prepass::<D>();
        let best = if self.exec.threads() > 1 && train.len() > 1 {
            self.eval_candidates_parallel::<D>(&train)
        } else {
            self.eval_candidates_serial::<D>(&train)
        };
        // Live-overlay continuation: the delta entries are the tail of
        // the logical candidate order.
        let best = self.eval_delta::<D>(train.len(), best);

        let hit = best.map(|(ti, d)| {
            // Emit in the logical id space: survivors shift down by
            // their tombstone rank; delta entries follow the survivors.
            let (neighbor, label) = if ti < train.len() {
                (ti - self.ov_dead_rank[ti], train.labels[ti])
            } else {
                let j = ti - train.len();
                (self.ov_survivors + j, self.ov_delta[j].0)
            };
            StreamMatch { start, neighbor, label, distance: d }
        });
        if let Some(m) = hit {
            self.stats.matches += 1;
            self.admit(m);
        }
        self.busy += t0.elapsed();
        hit
    }

    /// Cluster prepass: refill the skip mask with every candidate whose
    /// cluster's merged-envelope `LB_KEOGH` reaches the **window-entry**
    /// cutoff. Sound for both sweeps: admission is strict (`d < cutoff`)
    /// and the cutoff is monotone nonincreasing within a window, so a
    /// member with `DTW ≥ LB_KEOGH(member) ≥ cluster bound ≥` the entry
    /// cutoff can never be admitted — skipping it changes no match and
    /// no tie-break (the visit order itself is untouched).
    fn cluster_prepass<D: Delta>(&mut self) {
        if !self.has_clusters {
            return;
        }
        self.cluster_mask.iter_mut().for_each(|m| *m = false);
        let base_cut = self.cutoff();
        if !base_cut.is_finite() {
            return;
        }
        let shards = Arc::clone(&self.index.shards);
        for s in shards.iter() {
            let Some(cl) = s.clusters() else { continue };
            let env = cl.env();
            for c in 0..cl.len() {
                self.stats.cluster_lb_calls += 1;
                let clb = keogh::lb_keogh_flat::<D>(
                    &self.pq.values,
                    env.lo_row(c),
                    env.up_row(c),
                    base_cut,
                );
                if clb >= base_cut {
                    let members = cl.members_of(c);
                    self.stats.clusters_pruned += 1;
                    self.stats.cluster_members_pruned += members.len() as u64;
                    for &m in members {
                        self.cluster_mask[s.start() + m as usize] = true;
                    }
                }
            }
        }
    }

    /// Serial candidate sweep (the default): cascade screening with
    /// early abandoning, pruned exact DTW on survivors.
    fn eval_candidates_serial<D: Delta>(
        &mut self,
        train: &PreparedTrainSet,
    ) -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        'cands: for (ti, t) in train.series.iter().enumerate() {
            if self.cluster_mask[ti] || self.ov_dead[ti] {
                continue;
            }
            let mut cutoff = self.cutoff();
            if let Some((_, d)) = best {
                cutoff = cutoff.min(d);
            }
            let mut lb = 0.0f64;
            for si in 0..self.cascade.len() {
                let stage = self.cascade[si];
                if stage.requires_query_envelopes() {
                    self.ensure_envelopes();
                }
                self.stats.stages[si].lb_calls += 1;
                let v = stage.compute::<D>(&self.pq, t, self.w, cutoff, &mut self.scratch);
                // Stages accumulate by max: each is a valid lower bound,
                // so their max is too (and never loosens earlier work).
                lb = lb.max(v);
                if lb >= cutoff {
                    self.stats.stages[si].pruned += 1;
                    continue 'cands;
                }
            }
            self.stats.dtw_calls += 1;
            let d = if cutoff.is_finite() {
                keogh::lb_keogh_tail::<D>(&self.pq.values, &t.lo, &t.up, &mut self.scratch.tail);
                dtw_ea_pruned::<D>(
                    &self.pq.values,
                    &t.values,
                    self.w,
                    cutoff,
                    Some(&self.scratch.tail),
                )
            } else {
                dtw_ea_pruned::<D>(&self.pq.values, &t.values, self.w, cutoff, None)
            };
            if d.is_infinite() {
                self.stats.dtw_abandoned += 1;
                continue;
            }
            if d < cutoff {
                best = Some((ti, d));
            }
        }
        best
    }

    /// Live-overlay continuation: run the delta entries through the
    /// same cascade, serially in append order, against the cutoff the
    /// base sweep left behind. This is exactly the tail of a cold
    /// rebuild's serial sweep (delta ids follow every base id), and
    /// after the parallel sweep it is equally exact: the base winner is
    /// the true `(distance, index)` argmin over survivors, strict
    /// `d < cutoff` admission keeps it on ties, and later delta entries
    /// must strictly beat earlier ones — lowest-id tie-breaking all the
    /// way down.
    fn eval_delta<D: Delta>(
        &mut self,
        base_len: usize,
        mut best: Option<(usize, f64)>,
    ) -> Option<(usize, f64)> {
        if self.ov_delta.is_empty() {
            return best;
        }
        // Take the entries so cascade stages can borrow `self` freely.
        let delta = std::mem::take(&mut self.ov_delta);
        'cands: for (j, (_, t)) in delta.iter().enumerate() {
            self.stats.delta_scanned += 1;
            let mut cutoff = self.cutoff();
            if let Some((_, d)) = best {
                cutoff = cutoff.min(d);
            }
            let mut lb = 0.0f64;
            for si in 0..self.cascade.len() {
                let stage = self.cascade[si];
                if stage.requires_query_envelopes() {
                    self.ensure_envelopes();
                }
                self.stats.stages[si].lb_calls += 1;
                let v = stage.compute::<D>(&self.pq, t, self.w, cutoff, &mut self.scratch);
                lb = lb.max(v);
                if lb >= cutoff {
                    self.stats.stages[si].pruned += 1;
                    self.stats.delta_pruned += 1;
                    continue 'cands;
                }
            }
            self.stats.dtw_calls += 1;
            self.stats.delta_dtw += 1;
            let d = if cutoff.is_finite() {
                keogh::lb_keogh_tail::<D>(&self.pq.values, &t.lo, &t.up, &mut self.scratch.tail);
                dtw_ea_pruned::<D>(
                    &self.pq.values,
                    &t.values,
                    self.w,
                    cutoff,
                    Some(&self.scratch.tail),
                )
            } else {
                dtw_ea_pruned::<D>(&self.pq.values, &t.values, self.w, cutoff, None)
            };
            if d.is_infinite() {
                self.stats.dtw_abandoned += 1;
                continue;
            }
            if d < cutoff {
                best = Some((base_len + j, d));
            }
        }
        self.ov_delta = delta;
        best
    }

    /// Candidate-parallel sweep: workers pull the precomputed
    /// shard-aligned work ranges (`work_ranges`, built once at
    /// construction — no chunk crosses a shard boundary), prune against
    /// a shared atomic cutoff (τ / top-k k-th best / running
    /// intra-window best) and race the exact distances. The winning
    /// `(distance, index)` is a pure minimum over exactly-computed
    /// candidates, so matches are identical to the serial sweep at every
    /// shard and thread count; per-stage counters become
    /// scheduling-dependent.
    fn eval_candidates_parallel<D: Delta>(
        &mut self,
        train: &PreparedTrainSet,
    ) -> Option<(usize, f64)> {
        // Lazy envelope preparation cannot cross worker threads: pay it
        // up front when any stage reads query-side envelopes.
        if self.cascade.iter().any(|b| b.requires_query_envelopes()) {
            self.ensure_envelopes();
        }
        let base_cut = self.cutoff();
        // Monotone-nonincreasing cutoff as nonnegative f64 bits (bit
        // order == numeric order for nonnegative floats, +INF included).
        let cutoff_bits = AtomicU64::new(base_cut.max(0.0).to_bits());
        let best: Mutex<Option<(f64, usize)>> = Mutex::new(None);
        let nstages = self.cascade.len();
        // (per-stage (lb_calls, pruned), dtw_calls, dtw_abandoned)
        let agg = Mutex::new((vec![(0u64, 0u64); nstages], 0u64, 0u64));
        let pq = &self.pq;
        let cascade = &self.cascade;
        let w = self.w;
        let scratches = &self.par_scratch;
        let work = &self.work_ranges;
        let mask = &self.cluster_mask;
        let dead = &self.ov_dead;
        self.exec.run(work.len(), 1, |wid, queue| {
            let mut scratch = scratches[wid].lock().unwrap();
            let mut stages = vec![(0u64, 0u64); nstages];
            let (mut dtw_calls, mut dtw_abandoned) = (0u64, 0u64);
            while let Some(chunk) = queue.next_chunk() {
                'cands: for ti in chunk.flat_map(|ri| work[ri].clone()) {
                    if mask[ti] || dead[ti] {
                        continue;
                    }
                    let t = &train.series[ti];
                    let cut = f64::from_bits(cutoff_bits.load(Ordering::Relaxed));
                    let mut lb = 0.0f64;
                    for (si, stage) in cascade.iter().enumerate() {
                        stages[si].0 += 1;
                        let v = stage.compute::<D>(pq, t, w, cut, &mut scratch);
                        lb = lb.max(v);
                        // Strictly above only: an exact tie must still
                        // race on the candidate index.
                        if lb > cut {
                            stages[si].1 += 1;
                            continue 'cands;
                        }
                    }
                    dtw_calls += 1;
                    let d = if cut.is_finite() {
                        keogh::lb_keogh_tail::<D>(&pq.values, &t.lo, &t.up, &mut scratch.tail);
                        dtw_ea_pruned::<D>(&pq.values, &t.values, w, cut, Some(&scratch.tail))
                    } else {
                        dtw_ea_pruned::<D>(&pq.values, &t.values, w, cut, None)
                    };
                    if d.is_infinite() {
                        dtw_abandoned += 1;
                        continue;
                    }
                    let mut guard = best.lock().unwrap();
                    let better = match *guard {
                        None => true,
                        Some((bd, bt)) => d < bd || (d == bd && ti < bt),
                    };
                    if better {
                        *guard = Some((d, ti));
                        cutoff_bits.fetch_min(d.max(0.0).to_bits(), Ordering::Relaxed);
                    }
                }
            }
            let mut a = agg.lock().unwrap();
            for si in 0..nstages {
                a.0[si].0 += stages[si].0;
                a.0[si].1 += stages[si].1;
            }
            a.1 += dtw_calls;
            a.2 += dtw_abandoned;
        });
        let (stages, dtw_calls, dtw_abandoned) = agg.into_inner().unwrap();
        for (si, (calls, pruned)) in stages.into_iter().enumerate() {
            self.stats.stages[si].lb_calls += calls;
            self.stats.stages[si].pruned += pruned;
        }
        self.stats.dtw_calls += dtw_calls;
        self.stats.dtw_abandoned += dtw_abandoned;
        // A match still requires beating the window-entry cutoff (τ and
        // the top-k k-th best) — the atomic only tightened below it.
        best.into_inner()
            .unwrap()
            .filter(|&(d, _)| d < base_cut)
            .map(|(d, ti)| (ti, d))
    }
}
