//! # dtw-bounds — Tight lower bounds for Dynamic Time Warping
//!
//! A complete reproduction of Webb & Petitjean, *"Tight lower bounds for
//! Dynamic Time Warping"*, Pattern Recognition 114 (2021) 107895 — grown
//! into an exact nearest-neighbor DTW search service.
//!
//! ## Quickstart: the `DtwIndex` facade
//!
//! The primary API is [`index::DtwIndex`]: index a training corpus once
//! (envelopes are prepared off the query path, the UCR-suite discipline),
//! then ask for exact k-nearest neighbors. Lower bounds, search strategy
//! and the batched screening backend are builder knobs:
//!
//! ```
//! use dtw_bounds::bounds::BoundKind;
//! use dtw_bounds::delta::Squared;
//! use dtw_bounds::index::{DtwIndex, Query, QueryOptions};
//! use dtw_bounds::runtime::BackendKind;
//! use dtw_bounds::search::SearchStrategy;
//!
//! let train = vec![
//!     vec![0.0, 0.1, 0.4, 0.2, 0.0, -0.2],
//!     vec![1.0, 0.9, 0.8, 0.9, 1.1, 1.0],
//!     vec![0.0, 0.5, 1.0, 0.5, 0.0, -0.5],
//! ];
//! let index = DtwIndex::builder(train)
//!     .labels(vec![0, 1, 0])
//!     .window(1)
//!     .bound(BoundKind::Webb)
//!     .strategy(SearchStrategy::Sorted)
//!     .backend(BackendKind::Native)
//!     .build()?;
//!
//! // k-NN with per-stage pruning counts.
//! let outcome = index.knn::<Squared>(&[0.0, 0.2, 0.5, 0.2, 0.0, -0.3], 2);
//! assert_eq!(outcome.neighbors.len(), 2);
//! assert!(outcome.neighbors[0].distance <= outcome.neighbors[1].distance);
//!
//! // Typed queries carry an abandon threshold, z-norm policy and
//! // self-match exclusion; hot paths hold a per-thread `Searcher`.
//! let mut searcher = index.searcher();
//! let q = Query::new(vec![0.9, 1.0, 0.9, 0.8, 1.0, 1.1])
//!     .with_options(QueryOptions::k(1));
//! let one = searcher.query::<Squared>(&q);
//! assert_eq!(one.best().unwrap().label, 1);
//! # Ok::<(), anyhow::Error>(())
//! ```
//!
//! Every path is **exact**: strategies and backends only move the
//! screening cost. The free-function 1-NN API (`search::nn::nn_sorted`
//! and friends) is deprecated since 0.3.0 and shims onto the k-NN
//! kernels in [`search::knn`]; it will be removed one release later.
//!
//! ## Layers
//!
//! * **DTW** itself ([`dtw`]): windowed dynamic time warping with `O(w)`
//!   memory, early abandoning, cell pruning with cumulative-lower-bound
//!   tails (`dtw_ea_pruned` — the kernel behind every search path),
//!   full cost matrices and warping-path extraction.
//! * **Parallel substrate** ([`exec`]): a dependency-free scoped
//!   thread-pool with a dynamically-chunked work queue, threaded through
//!   `DtwIndexBuilder::threads(n)` — candidate screening, batched
//!   prefilter rows and stream window scoring all scale across cores
//!   with **identical results at every thread count**.
//! * **The complete lower-bound family** ([`bounds`]): the paper's four new
//!   bounds — `LB_PETITJEAN`, `LB_WEBB`, `LB_WEBB*`, `LB_WEBB_ENHANCED` —
//!   and every baseline it compares against (`LB_KIM`, `LB_KEOGH`,
//!   `LB_IMPROVED`, `LB_ENHANCED`) plus the ablation variants
//!   (`*_NoLR`) and the cascading evaluator from §8.
//! * **The index facade** ([`index`]): builder-configured exact k-NN
//!   search over a prepared corpus — the primary API. Candidates are
//!   owned by contiguous **shards** (`DtwIndexBuilder::shards`), every
//!   search path fans out per shard with bit-identical results, and the
//!   whole prepared index round-trips through a versioned, checksummed
//!   snapshot ([`index::snapshot`], `DtwIndex::save`/`load`) so serving
//!   processes cold-start from one file instead of re-preparing
//!   envelopes from raw series.
//! * **Live mutation** ([`live`]): a delta-shard write path (`insert` /
//!   `delete` with tombstones) over the frozen base, explicit or
//!   auto-threshold **compaction** into the next generation, and
//!   generational snapshots (v3) with rollback — every search path
//!   stays bit-identical to a cold rebuild of the logical series set.
//! * **Durability** ([`io`] + [`live::wal`]): every persisted byte flows
//!   through a five-verb file-ops trait with a real-FS default and a
//!   deterministic fault-injecting test double; accepted live mutations
//!   are appended to a checksummed write-ahead log *before* the ack, so
//!   a crashed server restarts bit-equal to an uninterrupted run
//!   (`rust/tests/recovery.rs` enumerates every crash point).
//! * **Streaming subsequence search** ([`stream`]): slide an index-length
//!   window over unbounded sample streams behind a cascaded-bound screen
//!   (`LB_KIM_FL → LB_KEOGH → LB_WEBB` by default), in threshold and
//!   top-k modes with per-stage prune statistics — the §1 monitoring
//!   scenario, reachable via [`index::DtwIndex::subsequence`].
//! * **Search kernels** ([`search`]): the paper's Algorithm 3
//!   (random order with early abandoning) and Algorithm 4 (bound-sorted)
//!   generalized to k-NN, tightness evaluation, LOOCV window selection
//!   and 1-NN classification.
//! * **Data substrate** ([`data`]): a UCR-archive `.tsv` loader and a
//!   deterministic synthetic archive generator that mirrors the shape
//!   statistics of the UCR-85 "bakeoff" suite (the real archive is not
//!   redistributable; see `DESIGN.md` §4).
//! * **A serving layer** ([`coordinator`]): a std-thread worker pool, query
//!   router and dynamic batcher exposing the index as a service.
//! * **Batched screening backends** ([`runtime`]): the pluggable
//!   [`runtime::LbBackend`] abstraction over the batched `LB_KEOGH`
//!   prefilter — a cache-blocked, early-abandoning pure-Rust default
//!   ([`runtime::NativeBatchLb`]), and, behind the `pjrt` cargo feature,
//!   a PJRT backend executing AOT-compiled XLA artifacts (built once from
//!   JAX + Pallas under `python/`) — Python is never on the query path.
//! * **Experiment drivers** ([`experiments`]): one per table/figure of the
//!   paper's evaluation section, shared by `benches/` and the CLI.
//!
//! ## Low-level API
//!
//! The bound kernels remain directly accessible when you manage
//! preparation and scratch yourself:
//!
//! ```
//! use dtw_bounds::delta::Squared;
//! use dtw_bounds::dtw::dtw;
//! use dtw_bounds::bounds::{BoundKind, PreparedSeries, Scratch};
//!
//! let a = vec![-1.0, 1.0, -1.0, 4.0, -2.0, 1.0, 1.0, 1.0, -1.0, 0.0, 1.0];
//! let b = vec![1.0, -1.0, 1.0, -1.0, -1.0, -4.0, -4.0, -1.0, 1.0, 0.0, -1.0];
//! let w = 1;
//! let d = dtw::<Squared>(&a, &b, w);
//! assert_eq!(d, 53.0); // paper Figure 3 (the caption's 52 is a typo)
//!
//! let q = PreparedSeries::prepare(a, w);
//! let t = PreparedSeries::prepare(b, w);
//! let mut scratch = Scratch::new(q.len());
//! let lb = BoundKind::Webb.compute::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
//! assert!(lb <= d);
//! ```
//!
//! All bounds share the invariant `λ_w(A, B) ≤ DTW_w(A, B)`, enforced by
//! the property-test suite in `rust/tests/`.

pub mod bounds;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod delta;
pub mod dtw;
pub mod exec;
pub mod experiments;
pub mod index;
pub mod io;
pub mod live;
pub mod metrics;
pub mod runtime;
pub mod search;
pub mod simd;
pub mod stream;

/// Library version, mirrored from `Cargo.toml`.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
