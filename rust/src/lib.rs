//! # dtw-bounds — Tight lower bounds for Dynamic Time Warping
//!
//! A complete reproduction of Webb & Petitjean, *"Tight lower bounds for
//! Dynamic Time Warping"*, Pattern Recognition 114 (2021) 107895.
//!
//! The library provides:
//!
//! * **DTW** itself ([`dtw`]): windowed dynamic time warping with `O(w)`
//!   memory, early abandoning, full cost matrices and warping-path
//!   extraction.
//! * **The complete lower-bound family** ([`bounds`]): the paper's four new
//!   bounds — `LB_PETITJEAN`, `LB_WEBB`, `LB_WEBB*`, `LB_WEBB_ENHANCED` —
//!   and every baseline it compares against (`LB_KIM`, `LB_KEOGH`,
//!   `LB_IMPROVED`, `LB_ENHANCED`) plus the ablation variants
//!   (`*_NoLR`) and the cascading evaluator from §8.
//! * **Nearest-neighbor search** ([`search`]): the paper's Algorithm 3
//!   (random order with early abandoning) and Algorithm 4 (bound-sorted),
//!   tightness evaluation, LOOCV window selection and 1-NN classification.
//! * **Data substrate** ([`data`]): a UCR-archive `.tsv` loader and a
//!   deterministic synthetic archive generator that mirrors the shape
//!   statistics of the UCR-85 "bakeoff" suite (the real archive is not
//!   redistributable; see `DESIGN.md` §4).
//! * **A serving layer** ([`coordinator`]): a std-thread worker pool, query
//!   router and dynamic batcher exposing NN search as a service.
//! * **Batched screening backends** ([`runtime`]): the pluggable
//!   [`runtime::LbBackend`] abstraction over the batched `LB_KEOGH`
//!   prefilter — a cache-blocked, early-abandoning pure-Rust default
//!   ([`runtime::NativeBatchLb`]), and, behind the `pjrt` cargo feature,
//!   a PJRT backend executing AOT-compiled XLA artifacts (built once from
//!   JAX + Pallas under `python/`) — Python is never on the query path.
//! * **Experiment drivers** ([`experiments`]): one per table/figure of the
//!   paper's evaluation section, shared by `benches/` and the CLI.
//!
//! ## Quickstart
//!
//! ```
//! use dtw_bounds::delta::Squared;
//! use dtw_bounds::dtw::dtw;
//! use dtw_bounds::bounds::{BoundKind, PreparedSeries, Scratch};
//!
//! let a = vec![-1.0, 1.0, -1.0, 4.0, -2.0, 1.0, 1.0, 1.0, -1.0, 0.0, 1.0];
//! let b = vec![1.0, -1.0, 1.0, -1.0, -1.0, -4.0, -4.0, -1.0, 1.0, 0.0, -1.0];
//! let w = 1;
//! let d = dtw::<Squared>(&a, &b, w);
//! assert_eq!(d, 53.0); // paper Figure 3 (the caption's 52 is a typo)
//!
//! let q = PreparedSeries::prepare(a, w);
//! let t = PreparedSeries::prepare(b, w);
//! let mut scratch = Scratch::new(q.len());
//! let lb = BoundKind::Webb.compute::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
//! assert!(lb <= d);
//! ```
//!
//! All bounds share the invariant `λ_w(A, B) ≤ DTW_w(A, B)`, enforced by
//! the property-test suite in `rust/tests/`.

pub mod bounds;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod delta;
pub mod dtw;
pub mod experiments;
pub mod metrics;
pub mod runtime;
pub mod search;

/// Library version, mirrored from `Cargo.toml`.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
