//! `dtw-bounds` CLI — the leader entrypoint.
//!
//! Subcommands:
//! * `gen-archive` — export the synthetic archive as UCR-format `.tsv`.
//! * `tightness`   — §6.1 tightness experiment (Figures 1, 2, 15–18).
//! * `nn`          — §6.2 NN timing (Figures 19–28).
//! * `knn`         — k-nearest-neighbor queries through the `DtwIndex`
//!   facade (`--k`, `--bound`, `--strategy`, `--threads`).
//! * `sweep`       — §6.3 window sweep (Tables 1–3, Figures 29–30).
//! * `ablation`    — §7 left/right-path ablation (Figures 31–34).
//! * `stream`      — streaming subsequence search: slide index-length
//!   windows over samples from a file/stdin (or a `--demo` synthetic
//!   stream) and report windows within `--tau` of an indexed series
//!   (and/or the `--k` best windows), with per-stage cascade stats.
//! * `index`       — persistent-index tooling: `index build` prepares a
//!   (optionally sharded, optionally cluster-pruned) index and saves it
//!   as a versioned, checksummed snapshot (`--out`, `--shards`,
//!   `--clusters <n|auto>`); `index inspect` prints a snapshot's header
//!   (version, checksum, shard/series/cluster counts, generation
//!   lineage, window, bound config) without loading the payload into an
//!   index; `index compact <snap>` rebuilds a snapshot into the next
//!   generation (`<base>.g<N+1>`).
//! * `serve`       — start the NN search server (router + batched
//!   prefilter; `--backend native|pjrt|none`, `--k` for a default k-NN
//!   depth, `--threads` for parallel candidate screening,
//!   `--snapshot <path>` to cold-start from a saved index with no
//!   access to the raw dataset, `--auto-compact <n>` to fold the live
//!   delta shard into the next generation once `n` mutations pend,
//!   `--wal off|always|never|every:<n>` for crash-durable mutations
//!   beside the snapshot anchor, `--read-timeout-ms`/`--max-request-kb`
//!   per-connection limits, `--queue-cap` for `err=busy` shedding).
//! * `info`        — build/backend/artifact report.
//!
//! Run `dtw-bounds <cmd> --help-args` to see each command's options.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use dtw_bounds::bounds::BoundKind;
use dtw_bounds::cli::Args;
use dtw_bounds::coordinator::{NnEngine, Router};
use dtw_bounds::data::synthetic::{generate_archive, ArchiveSpec, Scale};
use dtw_bounds::data::{ucr, Dataset};
use dtw_bounds::delta::Squared;
use dtw_bounds::experiments::{
    self, nn_timing::TimedBound, tightness_experiment, window_sweep, with_recommended_window,
};
use dtw_bounds::index::DtwIndex;
use dtw_bounds::metrics::format_duration;
use dtw_bounds::runtime::{default_artifacts_dir, read_manifest, BackendKind};
use dtw_bounds::search::SearchStrategy;

fn main() {
    init_logger();
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn init_logger() {
    struct StderrLogger;
    impl log::Log for StderrLogger {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{}] {}", r.level(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: StderrLogger = StderrLogger;
    let level = match std::env::var("RUST_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        Ok("info") => log::LevelFilter::Info,
        _ => log::LevelFilter::Warn,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

fn load_archive(args: &Args) -> Result<Vec<Dataset>> {
    if let Some(dir) = args.get("archive") {
        let datasets = ucr::load_archive(std::path::Path::new(dir), true)?;
        if datasets.is_empty() {
            bail!("no datasets under {dir}");
        }
        Ok(datasets)
    } else {
        let scale = Scale::parse(&args.str_or("scale", "small"))
            .context("--scale must be tiny|small|paper")?;
        let seed = args.parse_or::<u64>("seed", 2021);
        Ok(generate_archive(&ArchiveSpec::new(scale, seed)))
    }
}

/// Parse a list of CLI bound spellings (shared by `--bounds` and the
/// stream command's `--cascade`).
fn parse_bound_list(names: &[String]) -> Result<Vec<BoundKind>> {
    names
        .iter()
        .map(|n| BoundKind::parse(n).with_context(|| format!("unknown bound {n:?}")))
        .collect()
}

fn parse_bounds(args: &Args, default: &[BoundKind]) -> Result<Vec<BoundKind>> {
    match args.list("bounds") {
        None => Ok(default.to_vec()),
        Some(names) => parse_bound_list(&names),
    }
}

fn run(args: &Args) -> Result<()> {
    match args.command.as_deref() {
        Some("gen-archive") => cmd_gen_archive(args),
        Some("tightness") => cmd_tightness(args),
        Some("nn") => cmd_nn(args),
        Some("knn") => cmd_knn(args),
        Some("sweep") => cmd_sweep(args),
        Some("ablation") => cmd_ablation(args),
        Some("stream") => cmd_stream(args),
        Some("index") => cmd_index(args),
        Some("serve") => cmd_serve(args),
        Some("info") => cmd_info(),
        other => {
            bail!(
                "unknown command {other:?}; expected one of \
                 gen-archive|tightness|nn|knn|sweep|ablation|stream|index|serve|info"
            )
        }
    }
}

/// `index build` / `index inspect`: the persistent-index tooling.
///
/// * `index build --out <path>` prepares an index over a dataset
///   (`--scale`/`--archive`/`--dataset`, `--window`, `--bound`,
///   `--strategy`, `--shards`, `--clusters <n|auto>`, `--threads`,
///   `--znorm`, `--max-batch`) and saves it as a snapshot.
/// * `index inspect <path>` verifies and prints the snapshot header as
///   `key=value` lines (machine-parseable; CI greps them).
/// * `index compact <path> [--out <base>]` loads a snapshot and
///   rebuilds it into the next generation, saved to `<base>.g<N+1>`
///   (the base defaults to the input path with any `.g<N>` suffix
///   stripped) — the offline face of the server's `compact=` verb.
///
/// All report malformed paths/headers as ordinary errors (exit code 1)
/// with the snapshot failure mode spelled out — never a panic.
fn cmd_index(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("build") => {
            let out = args
                .get("out")
                .context("index build needs --out <path> for the snapshot")?
                .to_string();
            let archive = load_archive(args)?;
            let idx = args.parse_or::<usize>("dataset", 0);
            let ds = archive.get(idx).context("--dataset index out of range")?;
            let bound =
                BoundKind::parse(&args.str_or("bound", "webb")).context("bad --bound")?;
            let strategy = SearchStrategy::parse(&args.str_or("strategy", "sorted"))
                .context("--strategy must be sorted|random|precomputed|brute")?;
            let shards = args.parse_or::<usize>("shards", 1);
            if shards == 0 {
                bail!("--shards must be >= 1");
            }
            let mut builder = DtwIndex::builder_from_dataset(ds)
                .window(args.parse_or::<usize>("window", ds.window.max(1)))
                .bound(bound)
                .strategy(strategy)
                .shards(shards)
                .threads(args.parse_or::<usize>("threads", 1))
                .znormalize(args.flag("znorm"))
                .max_batch(args.parse_or::<usize>("max-batch", 16));
            // `--clusters <n>` groups each shard's candidates around n
            // pivots with merged-envelope cluster bounds; `auto` picks
            // ≈√(shard size). Omitted or 0 = no cluster pruning.
            builder = match args.get("clusters") {
                Some("auto") => builder.clusters_auto(),
                Some(v) => builder.clusters(
                    v.parse::<usize>()
                        .context("--clusters must be a non-negative integer or 'auto'")?,
                ),
                None => builder,
            };
            let index = builder.build()?;
            let bytes = index
                .save(&out)
                .map_err(|e| anyhow::anyhow!("save snapshot {out}: {e}"))?;
            println!(
                "built index over dataset {} (n={}, l={}, w={}, bound={bound}, \
                 shards={}, clusters={}) and saved {bytes} bytes to {out}",
                ds.name,
                index.len(),
                ds.series_len(),
                index.window(),
                index.shard_count(),
                index.clusters()
            );
            Ok(())
        }
        Some("inspect") => {
            let path = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .or_else(|| args.get("path"))
                .context("index inspect needs a snapshot path (positional or --path)")?;
            let info = dtw_bounds::index::snapshot::inspect(std::path::Path::new(path))
                .map_err(|e| anyhow::anyhow!("snapshot {path}: {e}"))?;
            println!("path={path}");
            println!("version={}", info.version);
            println!("checksum={:#018x}", info.checksum);
            println!("bytes={}", info.bytes);
            println!("series={}", info.series);
            println!("series_len={}", info.series_len);
            println!("window={}", info.window);
            println!("shards={}", info.shards);
            println!("clusters={}", info.clusters);
            println!("generation={}", info.generation);
            println!("parent={}", info.parent);
            println!("bound={}", info.bound);
            println!("strategy={}", info.strategy);
            println!("backend={}", info.backend);
            println!("znorm={}", info.znorm);
            println!("max_batch={}", info.max_batch);
            println!("threads={}", info.threads);
            println!("seed={}", info.seed);
            // Host property, not a snapshot field: the SIMD ISA this
            // process would serve the snapshot with (results are
            // bit-identical at every ISA; printed for observability).
            println!("isa={}", dtw_bounds::simd::isa_name());
            Ok(())
        }
        Some("compact") => {
            let path = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .or_else(|| args.get("path"))
                .context("index compact needs a snapshot path (positional or --path)")?;
            let index = DtwIndex::load(std::path::Path::new(path))
                .map_err(|e| anyhow::anyhow!("snapshot {path}: {e}"))?;
            // An empty overlay still advances the generation: the result
            // is a bit-exact rebuild of the same series set stamped
            // generation+1 with the old generation as parent.
            let next = dtw_bounds::live::compacted(
                &index,
                &dtw_bounds::live::DeltaShard::new(),
                &dtw_bounds::live::Tombstones::new(),
            )?;
            let base = args
                .get("out")
                .map(str::to_string)
                .unwrap_or_else(|| strip_generation_suffix(path));
            let out = dtw_bounds::index::snapshot::generation_path(
                std::path::Path::new(&base),
                next.generation(),
            );
            let bytes = next
                .save(&out)
                .map_err(|e| anyhow::anyhow!("save snapshot {}: {e}", out.display()))?;
            println!(
                "compacted {path} (generation {} -> {}, n={}) into {} ({bytes} bytes)",
                index.generation(),
                next.generation(),
                next.len(),
                out.display()
            );
            Ok(())
        }
        other => bail!("index: expected build|inspect|compact, got {other:?}"),
    }
}

/// Strip a trailing `.g<N>` generation suffix so `index compact` chains:
/// compacting `prod.snap.g2` writes `prod.snap.g3`, not `prod.snap.g2.g3`.
fn strip_generation_suffix(path: &str) -> String {
    if let Some((base, gen)) = path.rsplit_once(".g") {
        if !gen.is_empty() && gen.bytes().all(|b| b.is_ascii_digit()) {
            return base.to_string();
        }
    }
    path.to_string()
}

fn cmd_gen_archive(args: &Args) -> Result<()> {
    let out = args.str_or("out", "data/synthetic_archive");
    let archive = load_archive(args)?;
    for ds in &archive {
        let dir = std::path::Path::new(&out).join(&ds.name);
        ucr::save_dataset(&dir, ds)?;
        println!(
            "{}\tl={}\ttrain={}\ttest={}\tclasses={}\tw={}",
            ds.name,
            ds.series_len(),
            ds.train.len(),
            ds.test.len(),
            ds.num_classes(),
            ds.window
        );
    }
    println!("wrote {} datasets under {out}", archive.len());
    Ok(())
}

fn cmd_tightness(args: &Args) -> Result<()> {
    let archive = load_archive(args)?;
    let datasets = with_recommended_window(&archive);
    let take = args.parse_or::<usize>("take", datasets.len());
    let bounds = parse_bounds(
        args,
        &[
            BoundKind::Keogh,
            BoundKind::Improved,
            BoundKind::Enhanced(8),
            BoundKind::Petitjean,
            BoundKind::Webb,
        ],
    )?;
    let res = tightness_experiment::<Squared>(&datasets[..take.min(datasets.len())], &bounds);
    println!("{}", res.to_table().to_markdown());
    for i in 0..bounds.len() {
        for j in (i + 1)..bounds.len() {
            let (w, l) = res.win_loss(bounds[i], bounds[j]);
            println!("{} vs {}: tighter on {w}, less tight on {l}", bounds[i], bounds[j]);
        }
    }
    Ok(())
}

fn cmd_nn(args: &Args) -> Result<()> {
    let archive = load_archive(args)?;
    let datasets = with_recommended_window(&archive);
    let take = args.parse_or::<usize>("take", datasets.len());
    let datasets = &datasets[..take.min(datasets.len())];
    let mode = SearchStrategy::parse(&args.str_or("mode", "sorted"))
        .context("--mode must be sorted|random|precomputed|brute")?;
    let repeats = args.parse_or::<usize>("repeats", 3);
    let bounds: Vec<TimedBound> = match args.list("bounds") {
        None => vec![
            TimedBound::Fixed(BoundKind::Keogh),
            TimedBound::Fixed(BoundKind::Improved),
            TimedBound::Fixed(BoundKind::Petitjean),
            TimedBound::Fixed(BoundKind::Webb),
            TimedBound::EnhancedStar,
        ],
        Some(names) => names
            .iter()
            .map(|n| {
                if n.eq_ignore_ascii_case("enhanced*") {
                    Ok(TimedBound::EnhancedStar)
                } else {
                    BoundKind::parse(n)
                        .map(TimedBound::Fixed)
                        .with_context(|| format!("unknown bound {n:?}"))
                }
            })
            .collect::<Result<_>>()?,
    };
    let windows: Vec<usize> = datasets.iter().map(|d| d.window).collect();
    let cols = experiments::nn_timing::<Squared>(
        datasets,
        &windows,
        &bounds,
        mode,
        repeats,
        args.parse_or::<u64>("seed", 7),
    );
    for (i, c) in cols.iter().enumerate() {
        println!("{}: total {}", c.label, format_duration(c.total()));
        for j in 0..cols.len() {
            if i != j {
                let (w, l, r) = experiments::nn_timing::win_loss_ratio(c, &cols[j]);
                println!("  vs {}: {w}/{l}, ratio {r:.2}", cols[j].label);
            }
        }
    }
    if args.flag("scatter") && cols.len() >= 2 {
        println!("{}", experiments::nn_timing::scatter_table(&cols[0], &cols[1]).to_csv());
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let archive = load_archive(args)?;
    let datasets: Vec<&Dataset> = archive.iter().collect();
    let take = args.parse_or::<usize>("take", datasets.len());
    let datasets = &datasets[..take.min(datasets.len())];
    let fracs: Vec<f64> = args
        .list("frac")
        .unwrap_or_else(|| vec!["0.01".into(), "0.10".into(), "0.20".into()])
        .iter()
        .map(|s| s.parse::<f64>().context("bad --frac"))
        .collect::<Result<_>>()?;
    let repeats = args.parse_or::<usize>("repeats", 3);
    for frac in fracs {
        let res = window_sweep::<Squared>(datasets, frac, repeats, 11);
        println!("## w = {:.0}% · l\n", frac * 100.0);
        println!("{}", res.to_table().to_markdown());
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let archive = load_archive(args)?;
    let datasets = with_recommended_window(&archive);
    let take = args.parse_or::<usize>("take", datasets.len());
    let res = experiments::lr_ablation::<Squared>(
        &datasets[..take.min(datasets.len())],
        args.parse_or::<usize>("repeats", 3),
        13,
    );
    println!("### Tightness (Figures 31, 32)\n");
    println!("{}", res.tightness.to_table().to_markdown());
    println!("### Sorted NN time (Figures 33, 34)\n");
    for c in &res.timing {
        println!("{}: total {}", c.label, format_duration(c.total()));
    }
    Ok(())
}

/// `knn`: query the `DtwIndex` facade directly — the CLI face of the
/// primary API. Queries come from the dataset's test split.
fn cmd_knn(args: &Args) -> Result<()> {
    let archive = load_archive(args)?;
    let idx = args.parse_or::<usize>("dataset", 0);
    let ds = archive.get(idx).context("--dataset index out of range")?;
    let k = args.parse_or::<usize>("k", 3);
    if k == 0 {
        bail!("--k must be >= 1");
    }
    let bound = BoundKind::parse(&args.str_or("bound", "webb")).context("bad --bound")?;
    let strategy = SearchStrategy::parse(&args.str_or("strategy", "sorted"))
        .context("--strategy must be sorted|random|precomputed|brute")?;
    let threads = args.parse_or::<usize>("threads", 1);
    let index = DtwIndex::builder_from_dataset(ds)
        .window(args.parse_or::<usize>("window", ds.window.max(1)))
        .bound(bound)
        .strategy(strategy)
        .threads(threads)
        .build()?;
    let queries = args.parse_or::<usize>("queries", 5).min(ds.test.len());
    println!(
        "dataset {} (l={}, n={}, w={}), bound={bound}, strategy={strategy}, k={k}, threads={threads}",
        ds.name,
        ds.series_len(),
        index.len(),
        index.window()
    );
    let mut searcher = index.searcher();
    for (qi, q) in ds.test.iter().take(queries).enumerate() {
        let out = searcher.query_values::<Squared>(
            &q.values,
            &dtw_bounds::index::QueryOptions::k(k),
        );
        let neighbors: Vec<String> = out
            .neighbors
            .iter()
            .map(|n| format!("#{}(label {}, d={:.4})", n.index, n.label, n.distance))
            .collect();
        println!(
            "q{qi} (label {}): {} | pruned {}/{} by {bound}, {} DTW calls, {}us",
            q.label,
            neighbors.join(" "),
            out.stats.pruned,
            index.len(),
            out.stats.dtw_calls,
            out.latency.as_micros()
        );
    }
    Ok(())
}

/// `stream`: streaming subsequence search over a dataset's training
/// split. Samples come from `--input <file>`, stdin, or a `--demo <n>`
/// synthetic stream with embedded (noisy) training series.
fn cmd_stream(args: &Args) -> Result<()> {
    use dtw_bounds::stream::SubsequenceOptions;

    let archive = load_archive(args)?;
    let idx = args.parse_or::<usize>("dataset", 0);
    let ds = archive.get(idx).context("--dataset index out of range")?;
    let index = DtwIndex::builder_from_dataset(ds)
        .window(args.parse_or::<usize>("window", ds.window.max(1)))
        .threads(args.parse_or::<usize>("threads", 1))
        .build()?;

    let mut opts = SubsequenceOptions::default().with_hop(args.parse_or::<usize>("hop", 1));
    if let Some(tau) = args.get("tau") {
        let tau: f64 = tau.parse().context("--tau must be a number")?;
        if !(tau > 0.0 && tau.is_finite()) {
            bail!("--tau must be positive and finite");
        }
        opts.threshold = Some(tau);
    }
    if let Some(k) = args.get("k") {
        let k: usize = k.parse().context("--k must be an integer")?;
        if k == 0 {
            bail!("--k must be >= 1");
        }
        opts.top_k = Some(k);
    }
    if opts.threshold.is_none() && opts.top_k.is_none() {
        bail!("set --tau <dist> and/or --k <n> (otherwise every window matches)");
    }
    if args.flag("znorm") {
        opts.znorm = Some(true);
    }
    if let Some(names) = args.list("cascade") {
        opts.cascade = Some(parse_bound_list(&names)?);
    }

    // Sample source: --demo, --input, or stdin.
    let samples: Vec<f64> = if let Some(n) = args.get("demo") {
        let n: usize = n.parse().context("--demo must be a sample count")?;
        demo_stream(&index, n, args.parse_or::<u64>("demo-seed", 404))
    } else if let Some(path) = args.get("input") {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
        parse_samples(&text)?
    } else {
        let mut text = String::new();
        use std::io::Read;
        std::io::stdin().read_to_string(&mut text).context("read stdin")?;
        parse_samples(&text)?
    };

    // In top-k mode per-push emissions are provisional (later windows can
    // evict them), so only the final set from the report is printed.
    let top_k_mode = opts.top_k.is_some();
    let mut searcher = index.subsequence(opts)?;
    let cascade: Vec<String> =
        searcher.stats().stages.iter().map(|s| s.bound.name()).collect();
    println!(
        "dataset {} (l={}, n={}, w={}), cascade={}, hop={}",
        ds.name,
        ds.series_len(),
        index.len(),
        index.window(),
        cascade.join(" -> "),
        searcher.hop()
    );
    for &v in &samples {
        if let Some(m) = searcher.push::<Squared>(v) {
            if !top_k_mode {
                println!(
                    "match start={} neighbor={} label={} dist={:.6}",
                    m.start, m.neighbor, m.label, m.distance
                );
            }
        }
    }
    let report = searcher.finish();
    if top_k_mode {
        for m in &report.matches {
            println!(
                "top start={} neighbor={} label={} dist={:.6}",
                m.start, m.neighbor, m.label, m.distance
            );
        }
    }
    let s = &report.stats;
    println!("samples={} windows={} matches={}", s.samples, s.windows, s.matches);
    for st in &s.stages {
        let rate = 100.0 * st.pruned as f64 / s.candidates.max(1) as f64;
        println!(
            "stage {}: calls={} pruned={} ({rate:.1}% of pairs)",
            st.bound.name(),
            st.lb_calls,
            st.pruned
        );
    }
    println!("dtw: calls={} abandoned={}", s.dtw_calls, s.dtw_abandoned);
    let secs = report.busy.as_secs_f64();
    if secs > 0.0 && s.samples > 0 {
        println!("throughput: {:.0} samples/s (busy {:.3}s)", s.samples as f64 / secs, secs);
    }
    Ok(())
}

/// Parse whitespace/comma-separated floats.
fn parse_samples(text: &str) -> Result<Vec<f64>> {
    let samples: Vec<f64> = text
        .split(|c: char| c.is_whitespace() || c == ',')
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<f64>().with_context(|| format!("bad sample {t:?}")))
        .collect::<Result<_>>()?;
    if samples.is_empty() {
        bail!("no samples in input");
    }
    Ok(samples)
}

/// A synthetic sensor stream: background noise with occasional noisy
/// copies of the indexed series embedded (the streaming-monitor demo).
fn demo_stream(index: &DtwIndex, n: usize, seed: u64) -> Vec<f64> {
    use dtw_bounds::data::rng::Rng;
    use dtw_bounds::data::synthetic::embed_stream;
    let mut rng = Rng::seeded(seed);
    let patterns: Vec<Vec<f64>> =
        index.train().series.iter().map(|s| s.values.clone()).collect();
    embed_stream(&mut rng, &patterns, n, 0.05, 0.0, 0.05).0
}

fn cmd_serve(args: &Args) -> Result<()> {
    let default_k = args.parse_or::<usize>("k", 1);
    if default_k == 0 {
        bail!("--k must be >= 1");
    }
    // Validate --backend even when --no-batch overrides it, so typos
    // never slip through silently.
    let spelled = args.str_or("backend", "native");
    let mut backend = BackendKind::parse(&spelled).with_context(|| {
        format!("--backend: expected one of {}, got {spelled:?}", BackendKind::CHOICES.join("|"))
    })?;
    if args.flag("no-batch") {
        // Back-compat alias for `--backend none`.
        if backend != BackendKind::None && args.get("backend").is_some() {
            eprintln!("--no-batch overrides --backend {backend}; serving scalar only");
        }
        backend = BackendKind::None;
    }

    // Index source: `--snapshot <path>` cold-starts from a persisted
    // index — no raw dataset is read or needed — otherwise the index is
    // built in-process from the dataset knobs. Serve flags (`--bound`,
    // `--threads`) override the snapshot's stored configuration only
    // when given; the window and shards are fixed by the snapshot.
    let (index, source) = if let Some(snap) = args.get("snapshot") {
        let loaded =
            DtwIndex::load(snap).map_err(|e| anyhow::anyhow!("--snapshot {snap}: {e}"))?;
        let mut idx = loaded;
        if let Some(b) = args.get("bound") {
            idx = idx.with_bound(BoundKind::parse(b).context("bad --bound")?);
        }
        if args.get("threads").is_some() {
            idx = idx.with_threads(args.parse_or::<usize>("threads", 1));
        }
        (idx, format!("snapshot {snap}"))
    } else {
        let archive = load_archive(args)?;
        let ds_no = args.parse_or::<usize>("dataset", 0);
        let ds = archive.get(ds_no).context("--dataset index out of range")?;
        let bound =
            BoundKind::parse(&args.str_or("bound", "webb")).context("bad --bound")?;
        // Search worker threads: 1 = serial (default), 0 = machine
        // parallelism; overridable per request via the `threads=` prefix.
        let index = DtwIndex::builder_from_dataset(ds)
            .window(args.parse_or::<usize>("window", ds.window.max(1)))
            .bound(bound)
            .max_batch(args.parse_or::<usize>("max-batch", 16))
            .threads(args.parse_or::<usize>("threads", 1))
            .shards(args.parse_or::<usize>("shards", 1))
            .build()?;
        (index, format!("dataset {}", ds.name))
    };
    let max_batch = args.parse_or::<usize>("max-batch", index.max_batch());
    let threads = index.threads();
    let bound = index.bound();

    // One shared index: the envelopes are prepared once (or bulk-loaded
    // from the snapshot); the dispatch thread builds its searcher from a
    // cheap handle. Backend handles (PJRT in particular) are not Send,
    // so the backend itself is still constructed inside the router's
    // dispatch thread — the index handle carries `None` and the factory
    // attaches the kind resolved above.
    let index = index.with_backend(BackendKind::None);
    // `--auto-compact <n>`: fold the live delta shard and tombstones
    // into the next generation once `n` mutations pend (0 = never).
    let auto_compact = match args.get("auto-compact") {
        Some(v) => Some(
            v.parse::<usize>().context("--auto-compact must be a non-negative integer")?,
        ),
        None => None,
    };
    // `--wal off|always|never|every:<n>`: write-ahead logging of accepted
    // live mutations next to the snapshot anchor. Requires `--snapshot`
    // (the WAL lives beside the generation files and replays into them).
    let wal_spec = args.str_or("wal", "off");
    let wal_policy = if wal_spec == "off" {
        None
    } else {
        let policy = dtw_bounds::live::FsyncPolicy::parse(&wal_spec).ok_or_else(|| {
            anyhow::anyhow!("--wal: expected off|always|never|every:<n>, got {wal_spec:?}")
        })?;
        if args.get("snapshot").is_none() {
            bail!("--wal {wal_spec} needs --snapshot <path> (the WAL lives beside it)");
        }
        Some(policy)
    };
    // The anchor is the `--snapshot` path **verbatim**: compactions
    // persist the next generation over this same path (atomic rename),
    // so restarting with the same flag always finds the matching
    // `<anchor>.wal.g<N>` log.
    let wal_anchor = args.get("snapshot").map(std::path::PathBuf::from);
    // Serving limits: `--read-timeout-ms <n>` (0 = never time out),
    // `--max-request-kb <n>`, `--queue-cap <n>` (mutation/control queue
    // depth before `err=busy` shedding).
    let read_timeout_ms = args.parse_or::<u64>("read-timeout-ms", 0);
    let max_request_kb = args.parse_or::<usize>("max-request-kb", 1024);
    if max_request_kb == 0 {
        bail!("--max-request-kb must be >= 1");
    }
    let queue_cap = match args.get("queue-cap") {
        Some(v) => {
            Some(v.parse::<usize>().context("--queue-cap must be a non-negative integer")?)
        }
        None => None,
    };

    let factory_index = index.clone();
    let factory = move || {
        let mut engine = NnEngine::from_index(factory_index);
        engine.set_auto_compact(auto_compact);
        match backend {
            BackendKind::None => eprintln!("batch prefilter: disabled (scalar per query)"),
            BackendKind::Native => {
                engine.attach_native();
                eprintln!("batch prefilter: native");
            }
            BackendKind::Pjrt => attach_pjrt(&mut engine, max_batch),
        }
        if let Some(policy) = wal_policy {
            let anchor = wal_anchor.as_deref().expect("--wal implies --snapshot");
            // Startup-fatal on purpose: serving without the durability
            // the operator asked for would silently lose mutations.
            let info = engine
                .enable_wal(anchor, policy)
                .unwrap_or_else(|e| panic!("wal startup: {e:#}"));
            eprintln!(
                "wal: {} replayed {} record(s) ({} byte(s){}), fsync={policy}",
                dtw_bounds::live::wal::wal_path(anchor, engine.generation()).display(),
                info.records,
                info.valid_bytes,
                if info.truncated { ", torn tail repaired" } else { "" },
            );
        }
        engine
    };
    let router = Arc::new(Router::spawn(factory, max_batch));
    if let Some(cap) = queue_cap {
        router.set_queue_cap(cap);
    }
    let addr = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| args.str_or("addr", "127.0.0.1:7878"));
    let opts = dtw_bounds::coordinator::ServerOptions {
        default_k,
        read_timeout: (read_timeout_ms > 0)
            .then(|| std::time::Duration::from_millis(read_timeout_ms)),
        max_request: max_request_kb * 1024,
    };
    let server =
        dtw_bounds::coordinator::server::Server::spawn_with_options(&addr, router, opts)?;
    println!(
        "serving {source} (l={}, n={}, w={}, shards={}, bound={bound}, backend={backend}, \
         default k={default_k}, threads={threads}, wal={wal_spec}, \
         max-request={max_request_kb}KiB, read-timeout={}) on {}",
        index.train().series.first().map(|s| s.len()).unwrap_or(0),
        index.len(),
        index.window(),
        index.shard_count(),
        if read_timeout_ms == 0 { "off".to_string() } else { format!("{read_timeout_ms}ms") },
        server.addr()
    );
    println!(
        "protocol: one comma-separated series per line (or k=<n>;series for k-NN); \
         save=<path>;/load=<path>; generational snapshot control; \
         insert=<label>;series / delete=<id>; / compact=; / gens=; live mutation; \
         stats=; counters; PING/PONG; Ctrl-C to stop"
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Attach the PJRT backend (feature `pjrt`): load the best-fitting AOT
/// artifact and hand the engine the compiled executable.
#[cfg(feature = "pjrt")]
fn attach_pjrt(engine: &mut NnEngine, max_batch: usize) {
    let artifacts = default_artifacts_dir();
    if !artifacts.join("manifest.tsv").exists() {
        eprintln!("batch prefilter: no artifacts (run `make artifacts`); scalar only");
        return;
    }
    match dtw_bounds::runtime::XlaRuntime::cpu() {
        Ok(rt) => {
            match engine.attach_batch_lb(&rt, &artifacts, max_batch) {
                Ok(()) => eprintln!("batch prefilter: pjrt"),
                Err(e) => eprintln!("batch prefilter: unavailable ({e:#})"),
            }
            // The client must outlive executions; it lives as long as the
            // dispatch thread (whole process).
            std::mem::forget(rt);
        }
        Err(e) => eprintln!("PJRT unavailable ({e:#}); scalar only"),
    }
}

/// Without the feature the PJRT backend cannot exist; fall back loudly.
#[cfg(not(feature = "pjrt"))]
fn attach_pjrt(_engine: &mut NnEngine, _max_batch: usize) {
    eprintln!(
        "batch prefilter: pjrt requested but this build lacks the `pjrt` feature \
         (rebuild with --features pjrt); scalar only"
    );
}

fn cmd_info() -> Result<()> {
    println!("dtw-bounds {}", dtw_bounds::VERSION);
    println!(
        "simd: {} (available: {}; override with DTW_FORCE_ISA=scalar|sse2|avx2|neon)",
        dtw_bounds::simd::isa_name(),
        dtw_bounds::simd::available()
            .iter()
            .map(|i| i.name())
            .collect::<Vec<_>>()
            .join(", ")
    );
    if cfg!(feature = "pjrt") {
        println!("backends: native (default), pjrt");
    } else {
        println!("backends: native (default); build with --features pjrt for the XLA backend");
    }
    #[cfg(feature = "pjrt")]
    match dtw_bounds::runtime::XlaRuntime::cpu() {
        Ok(rt) => println!("PJRT: ok, platform = {}", rt.platform()),
        Err(e) => println!("PJRT: unavailable ({e:#})"),
    }
    let dir = default_artifacts_dir();
    match read_manifest(&dir) {
        Ok(m) => {
            println!("artifacts ({}):", dir.display());
            for e in m {
                println!("  {} b={} n={} l={} ({})", e.name, e.batch, e.rows, e.len, e.file);
            }
        }
        Err(_) => println!("artifacts: none (run `make artifacts`)"),
    }
    println!("bounds: {}", BoundKind::ALL.iter().map(|b| b.name()).collect::<Vec<_>>().join(", "));
    Ok(())
}
