//! Compaction: fold base survivors + delta entries into a fresh,
//! fully-aligned frozen index one generation up.
//!
//! Compaction is **rebuild-by-construction**: it feeds the logical
//! series set (base survivors in physical order, then delta entries in
//! append order — exactly the live id space) through the ordinary
//! [`DtwIndexBuilder`](crate::index::DtwIndexBuilder) with the base
//! index's own knobs. Same input bits + same knobs + deterministic
//! builder (seeded clustering, fixed partition arithmetic) ⇒ the
//! compacted index is **bit-identical** to a cold rebuild of the same
//! logical series set — the invariant `rust/tests/live.rs` pins.
//!
//! One wrinkle: series values are stored *as indexed*, i.e. already
//! z-normalized when the index normalizes. Re-normalizing would not be
//! bit-stable, so the rebuild runs with normalization **off** and the
//! policy flag is restored on the result's config afterwards (a cold
//! rebuild normalizes the raw series once — producing exactly the bits
//! we already store).

use anyhow::Result;

use crate::index::DtwIndex;

use super::delta::{DeltaShard, Tombstones};

/// Build the next generation: a frozen index over base survivors +
/// delta entries, with re-derived shard stores and clusters, stamped
/// `generation = old + 1`, `parent = old`. The input index is untouched
/// — callers swap atomically once the build succeeds.
pub fn compacted(
    index: &DtwIndex,
    delta: &DeltaShard,
    tombstones: &Tombstones,
) -> Result<DtwIndex> {
    let train = index.train();
    let survivors = train.len() - tombstones.len();
    let mut values = Vec::with_capacity(survivors + delta.len());
    let mut labels = Vec::with_capacity(survivors + delta.len());
    for (i, s) in train.series.iter().enumerate() {
        if tombstones.contains(i) {
            continue;
        }
        values.push(s.values.clone());
        labels.push(train.labels[i]);
    }
    for e in delta.entries() {
        values.push(e.series.values.clone());
        labels.push(e.label);
    }
    let cfg = &index.config;
    let mut out = DtwIndex::builder(values)
        .labels(labels)
        .window(index.window())
        .bound(cfg.bound)
        .strategy(cfg.strategy)
        .backend(cfg.backend)
        .max_batch(cfg.max_batch)
        // Values are already as-indexed; see the module docs.
        .znormalize(false)
        .seed(cfg.seed)
        .threads(cfg.threads)
        .shards(index.shard_count().max(1))
        .clusters(cfg.clusters)
        .build()?;
    out.config.znorm = cfg.znorm;
    out.config.generation = cfg.generation + 1;
    out.config.parent = cfg.generation;
    Ok(out)
}
