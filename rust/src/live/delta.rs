//! The write-absorbing side of a live index: the unsorted append log
//! ([`DeltaShard`]) and the base-index tombstone set ([`Tombstones`]),
//! plus the logical↔physical id arithmetic both share.
//!
//! ## Logical ids
//!
//! A live index presents one flat, gap-free id space — exactly the ids
//! a cold rebuild over the same logical series set would assign:
//!
//! * ids `0..survivors` are the **base survivors** (frozen-index series
//!   minus tombstones), in base physical order;
//! * ids `survivors..survivors + delta_len` are the **delta entries**,
//!   in append order.
//!
//! Both maps are strictly monotone, which is what keeps `(distance,
//! id)` tie-breaking identical between a live search (physical ids
//! remapped at the end) and a cold rebuild (logical ids throughout):
//! comparing remapped ids orders candidate pairs exactly as comparing
//! the physical ids did.

use crate::bounds::PreparedSeries;

/// One appended series: its label plus the prepared envelopes (computed
/// once at insert, exactly as the index builder prepares its series).
#[derive(Debug, Clone)]
pub struct DeltaEntry {
    /// The series label.
    pub label: u32,
    /// The prepared series (values stored **as indexed** — normalized
    /// already when the index z-normalizes).
    pub series: PreparedSeries,
}

/// The delta shard: a small unsorted append log scanned exactly on
/// every search path. Below the compaction threshold it carries no
/// `EnvelopeStore`, no clusters and no sort order — a plain
/// per-candidate LB-then-DTW sweep is cheaper than maintaining any of
/// that for a handful of entries.
#[derive(Debug, Clone, Default)]
pub struct DeltaShard {
    entries: Vec<DeltaEntry>,
}

impl DeltaShard {
    /// An empty delta shard.
    pub fn new() -> DeltaShard {
        DeltaShard::default()
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing has been appended (or everything appended was
    /// deleted again).
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Append one prepared series; returns its delta offset.
    pub fn push(&mut self, label: u32, series: PreparedSeries) -> usize {
        self.entries.push(DeltaEntry { label, series });
        self.entries.len() - 1
    }

    /// Remove the entry at delta offset `i`, shifting later entries
    /// down (logical ids above it decrease by one — the same compaction
    /// of the id space a cold rebuild without the series would show).
    pub fn remove(&mut self, i: usize) -> DeltaEntry {
        self.entries.remove(i)
    }

    /// The entries, in append order.
    pub fn entries(&self) -> &[DeltaEntry] {
        &self.entries
    }

    /// Drop every entry (post-compaction reset).
    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

/// The base-index tombstone set: physical indices of frozen-shard
/// series that are logically deleted. Kept as a sorted vector — the
/// live sets are small (compaction folds them away), and sortedness
/// gives `O(log n)` rank/select for the logical id maps.
#[derive(Debug, Clone, Default)]
pub struct Tombstones {
    /// Sorted ascending, no duplicates.
    dead: Vec<usize>,
}

impl Tombstones {
    /// An empty tombstone set.
    pub fn new() -> Tombstones {
        Tombstones::default()
    }

    /// Number of tombstoned base series.
    pub fn len(&self) -> usize {
        self.dead.len()
    }

    /// True when no base series is tombstoned.
    pub fn is_empty(&self) -> bool {
        self.dead.is_empty()
    }

    /// Tombstone base physical index `phys`; returns `false` when it
    /// already was.
    pub fn insert(&mut self, phys: usize) -> bool {
        match self.dead.binary_search(&phys) {
            Ok(_) => false,
            Err(at) => {
                self.dead.insert(at, phys);
                true
            }
        }
    }

    /// True when base physical index `phys` is tombstoned.
    pub fn contains(&self, phys: usize) -> bool {
        self.dead.binary_search(&phys).is_ok()
    }

    /// Number of tombstones strictly below `phys` — the rank shift that
    /// turns a surviving physical index into its logical id.
    pub fn count_before(&self, phys: usize) -> usize {
        self.dead.partition_point(|&d| d < phys)
    }

    /// Logical id of a **surviving** base physical index.
    pub fn to_logical(&self, phys: usize) -> usize {
        debug_assert!(!self.contains(phys), "tombstoned series have no logical id");
        phys - self.count_before(phys)
    }

    /// Base physical index of logical id `logical` (which must be below
    /// the survivor count): the `logical`-th non-tombstoned index.
    pub fn to_physical(&self, logical: usize) -> usize {
        let mut phys = logical;
        for &d in &self.dead {
            if d <= phys {
                phys += 1;
            } else {
                break;
            }
        }
        phys
    }

    /// Dense skip mask over `0..n` (`true` = tombstoned) — the shape
    /// the stream searcher's per-window sweep wants.
    pub fn dead_mask(&self, n: usize) -> Vec<bool> {
        let mut mask = vec![false; n];
        for &d in &self.dead {
            if d < n {
                mask[d] = true;
            }
        }
        mask
    }

    /// The tombstoned physical indices, sorted ascending.
    pub fn as_slice(&self) -> &[usize] {
        &self.dead
    }

    /// Drop every tombstone (post-compaction reset).
    pub fn clear(&mut self) {
        self.dead.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tombstone_rank_select_round_trip() {
        let mut t = Tombstones::new();
        assert!(t.insert(3));
        assert!(t.insert(1));
        assert!(!t.insert(3), "duplicate insert is a no-op");
        assert_eq!(t.as_slice(), &[1, 3]);
        // Base 0..5, dead {1,3}: survivors are physical 0, 2, 4.
        assert_eq!(t.to_physical(0), 0);
        assert_eq!(t.to_physical(1), 2);
        assert_eq!(t.to_physical(2), 4);
        for logical in 0..3 {
            let phys = t.to_physical(logical);
            assert!(!t.contains(phys));
            assert_eq!(t.to_logical(phys), logical);
        }
        assert_eq!(t.dead_mask(5), vec![false, true, false, true, false]);
    }

    #[test]
    fn delta_ids_shift_on_remove() {
        let mut d = DeltaShard::new();
        let s = |v: f64| PreparedSeries::prepare(vec![v, v, v, v], 1);
        assert_eq!(d.push(10, s(0.0)), 0);
        assert_eq!(d.push(11, s(1.0)), 1);
        assert_eq!(d.push(12, s(2.0)), 2);
        let gone = d.remove(1);
        assert_eq!(gone.label, 11);
        assert_eq!(d.len(), 2);
        assert_eq!(d.entries()[1].label, 12, "later entries shift down");
    }
}
