//! Live mutation: turn the frozen [`DtwIndex`] into a **mutable,
//! multi-generation** structure — inserts and deletes served exactly,
//! background compaction, generational snapshots — while every search
//! path stays exact and bit-deterministic.
//!
//! ## Shape
//!
//! A live index is three parts, owned together by [`LiveState`] next to
//! the frozen base:
//!
//! * **Base** — the ordinary frozen [`DtwIndex`] (shard stores,
//!   clusters, batched prefilter): never mutated in place.
//! * **Delta shard** ([`DeltaShard`]) — a small unsorted append log
//!   absorbing inserts. It is scanned *exactly* on every search path
//!   with the plain per-candidate bound-then-DTW cascade; below the
//!   compaction threshold that beats maintaining flat stores or
//!   clusters for a handful of entries.
//! * **Tombstones** ([`Tombstones`]) — deleted base series by physical
//!   index. Kernels never see them: the live query over-asks the base
//!   (`k + |T|`), drops tombstoned hits, and remaps survivors to the
//!   gap-free logical id space (see [`self::delta`] and
//!   `live/search.rs` for the exactness argument).
//!
//! **Compaction** ([`compacted`]) folds everything into a fresh frozen
//! index one generation up, bit-identical to a cold rebuild of the same
//! logical series set; callers (the engine) build it aside and swap
//! atomically, so concurrent readers only ever observe a fully-built
//! generation. **Generations** ride snapshot v3: each compaction bumps
//! `generation` and records its `parent`, `save=` auto-versions file
//! names ([`crate::index::snapshot::generation_path`]), and `load=` of
//! an older file is rollback.
//!
//! ## The exactness contract
//!
//! After *any* interleaving of `insert` / `delete` / `compact`, every
//! search path — scalar k-NN, the batched prefilter, the streaming
//! subsequence sweep — returns results **bit-identical** to a cold
//! rebuild over the same logical series set (`rust/tests/live.rs` pins
//! this across shard, cluster and thread grids).

pub mod compact;
pub mod delta;
mod search;
pub mod wal;

pub use compact::compacted;
pub use delta::{DeltaEntry, DeltaShard, Tombstones};
pub use wal::{FsyncPolicy, ReplayInfo, Wal, WalOp};

use anyhow::{bail, Result};

use crate::bounds::{PreparedSeries, Scratch};
use crate::data::znorm::znormalized;
use crate::delta::Delta;
use crate::index::{DtwIndex, QueryOptions, QueryOutcome, Searcher};

/// The mutable half of a live index: the delta shard and tombstone set,
/// plus the owned scratch the delta scan runs on. Lives next to the
/// frozen base (typically inside `NnEngine`); the base itself is only
/// ever *replaced* (by compaction or snapshot load), never mutated.
#[derive(Debug, Default)]
pub struct LiveState {
    delta: DeltaShard,
    tombstones: Tombstones,
    /// Scratch for the delta scan's bound evaluations — the live path
    /// cannot borrow the searcher's own scratch (private, and mutably
    /// held by the base query), so it owns one sized on demand.
    scratch: Scratch,
    /// Series length `scratch` was sized for (0 = unsized).
    scratch_len: usize,
}

impl LiveState {
    /// A clean live state (no pending mutations).
    pub fn new() -> LiveState {
        LiveState::default()
    }

    /// True when any mutation is pending — the signal to route searches
    /// through the live overlay instead of the plain frozen path.
    pub fn is_dirty(&self) -> bool {
        !self.delta.is_empty() || !self.tombstones.is_empty()
    }

    /// Pending inserts (delta-shard length).
    pub fn delta_len(&self) -> usize {
        self.delta.len()
    }

    /// Pending base deletes (tombstone count).
    pub fn tombstone_count(&self) -> usize {
        self.tombstones.len()
    }

    /// The delta shard (stream-overlay and compaction input).
    pub fn delta(&self) -> &DeltaShard {
        &self.delta
    }

    /// The tombstone set (stream-overlay and compaction input).
    pub fn tombstones(&self) -> &Tombstones {
        &self.tombstones
    }

    /// Surviving base series under `base`.
    pub fn survivors(&self, base: &DtwIndex) -> usize {
        base.len() - self.tombstones.len()
    }

    /// Logical series count: base survivors + delta entries.
    pub fn logical_len(&self, base: &DtwIndex) -> usize {
        self.survivors(base) + self.delta.len()
    }

    /// The series length this live index accepts: the base's when it
    /// holds anything, else the first delta entry's, else `None` (the
    /// next insert fixes it).
    pub fn series_len(&self, base: &DtwIndex) -> Option<usize> {
        base.train()
            .series
            .first()
            .map(|s| s.len())
            .or_else(|| self.delta.entries().first().map(|e| e.series.len()))
    }

    /// Check whether an insert of `values` would be accepted, without
    /// mutating anything. The write-ahead-log flow depends on this
    /// split: the engine validates first, logs the mutation, then
    /// applies it — after `validate_insert` passes, [`LiveState::insert`]
    /// cannot fail, so a logged record is always replayable.
    pub fn validate_insert(&self, base: &DtwIndex, values: &[f64]) -> Result<()> {
        if values.is_empty() {
            bail!("cannot insert an empty series");
        }
        if let Some(l) = self.series_len(base) {
            if values.len() != l {
                bail!(
                    "inserted series has length {}, expected {l} (bounds assume one shared length)",
                    values.len()
                );
            }
        }
        Ok(())
    }

    /// Check whether logical id `id` is deletable right now (same
    /// validate-then-log-then-apply contract as
    /// [`LiveState::validate_insert`]).
    pub fn validate_delete(&self, base: &DtwIndex, id: usize) -> Result<()> {
        if id >= self.logical_len(base) {
            bail!("delete: no series with logical id {id} ({} live)", self.logical_len(base));
        }
        Ok(())
    }

    /// Append one series; returns its logical id. The series is
    /// z-normalized here iff the base's policy says so — exactly the
    /// one normalization a cold rebuild would apply — and its envelopes
    /// are prepared once, under the base's window.
    pub fn insert(&mut self, base: &DtwIndex, label: u32, values: Vec<f64>) -> Result<usize> {
        self.validate_insert(base, &values)?;
        let values = if base.znormalizes() { znormalized(&values) } else { values };
        let prepared = PreparedSeries::prepare(values, base.window());
        let offset = self.delta.push(label, prepared);
        Ok(self.survivors(base) + offset)
    }

    /// Delete logical id `id`: tombstone a base survivor, or drop a
    /// delta entry (later delta ids shift down by one, exactly as a
    /// cold rebuild without the series would number them).
    pub fn delete(&mut self, base: &DtwIndex, id: usize) -> Result<()> {
        self.validate_delete(base, id)?;
        let survivors = self.survivors(base);
        if id < survivors {
            let phys = self.tombstones.to_physical(id);
            self.tombstones.insert(phys);
            return Ok(());
        }
        self.delta.remove(id - survivors);
        Ok(())
    }

    /// Reset to clean (after compaction folded the state into a new
    /// base, or a snapshot load replaced the base wholesale).
    pub fn clear(&mut self) {
        self.delta.clear();
        self.tombstones.clear();
    }

    fn ensure_scratch(&mut self, l: usize) {
        if self.scratch_len < l {
            self.scratch = Scratch::new(l);
            self.scratch_len = l;
        }
    }

    /// One exact k-NN query over the live index. Clean state routes
    /// straight to the frozen path (same bits, no overhead).
    pub fn query<D: Delta>(
        &mut self,
        searcher: &mut Searcher,
        values: &[f64],
        opts: &QueryOptions,
    ) -> QueryOutcome {
        if !self.is_dirty() {
            return searcher.query_values::<D>(values, opts);
        }
        let l = self.series_len(searcher.index()).unwrap_or(values.len());
        self.ensure_scratch(l);
        search::live_query::<D>(
            searcher,
            &self.delta,
            &self.tombstones,
            &mut self.scratch,
            values,
            opts,
        )
    }

    /// A batch of exact k-NN queries over the live index (rides the
    /// base's batched prefilter when profitable).
    pub fn query_batch<D: Delta>(
        &mut self,
        searcher: &mut Searcher,
        items: &[(Vec<f64>, QueryOptions)],
    ) -> Vec<QueryOutcome> {
        if !self.is_dirty() {
            return searcher.query_batch_mixed::<D>(items);
        }
        let l = self
            .series_len(searcher.index())
            .or_else(|| items.first().map(|(v, _)| v.len()))
            .unwrap_or(0);
        self.ensure_scratch(l);
        search::live_query_batch::<D>(
            searcher,
            &self.delta,
            &self.tombstones,
            &mut self.scratch,
            items,
        )
    }

    /// Compact: fold this state over `base` into the next generation
    /// (see [`compacted`]). On success the returned index replaces the
    /// base *and this state is reset* — the caller must install the new
    /// index before serving further queries.
    pub fn compact(&mut self, base: &DtwIndex) -> Result<DtwIndex> {
        let next = compacted(base, &self.delta, &self.tombstones)?;
        self.clear();
        Ok(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::Squared;

    fn base_index() -> DtwIndex {
        let series = vec![
            vec![0.0, 0.1, 0.4, 0.2, 0.0, -0.2],
            vec![1.0, 0.9, 0.8, 0.9, 1.1, 1.0],
            vec![0.0, 0.5, 1.0, 0.5, 0.0, -0.5],
            vec![-1.0, -0.9, -0.7, -0.9, -1.0, -1.1],
        ];
        DtwIndex::builder(series).labels(vec![0, 1, 0, 2]).window(1).build().unwrap()
    }

    #[test]
    fn insert_validates_length_and_assigns_logical_ids() {
        let base = base_index();
        let mut live = LiveState::new();
        assert!(live.insert(&base, 9, vec![1.0, 2.0]).is_err(), "length mismatch");
        let id = live.insert(&base, 9, vec![0.0, 0.0, 0.1, 0.2, 0.1, 0.0]).unwrap();
        assert_eq!(id, 4, "first delta entry follows the base survivors");
        assert_eq!(live.logical_len(&base), 5);
        live.delete(&base, 1).unwrap();
        let id2 = live.insert(&base, 10, vec![0.5; 6]).unwrap();
        assert_eq!(id2, 4, "a tombstone shifts the delta id space down");
        assert_eq!(live.logical_len(&base), 5);
        assert!(live.delete(&base, 5).is_err(), "out of range after remap");
    }

    #[test]
    fn clean_state_is_a_passthrough() {
        let base = base_index();
        let mut live = LiveState::new();
        let mut s = base.searcher();
        let q = vec![0.0, 0.2, 0.5, 0.2, 0.0, -0.3];
        let a = live.query::<Squared>(&mut s, &q, &QueryOptions::k(2));
        let b = base.knn::<Squared>(&q, 2);
        assert_eq!(a.distances(), b.distances());
        assert_eq!(a.stats.delta_scanned, 0);
    }

    #[test]
    fn live_query_matches_cold_rebuild_after_mutations() {
        let base = base_index();
        let mut live = LiveState::new();
        live.delete(&base, 1).unwrap();
        live.insert(&base, 7, vec![0.9, 1.0, 1.1, 1.0, 0.9, 1.0]).unwrap();
        live.insert(&base, 8, vec![-0.2, 0.0, 0.2, 0.0, -0.2, 0.0]).unwrap();

        // Cold rebuild over the logical series set.
        let cold = DtwIndex::builder(vec![
            vec![0.0, 0.1, 0.4, 0.2, 0.0, -0.2],
            vec![0.0, 0.5, 1.0, 0.5, 0.0, -0.5],
            vec![-1.0, -0.9, -0.7, -0.9, -1.0, -1.1],
            vec![0.9, 1.0, 1.1, 1.0, 0.9, 1.0],
            vec![-0.2, 0.0, 0.2, 0.0, -0.2, 0.0],
        ])
        .labels(vec![0, 0, 2, 7, 8])
        .window(1)
        .build()
        .unwrap();

        let mut s = base.searcher();
        for q in [
            vec![0.0, 0.2, 0.5, 0.2, 0.0, -0.3],
            vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0],
            vec![-0.1, 0.0, 0.1, 0.0, -0.1, 0.0],
        ] {
            for k in [1usize, 3, 5] {
                let a = live.query::<Squared>(&mut s, &q, &QueryOptions::k(k));
                let b = cold.knn::<Squared>(&q, k);
                let pair = |o: &QueryOutcome| -> Vec<(usize, f64, u32)> {
                    o.neighbors.iter().map(|n| (n.index, n.distance, n.label)).collect()
                };
                assert_eq!(pair(&a), pair(&b), "k={k}");
            }
        }
    }

    #[test]
    fn compaction_resets_state_and_bumps_generation() {
        let base = base_index();
        let mut live = LiveState::new();
        live.delete(&base, 0).unwrap();
        live.insert(&base, 5, vec![0.1; 6]).unwrap();
        let next = live.compact(&base).unwrap();
        assert!(!live.is_dirty());
        assert_eq!(next.len(), 4);
        assert_eq!(next.generation(), 1);
        assert_eq!(next.parent(), 0);
        assert_eq!(next.train().labels, vec![1, 0, 2, 5]);
    }
}
