//! The live index's write-ahead log: crash durability for acked
//! mutations.
//!
//! PR 7's delta shard made the index mutable but volatile — an
//! `insert=`/`delete=` acked over the wire lived only in memory until
//! the next compaction. The WAL closes that hole: every accepted
//! mutation is appended (and, per [`FsyncPolicy`], fsynced) to
//! `<snapshot>.wal.g<N>` **before** the ack leaves the engine, and
//! startup replays the log through the exact same [`LiveState`]
//! mutation path the live request took — so recovery is bit-equal to
//! an uninterrupted run by construction.
//!
//! ## Record format (all integers little-endian)
//!
//! ```text
//! offset size  field
//!      0    4  payload length in bytes (u32, >= 1)
//!      4    8  FNV-1a-64 checksum of the payload (u64)
//!     12    …  payload:
//!              tag(u8) = 1 insert | 2 delete
//!              insert: label(u32) · count(u64) · count × f64 raw bits
//!              delete: logical id(u64)
//! ```
//!
//! Values are stored as **raw f64 bits**, so replaying an insert
//! prepares envelopes from exactly the bytes the live insert prepared
//! them from — the bit-equality contract extends through a crash.
//!
//! ## Torn tails
//!
//! A crash mid-append can leave a torn record at the end of the log
//! (short header, short payload, or a payload whose checksum does not
//! match). Replay **truncates at the first invalid record and never
//! errors**: everything before the tear was acked against a complete
//! fsync'd (or at least fully buffered) record, everything at the tear
//! was never acked — by the append-before-ack ordering, dropping it is
//! exactly the pre-operation state. [`replay_bytes`] is the pure
//! decision procedure; its table of torn shapes is pinned in the unit
//! tests below.
//!
//! ## Generations and rotation
//!
//! The log file name carries the generation of the base snapshot it
//! applies to ([`wal_path`]: `<base>.wal.g<N>`). Compaction and
//! snapshot hot-swaps rotate the log (see
//! [`NnEngine`](crate::coordinator::NnEngine)): the new base is
//! persisted over the anchor path first (atomic tmp+fsync+rename), a
//! fresh `.wal.g<N+1>` is created, and only then is the old log
//! removed — at every intermediate crash point the anchor's stored
//! generation selects the one log that matches it, so a stale log can
//! never replay into the wrong base.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::index::snapshot::fnv1a64;
use crate::io::{FileOps, WriteFile};

/// Record tags.
const TAG_INSERT: u8 = 1;
const TAG_DELETE: u8 = 2;

/// Header bytes per record: payload length (u32) + checksum (u64).
pub const RECORD_HEADER: usize = 12;

/// When appends reach the platter relative to the ack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// fsync every record before acking — an acked mutation survives
    /// power loss (the durability the CI kill-9 smoke pins).
    Always,
    /// fsync every n records — bounded loss window, amortized cost.
    EveryN(usize),
    /// Never fsync from the engine — the OS flushes eventually; an
    /// acked mutation survives process death (the kernel holds the
    /// bytes) but not power loss.
    Never,
}

impl FsyncPolicy {
    /// Parse the CLI spelling: `always`, `never`, or `every:<n>`.
    pub fn parse(text: &str) -> Option<FsyncPolicy> {
        match text {
            "always" => Some(FsyncPolicy::Always),
            "never" => Some(FsyncPolicy::Never),
            _ => {
                let n = text.strip_prefix("every:")?.parse::<usize>().ok()?;
                if n == 0 {
                    None
                } else {
                    Some(FsyncPolicy::EveryN(n))
                }
            }
        }
    }
}

impl std::fmt::Display for FsyncPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FsyncPolicy::Always => write!(f, "always"),
            FsyncPolicy::EveryN(n) => write!(f, "every:{n}"),
            FsyncPolicy::Never => write!(f, "never"),
        }
    }
}

/// One logged mutation, decoded (the replay shape).
#[derive(Debug, Clone, PartialEq)]
pub enum WalOp {
    /// A validated insert: label + the exact values that were accepted.
    Insert {
        /// Class label of the inserted series.
        label: u32,
        /// The accepted values (pre-normalization — replay re-runs the
        /// same normalization the live path ran).
        values: Vec<f64>,
    },
    /// A validated delete of one logical id.
    Delete {
        /// Logical id at the time the delete was accepted.
        id: u64,
    },
}

/// What replay found in a log file.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ReplayInfo {
    /// Complete, checksum-valid records decoded.
    pub records: u64,
    /// Bytes covered by those records (the valid prefix).
    pub valid_bytes: u64,
    /// Total bytes in the file.
    pub total_bytes: u64,
    /// True when a torn/invalid tail was dropped.
    pub truncated: bool,
}

/// The WAL file for one generation: `<base>.wal.g<N>`. Sibling of the
/// generation-snapshot naming
/// ([`generation_path`](crate::index::snapshot::generation_path)).
pub fn wal_path(base: &Path, generation: u64) -> PathBuf {
    let mut name = base.as_os_str().to_owned();
    name.push(format!(".wal.g{generation}"));
    PathBuf::from(name)
}

/// Encode one record (header + payload) into a fresh buffer.
fn encode_record(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(RECORD_HEADER + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a64(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

fn encode_insert(label: u32, values: &[f64]) -> Vec<u8> {
    let mut payload = Vec::with_capacity(1 + 4 + 8 + values.len() * 8);
    payload.push(TAG_INSERT);
    payload.extend_from_slice(&label.to_le_bytes());
    payload.extend_from_slice(&(values.len() as u64).to_le_bytes());
    for &v in values {
        payload.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    encode_record(&payload)
}

fn encode_delete(id: u64) -> Vec<u8> {
    let mut payload = Vec::with_capacity(9);
    payload.push(TAG_DELETE);
    payload.extend_from_slice(&id.to_le_bytes());
    encode_record(&payload)
}

/// Decode one payload; `None` = malformed (treated as a torn tail).
fn decode_payload(payload: &[u8]) -> Option<WalOp> {
    let (&tag, rest) = payload.split_first()?;
    match tag {
        TAG_INSERT => {
            if rest.len() < 12 {
                return None;
            }
            let label = u32::from_le_bytes(rest[0..4].try_into().ok()?);
            let count = u64::from_le_bytes(rest[4..12].try_into().ok()?);
            let count = usize::try_from(count).ok()?;
            let values_bytes = rest.len() - 12;
            if count.checked_mul(8)? != values_bytes {
                return None;
            }
            let mut values = Vec::with_capacity(count);
            for chunk in rest[12..].chunks_exact(8) {
                values.push(f64::from_bits(u64::from_le_bytes(
                    chunk.try_into().expect("8-byte chunk"),
                )));
            }
            Some(WalOp::Insert { label, values })
        }
        TAG_DELETE => {
            if rest.len() != 8 {
                return None;
            }
            Some(WalOp::Delete { id: u64::from_le_bytes(rest.try_into().ok()?) })
        }
        _ => None,
    }
}

/// Replay a log image: decode records until the bytes run out or the
/// first invalid record (short header, zero-length payload, short
/// payload, checksum mismatch, unknown tag, malformed shape). **Never
/// errors** — an invalid tail marks the log truncated there; by the
/// append-before-ack ordering nothing past the valid prefix was ever
/// acked.
pub fn replay_bytes(bytes: &[u8]) -> (Vec<WalOp>, ReplayInfo) {
    let mut ops = Vec::new();
    let mut info =
        ReplayInfo { records: 0, valid_bytes: 0, total_bytes: bytes.len() as u64, truncated: false };
    let mut at = 0usize;
    while at < bytes.len() {
        let rest = &bytes[at..];
        if rest.len() < RECORD_HEADER {
            info.truncated = true; // torn header
            break;
        }
        let len = u32::from_le_bytes(rest[0..4].try_into().expect("4 bytes")) as usize;
        if len == 0 || rest.len() - RECORD_HEADER < len {
            info.truncated = true; // zero-length or torn payload
            break;
        }
        let stored = u64::from_le_bytes(rest[4..12].try_into().expect("8 bytes"));
        let payload = &rest[RECORD_HEADER..RECORD_HEADER + len];
        if fnv1a64(payload) != stored {
            info.truncated = true; // bit rot or a torn overwrite
            break;
        }
        match decode_payload(payload) {
            Some(op) => ops.push(op),
            None => {
                info.truncated = true; // valid checksum, malformed shape
                break;
            }
        }
        at += RECORD_HEADER + len;
        info.records += 1;
        info.valid_bytes = at as u64;
    }
    (ops, info)
}

/// An open, appendable write-ahead log for one `(anchor, generation)`.
pub struct Wal {
    fs: Arc<dyn FileOps>,
    path: PathBuf,
    file: Box<dyn WriteFile>,
    policy: FsyncPolicy,
    /// Records appended since the last fsync (the `EveryN` counter).
    since_sync: usize,
    /// Records in the log (replayed + appended).
    records: u64,
}

impl std::fmt::Debug for Wal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Wal")
            .field("path", &self.path)
            .field("policy", &self.policy)
            .field("records", &self.records)
            .finish()
    }
}

impl Wal {
    /// Create a **fresh, empty** log for `(base, generation)`,
    /// truncating any stale file at that path, and pin its (empty)
    /// content durably. The rotation entry point.
    pub fn create(
        fs: Arc<dyn FileOps>,
        base: &Path,
        generation: u64,
        policy: FsyncPolicy,
    ) -> std::io::Result<Wal> {
        let path = wal_path(base, generation);
        let mut file = fs.create(&path)?;
        file.sync()?;
        Ok(Wal { fs, path, file, policy, since_sync: 0, records: 0 })
    }

    /// Open the log for `(base, generation)` for recovery: read it
    /// (missing = empty), decode the valid prefix, and return the
    /// decoded ops alongside an appendable handle. When a torn tail was
    /// dropped, the valid prefix is first rewritten through a sibling
    /// `.tmp` + atomic rename (the snapshot-save discipline) so the
    /// on-disk log holds only complete records before new appends land
    /// after them.
    pub fn recover(
        fs: Arc<dyn FileOps>,
        base: &Path,
        generation: u64,
        policy: FsyncPolicy,
    ) -> std::io::Result<(Vec<WalOp>, ReplayInfo, Wal)> {
        let path = wal_path(base, generation);
        let bytes = match fs.read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e),
        };
        let (ops, info) = replay_bytes(&bytes);
        if info.truncated {
            // Drop the torn tail atomically: never truncate the live
            // log in place (a crash mid-rewrite must leave either the
            // old log — same valid prefix — or the clean one).
            let mut tmp_name = path.as_os_str().to_owned();
            tmp_name.push(".tmp");
            let tmp = PathBuf::from(tmp_name);
            let mut f = fs.create(&tmp)?;
            f.write(&bytes[..info.valid_bytes as usize])?;
            f.sync()?;
            drop(f);
            fs.rename(&tmp, &path)?;
        }
        let file = fs.open_append(&path)?;
        let wal = Wal { fs, path, file, policy, since_sync: 0, records: info.records };
        Ok((ops, info, wal))
    }

    /// The log's file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Records in the log (the `wal_records` gauge).
    pub fn records(&self) -> u64 {
        self.records
    }

    /// The shared file-ops handle (for rotation by the owner).
    pub fn fs(&self) -> Arc<dyn FileOps> {
        self.fs.clone()
    }

    /// Append one insert record — called **after** validation and
    /// **before** the mutation is applied or acked. On `Ok`, the record
    /// is complete in the file (and fsync'd per policy); on `Err`
    /// nothing was applied and at worst a torn tail remains, which
    /// replay drops.
    pub fn append_insert(&mut self, label: u32, values: &[f64]) -> std::io::Result<()> {
        self.append_record(encode_insert(label, values))
    }

    /// Append one delete record (same contract as [`Wal::append_insert`]).
    pub fn append_delete(&mut self, id: u64) -> std::io::Result<()> {
        self.append_record(encode_delete(id))
    }

    fn append_record(&mut self, record: Vec<u8>) -> std::io::Result<()> {
        self.file.write(&record)?;
        self.records += 1;
        self.since_sync += 1;
        let due = match self.policy {
            FsyncPolicy::Always => true,
            FsyncPolicy::EveryN(n) => self.since_sync >= n,
            FsyncPolicy::Never => false,
        };
        if due {
            self.file.sync()?;
            self.since_sync = 0;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::FaultFs;

    fn base() -> PathBuf {
        PathBuf::from("anchor.snap")
    }

    fn sample_ops() -> Vec<WalOp> {
        vec![
            WalOp::Insert { label: 7, values: vec![0.25, -1.5, f64::MIN_POSITIVE, 3.75] },
            WalOp::Delete { id: 2 },
            WalOp::Insert { label: 0, values: vec![1.0] },
        ]
    }

    fn log_with(ops: &[WalOp]) -> Vec<u8> {
        let mut bytes = Vec::new();
        for op in ops {
            match op {
                WalOp::Insert { label, values } => {
                    bytes.extend_from_slice(&encode_insert(*label, values))
                }
                WalOp::Delete { id } => bytes.extend_from_slice(&encode_delete(*id)),
            }
        }
        bytes
    }

    #[test]
    fn wal_path_carries_the_generation() {
        assert_eq!(wal_path(&base(), 0), PathBuf::from("anchor.snap.wal.g0"));
        assert_eq!(wal_path(&base(), 17), PathBuf::from("anchor.snap.wal.g17"));
    }

    #[test]
    fn fsync_policy_parses_the_cli_spellings() {
        assert_eq!(FsyncPolicy::parse("always"), Some(FsyncPolicy::Always));
        assert_eq!(FsyncPolicy::parse("never"), Some(FsyncPolicy::Never));
        assert_eq!(FsyncPolicy::parse("every:64"), Some(FsyncPolicy::EveryN(64)));
        assert_eq!(FsyncPolicy::parse("every:0"), None, "a 0 window would never sync");
        assert_eq!(FsyncPolicy::parse("sometimes"), None);
        assert_eq!(FsyncPolicy::EveryN(8).to_string(), "every:8");
    }

    #[test]
    fn records_round_trip_exact_bits() {
        let (ops, info) = replay_bytes(&log_with(&sample_ops()));
        assert_eq!(ops, sample_ops());
        assert_eq!(info.records, 3);
        assert!(!info.truncated);
        assert_eq!(info.valid_bytes, info.total_bytes);
        // Raw-bit storage: NaN-free exact round trip incl. subnormals.
        match &ops[0] {
            WalOp::Insert { values, .. } => {
                assert_eq!(values[2].to_bits(), f64::MIN_POSITIVE.to_bits())
            }
            other => panic!("want insert, got {other:?}"),
        }
    }

    /// The torn-tail table: every invalid-tail shape truncates at the
    /// tear and keeps every record before it — replay never errors.
    #[test]
    fn torn_tails_truncate_and_never_error() {
        let good = log_with(&sample_ops());
        let good_len = good.len() as u64;

        // Clean EOF: the whole file is the valid prefix.
        let (ops, info) = replay_bytes(&good);
        assert_eq!((ops.len(), info.truncated), (3, false));

        // Empty file: zero records, not truncated (a fresh log).
        let (ops, info) = replay_bytes(b"");
        assert_eq!((ops.len(), info.records, info.truncated), (0, 0, false));

        // Half a record: header + part of the payload.
        let mut torn = good.clone();
        torn.extend_from_slice(&encode_delete(9)[..15]);
        let (ops, info) = replay_bytes(&torn);
        assert_eq!((ops.len(), info.truncated), (3, true));
        assert_eq!(info.valid_bytes, good_len);

        // Short header: fewer than 12 trailing bytes.
        let mut torn = good.clone();
        torn.extend_from_slice(&[1, 2, 3]);
        let (ops, info) = replay_bytes(&torn);
        assert_eq!((ops.len(), info.truncated), (3, true));

        // Corrupt checksum: a full record whose payload was bit-flipped.
        let mut torn = good.clone();
        let bad = encode_delete(9);
        let flip_at = torn.len() + bad.len() - 1;
        torn.extend_from_slice(&bad);
        torn[flip_at] ^= 0x40;
        let (ops, info) = replay_bytes(&torn);
        assert_eq!((ops.len(), info.truncated), (3, true));
        assert_eq!(info.valid_bytes, good_len);

        // Zero-length record: len=0 can never be a valid payload.
        let mut torn = good.clone();
        torn.extend_from_slice(&0u32.to_le_bytes());
        torn.extend_from_slice(&fnv1a64(b"").to_le_bytes());
        let (ops, info) = replay_bytes(&torn);
        assert_eq!((ops.len(), info.truncated), (3, true));

        // Valid checksum, unknown tag: malformed shape, same treatment.
        let mut torn = good.clone();
        let payload = [99u8, 1, 2];
        torn.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        torn.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
        torn.extend_from_slice(&payload);
        let (ops, info) = replay_bytes(&torn);
        assert_eq!((ops.len(), info.truncated), (3, true));

        // A tear mid-log shadows everything after it: records past the
        // first invalid byte are unreachable by design (their acks, if
        // any, preceded the tear's — impossible under append-before-ack).
        let mut torn = log_with(&sample_ops()[..1]);
        torn.extend_from_slice(&[0xFF; 5]);
        torn.extend_from_slice(&log_with(&sample_ops()[1..]));
        let (ops, info) = replay_bytes(&torn);
        assert_eq!((ops.len(), info.truncated), (1, true));
    }

    #[test]
    fn append_then_recover_round_trips() {
        let fs = FaultFs::new();
        let arc: Arc<dyn FileOps> = Arc::new(fs.clone());
        let mut wal = Wal::create(arc.clone(), &base(), 0, FsyncPolicy::Always).unwrap();
        for op in sample_ops() {
            match op {
                WalOp::Insert { label, values } => wal.append_insert(label, &values).unwrap(),
                WalOp::Delete { id } => wal.append_delete(id).unwrap(),
            }
        }
        assert_eq!(wal.records(), 3);
        drop(wal);

        let (ops, info, wal) = Wal::recover(arc, &base(), 0, FsyncPolicy::Always).unwrap();
        assert_eq!(ops, sample_ops());
        assert!(!info.truncated);
        assert_eq!(wal.records(), 3);
        // fsync=always: every record is durable — a power-loss restart
        // image replays identically.
        let disk = fs.restart(crate::io::CrashStyle::DropUnsynced);
        let bytes = disk.get(&wal_path(&base(), 0)).unwrap();
        let (ops2, _) = replay_bytes(&bytes);
        assert_eq!(ops2, sample_ops());
    }

    #[test]
    fn recover_rewrites_a_torn_tail_atomically() {
        let fs = FaultFs::new();
        let arc: Arc<dyn FileOps> = Arc::new(fs.clone());
        let mut torn = log_with(&sample_ops());
        torn.extend_from_slice(&encode_delete(4)[..13]);
        let path = wal_path(&base(), 2);
        fs.put(&path, &torn);

        let (ops, info, mut wal) =
            Wal::recover(arc, &base(), 2, FsyncPolicy::Always).unwrap();
        assert_eq!(ops, sample_ops());
        assert!(info.truncated);
        // The on-disk log now holds exactly the valid prefix…
        assert_eq!(fs.get(&path).unwrap().len() as u64, info.valid_bytes);
        // …and new appends continue cleanly after it.
        wal.append_delete(4).unwrap();
        let (ops2, info2) = replay_bytes(&fs.get(&path).unwrap());
        assert_eq!(ops2.len(), 4);
        assert!(!info2.truncated);
        assert_eq!(ops2[3], WalOp::Delete { id: 4 });
    }

    #[test]
    fn every_n_policy_syncs_on_the_window_boundary() {
        let fs = FaultFs::new();
        let arc: Arc<dyn FileOps> = Arc::new(fs.clone());
        let mut wal = Wal::create(arc, &base(), 0, FsyncPolicy::EveryN(2)).unwrap();
        let path = wal.path().to_path_buf();
        wal.append_delete(0).unwrap();
        // One record in the window: buffered, not yet durable.
        let disk = fs.restart(crate::io::CrashStyle::DropUnsynced);
        assert_eq!(replay_bytes(&disk.get(&path).unwrap()).1.records, 0);
        wal.append_delete(1).unwrap();
        // Window boundary: both records are now durable.
        let disk = fs.restart(crate::io::CrashStyle::DropUnsynced);
        assert_eq!(replay_bytes(&disk.get(&path).unwrap()).1.records, 2);
    }

    #[test]
    fn missing_log_recovers_as_empty() {
        let fs = FaultFs::new();
        let arc: Arc<dyn FileOps> = Arc::new(fs.clone());
        let (ops, info, wal) = Wal::recover(arc, &base(), 5, FsyncPolicy::Never).unwrap();
        assert!(ops.is_empty());
        assert_eq!(info, ReplayInfo::default());
        assert_eq!(wal.records(), 0);
        assert!(fs.exists(&wal_path(&base(), 5)), "recover materializes the log file");
    }
}
