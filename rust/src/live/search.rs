//! Exact k-NN over a live index: frozen-base search + tombstone
//! filtering + delta-shard append-log scan, merged into one logical
//! result set that is **bit-identical** to querying a cold rebuild of
//! the same logical series set.
//!
//! ## Why the merge is exact
//!
//! The base index still physically contains tombstoned series, and its
//! kernels know nothing about them. Instead of teaching every kernel a
//! skip mask, the live path over-asks: with `|T|` tombstones it runs the
//! base search at `k' = k + |T|`. Among the true top-`k'` physical
//! neighbors at most `|T|` are tombstoned, so at least `k` survivors
//! remain — and they are exactly the top-`k` *logical* base neighbors.
//! An abandon threshold τ composes: the base returns every candidate
//! strictly under τ within its top-`k'`, which again covers the best
//! `k` surviving ones.
//!
//! Survivors are remapped physical → logical by subtracting the
//! tombstone rank ([`Tombstones::to_logical`]); delta entries get ids
//! `survivors + offset`. Both maps are strictly monotone, so
//! `(distance, id)` tie order is preserved relative to the cold
//! rebuild's id space.
//!
//! The delta scan mirrors the kernels exactly: strict `lb > cutoff`
//! pruning (a candidate *at* the cutoff can still win a distance tie by
//! index — see [`KnnSet`]), and the shared [`exact_distance`] kernel,
//! whose admitted distances are bit-exact regardless of the cutoff.
//! Distance ties between a delta entry and any incumbent resolve by id,
//! and every delta entry's logical id exceeds every id already offered
//! before it — base survivors by construction, earlier delta entries by
//! append order — so tie resolution matches the cold rebuild's
//! ascending-index visit.

use std::time::Instant;

use crate::bounds::Scratch;
use crate::data::znorm::znormalized;
use crate::delta::Delta;
use crate::index::{Neighbor, QueryOptions, QueryOutcome, Searcher};
use crate::search::knn::{exact_distance, KnnParams, KnnSet};
use crate::search::nn::{NnResult, SearchStats};

use super::delta::{DeltaShard, Tombstones};

/// Exclusion split across the two candidate pools: a logical id below
/// the survivor count excludes a base physical index; at or above it,
/// a delta offset.
fn split_exclude(
    exclude: Option<usize>,
    survivors: usize,
    tombstones: &Tombstones,
) -> (Option<usize>, Option<usize>) {
    match exclude {
        Some(e) if e < survivors => (Some(tombstones.to_physical(e)), None),
        Some(e) => (None, Some(e - survivors)),
        None => (None, None),
    }
}

/// Scan the delta shard against an already-seeded merged set, charging
/// the work to `stats` (both the global counters and the delta-specific
/// ones, so `delta_* ` stay subsets of their global counterparts).
#[allow(clippy::too_many_arguments)]
fn scan_delta<D: Delta>(
    searcher: &Searcher,
    delta: &DeltaShard,
    exclude: Option<usize>,
    survivors: usize,
    normed_query: &[f64],
    set: &mut KnnSet,
    stats: &mut SearchStats,
    scratch: &mut Scratch,
) {
    if delta.is_empty() {
        return;
    }
    let index = searcher.index();
    let w = index.window().max(1);
    let bound = index.bound();
    let pq = bound.prepare_query(normed_query.to_vec(), w);
    for (j, e) in delta.entries().iter().enumerate() {
        if Some(j) == exclude {
            continue;
        }
        stats.delta_scanned += 1;
        let cutoff = set.cutoff();
        if cutoff.is_infinite() {
            // Nothing can prune yet: straight to the exact distance,
            // like the kernels' first-candidate rule.
            stats.dtw_calls += 1;
            stats.delta_dtw += 1;
            let d = exact_distance::<D>(&pq.values, &e.series, w, f64::INFINITY, &mut scratch.tail);
            set.offer(NnResult { nn_index: survivors + j, distance: d, label: e.label });
            continue;
        }
        stats.lb_calls += 1;
        let lb = bound.compute::<D>(&pq, &e.series, w, cutoff, scratch);
        // Strictly above only — at-cutoff candidates still race the tie.
        if lb > cutoff {
            stats.pruned += 1;
            stats.delta_pruned += 1;
            continue;
        }
        stats.dtw_calls += 1;
        stats.delta_dtw += 1;
        let d = exact_distance::<D>(&pq.values, &e.series, w, cutoff, &mut scratch.tail);
        if d.is_infinite() {
            stats.dtw_abandoned += 1;
        } else {
            set.offer(NnResult { nn_index: survivors + j, distance: d, label: e.label });
        }
    }
}

/// Fold a base (physical-id) outcome and the delta scan into one
/// logical-id outcome under the caller's *original* options.
#[allow(clippy::too_many_arguments)]
fn merge_outcome<D: Delta>(
    searcher: &Searcher,
    delta: &DeltaShard,
    tombstones: &Tombstones,
    delta_exclude: Option<usize>,
    survivors: usize,
    normed_query: &[f64],
    opts: &QueryOptions,
    base: QueryOutcome,
    scratch: &mut Scratch,
    started: Instant,
) -> QueryOutcome {
    let mut stats = base.stats;
    let params = KnnParams {
        k: opts.k.max(1),
        threshold: opts.abandon_at.unwrap_or(f64::INFINITY),
        exclude: None, // already applied on both pools
    };
    let mut set = KnnSet::new(&params);
    for n in &base.neighbors {
        if tombstones.contains(n.index) {
            continue;
        }
        set.offer(NnResult {
            nn_index: tombstones.to_logical(n.index),
            distance: n.distance,
            label: n.label,
        });
    }
    scan_delta::<D>(
        searcher,
        delta,
        delta_exclude,
        survivors,
        normed_query,
        &mut set,
        &mut stats,
        scratch,
    );
    QueryOutcome {
        neighbors: set.into_sorted().into_iter().map(Neighbor::from).collect(),
        stats,
        strategy: base.strategy,
        batched: base.batched,
        latency: started.elapsed(),
    }
}

/// One exact k-NN query over base + tombstones + delta. The caller
/// guarantees the live state is dirty (otherwise route straight to
/// [`Searcher::query_values`]).
pub(crate) fn live_query<D: Delta>(
    searcher: &mut Searcher,
    delta: &DeltaShard,
    tombstones: &Tombstones,
    scratch: &mut Scratch,
    values: &[f64],
    opts: &QueryOptions,
) -> QueryOutcome {
    let started = Instant::now();
    let survivors = searcher.index().len() - tombstones.len();
    // Normalize exactly once, then pin normalization off below — the
    // same single-normalization a cold rebuild's query path performs.
    let znorm = opts.znorm.unwrap_or(searcher.index().znormalizes());
    let owned: Vec<f64> = if znorm { znormalized(values) } else { values.to_vec() };
    let (base_exclude, delta_exclude) = split_exclude(opts.exclude, survivors, tombstones);
    let mut base_opts = opts.clone();
    base_opts.k = opts.k.max(1) + tombstones.len();
    base_opts.znorm = Some(false);
    base_opts.exclude = base_exclude;
    let base = searcher.query_values::<D>(&owned, &base_opts);
    merge_outcome::<D>(
        searcher,
        delta,
        tombstones,
        delta_exclude,
        survivors,
        &owned,
        opts,
        base,
        scratch,
        started,
    )
}

/// Batched variant: rides the base batched prefilter (each query's `k`
/// bumped by `|T|`), then merges per query. Same exactness argument as
/// [`live_query`], applied per item.
pub(crate) fn live_query_batch<D: Delta>(
    searcher: &mut Searcher,
    delta: &DeltaShard,
    tombstones: &Tombstones,
    scratch: &mut Scratch,
    items: &[(Vec<f64>, QueryOptions)],
) -> Vec<QueryOutcome> {
    let started = Instant::now();
    let survivors = searcher.index().len() - tombstones.len();
    let cfg_znorm = searcher.index().znormalizes();
    let mut base_items = Vec::with_capacity(items.len());
    let mut delta_excludes = Vec::with_capacity(items.len());
    for (values, opts) in items {
        let znorm = opts.znorm.unwrap_or(cfg_znorm);
        let owned = if znorm { znormalized(values) } else { values.clone() };
        let (base_exclude, delta_exclude) = split_exclude(opts.exclude, survivors, tombstones);
        let mut o = opts.clone();
        o.k = opts.k.max(1) + tombstones.len();
        o.znorm = Some(false);
        o.exclude = base_exclude;
        delta_excludes.push(delta_exclude);
        base_items.push((owned, o));
    }
    let base_outs = searcher.query_batch_mixed::<D>(&base_items);
    base_outs
        .into_iter()
        .enumerate()
        .map(|(qi, base)| {
            merge_outcome::<D>(
                searcher,
                delta,
                tombstones,
                delta_excludes[qi],
                survivors,
                &base_items[qi].0,
                &items[qi].1,
                base,
                scratch,
                started,
            )
        })
        .collect()
}
