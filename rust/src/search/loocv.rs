//! Leave-one-out cross-validated window selection.
//!
//! The UCR archive's "recommended window" for each dataset is the warping
//! window that maximizes leave-one-out 1-NN accuracy on the training set
//! (§6.1: "These recommended window sizes are those that provide most
//! accurate nearest neighbor classification using leave-one-out
//! cross-validation on the training set"). This module reproduces that
//! derivation so real-archive runs and synthetic runs use the same rule,
//! built on the [`crate::index::DtwIndex`] facade's self-match exclusion
//! (`QueryOptions::with_exclude`).

use crate::data::Dataset;
use crate::delta::Delta;
use crate::index::{DtwIndex, Query, QueryOptions};
use crate::search::SearchStrategy;

/// LOOCV 1-NN accuracy on the training set at window `w`.
///
/// Uses the brute-force strategy (exhaustive early-abandoning DTW, no
/// bounds), so it is valid for any δ.
pub fn loocv_accuracy<D: Delta>(ds: &Dataset, w: usize) -> f64 {
    let n = ds.train.len();
    if n < 2 {
        return 0.0;
    }
    let index = DtwIndex::builder(ds.train.iter().map(|s| s.values.clone()).collect())
        .labels(ds.train.iter().map(|s| s.label).collect())
        .window(w)
        .strategy(SearchStrategy::BruteForce)
        .build()
        .expect("dataset series share one length");
    let mut searcher = index.searcher();
    let mut correct = 0usize;
    for (i, s) in ds.train.iter().enumerate() {
        let out = searcher.query::<D>(
            &Query::new(s.values.clone()).with_options(QueryOptions::k(1).with_exclude(i)),
        );
        if out.best().map(|nn| nn.label == s.label).unwrap_or(false) {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Select the best window from `candidates` by LOOCV accuracy; ties go to
/// the **smallest** window (cheapest DTW), matching archive practice.
pub fn select_window<D: Delta>(ds: &Dataset, candidates: &[usize]) -> (usize, f64) {
    let mut best_w = 0usize;
    let mut best_acc = -1.0;
    for &w in candidates {
        let acc = loocv_accuracy::<D>(ds, w);
        if acc > best_acc + 1e-12 {
            best_acc = acc;
            best_w = w;
        }
    }
    (best_w, best_acc)
}

/// The UCR-style candidate grid: 0%..20% of ℓ in 1% steps (deduplicated).
pub fn ucr_window_grid(series_len: usize) -> Vec<usize> {
    let mut grid: Vec<usize> = (0..=20)
        .map(|pct| ((series_len as f64) * (pct as f64) / 100.0).ceil() as usize)
        .collect();
    grid.sort_unstable();
    grid.dedup();
    grid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::data::{Dataset, Labeled};
    use crate::delta::Squared;

    #[test]
    fn grid_shape() {
        let g = ucr_window_grid(150);
        assert_eq!(g[0], 0);
        assert_eq!(*g.last().unwrap(), 30);
        assert!(g.windows(2).all(|p| p[0] < p[1]));
    }

    #[test]
    fn perfectly_separable_data_is_perfect() {
        // Two classes of constant series far apart: any window works.
        let mk = |label: u32, v: f64| Labeled { label, values: vec![v; 16] };
        let ds = Dataset {
            name: "sep".into(),
            train: vec![mk(0, 0.0), mk(0, 0.1), mk(1, 5.0), mk(1, 5.1)],
            test: vec![],
            window: 0,
        };
        assert_eq!(loocv_accuracy::<Squared>(&ds, 0), 1.0);
        let (w, acc) = select_window::<Squared>(&ds, &[0, 1, 2]);
        assert_eq!(acc, 1.0);
        assert_eq!(w, 0, "ties must pick the smallest window");
    }

    #[test]
    fn shifted_pulses_prefer_nonzero_window() {
        // Class 0: one pulse, time-jittered. Class 1: flat. Lock-step
        // distance confuses jittered pulses; a small window aligns them.
        let pulse = |pos: usize| -> Vec<f64> {
            let mut v = vec![0.0; 24];
            v[pos] = 5.0;
            v[pos + 1] = 5.0;
            v
        };
        let mut train = Vec::new();
        for p in [4usize, 7, 10, 13] {
            train.push(Labeled { label: 0, values: pulse(p) });
        }
        for amp in [0.5, 0.6, 0.7, 0.8] {
            train.push(Labeled { label: 1, values: vec![amp; 24] });
        }
        let ds = Dataset { name: "pulse".into(), train, test: vec![], window: 0 };
        let acc0 = loocv_accuracy::<Squared>(&ds, 0);
        let (w, acc) = select_window::<Squared>(&ds, &[0, 1, 2, 3, 4, 6]);
        assert!(acc >= acc0);
        assert!(w > 0, "selected w={w}, acc0={acc0}, acc={acc}");
    }

    #[test]
    fn generator_archive_loocv_runs() {
        // Smoke: LOOCV over generated data returns sane values.
        let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 97));
        let ds = &archive[0];
        let grid = ucr_window_grid(ds.series_len());
        let (w, acc) = select_window::<Squared>(ds, &grid[..4]);
        assert!((0.0..=1.0).contains(&acc));
        assert!(w <= ds.series_len());
    }
}
