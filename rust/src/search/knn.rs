//! Generalized k-nearest-neighbor DTW search — the strategy kernels
//! behind the [`crate::index::DtwIndex`] facade.
//!
//! Each function generalizes one of the paper's search procedures (§6.2,
//! Algorithms 3 & 4) from 1-NN to k-NN: the best-so-far scalar becomes a
//! bounded result set ([`KnnSet`]) whose **k-th best distance is the
//! pruning cutoff**. At `k = 1` (and no threshold/exclusion) every kernel
//! degenerates to exactly the paper's algorithm — same bound calls, same
//! pruning counts — which the deprecated 1-NN wrappers in [`super::nn`]
//! rely on.
//!
//! All kernels remain **exact**: a candidate is only pruned when a valid
//! lower bound (full or partial) proves its DTW distance cannot beat the
//! current k-th best (or the caller's abandon threshold).
//!
//! Every hot loop here — cluster screening via
//! [`keogh::lb_keogh_flat`], per-candidate bounds via
//! [`crate::bounds::BoundKind::compute`], and the exact
//! [`dtw_ea_pruned`] kernel — runs on the runtime-dispatched SIMD
//! vtable ([`crate::simd`]). Dispatch is bit-transparent: distances,
//! pruning decisions and tie-breaks are identical at every ISA, so
//! result sets never depend on the host CPU.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::bounds::store::ShardStore;
use crate::bounds::{keogh, BoundKind, PreparedSeries, Scratch};
use crate::delta::Delta;
use crate::dtw::{dtw_ea, dtw_ea_pruned};
use crate::exec::Executor;

use super::nn::{NnResult, SearchStats};
use super::PreparedTrainSet;

/// Candidates per work-queue chunk in [`knn_parallel`]: small enough to
/// balance wildly uneven early-abandon costs, large enough to amortize
/// the atomic pop.
const CANDIDATE_CHUNK: usize = 8;

/// Fill `scratch.tail` with the candidate-envelope `LB_KEOGH` suffix
/// sums and run the pruned exact-DTW kernel — the one exact-distance
/// path every search strategy shares.
#[inline]
pub(crate) fn exact_distance<D: Delta>(
    query: &[f64],
    t: &PreparedSeries,
    w: usize,
    cutoff: f64,
    tail: &mut Vec<f64>,
) -> f64 {
    if cutoff.is_infinite() {
        // No cutoff → nothing can prune; skip the tail pass.
        return dtw_ea_pruned::<D>(query, &t.values, w, f64::INFINITY, None);
    }
    keogh::lb_keogh_tail::<D>(query, &t.lo, &t.up, tail);
    dtw_ea_pruned::<D>(query, &t.values, w, cutoff, Some(tail))
}

/// Knobs shared by every k-NN kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KnnParams {
    /// Number of neighbors to return (clamped to ≥ 1 by [`KnnSet`]).
    pub k: usize,
    /// Global abandon threshold τ: candidates at distance ≥ τ are never
    /// reported, and τ seeds the pruning cutoff even while the result set
    /// is not yet full (the streaming-monitor regime). `f64::INFINITY`
    /// disables it.
    pub threshold: f64,
    /// Candidate index to skip entirely (self-match exclusion, e.g.
    /// leave-one-out cross-validation).
    pub exclude: Option<usize>,
}

impl Default for KnnParams {
    fn default() -> Self {
        KnnParams { k: 1, threshold: f64::INFINITY, exclude: None }
    }
}

impl KnnParams {
    /// Params for a plain k-NN query (no threshold, no exclusion).
    pub fn k(k: usize) -> Self {
        KnnParams { k, ..KnnParams::default() }
    }
}

/// Bounded best-k set, totally ordered by ascending
/// `(distance, candidate index)`.
///
/// [`KnnSet::cutoff`] is the abandon/prune threshold the kernels pass to
/// bounds and DTW: the k-th best distance once full, the caller's
/// threshold before that. The `(distance, index)` order makes the final
/// set a **pure function of the offered candidates** — independent of
/// offer order — which is what lets [`knn_parallel`] return the exact
/// same neighbors as the serial kernels at every thread count (ties on
/// distance resolve to the smaller training index, matching the serial
/// kernels' ascending-index visit of equal-distance candidates).
#[derive(Debug, Clone)]
pub struct KnnSet {
    k: usize,
    threshold: f64,
    items: Vec<NnResult>,
}

/// `(distance, index)` strictly before? Distances are never NaN.
#[inline]
fn beats(a: &NnResult, b: &NnResult) -> bool {
    a.distance < b.distance || (a.distance == b.distance && a.nn_index < b.nn_index)
}

impl KnnSet {
    /// Empty set for `params` (`k` clamped to ≥ 1).
    pub fn new(params: &KnnParams) -> KnnSet {
        let k = params.k.max(1);
        KnnSet { k, threshold: params.threshold, items: Vec::with_capacity(k.min(64)) }
    }

    /// Current pruning cutoff: a candidate whose lower bound (or exact
    /// distance) is **strictly above** this can never enter the set.
    /// (A candidate *at* the cutoff can still win a distance tie by
    /// index, so pruning tests must use `>`, not `>=`.)
    pub fn cutoff(&self) -> f64 {
        if self.items.len() < self.k {
            self.threshold
        } else {
            // Full: the worst kept distance (< threshold by construction).
            self.items[self.k - 1].distance
        }
    }

    /// True once k candidates are held.
    pub fn is_full(&self) -> bool {
        self.items.len() >= self.k
    }

    /// Candidates currently held.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when no candidate has been admitted yet.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Offer a candidate; returns `true` when it was admitted.
    pub fn offer(&mut self, c: NnResult) -> bool {
        // The caller's threshold τ gates on distance alone (strictly
        // below), regardless of fill state.
        if c.distance >= self.threshold {
            return false;
        }
        if self.items.len() >= self.k && !beats(&c, &self.items[self.k - 1]) {
            return false;
        }
        let pos = self.items.partition_point(|x| !beats(&c, x));
        self.items.insert(pos, c);
        self.items.truncate(self.k);
        true
    }

    /// The kept neighbors, ascending by `(distance, index)`.
    pub fn into_sorted(self) -> Vec<NnResult> {
        self.items
    }
}

/// Algorithm 3 generalized: random-order k-NN search with
/// early-abandoning bounds.
///
/// `order` is the visiting order (indices into `train`). While the result
/// set is not full and no threshold is active the bound cannot prune, so
/// candidates go straight to the full distance — the generalization of
/// Algorithm 3's first-candidate rule.
pub fn knn_random_order<D: Delta>(
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    order: &[usize],
    params: &KnnParams,
    scratch: &mut Scratch,
) -> (Vec<NnResult>, SearchStats) {
    let w = train.w;
    let mut stats = SearchStats::default();
    let mut set = KnnSet::new(params);

    for &ti in order {
        if Some(ti) == params.exclude {
            continue;
        }
        let t = &train.series[ti];
        let cutoff = set.cutoff();
        if cutoff.is_infinite() {
            stats.dtw_calls += 1;
            let d = exact_distance::<D>(&query.values, t, w, f64::INFINITY, &mut scratch.tail);
            set.offer(NnResult { nn_index: ti, distance: d, label: train.labels[ti] });
            continue;
        }
        stats.lb_calls += 1;
        let lb = bound.compute::<D>(query, t, w, cutoff, scratch);
        // Strictly above only: a candidate *at* the cutoff may still win
        // a distance tie by index (see `KnnSet`).
        if lb > cutoff {
            stats.pruned += 1;
            continue;
        }
        stats.dtw_calls += 1;
        let d = exact_distance::<D>(&query.values, t, w, cutoff, &mut scratch.tail);
        if d.is_infinite() {
            stats.dtw_abandoned += 1;
        } else {
            set.offer(NnResult { nn_index: ti, distance: d, label: train.labels[ti] });
        }
    }
    (set.into_sorted(), stats)
}

/// Algorithm 4 generalized: bound-sorted k-NN search.
///
/// Bounds every candidate (no abandoning — full values are needed for the
/// sort), visits candidates in ascending-bound order and stops when the
/// next bound reaches the k-th best distance. `bound_buf` / `index_buf`
/// are caller scratch to keep the hot loop allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn knn_sorted<D: Delta>(
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    params: &KnnParams,
    scratch: &mut Scratch,
    bound_buf: &mut Vec<f64>,
    index_buf: &mut Vec<usize>,
) -> (Vec<NnResult>, SearchStats) {
    let w = train.w;
    let n = train.len();
    let mut stats = SearchStats::default();

    bound_buf.clear();
    for (ti, t) in train.series.iter().enumerate() {
        if Some(ti) == params.exclude {
            // Sorts last; the walk skips it before the stop test.
            bound_buf.push(f64::INFINITY);
            continue;
        }
        stats.lb_calls += 1;
        bound_buf.push(bound.compute::<D>(query, t, w, f64::INFINITY, scratch));
    }
    index_buf.clear();
    index_buf.extend(0..n);
    index_buf.sort_unstable_by(|&a, &b| {
        bound_buf[a].partial_cmp(&bound_buf[b]).expect("bounds are never NaN")
    });

    // Skipped candidates must not count as bound-pruned at the break.
    let mut skips_remaining = match params.exclude {
        Some(e) if e < n => 1usize,
        _ => 0,
    };
    let mut set = KnnSet::new(params);
    for (visited, &ti) in index_buf.iter().enumerate() {
        if Some(ti) == params.exclude {
            skips_remaining -= 1;
            continue;
        }
        if bound_buf[ti] > set.cutoff() {
            // Everything after this in sorted order is pruned too
            // (minus any yet-unvisited skipped candidate).
            stats.pruned += n - visited - skips_remaining;
            break;
        }
        stats.dtw_calls += 1;
        let d = exact_distance::<D>(
            &query.values,
            &train.series[ti],
            w,
            set.cutoff(),
            &mut scratch.tail,
        );
        if d.is_infinite() {
            stats.dtw_abandoned += 1;
        } else {
            set.offer(NnResult { nn_index: ti, distance: d, label: train.labels[ti] });
        }
    }
    (set.into_sorted(), stats)
}

/// Algorithm 4's walk over **precomputed** bounds, generalized to k-NN.
///
/// `bounds[t]` must be a valid lower bound of `DTW_w(query, train[t])` —
/// full or partial (an early-abandoned sum of non-negative allowances is
/// still a lower bound, it merely sorts pessimistically) — and `order`
/// the candidate indices in ascending-bound order, as a
/// [`crate::runtime::LbBackend`] delivers them.
///
/// `initial` optionally seeds the set with a candidate whose exact DTW
/// distance is already known (the batched path pays one DTW per query to
/// give the backend a real abandon cutoff); that candidate is skipped in
/// the walk. `tail_buf` is caller scratch for the pruned DTW kernel's
/// cumulative-lower-bound tail (keeps the walk allocation-free).
#[allow(clippy::too_many_arguments)]
pub fn knn_sorted_precomputed<D: Delta>(
    query: &[f64],
    train: &PreparedTrainSet,
    bounds: &[f64],
    order: &[usize],
    initial: Option<NnResult>,
    params: &KnnParams,
    tail_buf: &mut Vec<f64>,
) -> (Vec<NnResult>, SearchStats) {
    let w = train.w;
    let n = train.len();
    debug_assert_eq!(bounds.len(), n, "one bound per training series");
    debug_assert_eq!(order.len(), n, "order must cover every training series");
    let mut stats = SearchStats::default();

    let mut set = KnnSet::new(params);
    if let Some(r) = initial {
        set.offer(r);
    }
    let skip = initial.map(|r| r.nn_index);
    // Skipped candidates (seed, excluded) must not count as bound-pruned
    // at the break.
    let mut skips_remaining = 0usize;
    if let Some(e) = params.exclude {
        if e < n {
            skips_remaining += 1;
        }
    }
    if let Some(s) = skip {
        if s < n && Some(s) != params.exclude {
            skips_remaining += 1;
        }
    }
    for (visited, &ti) in order.iter().enumerate() {
        if Some(ti) == skip || Some(ti) == params.exclude {
            skips_remaining -= 1;
            continue;
        }
        if bounds[ti] > set.cutoff() {
            // Everything after this in sorted order is pruned too
            // (minus any yet-unvisited skipped candidate).
            stats.pruned += n - visited - skips_remaining;
            break;
        }
        stats.dtw_calls += 1;
        let d = exact_distance::<D>(query, &train.series[ti], w, set.cutoff(), tail_buf);
        if d.is_infinite() {
            stats.dtw_abandoned += 1;
        } else {
            set.offer(NnResult { nn_index: ti, distance: d, label: train.labels[ti] });
        }
    }
    (set.into_sorted(), stats)
}

/// Candidate-parallel exact k-NN: screen and score candidates on an
/// [`Executor`] with a **shared atomic best-so-far cutoff**.
///
/// Workers pull candidate chunks off a dynamic queue; each candidate is
/// bounded against a snapshot of the shared cutoff, survivors run the
/// pruned exact-DTW kernel, and admissions tighten the cutoff for every
/// worker. Exactness does not depend on snapshot freshness: the cutoff
/// only ever shrinks, so a stale snapshot merely prunes less.
///
/// **Determinism:** the result is identical to the serial kernels at
/// every thread count. A candidate is only skipped when a valid lower
/// bound strictly exceeds a cutoff snapshot `≥` the final k-th best
/// distance — such a candidate can never belong to the final set — and
/// [`KnnSet`]'s total `(distance, index)` order makes the surviving
/// set independent of admission order. Work *counters* ([`SearchStats`])
/// are scheduling-dependent (how much was pruned depends on how fast
/// the cutoff tightened) — only the neighbors are pinned.
pub fn knn_parallel<D: Delta>(
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    params: &KnnParams,
    exec: &Executor,
) -> (Vec<NnResult>, SearchStats) {
    let n = train.len();
    let l = query.len();
    // Shared monotone-nonincreasing cutoff as f64 bits: for nonnegative
    // floats (distances, +INF) the bit pattern orders like the value, so
    // `fetch_min` on the bits is `fetch_min` on the distance. A negative
    // threshold would break that encoding — clamp to 0.0, which admits
    // nothing anyway (admission still checks the real threshold).
    let cutoff_bits = AtomicU64::new(params.threshold.max(0.0).to_bits());
    let shared = Mutex::new((KnnSet::new(params), SearchStats::default()));

    exec.run(n, CANDIDATE_CHUNK, |_wid, queue| {
        // Per-worker mutable state, set up once per worker; stats merge
        // into the shared pair at worker exit (tight lock windows).
        let mut scratch = Scratch::new(l);
        let mut local = SearchStats::default();
        while let Some(range) = queue.next_chunk() {
            screen_range::<D>(
                range,
                query,
                train,
                bound,
                params,
                &cutoff_bits,
                &shared,
                &mut scratch,
                &mut local,
            );
        }
        shared.lock().unwrap().1.add(&local);
    });

    let (set, stats) = shared.into_inner().unwrap();
    (set.into_sorted(), stats)
}

/// Shard-parallel exact k-NN: the fan-out unit is a **shard-aligned
/// chunk** — each shard's contiguous global candidate range (as a
/// persistent index partitions them) subdivided into
/// [`CANDIDATE_CHUNK`]-sized work ranges, so no work item ever crosses
/// a shard boundary and parallelism is never capped by the shard count.
/// Workers screen their ranges against the same shared atomic cutoff as
/// [`knn_parallel`], so the determinism argument is identical: only
/// candidates provably outside the final set are ever pruned, and
/// [`KnnSet`]'s total `(distance, index)` order makes the merged result
/// independent of shard count, shard sizes, thread count and admission
/// order — **sharded ≡ serial bit-exactly**. Work counters stay
/// scheduling-dependent.
///
/// `shard_ranges` must cover `0..train.len()` disjointly (the
/// contiguous partition of [`crate::bounds::store::partition_shards`];
/// callers hand in [`crate::bounds::store::ShardStore::range`]s).
pub fn knn_sharded<D: Delta>(
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    shard_ranges: &[Range<usize>],
    bound: BoundKind,
    params: &KnnParams,
    exec: &Executor,
) -> (Vec<NnResult>, SearchStats) {
    debug_assert_eq!(
        shard_ranges.iter().map(|r| r.len()).sum::<usize>(),
        train.len(),
        "shards must cover every candidate"
    );
    let work = chunk_shard_ranges(shard_ranges, CANDIDATE_CHUNK);
    let l = query.len();
    let cutoff_bits = AtomicU64::new(params.threshold.max(0.0).to_bits());
    let shared = Mutex::new((KnnSet::new(params), SearchStats::default()));

    exec.run(work.len(), 1, |_wid, queue| {
        let mut scratch = Scratch::new(l);
        let mut local = SearchStats::default();
        while let Some(chunk) = queue.next_chunk() {
            for wi in chunk {
                screen_range::<D>(
                    work[wi].clone(),
                    query,
                    train,
                    bound,
                    params,
                    &cutoff_bits,
                    &shared,
                    &mut scratch,
                    &mut local,
                );
            }
        }
        shared.lock().unwrap().1.add(&local);
    });

    let (set, stats) = shared.into_inner().unwrap();
    (set.into_sorted(), stats)
}

/// One work unit of [`knn_sharded_stores`]: a plain candidate range for
/// a clusterless shard, or one whole cluster of a clustered shard.
enum StoreWork {
    Range(Range<usize>),
    Cluster { shard: usize, cluster: usize },
}

/// Two-level sharded exact k-NN over shard **stores**: clusters first,
/// members second.
///
/// For a clusterless shard the fan-out unit is the same
/// [`CANDIDATE_CHUNK`]-sized range as [`knn_sharded`]. For a shard
/// carrying [`crate::bounds::store::ShardClusters`], the unit is one
/// whole cluster: the worker evaluates **one** `LB_KEOGH` of the query
/// against the cluster's merged envelope, and only when that group
/// bound does not exceed the shared cutoff does it screen the members
/// individually (in the precomputed near-pivot-first order, which
/// tightens the cutoff fastest).
///
/// **Exactness** rests on envelope containment: the merged envelope
/// contains every member's envelope, so the group bound lower-bounds
/// every member's `LB_KEOGH` and hence every member's DTW distance
/// ([`crate::bounds::envelope::merge_envelopes_into`]). Skipping the
/// cluster when `group bound > cutoff` therefore prunes only candidates
/// that could never enter the final set — the same strict-`>` test the
/// per-candidate kernels use — and [`KnnSet`]'s total `(distance,
/// index)` order keeps the result independent of visit order, so
/// clustered ≡ flat ≡ serial bit-exactly at every cluster, shard and
/// thread count. Only the work counters (now including the
/// cluster-level [`SearchStats`] fields) are scheduling-dependent.
///
/// `shards` must cover `0..train.len()` contiguously (the partition of
/// [`crate::bounds::store::partition_shards`]).
pub fn knn_sharded_stores<D: Delta>(
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    shards: &[ShardStore],
    bound: BoundKind,
    params: &KnnParams,
    exec: &Executor,
) -> (Vec<NnResult>, SearchStats) {
    debug_assert_eq!(
        shards.iter().map(|s| s.len()).sum::<usize>(),
        train.len(),
        "shards must cover every candidate"
    );
    let mut work: Vec<StoreWork> = Vec::new();
    for (si, s) in shards.iter().enumerate() {
        match s.clusters() {
            Some(cl) => {
                work.extend((0..cl.len()).map(|c| StoreWork::Cluster { shard: si, cluster: c }))
            }
            None => work.extend(
                chunk_shard_ranges(&[s.range()], CANDIDATE_CHUNK).into_iter().map(StoreWork::Range),
            ),
        }
    }
    let l = query.len();
    let cutoff_bits = AtomicU64::new(params.threshold.max(0.0).to_bits());
    let shared = Mutex::new((KnnSet::new(params), SearchStats::default()));

    exec.run(work.len(), 1, |_wid, queue| {
        let mut scratch = Scratch::new(l);
        let mut local = SearchStats::default();
        while let Some(chunk) = queue.next_chunk() {
            for wi in chunk {
                match &work[wi] {
                    StoreWork::Range(r) => screen_range::<D>(
                        r.clone(),
                        query,
                        train,
                        bound,
                        params,
                        &cutoff_bits,
                        &shared,
                        &mut scratch,
                        &mut local,
                    ),
                    &StoreWork::Cluster { shard, cluster } => {
                        let s = &shards[shard];
                        let cl = s.clusters().expect("cluster work implies cluster metadata");
                        let cut = f64::from_bits(cutoff_bits.load(Ordering::Relaxed));
                        if cut.is_finite() {
                            // One bound for the whole group; a partial
                            // (abandoned) sum still lower-bounds every
                            // member, so the skip stays exact.
                            local.cluster_lb_calls += 1;
                            let env = cl.env();
                            let clb = keogh::lb_keogh_flat::<D>(
                                &query.values,
                                env.lo_row(cluster),
                                env.up_row(cluster),
                                cut,
                            );
                            if clb > cut {
                                let members = cl.members_of(cluster);
                                let excluded = members
                                    .iter()
                                    .filter(|&&m| Some(s.start() + m as usize) == params.exclude)
                                    .count();
                                local.clusters_pruned += 1;
                                local.cluster_members_pruned += members.len() - excluded;
                                continue;
                            }
                        }
                        screen_members::<D>(
                            s.start(),
                            cl.members_of(cluster),
                            query,
                            train,
                            bound,
                            params,
                            &cutoff_bits,
                            &shared,
                            &mut scratch,
                            &mut local,
                        );
                    }
                }
            }
        }
        shared.lock().unwrap().1.add(&local);
    });

    let (set, stats) = shared.into_inner().unwrap();
    (set.into_sorted(), stats)
}

/// Subdivide contiguous shard ranges into at-most-`chunk`-sized work
/// ranges that never cross a shard boundary — the sharded kernels' work
/// list (candidate ownership stays per-shard; parallelism does not).
pub fn chunk_shard_ranges(shard_ranges: &[Range<usize>], chunk: usize) -> Vec<Range<usize>> {
    let chunk = chunk.max(1);
    let mut out = Vec::new();
    for r in shard_ranges {
        let mut a = r.start;
        while a < r.end {
            let b = (a + chunk).min(r.end);
            out.push(a..b);
            a = b;
        }
    }
    out
}

/// Screen one contiguous candidate range against the shared
/// cutoff/result state — the worker body [`knn_parallel`] and
/// [`knn_sharded`] have in common. Each candidate is bounded against a
/// snapshot of the shared cutoff (which only ever shrinks; a stale
/// snapshot merely prunes less), survivors run the pruned exact-DTW
/// kernel, and admissions tighten the cutoff for every worker.
#[allow(clippy::too_many_arguments)]
fn screen_range<D: Delta>(
    range: Range<usize>,
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    params: &KnnParams,
    cutoff_bits: &AtomicU64,
    shared: &Mutex<(KnnSet, SearchStats)>,
    scratch: &mut Scratch,
    local: &mut SearchStats,
) {
    for ti in range {
        screen_one::<D>(ti, query, train, bound, params, cutoff_bits, shared, scratch, local);
    }
}

/// [`screen_range`] over an explicit member list: `members` are local
/// offsets into a shard starting at global candidate `start` — the
/// member fan-in of one surviving cluster, visited in the precomputed
/// near-pivot-first order.
#[allow(clippy::too_many_arguments)]
fn screen_members<D: Delta>(
    start: usize,
    members: &[u32],
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    params: &KnnParams,
    cutoff_bits: &AtomicU64,
    shared: &Mutex<(KnnSet, SearchStats)>,
    scratch: &mut Scratch,
    local: &mut SearchStats,
) {
    for &m in members {
        let ti = start + m as usize;
        screen_one::<D>(ti, query, train, bound, params, cutoff_bits, shared, scratch, local);
    }
}

/// Screen one candidate against the shared cutoff/result state — the
/// per-candidate body all parallel kernels share. Bounded against a
/// snapshot of the shared cutoff (which only ever shrinks; a stale
/// snapshot merely prunes less); survivors run the pruned exact-DTW
/// kernel, and admissions tighten the cutoff for every worker.
#[allow(clippy::too_many_arguments)]
fn screen_one<D: Delta>(
    ti: usize,
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    params: &KnnParams,
    cutoff_bits: &AtomicU64,
    shared: &Mutex<(KnnSet, SearchStats)>,
    scratch: &mut Scratch,
    local: &mut SearchStats,
) {
    if Some(ti) == params.exclude {
        return;
    }
    let w = train.w;
    let offer = |r: NnResult| {
        let mut guard = shared.lock().unwrap();
        let (set, _) = &mut *guard;
        if set.offer(r) {
            cutoff_bits.fetch_min(set.cutoff().max(0.0).to_bits(), Ordering::Relaxed);
        }
    };
    let t = &train.series[ti];
    let cut = f64::from_bits(cutoff_bits.load(Ordering::Relaxed));
    if cut.is_infinite() {
        // Nothing to prune against yet (set not full, no τ):
        // straight to the exact distance, like Algorithm 3's
        // first candidates.
        local.dtw_calls += 1;
        let d = exact_distance::<D>(&query.values, t, w, f64::INFINITY, &mut scratch.tail);
        offer(NnResult { nn_index: ti, distance: d, label: train.labels[ti] });
        return;
    }
    local.lb_calls += 1;
    let lb = bound.compute::<D>(query, t, w, cut, scratch);
    if lb > cut {
        local.pruned += 1;
        return;
    }
    local.dtw_calls += 1;
    let d = exact_distance::<D>(&query.values, t, w, cut, &mut scratch.tail);
    if d.is_infinite() {
        local.dtw_abandoned += 1;
    } else {
        offer(NnResult { nn_index: ti, distance: d, label: train.labels[ti] });
    }
}

/// Reference k-NN brute force (no bounds) — ground truth for tests and
/// the "no lower bound" baseline. Still early-abandons DTW against the
/// k-th best distance, which cannot change the result.
pub fn knn_brute_force<D: Delta>(
    query: &[f64],
    train: &PreparedTrainSet,
    params: &KnnParams,
) -> (Vec<NnResult>, SearchStats) {
    let mut stats = SearchStats::default();
    let mut set = KnnSet::new(params);
    for (ti, t) in train.series.iter().enumerate() {
        if Some(ti) == params.exclude {
            continue;
        }
        stats.dtw_calls += 1;
        let d = dtw_ea::<D>(query, &t.values, train.w, set.cutoff());
        if d.is_infinite() {
            stats.dtw_abandoned += 1;
        } else {
            set.offer(NnResult { nn_index: ti, distance: d, label: train.labels[ti] });
        }
    }
    (set.into_sorted(), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;
    use crate::dtw::dtw;

    fn setup() -> (PreparedTrainSet, Vec<PreparedSeries>) {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 31))[2];
        let w = ds.window.max(1);
        let train = PreparedTrainSet::from_dataset(ds, w);
        let queries = ds
            .test
            .iter()
            .map(|s| PreparedSeries::prepare(s.values.clone(), w))
            .collect();
        (train, queries)
    }

    /// Ground truth: all DTW distances, fully computed, sorted ascending.
    fn truth_distances(q: &[f64], train: &PreparedTrainSet) -> Vec<f64> {
        let mut ds: Vec<f64> = train
            .series
            .iter()
            .map(|t| dtw::<Squared>(q, &t.values, train.w))
            .collect();
        ds.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        ds
    }

    #[test]
    fn knn_set_orders_and_caps() {
        let mut set = KnnSet::new(&KnnParams::k(2));
        assert!(set.is_empty());
        assert!(set.cutoff().is_infinite());
        let r = |i: usize, d: f64| NnResult { nn_index: i, distance: d, label: 0 };
        assert!(set.offer(r(0, 5.0)));
        assert!(set.offer(r(1, 3.0)));
        assert!(set.is_full());
        assert_eq!(set.cutoff(), 5.0);
        assert!(!set.offer(r(2, 5.0)), "ties keep the incumbent");
        assert!(set.offer(r(3, 1.0)));
        assert_eq!(set.cutoff(), 3.0);
        let out = set.into_sorted();
        assert_eq!(out.len(), 2);
        assert_eq!((out[0].nn_index, out[1].nn_index), (3, 1));
    }

    #[test]
    fn knn_set_threshold_gates_admission() {
        let mut set = KnnSet::new(&KnnParams { k: 3, threshold: 2.0, exclude: None });
        let r = |d: f64| NnResult { nn_index: 0, distance: d, label: 0 };
        assert!(!set.offer(r(2.0)), "at threshold is out");
        assert!(set.offer(r(1.9)));
        assert_eq!(set.cutoff(), 2.0, "not full: cutoff stays at the threshold");
    }

    #[test]
    fn all_strategies_agree_with_ground_truth_for_all_k() {
        let (train, queries) = setup();
        let mut scratch = Scratch::default();
        let mut rng = Rng::seeded(411);
        let (mut bb, mut ib) = (Vec::new(), Vec::new());
        for q in queries.iter().take(4) {
            let truth = truth_distances(&q.values, &train);
            for k in [1usize, 3, 10] {
                let params = KnnParams::k(k);
                let want: Vec<f64> =
                    truth.iter().take(k.min(train.len())).copied().collect();

                let (bf, _) = knn_brute_force::<Squared>(&q.values, &train, &params);
                let got: Vec<f64> = bf.iter().map(|r| r.distance).collect();
                assert_eq!(got, want, "brute force k={k}");

                for &bound in crate::bounds::BoundKind::ALL {
                    let mut order: Vec<usize> = (0..train.len()).collect();
                    rng.shuffle(&mut order);
                    let (ro, _) = knn_random_order::<Squared>(
                        q, &train, bound, &order, &params, &mut scratch,
                    );
                    let got: Vec<f64> = ro.iter().map(|r| r.distance).collect();
                    assert_eq!(got, want, "{bound} random-order k={k}");

                    let (so, _) = knn_sorted::<Squared>(
                        q, &train, bound, &params, &mut scratch, &mut bb, &mut ib,
                    );
                    let got: Vec<f64> = so.iter().map(|r| r.distance).collect();
                    assert_eq!(got, want, "{bound} sorted k={k}");
                }
            }
        }
    }

    #[test]
    fn precomputed_walk_matches_ground_truth_with_partial_bounds_and_seed() {
        let (train, queries) = setup();
        let mut scratch = Scratch::default();
        for q in queries.iter().take(3) {
            let truth = truth_distances(&q.values, &train);
            for k in [1usize, 3] {
                let params = KnnParams::k(k);
                let want: Vec<f64> =
                    truth.iter().take(k.min(train.len())).copied().collect();
                // Partial bounds abandoned against the candidate-0 seed.
                let seed = dtw::<Squared>(&q.values, &train.series[0].values, train.w);
                let bounds: Vec<f64> = train
                    .series
                    .iter()
                    .map(|t| {
                        crate::bounds::BoundKind::Keogh
                            .compute::<Squared>(q, t, train.w, seed, &mut scratch)
                    })
                    .collect();
                let mut order: Vec<usize> = (0..train.len()).collect();
                order.sort_unstable_by(|&a, &b| bounds[a].partial_cmp(&bounds[b]).unwrap());
                let initial =
                    NnResult { nn_index: 0, distance: seed, label: train.labels[0] };
                let mut tail_buf = Vec::new();
                let (r, _) = knn_sorted_precomputed::<Squared>(
                    &q.values,
                    &train,
                    &bounds,
                    &order,
                    Some(initial),
                    &params,
                    &mut tail_buf,
                );
                let got: Vec<f64> = r.iter().map(|x| x.distance).collect();
                assert_eq!(got, want, "seeded precomputed walk k={k}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial_at_every_thread_count() {
        let (train, queries) = setup();
        let mut scratch = Scratch::default();
        let (mut bb, mut ib) = (Vec::new(), Vec::new());
        for q in queries.iter().take(3) {
            for k in [1usize, 3, 10] {
                let params = KnnParams::k(k);
                let (serial, _) = knn_sorted::<Squared>(
                    q,
                    &train,
                    crate::bounds::BoundKind::Webb,
                    &params,
                    &mut scratch,
                    &mut bb,
                    &mut ib,
                );
                let want: Vec<(usize, f64)> =
                    serial.iter().map(|r| (r.nn_index, r.distance)).collect();
                for threads in [1usize, 2, 3, 8] {
                    let exec = crate::exec::Executor::new(threads);
                    let (par, _) = knn_parallel::<Squared>(
                        q,
                        &train,
                        crate::bounds::BoundKind::Webb,
                        &params,
                        &exec,
                    );
                    let got: Vec<(usize, f64)> =
                        par.iter().map(|r| (r.nn_index, r.distance)).collect();
                    assert_eq!(got, want, "threads={threads} k={k}");
                }
            }
        }
    }

    #[test]
    fn chunked_shard_ranges_cover_without_crossing_boundaries() {
        let shards = vec![0..5usize, 5..6, 6..20];
        let work = chunk_shard_ranges(&shards, 4);
        // Full disjoint coverage, in order.
        let mut next = 0usize;
        for r in &work {
            assert_eq!(r.start, next);
            assert!(r.len() <= 4 && !r.is_empty());
            next = r.end;
        }
        assert_eq!(next, 20);
        // No work range crosses a shard boundary.
        for r in &work {
            assert!(
                shards.iter().any(|s| s.start <= r.start && r.end <= s.end),
                "{r:?} crosses a shard boundary"
            );
        }
        assert!(chunk_shard_ranges(&[], 4).is_empty());
        assert_eq!(chunk_shard_ranges(&[0..3], 0), vec![0..1, 1..2, 2..3], "chunk clamps to 1");
    }

    #[test]
    fn sharded_matches_serial_at_every_shard_and_thread_count() {
        let (train, queries) = setup();
        let mut scratch = Scratch::default();
        let (mut bb, mut ib) = (Vec::new(), Vec::new());
        let n = train.len();
        for q in queries.iter().take(3) {
            for k in [1usize, 3] {
                let params = KnnParams::k(k);
                let (serial, _) = knn_sorted::<Squared>(
                    q,
                    &train,
                    crate::bounds::BoundKind::Webb,
                    &params,
                    &mut scratch,
                    &mut bb,
                    &mut ib,
                );
                let want: Vec<(usize, f64)> =
                    serial.iter().map(|r| (r.nn_index, r.distance)).collect();
                for shards in [1usize, 2, 3, 7] {
                    // The same contiguous partition the index builder uses.
                    let shards_eff = shards.clamp(1, n);
                    let (base, extra) = (n / shards_eff, n % shards_eff);
                    let mut ranges = Vec::new();
                    let mut start = 0usize;
                    for s in 0..shards_eff {
                        let len = base + usize::from(s < extra);
                        ranges.push(start..start + len);
                        start += len;
                    }
                    for threads in [1usize, 4] {
                        let exec = crate::exec::Executor::new(threads);
                        let (got, _) = knn_sharded::<Squared>(
                            q,
                            &train,
                            &ranges,
                            crate::bounds::BoundKind::Webb,
                            &params,
                            &exec,
                        );
                        let got: Vec<(usize, f64)> =
                            got.iter().map(|r| (r.nn_index, r.distance)).collect();
                        assert_eq!(got, want, "shards={shards} threads={threads} k={k}");
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_stores_with_clusters_match_serial_bit_exactly() {
        use crate::bounds::envelope::merge_envelopes_into;
        use crate::bounds::store::{partition_shards, EnvelopeStore, ShardClusters};

        // Hand-build cluster metadata: split each shard's members into
        // `k` contiguous groups, pivot = first member, merged envelopes
        // folded with `merge_envelopes_into`. Exactness must not depend
        // on how good the clustering is — any grouping is valid.
        fn clusterize(train: &PreparedTrainSet, shards: usize, k: usize) -> Vec<ShardStore> {
            partition_shards(&train.series, shards)
                .into_iter()
                .map(|s| {
                    if k == 0 {
                        return s;
                    }
                    let len = s.len();
                    let k = k.clamp(1, len);
                    let (base, extra) = (len / k, len % k);
                    let mut members = Vec::new();
                    let mut offsets = vec![0u32];
                    let mut pivots = Vec::new();
                    let (mut lo_rows, mut up_rows) = (Vec::new(), Vec::new());
                    let mut at = 0usize;
                    for c in 0..k {
                        let glen = base + usize::from(c < extra);
                        pivots.push(at as u32);
                        let l = train.series[0].len();
                        let mut lo = vec![f64::INFINITY; l];
                        let mut up = vec![f64::NEG_INFINITY; l];
                        for m in at..at + glen {
                            members.push(m as u32);
                            let t = &train.series[s.start() + m];
                            merge_envelopes_into(&mut lo, &mut up, &t.lo, &t.up);
                        }
                        lo_rows.push(lo);
                        up_rows.push(up);
                        at += glen;
                        offsets.push(at as u32);
                    }
                    let env = EnvelopeStore::from_rows(&lo_rows, &up_rows);
                    let cl = ShardClusters::from_parts(
                        len,
                        members,
                        offsets,
                        pivots,
                        vec![0.0; len],
                        env,
                    )
                    .unwrap();
                    s.with_clusters(cl)
                })
                .collect()
        }

        let (train, queries) = setup();
        let mut scratch = Scratch::default();
        let (mut bb, mut ib) = (Vec::new(), Vec::new());
        for q in queries.iter().take(3) {
            for k in [1usize, 3] {
                let params = KnnParams::k(k);
                let (serial, _) = knn_sorted::<Squared>(
                    q,
                    &train,
                    crate::bounds::BoundKind::Webb,
                    &params,
                    &mut scratch,
                    &mut bb,
                    &mut ib,
                );
                let want: Vec<(usize, f64)> =
                    serial.iter().map(|r| (r.nn_index, r.distance)).collect();
                for shards in [1usize, 3] {
                    for clusters in [0usize, 1, 2, 5] {
                        let stores = clusterize(&train, shards, clusters);
                        for threads in [1usize, 4] {
                            let exec = crate::exec::Executor::new(threads);
                            let (got, stats) = knn_sharded_stores::<Squared>(
                                q,
                                &train,
                                &stores,
                                crate::bounds::BoundKind::Webb,
                                &params,
                                &exec,
                            );
                            let got: Vec<(usize, f64)> =
                                got.iter().map(|r| (r.nn_index, r.distance)).collect();
                            assert_eq!(
                                got, want,
                                "shards={shards} clusters={clusters} threads={threads} k={k}"
                            );
                            if clusters == 0 {
                                assert_eq!(stats.cluster_lb_calls, 0);
                                assert_eq!(stats.clusters_pruned, 0);
                                assert_eq!(stats.cluster_members_pruned, 0);
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn exclude_drops_exactly_one_candidate() {
        let (train, queries) = setup();
        let q = &queries[0];
        // Ground truth without candidate 0.
        let mut truth: Vec<f64> = train
            .series
            .iter()
            .enumerate()
            .filter(|(ti, _)| *ti != 0)
            .map(|(_, t)| dtw::<Squared>(&q.values, &t.values, train.w))
            .collect();
        truth.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        let params = KnnParams { k: 3, threshold: f64::INFINITY, exclude: Some(0) };
        let (bf, _) = knn_brute_force::<Squared>(&q.values, &train, &params);
        let got: Vec<f64> = bf.iter().map(|r| r.distance).collect();
        assert_eq!(got, truth[..3.min(truth.len())].to_vec());
        assert!(bf.iter().all(|r| r.nn_index != 0));

        let mut scratch = Scratch::default();
        let (mut bb, mut ib) = (Vec::new(), Vec::new());
        let (so, _) = knn_sorted::<Squared>(
            q,
            &train,
            crate::bounds::BoundKind::Webb,
            &params,
            &mut scratch,
            &mut bb,
            &mut ib,
        );
        let got: Vec<f64> = so.iter().map(|r| r.distance).collect();
        assert_eq!(got, truth[..3.min(truth.len())].to_vec());
    }

    #[test]
    fn threshold_caps_reported_neighbors() {
        let (train, queries) = setup();
        let q = &queries[0];
        let truth = truth_distances(&q.values, &train);
        let tau = truth[truth.len() / 2]; // median distance as threshold
        let params = KnnParams { k: train.len(), threshold: tau, exclude: None };
        let (bf, _) = knn_brute_force::<Squared>(&q.values, &train, &params);
        assert!(bf.iter().all(|r| r.distance < tau));
        let want: Vec<f64> = truth.iter().copied().filter(|&d| d < tau).collect();
        let got: Vec<f64> = bf.iter().map(|r| r.distance).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn k1_stats_match_the_paper_algorithms() {
        let (train, queries) = setup();
        let mut scratch = Scratch::default();
        let order: Vec<usize> = (0..train.len()).collect();
        let q = &queries[0];
        let (_, s) = knn_random_order::<Squared>(
            q,
            &train,
            crate::bounds::BoundKind::Webb,
            &order,
            &KnnParams::default(),
            &mut scratch,
        );
        // First candidate bypasses the bound (Algorithm 3).
        assert_eq!(s.lb_calls, train.len() - 1);
        assert_eq!(s.lb_calls, s.pruned + s.dtw_calls - 1);
    }
}
