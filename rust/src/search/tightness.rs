//! Tightness evaluation (§6.1): `λ_w(Q,T) / DTW_w(Q,T)` averaged over all
//! test×train pairs, excluding pairs with `DTW = 0`.

use crate::bounds::Scratch;
use crate::data::Dataset;
use crate::delta::Delta;
use crate::dtw::dtw;
use crate::index::DtwIndex;

/// Tightness summary for one (dataset, bound) pair.
#[derive(Debug, Clone, Copy)]
pub struct Tightness {
    /// Mean λ/DTW over included pairs.
    pub mean: f64,
    /// Number of pairs included (DTW > 0).
    pub pairs: usize,
    /// Pairs skipped because DTW was 0.
    pub skipped: usize,
}

/// Mean tightness of `index.bound()` on a dataset at `index.window()`.
///
/// The index carries the prepared training envelopes and the bound under
/// test — evaluate several bounds over the same dataset with cheap
/// [`DtwIndex::with_bound`] handles. `dtw_cache` lets those calls reuse
/// the DTW denominators — pass the same (initially empty) vector.
pub fn dataset_tightness<D: Delta>(
    ds: &Dataset,
    index: &DtwIndex,
    dtw_cache: &mut Vec<f64>,
) -> Tightness {
    let train = index.train();
    let bound = index.bound();
    let w = train.w;
    let want = ds.test.len() * train.len();
    if dtw_cache.len() != want {
        dtw_cache.clear();
        dtw_cache.reserve(want);
        for q in &ds.test {
            for t in &train.series {
                dtw_cache.push(dtw::<D>(&q.values, &t.values, w));
            }
        }
    }

    let mut scratch = Scratch::default();
    let mut sum = 0.0;
    let mut pairs = 0usize;
    let mut skipped = 0usize;
    let mut k = 0usize;
    for q in &ds.test {
        let pq = bound.prepare_query(q.values.clone(), w);
        for t in &train.series {
            let d = dtw_cache[k];
            k += 1;
            if d <= 0.0 {
                skipped += 1;
                continue;
            }
            let lb = bound.compute::<D>(&pq, t, w, f64::INFINITY, &mut scratch);
            debug_assert!(
                lb <= d + 1e-6 * d.max(1.0),
                "{bound} exceeded DTW: {lb} > {d}"
            );
            sum += lb / d;
            pairs += 1;
        }
    }
    Tightness {
        mean: if pairs > 0 { sum / pairs as f64 } else { 0.0 },
        pairs,
        skipped,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::BoundKind;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;

    #[test]
    fn tightness_orderings_hold_on_dataset_means() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 17))[4];
        let w = ds.window.max(2);
        let index = DtwIndex::builder_from_dataset(ds).window(w).build().unwrap();
        let mut cache = Vec::new();
        let t = |b: BoundKind, cache: &mut Vec<f64>| {
            dataset_tightness::<Squared>(ds, &index.with_bound(b), cache).mean
        };
        let kim = t(BoundKind::KimFL, &mut cache);
        let keogh = t(BoundKind::Keogh, &mut cache);
        let improved = t(BoundKind::Improved, &mut cache);
        let petitjean = t(BoundKind::Petitjean, &mut cache);
        let petitjean_nolr = t(BoundKind::PetitjeanNoLr, &mut cache);
        let webb = t(BoundKind::Webb, &mut cache);
        let webb_nolr = t(BoundKind::WebbNoLr, &mut cache);
        let enhanced8 = t(BoundKind::Enhanced(8), &mut cache);
        let webb_enh8 = t(BoundKind::WebbEnhanced(8), &mut cache);

        // In [0, 1].
        for v in [kim, keogh, improved, petitjean, webb, enhanced8] {
            assert!((0.0..=1.0 + 1e-9).contains(&v), "{v}");
        }
        // Provable pointwise orderings must show in the means.
        assert!(improved >= keogh - 1e-12);
        assert!(petitjean_nolr >= improved - 1e-12);
        assert!(webb_nolr >= keogh - 1e-12);
        assert!(webb_enh8 >= enhanced8 - 1e-12);
        // Paper's headline orderings (means, this data).
        assert!(petitjean >= improved - 1e-9, "{petitjean} < {improved}");
        assert!(webb >= keogh - 1e-9, "{webb} < {keogh}");
        assert!(kim <= keogh + 1e-9);
    }

    #[test]
    fn identical_series_are_skipped() {
        // A dataset where a test series equals a training series → DTW=0
        // pair is excluded, not a division by zero.
        let mut ds = generate_archive(&ArchiveSpec::new(Scale::Tiny, 23))[0].clone();
        ds.test[0].values = ds.train[0].values.clone();
        let index = DtwIndex::builder_from_dataset(&ds)
            .window(2)
            .bound(BoundKind::Webb)
            .build()
            .unwrap();
        let mut cache = Vec::new();
        let t = dataset_tightness::<Squared>(&ds, &index, &mut cache);
        assert!(t.skipped >= 1);
        assert!(t.mean.is_finite());
    }
}
