//! The two nearest-neighbor search procedures of §6.2 (Algorithms 3 & 4).
//!
//! Both find `argmin_T DTW_w(Q, T)`; they differ in how they spend the
//! lower bound:
//!
//! * **Random order** ([`nn_random_order`], Algorithm 3): candidates are
//!   visited in a given order; the bound is computed *immediately before*
//!   the full distance and can therefore **early-abandon** against the
//!   best distance so far — the regime where `LB_PETITJEAN`'s expensive
//!   tightness pays (paper §6.2, Figures 19–26).
//! * **Sorted** ([`nn_sorted`], Algorithm 4): bounds for *all* candidates
//!   are computed first (no abandoning possible), candidates are visited
//!   in ascending bound order, and search stops when the next bound
//!   exceeds the best distance — the regime where `LB_WEBB`'s low cost
//!   wins (Figures 21–22, 27–30, Tables 1–3).
//! * **Sorted, precomputed** ([`nn_sorted_precomputed`]): the walk of
//!   Algorithm 4 alone, fed bound columns a batched
//!   [`crate::runtime::LbBackend`] already computed for a whole query
//!   batch. Any valid (possibly partial, early-abandoned) lower bounds
//!   keep the search exact.

use crate::bounds::{BoundKind, PreparedSeries, Scratch};
use crate::delta::Delta;
use crate::dtw::dtw_ea;

use super::PreparedTrainSet;

/// Outcome of one nearest-neighbor query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NnResult {
    /// Index of the nearest training series.
    pub nn_index: usize,
    /// Its DTW distance.
    pub distance: f64,
    /// Its label (the 1-NN prediction).
    pub label: u32,
}

/// Work counters for pruning-power analysis.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Lower-bound evaluations.
    pub lb_calls: usize,
    /// Candidates discarded by the bound alone.
    pub pruned: usize,
    /// Full DTW computations started.
    pub dtw_calls: usize,
    /// DTW computations abandoned early.
    pub dtw_abandoned: usize,
}

impl SearchStats {
    /// Merge counters (for per-dataset aggregation).
    pub fn add(&mut self, other: &SearchStats) {
        self.lb_calls += other.lb_calls;
        self.pruned += other.pruned;
        self.dtw_calls += other.dtw_calls;
        self.dtw_abandoned += other.dtw_abandoned;
    }
}

/// Algorithm 3: random-order search with early-abandoning bounds.
///
/// `order` is the visiting order (indices into `train`); the experiment
/// driver shuffles it per query. The query must be prepared with the same
/// window (`PreparedSeries::prepare`) — for bounds that never read query
/// envelopes this only costs the unused vectors.
pub fn nn_random_order<D: Delta>(
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    order: &[usize],
    scratch: &mut Scratch,
) -> (NnResult, SearchStats) {
    let w = train.w;
    let mut stats = SearchStats::default();
    let mut best = NnResult { nn_index: usize::MAX, distance: f64::INFINITY, label: 0 };

    for &ti in order {
        let t = &train.series[ti];
        if best.nn_index == usize::MAX {
            // First candidate: full distance, no bound (Algorithm 3).
            stats.dtw_calls += 1;
            let d = dtw_ea::<D>(&query.values, &t.values, w, f64::INFINITY);
            best = NnResult { nn_index: ti, distance: d, label: train.labels[ti] };
            continue;
        }
        stats.lb_calls += 1;
        let lb = bound.compute::<D>(query, t, w, best.distance, scratch);
        if lb >= best.distance {
            stats.pruned += 1;
            continue;
        }
        stats.dtw_calls += 1;
        let d = dtw_ea::<D>(&query.values, &t.values, w, best.distance);
        if d.is_infinite() {
            stats.dtw_abandoned += 1;
        } else if d < best.distance {
            best = NnResult { nn_index: ti, distance: d, label: train.labels[ti] };
        }
    }
    (best, stats)
}

/// Algorithm 4: bound-sorted search.
///
/// Computes the bound for every candidate (no early abandoning — the
/// bounds are needed in full for the sort), sorts ascending, then walks
/// until the next bound is at least the best distance found.
///
/// `bound_buf` / `index_buf` are caller scratch to keep the hot loop
/// allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn nn_sorted<D: Delta>(
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    scratch: &mut Scratch,
    bound_buf: &mut Vec<f64>,
    index_buf: &mut Vec<usize>,
) -> (NnResult, SearchStats) {
    let w = train.w;
    let n = train.len();
    let mut stats = SearchStats::default();

    bound_buf.clear();
    for t in &train.series {
        stats.lb_calls += 1;
        bound_buf.push(bound.compute::<D>(query, t, w, f64::INFINITY, scratch));
    }
    index_buf.clear();
    index_buf.extend(0..n);
    index_buf.sort_unstable_by(|&a, &b| {
        bound_buf[a].partial_cmp(&bound_buf[b]).expect("bounds are never NaN")
    });

    let mut best = NnResult { nn_index: usize::MAX, distance: f64::INFINITY, label: 0 };
    for (visited, &ti) in index_buf.iter().enumerate() {
        if bound_buf[ti] >= best.distance {
            // Everything after this in sorted order is pruned too.
            stats.pruned += n - visited;
            break;
        }
        stats.dtw_calls += 1;
        let d = dtw_ea::<D>(&query.values, &train.series[ti].values, w, best.distance);
        if d.is_infinite() {
            stats.dtw_abandoned += 1;
        } else if d < best.distance {
            best = NnResult { nn_index: ti, distance: d, label: train.labels[ti] };
        }
    }
    (best, stats)
}

/// Algorithm 4's walk over **precomputed** bounds.
///
/// `bounds[t]` must be a valid lower bound of `DTW_w(query, train[t])`
/// — full or partial (an early-abandoned sum of non-negative allowances
/// is still a lower bound, it merely sorts pessimistically) — and
/// `order` the candidate indices in ascending-bound order. This is the
/// per-query half of the batched screening path: a
/// [`crate::runtime::LbBackend`] computes the bound matrix and the
/// ranking for the whole batch (`LbBackend::rank`), then each query
/// walks its own columns here.
///
/// `initial` optionally seeds the best-so-far with a candidate whose
/// exact DTW distance is already known (the engine pays one DTW per query
/// to give the backend a real abandon cutoff); that candidate is skipped
/// in the walk.
pub fn nn_sorted_precomputed<D: Delta>(
    query: &[f64],
    train: &PreparedTrainSet,
    bounds: &[f64],
    order: &[usize],
    initial: Option<NnResult>,
) -> (NnResult, SearchStats) {
    let w = train.w;
    let n = train.len();
    debug_assert_eq!(bounds.len(), n, "one bound per training series");
    debug_assert_eq!(order.len(), n, "order must cover every training series");
    let mut stats = SearchStats::default();

    let mut best =
        initial.unwrap_or(NnResult { nn_index: usize::MAX, distance: f64::INFINITY, label: 0 });
    let skip = initial.map(|r| r.nn_index);
    for (visited, &ti) in order.iter().enumerate() {
        if bounds[ti] >= best.distance {
            // Everything after this in sorted order is pruned too.
            stats.pruned += n - visited;
            break;
        }
        if Some(ti) == skip {
            continue;
        }
        stats.dtw_calls += 1;
        let d = dtw_ea::<D>(query, &train.series[ti].values, w, best.distance);
        if d.is_infinite() {
            stats.dtw_abandoned += 1;
        } else if d < best.distance {
            best = NnResult { nn_index: ti, distance: d, label: train.labels[ti] };
        }
    }
    (best, stats)
}

/// Reference brute-force search (no bounds) — ground truth for tests and
/// the "no lower bound" baseline.
pub fn nn_brute_force<D: Delta>(
    query: &[f64],
    train: &PreparedTrainSet,
) -> (NnResult, SearchStats) {
    let mut stats = SearchStats::default();
    let mut best = NnResult { nn_index: usize::MAX, distance: f64::INFINITY, label: 0 };
    for (ti, t) in train.series.iter().enumerate() {
        stats.dtw_calls += 1;
        let d = dtw_ea::<D>(query, &t.values, train.w, best.distance);
        if d.is_infinite() {
            stats.dtw_abandoned += 1;
        } else if d < best.distance {
            best = NnResult { nn_index: ti, distance: d, label: train.labels[ti] };
        }
    }
    (best, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;

    fn setup() -> (PreparedTrainSet, Vec<PreparedSeries>, Vec<u32>) {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 31))[2];
        let w = ds.window.max(1);
        let train = PreparedTrainSet::from_dataset(ds, w);
        let queries: Vec<PreparedSeries> = ds
            .test
            .iter()
            .map(|s| PreparedSeries::prepare(s.values.clone(), w))
            .collect();
        let labels = ds.test.iter().map(|s| s.label).collect();
        (train, queries, labels)
    }

    #[test]
    fn all_bounds_and_orders_agree_with_brute_force() {
        let (train, queries, _) = setup();
        let mut scratch = Scratch::default();
        let mut rng = Rng::seeded(1001);
        let mut bb = Vec::new();
        let mut ib = Vec::new();
        for q in &queries {
            let (truth, _) = nn_brute_force::<Squared>(&q.values, &train);
            for &bound in crate::bounds::BoundKind::ALL {
                let mut order: Vec<usize> = (0..train.len()).collect();
                rng.shuffle(&mut order);
                let (r1, s1) =
                    nn_random_order::<Squared>(q, &train, bound, &order, &mut scratch);
                assert_eq!(
                    r1.distance, truth.distance,
                    "{bound} random-order distance mismatch"
                );
                let (r2, _) =
                    nn_sorted::<Squared>(q, &train, bound, &mut scratch, &mut bb, &mut ib);
                assert_eq!(r2.distance, truth.distance, "{bound} sorted distance mismatch");
                // Same nearest distance implies same label under ties-by-index
                // not guaranteed; distances must match exactly though.
                assert!(s1.lb_calls <= train.len());
            }
        }
    }

    #[test]
    fn tighter_bound_prunes_no_less_when_sorted() {
        // In sorted order, pruning count is monotone in tightness for
        // bounds computed on identical data: Webb >= Keogh on average.
        let (train, queries, _) = setup();
        let mut scratch = Scratch::default();
        let (mut bb, mut ib) = (Vec::new(), Vec::new());
        let mut keogh_pruned = 0usize;
        let mut webb_pruned = 0usize;
        for q in &queries {
            let (_, s1) = nn_sorted::<Squared>(
                q,
                &train,
                BoundKind::Keogh,
                &mut scratch,
                &mut bb,
                &mut ib,
            );
            keogh_pruned += s1.pruned;
            let (_, s2) = nn_sorted::<Squared>(
                q,
                &train,
                BoundKind::Webb,
                &mut scratch,
                &mut bb,
                &mut ib,
            );
            webb_pruned += s2.pruned;
        }
        assert!(
            webb_pruned >= keogh_pruned,
            "webb pruned {webb_pruned} < keogh {keogh_pruned}"
        );
    }

    fn argsort(bounds: &[f64]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..bounds.len()).collect();
        order.sort_unstable_by(|&a, &b| bounds[a].partial_cmp(&bounds[b]).unwrap());
        order
    }

    #[test]
    fn precomputed_walk_matches_brute_force() {
        let (train, queries, _) = setup();
        let mut scratch = Scratch::default();
        for q in &queries {
            let (truth, _) = nn_brute_force::<Squared>(&q.values, &train);
            // Exact Keogh bounds, as a batched backend would deliver them.
            let bounds: Vec<f64> = train
                .series
                .iter()
                .map(|t| {
                    BoundKind::Keogh.compute::<Squared>(q, t, train.w, f64::INFINITY, &mut scratch)
                })
                .collect();
            let (r, _) = nn_sorted_precomputed::<Squared>(
                &q.values,
                &train,
                &bounds,
                &argsort(&bounds),
                None,
            );
            assert_eq!(r.distance, truth.distance, "unseeded walk");

            // Seeded variant: candidate 0's exact distance as the initial
            // best, and *partial* bounds abandoned against it.
            let seed = dtw_ea::<Squared>(&q.values, &train.series[0].values, train.w, f64::INFINITY);
            let partial: Vec<f64> = train
                .series
                .iter()
                .map(|t| BoundKind::Keogh.compute::<Squared>(q, t, train.w, seed, &mut scratch))
                .collect();
            let initial = NnResult { nn_index: 0, distance: seed, label: train.labels[0] };
            let (r2, _) = nn_sorted_precomputed::<Squared>(
                &q.values,
                &train,
                &partial,
                &argsort(&partial),
                Some(initial),
            );
            assert_eq!(r2.distance, truth.distance, "seeded walk with partial bounds");
        }
    }

    #[test]
    fn stats_are_consistent() {
        let (train, queries, _) = setup();
        let mut scratch = Scratch::default();
        let order: Vec<usize> = (0..train.len()).collect();
        let q = &queries[0];
        let (_, s) = nn_random_order::<Squared>(q, &train, BoundKind::Webb, &order, &mut scratch);
        // First candidate bypasses the bound.
        assert_eq!(s.lb_calls, train.len() - 1);
        assert_eq!(s.lb_calls, s.pruned + s.dtw_calls - 1);
    }
}
