//! The result/statistics types of nearest-neighbor search, plus the
//! **deprecated** 1-NN entry points of §6.2 (Algorithms 3 & 4).
//!
//! Since the `DtwIndex` facade landed, the search kernels live in
//! [`super::knn`], generalized to k-NN; the free functions here are thin
//! `k = 1` shims kept for one release. Migrate call sites to either:
//!
//! * the high-level facade — [`crate::index::DtwIndex::knn`] /
//!   [`crate::index::Searcher`] — which owns preparation, scratch and
//!   strategy selection; or
//! * the strategy kernels — [`super::knn::knn_random_order`],
//!   [`super::knn::knn_sorted`], [`super::knn::knn_sorted_precomputed`],
//!   [`super::knn::knn_brute_force`] — when you manage
//!   [`PreparedSeries`]/[`Scratch`] yourself.
//!
//! The algorithmic split (paper §6.2) is unchanged:
//!
//! * **Random order** (Algorithm 3): candidates are visited in a given
//!   order; the bound is computed *immediately before* the full distance
//!   and can therefore **early-abandon** against the best distance so far
//!   — the regime where `LB_PETITJEAN`'s expensive tightness pays.
//! * **Sorted** (Algorithm 4): bounds for *all* candidates are computed
//!   first, candidates are visited in ascending bound order, and search
//!   stops when the next bound exceeds the best distance — the regime
//!   where `LB_WEBB`'s low cost wins.

use crate::bounds::{BoundKind, PreparedSeries, Scratch};
use crate::delta::Delta;

use super::knn::{self, KnnParams};
use super::PreparedTrainSet;

/// Outcome of one nearest-neighbor query.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NnResult {
    /// Index of the nearest training series.
    pub nn_index: usize,
    /// Its DTW distance.
    pub distance: f64,
    /// Its label (the 1-NN prediction).
    pub label: u32,
}

impl NnResult {
    /// The "no neighbor found" sentinel (empty training set).
    pub fn none() -> NnResult {
        NnResult { nn_index: usize::MAX, distance: f64::INFINITY, label: 0 }
    }
}

/// Work counters for pruning-power analysis.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Lower-bound evaluations.
    pub lb_calls: usize,
    /// Candidates discarded by the bound alone.
    pub pruned: usize,
    /// Full DTW computations started.
    pub dtw_calls: usize,
    /// DTW computations abandoned early.
    pub dtw_abandoned: usize,
    /// Cluster-level merged-envelope bound evaluations (only nonzero
    /// when the index was built with `clusters > 0`).
    pub cluster_lb_calls: usize,
    /// Whole clusters skipped because their merged-envelope bound
    /// exceeded the cutoff.
    pub clusters_pruned: usize,
    /// Candidates skipped via cluster-level pruning — they were never
    /// individually bounded, so they do not appear in `lb_calls` or
    /// `pruned`.
    pub cluster_members_pruned: usize,
    /// Delta-shard candidates visited by a live index's append-log scan
    /// (zero on a frozen index). Every visited entry is also accounted
    /// in exactly one of `delta_pruned` / `delta_dtw`.
    pub delta_scanned: usize,
    /// Delta-shard candidates discarded by their per-candidate lower
    /// bound alone (subset of `pruned`).
    pub delta_pruned: usize,
    /// Delta-shard candidates that reached the exact DTW kernel (subset
    /// of `dtw_calls`).
    pub delta_dtw: usize,
}

impl SearchStats {
    /// Merge counters (for per-dataset aggregation).
    pub fn add(&mut self, other: &SearchStats) {
        self.lb_calls += other.lb_calls;
        self.pruned += other.pruned;
        self.dtw_calls += other.dtw_calls;
        self.dtw_abandoned += other.dtw_abandoned;
        self.cluster_lb_calls += other.cluster_lb_calls;
        self.clusters_pruned += other.clusters_pruned;
        self.cluster_members_pruned += other.cluster_members_pruned;
        self.delta_scanned += other.delta_scanned;
        self.delta_pruned += other.delta_pruned;
        self.delta_dtw += other.delta_dtw;
    }
}

fn first(mut results: Vec<NnResult>) -> NnResult {
    if results.is_empty() {
        NnResult::none()
    } else {
        results.swap_remove(0)
    }
}

/// Algorithm 3: random-order 1-NN search with early-abandoning bounds.
#[deprecated(
    since = "0.3.0",
    note = "use `index::DtwIndex` (strategy `RandomOrder`) or `search::knn::knn_random_order`"
)]
pub fn nn_random_order<D: Delta>(
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    order: &[usize],
    scratch: &mut Scratch,
) -> (NnResult, SearchStats) {
    let (r, stats) =
        knn::knn_random_order::<D>(query, train, bound, order, &KnnParams::default(), scratch);
    (first(r), stats)
}

/// Algorithm 4: bound-sorted 1-NN search.
#[deprecated(
    since = "0.3.0",
    note = "use `index::DtwIndex` (strategy `Sorted`) or `search::knn::knn_sorted`"
)]
#[allow(clippy::too_many_arguments)]
pub fn nn_sorted<D: Delta>(
    query: &PreparedSeries,
    train: &PreparedTrainSet,
    bound: BoundKind,
    scratch: &mut Scratch,
    bound_buf: &mut Vec<f64>,
    index_buf: &mut Vec<usize>,
) -> (NnResult, SearchStats) {
    let (r, stats) = knn::knn_sorted::<D>(
        query,
        train,
        bound,
        &KnnParams::default(),
        scratch,
        bound_buf,
        index_buf,
    );
    (first(r), stats)
}

/// Algorithm 4's walk over **precomputed** (possibly partial) bounds.
#[deprecated(
    since = "0.3.0",
    note = "use `index::Searcher::query_batch` or `search::knn::knn_sorted_precomputed`"
)]
pub fn nn_sorted_precomputed<D: Delta>(
    query: &[f64],
    train: &PreparedTrainSet,
    bounds: &[f64],
    order: &[usize],
    initial: Option<NnResult>,
) -> (NnResult, SearchStats) {
    let mut tail_buf = Vec::new();
    let (r, stats) = knn::knn_sorted_precomputed::<D>(
        query,
        train,
        bounds,
        order,
        initial,
        &KnnParams::default(),
        &mut tail_buf,
    );
    (first(r), stats)
}

/// Reference brute-force 1-NN search (no bounds).
#[deprecated(
    since = "0.3.0",
    note = "use `index::DtwIndex` (strategy `BruteForce`) or `search::knn::knn_brute_force`"
)]
pub fn nn_brute_force<D: Delta>(
    query: &[f64],
    train: &PreparedTrainSet,
) -> (NnResult, SearchStats) {
    let (r, stats) = knn::knn_brute_force::<D>(query, train, &KnnParams::default());
    (first(r), stats)
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;
    use crate::dtw::dtw_ea;

    fn setup() -> (PreparedTrainSet, Vec<PreparedSeries>, Vec<u32>) {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 31))[2];
        let w = ds.window.max(1);
        let train = PreparedTrainSet::from_dataset(ds, w);
        let queries: Vec<PreparedSeries> = ds
            .test
            .iter()
            .map(|s| PreparedSeries::prepare(s.values.clone(), w))
            .collect();
        let labels = ds.test.iter().map(|s| s.label).collect();
        (train, queries, labels)
    }

    #[test]
    fn all_bounds_and_orders_agree_with_brute_force() {
        let (train, queries, _) = setup();
        let mut scratch = Scratch::default();
        let mut rng = Rng::seeded(1001);
        let mut bb = Vec::new();
        let mut ib = Vec::new();
        for q in &queries {
            let (truth, _) = nn_brute_force::<Squared>(&q.values, &train);
            for &bound in crate::bounds::BoundKind::ALL {
                let mut order: Vec<usize> = (0..train.len()).collect();
                rng.shuffle(&mut order);
                let (r1, s1) =
                    nn_random_order::<Squared>(q, &train, bound, &order, &mut scratch);
                assert_eq!(
                    r1.distance, truth.distance,
                    "{bound} random-order distance mismatch"
                );
                let (r2, _) =
                    nn_sorted::<Squared>(q, &train, bound, &mut scratch, &mut bb, &mut ib);
                assert_eq!(r2.distance, truth.distance, "{bound} sorted distance mismatch");
                assert!(s1.lb_calls <= train.len());
            }
        }
    }

    #[test]
    fn tighter_bound_prunes_no_less_when_sorted() {
        // In sorted order, pruning count is monotone in tightness for
        // bounds computed on identical data: Webb >= Keogh on average.
        let (train, queries, _) = setup();
        let mut scratch = Scratch::default();
        let (mut bb, mut ib) = (Vec::new(), Vec::new());
        let mut keogh_pruned = 0usize;
        let mut webb_pruned = 0usize;
        for q in &queries {
            let (_, s1) = nn_sorted::<Squared>(
                q,
                &train,
                BoundKind::Keogh,
                &mut scratch,
                &mut bb,
                &mut ib,
            );
            keogh_pruned += s1.pruned;
            let (_, s2) = nn_sorted::<Squared>(
                q,
                &train,
                BoundKind::Webb,
                &mut scratch,
                &mut bb,
                &mut ib,
            );
            webb_pruned += s2.pruned;
        }
        assert!(
            webb_pruned >= keogh_pruned,
            "webb pruned {webb_pruned} < keogh {keogh_pruned}"
        );
    }

    fn argsort(bounds: &[f64]) -> Vec<usize> {
        let mut order: Vec<usize> = (0..bounds.len()).collect();
        order.sort_unstable_by(|&a, &b| bounds[a].partial_cmp(&bounds[b]).unwrap());
        order
    }

    #[test]
    fn precomputed_walk_matches_brute_force() {
        let (train, queries, _) = setup();
        let mut scratch = Scratch::default();
        for q in &queries {
            let (truth, _) = nn_brute_force::<Squared>(&q.values, &train);
            // Exact Keogh bounds, as a batched backend would deliver them.
            let bounds: Vec<f64> = train
                .series
                .iter()
                .map(|t| {
                    BoundKind::Keogh.compute::<Squared>(q, t, train.w, f64::INFINITY, &mut scratch)
                })
                .collect();
            let (r, _) = nn_sorted_precomputed::<Squared>(
                &q.values,
                &train,
                &bounds,
                &argsort(&bounds),
                None,
            );
            assert_eq!(r.distance, truth.distance, "unseeded walk");

            // Seeded variant: candidate 0's exact distance as the initial
            // best, and *partial* bounds abandoned against it.
            let seed = dtw_ea::<Squared>(&q.values, &train.series[0].values, train.w, f64::INFINITY);
            let partial: Vec<f64> = train
                .series
                .iter()
                .map(|t| BoundKind::Keogh.compute::<Squared>(q, t, train.w, seed, &mut scratch))
                .collect();
            let initial = NnResult { nn_index: 0, distance: seed, label: train.labels[0] };
            let (r2, _) = nn_sorted_precomputed::<Squared>(
                &q.values,
                &train,
                &partial,
                &argsort(&partial),
                Some(initial),
            );
            assert_eq!(r2.distance, truth.distance, "seeded walk with partial bounds");
        }
    }

    #[test]
    fn stats_are_consistent() {
        let (train, queries, _) = setup();
        let mut scratch = Scratch::default();
        let order: Vec<usize> = (0..train.len()).collect();
        let q = &queries[0];
        let (_, s) = nn_random_order::<Squared>(q, &train, BoundKind::Webb, &order, &mut scratch);
        // First candidate bypasses the bound.
        assert_eq!(s.lb_calls, train.len() - 1);
        assert_eq!(s.lb_calls, s.pruned + s.dtw_calls - 1);
    }
}
