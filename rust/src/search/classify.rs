//! Dataset-level 1-NN classification runs — the timed unit of every
//! experiment in §6.2/§6.3, built on the [`crate::index::DtwIndex`]
//! facade.
//!
//! Reproduces the paper's protocol exactly:
//! * training envelopes are **pre**computed (not timed) — they live in
//!   the index, built before the clock starts;
//! * query envelopes (and envelope-of-envelopes) are computed once per
//!   query and **are** timed, but only when the bound needs them
//!   (the facade's [`crate::bounds::BoundKind::prepare_query`]);
//! * projection envelopes (inside `LB_IMPROVED`/`LB_PETITJEAN`) are per
//!   pair and timed;
//! * random-order runs shuffle the candidate order per query with a
//!   seeded RNG and early-abandon both bound and DTW.

use std::time::{Duration, Instant};

use crate::bounds::BoundKind;
use crate::data::Dataset;
use crate::delta::Delta;
use crate::index::{DtwIndex, QueryOptions};

use super::nn::{NnResult, SearchStats};
use super::SearchStrategy;

/// Former name of the strategy axis; two of its variants
/// (`RandomOrder`, `Sorted`) were the paper's modes.
#[deprecated(since = "0.3.0", note = "use `search::SearchStrategy`")]
pub type SearchMode = SearchStrategy;

/// Result of classifying one dataset's full test set.
#[derive(Debug, Clone)]
pub struct ClassifyOutcome {
    /// Dataset name.
    pub dataset: String,
    /// Bound used.
    pub bound: BoundKind,
    /// Search strategy.
    pub strategy: SearchStrategy,
    /// Window used.
    pub w: usize,
    /// 1-NN classification accuracy.
    pub accuracy: f64,
    /// Wall-clock time for the whole test set (excluding train prep).
    pub elapsed: Duration,
    /// Aggregated work counters.
    pub stats: SearchStats,
    /// Per-query nearest neighbors (for cross-bound agreement checks).
    pub neighbors: Vec<NnResult>,
}

/// Classify every test series of `ds` with 1-NN DTW through `index`
/// (whose bound, strategy and window are the experiment cell). `seed`
/// drives the per-query candidate shuffle in random-order mode.
///
/// The index must have been built over `ds`'s training split — use
/// [`DtwIndex::builder_from_dataset`] plus
/// [`DtwIndex::with_bound`]/[`DtwIndex::with_strategy`] for the
/// per-cell variations (the prepared envelopes are shared, not
/// recomputed).
pub fn classify_dataset<D: Delta>(ds: &Dataset, index: &DtwIndex, seed: u64) -> ClassifyOutcome {
    let mut searcher = index.searcher();
    searcher.reseed(seed);

    let mut correct = 0usize;
    let mut stats = SearchStats::default();
    let mut neighbors = Vec::with_capacity(ds.test.len());

    let opts = QueryOptions::default();
    let started = Instant::now();
    for q in &ds.test {
        // Query preparation happens inside the searcher and is timed
        // (paper: "Calculate and save U^Q and L^Q" sits inside the
        // per-query loop), skipped when the bound does not read it.
        let out = searcher.query_values::<D>(&q.values, &opts);
        stats.add(&out.stats);
        let best = out.best_nn();
        if best.label == q.label {
            correct += 1;
        }
        neighbors.push(best);
    }
    let elapsed = started.elapsed();

    ClassifyOutcome {
        dataset: ds.name.clone(),
        bound: index.bound(),
        strategy: index.strategy(),
        w: index.window(),
        accuracy: correct as f64 / ds.test.len().max(1) as f64,
        elapsed,
        stats,
        neighbors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;

    #[test]
    fn all_bounds_find_identical_nearest_distances() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 55))[3];
        let index = DtwIndex::builder_from_dataset(ds).build().unwrap();
        let reference = classify_dataset::<Squared>(
            ds,
            &index.with_bound(BoundKind::Keogh).with_strategy(SearchStrategy::Sorted),
            9,
        );
        for &bound in BoundKind::ALL {
            for strategy in [SearchStrategy::RandomOrder, SearchStrategy::Sorted] {
                let cell = index.with_bound(bound).with_strategy(strategy);
                let out = classify_dataset::<Squared>(ds, &cell, 9);
                assert_eq!(out.accuracy, reference.accuracy, "{bound} {strategy}");
                for (a, b) in out.neighbors.iter().zip(reference.neighbors.iter()) {
                    assert!(
                        (a.distance - b.distance).abs() < 1e-9,
                        "{bound} {strategy}: {} vs {}",
                        a.distance,
                        b.distance
                    );
                }
            }
        }
    }

    #[test]
    fn brute_force_strategy_is_the_same_answer_without_bounds() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 55))[2];
        let index = DtwIndex::builder_from_dataset(ds).build().unwrap();
        let sorted = classify_dataset::<Squared>(ds, &index, 5);
        let brute = classify_dataset::<Squared>(
            ds,
            &index.with_strategy(SearchStrategy::BruteForce),
            5,
        );
        assert_eq!(brute.accuracy, sorted.accuracy);
        assert_eq!(brute.stats.lb_calls, 0, "brute force never calls a bound");
        for (a, b) in brute.neighbors.iter().zip(sorted.neighbors.iter()) {
            assert_eq!(a.distance, b.distance);
        }
    }

    #[test]
    fn pruning_reduces_dtw_calls() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 55))[1];
        let index = DtwIndex::builder_from_dataset(ds)
            .bound(BoundKind::Webb)
            .strategy(SearchStrategy::Sorted)
            .build()
            .unwrap();
        let out = classify_dataset::<Squared>(ds, &index, 1);
        let max_calls = ds.test.len() * index.len();
        assert!(
            out.stats.dtw_calls < max_calls,
            "no pruning at all: {} vs {max_calls}",
            out.stats.dtw_calls
        );
    }
}
