//! Dataset-level 1-NN classification runs — the timed unit of every
//! experiment in §6.2/§6.3.
//!
//! Reproduces the paper's protocol exactly:
//! * training envelopes are **pre**computed (not timed);
//! * query envelopes (and envelope-of-envelopes) are computed once per
//!   query and **are** timed, but only when the bound needs them;
//! * projection envelopes (inside `LB_IMPROVED`/`LB_PETITJEAN`) are per
//!   pair and timed;
//! * random-order runs shuffle the candidate order per query with a
//!   seeded RNG and early-abandon both bound and DTW.

use std::time::{Duration, Instant};

use crate::bounds::{BoundKind, PreparedSeries, Scratch};
use crate::data::rng::Rng;
use crate::data::Dataset;
use crate::delta::Delta;

use super::nn::{nn_random_order, nn_sorted, NnResult, SearchStats};
use super::PreparedTrainSet;

/// Which of the paper's two search procedures to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// Algorithm 3 — random order, early abandoning.
    RandomOrder,
    /// Algorithm 4 — candidates sorted by lower bound.
    Sorted,
}

impl SearchMode {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "random" | "rand" | "random-order" => Some(Self::RandomOrder),
            "sorted" | "sort" => Some(Self::Sorted),
            _ => None,
        }
    }
}

/// Result of classifying one dataset's full test set.
#[derive(Debug, Clone)]
pub struct ClassifyOutcome {
    /// Dataset name.
    pub dataset: String,
    /// Bound used.
    pub bound: BoundKind,
    /// Search procedure.
    pub mode: SearchMode,
    /// Window used.
    pub w: usize,
    /// 1-NN classification accuracy.
    pub accuracy: f64,
    /// Wall-clock time for the whole test set (excluding train prep).
    pub elapsed: Duration,
    /// Aggregated work counters.
    pub stats: SearchStats,
    /// Per-query nearest neighbors (for cross-bound agreement checks).
    pub neighbors: Vec<NnResult>,
}

/// Classify every test series of `ds` with 1-NN DTW using `bound` under
/// `mode`. `train` must be prepared for the same window. `seed` drives
/// the per-query candidate shuffle in random-order mode.
pub fn classify_dataset<D: Delta>(
    ds: &Dataset,
    train: &PreparedTrainSet,
    bound: BoundKind,
    mode: SearchMode,
    seed: u64,
) -> ClassifyOutcome {
    let w = train.w;
    let mut rng = Rng::seeded(seed);
    let mut scratch = Scratch::default();
    let mut bound_buf: Vec<f64> = Vec::new();
    let mut index_buf: Vec<usize> = Vec::new();
    let mut order: Vec<usize> = (0..train.len()).collect();

    let needs_q_env = bound.requires_query_envelopes();
    let mut correct = 0usize;
    let mut stats = SearchStats::default();
    let mut neighbors = Vec::with_capacity(ds.test.len());

    let started = Instant::now();
    for q in &ds.test {
        // Query preparation is timed (paper: "Calculate and save U^Q and
        // L^Q" sits inside the per-query loop) but skipped when the bound
        // does not read it.
        let pq = if needs_q_env {
            PreparedSeries::prepare(q.values.clone(), w)
        } else {
            PreparedSeries {
                values: q.values.clone(),
                w,
                lo: Vec::new(),
                up: Vec::new(),
                lo_of_up: Vec::new(),
                up_of_lo: Vec::new(),
            }
        };
        let (result, qstats) = match mode {
            SearchMode::RandomOrder => {
                rng.shuffle(&mut order);
                nn_random_order::<D>(&pq, train, bound, &order, &mut scratch)
            }
            SearchMode::Sorted => nn_sorted::<D>(
                &pq,
                train,
                bound,
                &mut scratch,
                &mut bound_buf,
                &mut index_buf,
            ),
        };
        stats.add(&qstats);
        if result.label == q.label {
            correct += 1;
        }
        neighbors.push(result);
    }
    let elapsed = started.elapsed();

    ClassifyOutcome {
        dataset: ds.name.clone(),
        bound,
        mode,
        w,
        accuracy: correct as f64 / ds.test.len().max(1) as f64,
        elapsed,
        stats,
        neighbors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;

    #[test]
    fn all_bounds_find_identical_nearest_distances() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 55))[3];
        let w = ds.window.max(1);
        let train = PreparedTrainSet::from_dataset(ds, w);
        let reference = classify_dataset::<Squared>(
            ds,
            &train,
            BoundKind::Keogh,
            SearchMode::Sorted,
            9,
        );
        for &bound in BoundKind::ALL {
            for mode in [SearchMode::RandomOrder, SearchMode::Sorted] {
                let out = classify_dataset::<Squared>(ds, &train, bound, mode, 9);
                assert_eq!(out.accuracy, reference.accuracy, "{bound} {mode:?}");
                for (a, b) in out.neighbors.iter().zip(reference.neighbors.iter()) {
                    assert!(
                        (a.distance - b.distance).abs() < 1e-9,
                        "{bound} {mode:?}: {} vs {}",
                        a.distance,
                        b.distance
                    );
                }
            }
        }
    }

    #[test]
    fn pruning_reduces_dtw_calls() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 55))[1];
        let w = ds.window.max(1);
        let train = PreparedTrainSet::from_dataset(ds, w);
        let out =
            classify_dataset::<Squared>(ds, &train, BoundKind::Webb, SearchMode::Sorted, 1);
        let max_calls = ds.test.len() * train.len();
        assert!(
            out.stats.dtw_calls < max_calls,
            "no pruning at all: {} vs {max_calls}",
            out.stats.dtw_calls
        );
    }
}
