//! Nearest-neighbor DTW search — the paper's application and evaluation
//! harness (§6).
//!
//! * [`nn`] — the two search procedures: Algorithm 3 (**random order**,
//!   bound and DTW both early-abandon against the best-so-far) and
//!   Algorithm 4 (**sorted**: bound every candidate, walk in ascending
//!   bound order until the next bound exceeds the best distance).
//! * [`classify`] — 1-NN classification over a dataset with either
//!   procedure, including the per-query envelope bookkeeping the paper
//!   times (training envelopes precomputed, query envelopes once per
//!   query, projection envelopes per pair).
//! * [`tightness`] — mean `λ_w(Q,T)/DTW_w(Q,T)` per dataset (§6.1).
//! * [`loocv`] — leave-one-out window selection (how the archive derives
//!   its recommended windows).

pub mod classify;
pub mod loocv;
pub mod nn;
pub mod tightness;

use crate::bounds::PreparedSeries;
use crate::data::Dataset;

/// A training set prepared for a specific window: per-series envelopes
/// (and envelope-of-envelopes) computed once, as the paper's experimental
/// protocol prescribes ("the envelopes for the training series are
/// precalculated and the time for calculating these envelopes is not
/// included in the experimental timings").
#[derive(Debug, Clone)]
pub struct PreparedTrainSet {
    /// Labels, parallel to `series`.
    pub labels: Vec<u32>,
    /// Prepared training series.
    pub series: Vec<PreparedSeries>,
    /// The window the preparation is valid for.
    pub w: usize,
}

impl PreparedTrainSet {
    /// Prepare every training series of a dataset for window `w`.
    pub fn from_dataset(ds: &Dataset, w: usize) -> Self {
        let labels = ds.train.iter().map(|s| s.label).collect();
        let series = ds
            .train
            .iter()
            .map(|s| PreparedSeries::prepare(s.values.clone(), w))
            .collect();
        PreparedTrainSet { labels, series, w }
    }

    /// Number of training series.
    pub fn len(&self) -> usize {
        self.series.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }
}
