//! Nearest-neighbor DTW search — the paper's application and evaluation
//! harness (§6), generalized to k-NN behind the
//! [`crate::index::DtwIndex`] facade.
//!
//! * [`knn`] — the k-NN strategy kernels: Algorithm 3 (**random order**,
//!   bound and DTW both early-abandon against the k-th best so far),
//!   Algorithm 4 (**sorted**: bound every candidate, walk in ascending
//!   bound order until the next bound exceeds the k-th best distance),
//!   the precomputed-bound walk fed by batched
//!   [`crate::runtime::LbBackend`]s, the candidate-parallel
//!   [`knn::knn_parallel`] and shard-parallel [`knn::knn_sharded`]
//!   (shared atomic cutoff, identical results at every thread and
//!   shard count), and the brute-force baseline. Every kernel's
//!   exact-DTW tail runs [`crate::dtw::dtw_ea_pruned`] with the
//!   candidate-envelope cumulative-lower-bound tail.
//! * [`nn`] — the result/statistics types plus the deprecated 1-NN
//!   entry points (thin `k = 1` shims over [`knn`]).
//! * [`classify`] — 1-NN classification over a dataset with any
//!   [`SearchStrategy`], including the per-query envelope bookkeeping the
//!   paper times (training envelopes precomputed, query envelopes once
//!   per query, projection envelopes per pair).
//! * [`tightness`] — mean `λ_w(Q,T)/DTW_w(Q,T)` per dataset (§6.1).
//! * [`loocv`] — leave-one-out window selection (how the archive derives
//!   its recommended windows), built on the facade's self-match
//!   exclusion.
//!
//! ## Example
//!
//! The kernels are usable directly when you manage preparation yourself
//! (most callers should go through [`crate::index::DtwIndex`] instead):
//!
//! ```
//! use dtw_bounds::bounds::{BoundKind, PreparedSeries, Scratch};
//! use dtw_bounds::delta::Squared;
//! use dtw_bounds::search::knn::{knn_brute_force, knn_sorted, KnnParams};
//! use dtw_bounds::search::{PreparedTrainSet, SearchStrategy};
//!
//! let w = 1;
//! let train = PreparedTrainSet {
//!     labels: vec![0, 1],
//!     series: vec![
//!         PreparedSeries::prepare(vec![0.0, 0.1, 0.2, 0.1], w),
//!         PreparedSeries::prepare(vec![9.0, 9.1, 9.2, 9.1], w),
//!     ],
//!     w,
//! };
//! let q = BoundKind::Webb.prepare_query(vec![0.05, 0.15, 0.25, 0.15], w);
//! let mut scratch = Scratch::new(q.len());
//! let (mut bound_buf, mut index_buf) = (Vec::new(), Vec::new());
//! let (hits, _stats) = knn_sorted::<Squared>(
//!     &q, &train, BoundKind::Webb, &KnnParams::k(1), &mut scratch,
//!     &mut bound_buf, &mut index_buf,
//! );
//! let (truth, _) = knn_brute_force::<Squared>(&q.values, &train, &KnnParams::k(1));
//! assert_eq!(hits[0].distance, truth[0].distance, "sorted search is exact");
//! assert_eq!(hits[0].label, 0);
//! assert_eq!(SearchStrategy::parse("sorted"), Some(SearchStrategy::Sorted));
//! ```

pub mod classify;
pub mod knn;
pub mod loocv;
pub mod nn;
pub mod tightness;

use crate::bounds::PreparedSeries;
use crate::data::Dataset;

/// Which search procedure answers a query — the strategy axis of the
/// [`crate::index::DtwIndex`] facade.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SearchStrategy {
    /// Algorithm 3: random candidate order; both the bound and DTW
    /// early-abandon against the k-th best distance so far. The regime
    /// where `LB_PETITJEAN`'s expensive tightness pays (§6.2).
    RandomOrder,
    /// Algorithm 4: bound every candidate, then visit in ascending-bound
    /// order until the next bound exceeds the k-th best distance. The
    /// regime where `LB_WEBB`'s low cost wins (§6.2).
    Sorted,
    /// Algorithm 4's walk over a bound matrix a batched
    /// [`crate::runtime::LbBackend`] computed for a whole query batch;
    /// lone queries fall back to [`SearchStrategy::Sorted`].
    SortedPrecomputed,
    /// Exhaustive DTW, no bounds — the ground-truth baseline.
    BruteForce,
}

impl SearchStrategy {
    /// Every strategy, in documentation order.
    pub const ALL: &'static [SearchStrategy] = &[
        SearchStrategy::RandomOrder,
        SearchStrategy::Sorted,
        SearchStrategy::SortedPrecomputed,
        SearchStrategy::BruteForce,
    ];

    /// Parse a CLI spelling (case-insensitive, `-`/`_` ignored):
    /// `random`, `sorted`, `precomputed`/`batched`, `brute`.
    pub fn parse(s: &str) -> Option<SearchStrategy> {
        match s.to_ascii_lowercase().replace(['-', '_'], "").as_str() {
            "random" | "rand" | "randomorder" => Some(SearchStrategy::RandomOrder),
            "sorted" | "sort" => Some(SearchStrategy::Sorted),
            "precomputed" | "sortedprecomputed" | "batched" => {
                Some(SearchStrategy::SortedPrecomputed)
            }
            "brute" | "bruteforce" | "linear" => Some(SearchStrategy::BruteForce),
            _ => None,
        }
    }

    /// Canonical (re-parseable) name.
    pub fn name(&self) -> &'static str {
        match self {
            SearchStrategy::RandomOrder => "random-order",
            SearchStrategy::Sorted => "sorted",
            SearchStrategy::SortedPrecomputed => "sorted-precomputed",
            SearchStrategy::BruteForce => "brute-force",
        }
    }
}

impl std::fmt::Display for SearchStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A training set prepared for a specific window: per-series envelopes
/// (and envelope-of-envelopes) computed once, as the paper's experimental
/// protocol prescribes ("the envelopes for the training series are
/// precalculated and the time for calculating these envelopes is not
/// included in the experimental timings").
#[derive(Debug, Clone)]
pub struct PreparedTrainSet {
    /// Labels, parallel to `series`.
    pub labels: Vec<u32>,
    /// Prepared training series.
    pub series: Vec<PreparedSeries>,
    /// The window the preparation is valid for.
    pub w: usize,
}

impl PreparedTrainSet {
    /// Prepare every training series of a dataset for window `w`.
    pub fn from_dataset(ds: &Dataset, w: usize) -> Self {
        let labels = ds.train.iter().map(|s| s.label).collect();
        let series = ds
            .train
            .iter()
            .map(|s| PreparedSeries::prepare(s.values.clone(), w))
            .collect();
        PreparedTrainSet { labels, series, w }
    }

    /// Number of training series.
    pub fn len(&self) -> usize {
        self.series.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.series.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_name_parse_roundtrip() {
        for &s in SearchStrategy::ALL {
            assert_eq!(SearchStrategy::parse(s.name()), Some(s), "{s}");
        }
        // Legacy CLI spellings stay accepted.
        assert_eq!(SearchStrategy::parse("random"), Some(SearchStrategy::RandomOrder));
        assert_eq!(SearchStrategy::parse("sort"), Some(SearchStrategy::Sorted));
        assert_eq!(SearchStrategy::parse("batched"), Some(SearchStrategy::SortedPrecomputed));
        assert_eq!(SearchStrategy::parse("bogus"), None);
    }
}
