//! L3 coordinator — the serving layer that turns the bound library into a
//! nearest-neighbor search service.
//!
//! The paper's contribution is algorithmic, so the coordinator is the
//! deployment shell around it (DESIGN.md §2):
//!
//! * [`pool`] — a std-thread worker pool (`tokio` is unavailable in the
//!   offline build; see DESIGN.md §5) used for dataset-parallel
//!   experiment execution, with per-worker state (`map_init`).
//! * [`engine`] — the query engine: a per-thread
//!   [`crate::index::Searcher`] over a shared [`crate::index::DtwIndex`]
//!   plus an optional batched screening backend
//!   ([`crate::runtime::LbBackend`]), answering exact k-NN DTW queries.
//! * [`router`] — request router, **dynamic batcher** and multi-shard
//!   coordinator: concurrent clients enqueue queries; the dispatch loop
//!   drains the queue and routes a full batch through the engine's
//!   backend (native Rust by default, one XLA execution per batch with
//!   the `pjrt` feature) or single queries through the scalar path,
//!   whichever is available/profitable. Snapshot control rides the same
//!   loop: [`Router::save_snapshot`] serializes the served index to a
//!   generation-versioned path and [`Router::load_snapshot`] hot-swaps
//!   onto a persisted one (the `save=`/`load=` protocol verbs). Live
//!   mutation rides it too: [`Router::insert`], [`Router::delete`] and
//!   [`Router::compact`] (the `insert=`/`delete=`/`compact=` verbs)
//!   mutate the engine's delta shard / tombstone overlay between
//!   batches, keeping every search path bit-identical to a cold
//!   rebuild; [`Router::generations`] (`gens=`) reports the lineage.
//! * [`server`] — a line-protocol TCP front end over the router (used by
//!   `examples/serve.rs`; the wire format is specified with worked
//!   examples in `docs/protocol.md`).
//!
//! ## Hardening & durability
//!
//! The serving path is defended end to end. The server caps request
//! size and idle time per connection ([`ServerOptions`]; `err=too-large`
//! / `err=timeout`). The router bounds its control/mutation queue and
//! sheds overload with [`Busy`] (`err=busy`), isolates panicking
//! requests behind `catch_unwind` so one bad query fails alone
//! (`err=internal`), and exposes its counters through
//! [`Router::stats`] (the `stats=` verb). Mutations accepted while
//! serving from a snapshot anchor are made crash-durable through the
//! engine's write-ahead log ([`crate::live::wal`]): appended and
//! (per [`crate::live::FsyncPolicy`]) fsynced *before* the ack, and
//! replayed through the identical mutation path on restart, so recovery
//! is bit-equal to an uninterrupted run.
//!
//! ## Example
//!
//! A router over a shared index answers exact k-NN queries from any
//! thread, and serves streaming subsequence searches on the same
//! dispatch thread:
//!
//! ```
//! use std::sync::Arc;
//! use dtw_bounds::coordinator::Router;
//! use dtw_bounds::index::{DtwIndex, QueryOptions};
//! use dtw_bounds::stream::SubsequenceOptions;
//!
//! let index = DtwIndex::builder(vec![
//!     vec![0.0, 0.1, 0.2, 0.1],
//!     vec![5.0, 5.1, 5.2, 5.1],
//! ])
//! .labels(vec![0, 1])
//! .window(1)
//! .build()?;
//! let router = Arc::new(Router::spawn_index(index));
//!
//! let out = router.query_with(vec![0.05, 0.1, 0.2, 0.1], QueryOptions::k(1));
//! assert_eq!(out.best().unwrap().label, 0);
//!
//! let report = router.stream(
//!     vec![9.0, 9.0, 0.0, 0.1, 0.2, 0.1, 9.0],
//!     SubsequenceOptions::threshold(1e-3),
//! )?;
//! assert_eq!(report.matches[0].start, 2); // the embedded pattern
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod engine;
pub mod pool;
pub mod router;
pub mod server;

pub use engine::{EnginePath, GenerationInfo, NnEngine, QueryResponse};
pub use pool::WorkerPool;
pub use router::{
    Busy, CompactReceipt, DeleteReceipt, InsertReceipt, Router, RouterStats,
    SnapshotLoaded, SnapshotSaved,
};
pub use server::{Server, ServerOptions};
