//! L3 coordinator — the serving layer that turns the bound library into a
//! nearest-neighbor search service.
//!
//! The paper's contribution is algorithmic, so the coordinator is the
//! deployment shell around it (DESIGN.md §2):
//!
//! * [`pool`] — a std-thread worker pool (`tokio` is unavailable in the
//!   offline build; see DESIGN.md §5) used for dataset-parallel
//!   experiment execution, with per-worker state (`map_init`).
//! * [`engine`] — the query engine: a per-thread
//!   [`crate::index::Searcher`] over a shared [`crate::index::DtwIndex`]
//!   plus an optional batched screening backend
//!   ([`crate::runtime::LbBackend`]), answering exact k-NN DTW queries.
//! * [`router`] — request router and **dynamic batcher**: concurrent
//!   clients enqueue queries; the dispatch loop drains the queue and
//!   routes a full batch through the engine's backend (native Rust by
//!   default, one XLA execution per batch with the `pjrt` feature) or
//!   single queries through the scalar path, whichever is
//!   available/profitable.
//! * [`server`] — a line-protocol TCP front end over the router (used by
//!   `examples/serve.rs`).

pub mod engine;
pub mod pool;
pub mod router;
pub mod server;

pub use engine::{EnginePath, NnEngine, QueryResponse};
pub use pool::WorkerPool;
pub use router::{Router, RouterStats};
