//! A small scoped worker pool over `std::thread` + `mpsc`.
//!
//! Drives dataset-parallel experiment runs (each worker owns its own
//! `Scratch`). The pool is order-preserving: `map` returns outputs in
//! input order regardless of completion order.

use std::sync::mpsc;
use std::sync::Mutex;

/// A fixed-size worker pool.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// Pool sized to the machine (`available_parallelism`, min 1).
    pub fn auto() -> Self {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        WorkerPool { threads }
    }

    /// Pool with an explicit thread count (≥ 1).
    pub fn with_threads(threads: usize) -> Self {
        WorkerPool { threads: threads.max(1) }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every item, in parallel, preserving input order.
    ///
    /// `f` must be `Sync` (shared across workers); items and outputs move
    /// across threads.
    pub fn map<I, O, F>(&self, items: Vec<I>, f: F) -> Vec<O>
    where
        I: Send,
        O: Send,
        F: Fn(I) -> O + Sync,
    {
        self.map_init(items, || (), |_, item| f(item))
    }

    /// Like [`WorkerPool::map`], but each worker first builds private
    /// per-thread state with `init` — a `Scratch`, a prepared query
    /// buffer, or a screening backend — which `f` receives by `&mut`.
    /// State is built once per worker, not once per item, so expensive
    /// setup amortizes across the worker's share of the queue.
    pub fn map_init<I, O, S, N, F>(&self, items: Vec<I>, init: N, f: F) -> Vec<O>
    where
        I: Send,
        O: Send,
        N: Fn() -> S + Sync,
        F: Fn(&mut S, I) -> O + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        if self.threads == 1 || n == 1 {
            let mut state = init();
            return items.into_iter().map(|item| f(&mut state, item)).collect();
        }

        // Shared work queue of (index, item); results sent back with index.
        let queue: Mutex<std::vec::IntoIter<(usize, I)>> =
            Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
        let (tx, rx) = mpsc::channel::<(usize, O)>();

        std::thread::scope(|scope| {
            for _ in 0..self.threads.min(n) {
                let tx = tx.clone();
                let queue = &queue;
                let init = &init;
                let f = &f;
                scope.spawn(move || {
                    let mut state = init();
                    loop {
                        let next = queue.lock().unwrap().next();
                        match next {
                            Some((i, item)) => {
                                if tx.send((i, f(&mut state, item))).is_err() {
                                    return;
                                }
                            }
                            None => return,
                        }
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<O>> = (0..n).map(|_| None).collect();
            for (i, o) in rx {
                out[i] = Some(o);
            }
            out.into_iter().map(|o| o.expect("worker delivered all items")).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let pool = WorkerPool::with_threads(4);
        let out = pool.map((0..100).collect(), |x: i32| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let pool = WorkerPool::with_threads(1);
        assert_eq!(pool.map(vec![1, 2, 3], |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let pool = WorkerPool::auto();
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn auto_pool_is_nonzero() {
        assert!(WorkerPool::auto().threads() >= 1);
    }

    #[test]
    fn map_init_reuses_per_worker_state() {
        let pool = WorkerPool::with_threads(3);
        // Each worker counts how many items it processed in its own
        // state; outputs stay order-preserving and correct.
        let out = pool.map_init(
            (0..50).collect::<Vec<i64>>(),
            || 0i64,
            |seen, x| {
                *seen += 1;
                assert!(*seen >= 1);
                x * 2
            },
        );
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }
}
