//! TCP line-protocol front end over the [`Router`].
//!
//! Protocol (one line per message, UTF-8; the full specification with
//! worked request/response examples lives in `docs/protocol.md`):
//! * request:  `v1,v2,...,vN` — comma-separated series values (1-NN), or
//!   `k=<n>;v1,v2,...,vN` for the `n` nearest neighbors. A
//!   `threads=<n>;` prefix (combinable with `k=`, any order) screens
//!   this query's candidates on `n` workers (`0` = machine
//!   parallelism) on the scalar paths — batched prefilter executions
//!   use the server-wide `--threads` instead. Results are identical
//!   at every thread count either way;
//! * 1-NN response: `label=<u32> dist=<f64> nn=<usize>
//!   path=<scalar|batched> us=<u128>`;
//! * k-NN response: `k=<n> neighbors=<idx>:<label>:<dist>,...
//!   path=<scalar|batched> us=<u128>` (neighbors ascending by distance);
//! * subsequence search: `stream=<params>;v1,v2,...,vN` where `<params>`
//!   is a comma-separated list of `tau:<f>`, `k:<n>`, `hop:<n>`,
//!   `znorm:<0|1>` (at least one of `tau`/`k`); the payload is a finite
//!   sample stream, matched by sliding index-length windows (see
//!   [`crate::stream`]). Response: `stream
//!   matches=<start>:<neighbor>:<label>:<dist>,... windows=<n>
//!   pruned=<p> dtw=<d> us=<u128>` (`matches=-` when none);
//! * snapshot control: `save=<path>;` serializes the served index to a
//!   **generation-versioned** snapshot at `<path>.g<N>` (`saved
//!   path=<p> bytes=<n>` carries the actual path); `load=<path>;`
//!   hot-swaps the served index from a snapshot — loading an older
//!   generation is a rollback (`loaded series=<n> shards=<s>
//!   window=<w>`). Failures answer a machine-parseable `err=<verb>
//!   <path>: <why>` line with a distinct reason per failure mode (io,
//!   bad magic, unsupported version, checksum mismatch, corruption)
//!   and leave the served index intact;
//! * live mutation: `insert=<label>;v1,v2,...,vN` appends a series to
//!   the delta shard (`inserted id=<n> delta=<d> generation=<g>`);
//!   `delete=<id>;` removes the series at logical id `<id>` (`deleted
//!   id=<n> remaining=<r> tombstones=<t>`); `compact=;` merges the
//!   delta and tombstones into the next generation (`compacted
//!   generation=<g> series=<n>`); `gens=;` reports the lineage (`gens
//!   generation=<g> parent=<p> delta=<d> tombstones=<t>
//!   saved=<g:path,...|->`). Every search path stays bit-identical to
//!   a cold rebuild over the mutated series set; failures answer
//!   `err=<verb> <why>` and leave the served index intact;
//! * observability: `stats=;` dumps the router's counters and gauges
//!   plus the active SIMD ISA (`stats served=<n> ... panics=<n>
//!   shed=<n> wal_records=<n> isa=<scalar|sse2|avx2|neon>`);
//! * `PING` → `PONG`; malformed input → `ERR <why>`.
//!
//! One thread per connection feeds the shared router, whose dispatch loop
//! batches across connections — concurrent clients automatically share
//! batched prefilter executions on whichever
//! [`crate::runtime::LbBackend`] the engine carries. `stream=` requests
//! run after any queued query batch so they never delay the
//! latency-sensitive k-NN path.
//!
//! ## Hardening ([`ServerOptions`])
//!
//! Connections are defended against slow and abusive clients:
//!
//! * **Bounded requests** — a line longer than
//!   [`ServerOptions::max_request`] bytes is discarded (consumed up to
//!   its newline, never buffered) and answered `err=too-large …`; the
//!   connection stays usable. This applies to *every* verb, including
//!   the legacy bare-query and `stream=` payload paths.
//! * **Read timeouts** — with [`ServerOptions::read_timeout`] set, a
//!   connection idle past the deadline is answered `err=timeout …` and
//!   closed, so stalled clients cannot pin connection threads forever.
//! * **Overload + panic mapping** — router shedding surfaces as
//!   `err=busy …`; a request whose dispatch-side execution panicked
//!   (reply channel dropped) surfaces as `err=internal …`. Neither
//!   kills the connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::index::QueryOptions;
use crate::stream::SubsequenceOptions;

use super::engine::{EnginePath, QueryResponse};
use super::router::{Busy, Router};

/// Per-connection serving limits and defaults.
#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// `k` applied to requests without a `k=` prefix.
    pub default_k: usize,
    /// Close a connection idle longer than this (`err=timeout`);
    /// `None` = wait forever (trusted/test clients).
    pub read_timeout: Option<Duration>,
    /// Maximum request-line length in bytes; longer lines answer
    /// `err=too-large` without ever being buffered in full.
    pub max_request: usize,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions { default_k: 1, read_timeout: None, max_request: 1024 * 1024 }
    }
}

/// A running server (listener thread + per-connection threads).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and serve
    /// queries through `router`. Requests without a `k=` prefix are 1-NN.
    pub fn spawn(addr: &str, router: Arc<Router>) -> Result<Server> {
        Server::spawn_with_options(addr, router, ServerOptions::default())
    }

    /// [`Server::spawn`] with a different default `k` applied to
    /// requests that carry no `k=` prefix (the serve example's `--k`).
    pub fn spawn_with_default_k(
        addr: &str,
        router: Arc<Router>,
        default_k: usize,
    ) -> Result<Server> {
        Server::spawn_with_options(
            addr,
            router,
            ServerOptions { default_k, ..ServerOptions::default() },
        )
    }

    /// [`Server::spawn`] with full per-connection limits.
    pub fn spawn_with_options(
        addr: &str,
        router: Arc<Router>,
        opts: ServerOptions,
    ) -> Result<Server> {
        let opts = ServerOptions { default_k: opts.default_k.max(1), ..opts };
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        let router = router.clone();
                        // Detached: connection threads end at client EOF
                        // (or process exit); joining them here would make
                        // shutdown wait on idle clients.
                        std::thread::spawn(move || handle_conn(stream, router, opts));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(e) => {
                        log::warn!("accept: {e}");
                        break;
                    }
                }
            }
        });
        log::info!("server listening on {local}");
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (useful with ephemeral ports).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread (open connections
    /// finish their current line).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, router: Arc<Router>, opts: ServerOptions) {
    let peer = stream.peer_addr().ok();
    if stream.set_read_timeout(opts.read_timeout).is_err() {
        return;
    }
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        match read_bounded_line(&mut reader, opts.max_request) {
            Ok(Request::Eof) => break,
            Ok(Request::TooLarge) => {
                let reply =
                    format!("err=too-large request exceeds {} bytes\n", opts.max_request);
                if writer.write_all(reply.as_bytes()).is_err() {
                    break;
                }
            }
            Ok(Request::Line(line)) => {
                let reply = respond(&line, &router, opts.default_k);
                if writer.write_all(reply.as_bytes()).is_err()
                    || writer.write_all(b"\n").is_err()
                {
                    break;
                }
            }
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                // Idle past the read deadline: tell the client why the
                // connection is going away, then close it.
                let _ = writer.write_all(b"err=timeout idle connection closed\n");
                break;
            }
            Err(_) => break,
        }
    }
    log::debug!("connection {peer:?} closed");
}

/// One request as read off the wire by [`read_bounded_line`].
enum Request {
    /// A complete line (newline stripped, lossy UTF-8 decode).
    Line(String),
    /// The line exceeded the cap; it was consumed but never buffered.
    TooLarge,
    /// Clean end of stream with no pending bytes.
    Eof,
}

/// Read one `\n`-terminated line of at most `max` bytes.
///
/// Unlike [`BufRead::lines`] (which buffers without bound — a client
/// could exhaust server memory with one giant line), an over-long line
/// is *discarded as it streams in*: we drop the partial prefix, keep
/// consuming until the newline, and report [`Request::TooLarge`] so the
/// connection stays usable for the next request. Never holds more than
/// `max` bytes (plus the `BufReader` block) per connection.
fn read_bounded_line(
    reader: &mut BufReader<TcpStream>,
    max: usize,
) -> std::io::Result<Request> {
    let mut buf: Vec<u8> = Vec::new();
    let mut dropping = false;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF. An unterminated over-long tail still answers
            // too-large; an unterminated short tail is served as-is.
            return Ok(if dropping {
                Request::TooLarge
            } else if buf.is_empty() {
                Request::Eof
            } else {
                Request::Line(String::from_utf8_lossy(&buf).into_owned())
            });
        }
        if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
            let too_large = dropping || buf.len() + pos > max;
            if !too_large {
                buf.extend_from_slice(&chunk[..pos]);
            }
            reader.consume(pos + 1);
            return Ok(if too_large {
                Request::TooLarge
            } else {
                Request::Line(String::from_utf8_lossy(&buf).into_owned())
            });
        }
        let len = chunk.len();
        if !dropping {
            if buf.len() + len > max {
                dropping = true;
                buf.clear();
            } else {
                buf.extend_from_slice(chunk);
            }
        }
        reader.consume(len);
    }
}

/// Await a shed-aware router submission: `Busy` becomes `err=busy`, a
/// dropped reply channel (the dispatch side panicked executing this
/// request) becomes `err=internal`.
fn awaited<T>(submitted: std::result::Result<Receiver<T>, Busy>) -> std::result::Result<T, String> {
    match submitted {
        Err(Busy) => Err("err=busy queue at capacity, retry later".into()),
        Ok(rx) => rx
            .recv()
            .map_err(|_| "err=internal request failed (see stats=; panics counter)".into()),
    }
}

fn respond(line: &str, router: &Router, default_k: usize) -> String {
    let line = line.trim();
    if line.is_empty() {
        return "ERR empty".into();
    }
    if line.eq_ignore_ascii_case("PING") {
        return "PONG".into();
    }
    // `stream=<params>;` selects subsequence search for this request.
    if let Some(rest) = line.strip_prefix("stream=") {
        return respond_stream(rest, router);
    }
    // Snapshot control: `save=<path>;` / `load=<path>;`. Failures answer
    // a machine-parseable `err=<verb> <why>` line (distinct per failure
    // mode — io, bad magic, version, checksum, corruption) and never
    // kill the connection or the served index.
    if let Some(rest) = line.strip_prefix("save=") {
        let path = rest.trim().trim_end_matches(';').trim();
        if path.is_empty() {
            return "err=save expected save=<path>;".into();
        }
        return match awaited(router.try_save(path)) {
            Ok(Ok(r)) => format!("saved path={} bytes={}", r.path.display(), r.bytes),
            Ok(Err(e)) => format!("err=save {path}: {e}"),
            Err(shed) => shed,
        };
    }
    if let Some(rest) = line.strip_prefix("load=") {
        let path = rest.trim().trim_end_matches(';').trim();
        if path.is_empty() {
            return "err=load expected load=<path>;".into();
        }
        return match awaited(router.try_load(path)) {
            Ok(Ok(r)) => {
                format!("loaded series={} shards={} window={}", r.series, r.shards, r.window)
            }
            Ok(Err(e)) => format!("err=load {path}: {e}"),
            Err(shed) => shed,
        };
    }
    // Live mutation: `insert=<label>;<samples>` / `delete=<id>;` /
    // `compact=;` / `gens=;`. Failures answer `err=<verb> <why>` and
    // leave the served index (and its pending delta) intact.
    if let Some(rest) = line.strip_prefix("insert=") {
        let (label, payload) = match rest.split_once(';') {
            Some(x) => x,
            None => return "err=insert expected insert=<label>;v1,v2,...".into(),
        };
        let label = match label.trim().parse::<u32>() {
            Ok(l) => l,
            Err(_) => return "err=insert label must be a u32".into(),
        };
        let values: Result<Vec<f64>, _> =
            payload.split(',').map(|f| f.trim().parse::<f64>()).collect();
        let values = match values {
            Ok(v) if !v.is_empty() => v,
            _ => return "err=insert expected comma-separated floats".into(),
        };
        return match awaited(router.try_insert(label, values)) {
            Ok(Ok(r)) => format!(
                "inserted id={} delta={} generation={}",
                r.id, r.delta_len, r.generation
            ),
            Ok(Err(e)) => format!("err=insert {e:#}"),
            Err(shed) => shed,
        };
    }
    if let Some(rest) = line.strip_prefix("delete=") {
        let id = match rest.trim().trim_end_matches(';').trim().parse::<usize>() {
            Ok(id) => id,
            Err(_) => return "err=delete expected delete=<id>;".into(),
        };
        return match awaited(router.try_delete(id)) {
            Ok(Ok(r)) => format!(
                "deleted id={id} remaining={} tombstones={}",
                r.remaining, r.tombstones
            ),
            Ok(Err(e)) => format!("err=delete {e:#}"),
            Err(shed) => shed,
        };
    }
    if line.strip_prefix("compact=").is_some() {
        return match awaited(router.try_compact()) {
            Ok(Ok(r)) => format!("compacted generation={} series={}", r.generation, r.series),
            Ok(Err(e)) => format!("err=compact {e:#}"),
            Err(shed) => shed,
        };
    }
    if line.strip_prefix("gens=").is_some() {
        let info = router.generations();
        let saved = if info.saved.is_empty() {
            "-".to_string()
        } else {
            info.saved
                .iter()
                .map(|(g, p)| format!("{g}:{}", p.display()))
                .collect::<Vec<_>>()
                .join(",")
        };
        return format!(
            "gens generation={} parent={} delta={} tombstones={} saved={saved}",
            info.generation, info.parent, info.delta_len, info.tombstones
        );
    }
    // Observability: `stats=;` dumps the router's counters and gauges.
    // Like `gens=`, it bypasses shedding — you can always ask an
    // overloaded server *why* it is busy.
    if line.strip_prefix("stats=").is_some() {
        let s = router.stats();
        return format!(
            "stats served={} batches={} max_batch={} batched={} scalar={} streams={} \
             saves={} loads={} inserts={} deletes={} compactions={} delta={} \
             generation={} panics={} shed={} pending={} wal_records={} isa={}",
            s.served,
            s.batches,
            s.max_batch,
            s.batched,
            s.scalar,
            s.streams,
            s.saves,
            s.loads,
            s.inserts,
            s.deletes,
            s.compactions,
            s.delta_len,
            s.generation,
            s.panics,
            s.shed,
            s.pending,
            s.wal_records,
            crate::simd::isa_name()
        );
    }
    // Optional `k=<n>;` / `threads=<n>;` prefixes (any order) select
    // k-NN depth and the per-query screening thread count.
    let mut k = default_k;
    let mut threads: Option<usize> = None;
    let mut payload = line;
    loop {
        if let Some(rest) = payload.strip_prefix("k=") {
            match rest.split_once(';') {
                Some((kstr, next)) => match kstr.trim().parse::<usize>() {
                    Ok(v) if v >= 1 => {
                        k = v;
                        payload = next;
                    }
                    _ => return "ERR k must be a positive integer".into(),
                },
                None => return "ERR expected k=<n>;v1,v2,...".into(),
            }
        } else if let Some(rest) = payload.strip_prefix("threads=") {
            match rest.split_once(';') {
                Some((tstr, next)) => match tstr.trim().parse::<usize>() {
                    Ok(v) => {
                        threads = Some(v);
                        payload = next;
                    }
                    _ => return "ERR threads must be a non-negative integer".into(),
                },
                None => return "ERR expected threads=<n>;v1,v2,...".into(),
            }
        } else {
            break;
        }
    }
    let values: Result<Vec<f64>, _> =
        payload.split(',').map(|f| f.trim().parse::<f64>()).collect();
    let values = match values {
        Ok(values) if !values.is_empty() => values,
        _ => return "ERR expected comma-separated floats".into(),
    };
    let mut opts = QueryOptions::k(k);
    opts.threads = threads;
    let outcome = match awaited(router.try_query_with(values, opts)) {
        Ok(outcome) => outcome,
        Err(shed) => return shed,
    };
    let path = if outcome.batched { "batched" } else { "scalar" };
    if k == 1 {
        // Legacy 1-NN shape, byte-compatible with the v1 protocol.
        let resp = QueryResponse::from_outcome(outcome);
        format!(
            "label={} dist={:.6} nn={} path={} us={}",
            resp.result.label,
            resp.result.distance,
            resp.result.nn_index,
            match resp.path {
                EnginePath::Scalar => "scalar",
                EnginePath::Batched => "batched",
            },
            resp.latency.as_micros()
        )
    } else {
        let neighbors: Vec<String> = outcome
            .neighbors
            .iter()
            .map(|n| format!("{}:{}:{:.6}", n.index, n.label, n.distance))
            .collect();
        format!(
            "k={k} neighbors={} path={path} us={}",
            neighbors.join(","),
            outcome.latency.as_micros()
        )
    }
}

/// Serve one `stream=<params>;v1,v2,...` request (the `stream=` prefix
/// already stripped).
fn respond_stream(rest: &str, router: &Router) -> String {
    let (params, payload) = match rest.split_once(';') {
        Some(x) => x,
        None => return "ERR expected stream=<params>;v1,v2,...".into(),
    };
    let mut opts = SubsequenceOptions::default();
    for kv in params.split(',').filter(|s| !s.trim().is_empty()) {
        let (key, val) = match kv.split_once(':') {
            Some(x) => x,
            None => return format!("ERR stream param {kv:?}: expected key:value"),
        };
        match (key.trim(), val.trim()) {
            ("tau", v) => match v.parse::<f64>() {
                Ok(tau) if tau > 0.0 && tau.is_finite() => opts.threshold = Some(tau),
                _ => return "ERR tau must be a positive finite number".into(),
            },
            ("k", v) => match v.parse::<usize>() {
                Ok(k) if k >= 1 => opts.top_k = Some(k),
                _ => return "ERR k must be a positive integer".into(),
            },
            ("hop", v) => match v.parse::<usize>() {
                Ok(h) if h >= 1 => opts.hop = h,
                _ => return "ERR hop must be a positive integer".into(),
            },
            ("znorm", v) => match v {
                "1" | "true" => opts.znorm = Some(true),
                "0" | "false" => opts.znorm = Some(false),
                _ => return "ERR znorm must be 0 or 1".into(),
            },
            ("threads", v) => match v.parse::<usize>() {
                Ok(t) => opts.threads = Some(t),
                _ => return "ERR threads must be a non-negative integer".into(),
            },
            (k, _) => return format!("ERR unknown stream param {k:?}"),
        }
    }
    if opts.threshold.is_none() && opts.top_k.is_none() {
        return "ERR stream needs tau:<f> and/or k:<n>".into();
    }
    let values: Result<Vec<f64>, _> =
        payload.split(',').map(|f| f.trim().parse::<f64>()).collect();
    let values = match values {
        Ok(values) if !values.is_empty() => values,
        _ => return "ERR expected comma-separated floats".into(),
    };
    let report = match awaited(router.try_stream(values, opts)) {
        Ok(report) => report,
        Err(shed) => return shed,
    };
    match report {
        Ok(report) => {
            let matches = if report.matches.is_empty() {
                "-".to_string()
            } else {
                report
                    .matches
                    .iter()
                    .map(|m| {
                        format!("{}:{}:{}:{:.6}", m.start, m.neighbor, m.label, m.distance)
                    })
                    .collect::<Vec<_>>()
                    .join(",")
            };
            format!(
                "stream matches={matches} windows={} pruned={} dtw={} us={}",
                report.stats.windows,
                report.stats.pruned(),
                report.stats.dtw_calls,
                report.busy.as_micros()
            )
        }
        Err(e) => format!("ERR stream: {e:#}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::BoundKind;
    use crate::coordinator::engine::NnEngine;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};

    #[test]
    fn ping_and_query_roundtrip() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 81))[0];
        let w = ds.window.max(1);
        let ds2 = ds.clone();
        let router =
            Arc::new(Router::spawn(move || NnEngine::new(&ds2, w, BoundKind::Webb), 8));
        let server = Server::spawn("127.0.0.1:0", router).unwrap();

        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(b"PING\n").unwrap();
        let q: Vec<String> = ds.test[0].values.iter().map(|v| v.to_string()).collect();
        conn.write_all(format!("{}\n", q.join(",")).as_bytes()).unwrap();
        conn.write_all(format!("k=3;{}\n", q.join(",")).as_bytes()).unwrap();
        conn.write_all(format!("threads=2;k=3;{}\n", q.join(",")).as_bytes()).unwrap();
        conn.write_all(b"threads=x;1,2\n").unwrap();
        conn.write_all(b"k=0;1,2\n").unwrap();
        conn.write_all(b"garbage\n").unwrap();
        // Subsequence search: an exact copy of train[0] between far-away
        // filler matches once at distance zero.
        let t0: Vec<String> =
            ds.train[0].values.iter().map(|v| v.to_string()).collect();
        conn.write_all(
            format!("stream=tau:0.000001,hop:1;1000,1000,{},1000,1000\n", t0.join(","))
                .as_bytes(),
        )
        .unwrap();
        conn.write_all(b"stream=;1,2,3\n").unwrap();
        conn.write_all(b"stream=tau:-4;1,2,3\n").unwrap();

        let mut lines = BufReader::new(conn).lines();
        assert_eq!(lines.next().unwrap().unwrap(), "PONG");
        let resp = lines.next().unwrap().unwrap();
        assert!(resp.starts_with("label="), "{resp}");
        assert!(resp.contains("path=scalar"));
        let knn = lines.next().unwrap().unwrap();
        assert!(knn.starts_with("k=3 neighbors="), "{knn}");
        assert_eq!(knn.matches(':').count(), 6, "3 neighbors, 2 colons each: {knn}");
        let knn_threaded = lines.next().unwrap().unwrap();
        assert!(knn_threaded.starts_with("k=3 neighbors="), "{knn_threaded}");
        // Identical neighbors at any thread count.
        let head = |s: &str| s.split(" path=").next().unwrap().to_string();
        assert_eq!(head(&knn_threaded), head(&knn), "thread-count invariance");
        let bad_threads = lines.next().unwrap().unwrap();
        assert!(bad_threads.starts_with("ERR threads"), "{bad_threads}");
        let bad_k = lines.next().unwrap().unwrap();
        assert!(bad_k.starts_with("ERR"), "{bad_k}");
        let err = lines.next().unwrap().unwrap();
        assert!(err.starts_with("ERR"), "{err}");
        let stream = lines.next().unwrap().unwrap();
        assert!(stream.starts_with("stream matches=2:0:"), "{stream}");
        assert!(stream.contains("windows=5"), "{stream}");
        let no_mode = lines.next().unwrap().unwrap();
        assert!(no_mode.starts_with("ERR stream needs"), "{no_mode}");
        let bad_tau = lines.next().unwrap().unwrap();
        assert!(bad_tau.starts_with("ERR tau"), "{bad_tau}");

        // Close our connection before shutdown: the server joins its
        // per-connection threads, which read until client EOF.
        drop(lines);
        server.shutdown();
    }

    #[test]
    fn snapshot_verbs_round_trip_and_fail_typed() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 82))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds)
            .shards(2)
            .build()
            .unwrap();
        let router = Arc::new(Router::spawn_index(index.clone()));
        let server = Server::spawn("127.0.0.1:0", router).unwrap();
        let snap = std::env::temp_dir()
            .join(format!("dtwb_server_snap_{}.snap", std::process::id()));
        let bogus = std::env::temp_dir()
            .join(format!("dtwb_server_bogus_{}.snap", std::process::id()));
        std::fs::write(&bogus, b"definitely not a snapshot").unwrap();

        let conn = TcpStream::connect(server.addr()).unwrap();
        let mut wconn = conn.try_clone().unwrap();
        let mut lines = BufReader::new(conn).lines();
        let q: Vec<String> = ds.test[0].values.iter().map(|v| v.to_string()).collect();
        wconn.write_all(format!("k=3;{}\n", q.join(",")).as_bytes()).unwrap();
        wconn.write_all(format!("save={};\n", snap.display()).as_bytes()).unwrap();
        let before = lines.next().unwrap().unwrap();
        assert!(before.starts_with("k=3 neighbors="), "{before}");
        let saved = lines.next().unwrap().unwrap();
        // The reply carries the generation-versioned path actually
        // written (`<path>.g0` for a freshly built index).
        assert!(saved.starts_with("saved path="), "{saved}");
        assert!(saved.contains("bytes="), "{saved}");
        let saved_path = saved
            .strip_prefix("saved path=")
            .and_then(|s| s.split(" bytes=").next())
            .unwrap()
            .to_string();
        assert!(saved_path.ends_with(".g0"), "{saved_path}");

        wconn.write_all(format!("load={saved_path};\n").as_bytes()).unwrap();
        wconn.write_all(format!("k=3;{}\n", q.join(",")).as_bytes()).unwrap();
        wconn.write_all(b"save=\n").unwrap();
        wconn.write_all(b"load=/nonexistent/dir/idx.snap;\n").unwrap();
        wconn.write_all(format!("load={};\n", bogus.display()).as_bytes()).unwrap();

        let loaded = lines.next().unwrap().unwrap();
        assert!(
            loaded.starts_with(&format!("loaded series={} shards=2", index.len())),
            "{loaded}"
        );
        // Same answers from the snapshot-served index (strip timing).
        let head = |s: &str| s.split(" path=").next().unwrap().to_string();
        let after = lines.next().unwrap().unwrap();
        assert_eq!(head(&after), head(&before), "snapshot serves bit-equal answers");
        let empty = lines.next().unwrap().unwrap();
        assert!(empty.starts_with("err=save expected"), "{empty}");
        let missing = lines.next().unwrap().unwrap();
        assert!(missing.starts_with("err=load ") && missing.contains("io:"), "{missing}");
        let not_snap = lines.next().unwrap().unwrap();
        assert!(
            not_snap.starts_with("err=load ") && not_snap.contains("bad magic"),
            "{not_snap}"
        );

        drop(lines);
        server.shutdown();
        std::fs::remove_file(&saved_path).ok();
        std::fs::remove_file(&bogus).ok();
    }

    #[test]
    fn live_verbs_round_trip_and_fail_typed() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 83))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let n = index.len();
        let m = index.train().series[0].values.len();
        let router = Arc::new(Router::spawn_index(index));
        let server = Server::spawn("127.0.0.1:0", router).unwrap();

        let conn = TcpStream::connect(server.addr()).unwrap();
        let mut wconn = conn.try_clone().unwrap();
        let mut lines = BufReader::new(conn).lines();
        let mut ask = |req: String| -> String {
            wconn.write_all(req.as_bytes()).unwrap();
            wconn.write_all(b"\n").unwrap();
            lines.next().unwrap().unwrap()
        };

        // Insert a ramp of index length; it must answer its own query.
        let ramp: Vec<String> = (0..m).map(|i| format!("{}.5", i)).collect();
        let ins = ask(format!("insert=42;{}", ramp.join(",")));
        assert_eq!(ins, format!("inserted id={n} delta=1 generation=0"), "{ins}");
        let hit = ask(format!("k=1;{}", ramp.join(",")));
        assert!(hit.contains("label=42"), "{hit}");
        assert!(hit.contains("dist=0.000000"), "{hit}");

        // Delete base id 0; gens reflects both pending mutations.
        let del = ask("delete=0;".into());
        assert_eq!(del, format!("deleted id=0 remaining={n} tombstones=1"), "{del}");
        let gens = ask("gens=;".into());
        assert_eq!(
            gens, "gens generation=0 parent=0 delta=1 tombstones=1 saved=-",
            "{gens}"
        );

        // Compact into generation 1; the overlay is folded in.
        let comp = ask("compact=;".into());
        assert_eq!(comp, format!("compacted generation=1 series={n}"), "{comp}");
        let gens = ask("gens=;".into());
        assert_eq!(
            gens, "gens generation=1 parent=0 delta=0 tombstones=0 saved=-",
            "{gens}"
        );
        let hit = ask(format!("k=1;{}", ramp.join(",")));
        assert!(hit.contains("label=42"), "{hit}");

        // Typed failures leave the served index intact.
        let bad = ask(format!("insert=42;{}", "1.0"));
        assert!(bad.starts_with("err=insert "), "{bad}");
        let bad = ask("insert=notanumber;1,2,3".into());
        assert!(bad.starts_with("err=insert label"), "{bad}");
        let bad = ask(format!("delete={};", 10_000));
        assert!(bad.starts_with("err=delete "), "{bad}");
        let still = ask(format!("k=1;{}", ramp.join(",")));
        assert!(still.contains("label=42"), "{still}");

        drop(lines);
        drop(wconn);
        server.shutdown();
    }

    #[test]
    fn oversized_requests_answer_too_large_and_keep_the_connection() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 84))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Arc::new(Router::spawn_index(index));
        let opts =
            ServerOptions { max_request: 64, ..ServerOptions::default() };
        let server = Server::spawn_with_options("127.0.0.1:0", router, opts).unwrap();

        let conn = TcpStream::connect(server.addr()).unwrap();
        let mut wconn = conn.try_clone().unwrap();
        let mut lines = BufReader::new(conn).lines();
        let mut ask = |req: String| -> String {
            wconn.write_all(req.as_bytes()).unwrap();
            wconn.write_all(b"\n").unwrap();
            lines.next().unwrap().unwrap()
        };

        // An over-long legacy query line is refused without buffering…
        let huge = "1,".repeat(100);
        assert_eq!(ask(huge), "err=too-large request exceeds 64 bytes");
        // …as is every other verb, including the stream payload path…
        let huge_stream = format!("stream=tau:0.5;{}", "2,".repeat(100));
        assert_eq!(ask(huge_stream), "err=too-large request exceeds 64 bytes");
        // …and the connection survives to serve the next request.
        assert_eq!(ask("PING".into()), "PONG");
        // Exactly at the cap is still parsed normally (here: garbage).
        let at_cap = "g".repeat(64);
        assert!(ask(at_cap).starts_with("ERR"), "cap is inclusive");

        drop(lines);
        drop(wconn);
        server.shutdown();
    }

    #[test]
    fn idle_connections_time_out_with_a_typed_reply() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 85))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Arc::new(Router::spawn_index(index));
        let opts = ServerOptions {
            read_timeout: Some(Duration::from_millis(50)),
            ..ServerOptions::default()
        };
        let server = Server::spawn_with_options("127.0.0.1:0", router, opts).unwrap();

        let conn = TcpStream::connect(server.addr()).unwrap();
        let mut wconn = conn.try_clone().unwrap();
        let mut lines = BufReader::new(conn).lines();
        // A prompt request is served fine…
        wconn.write_all(b"PING\n").unwrap();
        assert_eq!(lines.next().unwrap().unwrap(), "PONG");
        // …then we go silent: the server answers err=timeout and closes.
        let bye = lines.next().unwrap().unwrap();
        assert_eq!(bye, "err=timeout idle connection closed");
        assert!(lines.next().is_none(), "connection closed after timeout");

        server.shutdown();
    }

    #[test]
    fn overload_sheds_busy_but_observability_stays_up() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 86))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Arc::new(Router::spawn_index(index));
        router.set_queue_cap(0);
        let server = Server::spawn("127.0.0.1:0", router.clone()).unwrap();

        let conn = TcpStream::connect(server.addr()).unwrap();
        let mut wconn = conn.try_clone().unwrap();
        let mut lines = BufReader::new(conn).lines();
        let mut ask = |req: String| -> String {
            wconn.write_all(req.as_bytes()).unwrap();
            wconn.write_all(b"\n").unwrap();
            lines.next().unwrap().unwrap()
        };

        // Every sheddable verb answers err=busy at capacity zero.
        assert_eq!(ask("1,2,3".into()), "err=busy queue at capacity, retry later");
        assert_eq!(ask("insert=7;1,2,3".into()), "err=busy queue at capacity, retry later");
        assert_eq!(ask("compact=;".into()), "err=busy queue at capacity, retry later");
        // Observability and liveness verbs never shed.
        assert_eq!(ask("PING".into()), "PONG");
        assert!(ask("gens=;".into()).starts_with("gens generation="));
        let stats = ask("stats=;".into());
        assert!(stats.starts_with("stats served="), "{stats}");
        assert!(stats.contains(" shed=3 "), "three refusals counted: {stats}");

        // Raising the cap readmits traffic on the same connection.
        router.set_queue_cap(1024);
        let q: Vec<String> = ds.test[0].values.iter().map(|v| v.to_string()).collect();
        assert!(ask(q.join(",")).starts_with("label="), "readmitted after cap raise");

        drop(lines);
        drop(wconn);
        server.shutdown();
    }

    #[test]
    fn panicking_query_answers_internal_and_spares_the_connection() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 87))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Arc::new(Router::spawn_index(index));
        router.poison_next_query();
        let server = Server::spawn("127.0.0.1:0", router).unwrap();

        let conn = TcpStream::connect(server.addr()).unwrap();
        let mut wconn = conn.try_clone().unwrap();
        let mut lines = BufReader::new(conn).lines();
        let mut ask = |req: String| -> String {
            wconn.write_all(req.as_bytes()).unwrap();
            wconn.write_all(b"\n").unwrap();
            lines.next().unwrap().unwrap()
        };

        let q: Vec<String> = ds.test[0].values.iter().map(|v| v.to_string()).collect();
        // The poisoned request fails alone, with a typed reply…
        let hurt = ask(q.join(","));
        assert!(hurt.starts_with("err=internal"), "{hurt}");
        // …and the very next request on the same connection is served.
        let fine = ask(q.join(","));
        assert!(fine.starts_with("label="), "{fine}");
        let stats = ask("stats=;".into());
        assert!(stats.contains(" panics=1 "), "panic counted once: {stats}");

        drop(lines);
        drop(wconn);
        server.shutdown();
    }
}
