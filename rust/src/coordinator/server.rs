//! TCP line-protocol front end over the [`Router`].
//!
//! Protocol (one line per message, UTF-8; the full specification with
//! worked request/response examples lives in `docs/protocol.md`):
//! * request:  `v1,v2,...,vN` — comma-separated series values (1-NN), or
//!   `k=<n>;v1,v2,...,vN` for the `n` nearest neighbors. A
//!   `threads=<n>;` prefix (combinable with `k=`, any order) screens
//!   this query's candidates on `n` workers (`0` = machine
//!   parallelism) on the scalar paths — batched prefilter executions
//!   use the server-wide `--threads` instead. Results are identical
//!   at every thread count either way;
//! * 1-NN response: `label=<u32> dist=<f64> nn=<usize>
//!   path=<scalar|batched> us=<u128>`;
//! * k-NN response: `k=<n> neighbors=<idx>:<label>:<dist>,...
//!   path=<scalar|batched> us=<u128>` (neighbors ascending by distance);
//! * subsequence search: `stream=<params>;v1,v2,...,vN` where `<params>`
//!   is a comma-separated list of `tau:<f>`, `k:<n>`, `hop:<n>`,
//!   `znorm:<0|1>` (at least one of `tau`/`k`); the payload is a finite
//!   sample stream, matched by sliding index-length windows (see
//!   [`crate::stream`]). Response: `stream
//!   matches=<start>:<neighbor>:<label>:<dist>,... windows=<n>
//!   pruned=<p> dtw=<d> us=<u128>` (`matches=-` when none);
//! * snapshot control: `save=<path>;` serializes the served index to a
//!   **generation-versioned** snapshot at `<path>.g<N>` (`saved
//!   path=<p> bytes=<n>` carries the actual path); `load=<path>;`
//!   hot-swaps the served index from a snapshot — loading an older
//!   generation is a rollback (`loaded series=<n> shards=<s>
//!   window=<w>`). Failures answer a machine-parseable `err=<verb>
//!   <path>: <why>` line with a distinct reason per failure mode (io,
//!   bad magic, unsupported version, checksum mismatch, corruption)
//!   and leave the served index intact;
//! * live mutation: `insert=<label>;v1,v2,...,vN` appends a series to
//!   the delta shard (`inserted id=<n> delta=<d> generation=<g>`);
//!   `delete=<id>;` removes the series at logical id `<id>` (`deleted
//!   id=<n> remaining=<r> tombstones=<t>`); `compact=;` merges the
//!   delta and tombstones into the next generation (`compacted
//!   generation=<g> series=<n>`); `gens=;` reports the lineage (`gens
//!   generation=<g> parent=<p> delta=<d> tombstones=<t>
//!   saved=<g:path,...|->`). Every search path stays bit-identical to
//!   a cold rebuild over the mutated series set; failures answer
//!   `err=<verb> <why>` and leave the served index intact;
//! * `PING` → `PONG`; malformed input → `ERR <why>`.
//!
//! One thread per connection feeds the shared router, whose dispatch loop
//! batches across connections — concurrent clients automatically share
//! batched prefilter executions on whichever
//! [`crate::runtime::LbBackend`] the engine carries. `stream=` requests
//! run after any queued query batch so they never delay the
//! latency-sensitive k-NN path.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::index::QueryOptions;
use crate::stream::SubsequenceOptions;

use super::engine::{EnginePath, QueryResponse};
use super::router::Router;

/// A running server (listener thread + per-connection threads).
pub struct Server {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and serve
    /// queries through `router`. Requests without a `k=` prefix are 1-NN.
    pub fn spawn(addr: &str, router: Arc<Router>) -> Result<Server> {
        Server::spawn_with_default_k(addr, router, 1)
    }

    /// [`Server::spawn`] with a different default `k` applied to
    /// requests that carry no `k=` prefix (the serve example's `--k`).
    pub fn spawn_with_default_k(
        addr: &str,
        router: Arc<Router>,
        default_k: usize,
    ) -> Result<Server> {
        let default_k = default_k.max(1);
        let listener = TcpListener::bind(addr).with_context(|| format!("bind {addr}"))?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        let router = router.clone();
                        // Detached: connection threads end at client EOF
                        // (or process exit); joining them here would make
                        // shutdown wait on idle clients.
                        std::thread::spawn(move || handle_conn(stream, router, default_k));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(e) => {
                        log::warn!("accept: {e}");
                        break;
                    }
                }
            }
        });
        log::info!("server listening on {local}");
        Ok(Server { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (useful with ephemeral ports).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting and join the listener thread (open connections
    /// finish their current line).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, router: Arc<Router>, default_k: usize) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        let reply = respond(&line, &router, default_k);
        if writer.write_all(reply.as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            break;
        }
    }
    log::debug!("connection {peer:?} closed");
}

fn respond(line: &str, router: &Router, default_k: usize) -> String {
    let line = line.trim();
    if line.is_empty() {
        return "ERR empty".into();
    }
    if line.eq_ignore_ascii_case("PING") {
        return "PONG".into();
    }
    // `stream=<params>;` selects subsequence search for this request.
    if let Some(rest) = line.strip_prefix("stream=") {
        return respond_stream(rest, router);
    }
    // Snapshot control: `save=<path>;` / `load=<path>;`. Failures answer
    // a machine-parseable `err=<verb> <why>` line (distinct per failure
    // mode — io, bad magic, version, checksum, corruption) and never
    // kill the connection or the served index.
    if let Some(rest) = line.strip_prefix("save=") {
        let path = rest.trim().trim_end_matches(';').trim();
        if path.is_empty() {
            return "err=save expected save=<path>;".into();
        }
        return match router.save_snapshot(path) {
            Ok(r) => format!("saved path={} bytes={}", r.path.display(), r.bytes),
            Err(e) => format!("err=save {path}: {e}"),
        };
    }
    if let Some(rest) = line.strip_prefix("load=") {
        let path = rest.trim().trim_end_matches(';').trim();
        if path.is_empty() {
            return "err=load expected load=<path>;".into();
        }
        return match router.load_snapshot(path) {
            Ok(r) => {
                format!("loaded series={} shards={} window={}", r.series, r.shards, r.window)
            }
            Err(e) => format!("err=load {path}: {e}"),
        };
    }
    // Live mutation: `insert=<label>;<samples>` / `delete=<id>;` /
    // `compact=;` / `gens=;`. Failures answer `err=<verb> <why>` and
    // leave the served index (and its pending delta) intact.
    if let Some(rest) = line.strip_prefix("insert=") {
        let (label, payload) = match rest.split_once(';') {
            Some(x) => x,
            None => return "err=insert expected insert=<label>;v1,v2,...".into(),
        };
        let label = match label.trim().parse::<u32>() {
            Ok(l) => l,
            Err(_) => return "err=insert label must be a u32".into(),
        };
        let values: Result<Vec<f64>, _> =
            payload.split(',').map(|f| f.trim().parse::<f64>()).collect();
        let values = match values {
            Ok(v) if !v.is_empty() => v,
            _ => return "err=insert expected comma-separated floats".into(),
        };
        return match router.insert(label, values) {
            Ok(r) => format!(
                "inserted id={} delta={} generation={}",
                r.id, r.delta_len, r.generation
            ),
            Err(e) => format!("err=insert {e:#}"),
        };
    }
    if let Some(rest) = line.strip_prefix("delete=") {
        let id = match rest.trim().trim_end_matches(';').trim().parse::<usize>() {
            Ok(id) => id,
            Err(_) => return "err=delete expected delete=<id>;".into(),
        };
        return match router.delete(id) {
            Ok(r) => format!(
                "deleted id={id} remaining={} tombstones={}",
                r.remaining, r.tombstones
            ),
            Err(e) => format!("err=delete {e:#}"),
        };
    }
    if line.strip_prefix("compact=").is_some() {
        return match router.compact() {
            Ok(r) => format!("compacted generation={} series={}", r.generation, r.series),
            Err(e) => format!("err=compact {e:#}"),
        };
    }
    if line.strip_prefix("gens=").is_some() {
        let info = router.generations();
        let saved = if info.saved.is_empty() {
            "-".to_string()
        } else {
            info.saved
                .iter()
                .map(|(g, p)| format!("{g}:{}", p.display()))
                .collect::<Vec<_>>()
                .join(",")
        };
        return format!(
            "gens generation={} parent={} delta={} tombstones={} saved={saved}",
            info.generation, info.parent, info.delta_len, info.tombstones
        );
    }
    // Optional `k=<n>;` / `threads=<n>;` prefixes (any order) select
    // k-NN depth and the per-query screening thread count.
    let mut k = default_k;
    let mut threads: Option<usize> = None;
    let mut payload = line;
    loop {
        if let Some(rest) = payload.strip_prefix("k=") {
            match rest.split_once(';') {
                Some((kstr, next)) => match kstr.trim().parse::<usize>() {
                    Ok(v) if v >= 1 => {
                        k = v;
                        payload = next;
                    }
                    _ => return "ERR k must be a positive integer".into(),
                },
                None => return "ERR expected k=<n>;v1,v2,...".into(),
            }
        } else if let Some(rest) = payload.strip_prefix("threads=") {
            match rest.split_once(';') {
                Some((tstr, next)) => match tstr.trim().parse::<usize>() {
                    Ok(v) => {
                        threads = Some(v);
                        payload = next;
                    }
                    _ => return "ERR threads must be a non-negative integer".into(),
                },
                None => return "ERR expected threads=<n>;v1,v2,...".into(),
            }
        } else {
            break;
        }
    }
    let values: Result<Vec<f64>, _> =
        payload.split(',').map(|f| f.trim().parse::<f64>()).collect();
    let values = match values {
        Ok(values) if !values.is_empty() => values,
        _ => return "ERR expected comma-separated floats".into(),
    };
    let mut opts = QueryOptions::k(k);
    opts.threads = threads;
    let outcome = router.query_with(values, opts);
    let path = if outcome.batched { "batched" } else { "scalar" };
    if k == 1 {
        // Legacy 1-NN shape, byte-compatible with the v1 protocol.
        let resp = QueryResponse::from_outcome(outcome);
        format!(
            "label={} dist={:.6} nn={} path={} us={}",
            resp.result.label,
            resp.result.distance,
            resp.result.nn_index,
            match resp.path {
                EnginePath::Scalar => "scalar",
                EnginePath::Batched => "batched",
            },
            resp.latency.as_micros()
        )
    } else {
        let neighbors: Vec<String> = outcome
            .neighbors
            .iter()
            .map(|n| format!("{}:{}:{:.6}", n.index, n.label, n.distance))
            .collect();
        format!(
            "k={k} neighbors={} path={path} us={}",
            neighbors.join(","),
            outcome.latency.as_micros()
        )
    }
}

/// Serve one `stream=<params>;v1,v2,...` request (the `stream=` prefix
/// already stripped).
fn respond_stream(rest: &str, router: &Router) -> String {
    let (params, payload) = match rest.split_once(';') {
        Some(x) => x,
        None => return "ERR expected stream=<params>;v1,v2,...".into(),
    };
    let mut opts = SubsequenceOptions::default();
    for kv in params.split(',').filter(|s| !s.trim().is_empty()) {
        let (key, val) = match kv.split_once(':') {
            Some(x) => x,
            None => return format!("ERR stream param {kv:?}: expected key:value"),
        };
        match (key.trim(), val.trim()) {
            ("tau", v) => match v.parse::<f64>() {
                Ok(tau) if tau > 0.0 && tau.is_finite() => opts.threshold = Some(tau),
                _ => return "ERR tau must be a positive finite number".into(),
            },
            ("k", v) => match v.parse::<usize>() {
                Ok(k) if k >= 1 => opts.top_k = Some(k),
                _ => return "ERR k must be a positive integer".into(),
            },
            ("hop", v) => match v.parse::<usize>() {
                Ok(h) if h >= 1 => opts.hop = h,
                _ => return "ERR hop must be a positive integer".into(),
            },
            ("znorm", v) => match v {
                "1" | "true" => opts.znorm = Some(true),
                "0" | "false" => opts.znorm = Some(false),
                _ => return "ERR znorm must be 0 or 1".into(),
            },
            ("threads", v) => match v.parse::<usize>() {
                Ok(t) => opts.threads = Some(t),
                _ => return "ERR threads must be a non-negative integer".into(),
            },
            (k, _) => return format!("ERR unknown stream param {k:?}"),
        }
    }
    if opts.threshold.is_none() && opts.top_k.is_none() {
        return "ERR stream needs tau:<f> and/or k:<n>".into();
    }
    let values: Result<Vec<f64>, _> =
        payload.split(',').map(|f| f.trim().parse::<f64>()).collect();
    let values = match values {
        Ok(values) if !values.is_empty() => values,
        _ => return "ERR expected comma-separated floats".into(),
    };
    match router.stream(values, opts) {
        Ok(report) => {
            let matches = if report.matches.is_empty() {
                "-".to_string()
            } else {
                report
                    .matches
                    .iter()
                    .map(|m| {
                        format!("{}:{}:{}:{:.6}", m.start, m.neighbor, m.label, m.distance)
                    })
                    .collect::<Vec<_>>()
                    .join(",")
            };
            format!(
                "stream matches={matches} windows={} pruned={} dtw={} us={}",
                report.stats.windows,
                report.stats.pruned(),
                report.stats.dtw_calls,
                report.busy.as_micros()
            )
        }
        Err(e) => format!("ERR stream: {e:#}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::BoundKind;
    use crate::coordinator::engine::NnEngine;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};

    #[test]
    fn ping_and_query_roundtrip() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 81))[0];
        let w = ds.window.max(1);
        let ds2 = ds.clone();
        let router =
            Arc::new(Router::spawn(move || NnEngine::new(&ds2, w, BoundKind::Webb), 8));
        let server = Server::spawn("127.0.0.1:0", router).unwrap();

        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.write_all(b"PING\n").unwrap();
        let q: Vec<String> = ds.test[0].values.iter().map(|v| v.to_string()).collect();
        conn.write_all(format!("{}\n", q.join(",")).as_bytes()).unwrap();
        conn.write_all(format!("k=3;{}\n", q.join(",")).as_bytes()).unwrap();
        conn.write_all(format!("threads=2;k=3;{}\n", q.join(",")).as_bytes()).unwrap();
        conn.write_all(b"threads=x;1,2\n").unwrap();
        conn.write_all(b"k=0;1,2\n").unwrap();
        conn.write_all(b"garbage\n").unwrap();
        // Subsequence search: an exact copy of train[0] between far-away
        // filler matches once at distance zero.
        let t0: Vec<String> =
            ds.train[0].values.iter().map(|v| v.to_string()).collect();
        conn.write_all(
            format!("stream=tau:0.000001,hop:1;1000,1000,{},1000,1000\n", t0.join(","))
                .as_bytes(),
        )
        .unwrap();
        conn.write_all(b"stream=;1,2,3\n").unwrap();
        conn.write_all(b"stream=tau:-4;1,2,3\n").unwrap();

        let mut lines = BufReader::new(conn).lines();
        assert_eq!(lines.next().unwrap().unwrap(), "PONG");
        let resp = lines.next().unwrap().unwrap();
        assert!(resp.starts_with("label="), "{resp}");
        assert!(resp.contains("path=scalar"));
        let knn = lines.next().unwrap().unwrap();
        assert!(knn.starts_with("k=3 neighbors="), "{knn}");
        assert_eq!(knn.matches(':').count(), 6, "3 neighbors, 2 colons each: {knn}");
        let knn_threaded = lines.next().unwrap().unwrap();
        assert!(knn_threaded.starts_with("k=3 neighbors="), "{knn_threaded}");
        // Identical neighbors at any thread count.
        let head = |s: &str| s.split(" path=").next().unwrap().to_string();
        assert_eq!(head(&knn_threaded), head(&knn), "thread-count invariance");
        let bad_threads = lines.next().unwrap().unwrap();
        assert!(bad_threads.starts_with("ERR threads"), "{bad_threads}");
        let bad_k = lines.next().unwrap().unwrap();
        assert!(bad_k.starts_with("ERR"), "{bad_k}");
        let err = lines.next().unwrap().unwrap();
        assert!(err.starts_with("ERR"), "{err}");
        let stream = lines.next().unwrap().unwrap();
        assert!(stream.starts_with("stream matches=2:0:"), "{stream}");
        assert!(stream.contains("windows=5"), "{stream}");
        let no_mode = lines.next().unwrap().unwrap();
        assert!(no_mode.starts_with("ERR stream needs"), "{no_mode}");
        let bad_tau = lines.next().unwrap().unwrap();
        assert!(bad_tau.starts_with("ERR tau"), "{bad_tau}");

        // Close our connection before shutdown: the server joins its
        // per-connection threads, which read until client EOF.
        drop(lines);
        server.shutdown();
    }

    #[test]
    fn snapshot_verbs_round_trip_and_fail_typed() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 82))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds)
            .shards(2)
            .build()
            .unwrap();
        let router = Arc::new(Router::spawn_index(index.clone()));
        let server = Server::spawn("127.0.0.1:0", router).unwrap();
        let snap = std::env::temp_dir()
            .join(format!("dtwb_server_snap_{}.snap", std::process::id()));
        let bogus = std::env::temp_dir()
            .join(format!("dtwb_server_bogus_{}.snap", std::process::id()));
        std::fs::write(&bogus, b"definitely not a snapshot").unwrap();

        let conn = TcpStream::connect(server.addr()).unwrap();
        let mut wconn = conn.try_clone().unwrap();
        let mut lines = BufReader::new(conn).lines();
        let q: Vec<String> = ds.test[0].values.iter().map(|v| v.to_string()).collect();
        wconn.write_all(format!("k=3;{}\n", q.join(",")).as_bytes()).unwrap();
        wconn.write_all(format!("save={};\n", snap.display()).as_bytes()).unwrap();
        let before = lines.next().unwrap().unwrap();
        assert!(before.starts_with("k=3 neighbors="), "{before}");
        let saved = lines.next().unwrap().unwrap();
        // The reply carries the generation-versioned path actually
        // written (`<path>.g0` for a freshly built index).
        assert!(saved.starts_with("saved path="), "{saved}");
        assert!(saved.contains("bytes="), "{saved}");
        let saved_path = saved
            .strip_prefix("saved path=")
            .and_then(|s| s.split(" bytes=").next())
            .unwrap()
            .to_string();
        assert!(saved_path.ends_with(".g0"), "{saved_path}");

        wconn.write_all(format!("load={saved_path};\n").as_bytes()).unwrap();
        wconn.write_all(format!("k=3;{}\n", q.join(",")).as_bytes()).unwrap();
        wconn.write_all(b"save=\n").unwrap();
        wconn.write_all(b"load=/nonexistent/dir/idx.snap;\n").unwrap();
        wconn.write_all(format!("load={};\n", bogus.display()).as_bytes()).unwrap();

        let loaded = lines.next().unwrap().unwrap();
        assert!(
            loaded.starts_with(&format!("loaded series={} shards=2", index.len())),
            "{loaded}"
        );
        // Same answers from the snapshot-served index (strip timing).
        let head = |s: &str| s.split(" path=").next().unwrap().to_string();
        let after = lines.next().unwrap().unwrap();
        assert_eq!(head(&after), head(&before), "snapshot serves bit-equal answers");
        let empty = lines.next().unwrap().unwrap();
        assert!(empty.starts_with("err=save expected"), "{empty}");
        let missing = lines.next().unwrap().unwrap();
        assert!(missing.starts_with("err=load ") && missing.contains("io:"), "{missing}");
        let not_snap = lines.next().unwrap().unwrap();
        assert!(
            not_snap.starts_with("err=load ") && not_snap.contains("bad magic"),
            "{not_snap}"
        );

        drop(lines);
        server.shutdown();
        std::fs::remove_file(&saved_path).ok();
        std::fs::remove_file(&bogus).ok();
    }

    #[test]
    fn live_verbs_round_trip_and_fail_typed() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 83))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let n = index.len();
        let m = index.train().series[0].values.len();
        let router = Arc::new(Router::spawn_index(index));
        let server = Server::spawn("127.0.0.1:0", router).unwrap();

        let conn = TcpStream::connect(server.addr()).unwrap();
        let mut wconn = conn.try_clone().unwrap();
        let mut lines = BufReader::new(conn).lines();
        let mut ask = |req: String| -> String {
            wconn.write_all(req.as_bytes()).unwrap();
            wconn.write_all(b"\n").unwrap();
            lines.next().unwrap().unwrap()
        };

        // Insert a ramp of index length; it must answer its own query.
        let ramp: Vec<String> = (0..m).map(|i| format!("{}.5", i)).collect();
        let ins = ask(format!("insert=42;{}", ramp.join(",")));
        assert_eq!(ins, format!("inserted id={n} delta=1 generation=0"), "{ins}");
        let hit = ask(format!("k=1;{}", ramp.join(",")));
        assert!(hit.contains("label=42"), "{hit}");
        assert!(hit.contains("dist=0.000000"), "{hit}");

        // Delete base id 0; gens reflects both pending mutations.
        let del = ask("delete=0;".into());
        assert_eq!(del, format!("deleted id=0 remaining={n} tombstones=1"), "{del}");
        let gens = ask("gens=;".into());
        assert_eq!(
            gens, "gens generation=0 parent=0 delta=1 tombstones=1 saved=-",
            "{gens}"
        );

        // Compact into generation 1; the overlay is folded in.
        let comp = ask("compact=;".into());
        assert_eq!(comp, format!("compacted generation=1 series={n}"), "{comp}");
        let gens = ask("gens=;".into());
        assert_eq!(
            gens, "gens generation=1 parent=0 delta=0 tombstones=0 saved=-",
            "{gens}"
        );
        let hit = ask(format!("k=1;{}", ramp.join(",")));
        assert!(hit.contains("label=42"), "{hit}");

        // Typed failures leave the served index intact.
        let bad = ask(format!("insert=42;{}", "1.0"));
        assert!(bad.starts_with("err=insert "), "{bad}");
        let bad = ask("insert=notanumber;1,2,3".into());
        assert!(bad.starts_with("err=insert label"), "{bad}");
        let bad = ask(format!("delete={};", 10_000));
        assert!(bad.starts_with("err=delete "), "{bad}");
        let still = ask(format!("k=1;{}", ramp.join(",")));
        assert!(still.contains("label=42"), "{still}");

        drop(lines);
        drop(wconn);
        server.shutdown();
    }
}
