//! The query engine: exact 1-NN DTW with lower-bound screening, plus a
//! pluggable batched prefilter ([`LbBackend`]).
//!
//! Scalar path = the paper's Algorithm 4 per query. Batch path = the
//! attached backend computes the `LB_KEOGH` matrix for the whole query
//! batch — the cache-blocked native backend by default, one XLA execution
//! with `--features pjrt` — then each query walks its candidates in
//! ascending-bound order with early-abandoning DTW
//! ([`nn_sorted_precomputed`]). Results are exact either way; only the
//! screening cost moves.

use std::time::{Duration, Instant};

use crate::bounds::{BoundKind, PreparedSeries, Scratch};
use crate::data::Dataset;
use crate::delta::Squared;
use crate::dtw::dtw_ea;
use crate::runtime::{LbBackend, NativeBatchLb};
use crate::search::nn::{nn_sorted, nn_sorted_precomputed, NnResult};
use crate::search::PreparedTrainSet;

/// Which path answered a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnginePath {
    /// Per-query scalar bound (Algorithm 4 in Rust).
    Scalar,
    /// Batched backend prefilter + DTW on survivors.
    Batched,
}

/// Response for one query.
#[derive(Debug, Clone)]
pub struct QueryResponse {
    /// The exact nearest neighbor.
    pub result: NnResult,
    /// Which path computed it.
    pub path: EnginePath,
    /// Engine-side latency.
    pub latency: Duration,
}

/// Exact 1-NN engine over one dataset's training split.
pub struct NnEngine {
    train: PreparedTrainSet,
    bound: BoundKind,
    backend: Option<Box<dyn LbBackend>>,
    scratch: Scratch,
    bound_buf: Vec<f64>,
    index_buf: Vec<usize>,
}

impl NnEngine {
    /// Build an engine (scalar path only) for a dataset at window `w`.
    pub fn new(ds: &Dataset, w: usize, bound: BoundKind) -> Self {
        let train = PreparedTrainSet::from_dataset(ds, w);
        NnEngine {
            train,
            bound,
            backend: None,
            scratch: Scratch::default(),
            bound_buf: Vec::new(),
            index_buf: Vec::new(),
        }
    }

    /// Build an engine with a batched screening backend attached.
    pub fn with_backend(
        ds: &Dataset,
        w: usize,
        bound: BoundKind,
        backend: Box<dyn LbBackend>,
    ) -> Self {
        let mut engine = NnEngine::new(ds, w, bound);
        engine.set_backend(backend);
        engine
    }

    /// Attach (or replace) the batched screening backend.
    pub fn set_backend(&mut self, backend: Box<dyn LbBackend>) {
        log::info!("engine: batched prefilter backend = {}", backend.name());
        self.backend = Some(backend);
    }

    /// Attach the default pure-Rust batched backend.
    pub fn attach_native(&mut self) {
        self.set_backend(Box::new(NativeBatchLb::new()));
    }

    /// Attach the PJRT batch prefilter loaded from `artifacts_dir`.
    /// Fails (leaving any current backend intact) when no artifact fits.
    #[cfg(feature = "pjrt")]
    pub fn attach_batch_lb(
        &mut self,
        rt: &crate::runtime::XlaRuntime,
        artifacts_dir: &std::path::Path,
        max_batch: usize,
    ) -> anyhow::Result<()> {
        let l = self.train.series.first().map(|s| s.len()).unwrap_or(0);
        let blb =
            crate::runtime::BatchLb::load(rt, artifacts_dir, max_batch, self.train.len(), l)?;
        self.set_backend(Box::new(blb));
        Ok(())
    }

    /// True when a batched screening backend is attached.
    pub fn has_batch_path(&self) -> bool {
        self.backend.is_some()
    }

    /// Name of the attached screening backend, if any.
    pub fn backend_name(&self) -> Option<&'static str> {
        self.backend.as_ref().map(|b| b.name())
    }

    /// Training-set size.
    pub fn train_len(&self) -> usize {
        self.train.len()
    }

    /// The engine's window.
    pub fn window(&self) -> usize {
        self.train.w
    }

    /// Answer one query on the scalar path.
    pub fn query_one(&mut self, values: &[f64]) -> QueryResponse {
        let started = Instant::now();
        let pq = PreparedSeries::prepare(values.to_vec(), self.train.w);
        let (result, _) = nn_sorted::<Squared>(
            &pq,
            &self.train,
            self.bound,
            &mut self.scratch,
            &mut self.bound_buf,
            &mut self.index_buf,
        );
        QueryResponse { result, path: EnginePath::Scalar, latency: started.elapsed() }
    }

    /// Answer a batch of queries, riding the attached backend when the
    /// batch is non-trivial and fits its shape, otherwise the scalar path
    /// per query.
    pub fn query_batch(&mut self, queries: &[Vec<f64>]) -> Vec<QueryResponse> {
        if queries.is_empty() {
            return Vec::new();
        }
        let l = queries[0].len();
        let use_batch = match &self.backend {
            Some(be) => {
                queries.len() > 1
                    && !self.train.is_empty()
                    // Backends require one shared length; reject up front
                    // rather than paying the seed DTWs and a per-batch
                    // backend error + warn-log on every dispatch.
                    && l == self.train.series[0].len()
                    && queries.iter().all(|q| q.len() == l)
                    && be.supports(queries.len(), self.train.len(), l)
            }
            None => false,
        };
        if !use_batch {
            return queries.iter().map(|q| self.query_one(q)).collect();
        }

        let started = Instant::now();
        let w = self.train.w;
        let backend = self.backend.as_mut().expect("checked above");
        // For cutoff-honouring backends, seed each query's best-so-far
        // with its exact DTW distance to candidate 0: candidates whose
        // (partial) bound crosses the seed would be pruned regardless, so
        // abandoning them early cannot change the result. Tradeoff: when
        // candidate 0 is not the min-bound candidate this is one extra
        // full DTW per query beyond what Algorithm 4's walk would pay,
        // traded for O(ℓ) early-abandon savings on every screened-out
        // bound row (n per query) — a win for n ≫ w. Branch-free backends
        // ignore cutoffs, so for them the seed DTW would buy nothing:
        // skip it and start the walk cold, exactly like Algorithm 4.
        let seeds: Vec<f64> = if backend.uses_cutoffs() {
            queries
                .iter()
                .map(|q| dtw_ea::<Squared>(q, &self.train.series[0].values, w, f64::INFINITY))
                .collect()
        } else {
            vec![f64::INFINITY; queries.len()]
        };
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let ranking = match backend.rank(&q_refs, &self.train.series, &seeds) {
            Ok(r) => r,
            Err(e) => {
                log::warn!("batch prefilter failed ({e:#}); falling back to scalar");
                return queries.iter().map(|q| self.query_one(q)).collect();
            }
        };
        let prefilter_each = started.elapsed() / queries.len() as u32;

        let mut out = Vec::with_capacity(queries.len());
        for (qi, q) in queries.iter().enumerate() {
            let q_started = Instant::now();
            // A finite seed is a known candidate-0 distance; an infinite
            // one means "unseeded" (cold walk).
            let initial = if seeds[qi].is_finite() {
                Some(NnResult { nn_index: 0, distance: seeds[qi], label: self.train.labels[0] })
            } else {
                None
            };
            let (result, _) = nn_sorted_precomputed::<Squared>(
                q,
                &self.train,
                &ranking.bounds[qi],
                &ranking.order[qi],
                initial,
            );
            out.push(QueryResponse {
                result,
                path: EnginePath::Batched,
                latency: prefilter_each + q_started.elapsed(),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::search::nn::nn_brute_force;

    #[test]
    fn scalar_path_is_exact() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 61))[0];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Webb);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for q in &ds.test {
            let resp = engine.query_one(&q.values);
            let (truth, _) = nn_brute_force::<Squared>(&q.values, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Scalar);
        }
    }

    #[test]
    fn batch_without_backend_falls_back_to_scalar() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 61))[1];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Webb);
        assert!(!engine.has_batch_path());
        assert_eq!(engine.backend_name(), None);
        let queries: Vec<Vec<f64>> = ds.test.iter().take(3).map(|s| s.values.clone()).collect();
        let out = engine.query_batch(&queries);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.path == EnginePath::Scalar));
    }

    #[test]
    fn native_backend_batch_is_exact() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 62))[0];
        let w = ds.window.max(1);
        let mut engine =
            NnEngine::with_backend(ds, w, BoundKind::Keogh, Box::new(NativeBatchLb::new()));
        assert_eq!(engine.backend_name(), Some("native"));
        let queries: Vec<Vec<f64>> = ds.test.iter().map(|s| s.values.clone()).collect();
        assert!(queries.len() > 1, "need a real batch");
        let out = engine.query_batch(&queries);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for (resp, q) in out.iter().zip(queries.iter()) {
            let (truth, _) = nn_brute_force::<Squared>(q, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Batched);
        }
    }

    #[test]
    fn single_query_batch_takes_scalar_path() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 63))[2];
        let w = ds.window.max(1);
        let mut engine =
            NnEngine::with_backend(ds, w, BoundKind::Webb, Box::new(NativeBatchLb::new()));
        let out = engine.query_batch(&[ds.test[0].values.clone()]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].path, EnginePath::Scalar);
    }

    /// Exactness of the PJRT path (needs `make artifacts` + real XLA).
    #[cfg(feature = "pjrt")]
    #[test]
    fn batched_path_is_exact_when_artifact_present() {
        use crate::runtime::XlaRuntime;
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 62))[0];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Keogh);
        let rt = match XlaRuntime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e:#})");
                return;
            }
        };
        if let Err(e) = engine.attach_batch_lb(&rt, &dir, 8) {
            eprintln!("skipping: {e:#}");
            return;
        }
        let queries: Vec<Vec<f64>> =
            ds.test.iter().take(8).map(|s| s.values.clone()).collect();
        let out = engine.query_batch(&queries);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for (resp, q) in out.iter().zip(queries.iter()) {
            let (truth, _) = nn_brute_force::<Squared>(q, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Batched);
        }
    }
}
