//! The query engine — a thin adapter holding a per-thread
//! [`Searcher`] over a shared [`DtwIndex`].
//!
//! The index owns the prepared envelopes and configuration; the engine
//! adds the serving-era surface the router/server consume (legacy
//! [`QueryResponse`] conversion, backend attachment helpers). Scalar
//! path = the paper's Algorithm 4 per query; batch path = the attached
//! [`LbBackend`] computes the `LB_KEOGH` matrix for the whole query
//! batch, then each query walks its candidates in ascending-bound order
//! with early-abandoning DTW. Results are exact either way; only the
//! screening cost moves.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::bounds::{BoundKind, PreparedSeries};
use crate::data::Dataset;
use crate::delta::Squared;
use crate::index::snapshot::{generation_path, SnapshotError};
use crate::index::{DtwIndex, QueryOptions, QueryOutcome, Searcher};
use crate::io::{FileOps, RealFs};
use crate::live::wal::{self, FsyncPolicy, ReplayInfo, Wal, WalOp};
use crate::live::LiveState;
use crate::runtime::{BackendKind, LbBackend, NativeBatchLb};
use crate::search::nn::NnResult;
use crate::search::SearchStrategy;

/// Which path answered a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnginePath {
    /// Per-query scalar bound (Algorithm 4 in Rust).
    Scalar,
    /// Batched backend prefilter + DTW on survivors.
    Batched,
}

/// Legacy 1-NN response for one query (the server's line protocol).
#[derive(Debug, Clone)]
pub struct QueryResponse {
    /// The exact nearest neighbor.
    pub result: NnResult,
    /// Which path computed it.
    pub path: EnginePath,
    /// Engine-side latency.
    pub latency: Duration,
}

impl QueryResponse {
    /// Collapse a k-NN [`QueryOutcome`] to its nearest neighbor.
    pub fn from_outcome(outcome: QueryOutcome) -> QueryResponse {
        QueryResponse {
            result: outcome.best_nn(),
            path: if outcome.batched { EnginePath::Batched } else { EnginePath::Scalar },
            latency: outcome.latency,
        }
    }
}

/// A live index's generation status — the `gens=;` protocol verb's
/// payload.
#[derive(Debug, Clone)]
pub struct GenerationInfo {
    /// Generation of the currently served frozen base.
    pub generation: u64,
    /// The generation it was compacted from (0 = baseline).
    pub parent: u64,
    /// Pending delta-shard inserts.
    pub delta_len: usize,
    /// Pending base tombstones.
    pub tombstones: usize,
    /// Generation snapshots written by this engine: `(generation, path)`
    /// in save order (rollback targets for `load=`).
    pub saved: Vec<(u64, PathBuf)>,
}

/// Exact k-NN engine over one dataset's training split: a [`Searcher`]
/// plus adapters for the line-protocol serving stack.
///
/// The engine is also the ownership point of **live mutation**
/// ([`crate::live`]): it pairs the frozen index with a [`LiveState`]
/// (delta shard + tombstones) and routes every query/batch/stream
/// through the live overlay whenever mutations are pending — results
/// stay bit-identical to a cold rebuild of the logical series set.
pub struct NnEngine {
    searcher: Searcher,
    /// Pending live mutations over the served index.
    live: LiveState,
    /// Compact automatically once this many mutations (delta inserts +
    /// tombstones) are pending (`None` = explicit compaction only).
    auto_compact: Option<usize>,
    /// Generation snapshots written so far: `(generation, path)`.
    saved: Vec<(u64, PathBuf)>,
    /// File ops every persisted byte (snapshots, WAL) flows through —
    /// [`RealFs`] in production, a fault-injecting double in the
    /// recovery suite.
    fs: Arc<dyn FileOps>,
    /// Write-ahead durability, when enabled ([`NnEngine::enable_wal`]).
    wal: Option<WalState>,
}

/// The engine's durability attachment: the open log plus the anchor
/// snapshot path it rotates against.
struct WalState {
    wal: Wal,
    /// The serving snapshot path: recovery loads this file and replays
    /// its generation's log; rotation persists the new base here.
    anchor: PathBuf,
    policy: FsyncPolicy,
}

impl NnEngine {
    /// Build an engine (scalar path only) for a dataset at window `w`.
    pub fn new(ds: &Dataset, w: usize, bound: BoundKind) -> Self {
        let index = DtwIndex::builder_from_dataset(ds)
            .window(w)
            .bound(bound)
            .strategy(SearchStrategy::Sorted)
            .backend(BackendKind::None)
            .build()
            .expect("dataset series share one length");
        NnEngine::from_index(index)
    }

    /// Wrap a prebuilt index — the facade path: the index (and its
    /// prepared envelopes) can be shared across engines/threads.
    pub fn from_index(index: DtwIndex) -> Self {
        NnEngine {
            searcher: index.searcher(),
            live: LiveState::new(),
            auto_compact: None,
            saved: Vec::new(),
            fs: Arc::new(RealFs),
            wal: None,
        }
    }

    /// Swap the file-ops implementation (fault injection in the
    /// recovery suite). Call before [`NnEngine::enable_wal`].
    pub fn set_fs(&mut self, fs: Arc<dyn FileOps>) {
        self.fs = fs;
    }

    /// Build an engine with a batched screening backend attached.
    pub fn with_backend(
        ds: &Dataset,
        w: usize,
        bound: BoundKind,
        backend: Box<dyn LbBackend>,
    ) -> Self {
        let mut engine = NnEngine::new(ds, w, bound);
        engine.set_backend(backend);
        engine
    }

    /// Attach (or replace) the batched screening backend.
    pub fn set_backend(&mut self, backend: Box<dyn LbBackend>) {
        log::info!("engine: batched prefilter backend = {}", backend.name());
        self.searcher.set_backend(backend);
    }

    /// Attach the default pure-Rust batched backend, scoring query rows
    /// on the index's configured thread count.
    pub fn attach_native(&mut self) {
        let threads = self.searcher.index().threads();
        self.set_backend(Box::new(NativeBatchLb::with_threads(threads)));
    }

    /// Attach the PJRT batch prefilter loaded from `artifacts_dir`.
    /// Fails (leaving any current backend intact) when no artifact fits.
    #[cfg(feature = "pjrt")]
    pub fn attach_batch_lb(
        &mut self,
        rt: &crate::runtime::XlaRuntime,
        artifacts_dir: &std::path::Path,
        max_batch: usize,
    ) -> anyhow::Result<()> {
        let index = self.searcher.index();
        let l = index.train().series.first().map(|s| s.len()).unwrap_or(0);
        let blb =
            crate::runtime::BatchLb::load(rt, artifacts_dir, max_batch, index.len(), l)?;
        self.set_backend(Box::new(blb));
        Ok(())
    }

    /// The index this engine serves.
    pub fn index(&self) -> &DtwIndex {
        self.searcher.index()
    }

    /// Swap the served index: the engine rebuilds its searcher (scratch,
    /// RNG, sort buffers) around the new index while **keeping its
    /// current backend attachment** — which screening backend serves is
    /// a deployment choice (`serve --backend`, an explicit
    /// [`NnEngine::set_backend`]), so a hot-swap must not silently flip
    /// it to whatever the snapshot's stored config names (scalar-only
    /// snapshots would drop a native prefilter; native snapshots would
    /// override `--no-batch`). This is the `load=<path>;` protocol
    /// verb's engine half: a running router hot-swaps onto a snapshot
    /// without restarting and without changing how it screens.
    /// Any swap also resets the live state: pending delta entries and
    /// tombstones are defined against the *old* base's id space, so a
    /// loaded snapshot (including a generation rollback) starts clean.
    pub fn replace_index(&mut self, index: DtwIndex) {
        let backend = self.searcher.take_backend();
        self.searcher = index.searcher();
        match backend {
            Some(b) => self.searcher.set_backend(b),
            None => self.searcher.clear_backend(),
        }
        self.live.clear();
    }

    /// True when a batched screening backend is attached.
    pub fn has_batch_path(&self) -> bool {
        self.searcher.has_backend()
    }

    /// Name of the attached screening backend, if any.
    pub fn backend_name(&self) -> Option<&'static str> {
        self.searcher.backend_name()
    }

    /// Training-set size.
    pub fn train_len(&self) -> usize {
        self.searcher.index().len()
    }

    /// The engine's window.
    pub fn window(&self) -> usize {
        self.searcher.index().window()
    }

    // ---- durability ---------------------------------------------------

    /// Turn on write-ahead durability against `anchor` (the snapshot
    /// this engine serves from): recover the current generation's log
    /// (`<anchor>.wal.g<N>`, torn tails dropped), replay its records
    /// through the exact live mutation path a client would have taken,
    /// and keep the log open for appends. After this, every accepted
    /// `insert`/`delete` is logged (and fsynced per `policy`) **before**
    /// it is applied or acked.
    ///
    /// A record that no longer applies (e.g. a log paired with the
    /// wrong snapshot bytes) is a hard error — that is corruption, not
    /// a torn tail, and serving from half a log would silently violate
    /// the recovery contract.
    pub fn enable_wal(
        &mut self,
        anchor: &Path,
        policy: FsyncPolicy,
    ) -> anyhow::Result<ReplayInfo> {
        let (ops, info, wal) =
            Wal::recover(self.fs.clone(), anchor, self.generation(), policy)
                .map_err(|e| anyhow::anyhow!("wal recover: {e}"))?;
        for (n, op) in ops.into_iter().enumerate() {
            let applied = match op {
                WalOp::Insert { label, values } => {
                    self.live.insert(self.searcher.index(), label, values).map(|_| ())
                }
                WalOp::Delete { id } => {
                    let id = usize::try_from(id)
                        .map_err(|_| anyhow::anyhow!("id {id} exceeds usize"));
                    id.and_then(|id| self.live.delete(self.searcher.index(), id))
                }
            };
            if let Err(e) = applied {
                anyhow::bail!(
                    "wal replay: record {n} of {} no longer applies ({e}) — \
                     the log does not belong to this snapshot",
                    info.records
                );
            }
        }
        self.wal = Some(WalState { wal, anchor: anchor.to_path_buf(), policy });
        Ok(info)
    }

    /// True when write-ahead durability is on.
    pub fn wal_enabled(&self) -> bool {
        self.wal.is_some()
    }

    /// Records in the open log (the `wal_records` stats gauge; 0 when
    /// the WAL is off).
    pub fn wal_records(&self) -> u64 {
        self.wal.as_ref().map(|w| w.wal.records()).unwrap_or(0)
    }

    /// Durable hot-swap onto `next`, rotating the log. Ordering is the
    /// crash-safety argument (see ARCHITECTURE.md, "Durability & fault
    /// model"):
    ///
    /// 1. create + fsync the new generation's empty log — on failure
    ///    nothing changed, and a stale empty `.wal.g<M>` is harmless
    ///    because the anchor still names the old generation;
    /// 2. persist `next` over the anchor (write-tmp/fsync/rename,
    ///    atomic) — a crash leaves either old snapshot + old log (the
    ///    pre state) or new snapshot + its empty log (the post state),
    ///    and the snapshot's stored generation always selects the one
    ///    log that matches it;
    /// 3. swap in memory (clears live state) and adopt the new log;
    /// 4. best-effort remove of the superseded log — a failure is not
    ///    an error, the orphan can never be replayed again.
    ///
    /// When `next` carries the **same** generation as the served base
    /// (a `load=` back onto the current generation), the log path does
    /// not change, so step 1's truncating create would destroy records
    /// *before* the new base is durable. That case removes the old log
    /// first (its records are being discarded by design — `load=`
    /// resets live state even without a crash), then saves, then
    /// creates the fresh log: every crash point leaves a clean
    /// old-base-or-new-base state with no cross-base replay possible.
    /// (Two files cannot be swapped atomically; removing the doomed
    /// records first is the one ordering that can never replay them
    /// into a base they don't match. The narrow cost: if the save then
    /// *fails* — no crash, an I/O error — the engine keeps serving the
    /// old state but its previously logged records are gone, so those
    /// mutations would not survive a subsequent crash. The error
    /// message says so.)
    fn rotate_onto(&mut self, next: DtwIndex) -> anyhow::Result<()> {
        let state = self.wal.as_ref().expect("rotation requires an open wal");
        let anchor = state.anchor.clone();
        let policy = state.policy;
        let old_path = state.wal.path().to_path_buf();
        let same_generation = old_path == wal::wal_path(&anchor, next.generation());

        if same_generation {
            let _ = self.fs.remove(&old_path);
        }
        let new_wal = if same_generation {
            None
        } else {
            Some(
                Wal::create(self.fs.clone(), &anchor, next.generation(), policy)
                    .map_err(|e| anyhow::anyhow!("wal rotate: create new log: {e}"))?,
            )
        };
        if let Err(e) = crate::index::snapshot::save_with(&next, &anchor, self.fs.as_ref()) {
            if same_generation {
                anyhow::bail!(
                    "wal rotate: persist new base: {e} — the superseded log was \
                     already discarded; pending live mutations are no longer \
                     crash-durable (compact or save to restore durability)"
                );
            }
            anyhow::bail!("wal rotate: persist new base: {e}");
        }
        let new_wal = match new_wal {
            Some(w) => w,
            None => Wal::create(self.fs.clone(), &anchor, next.generation(), policy)
                .map_err(|e| anyhow::anyhow!("wal rotate: recreate log: {e}"))?,
        };
        self.replace_index(next);
        if let Some(state) = self.wal.as_mut() {
            state.wal = new_wal;
        }
        if !same_generation {
            let _ = self.fs.remove(&old_path);
        }
        Ok(())
    }

    /// Install a loaded snapshot as the served index — the `load=`
    /// protocol verb's engine half. Without a WAL this is exactly
    /// [`NnEngine::replace_index`]; with one, the swap must also move
    /// the durable anchor (persist the loaded base over it and rotate
    /// the log), or a crash after the ack would silently revert the
    /// rollback.
    pub fn install_index(&mut self, index: DtwIndex) -> anyhow::Result<()> {
        if self.wal.is_none() {
            self.replace_index(index);
            return Ok(());
        }
        self.rotate_onto(index)
    }

    // ---- live mutation ------------------------------------------------

    /// Append one series to the delta shard; returns its logical id.
    /// With the WAL on, the record is logged (and fsynced per policy)
    /// **before** the state mutates — validation runs first, so a
    /// logged record is always applicable on replay.
    pub fn insert(&mut self, label: u32, values: Vec<f64>) -> anyhow::Result<usize> {
        self.live.validate_insert(self.searcher.index(), &values)?;
        if let Some(state) = self.wal.as_mut() {
            state
                .wal
                .append_insert(label, &values)
                .map_err(|e| anyhow::anyhow!("wal append (insert): {e}"))?;
        }
        self.live.insert(self.searcher.index(), label, values)
    }

    /// Delete the series with logical id `id` (tombstone a base series
    /// or drop a delta entry). Same log-before-apply contract as
    /// [`NnEngine::insert`].
    pub fn delete(&mut self, id: usize) -> anyhow::Result<()> {
        self.live.validate_delete(self.searcher.index(), id)?;
        if let Some(state) = self.wal.as_mut() {
            state
                .wal
                .append_delete(id as u64)
                .map_err(|e| anyhow::anyhow!("wal append (delete): {e}"))?;
        }
        self.live.delete(self.searcher.index(), id)
    }

    /// Fold the pending mutations into the next generation: the
    /// compacted index is built **aside** (the served index keeps
    /// answering until the build succeeds) and then swapped in with the
    /// deployment backend attachment intact. Returns the new generation.
    ///
    /// With the WAL on, the swap is the durable rotation described at
    /// [`NnEngine::rotate_onto`] — the pending delta is *not* cleared
    /// until the new base and its log are safely on disk, so a rotation
    /// failure leaves the engine serving exactly what it served before.
    pub fn compact(&mut self) -> anyhow::Result<u64> {
        if self.wal.is_none() {
            let next = self.live.compact(self.searcher.index())?;
            let generation = next.generation();
            self.replace_index(next);
            return Ok(generation);
        }
        let next = crate::live::compacted(
            self.searcher.index(),
            self.live.delta(),
            self.live.tombstones(),
        )?;
        let generation = next.generation();
        self.rotate_onto(next)?;
        Ok(generation)
    }

    /// Set (or clear) the auto-compaction threshold: compact as soon as
    /// delta inserts + tombstones reach `n` pending mutations.
    pub fn set_auto_compact(&mut self, n: Option<usize>) {
        self.auto_compact = n.filter(|&n| n > 0);
    }

    /// Compact iff the auto-compaction threshold is set and reached;
    /// returns the new generation when a compaction ran.
    pub fn maybe_auto_compact(&mut self) -> anyhow::Result<Option<u64>> {
        match self.auto_compact {
            Some(n) if self.live.delta_len() + self.live.tombstone_count() >= n => {
                self.compact().map(Some)
            }
            _ => Ok(None),
        }
    }

    /// Pending delta-shard inserts.
    pub fn delta_len(&self) -> usize {
        self.live.delta_len()
    }

    /// Generation of the served frozen base.
    pub fn generation(&self) -> u64 {
        self.searcher.index().generation()
    }

    /// Logical series count (base survivors + delta entries).
    pub fn logical_len(&self) -> usize {
        self.live.logical_len(self.searcher.index())
    }

    /// The generation status ([`GenerationInfo`]) — served generation,
    /// pending mutation counts, and every generation snapshot written.
    pub fn generations(&self) -> GenerationInfo {
        let index = self.searcher.index();
        GenerationInfo {
            generation: index.generation(),
            parent: index.parent(),
            delta_len: self.live.delta_len(),
            tombstones: self.live.tombstone_count(),
            saved: self.saved.clone(),
        }
    }

    /// Save the served frozen base as a **generation snapshot**:
    /// `<base>.g<N>` ([`generation_path`]), recorded for `gens=` /
    /// rollback. Pending delta mutations are *not* serialized — compact
    /// first to persist them.
    pub fn save_generation(&mut self, base: &Path) -> Result<(PathBuf, u64), SnapshotError> {
        let generation = self.generation();
        let path = generation_path(base, generation);
        let bytes =
            crate::index::snapshot::save_with(self.searcher.index(), &path, self.fs.as_ref())?;
        self.saved.push((generation, path.clone()));
        Ok((path, bytes))
    }

    // ---- query paths ---------------------------------------------------

    /// Answer one query on the scalar path (1-NN legacy shape).
    pub fn query_one(&mut self, values: &[f64]) -> QueryResponse {
        QueryResponse::from_outcome(self.query_with(values, &QueryOptions::default()))
    }

    /// Answer one query with full options (k-NN, threshold, z-norm).
    /// Routes through the live overlay when mutations are pending.
    pub fn query_with(&mut self, values: &[f64], opts: &QueryOptions) -> QueryOutcome {
        self.live.query::<Squared>(&mut self.searcher, values, opts)
    }

    /// Answer a batch of queries (1-NN legacy shape), riding the
    /// attached backend when the batch is non-trivial and fits its
    /// shape, otherwise the scalar path per query.
    pub fn query_batch(&mut self, queries: &[Vec<f64>]) -> Vec<QueryResponse> {
        let items: Vec<(Vec<f64>, QueryOptions)> =
            queries.iter().map(|q| (q.clone(), QueryOptions::default())).collect();
        self.query_batch_with(&items).into_iter().map(QueryResponse::from_outcome).collect()
    }

    /// Answer a batch of `(values, options)` pairs — the router's shape,
    /// where concurrent clients may ask for different `k`. Routes
    /// through the live overlay when mutations are pending.
    pub fn query_batch_with(
        &mut self,
        items: &[(Vec<f64>, QueryOptions)],
    ) -> Vec<QueryOutcome> {
        self.live.query_batch::<Squared>(&mut self.searcher, items)
    }

    /// Streaming subsequence search over this engine's index: slide an
    /// index-length window along `samples` and report matching windows —
    /// the line protocol's `stream=` requests (see `docs/protocol.md`).
    ///
    /// With pending mutations the sweep carries the live overlay
    /// (tombstone skip mask + delta continuation, logical-id emission);
    /// an insert-only index (empty base) scans a temporary compacted
    /// build, which the compaction invariant makes identical to a cold
    /// rebuild. Matches are bit-identical to a frozen index over the
    /// same logical series set either way.
    pub fn query_stream(
        &mut self,
        samples: &[f64],
        opts: crate::stream::SubsequenceOptions,
    ) -> anyhow::Result<crate::stream::StreamReport> {
        if !self.live.is_dirty() {
            return self.searcher.index().subsequence_scan::<Squared>(samples, opts);
        }
        let index = self.searcher.index().clone();
        if index.is_empty() {
            let tmp = crate::live::compacted(&index, self.live.delta(), self.live.tombstones())?;
            return tmp.subsequence_scan::<Squared>(samples, opts);
        }
        let mut s = index.subsequence(opts)?;
        let delta: Vec<(u32, PreparedSeries)> = self
            .live
            .delta()
            .entries()
            .iter()
            .map(|e| (e.label, e.series.clone()))
            .collect();
        s.set_overlay(delta, self.live.tombstones().dead_mask(index.len()));
        s.scan::<Squared>(samples);
        Ok(s.finish())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::search::knn::{knn_brute_force, KnnParams};
    use crate::search::PreparedTrainSet;

    fn brute_1nn(q: &[f64], train: &PreparedTrainSet) -> NnResult {
        let (r, _) = knn_brute_force::<Squared>(q, train, &KnnParams::default());
        r.into_iter().next().unwrap()
    }

    #[test]
    fn scalar_path_is_exact() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 61))[0];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Webb);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for q in &ds.test {
            let resp = engine.query_one(&q.values);
            let truth = brute_1nn(&q.values, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Scalar);
        }
    }

    #[test]
    fn replace_index_keeps_the_serving_backend_attachment() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 65))[0];
        // A scalar-only engine must stay scalar-only even when the new
        // index's stored config names the native backend…
        let scalar_idx = crate::index::DtwIndex::builder_from_dataset(ds)
            .backend(crate::runtime::BackendKind::None)
            .build()
            .unwrap();
        let native_idx = crate::index::DtwIndex::builder_from_dataset(ds)
            .backend(crate::runtime::BackendKind::Native)
            .build()
            .unwrap();
        let mut engine = NnEngine::from_index(scalar_idx.clone());
        assert!(!engine.has_batch_path());
        engine.replace_index(native_idx.clone());
        assert!(!engine.has_batch_path(), "load must not silently attach a backend");
        // …and a batched engine must keep its prefilter when the new
        // index's stored config says none.
        let mut engine = NnEngine::from_index(native_idx);
        assert_eq!(engine.backend_name(), Some("native"));
        engine.replace_index(scalar_idx);
        assert_eq!(
            engine.backend_name(),
            Some("native"),
            "load must not silently drop the serving backend"
        );
    }

    #[test]
    fn batch_without_backend_falls_back_to_scalar() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 61))[1];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Webb);
        assert!(!engine.has_batch_path());
        assert_eq!(engine.backend_name(), None);
        let queries: Vec<Vec<f64>> = ds.test.iter().take(3).map(|s| s.values.clone()).collect();
        let out = engine.query_batch(&queries);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.path == EnginePath::Scalar));
    }

    #[test]
    fn native_backend_batch_is_exact() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 62))[0];
        let w = ds.window.max(1);
        let mut engine =
            NnEngine::with_backend(ds, w, BoundKind::Keogh, Box::new(NativeBatchLb::new()));
        assert_eq!(engine.backend_name(), Some("native"));
        let queries: Vec<Vec<f64>> = ds.test.iter().map(|s| s.values.clone()).collect();
        assert!(queries.len() > 1, "need a real batch");
        let out = engine.query_batch(&queries);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for (resp, q) in out.iter().zip(queries.iter()) {
            let truth = brute_1nn(q, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Batched);
        }
    }

    #[test]
    fn batched_knn_with_mixed_k_is_exact() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 64))[0];
        let w = ds.window.max(1);
        let mut engine =
            NnEngine::with_backend(ds, w, BoundKind::Keogh, Box::new(NativeBatchLb::new()));
        let train = PreparedTrainSet::from_dataset(ds, w);
        let items: Vec<(Vec<f64>, QueryOptions)> = ds
            .test
            .iter()
            .enumerate()
            .map(|(i, s)| (s.values.clone(), QueryOptions::k(1 + (i % 3) * 2)))
            .collect();
        assert!(items.len() > 1);
        let outs = engine.query_batch_with(&items);
        for (out, (q, opts)) in outs.iter().zip(items.iter()) {
            assert!(out.batched);
            let (truth, _) = knn_brute_force::<Squared>(q, &train, &KnnParams::k(opts.k));
            let want: Vec<f64> = truth.iter().map(|r| r.distance).collect();
            assert_eq!(out.distances(), want, "k={}", opts.k);
        }
    }

    #[test]
    fn single_query_batch_takes_scalar_path() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 63))[2];
        let w = ds.window.max(1);
        let mut engine =
            NnEngine::with_backend(ds, w, BoundKind::Webb, Box::new(NativeBatchLb::new()));
        let out = engine.query_batch(&[ds.test[0].values.clone()]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].path, EnginePath::Scalar);
    }

    #[test]
    fn live_mutations_match_cold_rebuild_on_every_path() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 66))[0];
        let w = ds.window.max(1);
        let raw: Vec<Vec<f64>> = ds.train.iter().map(|s| s.values.clone()).collect();
        let labels: Vec<u32> = ds.train.iter().map(|s| s.label).collect();
        let build = |series: Vec<Vec<f64>>, labels: Vec<u32>| {
            crate::index::DtwIndex::builder(series)
                .labels(labels)
                .window(w)
                .build()
                .unwrap()
        };
        let mut engine = NnEngine::from_index(build(raw.clone(), labels.clone()));

        // Mutate: delete two base series, insert two test series.
        engine.delete(1).unwrap();
        engine.delete(3).unwrap();
        let id = engine.insert(41, ds.test[0].values.clone()).unwrap();
        assert_eq!(id, raw.len() - 2);
        engine.insert(42, ds.test[1].values.clone()).unwrap();
        assert_eq!(engine.logical_len(), raw.len());

        // The same logical series set, cold.
        let mut cold_series: Vec<Vec<f64>> = Vec::new();
        let mut cold_labels: Vec<u32> = Vec::new();
        for (i, s) in raw.iter().enumerate() {
            // Logical deletes above targeted ids 1 and 3 of the shifting
            // id space: physical 1, then physical 4.
            if i == 1 || i == 4 {
                continue;
            }
            cold_series.push(s.clone());
            cold_labels.push(labels[i]);
        }
        cold_series.push(ds.test[0].values.clone());
        cold_labels.push(41);
        cold_series.push(ds.test[1].values.clone());
        cold_labels.push(42);
        let cold = build(cold_series, cold_labels);
        let mut cold_engine = NnEngine::from_index(cold.clone());

        let pair = |o: &QueryOutcome| -> Vec<(usize, f64, u32)> {
            o.neighbors.iter().map(|n| (n.index, n.distance, n.label)).collect()
        };
        for q in ds.test.iter().take(4) {
            for k in [1usize, 3] {
                let a = engine.query_with(&q.values, &QueryOptions::k(k));
                let b = cold_engine.query_with(&q.values, &QueryOptions::k(k));
                assert_eq!(pair(&a), pair(&b), "live vs cold k={k}");
            }
        }
        // Batched path.
        let items: Vec<(Vec<f64>, QueryOptions)> =
            ds.test.iter().take(4).map(|s| (s.values.clone(), QueryOptions::k(2))).collect();
        let live_outs = engine.query_batch_with(&items);
        let cold_outs = cold_engine.query_batch_with(&items);
        for (a, b) in live_outs.iter().zip(cold_outs.iter()) {
            assert_eq!(pair(a), pair(b), "batched live vs cold");
        }
        // Stream path.
        let mut samples: Vec<f64> = Vec::new();
        for s in ds.test.iter().take(3) {
            samples.extend_from_slice(&s.values);
        }
        let opts = crate::stream::SubsequenceOptions::top_k(3);
        let a = engine.query_stream(&samples, opts.clone()).unwrap();
        let b = cold_engine.query_stream(&samples, opts).unwrap();
        let ms = |r: &crate::stream::StreamReport| -> Vec<(u64, usize, f64)> {
            r.matches.iter().map(|m| (m.start, m.neighbor, m.distance)).collect()
        };
        assert_eq!(ms(&a), ms(&b), "stream live vs cold");
        assert!(a.stats.delta_scanned > 0, "overlay continuation ran");

        // Compaction folds the state and keeps every answer.
        let want = engine.query_with(&ds.test[0].values, &QueryOptions::k(3));
        let generation = engine.compact().unwrap();
        assert_eq!(generation, 1);
        assert_eq!(engine.delta_len(), 0);
        assert_eq!(engine.train_len(), raw.len());
        let got = engine.query_with(&ds.test[0].values, &QueryOptions::k(3));
        assert_eq!(pair(&want), pair(&got), "compaction changes no answer");
        // Compacted base ≡ cold rebuild, bit for bit.
        for (a, b) in engine.index().train().series.iter().zip(cold.train().series.iter()) {
            assert_eq!(a.values, b.values);
            assert_eq!(a.lo, b.lo);
            assert_eq!(a.up, b.up);
        }
        assert_eq!(engine.index().train().labels, cold.train().labels);
    }

    #[test]
    fn auto_compact_triggers_at_threshold() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 67))[1];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let mut engine = NnEngine::from_index(index);
        engine.set_auto_compact(Some(2));
        engine.insert(9, ds.test[0].values.clone()).unwrap();
        assert_eq!(engine.maybe_auto_compact().unwrap(), None, "below threshold");
        engine.insert(9, ds.test[1].values.clone()).unwrap();
        assert_eq!(engine.maybe_auto_compact().unwrap(), Some(1), "threshold reached");
        assert_eq!(engine.generation(), 1);
        assert_eq!(engine.delta_len(), 0);
    }

    #[test]
    fn wal_replay_recovers_acked_mutations_bit_equal() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 68))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let fs = crate::io::FaultFs::new();
        let anchor = PathBuf::from("serve.snap");

        let mut engine = NnEngine::from_index(index.clone());
        engine.set_fs(Arc::new(fs.clone()));
        let info = engine.enable_wal(&anchor, FsyncPolicy::Always).unwrap();
        assert_eq!(info.records, 0);
        assert!(engine.wal_enabled());
        engine.insert(9, ds.test[0].values.clone()).unwrap();
        engine.delete(0).unwrap();
        let want = engine.query_with(&ds.test[2].values, &QueryOptions::k(3));
        drop(engine);

        // A fresh process over the same base replays the log through the
        // identical mutation path — answers match bit for bit.
        let mut engine = NnEngine::from_index(index.clone());
        engine.set_fs(Arc::new(fs.clone()));
        let info = engine.enable_wal(&anchor, FsyncPolicy::Always).unwrap();
        assert_eq!(info.records, 2);
        assert!(!info.truncated);
        assert_eq!(engine.wal_records(), 2);
        let got = engine.query_with(&ds.test[2].values, &QueryOptions::k(3));
        assert_eq!(want.distances(), got.distances());

        // Compaction rotates: new base persisted over the anchor, fresh
        // empty log for generation 1, old log gone.
        let generation = engine.compact().unwrap();
        assert_eq!(generation, 1);
        assert_eq!(engine.wal_records(), 0);
        assert!(fs.exists(&wal::wal_path(&anchor, 1)));
        assert!(!fs.exists(&wal::wal_path(&anchor, 0)));
        let loaded = crate::index::snapshot::load_with(&anchor, &fs).unwrap();
        assert_eq!(loaded.generation(), 1);

        // Rejected mutations never touch the log.
        assert!(engine.insert(1, vec![]).is_err());
        assert!(engine.delete(10_000).is_err());
        assert_eq!(engine.wal_records(), 0);
    }

    /// Exactness of the PJRT path (needs `make artifacts` + real XLA).
    #[cfg(feature = "pjrt")]
    #[test]
    fn batched_path_is_exact_when_artifact_present() {
        use crate::runtime::XlaRuntime;
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 62))[0];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Keogh);
        let rt = match XlaRuntime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e:#})");
                return;
            }
        };
        if let Err(e) = engine.attach_batch_lb(&rt, &dir, 8) {
            eprintln!("skipping: {e:#}");
            return;
        }
        let queries: Vec<Vec<f64>> =
            ds.test.iter().take(8).map(|s| s.values.clone()).collect();
        let out = engine.query_batch(&queries);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for (resp, q) in out.iter().zip(queries.iter()) {
            let truth = brute_1nn(q, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Batched);
        }
    }
}
