//! The query engine: exact 1-NN DTW with lower-bound screening, with an
//! optional PJRT **batch prefilter**.
//!
//! Scalar path = the paper's Algorithm 4 per query. Batch path = one XLA
//! execution computes the `LB_KEOGH` matrix for the whole query batch
//! (the L1 Pallas kernel), then each query walks its candidates in
//! ascending-bound order with early-abandoning DTW. Results are exact
//! either way; only the screening cost moves.

use std::time::{Duration, Instant};

use crate::bounds::{BoundKind, PreparedSeries, Scratch};
use crate::data::Dataset;
use crate::delta::Squared;
use crate::dtw::dtw_ea;
use crate::runtime::{BatchLb, XlaRuntime};
use crate::search::nn::{nn_sorted, NnResult};
use crate::search::PreparedTrainSet;

/// Which path answered a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnginePath {
    /// Per-query scalar bound (Algorithm 4 in Rust).
    Scalar,
    /// XLA batched prefilter + DTW on survivors.
    Batched,
}

/// Response for one query.
#[derive(Debug, Clone)]
pub struct QueryResponse {
    /// The exact nearest neighbor.
    pub result: NnResult,
    /// Which path computed it.
    pub path: EnginePath,
    /// Engine-side latency.
    pub latency: Duration,
}

/// Exact 1-NN engine over one dataset's training split.
pub struct NnEngine {
    train: PreparedTrainSet,
    bound: BoundKind,
    batch_lb: Option<BatchLb>,
    scratch: Scratch,
    bound_buf: Vec<f64>,
    index_buf: Vec<usize>,
}

impl NnEngine {
    /// Build an engine (scalar paths only) for a dataset at window `w`.
    pub fn new(ds: &Dataset, w: usize, bound: BoundKind) -> Self {
        let train = PreparedTrainSet::from_dataset(ds, w);
        NnEngine {
            train,
            bound,
            batch_lb: None,
            scratch: Scratch::default(),
            bound_buf: Vec::new(),
            index_buf: Vec::new(),
        }
    }

    /// Attach a PJRT batch prefilter loaded from `artifacts_dir`.
    /// Fails (leaving the scalar path intact) when no artifact fits.
    pub fn attach_batch_lb(
        &mut self,
        rt: &XlaRuntime,
        artifacts_dir: &std::path::Path,
        max_batch: usize,
    ) -> anyhow::Result<()> {
        let l = self.train.series.first().map(|s| s.len()).unwrap_or(0);
        let blb = BatchLb::load(rt, artifacts_dir, max_batch, self.train.len(), l)?;
        self.batch_lb = Some(blb);
        Ok(())
    }

    /// True when the batch path is available.
    pub fn has_batch_path(&self) -> bool {
        self.batch_lb.is_some()
    }

    /// Training-set size.
    pub fn train_len(&self) -> usize {
        self.train.len()
    }

    /// The engine's window.
    pub fn window(&self) -> usize {
        self.train.w
    }

    /// Answer one query on the scalar path.
    pub fn query_one(&mut self, values: &[f64]) -> QueryResponse {
        let started = Instant::now();
        let pq = PreparedSeries::prepare(values.to_vec(), self.train.w);
        let (result, _) = nn_sorted::<Squared>(
            &pq,
            &self.train,
            self.bound,
            &mut self.scratch,
            &mut self.bound_buf,
            &mut self.index_buf,
        );
        QueryResponse { result, path: EnginePath::Scalar, latency: started.elapsed() }
    }

    /// Answer a batch of queries, using the XLA prefilter when attached
    /// (and the batch is non-trivial), otherwise the scalar path per query.
    pub fn query_batch(&mut self, queries: &[Vec<f64>]) -> Vec<QueryResponse> {
        if queries.is_empty() {
            return Vec::new();
        }
        let use_batch = match &self.batch_lb {
            Some(blb) => {
                let (cb, cn, cl) = blb.shape;
                let l = queries[0].len();
                queries.len() > 1
                    && queries.len() <= cb
                    && self.train.len() <= cn
                    && l <= cl
                    && queries.iter().all(|q| q.len() == l)
            }
            None => false,
        };
        if !use_batch {
            return queries.iter().map(|q| self.query_one(q)).collect();
        }

        let started = Instant::now();
        let blb = self.batch_lb.as_mut().expect("checked above");
        let q_refs: Vec<&[f64]> = queries.iter().map(|v| v.as_slice()).collect();
        let lo_refs: Vec<&[f64]> = self.train.series.iter().map(|t| t.lo.as_slice()).collect();
        let up_refs: Vec<&[f64]> = self.train.series.iter().map(|t| t.up.as_slice()).collect();
        let matrix = match blb.compute(&q_refs, &lo_refs, &up_refs) {
            Ok(m) => m,
            Err(e) => {
                log::warn!("batch prefilter failed ({e:#}); falling back to scalar");
                return queries.iter().map(|q| self.query_one(q)).collect();
            }
        };
        let prefilter_each = started.elapsed() / queries.len() as u32;

        let w = self.train.w;
        let mut out = Vec::with_capacity(queries.len());
        for (qi, q) in queries.iter().enumerate() {
            let q_started = Instant::now();
            let lbs = &matrix[qi];
            self.index_buf.clear();
            self.index_buf.extend(0..self.train.len());
            let idx = &mut self.index_buf;
            idx.sort_unstable_by(|&a, &b| lbs[a].partial_cmp(&lbs[b]).unwrap());
            let mut best =
                NnResult { nn_index: usize::MAX, distance: f64::INFINITY, label: 0 };
            for &ti in idx.iter() {
                if lbs[ti] >= best.distance {
                    break;
                }
                let d = dtw_ea::<Squared>(q, &self.train.series[ti].values, w, best.distance);
                if d < best.distance {
                    best = NnResult {
                        nn_index: ti,
                        distance: d,
                        label: self.train.labels[ti],
                    };
                }
            }
            out.push(QueryResponse {
                result: best,
                path: EnginePath::Batched,
                latency: prefilter_each + q_started.elapsed(),
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::search::nn::nn_brute_force;

    #[test]
    fn scalar_path_is_exact() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 61))[0];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Webb);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for q in &ds.test {
            let resp = engine.query_one(&q.values);
            let (truth, _) = nn_brute_force::<Squared>(&q.values, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Scalar);
        }
    }

    #[test]
    fn batch_without_artifact_falls_back() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 61))[1];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Webb);
        assert!(!engine.has_batch_path());
        let queries: Vec<Vec<f64>> = ds.test.iter().take(3).map(|s| s.values.clone()).collect();
        let out = engine.query_batch(&queries);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.path == EnginePath::Scalar));
    }

    /// Exactness of the batched path (needs `make artifacts`).
    #[test]
    fn batched_path_is_exact_when_artifact_present() {
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 62))[0];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Keogh);
        let rt = XlaRuntime::cpu().unwrap();
        if let Err(e) = engine.attach_batch_lb(&rt, &dir, 8) {
            eprintln!("skipping: {e:#}");
            return;
        }
        let queries: Vec<Vec<f64>> =
            ds.test.iter().take(8).map(|s| s.values.clone()).collect();
        let out = engine.query_batch(&queries);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for (resp, q) in out.iter().zip(queries.iter()) {
            let (truth, _) = nn_brute_force::<Squared>(q, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Batched);
        }
    }
}
