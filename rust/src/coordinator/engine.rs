//! The query engine — a thin adapter holding a per-thread
//! [`Searcher`] over a shared [`DtwIndex`].
//!
//! The index owns the prepared envelopes and configuration; the engine
//! adds the serving-era surface the router/server consume (legacy
//! [`QueryResponse`] conversion, backend attachment helpers). Scalar
//! path = the paper's Algorithm 4 per query; batch path = the attached
//! [`LbBackend`] computes the `LB_KEOGH` matrix for the whole query
//! batch, then each query walks its candidates in ascending-bound order
//! with early-abandoning DTW. Results are exact either way; only the
//! screening cost moves.

use std::time::Duration;

use crate::bounds::BoundKind;
use crate::data::Dataset;
use crate::delta::Squared;
use crate::index::{DtwIndex, QueryOptions, QueryOutcome, Searcher};
use crate::runtime::{BackendKind, LbBackend, NativeBatchLb};
use crate::search::nn::NnResult;
use crate::search::SearchStrategy;

/// Which path answered a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnginePath {
    /// Per-query scalar bound (Algorithm 4 in Rust).
    Scalar,
    /// Batched backend prefilter + DTW on survivors.
    Batched,
}

/// Legacy 1-NN response for one query (the server's line protocol).
#[derive(Debug, Clone)]
pub struct QueryResponse {
    /// The exact nearest neighbor.
    pub result: NnResult,
    /// Which path computed it.
    pub path: EnginePath,
    /// Engine-side latency.
    pub latency: Duration,
}

impl QueryResponse {
    /// Collapse a k-NN [`QueryOutcome`] to its nearest neighbor.
    pub fn from_outcome(outcome: QueryOutcome) -> QueryResponse {
        QueryResponse {
            result: outcome.best_nn(),
            path: if outcome.batched { EnginePath::Batched } else { EnginePath::Scalar },
            latency: outcome.latency,
        }
    }
}

/// Exact k-NN engine over one dataset's training split: a [`Searcher`]
/// plus adapters for the line-protocol serving stack.
pub struct NnEngine {
    searcher: Searcher,
}

impl NnEngine {
    /// Build an engine (scalar path only) for a dataset at window `w`.
    pub fn new(ds: &Dataset, w: usize, bound: BoundKind) -> Self {
        let index = DtwIndex::builder_from_dataset(ds)
            .window(w)
            .bound(bound)
            .strategy(SearchStrategy::Sorted)
            .backend(BackendKind::None)
            .build()
            .expect("dataset series share one length");
        NnEngine::from_index(index)
    }

    /// Wrap a prebuilt index — the facade path: the index (and its
    /// prepared envelopes) can be shared across engines/threads.
    pub fn from_index(index: DtwIndex) -> Self {
        NnEngine { searcher: index.searcher() }
    }

    /// Build an engine with a batched screening backend attached.
    pub fn with_backend(
        ds: &Dataset,
        w: usize,
        bound: BoundKind,
        backend: Box<dyn LbBackend>,
    ) -> Self {
        let mut engine = NnEngine::new(ds, w, bound);
        engine.set_backend(backend);
        engine
    }

    /// Attach (or replace) the batched screening backend.
    pub fn set_backend(&mut self, backend: Box<dyn LbBackend>) {
        log::info!("engine: batched prefilter backend = {}", backend.name());
        self.searcher.set_backend(backend);
    }

    /// Attach the default pure-Rust batched backend, scoring query rows
    /// on the index's configured thread count.
    pub fn attach_native(&mut self) {
        let threads = self.searcher.index().threads();
        self.set_backend(Box::new(NativeBatchLb::with_threads(threads)));
    }

    /// Attach the PJRT batch prefilter loaded from `artifacts_dir`.
    /// Fails (leaving any current backend intact) when no artifact fits.
    #[cfg(feature = "pjrt")]
    pub fn attach_batch_lb(
        &mut self,
        rt: &crate::runtime::XlaRuntime,
        artifacts_dir: &std::path::Path,
        max_batch: usize,
    ) -> anyhow::Result<()> {
        let index = self.searcher.index();
        let l = index.train().series.first().map(|s| s.len()).unwrap_or(0);
        let blb =
            crate::runtime::BatchLb::load(rt, artifacts_dir, max_batch, index.len(), l)?;
        self.set_backend(Box::new(blb));
        Ok(())
    }

    /// The index this engine serves.
    pub fn index(&self) -> &DtwIndex {
        self.searcher.index()
    }

    /// Swap the served index: the engine rebuilds its searcher (scratch,
    /// RNG, sort buffers) around the new index while **keeping its
    /// current backend attachment** — which screening backend serves is
    /// a deployment choice (`serve --backend`, an explicit
    /// [`NnEngine::set_backend`]), so a hot-swap must not silently flip
    /// it to whatever the snapshot's stored config names (scalar-only
    /// snapshots would drop a native prefilter; native snapshots would
    /// override `--no-batch`). This is the `load=<path>;` protocol
    /// verb's engine half: a running router hot-swaps onto a snapshot
    /// without restarting and without changing how it screens.
    pub fn replace_index(&mut self, index: DtwIndex) {
        let backend = self.searcher.take_backend();
        self.searcher = index.searcher();
        match backend {
            Some(b) => self.searcher.set_backend(b),
            None => self.searcher.clear_backend(),
        }
    }

    /// True when a batched screening backend is attached.
    pub fn has_batch_path(&self) -> bool {
        self.searcher.has_backend()
    }

    /// Name of the attached screening backend, if any.
    pub fn backend_name(&self) -> Option<&'static str> {
        self.searcher.backend_name()
    }

    /// Training-set size.
    pub fn train_len(&self) -> usize {
        self.searcher.index().len()
    }

    /// The engine's window.
    pub fn window(&self) -> usize {
        self.searcher.index().window()
    }

    /// Answer one query on the scalar path (1-NN legacy shape).
    pub fn query_one(&mut self, values: &[f64]) -> QueryResponse {
        QueryResponse::from_outcome(
            self.searcher.query_values::<Squared>(values, &QueryOptions::default()),
        )
    }

    /// Answer one query with full options (k-NN, threshold, z-norm).
    pub fn query_with(&mut self, values: &[f64], opts: &QueryOptions) -> QueryOutcome {
        self.searcher.query_values::<Squared>(values, opts)
    }

    /// Answer a batch of queries (1-NN legacy shape), riding the
    /// attached backend when the batch is non-trivial and fits its
    /// shape, otherwise the scalar path per query.
    pub fn query_batch(&mut self, queries: &[Vec<f64>]) -> Vec<QueryResponse> {
        self.searcher
            .query_batch::<Squared>(queries, &QueryOptions::default())
            .into_iter()
            .map(QueryResponse::from_outcome)
            .collect()
    }

    /// Answer a batch of `(values, options)` pairs — the router's shape,
    /// where concurrent clients may ask for different `k`.
    pub fn query_batch_with(
        &mut self,
        items: &[(Vec<f64>, QueryOptions)],
    ) -> Vec<QueryOutcome> {
        self.searcher.query_batch_mixed::<Squared>(items)
    }

    /// Streaming subsequence search over this engine's index: slide an
    /// index-length window along `samples` and report matching windows —
    /// the line protocol's `stream=` requests (see `docs/protocol.md`).
    pub fn query_stream(
        &mut self,
        samples: &[f64],
        opts: crate::stream::SubsequenceOptions,
    ) -> anyhow::Result<crate::stream::StreamReport> {
        self.searcher.index().subsequence_scan::<Squared>(samples, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::search::knn::{knn_brute_force, KnnParams};
    use crate::search::PreparedTrainSet;

    fn brute_1nn(q: &[f64], train: &PreparedTrainSet) -> NnResult {
        let (r, _) = knn_brute_force::<Squared>(q, train, &KnnParams::default());
        r.into_iter().next().unwrap()
    }

    #[test]
    fn scalar_path_is_exact() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 61))[0];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Webb);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for q in &ds.test {
            let resp = engine.query_one(&q.values);
            let truth = brute_1nn(&q.values, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Scalar);
        }
    }

    #[test]
    fn replace_index_keeps_the_serving_backend_attachment() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 65))[0];
        // A scalar-only engine must stay scalar-only even when the new
        // index's stored config names the native backend…
        let scalar_idx = crate::index::DtwIndex::builder_from_dataset(ds)
            .backend(crate::runtime::BackendKind::None)
            .build()
            .unwrap();
        let native_idx = crate::index::DtwIndex::builder_from_dataset(ds)
            .backend(crate::runtime::BackendKind::Native)
            .build()
            .unwrap();
        let mut engine = NnEngine::from_index(scalar_idx.clone());
        assert!(!engine.has_batch_path());
        engine.replace_index(native_idx.clone());
        assert!(!engine.has_batch_path(), "load must not silently attach a backend");
        // …and a batched engine must keep its prefilter when the new
        // index's stored config says none.
        let mut engine = NnEngine::from_index(native_idx);
        assert_eq!(engine.backend_name(), Some("native"));
        engine.replace_index(scalar_idx);
        assert_eq!(
            engine.backend_name(),
            Some("native"),
            "load must not silently drop the serving backend"
        );
    }

    #[test]
    fn batch_without_backend_falls_back_to_scalar() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 61))[1];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Webb);
        assert!(!engine.has_batch_path());
        assert_eq!(engine.backend_name(), None);
        let queries: Vec<Vec<f64>> = ds.test.iter().take(3).map(|s| s.values.clone()).collect();
        let out = engine.query_batch(&queries);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|r| r.path == EnginePath::Scalar));
    }

    #[test]
    fn native_backend_batch_is_exact() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 62))[0];
        let w = ds.window.max(1);
        let mut engine =
            NnEngine::with_backend(ds, w, BoundKind::Keogh, Box::new(NativeBatchLb::new()));
        assert_eq!(engine.backend_name(), Some("native"));
        let queries: Vec<Vec<f64>> = ds.test.iter().map(|s| s.values.clone()).collect();
        assert!(queries.len() > 1, "need a real batch");
        let out = engine.query_batch(&queries);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for (resp, q) in out.iter().zip(queries.iter()) {
            let truth = brute_1nn(q, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Batched);
        }
    }

    #[test]
    fn batched_knn_with_mixed_k_is_exact() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 64))[0];
        let w = ds.window.max(1);
        let mut engine =
            NnEngine::with_backend(ds, w, BoundKind::Keogh, Box::new(NativeBatchLb::new()));
        let train = PreparedTrainSet::from_dataset(ds, w);
        let items: Vec<(Vec<f64>, QueryOptions)> = ds
            .test
            .iter()
            .enumerate()
            .map(|(i, s)| (s.values.clone(), QueryOptions::k(1 + (i % 3) * 2)))
            .collect();
        assert!(items.len() > 1);
        let outs = engine.query_batch_with(&items);
        for (out, (q, opts)) in outs.iter().zip(items.iter()) {
            assert!(out.batched);
            let (truth, _) = knn_brute_force::<Squared>(q, &train, &KnnParams::k(opts.k));
            let want: Vec<f64> = truth.iter().map(|r| r.distance).collect();
            assert_eq!(out.distances(), want, "k={}", opts.k);
        }
    }

    #[test]
    fn single_query_batch_takes_scalar_path() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 63))[2];
        let w = ds.window.max(1);
        let mut engine =
            NnEngine::with_backend(ds, w, BoundKind::Webb, Box::new(NativeBatchLb::new()));
        let out = engine.query_batch(&[ds.test[0].values.clone()]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].path, EnginePath::Scalar);
    }

    /// Exactness of the PJRT path (needs `make artifacts` + real XLA).
    #[cfg(feature = "pjrt")]
    #[test]
    fn batched_path_is_exact_when_artifact_present() {
        use crate::runtime::XlaRuntime;
        let dir = crate::runtime::default_artifacts_dir();
        if !dir.join("manifest.tsv").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 62))[0];
        let w = ds.window.max(1);
        let mut engine = NnEngine::new(ds, w, BoundKind::Keogh);
        let rt = match XlaRuntime::cpu() {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("skipping: PJRT unavailable ({e:#})");
                return;
            }
        };
        if let Err(e) = engine.attach_batch_lb(&rt, &dir, 8) {
            eprintln!("skipping: {e:#}");
            return;
        }
        let queries: Vec<Vec<f64>> =
            ds.test.iter().take(8).map(|s| s.values.clone()).collect();
        let out = engine.query_batch(&queries);
        let train = PreparedTrainSet::from_dataset(ds, w);
        for (resp, q) in out.iter().zip(queries.iter()) {
            let truth = brute_1nn(q, &train);
            assert_eq!(resp.result.distance, truth.distance);
            assert_eq!(resp.path, EnginePath::Batched);
        }
    }
}
