//! Request router and dynamic batcher.
//!
//! Clients call [`Router::query`] (or [`Router::query_with`] for k-NN)
//! from any thread; a single dispatch thread owns the [`NnEngine`]
//! (backend handles — PJRT in particular — are not `Sync`) and drains
//! the queue into batches: when several queries are waiting they ride
//! the engine's batched [`crate::runtime::LbBackend`] prefilter
//! together; a lone query takes the scalar path immediately. This is the
//! standard router/batcher shape of serving systems (vLLM-style),
//! scaled to this paper's workload.
//!
//! The cheapest way to stand one up is [`Router::spawn_index`]: hand it
//! a shared [`DtwIndex`] and the dispatch thread builds its searcher
//! from the index's configuration.
//!
//! ## Hardening
//!
//! The dispatch loop is the serving process's single point of failure,
//! so it is defended on two fronts:
//!
//! * **Overload shedding** — the `try_*` submit variants refuse new
//!   work with [`Busy`] once the queue holds [`Router::queue_cap`]
//!   unpicked messages (the server replies `err=busy`); the blocking
//!   variants never shed (internal/CLI callers prefer waiting).
//! * **Panic isolation** — batch execution, stream scans and control
//!   handling each run under `catch_unwind`: a panicking request drops
//!   its reply sender (the waiting client sees a disconnect →
//!   `err=internal`), bumps the `panics` counter, and the loop keeps
//!   serving everyone else.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::index::{DtwIndex, QueryOptions, QueryOutcome, SnapshotError};
use crate::stream::{StreamReport, SubsequenceOptions};

use super::engine::{GenerationInfo, NnEngine, QueryResponse};

enum Msg {
    Query(Vec<f64>, QueryOptions, Sender<QueryOutcome>),
    Stream(Vec<f64>, SubsequenceOptions, Sender<anyhow::Result<StreamReport>>),
    Save(PathBuf, Sender<Result<SnapshotSaved, SnapshotError>>),
    Load(PathBuf, Sender<Result<SnapshotLoaded, SnapshotError>>),
    Insert(u32, Vec<f64>, Sender<anyhow::Result<InsertReceipt>>),
    Delete(usize, Sender<anyhow::Result<DeleteReceipt>>),
    Compact(Sender<anyhow::Result<CompactReceipt>>),
    Gens(Sender<GenerationInfo>),
    Stats(Sender<RouterStats>),
    Shutdown,
}

/// Refused by the `try_*` submit variants when the router's queue is at
/// capacity — the shed-on-overload signal (wire reply: `err=busy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Busy;

impl std::fmt::Display for Busy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "router queue at capacity")
    }
}

impl std::error::Error for Busy {}

/// State shared between submitters and the dispatch thread: queue
/// accounting for shedding, plus the hardening counters.
struct Shared {
    /// Messages submitted but not yet picked up by dispatch.
    pending: AtomicUsize,
    /// Queue capacity the `try_*` paths admit against (admission is
    /// approximate under contention — the cap bounds backlog, it is not
    /// a strict semaphore).
    cap: AtomicUsize,
    /// Requests refused with [`Busy`].
    shed: AtomicUsize,
    /// Panics caught by the dispatch loop (each failed one request).
    panics: AtomicUsize,
    /// Test hook: make the next batch execution panic.
    poison: AtomicBool,
}

/// Default queue capacity for the fallible submit paths.
const DEFAULT_QUEUE_CAP: usize = 1024;

/// Receipt for a `save=` request: where the snapshot landed and its
/// size. The path is the **generation-versioned** one actually written
/// (`<requested>.g<N>`), not the requested base.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SnapshotSaved {
    /// Path the snapshot was written to.
    pub path: PathBuf,
    /// Bytes written.
    pub bytes: u64,
}

/// Receipt for an `insert=` request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InsertReceipt {
    /// Logical id assigned to the inserted series.
    pub id: usize,
    /// Delta-shard length after the insert.
    pub delta_len: usize,
    /// Generation of the serving base.
    pub generation: u64,
}

/// Receipt for a `delete=` request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeleteReceipt {
    /// Logical series count after the delete.
    pub remaining: usize,
    /// Base tombstones now pending.
    pub tombstones: usize,
}

/// Receipt for a `compact=` request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompactReceipt {
    /// Generation now serving (old + 1).
    pub generation: u64,
    /// Series count of the compacted base.
    pub series: usize,
}

/// Receipt for a `load=` request: the shape of the index now serving.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SnapshotLoaded {
    /// Indexed series count.
    pub series: usize,
    /// Shard count.
    pub shards: usize,
    /// Warping window.
    pub window: usize,
}

/// Handle to the dispatch thread. Cloneable senders, blocking `query`.
pub struct Router {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<RouterStats>>,
    shared: Arc<Shared>,
}

/// Dispatch-loop statistics, returned by [`Router::shutdown`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Total queries served.
    pub served: usize,
    /// Number of dispatch batches formed.
    pub batches: usize,
    /// Largest batch formed.
    pub max_batch: usize,
    /// Queries answered through the batched backend prefilter.
    pub batched: usize,
    /// Queries answered on the scalar path.
    pub scalar: usize,
    /// Subsequence-search (`stream=`) requests served.
    pub streams: usize,
    /// Snapshot `save=` requests served (successfully or not).
    pub saves: usize,
    /// Snapshot `load=` requests that swapped the served index.
    pub loads: usize,
    /// Whole clusters skipped across all served queries (nonzero only
    /// when the served index carries a cluster-pruning layer).
    pub clusters_pruned: usize,
    /// Candidates skipped via cluster-level pruning across all served
    /// queries.
    pub cluster_members_pruned: usize,
    /// `insert=` requests that appended to the delta shard.
    pub inserts: usize,
    /// `delete=` requests that removed a logical series.
    pub deletes: usize,
    /// Compactions performed (explicit `compact=` plus auto-threshold).
    pub compactions: usize,
    /// Gauge: delta-shard length when the loop last settled.
    pub delta_len: usize,
    /// Gauge: generation of the base index when the loop last settled.
    pub generation: u64,
    /// Panics caught by the dispatch loop (each failed exactly one
    /// request; the loop kept serving).
    pub panics: usize,
    /// Requests refused with [`Busy`] under overload.
    pub shed: usize,
    /// Gauge: submitted-but-unpicked messages when the loop last
    /// settled.
    pub pending: usize,
    /// Gauge: records in the engine's write-ahead log (0 = WAL off).
    pub wal_records: u64,
}

impl Router {
    /// Spawn the dispatch loop. The engine is **constructed inside** the
    /// dispatch thread by `factory` — backend handles (PJRT in
    /// particular) are not `Send`, so the engine must never cross
    /// threads. `max_batch` caps how many queued queries ride one
    /// prefilter execution.
    pub fn spawn<F>(factory: F, max_batch: usize) -> Router
    where
        F: FnOnce() -> NnEngine + Send + 'static,
    {
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = mpsc::channel();
        let shared = Arc::new(Shared {
            pending: AtomicUsize::new(0),
            cap: AtomicUsize::new(DEFAULT_QUEUE_CAP),
            shed: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
            poison: AtomicBool::new(false),
        });
        let shared_loop = shared.clone();
        let handle = std::thread::spawn(move || {
            let shared = shared_loop;
            let mut engine = factory();
            let mut stats = RouterStats::default();
            loop {
                // Block for the first message…
                let msg = match rx.recv() {
                    Ok(m) => m,
                    Err(_) => {
                        settle_gauges(&engine, &shared, &mut stats);
                        return stats;
                    }
                };
                if !matches!(msg, Msg::Shutdown) {
                    shared.pending.fetch_sub(1, Ordering::SeqCst);
                }
                let first = match msg {
                    Msg::Query(q, opts, reply) => (q, opts, reply),
                    Msg::Stream(samples, opts, reply) => {
                        // Stream requests are self-contained passes over
                        // their own samples — nothing to batch.
                        serve_stream(&mut engine, &shared, &mut stats, samples, opts, reply);
                        continue;
                    }
                    m @ (Msg::Save(..)
                    | Msg::Load(..)
                    | Msg::Insert(..)
                    | Msg::Delete(..)
                    | Msg::Compact(..)
                    | Msg::Gens(..)
                    | Msg::Stats(..)) => {
                        serve_control(&mut engine, &shared, &mut stats, m);
                        auto_compact(&mut engine, &mut stats);
                        continue;
                    }
                    Msg::Shutdown => {
                        settle_gauges(&engine, &shared, &mut stats);
                        return stats;
                    }
                };
                // …then opportunistically drain whatever else is queued
                // (dynamic batching: no artificial delay, batch = backlog).
                let mut batch = vec![first];
                let mut streams = Vec::new();
                let mut controls = Vec::new();
                let mut shutdown = false;
                while batch.len() < max_batch {
                    match rx.try_recv() {
                        Ok(Msg::Shutdown) => {
                            shutdown = true;
                            break;
                        }
                        Ok(m) => {
                            shared.pending.fetch_sub(1, Ordering::SeqCst);
                            match m {
                                Msg::Query(q, opts, reply) => batch.push((q, opts, reply)),
                                Msg::Stream(samples, opts, reply) => {
                                    streams.push((samples, opts, reply));
                                }
                                // Control traffic drained mid-batch runs
                                // after the batch, like streams: queries
                                // already queued are answered by the index
                                // (and live overlay) they were sent to.
                                other => controls.push(other),
                            }
                        }
                        Err(_) => break,
                    }
                }
                stats.batches += 1;
                stats.max_batch = stats.max_batch.max(batch.len());
                stats.served += batch.len();

                // Move the queries out of the messages — no copies on
                // the dispatch hot path.
                let mut items = Vec::with_capacity(batch.len());
                let mut replies = Vec::with_capacity(batch.len());
                for (q, opts, reply) in batch {
                    items.push((q, opts));
                    replies.push(reply);
                }
                // The batch runs under catch_unwind: a panicking query
                // kills its batch's replies (every waiting client sees a
                // disconnect → `err=internal`), not the process. The
                // engine's query path only mutates per-call scratch that
                // the next call resizes/rewrites from scratch, so
                // serving on is sound (AssertUnwindSafe).
                let poisoned = shared.poison.swap(false, Ordering::SeqCst);
                let responses = catch_unwind(AssertUnwindSafe(|| {
                    if poisoned {
                        panic!("poisoned batch (test hook)");
                    }
                    engine.query_batch_with(&items)
                }));
                match responses {
                    Ok(responses) => {
                        for (reply, resp) in replies.into_iter().zip(responses) {
                            if resp.batched {
                                stats.batched += 1;
                            } else {
                                stats.scalar += 1;
                            }
                            stats.clusters_pruned += resp.stats.clusters_pruned;
                            stats.cluster_members_pruned += resp.stats.cluster_members_pruned;
                            let _ = reply.send(resp);
                        }
                    }
                    Err(_) => {
                        shared.panics.fetch_add(1, Ordering::SeqCst);
                        drop(replies);
                    }
                }
                // Stream requests drained mid-batch run after the batch
                // (they never delay the latency-sensitive query path).
                for (samples, opts, reply) in streams {
                    serve_stream(&mut engine, &shared, &mut stats, samples, opts, reply);
                }
                let had_controls = !controls.is_empty();
                for msg in controls {
                    serve_control(&mut engine, &shared, &mut stats, msg);
                }
                if had_controls {
                    auto_compact(&mut engine, &mut stats);
                }
                settle_gauges(&engine, &shared, &mut stats);
                if shutdown {
                    return stats;
                }
            }
        });
        Router { tx, handle: Some(handle), shared }
    }

    /// Spawn a router over a shared [`DtwIndex`]: the dispatch thread
    /// builds its per-thread searcher (and the index's configured
    /// backend) inside itself. `max_batch` comes from the index.
    pub fn spawn_index(index: DtwIndex) -> Router {
        let max_batch = index.max_batch();
        Router::spawn(move || NnEngine::from_index(index), max_batch)
    }

    /// Enqueue unconditionally (the blocking callers' path — they
    /// prefer waiting over shedding).
    fn submit(&self, msg: Msg) {
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.send(msg).expect("router alive");
    }

    /// Enqueue iff the queue is under capacity; otherwise count a shed
    /// and refuse with [`Busy`].
    fn try_submit(&self, msg: Msg) -> Result<(), Busy> {
        let cap = self.shared.cap.load(Ordering::SeqCst);
        if self.shared.pending.load(Ordering::SeqCst) >= cap {
            self.shared.shed.fetch_add(1, Ordering::SeqCst);
            return Err(Busy);
        }
        self.submit(msg);
        Ok(())
    }

    /// Set the queue capacity the `try_*` submit paths admit against
    /// (`--queue-cap`; 0 sheds everything — a deterministic test hook).
    pub fn set_queue_cap(&self, cap: usize) {
        self.shared.cap.store(cap, Ordering::SeqCst);
    }

    /// The current queue capacity.
    pub fn queue_cap(&self) -> usize {
        self.shared.cap.load(Ordering::SeqCst)
    }

    /// Make the next dispatched query batch panic (exercises the
    /// panic-isolation path deterministically). Test hook.
    #[doc(hidden)]
    pub fn poison_next_query(&self) {
        self.shared.poison.store(true, Ordering::SeqCst);
    }

    /// Submit a query and block for the exact 1-NN answer.
    pub fn query(&self, values: Vec<f64>) -> QueryResponse {
        QueryResponse::from_outcome(self.query_with(values, QueryOptions::default()))
    }

    /// Submit a query with options (k-NN, abandon threshold, z-norm) and
    /// block for the outcome.
    pub fn query_with(&self, values: Vec<f64>, opts: QueryOptions) -> QueryOutcome {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Query(values, opts, reply_tx));
        reply_rx.recv().expect("router answers")
    }

    /// Submit without blocking; the response arrives on the returned
    /// receiver. Lets tests/clients build up a real batch.
    pub fn query_async(&self, values: Vec<f64>) -> Receiver<QueryOutcome> {
        self.query_async_with(values, QueryOptions::default())
    }

    /// [`Router::query_async`] with options.
    pub fn query_async_with(
        &self,
        values: Vec<f64>,
        opts: QueryOptions,
    ) -> Receiver<QueryOutcome> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Query(values, opts, reply_tx));
        reply_rx
    }

    // ---- fallible (shedding) submit variants — the server's paths ----
    //
    // Each returns the reply receiver instead of blocking: the server
    // maps `Busy` to `err=busy` and a dropped reply (a panic killed the
    // request) to `err=internal`.

    /// [`Router::query_with`], shedding under overload.
    pub fn try_query_with(
        &self,
        values: Vec<f64>,
        opts: QueryOptions,
    ) -> Result<Receiver<QueryOutcome>, Busy> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.try_submit(Msg::Query(values, opts, reply_tx))?;
        Ok(reply_rx)
    }

    /// [`Router::stream`], shedding under overload.
    pub fn try_stream(
        &self,
        samples: Vec<f64>,
        opts: SubsequenceOptions,
    ) -> Result<Receiver<anyhow::Result<StreamReport>>, Busy> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.try_submit(Msg::Stream(samples, opts, reply_tx))?;
        Ok(reply_rx)
    }

    /// [`Router::insert`], shedding under overload.
    pub fn try_insert(
        &self,
        label: u32,
        values: Vec<f64>,
    ) -> Result<Receiver<anyhow::Result<InsertReceipt>>, Busy> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.try_submit(Msg::Insert(label, values, reply_tx))?;
        Ok(reply_rx)
    }

    /// [`Router::delete`], shedding under overload.
    pub fn try_delete(&self, id: usize) -> Result<Receiver<anyhow::Result<DeleteReceipt>>, Busy> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.try_submit(Msg::Delete(id, reply_tx))?;
        Ok(reply_rx)
    }

    /// [`Router::compact`], shedding under overload.
    pub fn try_compact(&self) -> Result<Receiver<anyhow::Result<CompactReceipt>>, Busy> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.try_submit(Msg::Compact(reply_tx))?;
        Ok(reply_rx)
    }

    /// [`Router::save_snapshot`], shedding under overload.
    pub fn try_save(
        &self,
        path: impl Into<PathBuf>,
    ) -> Result<Receiver<Result<SnapshotSaved, SnapshotError>>, Busy> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.try_submit(Msg::Save(path.into(), reply_tx))?;
        Ok(reply_rx)
    }

    /// [`Router::load_snapshot`], shedding under overload.
    pub fn try_load(
        &self,
        path: impl Into<PathBuf>,
    ) -> Result<Receiver<Result<SnapshotLoaded, SnapshotError>>, Busy> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.try_submit(Msg::Load(path.into(), reply_tx))?;
        Ok(reply_rx)
    }

    /// A point-in-time copy of the dispatch loop's statistics (the
    /// `stats=` protocol verb). Blocking and never shed — observability
    /// must work *especially* under overload.
    pub fn stats(&self) -> RouterStats {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Stats(reply_tx));
        reply_rx.recv().expect("router answers")
    }

    /// Submit a finite sample stream for subsequence search (threshold
    /// and/or top-k per `opts`) and block for the report — the serving
    /// face of [`crate::index::DtwIndex::subsequence`].
    pub fn stream(
        &self,
        samples: Vec<f64>,
        opts: SubsequenceOptions,
    ) -> anyhow::Result<StreamReport> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Stream(samples, opts, reply_tx));
        reply_rx.recv().expect("router answers")
    }

    /// Snapshot the currently served index to `path` (the `save=`
    /// protocol verb): the dispatch thread serializes its engine's index
    /// after any in-flight batch, so the snapshot is a consistent
    /// point-in-time image. Blocks for the receipt.
    pub fn save_snapshot(
        &self,
        path: impl Into<PathBuf>,
    ) -> Result<SnapshotSaved, SnapshotError> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Save(path.into(), reply_tx));
        reply_rx.recv().expect("router answers")
    }

    /// Hot-swap the served index from the snapshot at `path` (the
    /// `load=` protocol verb). Queries queued before the swap are
    /// answered by the old index; a failed load leaves it serving
    /// untouched. Blocks for the receipt.
    pub fn load_snapshot(
        &self,
        path: impl Into<PathBuf>,
    ) -> Result<SnapshotLoaded, SnapshotError> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Load(path.into(), reply_tx));
        reply_rx.recv().expect("router answers")
    }

    /// Append a labelled series to the live delta shard (the `insert=`
    /// protocol verb). The series becomes visible to every search path
    /// — k-NN, batched, stream — from the next dispatched batch on,
    /// with answers bit-identical to a cold rebuild over the enlarged
    /// set. Blocks for the receipt carrying the assigned logical id.
    pub fn insert(&self, label: u32, values: Vec<f64>) -> anyhow::Result<InsertReceipt> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Insert(label, values, reply_tx));
        reply_rx.recv().expect("router answers")
    }

    /// Remove the series at logical `id` (the `delete=` protocol verb):
    /// base series are tombstoned, delta series are dropped outright.
    /// Blocks for the receipt.
    pub fn delete(&self, id: usize) -> anyhow::Result<DeleteReceipt> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Delete(id, reply_tx));
        reply_rx.recv().expect("router answers")
    }

    /// Merge the delta shard and tombstones into a fresh base index of
    /// the next generation (the `compact=` protocol verb). The new base
    /// is built aside and atomically swapped between batches; it is
    /// bit-identical to a cold build over the same logical series.
    /// Blocks for the receipt.
    pub fn compact(&self) -> anyhow::Result<CompactReceipt> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Compact(reply_tx));
        reply_rx.recv().expect("router answers")
    }

    /// Report the generation lineage of the served index (the `gens=`
    /// protocol verb): current generation, parent, pending delta /
    /// tombstone counts, and the generation snapshots saved so far.
    pub fn generations(&self) -> GenerationInfo {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.submit(Msg::Gens(reply_tx));
        reply_rx.recv().expect("router answers")
    }

    /// Stop the dispatch loop and collect its statistics.
    pub fn shutdown(mut self) -> RouterStats {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle.take().map(|h| h.join().expect("dispatch thread")).unwrap_or_default()
    }

    /// Wait until the queue is likely drained (test helper).
    pub fn settle(&self) {
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Serve one stream request under panic isolation: the closure owns the
/// reply sender, so a panic drops it and the waiting client sees a
/// disconnect (`err=internal`) instead of a hung connection.
fn serve_stream(
    engine: &mut NnEngine,
    shared: &Shared,
    stats: &mut RouterStats,
    samples: Vec<f64>,
    opts: SubsequenceOptions,
    reply: Sender<anyhow::Result<StreamReport>>,
) {
    stats.streams += 1;
    let caught = catch_unwind(AssertUnwindSafe(|| {
        let _ = reply.send(engine.query_stream(&samples, opts));
    }));
    if caught.is_err() {
        shared.panics.fetch_add(1, Ordering::SeqCst);
    }
}

/// Serve one control message (snapshot or live mutation) on the
/// dispatch thread, under panic isolation (a panic drops the message's
/// reply sender — `err=internal` at the client — and the loop serves
/// on). A failed `load=` leaves the current index serving.
fn serve_control(engine: &mut NnEngine, shared: &Shared, stats: &mut RouterStats, msg: Msg) {
    let caught =
        catch_unwind(AssertUnwindSafe(|| serve_control_inner(engine, shared, stats, msg)));
    if caught.is_err() {
        shared.panics.fetch_add(1, Ordering::SeqCst);
    }
}

fn serve_control_inner(
    engine: &mut NnEngine,
    shared: &Shared,
    stats: &mut RouterStats,
    msg: Msg,
) {
    match msg {
        Msg::Save(path, reply) => {
            stats.saves += 1;
            let r = engine
                .save_generation(&path)
                .map(|(path, bytes)| SnapshotSaved { path, bytes });
            let _ = reply.send(r);
        }
        Msg::Load(path, reply) => {
            let r = DtwIndex::load(&path).and_then(|idx| {
                let info = SnapshotLoaded {
                    series: idx.len(),
                    shards: idx.shard_count(),
                    window: idx.window(),
                };
                // With a WAL attached the swap also moves the durable
                // anchor; a rotation failure surfaces as an I/O error
                // and the old index keeps serving.
                engine.install_index(idx).map_err(|e| {
                    SnapshotError::Io(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        e.to_string(),
                    ))
                })?;
                stats.loads += 1;
                Ok(info)
            });
            let _ = reply.send(r);
        }
        Msg::Insert(label, values, reply) => {
            let r = engine.insert(label, values).map(|id| {
                stats.inserts += 1;
                InsertReceipt {
                    id,
                    delta_len: engine.delta_len(),
                    generation: engine.generation(),
                }
            });
            let _ = reply.send(r);
        }
        Msg::Delete(id, reply) => {
            let r = engine.delete(id).map(|()| {
                stats.deletes += 1;
                DeleteReceipt {
                    remaining: engine.logical_len(),
                    tombstones: engine.generations().tombstones,
                }
            });
            let _ = reply.send(r);
        }
        Msg::Compact(reply) => {
            let r = engine.compact().map(|generation| {
                stats.compactions += 1;
                CompactReceipt { generation, series: engine.index().len() }
            });
            let _ = reply.send(r);
        }
        Msg::Gens(reply) => {
            let _ = reply.send(engine.generations());
        }
        Msg::Stats(reply) => {
            settle_gauges(engine, shared, stats);
            let _ = reply.send(*stats);
        }
        Msg::Query(..) | Msg::Stream(..) | Msg::Shutdown => {
            unreachable!("only control messages reach serve_control")
        }
    }
}

/// Run the auto-compaction check after control traffic mutated the
/// live state. A threshold crossing compacts in place; a failure (not
/// reachable for well-formed state) leaves the overlay serving.
fn auto_compact(engine: &mut NnEngine, stats: &mut RouterStats) {
    if let Ok(Some(_)) = engine.maybe_auto_compact() {
        stats.compactions += 1;
    }
}

/// Refresh the gauge fields from the engine's live state and the shared
/// hardening counters.
fn settle_gauges(engine: &NnEngine, shared: &Shared, stats: &mut RouterStats) {
    stats.delta_len = engine.delta_len();
    stats.generation = engine.generation();
    stats.wal_records = engine.wal_records();
    stats.panics = shared.panics.load(Ordering::SeqCst);
    stats.shed = shared.shed.load(Ordering::SeqCst);
    stats.pending = shared.pending.load(Ordering::SeqCst);
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::BoundKind;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;
    use crate::runtime::BackendKind;
    use crate::search::knn::{knn_brute_force, KnnParams};
    use crate::search::PreparedTrainSet;

    fn brute_distance(q: &[f64], train: &PreparedTrainSet) -> f64 {
        knn_brute_force::<Squared>(q, train, &KnnParams::default()).0[0].distance
    }

    #[test]
    fn router_serves_exact_answers() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 71))[0];
        let w = ds.window.max(1);
        let ds2 = ds.clone();
        let router = Router::spawn(move || NnEngine::new(&ds2, w, BoundKind::Webb), 8);
        let train = PreparedTrainSet::from_dataset(ds, w);

        // Async-submit everything first so batches can form.
        let rxs: Vec<_> =
            ds.test.iter().map(|q| router.query_async(q.values.clone())).collect();
        for (rx, q) in rxs.into_iter().zip(ds.test.iter()) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.best().unwrap().distance, brute_distance(&q.values, &train));
        }
        let stats = router.shutdown();
        assert_eq!(stats.served, ds.test.len());
        assert!(stats.batches >= 1);
        assert!(stats.max_batch >= 1);
        // No backend attached: everything rides the scalar path.
        assert_eq!(stats.scalar, stats.served);
        assert_eq!(stats.batched, 0);
    }

    #[test]
    fn router_over_shared_index_serves_knn() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 73))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds)
            .bound(BoundKind::Keogh)
            .backend(BackendKind::Native)
            .max_batch(8)
            .build()
            .unwrap();
        let router = Router::spawn_index(index.clone());
        let rxs: Vec<_> = ds
            .test
            .iter()
            .map(|q| router.query_async_with(q.values.clone(), QueryOptions::k(3)))
            .collect();
        for (rx, q) in rxs.into_iter().zip(ds.test.iter()) {
            let resp = rx.recv().unwrap();
            let (truth, _) =
                knn_brute_force::<Squared>(&q.values, index.train(), &KnnParams::k(3));
            let want: Vec<f64> = truth.iter().map(|r| r.distance).collect();
            assert_eq!(resp.distances(), want);
        }
        let stats = router.shutdown();
        assert_eq!(stats.served, ds.test.len());
        // Every query is attributed to exactly one path.
        assert_eq!(stats.scalar + stats.batched, stats.served);
    }

    #[test]
    fn router_serves_stream_requests() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 74))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Router::spawn_index(index.clone());
        // Far-away filler around an exact copy of train[0]: exactly one
        // window matches, at distance zero.
        let mut samples = vec![1e3; 5];
        samples.extend_from_slice(&index.train().series[0].values);
        samples.extend(vec![1e3; 5]);
        let report = router
            .stream(samples, crate::stream::SubsequenceOptions::threshold(1e-9))
            .unwrap();
        assert_eq!(report.matches.len(), 1);
        assert_eq!(report.matches[0].start, 5);
        assert_eq!(report.matches[0].neighbor, 0);
        assert_eq!(report.matches[0].distance, 0.0);
        assert_eq!(report.stats.windows, 11);
        // Inconsistent options surface as an error, not a panic.
        assert!(router
            .stream(vec![0.0; 4], crate::stream::SubsequenceOptions::default())
            .is_err());
        let stats = router.shutdown();
        assert_eq!(stats.streams, 2);
        assert_eq!(stats.served, 0, "stream requests are not query traffic");
    }

    #[test]
    fn save_and_load_round_trip_through_the_router() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 75))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds)
            .shards(2)
            .build()
            .unwrap();
        let router = Router::spawn_index(index.clone());
        let q = ds.test[0].values.clone();
        let before = router.query_with(q.clone(), QueryOptions::k(3));

        let path = std::env::temp_dir()
            .join(format!("dtwb_router_snap_{}.snap", std::process::id()));
        let saved = router.save_snapshot(&path).unwrap();
        assert!(saved.bytes > 0);
        // Saves are generation-versioned: generation 0 lands at `.g0`.
        assert_eq!(saved.path, crate::index::snapshot::generation_path(&path, 0));

        // Swap onto the snapshot we just wrote: answers are bit-equal.
        let loaded = router.load_snapshot(&saved.path).unwrap();
        assert_eq!(loaded.series, index.len());
        assert_eq!(loaded.shards, 2);
        assert_eq!(loaded.window, index.window());
        let after = router.query_with(q, QueryOptions::k(3));
        assert_eq!(before.distances(), after.distances());

        // A failed load is a typed error and leaves the index serving.
        let missing = std::env::temp_dir().join("dtwb_router_missing.snap");
        assert!(router.load_snapshot(&missing).is_err());
        let still = router.query_with(ds.test[1].values.clone(), QueryOptions::k(1));
        assert!(!still.neighbors.is_empty());

        let stats = router.shutdown();
        assert_eq!(stats.saves, 1);
        assert_eq!(stats.loads, 1, "the failed load must not count");
        std::fs::remove_file(&saved.path).ok();
    }

    #[test]
    fn live_mutations_flow_through_the_router() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 76))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Router::spawn_index(index.clone());

        // Insert a probe the base does not contain: it must win its own
        // 1-NN query at distance zero.
        let probe = ds.test[0].values.clone();
        let receipt = router.insert(99, probe.clone()).unwrap();
        assert_eq!(receipt.id, index.len());
        assert_eq!(receipt.delta_len, 1);
        assert_eq!(receipt.generation, 0);
        let hit = router.query_with(probe.clone(), QueryOptions::k(1));
        assert_eq!(hit.neighbors[0].index, receipt.id);
        assert_eq!(hit.neighbors[0].label, 99);
        assert_eq!(hit.neighbors[0].distance, 0.0);

        // Delete a base series: logical count shrinks, tombstone pends.
        let del = router.delete(0).unwrap();
        assert_eq!(del.remaining, index.len());
        assert_eq!(del.tombstones, 1);

        // Compact: next generation, delta folded in, answers preserved.
        let compacted = router.compact().unwrap();
        assert_eq!(compacted.generation, 1);
        assert_eq!(compacted.series, index.len());
        let info = router.generations();
        assert_eq!(info.generation, 1);
        assert_eq!(info.parent, 0);
        assert_eq!(info.delta_len, 0);
        assert_eq!(info.tombstones, 0);
        let again = router.query_with(probe, QueryOptions::k(1));
        assert_eq!(again.neighbors[0].label, 99);
        assert_eq!(again.neighbors[0].distance, 0.0);

        let stats = router.shutdown();
        assert_eq!(stats.inserts, 1);
        assert_eq!(stats.deletes, 1);
        assert_eq!(stats.compactions, 1);
        assert_eq!(stats.delta_len, 0);
        assert_eq!(stats.generation, 1);
    }

    #[test]
    fn auto_compaction_counts_in_router_stats() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 77))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Router::spawn(
            move || {
                let mut e = NnEngine::from_index(index);
                e.set_auto_compact(Some(2));
                e
            },
            8,
        );
        let s0 = ds.train[0].values.clone();
        let s1 = ds.train[1].values.clone();
        assert_eq!(router.insert(7, s0).unwrap().generation, 0);
        // Second insert crosses the threshold: the overlay compacts
        // before the next control settles.
        router.insert(8, s1).unwrap();
        let info = router.generations();
        assert_eq!(info.generation, 1);
        assert_eq!(info.delta_len, 0);
        let stats = router.shutdown();
        assert_eq!(stats.inserts, 2);
        assert_eq!(stats.compactions, 1);
        assert_eq!(stats.generation, 1);
    }

    #[test]
    fn zero_cap_sheds_with_busy_and_counts() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 78))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Router::spawn_index(index);
        router.set_queue_cap(0);
        assert_eq!(router.queue_cap(), 0);
        let q = ds.test[0].values.clone();
        assert_eq!(router.try_query_with(q.clone(), QueryOptions::k(1)).err(), Some(Busy));
        assert_eq!(router.try_insert(5, q.clone()).err(), Some(Busy));
        assert_eq!(router.try_compact().err(), Some(Busy));
        // Blocking paths never shed — and `stats` itself must keep
        // working under overload.
        let resp = router.query(q.clone());
        assert!(resp.result.distance.is_finite());
        let stats = router.stats();
        assert_eq!(stats.shed, 3);
        assert_eq!(stats.served, 1);
        // Raising the cap readmits.
        router.set_queue_cap(1024);
        let rx = router.try_query_with(q, QueryOptions::k(1)).unwrap();
        assert!(rx.recv().unwrap().best().unwrap().distance.is_finite());
    }

    #[test]
    fn panicking_query_fails_only_its_request() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 79))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Router::spawn_index(index);
        let q = ds.test[0].values.clone();
        router.poison_next_query();
        let rx = router.query_async(q.clone());
        assert!(rx.recv().is_err(), "the poisoned batch drops its replies");
        // The loop survived: the next query is served normally.
        let resp = router.query(q);
        assert!(resp.result.distance.is_finite());
        let stats = router.shutdown();
        assert_eq!(stats.panics, 1);
        assert_eq!(stats.pending, 0);
    }

    #[test]
    fn stats_verb_reports_the_live_gauges() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 80))[0];
        let index = crate::index::DtwIndex::builder_from_dataset(ds).build().unwrap();
        let router = Router::spawn_index(index);
        router.insert(3, ds.test[0].values.clone()).unwrap();
        let stats = router.stats();
        assert_eq!(stats.inserts, 1);
        assert_eq!(stats.delta_len, 1);
        assert_eq!(stats.generation, 0);
        assert_eq!(stats.wal_records, 0, "no wal attached");
        assert_eq!(stats.panics, 0);
        assert_eq!(stats.shed, 0);
    }

    #[test]
    fn blocking_query_works() {
        let ds = generate_archive(&ArchiveSpec::new(Scale::Tiny, 72))[1].clone();
        let w = ds.window.max(1);
        let q0 = ds.test[0].values.clone();
        let router = Router::spawn(move || NnEngine::new(&ds, w, BoundKind::Keogh), 4);
        let resp = router.query(q0);
        assert!(resp.result.distance.is_finite());
    }
}
