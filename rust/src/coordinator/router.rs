//! Request router and dynamic batcher.
//!
//! Clients call [`Router::query`] from any thread; a single dispatch
//! thread owns the [`NnEngine`] (backend handles — PJRT in particular —
//! are not `Sync`) and drains the queue into batches: when several
//! queries are waiting they ride the engine's batched
//! [`crate::runtime::LbBackend`] prefilter together; a lone query takes
//! the scalar path immediately. This is the standard router/batcher shape
//! of serving systems (vLLM-style), scaled to this paper's workload.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use super::engine::{EnginePath, NnEngine, QueryResponse};

enum Msg {
    Query(Vec<f64>, Sender<QueryResponse>),
    Shutdown,
}

/// Handle to the dispatch thread. Cloneable senders, blocking `query`.
pub struct Router {
    tx: Sender<Msg>,
    handle: Option<JoinHandle<RouterStats>>,
}

/// Dispatch-loop statistics, returned by [`Router::shutdown`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Total queries served.
    pub served: usize,
    /// Number of dispatch batches formed.
    pub batches: usize,
    /// Largest batch formed.
    pub max_batch: usize,
    /// Queries answered through the batched backend prefilter.
    pub batched: usize,
    /// Queries answered on the scalar path.
    pub scalar: usize,
}

impl Router {
    /// Spawn the dispatch loop. The engine is **constructed inside** the
    /// dispatch thread by `factory` — backend handles (PJRT in
    /// particular) are not `Send`, so the engine must never cross
    /// threads. `max_batch` caps how many queued queries ride one
    /// prefilter execution.
    pub fn spawn<F>(factory: F, max_batch: usize) -> Router
    where
        F: FnOnce() -> NnEngine + Send + 'static,
    {
        let (tx, rx): (Sender<Msg>, Receiver<Msg>) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            let mut engine = factory();
            let mut stats = RouterStats::default();
            loop {
                // Block for the first message…
                let first = match rx.recv() {
                    Ok(Msg::Query(q, reply)) => (q, reply),
                    Ok(Msg::Shutdown) | Err(_) => return stats,
                };
                // …then opportunistically drain whatever else is queued
                // (dynamic batching: no artificial delay, batch = backlog).
                let mut batch = vec![first];
                let mut shutdown = false;
                while batch.len() < max_batch {
                    match rx.try_recv() {
                        Ok(Msg::Query(q, reply)) => batch.push((q, reply)),
                        Ok(Msg::Shutdown) => {
                            shutdown = true;
                            break;
                        }
                        Err(_) => break,
                    }
                }
                stats.batches += 1;
                stats.max_batch = stats.max_batch.max(batch.len());
                stats.served += batch.len();

                let queries: Vec<Vec<f64>> = batch.iter().map(|(q, _)| q.clone()).collect();
                let responses = engine.query_batch(&queries);
                for ((_, reply), resp) in batch.into_iter().zip(responses) {
                    match resp.path {
                        EnginePath::Batched => stats.batched += 1,
                        EnginePath::Scalar => stats.scalar += 1,
                    }
                    let _ = reply.send(resp);
                }
                if shutdown {
                    return stats;
                }
            }
        });
        Router { tx, handle: Some(handle) }
    }

    /// Submit a query and block for the exact 1-NN answer.
    pub fn query(&self, values: Vec<f64>) -> QueryResponse {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx.send(Msg::Query(values, reply_tx)).expect("router alive");
        reply_rx.recv().expect("router answers")
    }

    /// Submit without blocking; the response arrives on the returned
    /// receiver. Lets tests/clients build up a real batch.
    pub fn query_async(&self, values: Vec<f64>) -> Receiver<QueryResponse> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx.send(Msg::Query(values, reply_tx)).expect("router alive");
        reply_rx
    }

    /// Stop the dispatch loop and collect its statistics.
    pub fn shutdown(mut self) -> RouterStats {
        let _ = self.tx.send(Msg::Shutdown);
        self.handle.take().map(|h| h.join().expect("dispatch thread")).unwrap_or_default()
    }

    /// Wait until the queue is likely drained (test helper).
    pub fn settle(&self) {
        std::thread::sleep(Duration::from_millis(10));
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::BoundKind;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;
    use crate::search::nn::nn_brute_force;
    use crate::search::PreparedTrainSet;

    #[test]
    fn router_serves_exact_answers() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 71))[0];
        let w = ds.window.max(1);
        let ds2 = ds.clone();
        let router = Router::spawn(move || NnEngine::new(&ds2, w, BoundKind::Webb), 8);
        let train = PreparedTrainSet::from_dataset(ds, w);

        // Async-submit everything first so batches can form.
        let rxs: Vec<_> =
            ds.test.iter().map(|q| router.query_async(q.values.clone())).collect();
        for (rx, q) in rxs.into_iter().zip(ds.test.iter()) {
            let resp = rx.recv().unwrap();
            let (truth, _) = nn_brute_force::<Squared>(&q.values, &train);
            assert_eq!(resp.result.distance, truth.distance);
        }
        let stats = router.shutdown();
        assert_eq!(stats.served, ds.test.len());
        assert!(stats.batches >= 1);
        assert!(stats.max_batch >= 1);
        // No backend attached: everything rides the scalar path.
        assert_eq!(stats.scalar, stats.served);
        assert_eq!(stats.batched, 0);
    }

    #[test]
    fn router_with_native_backend_serves_exact_answers() {
        let ds = &generate_archive(&ArchiveSpec::new(Scale::Tiny, 73))[0];
        let w = ds.window.max(1);
        let ds2 = ds.clone();
        let router = Router::spawn(
            move || {
                let mut engine = NnEngine::new(&ds2, w, BoundKind::Keogh);
                engine.attach_native();
                engine
            },
            8,
        );
        let train = PreparedTrainSet::from_dataset(ds, w);
        let rxs: Vec<_> =
            ds.test.iter().map(|q| router.query_async(q.values.clone())).collect();
        for (rx, q) in rxs.into_iter().zip(ds.test.iter()) {
            let resp = rx.recv().unwrap();
            let (truth, _) = nn_brute_force::<Squared>(&q.values, &train);
            assert_eq!(resp.result.distance, truth.distance);
        }
        let stats = router.shutdown();
        assert_eq!(stats.served, ds.test.len());
        // Every query is attributed to exactly one path.
        assert_eq!(stats.scalar + stats.batched, stats.served);
    }

    #[test]
    fn blocking_query_works() {
        let ds = generate_archive(&ArchiveSpec::new(Scale::Tiny, 72))[1].clone();
        let w = ds.window.max(1);
        let q0 = ds.test[0].values.clone();
        let router = Router::spawn(move || NnEngine::new(&ds, w, BoundKind::Keogh), 4);
        let resp = router.query(q0);
        assert!(resp.result.distance.is_finite());
    }
}
