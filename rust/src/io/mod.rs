//! Durable file I/O behind a narrow, injectable trait.
//!
//! Every byte the serving stack persists — index snapshots
//! ([`crate::index::snapshot`]) and the live write-ahead log
//! ([`crate::live::wal`]) — flows through [`FileOps`], a five-verb
//! file-system abstraction (create/append/read/rename/remove) with
//! explicit durability ([`WriteFile::sync`]). Production uses
//! [`RealFs`], a zero-cost shim over `std::fs`. Tests swap in
//! [`fault::FaultFs`], a deterministic in-memory file system that can
//! crash at any enumerated operation and then present the file images a
//! real machine could observe after the crash — the proof mechanism
//! behind the recovery property suite (`rust/tests/recovery.rs`).
//!
//! ## Why a trait and not `std::fs`
//!
//! Crash-safety claims ("an acked insert survives restart", "a torn
//! snapshot write never destroys the previous good snapshot") are
//! *universally quantified over crash points* — you cannot demonstrate
//! them by killing a process a few times and hoping the scheduler
//! cooperates. Routing all writes through one seam makes the set of
//! crash points finite and enumerable: each `create`/`write`/`sync`/
//! `rename`/`remove` is one point, and [`fault::FaultFs`] can fail
//! exactly the nth one (optionally leaving a short write behind) and
//! then replay both the *all-buffered-bytes-survived* and the
//! *only-synced-bytes-survived* restart images.

pub mod fault;

pub use fault::{CrashStyle, FaultFs, FaultPlan, OpKind, OpRecord};

use std::io::{Read, Write};
use std::path::Path;

/// An open file handle for writing. `write` has `write_all` semantics
/// (the full buffer or an error); `sync` is the durability barrier —
/// bytes written before a successful `sync` survive any crash model
/// this crate reasons about, bytes after it may not.
pub trait WriteFile: Send {
    /// Append `bytes` at the current position (whole-buffer semantics).
    fn write(&mut self, bytes: &[u8]) -> std::io::Result<()>;
    /// Flush written bytes to durable storage (`fsync`).
    fn sync(&mut self) -> std::io::Result<()>;
}

/// The five file-system verbs the persistence layer needs. Implementors
/// must be `Send + Sync` — the engine shares one instance across the
/// dispatch thread and tests.
pub trait FileOps: Send + Sync {
    /// Create (or truncate) the file at `path` for writing.
    fn create(&self, path: &Path) -> std::io::Result<Box<dyn WriteFile>>;
    /// Open the file at `path` for appending, creating it if absent.
    fn open_append(&self, path: &Path) -> std::io::Result<Box<dyn WriteFile>>;
    /// Read the whole file at `path`.
    fn read(&self, path: &Path) -> std::io::Result<Vec<u8>>;
    /// Atomically rename `from` over `to` (same directory in practice).
    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()>;
    /// Remove the file at `path`.
    fn remove(&self, path: &Path) -> std::io::Result<()>;
    /// True when a file exists at `path`.
    fn exists(&self, path: &Path) -> bool;
}

/// The production [`FileOps`]: a stateless shim over `std::fs`.
#[derive(Debug, Clone, Copy, Default)]
pub struct RealFs;

struct RealFile {
    file: std::fs::File,
}

impl WriteFile for RealFile {
    fn write(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.file.write_all(bytes)
    }

    fn sync(&mut self) -> std::io::Result<()> {
        self.file.sync_all()
    }
}

impl FileOps for RealFs {
    fn create(&self, path: &Path) -> std::io::Result<Box<dyn WriteFile>> {
        Ok(Box::new(RealFile { file: std::fs::File::create(path)? }))
    }

    fn open_append(&self, path: &Path) -> std::io::Result<Box<dyn WriteFile>> {
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(Box::new(RealFile { file }))
    }

    fn read(&self, path: &Path) -> std::io::Result<Vec<u8>> {
        let mut file = std::fs::File::open(path)?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes)?;
        Ok(bytes)
    }

    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()> {
        std::fs::rename(from, to)
    }

    fn remove(&self, path: &Path) -> std::io::Result<()> {
        std::fs::remove_file(path)
    }

    fn exists(&self, path: &Path) -> bool {
        path.exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_fs_round_trips_and_appends() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("dtwb_io_real_{}.bin", std::process::id()));
        let fs = RealFs;
        {
            let mut f = fs.create(&path).unwrap();
            f.write(b"hello").unwrap();
            f.sync().unwrap();
        }
        assert_eq!(fs.read(&path).unwrap(), b"hello");
        {
            let mut f = fs.open_append(&path).unwrap();
            f.write(b" world").unwrap();
            f.sync().unwrap();
        }
        assert_eq!(fs.read(&path).unwrap(), b"hello world");
        assert!(fs.exists(&path));

        let moved = dir.join(format!("dtwb_io_real_{}_moved.bin", std::process::id()));
        fs.rename(&path, &moved).unwrap();
        assert!(!fs.exists(&path));
        assert_eq!(fs.read(&moved).unwrap(), b"hello world");
        fs.remove(&moved).unwrap();
        assert!(!fs.exists(&moved));
        assert!(fs.read(&moved).is_err());
    }
}
