//! Deterministic fault injection for the persistence layer.
//!
//! [`FaultFs`] is an in-memory [`FileOps`](super::FileOps)
//! implementation that models exactly the failure surface the durable
//! paths (snapshot save, WAL append) must survive:
//!
//! * every mutating operation — `create`, `write`, `sync`, `rename`,
//!   `remove` — is numbered in a global sequence and recorded in a
//!   trace, so a clean run *enumerates* the crash points of a scenario;
//! * a [`FaultPlan`] fails the nth operation (optionally applying a
//!   **short write** of the first `j` bytes first), after which the
//!   "process" is considered dead: every further operation fails too,
//!   including error-path cleanup like `remove` — a crashed process
//!   cannot clean up after itself;
//! * [`FaultFs::restart`] then produces the file images a real machine
//!   could present after the crash, under two models
//!   ([`CrashStyle`]): **`KeepAll`** (every buffered byte reached the
//!   platter — the lucky case) and **`DropUnsynced`** (each file is
//!   truncated to its last successfully `sync`ed prefix — the
//!   power-loss case). File *metadata* operations (`create`, `rename`,
//!   `remove`) are modeled atomic and immediately durable, the standard
//!   journaled-file-system assumption the snapshot's
//!   write-tmp/fsync/rename discipline relies on.
//!
//! A property over crash points then reads: for every op index `i` in
//! the clean trace, for both crash styles, running the scenario with
//! `FaultPlan::fail_op(i)` and restarting must recover a state
//! bit-equal to the scenario's pre- or post-state — never a hybrid.
//! `rust/tests/recovery.rs` instantiates this for snapshot save, WAL
//! append and WAL rotation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use super::{FileOps, WriteFile};

/// What survives a crash, per file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrashStyle {
    /// Every written byte survives (the OS flushed everything anyway).
    KeepAll,
    /// Only bytes covered by a successful `sync` survive; each file is
    /// truncated to its synced prefix (power loss before writeback).
    DropUnsynced,
}

/// The kind of one traced operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// `create` (truncating open-for-write).
    Create,
    /// One `write` call on an open handle.
    Write,
    /// One `sync` call on an open handle.
    Sync,
    /// `rename(from, to)`.
    Rename,
    /// `remove(path)`.
    Remove,
}

/// One entry of the operation trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct OpRecord {
    /// What the operation was.
    pub kind: OpKind,
    /// The file it targeted (the `from` path for renames).
    pub path: PathBuf,
}

/// When (and how) to fail. Operations are numbered from 0 in execution
/// order across the whole [`FaultFs`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    /// Index of the operation that fails (everything after fails too).
    pub crash_at: usize,
    /// When the failing operation is a `write`: how many leading bytes
    /// land before the failure (a torn write). Ignored otherwise.
    pub short_write: usize,
}

impl FaultPlan {
    /// Fail the nth operation cleanly (no bytes of a failing write land).
    pub fn fail_op(crash_at: usize) -> FaultPlan {
        FaultPlan { crash_at, short_write: 0 }
    }

    /// Fail the nth operation; if it is a write, tear it after `bytes`.
    pub fn torn_write(crash_at: usize, bytes: usize) -> FaultPlan {
        FaultPlan { crash_at, short_write: bytes }
    }
}

#[derive(Debug, Clone, Default)]
struct FileImage {
    content: Vec<u8>,
    /// Length of the prefix guaranteed durable (last successful sync).
    synced: usize,
}

#[derive(Debug, Default)]
struct State {
    files: BTreeMap<PathBuf, FileImage>,
    trace: Vec<OpRecord>,
    plan: Option<FaultPlan>,
    crashed: bool,
}

impl State {
    /// Record one mutating op; decide whether it is the crash point.
    /// Returns `Err` when the fs already crashed or this op triggers
    /// the plan (the caller must NOT apply the op's effect, except the
    /// short-write prefix which the `write` path applies itself).
    fn admit(&mut self, kind: OpKind, path: &Path) -> std::io::Result<Option<FaultPlan>> {
        if self.crashed {
            return Err(injected("operation after crash"));
        }
        let index = self.trace.len();
        self.trace.push(OpRecord { kind, path: to_owned(path) });
        if let Some(plan) = self.plan {
            if index == plan.crash_at {
                self.crashed = true;
                return Ok(Some(plan));
            }
        }
        Ok(None)
    }
}

fn injected(what: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::Other, format!("injected fault: {what}"))
}

fn to_owned(path: &Path) -> PathBuf {
    path.to_path_buf()
}

/// The deterministic in-memory file system. Cloning shares the
/// underlying state (all clones see the same files, trace and plan), so
/// a test can hold one handle while the engine holds another behind
/// `Arc<dyn FileOps>`.
#[derive(Debug, Clone, Default)]
pub struct FaultFs {
    state: Arc<Mutex<State>>,
}

impl FaultFs {
    /// A fault-free in-memory fs (still records the op trace).
    pub fn new() -> FaultFs {
        FaultFs::default()
    }

    /// An fs that fails per `plan`.
    pub fn with_plan(plan: FaultPlan) -> FaultFs {
        let fs = FaultFs::new();
        fs.state.lock().expect("fault fs lock").plan = Some(plan);
        fs
    }

    /// Seed a file without touching the op trace (pre-existing state).
    pub fn put(&self, path: &Path, bytes: &[u8]) {
        let mut s = self.state.lock().expect("fault fs lock");
        s.files.insert(
            to_owned(path),
            FileImage { content: bytes.to_vec(), synced: bytes.len() },
        );
    }

    /// The current content of `path` (test-side view; works even after
    /// a crash — this is the examiner looking at the disk, not the dead
    /// process reading it).
    pub fn get(&self, path: &Path) -> Option<Vec<u8>> {
        let s = self.state.lock().expect("fault fs lock");
        s.files.get(path).map(|f| f.content.clone())
    }

    /// Mutating operations executed so far (the crash-point space).
    pub fn op_count(&self) -> usize {
        self.state.lock().expect("fault fs lock").trace.len()
    }

    /// The full op trace so far.
    pub fn trace(&self) -> Vec<OpRecord> {
        self.state.lock().expect("fault fs lock").trace.clone()
    }

    /// True once the plan's crash point has fired.
    pub fn crashed(&self) -> bool {
        self.state.lock().expect("fault fs lock").crashed
    }

    /// The paths currently present.
    pub fn files(&self) -> Vec<PathBuf> {
        let s = self.state.lock().expect("fault fs lock");
        s.files.keys().cloned().collect()
    }

    /// The disk as a fresh process would find it after the crash: a new
    /// fault-free [`FaultFs`] holding this one's files under `style`.
    /// With [`CrashStyle::DropUnsynced`] every file is truncated to its
    /// synced prefix (and its synced marker carries over); with
    /// [`CrashStyle::KeepAll`] contents survive verbatim.
    pub fn restart(&self, style: CrashStyle) -> FaultFs {
        let s = self.state.lock().expect("fault fs lock");
        let fresh = FaultFs::new();
        {
            let mut t = fresh.state.lock().expect("fault fs lock");
            for (path, img) in &s.files {
                let content = match style {
                    CrashStyle::KeepAll => img.content.clone(),
                    CrashStyle::DropUnsynced => img.content[..img.synced].to_vec(),
                };
                let synced = content.len();
                t.files.insert(path.clone(), FileImage { content, synced });
            }
        }
        fresh
    }
}

struct FaultFile {
    state: Arc<Mutex<State>>,
    path: PathBuf,
}

impl WriteFile for FaultFile {
    fn write(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        let mut s = self.state.lock().expect("fault fs lock");
        let fired = s.admit(OpKind::Write, &self.path)?;
        let file = s
            .files
            .entry(self.path.clone())
            .or_insert_with(FileImage::default);
        match fired {
            Some(plan) => {
                // A torn write: the leading prefix lands, then the op
                // (and the process) dies.
                let keep = plan.short_write.min(bytes.len());
                file.content.extend_from_slice(&bytes[..keep]);
                Err(injected("write"))
            }
            None => {
                file.content.extend_from_slice(bytes);
                Ok(())
            }
        }
    }

    fn sync(&mut self) -> std::io::Result<()> {
        let mut s = self.state.lock().expect("fault fs lock");
        let fired = s.admit(OpKind::Sync, &self.path)?;
        if fired.is_some() {
            return Err(injected("sync"));
        }
        if let Some(file) = s.files.get_mut(&self.path) {
            file.synced = file.content.len();
        }
        Ok(())
    }
}

impl FileOps for FaultFs {
    fn create(&self, path: &Path) -> std::io::Result<Box<dyn WriteFile>> {
        {
            let mut s = self.state.lock().expect("fault fs lock");
            if s.admit(OpKind::Create, path)?.is_some() {
                return Err(injected("create"));
            }
            // Truncating create: a fresh, unsynced, empty image. If the
            // path existed, its old bytes are gone (truncation is a
            // metadata op — atomic, like rename).
            s.files.insert(to_owned(path), FileImage::default());
        }
        Ok(Box::new(FaultFile { state: self.state.clone(), path: to_owned(path) }))
    }

    fn open_append(&self, path: &Path) -> std::io::Result<Box<dyn WriteFile>> {
        {
            let mut s = self.state.lock().expect("fault fs lock");
            if s.crashed {
                return Err(injected("operation after crash"));
            }
            // Opening for append neither writes nor destroys bytes —
            // not a crash point, but it must materialize the file.
            s.files.entry(to_owned(path)).or_insert_with(FileImage::default);
        }
        Ok(Box::new(FaultFile { state: self.state.clone(), path: to_owned(path) }))
    }

    fn read(&self, path: &Path) -> std::io::Result<Vec<u8>> {
        let s = self.state.lock().expect("fault fs lock");
        if s.crashed {
            return Err(injected("operation after crash"));
        }
        match s.files.get(path) {
            Some(f) => Ok(f.content.clone()),
            None => Err(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                format!("no such in-memory file: {}", path.display()),
            )),
        }
    }

    fn rename(&self, from: &Path, to: &Path) -> std::io::Result<()> {
        let mut s = self.state.lock().expect("fault fs lock");
        if s.admit(OpKind::Rename, from)?.is_some() {
            return Err(injected("rename"));
        }
        match s.files.remove(from) {
            Some(img) => {
                s.files.insert(to_owned(to), img);
                Ok(())
            }
            None => Err(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                format!("rename source missing: {}", from.display()),
            )),
        }
    }

    fn remove(&self, path: &Path) -> std::io::Result<()> {
        let mut s = self.state.lock().expect("fault fs lock");
        if s.admit(OpKind::Remove, path)?.is_some() {
            return Err(injected("remove"));
        }
        match s.files.remove(path) {
            Some(_) => Ok(()),
            None => Err(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                format!("remove target missing: {}", path.display()),
            )),
        }
    }

    fn exists(&self, path: &Path) -> bool {
        let s = self.state.lock().expect("fault fs lock");
        s.files.contains_key(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(s: &str) -> PathBuf {
        PathBuf::from(s)
    }

    #[test]
    fn clean_run_traces_every_mutating_op() {
        let fs = FaultFs::new();
        let mut f = fs.create(&p("a")).unwrap();
        f.write(b"xy").unwrap();
        f.sync().unwrap();
        fs.rename(&p("a"), &p("b")).unwrap();
        fs.remove(&p("b")).unwrap();
        let kinds: Vec<OpKind> = fs.trace().into_iter().map(|r| r.kind).collect();
        assert_eq!(
            kinds,
            vec![OpKind::Create, OpKind::Write, OpKind::Sync, OpKind::Rename, OpKind::Remove]
        );
        assert!(!fs.crashed());
    }

    #[test]
    fn crash_point_fails_the_op_and_everything_after() {
        // Crash at op 2 (the sync): the write landed, the sync did not,
        // and the error-path remove also fails (dead process).
        let fs = FaultFs::with_plan(FaultPlan::fail_op(2));
        let mut f = fs.create(&p("a")).unwrap();
        f.write(b"hello").unwrap();
        assert!(f.sync().is_err());
        assert!(fs.remove(&p("a")).is_err(), "cleanup after a crash must fail");
        assert!(fs.crashed());

        // KeepAll: the buffered write survives. DropUnsynced: nothing
        // was ever synced, so the file comes back empty.
        assert_eq!(fs.restart(CrashStyle::KeepAll).get(&p("a")).unwrap(), b"hello");
        assert_eq!(fs.restart(CrashStyle::DropUnsynced).get(&p("a")).unwrap(), b"");
    }

    #[test]
    fn torn_write_keeps_only_the_prefix() {
        let fs = FaultFs::with_plan(FaultPlan::torn_write(1, 3));
        let mut f = fs.create(&p("a")).unwrap();
        assert!(f.write(b"abcdef").is_err());
        assert_eq!(fs.restart(CrashStyle::KeepAll).get(&p("a")).unwrap(), b"abc");
        assert_eq!(fs.restart(CrashStyle::DropUnsynced).get(&p("a")).unwrap(), b"");
    }

    #[test]
    fn sync_marks_the_durable_prefix() {
        let fs = FaultFs::new();
        let mut f = fs.create(&p("a")).unwrap();
        f.write(b"abc").unwrap();
        f.sync().unwrap();
        f.write(b"def").unwrap();
        // No crash: both images agree on present content, but a
        // DropUnsynced restart only keeps the synced prefix.
        assert_eq!(fs.get(&p("a")).unwrap(), b"abcdef");
        assert_eq!(fs.restart(CrashStyle::KeepAll).get(&p("a")).unwrap(), b"abcdef");
        assert_eq!(fs.restart(CrashStyle::DropUnsynced).get(&p("a")).unwrap(), b"abc");
    }

    #[test]
    fn rename_is_atomic_and_carries_the_synced_marker() {
        let fs = FaultFs::new();
        let mut f = fs.create(&p("t.tmp")).unwrap();
        f.write(b"abc").unwrap();
        f.sync().unwrap();
        f.write(b"tail").unwrap();
        drop(f);
        fs.rename(&p("t.tmp"), &p("t")).unwrap();
        assert!(!fs.exists(&p("t.tmp")));
        assert_eq!(fs.restart(CrashStyle::DropUnsynced).get(&p("t")).unwrap(), b"abc");
    }

    #[test]
    fn failed_rename_leaves_both_paths_untouched() {
        let fs = FaultFs::with_plan(FaultPlan::fail_op(3));
        fs.put(&p("old"), b"OLD");
        let mut f = fs.create(&p("new.tmp")).unwrap();
        f.write(b"NEW").unwrap();
        f.sync().unwrap();
        assert!(fs.rename(&p("new.tmp"), &p("old")).is_err());
        let disk = fs.restart(CrashStyle::KeepAll);
        assert_eq!(disk.get(&p("old")).unwrap(), b"OLD", "target untouched");
        assert_eq!(disk.get(&p("new.tmp")).unwrap(), b"NEW", "source untouched");
    }

    #[test]
    fn restart_resets_the_trace_and_the_plan() {
        let fs = FaultFs::with_plan(FaultPlan::fail_op(0));
        assert!(fs.create(&p("a")).is_err());
        let disk = fs.restart(CrashStyle::KeepAll);
        assert!(!disk.crashed());
        assert_eq!(disk.op_count(), 0);
        // The restarted fs is fault-free: the same op now succeeds.
        let mut f = disk.create(&p("a")).unwrap();
        f.write(b"ok").unwrap();
        assert_eq!(disk.get(&p("a")).unwrap(), b"ok");
    }

    #[test]
    fn put_seeds_files_without_trace_entries() {
        let fs = FaultFs::new();
        fs.put(&p("seed"), b"S");
        assert_eq!(fs.op_count(), 0);
        assert_eq!(fs.read(&p("seed")).unwrap(), b"S");
        // Seeded files are considered durable (synced in full).
        assert_eq!(fs.restart(CrashStyle::DropUnsynced).get(&p("seed")).unwrap(), b"S");
    }
}
