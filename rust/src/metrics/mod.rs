//! Measurement utilities shared by the experiment drivers and benches:
//! summary statistics, win/loss tables and report writers.

pub mod report;
pub mod stats;

pub use report::{format_duration, Table};
pub use stats::Summary;
