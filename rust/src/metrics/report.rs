//! Plain-text table/CSV emission for experiment results — the benches
//! print the same rows the paper's tables report.

use std::fmt::Write as _;
use std::time::Duration;

/// A simple column-aligned table with markdown and CSV rendering.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create with a header row.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header arity).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            out.push('|');
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(out, " {c:<w$} |");
            }
            out.push('\n');
        };
        fmt_row(&self.header, &widths, &mut out);
        out.push('|');
        for w in &widths {
            let _ = write!(out, "{}|", "-".repeat(w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }

    /// Render as CSV (naive quoting: cells containing commas are quoted).
    pub fn to_csv(&self) -> String {
        let esc = |c: &String| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a duration the way the paper's tables do: `H:MM:SS` above a
/// minute, otherwise seconds or milliseconds.
pub fn format_duration(d: Duration) -> String {
    let secs = d.as_secs_f64();
    if secs >= 60.0 {
        let total = d.as_secs();
        format!("{}:{:02}:{:02}", total / 3600, (total % 3600) / 60, total % 60)
    } else if secs >= 1.0 {
        format!("{secs:.2}s")
    } else {
        format!("{:.2}ms", secs * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_roundtrip() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "long cell"]);
        let md = t.to_markdown();
        assert!(md.contains("| a "));
        assert!(md.contains("| long cell |"));
        assert!(md.lines().count() == 3);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only one"]);
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new(vec!["x"]);
        t.row(vec!["a,b"]);
        assert_eq!(t.to_csv(), "x\n\"a,b\"\n");
    }

    #[test]
    fn durations() {
        assert_eq!(format_duration(Duration::from_millis(5)), "5.00ms");
        assert_eq!(format_duration(Duration::from_secs_f64(2.5)), "2.50s");
        assert_eq!(format_duration(Duration::from_secs(4 * 3600 + 23 * 60 + 9)), "4:23:09");
    }
}
