//! Summary statistics for repeated timing measurements (the paper reports
//! means of ten runs with standard-deviation error bars).

/// Mean / standard deviation / extrema of a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    /// Sample size.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 for n < 2).
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample. Empty input yields zeros.
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        if n == 0 {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        Summary { n, mean, std: var.sqrt(), min, max }
    }

    /// Percentile by linear interpolation (p in [0, 100]).
    pub fn percentile(xs: &mut [f64], p: f64) -> f64 {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (xs.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            xs[lo]
        } else {
            xs[lo] + (xs[hi] - xs[lo]) * (rank - lo as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_summary() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_and_singleton() {
        assert_eq!(Summary::of(&[]).n, 0);
        let s = Summary::of(&[7.0]);
        assert_eq!((s.mean, s.std), (7.0, 0.0));
    }

    #[test]
    fn percentiles() {
        let mut xs = vec![4.0, 1.0, 3.0, 2.0];
        assert_eq!(Summary::percentile(&mut xs, 0.0), 1.0);
        assert_eq!(Summary::percentile(&mut xs, 100.0), 4.0);
        assert_eq!(Summary::percentile(&mut xs, 50.0), 2.5);
    }
}
