//! §6.2 nearest-neighbor timing experiment — Figures 19–28.
//!
//! For each dataset (recommended window ≥ 1) and each bound, classify the
//! full test set `repeats` times and record per-run wall-clock times; the
//! paper plots per-dataset means with ±1σ error bars on log-log axes and
//! quotes win/loss counts and repository-total times.
//!
//! `LB_ENHANCED*` (the best `k` per dataset) is handled by running every
//! `k` in [`super::ENHANCED_K_GRID`] and keeping the fastest mean, exactly
//! as §6.2 describes ("the best performance of LB_ENHANCED for any
//! setting of k").

use std::time::Duration;

use crate::bounds::BoundKind;
use crate::data::Dataset;
use crate::delta::Delta;
use crate::index::DtwIndex;
use crate::metrics::{format_duration, Summary, Table};
use crate::search::classify::classify_dataset;
use crate::search::SearchStrategy;

/// Timing of one (dataset, bound) cell.
#[derive(Debug, Clone)]
pub struct CellTiming {
    /// Dataset name.
    pub dataset: String,
    /// Per-repeat wall times in milliseconds.
    pub times_ms: Vec<f64>,
    /// Classification accuracy (identical across bounds by construction).
    pub accuracy: f64,
    /// For `Enhanced*`: the selected k.
    pub chosen_k: Option<usize>,
}

impl CellTiming {
    /// Mean time in ms.
    pub fn mean_ms(&self) -> f64 {
        Summary::of(&self.times_ms).mean
    }
}

/// A bound column: timing cells for every dataset.
#[derive(Debug, Clone)]
pub struct BoundTiming {
    /// The bound (for `EnhancedStar`, the base kind is `Enhanced(0)`).
    pub label: String,
    /// Per-dataset cells, parallel to the dataset list.
    pub cells: Vec<CellTiming>,
}

impl BoundTiming {
    /// Total mean time across datasets.
    pub fn total(&self) -> Duration {
        Duration::from_secs_f64(self.cells.iter().map(|c| c.mean_ms()).sum::<f64>() / 1e3)
    }
}

/// Bound selector for timing runs: a concrete bound, or the per-dataset
/// best-k `LB_ENHANCED*`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimedBound {
    /// A fixed bound.
    Fixed(BoundKind),
    /// `LB_ENHANCED*`: best k from the grid per dataset.
    EnhancedStar,
}

impl TimedBound {
    /// Display label.
    pub fn label(&self) -> String {
        match self {
            TimedBound::Fixed(b) => b.name(),
            TimedBound::EnhancedStar => "LB_Enhanced*".into(),
        }
    }
}

/// Run the timing experiment.
///
/// `windows` gives the window per dataset (parallel slice) so the same
/// function serves §6.2 (recommended windows) and §6.3 (percentage
/// windows). Training-set preparation is excluded from timing, as in the
/// paper.
pub fn nn_timing<D: Delta>(
    datasets: &[&Dataset],
    windows: &[usize],
    bounds: &[TimedBound],
    strategy: SearchStrategy,
    repeats: usize,
    seed: u64,
) -> Vec<BoundTiming> {
    assert_eq!(datasets.len(), windows.len());
    let mut out: Vec<BoundTiming> = bounds
        .iter()
        .map(|b| BoundTiming { label: b.label(), cells: Vec::with_capacity(datasets.len()) })
        .collect();

    for (di, ds) in datasets.iter().enumerate() {
        let w = windows[di];
        // One index per dataset; per-cell bound variations share its
        // prepared envelopes through cheap `with_bound` handles.
        let index = DtwIndex::builder_from_dataset(ds)
            .window(w)
            .strategy(strategy)
            .build()
            .expect("dataset series share one length");
        for (bi, tb) in bounds.iter().enumerate() {
            let cell = match tb {
                TimedBound::Fixed(b) => time_cell::<D>(ds, &index, *b, repeats, seed, None),
                TimedBound::EnhancedStar => {
                    // Paper protocol: report the fastest k per dataset.
                    let mut best: Option<CellTiming> = None;
                    for &k in super::ENHANCED_K_GRID {
                        let c = time_cell::<D>(
                            ds,
                            &index,
                            BoundKind::Enhanced(k),
                            repeats,
                            seed,
                            Some(k),
                        );
                        if best.as_ref().map(|b| c.mean_ms() < b.mean_ms()).unwrap_or(true) {
                            best = Some(c);
                        }
                    }
                    best.unwrap()
                }
            };
            log::info!(
                "nn_timing {} {} w={w}: {:.1}ms",
                ds.name,
                out[bi].label,
                cell.mean_ms()
            );
            out[bi].cells.push(cell);
        }
    }
    out
}

fn time_cell<D: Delta>(
    ds: &Dataset,
    index: &DtwIndex,
    bound: BoundKind,
    repeats: usize,
    seed: u64,
    chosen_k: Option<usize>,
) -> CellTiming {
    let cell_index = index.with_bound(bound);
    let mut times_ms = Vec::with_capacity(repeats);
    let mut accuracy = 0.0;
    for rep in 0..repeats {
        let out = classify_dataset::<D>(ds, &cell_index, seed.wrapping_add(rep as u64));
        times_ms.push(out.elapsed.as_secs_f64() * 1e3);
        accuracy = out.accuracy;
    }
    CellTiming { dataset: ds.name.clone(), times_ms, accuracy, chosen_k }
}

/// Win/loss between two timing columns (count of datasets where `a`'s
/// mean is lower), plus the total-time ratio `total(a)/total(b)` — the
/// exact format of Tables 1–3.
pub fn win_loss_ratio(a: &BoundTiming, b: &BoundTiming) -> (usize, usize, f64) {
    let mut wins = 0;
    let mut losses = 0;
    for (ca, cb) in a.cells.iter().zip(b.cells.iter()) {
        if ca.mean_ms() < cb.mean_ms() {
            wins += 1;
        } else if cb.mean_ms() < ca.mean_ms() {
            losses += 1;
        }
    }
    let ratio = a.total().as_secs_f64() / b.total().as_secs_f64();
    (wins, losses, ratio)
}

/// Render a comparison block like the paper's tables.
pub fn comparison_table(columns: &[BoundTiming], pairings: &[(usize, usize)]) -> Table {
    let mut t = Table::new(vec!["Comparison", "win/loss", "Total time ratio"]);
    for &(i, j) in pairings {
        let (w, l, r) = win_loss_ratio(&columns[i], &columns[j]);
        t.row(vec![
            format!("{} vs {}", columns[i].label, columns[j].label),
            format!("{w} / {l}"),
            format!(
                "{}/{} = {r:.2}",
                format_duration(columns[i].total()),
                format_duration(columns[j].total())
            ),
        ]);
    }
    t
}

/// Per-dataset scatter data (mean ± std for two columns) — the log-log
/// scatter plots of Figures 19–30.
pub fn scatter_table(a: &BoundTiming, b: &BoundTiming) -> Table {
    let mut t = Table::new(vec![
        "dataset".to_string(),
        format!("{} mean ms", a.label),
        format!("{} std", a.label),
        format!("{} mean ms", b.label),
        format!("{} std", b.label),
    ]);
    for (ca, cb) in a.cells.iter().zip(b.cells.iter()) {
        let (sa, sb) = (Summary::of(&ca.times_ms), Summary::of(&cb.times_ms));
        t.row(vec![
            ca.dataset.clone(),
            format!("{:.2}", sa.mean),
            format!("{:.2}", sa.std),
            format!("{:.2}", sb.mean),
            format!("{:.2}", sb.std),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;
    use crate::experiments::with_recommended_window;

    #[test]
    fn timing_runs_and_tables_render() {
        let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 77));
        let datasets: Vec<&crate::data::Dataset> =
            with_recommended_window(&archive).into_iter().take(2).collect();
        let windows: Vec<usize> = datasets.iter().map(|d| d.window).collect();
        let bounds = [
            TimedBound::Fixed(BoundKind::Keogh),
            TimedBound::Fixed(BoundKind::Webb),
        ];
        let cols = nn_timing::<Squared>(
            &datasets,
            &windows,
            &bounds,
            SearchStrategy::Sorted,
            2,
            42,
        );
        assert_eq!(cols.len(), 2);
        assert_eq!(cols[0].cells.len(), datasets.len());
        // Accuracy is identical across bounds (exact same NN distances).
        for (a, b) in cols[0].cells.iter().zip(cols[1].cells.iter()) {
            assert_eq!(a.accuracy, b.accuracy);
        }
        let cmp = comparison_table(&cols, &[(1, 0)]);
        assert_eq!(cmp.len(), 1);
        let sc = scatter_table(&cols[1], &cols[0]);
        assert_eq!(sc.len(), datasets.len());
        let (w, l, r) = win_loss_ratio(&cols[0], &cols[1]);
        assert!(w + l <= datasets.len());
        assert!(r > 0.0);
    }

    #[test]
    fn enhanced_star_selects_a_k() {
        let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 78));
        let datasets: Vec<&crate::data::Dataset> =
            with_recommended_window(&archive).into_iter().take(1).collect();
        let windows: Vec<usize> = datasets.iter().map(|d| d.window).collect();
        let cols = nn_timing::<Squared>(
            &datasets,
            &windows,
            &[TimedBound::EnhancedStar],
            SearchStrategy::Sorted,
            1,
            7,
        );
        assert!(cols[0].cells[0].chosen_k.is_some());
    }
}
