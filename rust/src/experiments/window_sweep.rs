//! §6.3 window sweep — Tables 1, 2, 3 and Figures 29, 30.
//!
//! Classification time over **all** datasets (not just those with
//! recommended window ≥ 1), sorted-order search, with the window set to a
//! fixed percentage of series length (1%, 10%, 20%), rounded **up**. Each
//! table reports eight pairings of win/loss counts and total-time ratios.

use crate::data::Dataset;
use crate::delta::Delta;
use crate::metrics::Table;
use crate::search::SearchStrategy;

use super::nn_timing::{comparison_table, nn_timing, BoundTiming, TimedBound};
use crate::bounds::BoundKind;

/// The eight pairings of Tables 1–3, as (row label order preserved).
pub fn paper_pairings() -> Vec<(TimedBound, TimedBound)> {
    use BoundKind::*;
    use TimedBound::*;
    vec![
        (Fixed(Webb), Fixed(Keogh)),
        (Fixed(Webb), Fixed(Improved)),
        (Fixed(Webb), Fixed(Petitjean)),
        (Fixed(Webb), EnhancedStar),
        (Fixed(Petitjean), Fixed(Keogh)),
        (Fixed(Petitjean), Fixed(Improved)),
        (Fixed(Petitjean), Fixed(Webb)),
        (Fixed(Petitjean), EnhancedStar),
    ]
}

/// Result of one sweep at a window fraction.
#[derive(Debug, Clone)]
pub struct SweepResult {
    /// The window fraction (e.g. 0.01).
    pub frac: f64,
    /// Timing columns, in the order of [`sweep_bounds`].
    pub columns: Vec<BoundTiming>,
}

/// The distinct bounds a sweep must time (columns of the pairings).
pub fn sweep_bounds() -> Vec<TimedBound> {
    vec![
        TimedBound::Fixed(BoundKind::Webb),
        TimedBound::Fixed(BoundKind::Keogh),
        TimedBound::Fixed(BoundKind::Improved),
        TimedBound::Fixed(BoundKind::Petitjean),
        TimedBound::EnhancedStar,
    ]
}

impl SweepResult {
    /// Index of a timed bound in `columns`.
    fn col(&self, b: TimedBound) -> usize {
        let label = b.label();
        self.columns.iter().position(|c| c.label == label).expect("column present")
    }

    /// Render the paper-table comparison block.
    pub fn to_table(&self) -> Table {
        let pair_idx: Vec<(usize, usize)> = paper_pairings()
            .into_iter()
            .map(|(a, b)| (self.col(a), self.col(b)))
            .collect();
        comparison_table(&self.columns, &pair_idx)
    }
}

/// Run the sweep at one window fraction over all datasets.
pub fn window_sweep<D: Delta>(
    datasets: &[&Dataset],
    frac: f64,
    repeats: usize,
    seed: u64,
) -> SweepResult {
    let windows: Vec<usize> = datasets.iter().map(|d| d.window_fraction(frac)).collect();
    let bounds = sweep_bounds();
    let columns = nn_timing::<D>(
        datasets,
        &windows,
        &bounds,
        SearchStrategy::Sorted,
        repeats,
        seed,
    );
    SweepResult { frac, columns }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;

    #[test]
    fn sweep_produces_eight_pairings() {
        let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 91));
        let datasets: Vec<&crate::data::Dataset> = archive.iter().take(2).collect();
        let res = window_sweep::<Squared>(&datasets, 0.05, 1, 3);
        let t = res.to_table();
        assert_eq!(t.len(), 8);
        assert_eq!(res.columns.len(), 5);
        // Windows were rounded up: never zero.
        // (implicit: classify ran with w >= 1 because frac*len >= 1 ceil)
    }
}
