//! §6.1 tightness experiment — the data behind Figures 1, 2 and 15–18.
//!
//! For every dataset with recommended window ≥ 1, compute the mean
//! tightness `λ_w(Q,T)/DTW_w(Q,T)` over all test×train pairs for each
//! bound. The paper presents these as per-dataset scatter plots of one
//! bound against another; we emit the full per-dataset matrix, from which
//! every pairwise scatter (and the win counts quoted in the text) follows.

use crate::bounds::BoundKind;
use crate::coordinator::WorkerPool;
use crate::data::Dataset;
use crate::delta::Delta;
use crate::index::DtwIndex;
use crate::metrics::Table;
use crate::search::tightness::dataset_tightness;

/// Per-dataset tightness for a set of bounds.
#[derive(Debug, Clone)]
pub struct TightnessResult {
    /// Bounds evaluated, in column order.
    pub bounds: Vec<BoundKind>,
    /// `(dataset name, window, mean tightness per bound)`.
    pub rows: Vec<(String, usize, Vec<f64>)>,
}

impl TightnessResult {
    /// Column index of a bound.
    pub fn col(&self, bound: BoundKind) -> Option<usize> {
        self.bounds.iter().position(|&b| b == bound)
    }

    /// Count datasets where `a` is strictly tighter than `b` (and vice
    /// versa) — the "tighter on average for N datasets" numbers of §6.1.
    pub fn win_loss(&self, a: BoundKind, b: BoundKind) -> (usize, usize) {
        let (ca, cb) = (self.col(a).unwrap(), self.col(b).unwrap());
        let mut wins = 0;
        let mut losses = 0;
        for (_, _, t) in &self.rows {
            if t[ca] > t[cb] + 1e-12 {
                wins += 1;
            } else if t[cb] > t[ca] + 1e-12 {
                losses += 1;
            }
        }
        (wins, losses)
    }

    /// Render the full matrix as a table.
    pub fn to_table(&self) -> Table {
        let mut header = vec!["dataset".to_string(), "w".to_string()];
        header.extend(self.bounds.iter().map(|b| b.name()));
        let mut t = Table::new(header);
        for (name, w, vals) in &self.rows {
            let mut row = vec![name.clone(), w.to_string()];
            row.extend(vals.iter().map(|v| format!("{v:.4}")));
            t.row(row);
        }
        t
    }
}

/// Run the tightness experiment over `datasets` (already filtered to
/// recommended-window ≥ 1 by the caller, matching §6.1).
///
/// Dataset-parallel over a [`WorkerPool`]; each worker keeps one DTW
/// cache for its share of the datasets, so the denominator buffer is
/// allocated once per thread instead of once per dataset. Results are
/// independent per dataset and returned in input order, so the output is
/// identical to the sequential run.
pub fn tightness_experiment<D: Delta>(
    datasets: &[&Dataset],
    bounds: &[BoundKind],
) -> TightnessResult {
    let pool = WorkerPool::auto();
    let rows = pool.map_init(datasets.to_vec(), Vec::new, |cache, ds| {
        // The cache keys on nothing but its length — clear it between
        // datasets (capacity is retained, which is the point of the
        // per-worker state).
        cache.clear();
        let index = DtwIndex::builder_from_dataset(ds)
            .window(ds.window)
            .build()
            .expect("dataset series share one length");
        let vals: Vec<f64> = bounds
            .iter()
            .map(|&b| dataset_tightness::<D>(ds, &index.with_bound(b), cache).mean)
            .collect();
        log::info!("tightness {}: done ({} bounds)", ds.name, bounds.len());
        (ds.name.clone(), ds.window, vals)
    });
    TightnessResult { bounds: bounds.to_vec(), rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;
    use crate::experiments::with_recommended_window;

    #[test]
    fn paper_orderings_hold_per_dataset() {
        let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 5));
        let datasets = with_recommended_window(&archive);
        let bounds = vec![
            BoundKind::Keogh,
            BoundKind::Improved,
            BoundKind::PetitjeanNoLr,
            BoundKind::Webb,
            BoundKind::WebbNoLr,
        ];
        let res = tightness_experiment::<Squared>(&datasets[..3.min(datasets.len())], &bounds);
        assert!(!res.rows.is_empty());
        let (ck, ci, cpn, _cw, cwn) = (
            res.col(BoundKind::Keogh).unwrap(),
            res.col(BoundKind::Improved).unwrap(),
            res.col(BoundKind::PetitjeanNoLr).unwrap(),
            res.col(BoundKind::Webb).unwrap(),
            res.col(BoundKind::WebbNoLr).unwrap(),
        );
        for (name, _, t) in &res.rows {
            assert!(t[ci] >= t[ck] - 1e-12, "{name}: improved < keogh");
            assert!(t[cpn] >= t[ci] - 1e-12, "{name}: petitjean_nolr < improved");
            assert!(t[cwn] >= t[ck] - 1e-12, "{name}: webb_nolr < keogh");
            for &v in t {
                assert!((0.0..=1.0 + 1e-9).contains(&v));
            }
        }
        // win_loss is antisymmetric-ish
        let (w1, l1) = res.win_loss(BoundKind::Improved, BoundKind::Keogh);
        let (w2, l2) = res.win_loss(BoundKind::Keogh, BoundKind::Improved);
        assert_eq!((w1, l1), (l2, w2));
        // Table renders
        let table = res.to_table();
        assert_eq!(table.len(), res.rows.len());
    }
}
