//! Experiment drivers — one per artifact of the paper's evaluation
//! section. `benches/` and the CLI are thin wrappers over these.
//!
//! | Paper artifact | Driver |
//! |---|---|
//! | Figures 1, 2, 15–18 (tightness scatter, optimal windows) | [`tightness_experiment`] |
//! | Figures 19–28 (NN timing, optimal windows, both orders) | [`nn_timing`] |
//! | Tables 1–3 + Figures 29, 30 (window sweep 1/10/20%) | [`window_sweep`] |
//! | Figures 31–34 (left/right path ablation) | [`lr_ablation`] |

pub mod lr_ablation;
pub mod nn_timing;
pub mod tightness;
pub mod window_sweep;

pub use lr_ablation::lr_ablation;
pub use nn_timing::nn_timing;
pub use tightness::tightness_experiment;
pub use window_sweep::window_sweep;

use crate::data::Dataset;

/// §6.1/6.2 protocol: experiments at "optimal" windows use only datasets
/// whose recommended window is ≥ 1 (the paper keeps 60 of 85).
pub fn with_recommended_window(archive: &[Dataset]) -> Vec<&Dataset> {
    archive.iter().filter(|d| d.window >= 1).collect()
}

/// The `LB_ENHANCED*` protocol of §6.2/6.3: the best-performing `k` per
/// dataset is chosen from this grid (the paper sweeps `k ≤ 16`).
pub const ENHANCED_K_GRID: &[usize] = &[1, 2, 4, 8, 16];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};

    #[test]
    fn recommended_window_filter() {
        let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 2));
        let kept = with_recommended_window(&archive);
        assert!(kept.len() <= archive.len());
        assert!(kept.iter().all(|d| d.window >= 1));
    }
}
