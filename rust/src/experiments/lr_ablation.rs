//! §7 — the effect of the left and right paths (Figures 31–34).
//!
//! Compares `LB_WEBB` against `LB_WEBB_NoLR` (paths removed) and
//! `LB_WEBB_ENHANCED³` (paths replaced by bands) on both tightness and
//! sorted-order NN time, over the recommended-window datasets.

use crate::bounds::BoundKind;
use crate::data::Dataset;
use crate::delta::Delta;
use crate::search::SearchStrategy;

use super::nn_timing::{nn_timing, BoundTiming, TimedBound};
use super::tightness::{tightness_experiment, TightnessResult};

/// The three §7 variants in column order.
pub fn ablation_bounds() -> Vec<BoundKind> {
    vec![BoundKind::Webb, BoundKind::WebbNoLr, BoundKind::WebbEnhanced(3)]
}

/// Combined §7 result.
#[derive(Debug)]
pub struct LrAblationResult {
    /// Figures 31/32 data.
    pub tightness: TightnessResult,
    /// Figures 33/34 data (sorted order).
    pub timing: Vec<BoundTiming>,
}

/// Run the ablation.
pub fn lr_ablation<D: Delta>(
    datasets: &[&Dataset],
    repeats: usize,
    seed: u64,
) -> LrAblationResult {
    let bounds = ablation_bounds();
    let tightness = tightness_experiment::<D>(datasets, &bounds);
    let windows: Vec<usize> = datasets.iter().map(|d| d.window).collect();
    let timed: Vec<TimedBound> = bounds.iter().map(|&b| TimedBound::Fixed(b)).collect();
    let timing = nn_timing::<D>(datasets, &windows, &timed, SearchStrategy::Sorted, repeats, seed);
    LrAblationResult { tightness, timing }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{generate_archive, ArchiveSpec, Scale};
    use crate::delta::Squared;
    use crate::experiments::with_recommended_window;

    #[test]
    fn webb_enhanced3_never_tighter_than_webb_family_rules() {
        let archive = generate_archive(&ArchiveSpec::new(Scale::Tiny, 33));
        let datasets: Vec<&crate::data::Dataset> =
            with_recommended_window(&archive).into_iter().take(2).collect();
        let res = lr_ablation::<Squared>(&datasets, 1, 5);
        assert_eq!(res.tightness.bounds.len(), 3);
        assert_eq!(res.timing.len(), 3);
        // §7: LB_WEBB tighter than LB_WEBB_ENHANCED^3 on every dataset
        // (difference always small). We assert the direction.
        let (cw, cwe) = (
            res.tightness.col(BoundKind::Webb).unwrap(),
            res.tightness.col(BoundKind::WebbEnhanced(3)).unwrap(),
        );
        for (name, _, t) in &res.tightness.rows {
            assert!(
                t[cw] >= t[cwe] - 1e-9,
                "{name}: webb {} < webb_enhanced3 {}",
                t[cw],
                t[cwe]
            );
        }
    }
}
