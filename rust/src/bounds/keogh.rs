//! `LB_KEOGH` (Keogh & Ratanamahatana 2005) — the workhorse envelope bound
//! and the "bridge" every other bound in this crate builds on.
//!
//! ```text
//! LB_Keogh_w(A, B) = Σ_i  δ(A_i, 𝕌_i^B)  if A_i > 𝕌_i^B
//!                        δ(A_i, 𝕃_i^B)  if A_i < 𝕃_i^B
//!                        0              otherwise
//! ```
//!
//! Sound because any `B_j` that `A_i` may align with (`|i-j| ≤ w`) lies
//! within `[𝕃_i^B, 𝕌_i^B]`, so the distance from `A_i` to the envelope
//! never exceeds the distance to the aligned element.

use crate::delta::{Delta, DeltaId};

use super::PreparedSeries;

/// Full-range `LB_KEOGH` with early abandoning.
#[inline]
pub fn lb_keogh<D: Delta>(a: &[f64], t: &PreparedSeries, abandon_at: f64) -> f64 {
    lb_keogh_bridge::<D>(a, &t.lo, &t.up, 0, a.len(), 0.0, abandon_at)
}

/// `LB_KEOGH` with the roles of the two series reversed — candidate
/// against the *query's* envelope. §8 of the paper: "Reversing the order
/// of the two series in LB_KEOGH will obtain a tighter bound … in
/// approximately 50% of cases"; the UCR-suite cascade (Rakthanmanon &
/// Keogh 2013) runs both. Requires a query prepared with envelopes.
#[inline]
pub fn lb_keogh_reversed<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    abandon_at: f64,
) -> f64 {
    debug_assert_eq!(q.lo.len(), t.values.len(), "reversed Keogh needs query envelopes");
    lb_keogh_bridge::<D>(&t.values, &q.lo, &q.up, 0, t.values.len(), 0.0, abandon_at)
}

/// The Keogh *bridge*: the same sum restricted to `range_lo..range_hi`,
/// starting from an already-accumulated value `acc` (the LR-path or band
/// contribution of the enclosing bound). Abandons (returning the partial,
/// still-valid bound) once the sum exceeds `abandon_at`.
pub fn lb_keogh_bridge<D: Delta>(
    a: &[f64],
    t_lo: &[f64],
    t_up: &[f64],
    range_lo: usize,
    range_hi: usize,
    acc: f64,
    abandon_at: f64,
) -> f64 {
    let mut b = acc;
    for i in range_lo..range_hi {
        let v = a[i];
        if v > t_up[i] {
            b += D::delta(v, t_up[i]);
        } else if v < t_lo[i] {
            b += D::delta(v, t_lo[i]);
        }
        if b > abandon_at {
            return b;
        }
    }
    b
}

/// Per-position `LB_KEOGH` contributions as a **suffix-sum tail array**
/// for [`crate::dtw::dtw_ea_pruned`]: fills `tail` (length `a.len() + 1`)
/// with `tail[i] = Σ_{j ≥ i} keogh_term(j)` and `tail[len] = 0`, and
/// returns `tail[0]` (the full `LB_KEOGH` value).
///
/// Soundness for the pruned DTW kernel: every in-window alignment of
/// `a[i]` costs at least `keogh_term(i)` (the envelope is the closest
/// any aligned element can be, and δ is monotone in `|a-b|`), so
/// `tail[i]` lower-bounds the cost rows `i..` add to any warping path,
/// and each increment `tail[i] - tail[i+1]` never exceeds
/// `δ(a[i], b[j])` — the two properties `dtw_ea_pruned` requires.
pub fn lb_keogh_tail<D: Delta>(
    a: &[f64],
    t_lo: &[f64],
    t_up: &[f64],
    tail: &mut Vec<f64>,
) -> f64 {
    let n = a.len();
    debug_assert_eq!(t_lo.len(), n);
    debug_assert_eq!(t_up.len(), n);
    tail.clear();
    tail.resize(n + 1, 0.0);
    let mut acc = 0.0f64;
    for i in (0..n).rev() {
        let v = a[i];
        if v > t_up[i] {
            acc += D::delta(v, t_up[i]);
        } else if v < t_lo[i] {
            acc += D::delta(v, t_lo[i]);
        }
        tail[i] = acc;
    }
    acc
}

/// `LB_KEOGH` over flat SoA envelope rows — the inner kernel of
/// [`crate::runtime::NativeBatchLb`] over an
/// [`crate::bounds::store::EnvelopeStore`], and the cluster-prepass
/// kernel of the sharded k-NN and streaming paths.
///
/// Dispatches to the runtime-selected SIMD vtable
/// ([`crate::simd::kernels`]) for [`Squared`] and [`Absolute`] δ; any
/// other δ runs the generic scalar lane-protocol reference. All paths
/// follow the 4-lane accumulator protocol (`crate::simd` module docs):
/// lane `j` sums indices `i ≡ j (mod 4)`, lanes reduce as
/// `(l0 + l2) + (l1 + l3)`, tails add in order, and the early-abandon
/// variant tests the reduced partial once per 4-element group — so
/// results are **bit-identical at every ISA**, and a non-abandoned sum
/// is bit-identical to an `abandon_at = ∞` call. The lane-protocol sum
/// differs from [`lb_keogh_bridge`]'s strictly sequential accumulation
/// only by float reassociation (same terms, different addition order);
/// both remain exact lower bounds.
///
/// [`Squared`]: crate::delta::Squared
/// [`Absolute`]: crate::delta::Absolute
#[inline]
pub fn lb_keogh_flat<D: Delta>(a: &[f64], t_lo: &[f64], t_up: &[f64], abandon_at: f64) -> f64 {
    let n = a.len();
    debug_assert_eq!(t_lo.len(), n);
    debug_assert_eq!(t_up.len(), n);
    let k = crate::simd::kernels();
    match D::ID {
        DeltaId::Squared => {
            if abandon_at == f64::INFINITY {
                (k.keogh_sq_sum)(a, t_lo, t_up)
            } else {
                (k.keogh_sq_ea)(a, t_lo, t_up, abandon_at)
            }
        }
        DeltaId::Absolute => {
            if abandon_at == f64::INFINITY {
                (k.keogh_abs_sum)(a, t_lo, t_up)
            } else {
                (k.keogh_abs_ea)(a, t_lo, t_up, abandon_at)
            }
        }
        DeltaId::Other => {
            if abandon_at == f64::INFINITY {
                crate::simd::scalar::keogh_sum::<D>(a, t_lo, t_up)
            } else {
                crate::simd::scalar::keogh_ea::<D>(a, t_lo, t_up, abandon_at)
            }
        }
    }
}

/// Keogh bridge that also materializes the **projection**
/// `Ω_w(A, B)_i = clip(A_i, 𝕃_i^B, 𝕌_i^B)` over the *full* series (the
/// envelope of the projection near the bridge edges reads values outside
/// the bridge range, and Theorems 1–2 define Ω over the whole series).
///
/// Because the Keogh term is exactly `δ(A_i, Ω_i)`, filling the projection
/// first costs one extra pass but no extra branching in the summation.
pub fn lb_keogh_bridge_proj<D: Delta>(
    a: &[f64],
    t_lo: &[f64],
    t_up: &[f64],
    range_lo: usize,
    range_hi: usize,
    acc: f64,
    abandon_at: f64,
    proj: &mut Vec<f64>,
) -> f64 {
    let n = a.len();
    proj.clear();
    proj.resize(n, 0.0);
    for i in 0..n {
        proj[i] = a[i].clamp(t_lo[i], t_up[i]);
    }
    let mut b = acc;
    for i in range_lo..range_hi {
        b += D::delta(a[i], proj[i]);
        if b > abandon_at {
            return b;
        }
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::delta::{Absolute, Squared};
    use crate::dtw::dtw;

    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    fn prep(s: &[f64], w: usize) -> PreparedSeries {
        PreparedSeries::prepare(s.to_vec(), w)
    }

    #[test]
    fn figure5_value() {
        // Hand-computed LB_Keogh for the running example, w = 1, squared δ.
        // Envelope of B (w=1): U = [1,1,1,1,-1,-1,-1,1,1,1,0], pointwise with
        // L = [-1,-1,-1,-1,-4,-4,-4,-4,-1,-1,-1].
        // A outside: i=3 (4 > 1 → 9), i=5,6 (1 > -1 → 4 each), i=10 (1 > 0 → 1);
        // i=7 sits exactly on the envelope (1 = U_7) and contributes 0.
        let t = prep(&B, 1);
        assert_eq!(t.up, vec![1., 1., 1., 1., -1., -1., -1., 1., 1., 1., 0.]);
        assert_eq!(t.lo, vec![-1., -1., -1., -1., -4., -4., -4., -4., -1., -1., -1.]);
        let lb = lb_keogh::<Squared>(&A, &t, f64::INFINITY);
        assert_eq!(lb, 9.0 + 4.0 + 4.0 + 1.0);
        assert!(lb <= dtw::<Squared>(&A, &B, 1));
    }

    #[test]
    fn zero_when_inside_envelope() {
        let t = prep(&B, 10); // full-width window swallows everything
        let inside: Vec<f64> = vec![0.0; B.len()];
        assert_eq!(lb_keogh::<Squared>(&inside, &t, f64::INFINITY), 0.0);
    }

    #[test]
    fn early_abandon_partial_is_lower_bound() {
        let t = prep(&B, 1);
        let full = lb_keogh::<Squared>(&A, &t, f64::INFINITY);
        let part = lb_keogh::<Squared>(&A, &t, 5.0);
        assert!(part > 5.0, "must exceed the abandon threshold");
        assert!(part <= full, "partial sum can never exceed the full bound");
    }

    #[test]
    fn projection_variant_matches_and_fills_clip() {
        let t = prep(&B, 1);
        let mut proj = Vec::new();
        let via_proj = lb_keogh_bridge_proj::<Squared>(
            &A, &t.lo, &t.up, 0, A.len(), 0.0, f64::INFINITY, &mut proj,
        );
        assert_eq!(via_proj, lb_keogh::<Squared>(&A, &t, f64::INFINITY));
        for i in 0..A.len() {
            assert!(proj[i] >= t.lo[i] && proj[i] <= t.up[i]);
            if A[i] >= t.lo[i] && A[i] <= t.up[i] {
                assert_eq!(proj[i], A[i]);
            }
        }
    }

    #[test]
    fn lower_bound_on_random_pairs() {
        let mut rng = Rng::seeded(301);
        for _ in 0..200 {
            let n = rng.int_range(6, 80);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.below(n);
            let t = prep(&b, w);
            let lb = lb_keogh::<Squared>(&a, &t, f64::INFINITY);
            let d = dtw::<Squared>(&a, &b, w);
            assert!(lb <= d + 1e-9, "n={n} w={w} lb={lb} dtw={d}");
            let lb1 = lb_keogh::<Absolute>(&a, &t, f64::INFINITY);
            let d1 = dtw::<Absolute>(&a, &b, w);
            assert!(lb1 <= d1 + 1e-9);
        }
    }

    #[test]
    fn tail_suffix_sums_match_full_bound() {
        let mut rng = Rng::seeded(515);
        for _ in 0..100 {
            let n = rng.int_range(4, 60);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.below(n);
            let t = prep(&b, w);
            let mut tail = Vec::new();
            let total = lb_keogh_tail::<Squared>(&a, &t.lo, &t.up, &mut tail);
            assert_eq!(tail.len(), n + 1);
            assert_eq!(tail[n], 0.0);
            assert_eq!(tail[0], total);
            assert_eq!(total, lb_keogh::<Squared>(&a, &t, f64::INFINITY));
            // Suffix sums are nonincreasing with nonnegative increments.
            for i in 0..n {
                assert!(tail[i] >= tail[i + 1]);
            }
        }
    }

    #[test]
    fn flat_kernel_matches_lane_protocol_reference_bitwise() {
        let mut rng = Rng::seeded(516);
        for &n in &[1usize, 3, 4, 5, 8, 17, 64, 129] {
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let t = prep(&b, 2.min(n - 1));
            // The flat kernel (whatever ISA was dispatched) is pinned
            // bit-for-bit to the scalar lane-protocol reference; the
            // sequential bridge agrees up to float reassociation.
            let full = lb_keogh_flat::<Squared>(&a, &t.lo, &t.up, f64::INFINITY);
            let reference = crate::simd::scalar::keogh_sum::<Squared>(&a, &t.lo, &t.up);
            assert_eq!(full.to_bits(), reference.to_bits(), "n={n}");
            let bridge = lb_keogh_bridge::<Squared>(&a, &t.lo, &t.up, 0, n, 0.0, f64::INFINITY);
            assert!((full - bridge).abs() <= 1e-9 * (1.0 + bridge.abs()), "n={n}");
            // Abandoned partials stay valid lower bounds above the cutoff.
            if full > 0.0 {
                let cut = full * 0.25;
                let part = lb_keogh_flat::<Squared>(&a, &t.lo, &t.up, cut);
                let part_ref = crate::simd::scalar::keogh_ea::<Squared>(&a, &t.lo, &t.up, cut);
                assert_eq!(part.to_bits(), part_ref.to_bits(), "n={n}");
                assert!(part <= full + 1e-12);
                if part < full {
                    assert!(part > cut);
                }
            }
        }
    }

    #[test]
    fn tightness_nonincreasing_in_window() {
        // Wider window → looser envelope → smaller bound.
        let mut rng = Rng::seeded(302);
        let n = 64;
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut last = f64::INFINITY;
        for w in 0..n {
            let t = prep(&b, w);
            let lb = lb_keogh::<Squared>(&a, &t, f64::INFINITY);
            assert!(lb <= last + 1e-12);
            last = lb;
        }
    }
}
