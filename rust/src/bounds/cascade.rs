//! Cascading lower bounds (paper §8).
//!
//! Rakthanmanon & Keogh's UCR suite cascades `LB_KIM` → `LB_KEOGH` →
//! reversed `LB_KEOGH`. The paper observes that `LB_WEBB` decomposes into
//! the same kind of anytime cascade: constant-time left/right paths, then
//! the `LB_KEOGH` bridge, then the final Webb pass — each stage starting
//! from the previous stage's value, abandoning the moment the accumulated
//! bound clears the pruning threshold.
//!
//! [`lb_cascade`] implements that: a constant-time `LB_KIM_FL` screen
//! first (it is *not* part of `MinLRPaths`' path terms, but shares the
//! endpoint deltas, so we use it purely as a cheap pre-test), then full
//! `LB_WEBB` with early abandoning carrying the threshold through every
//! stage.

use crate::delta::Delta;

use super::{improved, kim, webb, PreparedSeries, Scratch};

/// Staged `KimFL → LB_WEBB` cascade. Semantics match `LB_WEBB` exactly
/// when not abandoned; with a finite `abandon_at` it often exits after the
/// two-element Kim test.
pub fn lb_cascade<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    let kim = kim::lb_kim_fl::<D>(&q.values, &t.values);
    if kim > abandon_at {
        return kim;
    }
    // Max of two valid lower bounds is a valid lower bound; on very short
    // or endpoint-divergent series KimFL can exceed LB_WEBB.
    webb::lb_webb::<D>(q, t, w, abandon_at, scratch).max(kim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::delta::Squared;
    use crate::dtw::dtw;

    fn prep(s: &[f64], w: usize) -> PreparedSeries {
        PreparedSeries::prepare(s.to_vec(), w)
    }

    #[test]
    fn equals_webb_when_not_abandoned() {
        let mut rng = Rng::seeded(901);
        let mut scratch = Scratch::default();
        for _ in 0..100 {
            let n = rng.int_range(8, 60);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.int_range(1, n - 1);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let c = lb_cascade::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            let wb = webb::lb_webb::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert_eq!(c, wb);
            assert!(c <= dtw::<Squared>(&a, &b, w) + 1e-9);
        }
    }

    #[test]
    fn improved_cascade_equals_improved_when_not_abandoned() {
        let mut rng = Rng::seeded(902);
        let mut scratch = Scratch::default();
        for _ in 0..100 {
            let n = rng.int_range(8, 60);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.int_range(1, n - 1);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let c = lb_improved_cascade::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            let imp =
                crate::bounds::improved::lb_improved::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(c >= imp, "cascade is the max of its stages");
            assert!(c <= dtw::<Squared>(&a, &b, w) + 1e-9);
        }
    }

    #[test]
    fn improved_cascade_kim_stage_short_circuits() {
        let a: Vec<f64> = vec![100.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, -100.0];
        let b: Vec<f64> = vec![-100.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 100.0];
        let q = prep(&a, 1);
        let t = prep(&b, 1);
        let mut scratch = Scratch::default();
        let c = lb_improved_cascade::<Squared>(&q, &t, 1, 1.0, &mut scratch);
        assert_eq!(c, 200.0 * 200.0 * 2.0); // exactly the Kim value
    }

    #[test]
    fn kim_stage_short_circuits() {
        // Wildly different endpoints: the Kim stage alone must clear a
        // small threshold.
        let a: Vec<f64> = vec![100.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, -100.0];
        let b: Vec<f64> = vec![-100.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 100.0];
        let q = prep(&a, 1);
        let t = prep(&b, 1);
        let mut scratch = Scratch::default();
        let c = lb_cascade::<Squared>(&q, &t, 1, 1.0, &mut scratch);
        assert_eq!(c, 200.0 * 200.0 * 2.0); // exactly the Kim value
    }
}

/// Staged `KimFL → LB_IMPROVED` cascade — Lemire's two-pass retrieval
/// discipline (arXiv 0811.3301) as an anytime cascade, with every
/// summing stage on the SIMD vtable. The constant-time Kim screen runs
/// first; survivors pay the vectorised `LB_KEOGH` first pass
/// ([`super::keogh::lb_keogh_flat`], the pass that dominates
/// sequential-search wall-clock and which SIMD accelerates most); only
/// candidates still under the threshold pay the projection-envelope
/// second pass — itself the same vectorised flat kernel, threaded
/// through [`improved::lb_improved`]'s combined abandon logic.
/// Returns the max of the stages reached (the max of valid lower
/// bounds is a valid lower bound).
pub fn lb_improved_cascade<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    let kim = kim::lb_kim_fl::<D>(&q.values, &t.values);
    if kim > abandon_at {
        return kim;
    }
    improved::lb_improved::<D>(q, t, w, abandon_at, scratch).max(kim)
}

/// The UCR-suite cascade (Rakthanmanon & Keogh 2013): constant-time
/// `LB_KIM_FL`, then `LB_KEOGH(A,B)`, then — only when still below the
/// threshold — `LB_KEOGH(B,A)`. Returns the max of the stages reached.
pub fn lb_ucr_cascade<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    abandon_at: f64,
) -> f64 {
    let kim = kim::lb_kim_fl::<D>(&q.values, &t.values);
    if kim > abandon_at {
        return kim;
    }
    let fwd = super::keogh::lb_keogh::<D>(&q.values, t, abandon_at).max(kim);
    if fwd > abandon_at {
        return fwd;
    }
    super::keogh::lb_keogh_reversed::<D>(q, t, abandon_at).max(fwd)
}
