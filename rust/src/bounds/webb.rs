//! `LB_WEBB` and variants (paper §5, Theorem 2, Algorithm 2).
//!
//! `LB_WEBB` approximates `LB_PETITJEAN` **without the per-pair projection
//! envelope** — the whole point. Two ingredients replace it:
//!
//! * **Envelopes of envelopes**, `𝕌^{𝕃^B}` and `𝕃^{𝕌^B}`, which are
//!   properties of the candidate alone and thus precomputable offline
//!   (they live in [`PreparedSeries`]).
//! * **Freeness flags**: `B_j` is *free above* `𝕌^A` when no `A_i` in its
//!   window projects Keogh allowance above `𝕃_i^{𝕌^A}`; then the full
//!   `δ(B_j, 𝕌_j^A)` can be added without double counting. Mirrored for
//!   *free below*.
//!
//! We implement the freeness test exactly as defined for Theorem 2 (a
//! position `i` blocks `F↑` when `A_i > 𝕌_i^B`, or when `A_i < 𝕃_i^B` with
//! `𝕃_i^B > 𝕃_i^{𝕌^A}`), using prefix sums of blocking positions so each
//! `F↑(j)`/`F↓(j)` query is O(1) and the whole bound stays `O(ℓ)` with no
//! dependence on `w`. Algorithm 2's run-length counters realize a slightly
//! more permissive test; the definition-faithful version keeps the
//! invariant `LB ≤ DTW` unconditionally provable, and the cost difference
//! is one branch per element (measured in `benches/bound_micro.rs`).
//!
//! Variants:
//! * [`lb_webb_nolr`] — ablation without `MinLRPaths` (§7).
//! * [`lb_webb_star`] — `LB_WEBB*` (§5.1): adds distance to the
//!   envelope-of-envelope itself instead of the double-distance
//!   correction; valid for any δ monotone in `|a−b|` with the point
//!   triangle property.
//! * [`lb_webb_enhanced`] — `LB_WEBB_ENHANCED^k` (§5.2): left/right
//!   *bands* in place of the length-3 paths, for large-window regimes.

use crate::delta::Delta;

use super::{bands, lr_paths, PreparedSeries, Scratch};

/// `LB_WEBB_w(A, B)` with early abandoning.
///
/// Falls back to [`lb_webb_nolr`] for `ℓ < 8` where the paper's bridge
/// range `4 ≤ i ≤ ℓ-3` would be degenerate.
pub fn lb_webb<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    let n = q.len();
    if n < 8 {
        return lb_webb_nolr::<D>(q, t, w, abandon_at, scratch);
    }
    let acc = lr_paths::min_lr_paths::<D>(&q.values, &t.values, w);
    if acc > abandon_at {
        return acc;
    }
    webb_core::<D, false>(q, t, w, 3, n - 3, acc, abandon_at, scratch)
}

/// `LB_WEBB_NoLR` — the §7 ablation: no left/right paths, bridge over the
/// whole series.
pub fn lb_webb_nolr<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    webb_core::<D, false>(q, t, w, 0, q.len(), 0.0, abandon_at, scratch)
}

/// `LB_WEBB*` (§5.1) — distances to the envelope-of-envelope in place of
/// the double-distance correction. Sound for δ monotone in `|a−b|` with
/// the point-triangle property (the class of `LB_IMPROVED`).
pub fn lb_webb_star<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    let n = q.len();
    if n < 8 {
        return webb_core::<D, true>(q, t, w, 0, n, 0.0, abandon_at, scratch);
    }
    let acc = lr_paths::min_lr_paths::<D>(&q.values, &t.values, w);
    if acc > abandon_at {
        return acc;
    }
    webb_core::<D, true>(q, t, w, 3, n - 3, acc, abandon_at, scratch)
}

/// `LB_WEBB_ENHANCED^k` (§5.2) — `LB_ENHANCED`'s left/right bands, then
/// the Webb pass over the bridge. Always at least as tight as
/// `LB_ENHANCED^k`.
pub fn lb_webb_enhanced<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    k: usize,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    let n = q.len();
    let k = k.min(n / 2);
    let acc = bands::band_ends_sum::<D>(&q.values, &t.values, k, w);
    if acc > abandon_at {
        return acc;
    }
    webb_core::<D, false>(q, t, w, k, n - k, acc, abandon_at, scratch)
}

/// Shared Webb core over bridge range `[lo, hi)`.
///
/// Pass 1: Keogh bridge on `A` vs `env(B)` while marking *blocking*
/// positions for the freeness flags. Pass 2: the Theorem 2 case analysis
/// for each `B_j`. `STAR` selects the `LB_WEBB*` allowances.
#[allow(clippy::too_many_arguments)]
fn webb_core<D: Delta, const STAR: bool>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    lo: usize,
    hi: usize,
    acc: f64,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    let a = &q.values;
    let b = &t.values;
    let n = a.len();
    debug_assert!(lo <= hi && hi <= n);

    // Prefix counts of blocking positions. pu[i+1]-pu[i] = 1 iff position
    // i prevents "free above" for any j whose window contains i.
    let pu = &mut scratch.block_up;
    let pd = &mut scratch.block_dn;
    pu.clear();
    pd.clear();
    pu.resize(n + 1, 0);
    pd.resize(n + 1, 0);

    // Pass 1: Keogh bridge + blocking flags.
    let mut bound = acc;
    let mut abandoned = false;
    for i in lo..hi {
        let v = a[i];
        let (mut bu, mut bd) = (0u32, 0u32);
        if v > t.up[i] {
            bound += D::delta(v, t.up[i]);
            bu = 1; // allowance reaches up past 𝕌^B — blocks F↑ outright
            if t.up[i] < q.up_of_lo[i] {
                bd = 1; // reaches below 𝕌^{𝕃^A} — blocks F↓
            }
        } else if v < t.lo[i] {
            bound += D::delta(v, t.lo[i]);
            bd = 1;
            if t.lo[i] > q.lo_of_up[i] {
                bu = 1;
            }
        }
        pu[i + 1] = pu[i] + bu;
        pd[i + 1] = pd[i] + bd;
        if bound > abandon_at {
            // Partial sums of non-negative allowances stay valid bounds.
            abandoned = true;
            break;
        }
    }
    if abandoned {
        return bound;
    }
    // (Positions outside [lo, hi) never block: carry prefix sums flat.)
    for i in hi..n {
        pu[i + 1] = pu[i];
        pd[i + 1] = pd[i];
    }

    // Pass 2: allowances for B_j the Keogh bridge could not reach.
    for j in lo..hi {
        let v = b[j];
        // Fast path: every case below requires B_j outside the query
        // envelope (cases 1/2 directly; 3/4 via `ULB ≥ UA` / `LUB ≤ LA`),
        // and most elements are inside — skip the freeness loads for them
        // (§Perf O3 in EXPERIMENTS.md).
        if v <= q.up[j] && v >= q.lo[j] {
            continue;
        }
        let wlo = j.saturating_sub(w);
        let whi = (j + w + 1).min(n);
        let free_up = pu[whi] == pu[wlo];
        let free_dn = pd[whi] == pd[wlo];

        if free_up && v > q.up[j] {
            bound += D::delta(v, q.up[j]);
        } else if free_dn && v < q.lo[j] {
            bound += D::delta(v, q.lo[j]);
        } else if STAR {
            if !free_up && v > t.up_of_lo[j] && t.up_of_lo[j] > q.up[j] {
                bound += D::delta(v, t.up_of_lo[j]);
            } else if !free_dn && v < t.lo_of_up[j] && t.lo_of_up[j] < q.lo[j] {
                bound += D::delta(v, t.lo_of_up[j]);
            }
        } else if v > t.up_of_lo[j] && t.up_of_lo[j] >= q.up[j] {
            // Theorem 2 clause (42): double-distance correction above.
            bound += D::delta(v, q.up[j]) - D::delta(t.up_of_lo[j], q.up[j]);
        } else if v < t.lo_of_up[j] && t.lo_of_up[j] <= q.lo[j] {
            // Clause (41): below.
            bound += D::delta(v, q.lo[j]) - D::delta(t.lo_of_up[j], q.lo[j]);
        }
        if bound > abandon_at {
            return bound;
        }
    }
    bound
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bounds::{enhanced, keogh as keogh_mod};
    use crate::data::rng::Rng;
    use crate::delta::{Absolute, Squared};
    use crate::dtw::dtw;

    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    fn prep(s: &[f64], w: usize) -> PreparedSeries {
        PreparedSeries::prepare(s.to_vec(), w)
    }

    fn random_pair(rng: &mut Rng, n_lo: usize, n_hi: usize) -> (Vec<f64>, Vec<f64>, usize) {
        let n = rng.int_range(n_lo, n_hi);
        let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let w = rng.int_range(0, n - 1);
        (a, b, w)
    }

    #[test]
    fn webb_is_lower_bound() {
        let mut rng = Rng::seeded(801);
        let mut scratch = Scratch::default();
        for _ in 0..400 {
            let (a, b, w) = random_pair(&mut rng, 4, 100);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let d = dtw::<Squared>(&a, &b, w);
            for (name, lb) in [
                ("webb", lb_webb::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch)),
                ("nolr", lb_webb_nolr::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch)),
                ("star", lb_webb_star::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch)),
                ("enh3", lb_webb_enhanced::<Squared>(&q, &t, w, 3, f64::INFINITY, &mut scratch)),
                ("enh8", lb_webb_enhanced::<Squared>(&q, &t, w, 8, f64::INFINITY, &mut scratch)),
            ] {
                assert!(lb <= d + 1e-9, "{name} n={} w={w}: {lb} > {d}", a.len());
            }
            let d1 = dtw::<Absolute>(&a, &b, w);
            let lb1 = lb_webb::<Absolute>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(lb1 <= d1 + 1e-9, "abs");
            let lb1s = lb_webb_star::<Absolute>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(lb1s <= d1 + 1e-9, "abs star");
        }
    }

    #[test]
    fn webb_nolr_always_at_least_keogh() {
        // Provable pointwise: LB_WEBB_NoLR = LB_KEOGH + non-negative
        // second-pass allowances.
        let mut rng = Rng::seeded(802);
        let mut scratch = Scratch::default();
        for _ in 0..400 {
            let (a, b, w) = random_pair(&mut rng, 8, 90);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let k = keogh_mod::lb_keogh::<Squared>(&a, &t, f64::INFINITY);
            let webb = lb_webb_nolr::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(webb >= k - 1e-9, "n={} w={w}: webb_nolr {webb} < keogh {k}", a.len());
        }
    }

    #[test]
    fn webb_usually_at_least_keogh() {
        // §5 claims "always tighter than LB_KEOGH"; with the LR paths
        // replacing the six end Keogh terms this is not pointwise-provable
        // on adversarial noise (MinLRPaths can dip below them), but it
        // holds overwhelmingly and on every dataset average — mirror that.
        let mut rng = Rng::seeded(812);
        let mut scratch = Scratch::default();
        let (mut wins, mut total) = (0usize, 0usize);
        let (mut webb_sum, mut keogh_sum) = (0.0, 0.0);
        for _ in 0..400 {
            let (a, b, w) = random_pair(&mut rng, 8, 90);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let k = keogh_mod::lb_keogh::<Squared>(&a, &t, f64::INFINITY);
            let webb = lb_webb::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            total += 1;
            if webb >= k - 1e-9 {
                wins += 1;
            }
            webb_sum += webb;
            keogh_sum += k;
        }
        assert!(wins * 100 >= total * 95, "webb >= keogh only {wins}/{total}");
        assert!(webb_sum > keogh_sum, "webb not tighter on aggregate");
    }

    #[test]
    fn webb_enhanced_at_least_enhanced_same_k() {
        // §5.2 / abstract: "LB_WEBB_ENHANCED is always tighter than LB_ENHANCED."
        let mut rng = Rng::seeded(803);
        let mut scratch = Scratch::default();
        for _ in 0..300 {
            let (a, b, w) = random_pair(&mut rng, 6, 80);
            let q = prep(&a, w);
            let t = prep(&b, w);
            for k in [1usize, 3, 8] {
                let e = enhanced::lb_enhanced::<Squared>(&a, &t, w, k, f64::INFINITY);
                let we =
                    lb_webb_enhanced::<Squared>(&q, &t, w, k, f64::INFINITY, &mut scratch);
                assert!(we >= e - 1e-9, "k={k} n={} w={w}: {we} < {e}", a.len());
            }
        }
    }

    #[test]
    fn star_never_tighter_than_webb_under_squared() {
        // The double-distance correction dominates the plain envelope
        // distance when both apply, so LB_WEBB* ≤ LB_WEBB for squared δ.
        let mut rng = Rng::seeded(804);
        let mut scratch = Scratch::default();
        for _ in 0..300 {
            let (a, b, w) = random_pair(&mut rng, 8, 80);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let webb = lb_webb::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            let star = lb_webb_star::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(star <= webb + 1e-9, "n={} w={w}: star {star} > webb {webb}", a.len());
        }
    }

    #[test]
    fn running_example_webb_vs_keogh() {
        let mut scratch = Scratch::default();
        let q = prep(&A, 1);
        let t = prep(&B, 1);
        let keogh = keogh_mod::lb_keogh::<Squared>(&A, &t, f64::INFINITY);
        let webb = lb_webb::<Squared>(&q, &t, 1, f64::INFINITY, &mut scratch);
        assert!(webb > keogh, "webb {webb} should beat keogh {keogh} here (Figure 14)");
        assert!(webb <= 52.0);
    }

    #[test]
    fn zero_on_identical() {
        let mut scratch = Scratch::default();
        let q = prep(&A, 2);
        assert_eq!(lb_webb::<Squared>(&q, &q, 2, f64::INFINITY, &mut scratch), 0.0);
        assert_eq!(lb_webb_star::<Squared>(&q, &q, 2, f64::INFINITY, &mut scratch), 0.0);
    }

    #[test]
    fn abandon_partial_is_valid() {
        let mut scratch = Scratch::default();
        let q = prep(&A, 1);
        let t = prep(&B, 1);
        let full = lb_webb::<Squared>(&q, &t, 1, f64::INFINITY, &mut scratch);
        for cut in [0.5, 4.0, 12.0, 30.0] {
            let part = lb_webb::<Squared>(&q, &t, 1, cut, &mut scratch);
            if part > cut {
                assert!(part <= full + 1e-12);
            } else {
                assert!((part - full).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn freeness_flags_match_naive_definition() {
        // Cross-check the prefix-sum freeness against a direct evaluation
        // of the Theorem 2 definition.
        let mut rng = Rng::seeded(805);
        for _ in 0..60 {
            let n = rng.int_range(8, 50);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.int_range(1, n - 1);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let (lo, hi) = (3usize, n - 3);

            // Naive freeness.
            let naive_free_up = |j: usize| -> bool {
                (lo..hi)
                    .filter(|&i| i + w >= j && i <= j + w)
                    .all(|i| {
                        let inside = a[i] >= t.lo[i] && a[i] <= t.up[i];
                        inside || (a[i] < t.lo[i] && t.lo[i] <= q.lo_of_up[i])
                    })
            };
            let naive_free_dn = |j: usize| -> bool {
                (lo..hi)
                    .filter(|&i| i + w >= j && i <= j + w)
                    .all(|i| {
                        let inside = a[i] >= t.lo[i] && a[i] <= t.up[i];
                        inside || (a[i] > t.up[i] && t.up[i] >= q.up_of_lo[i])
                    })
            };

            // Recompute the prefix arrays the same way webb_core does.
            let mut pu = vec![0u32; n + 1];
            let mut pd = vec![0u32; n + 1];
            for i in 0..n {
                let (mut bu, mut bd) = (0u32, 0u32);
                if (lo..hi).contains(&i) {
                    if a[i] > t.up[i] {
                        bu = 1;
                        if t.up[i] < q.up_of_lo[i] {
                            bd = 1;
                        }
                    } else if a[i] < t.lo[i] {
                        bd = 1;
                        if t.lo[i] > q.lo_of_up[i] {
                            bu = 1;
                        }
                    }
                }
                pu[i + 1] = pu[i] + bu;
                pd[i + 1] = pd[i] + bd;
            }
            for j in lo..hi {
                let wlo = j.saturating_sub(w);
                let whi = (j + w + 1).min(n);
                assert_eq!(pu[whi] == pu[wlo], naive_free_up(j), "F_up j={j} n={n} w={w}");
                assert_eq!(pd[whi] == pd[wlo], naive_free_dn(j), "F_dn j={j} n={n} w={w}");
            }
        }
    }
}
