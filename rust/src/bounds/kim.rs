//! `LB_KIM` — the constant-time bound (Kim, Park & Chu 2001).
//!
//! We implement the windowed-safe *first/last* form used throughout the
//! modern literature (e.g. the UCR suite): the boundary conditions force
//! `A_1 ↔ B_1` and `A_ℓ ↔ B_ℓ` into **every** warping path, so
//!
//! ```text
//! LB_KimFL(A, B) = δ(A_1, B_1) + δ(A_ℓ, B_ℓ) ≤ DTW_w(A, B)
//! ```
//!
//! for any window and any δ monotone in `|a-b|` (in fact for any
//! non-negative δ). The original LB_Kim also compared global min/max
//! features, which is not sound under windowing for arbitrary δ and adds
//! little under z-normalization, so the FL form is what cascades use
//! (Rakthanmanon & Keogh 2013 — cited in §8 of the paper).

use crate::delta::Delta;

/// Constant-time first/last lower bound.
#[inline]
pub fn lb_kim_fl<D: Delta>(a: &[f64], b: &[f64]) -> f64 {
    debug_assert!(!a.is_empty() && !b.is_empty());
    if a.len() == 1 && b.len() == 1 {
        // A single alignment: first and last coincide.
        return D::delta(a[0], b[0]);
    }
    D::delta(a[0], b[0]) + D::delta(a[a.len() - 1], b[b.len() - 1])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{Absolute, Squared};
    use crate::dtw::dtw;

    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    #[test]
    fn figure3_values() {
        // δ(A1,B1) = (-1-1)^2 = 4, δ(A11,B11) = (1-(-1))^2 = 4.
        assert_eq!(lb_kim_fl::<Squared>(&A, &B), 8.0);
        assert_eq!(lb_kim_fl::<Absolute>(&A, &B), 4.0);
    }

    #[test]
    fn is_lower_bound_at_every_window() {
        for w in 0..A.len() {
            assert!(lb_kim_fl::<Squared>(&A, &B) <= dtw::<Squared>(&A, &B, w));
            assert!(lb_kim_fl::<Absolute>(&A, &B) <= dtw::<Absolute>(&A, &B, w));
        }
    }

    #[test]
    fn zero_on_identical() {
        assert_eq!(lb_kim_fl::<Squared>(&A, &A), 0.0);
    }
}
