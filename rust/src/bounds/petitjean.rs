//! `LB_PETITJEAN` (paper §4, Theorem 1, Algorithm 1) — to the authors'
//! knowledge the tightest DTW lower bound with `O(ℓ)` time and `O(1)`
//! dependence on window size.
//!
//! Two strengthenings over `LB_IMPROVED`:
//!
//! 1. **Double-distance correction.** Where `LB_IMPROVED` adds
//!    `δ(B_j, 𝕌_j^Ω)` for a `B_j` above the projection envelope,
//!    `LB_PETITJEAN` adds the larger `δ(B_j, 𝕌_j^A) − δ(𝕌_j^Ω, 𝕌_j^A)`
//!    whenever `𝕌_j^Ω > 𝕌_j^A`: the aligned `A_i` can be no further than
//!    `𝕌_j^A`, and at most `δ(𝕌_j^A, 𝕌_j^Ω)`-worth of that gap was already
//!    credited by the Keogh pass (Observations 1–2 rule out double
//!    counting). Requires δ's triangle-adjustment property.
//! 2. **Left/right paths** — `MinLRPaths` over the constrained first/last
//!    three alignments (see [`super::lr_paths`]), replacing the Keogh terms
//!    for `i ≤ 3 ∨ i ≥ ℓ-2`.
//!
//! The *cost*: like `LB_IMPROVED` it must build the envelope of the
//! projection for every pair — that is the overhead `LB_WEBB` removes.

use crate::delta::Delta;

use super::{envelope, keogh, lr_paths, PreparedSeries, Scratch};

/// `LB_PETITJEAN_w(A, B)` with early abandoning (paper Algorithm 1).
///
/// Falls back to [`lb_petitjean_nolr`] for `ℓ < 8`, where the paper's
/// `4 ≤ i ≤ ℓ-3` bridge would be degenerate.
pub fn lb_petitjean<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    let n = q.len();
    if n < 8 {
        return lb_petitjean_nolr::<D>(q, t, w, abandon_at, scratch);
    }
    let acc = lr_paths::min_lr_paths::<D>(&q.values, &t.values, w);
    if acc > abandon_at {
        return acc;
    }
    petitjean_core::<D>(q, t, w, 3, n - 3, acc, abandon_at, scratch)
}

/// `LB_PETITJEAN_NoLR` — the ablation without left/right paths (paper §4).
/// Bridges the whole series; always at least as tight as `LB_IMPROVED`.
pub fn lb_petitjean_nolr<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    petitjean_core::<D>(q, t, w, 0, q.len(), 0.0, abandon_at, scratch)
}

/// Shared core: Keogh bridge over `[lo, hi)` (with full-series projection),
/// then the four-case second pass of Theorem 1 over the same range.
#[allow(clippy::too_many_arguments)]
fn petitjean_core<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    lo: usize,
    hi: usize,
    acc: f64,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    let a = &q.values;
    let b = &t.values;

    // Bridge + projection Ω (projection is defined over the full series —
    // the envelope of Ω read at j near the bridge edges depends on it).
    let mut bound = keogh::lb_keogh_bridge_proj::<D>(
        a, &t.lo, &t.up, lo, hi, acc, abandon_at, &mut scratch.proj,
    );
    if bound > abandon_at {
        return bound;
    }

    // Envelope of the projection — the per-pair O(l) overhead.
    envelope::envelopes_into(&scratch.proj, w, &mut scratch.proj_lo, &mut scratch.proj_up);

    let (up_a, lo_a) = (&q.up, &q.lo);
    let (up_p, lo_p) = (&scratch.proj_up, &scratch.proj_lo);
    for j in lo..hi {
        let v = b[j];
        if v > up_p[j] {
            bound += if up_p[j] > up_a[j] {
                // Theorem 1 case (20): B_j beyond both envelopes.
                D::delta(v, up_a[j]) - D::delta(up_p[j], up_a[j])
            } else {
                // Case (22): classic Improved-style allowance.
                D::delta(v, up_p[j])
            };
        } else if v < lo_p[j] {
            bound += if lo_p[j] < lo_a[j] {
                // Case (21).
                D::delta(v, lo_a[j]) - D::delta(lo_p[j], lo_a[j])
            } else {
                // Case (23).
                D::delta(v, lo_p[j])
            };
        }
        if bound > abandon_at {
            return bound;
        }
    }
    bound
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::delta::{Absolute, Squared};
    use crate::dtw::dtw;

    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    fn prep(s: &[f64], w: usize) -> PreparedSeries {
        PreparedSeries::prepare(s.to_vec(), w)
    }

    #[test]
    fn is_lower_bound_on_random_pairs() {
        let mut rng = Rng::seeded(701);
        let mut scratch = Scratch::default();
        for _ in 0..300 {
            let n = rng.int_range(4, 90);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.int_range(0, n - 1);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let d = dtw::<Squared>(&a, &b, w);
            let lb = lb_petitjean::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(lb <= d + 1e-9, "n={n} w={w}: {lb} > {d}");
            let lb2 = lb_petitjean_nolr::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(lb2 <= d + 1e-9, "NoLR n={n} w={w}: {lb2} > {d}");
            let d1 = dtw::<Absolute>(&a, &b, w);
            let lb1 = lb_petitjean::<Absolute>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(lb1 <= d1 + 1e-9, "abs n={n} w={w}");
        }
    }

    #[test]
    fn nolr_at_least_as_tight_as_improved() {
        // §4: "LB_PETITJEAN_NoLR is tighter than LB_IMPROVED" (≥ pointwise).
        let mut rng = Rng::seeded(702);
        let mut scratch = Scratch::default();
        let mut strictly = 0;
        for _ in 0..300 {
            let n = rng.int_range(6, 70);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.int_range(1, (n - 1).min(10));
            let q = prep(&a, w);
            let t = prep(&b, w);
            let imp = super::super::improved::lb_improved::<Squared>(
                &q, &t, w, f64::INFINITY, &mut scratch,
            );
            let pj = lb_petitjean_nolr::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(pj >= imp - 1e-9, "n={n} w={w}: {pj} < {imp}");
            if pj > imp + 1e-9 {
                strictly += 1;
            }
        }
        assert!(strictly > 20, "double-distance case almost never fired: {strictly}");
    }

    #[test]
    fn running_example_beats_improved() {
        // Figure 12: LB_Petitjean captures strictly more than LB_Improved
        // on the running example.
        let mut scratch = Scratch::default();
        let q = prep(&A, 1);
        let t = prep(&B, 1);
        let imp =
            super::super::improved::lb_improved::<Squared>(&q, &t, 1, f64::INFINITY, &mut scratch);
        let pj = lb_petitjean::<Squared>(&q, &t, 1, f64::INFINITY, &mut scratch);
        assert!(pj > imp, "petitjean {pj} <= improved {imp}");
        assert!(pj <= 52.0);
    }

    #[test]
    fn short_series_fall_back() {
        let mut scratch = Scratch::default();
        for n in 1..8usize {
            let a: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let b: Vec<f64> = (0..n).map(|i| (n - i) as f64).collect();
            let w = 1.min(n - 1);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let lb = lb_petitjean::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(lb <= dtw::<Squared>(&a, &b, w) + 1e-9, "n={n}");
        }
    }

    #[test]
    fn zero_on_identical() {
        let mut scratch = Scratch::default();
        let q = prep(&A, 2);
        assert_eq!(lb_petitjean::<Squared>(&q, &q, 2, f64::INFINITY, &mut scratch), 0.0);
    }

    #[test]
    fn abandon_partial_is_valid() {
        let mut scratch = Scratch::default();
        let q = prep(&A, 1);
        let t = prep(&B, 1);
        let full = lb_petitjean::<Squared>(&q, &t, 1, f64::INFINITY, &mut scratch);
        for cut in [0.5, 4.0, 12.0, 30.0] {
            let part = lb_petitjean::<Squared>(&q, &t, 1, cut, &mut scratch);
            if part > cut {
                assert!(part <= full + 1e-12);
            } else {
                assert!((part - full).abs() < 1e-12);
            }
        }
    }
}
