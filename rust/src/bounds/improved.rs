//! `LB_IMPROVED` (Lemire 2009) — the two-pass envelope bound that
//! `LB_PETITJEAN` tightens and `LB_WEBB` out-runs.
//!
//! Pass 1 is `LB_KEOGH(A, B)`, which as a side effect yields the
//! *projection* `Ω_w(A,B)_i = clip(A_i, 𝕃_i^B, 𝕌_i^B)`. Pass 2 adds
//! `LB_KEOGH(B, Ω)` — distances from `B` to the envelope *of the
//! projection* — capturing mass that the first pass cannot see (paper §3,
//! Figure 6).
//!
//! The per-pair envelope of the projection is exactly the overhead
//! `LB_WEBB` eliminates: it costs another `O(ℓ)` deque sweep on **every**
//! query-candidate pair, where `LB_WEBB`'s envelope-of-envelope terms are
//! precomputable per series.

use crate::delta::Delta;

use super::{envelope, keogh, PreparedSeries, Scratch};

/// `LB_IMPROVED` with early abandoning.
///
/// Both passes run on the runtime-dispatched SIMD vtable
/// ([`crate::simd`]): the projection fill is the vectorised `clamp`
/// kernel (select-form `min(max(A_i, 𝕃_i), 𝕌_i)`, bit-identical to
/// `maxpd`+`minpd` at every ISA) and each pass's sum is
/// [`keogh::lb_keogh_flat`] under the 4-lane accumulator protocol.
/// Only the Lemire deque sweep between the passes stays scalar (its
/// control flow is data-dependent). Results are therefore bit-equal
/// across ISAs; pass 2 abandons once the combined bound crosses
/// `abandon_at`.
pub fn lb_improved<D: Delta>(
    q: &PreparedSeries,
    t: &PreparedSeries,
    w: usize,
    abandon_at: f64,
    scratch: &mut Scratch,
) -> f64 {
    let a = &q.values;
    let b = &t.values;
    let n = a.len();

    // Pass 1: LB_Keogh(A, B), materializing the projection Ω.
    scratch.proj.clear();
    scratch.proj.resize(n, 0.0);
    (crate::simd::kernels().clamp)(a, &t.lo, &t.up, &mut scratch.proj);
    let acc = keogh::lb_keogh_flat::<D>(a, &t.lo, &t.up, abandon_at);
    if acc > abandon_at {
        return acc;
    }

    // Pass 2: LB_Keogh(B, Ω) against the envelope of the projection.
    // `abandon_at - acc` keeps the combined abandon semantics; with
    // `abandon_at = ∞` it stays ∞ and the full-sum kernel runs.
    envelope::envelopes_into(&scratch.proj, w, &mut scratch.proj_lo, &mut scratch.proj_up);
    acc + keogh::lb_keogh_flat::<D>(b, &scratch.proj_lo, &scratch.proj_up, abandon_at - acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::delta::{Absolute, Squared};
    use crate::dtw::dtw;

    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    fn prep(s: &[f64], w: usize) -> PreparedSeries {
        PreparedSeries::prepare(s.to_vec(), w)
    }

    #[test]
    fn at_least_as_tight_as_keogh() {
        let mut rng = Rng::seeded(601);
        let mut scratch = Scratch::default();
        let mut strictly_tighter = 0usize;
        for _ in 0..200 {
            let n = rng.int_range(6, 80);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.int_range(1, (n - 1).min(12));
            let q = prep(&a, w);
            let t = prep(&b, w);
            let k = keogh::lb_keogh::<Squared>(&a, &t, f64::INFINITY);
            let imp = lb_improved::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
            // Pass 1 uses the lane-protocol sum, `lb_keogh` the
            // sequential bridge — same terms, reassociated — so allow
            // a few ulps of slack in the dominance check.
            assert!(imp >= k - 1e-9);
            if imp > k + 1e-9 {
                strictly_tighter += 1;
            }
            assert!(imp <= dtw::<Squared>(&a, &b, w) + 1e-9);
        }
        assert!(strictly_tighter > 50, "second pass almost never fired: {strictly_tighter}");
    }

    #[test]
    fn lower_bound_absolute_delta() {
        let mut rng = Rng::seeded(602);
        let mut scratch = Scratch::default();
        for _ in 0..100 {
            let n = rng.int_range(6, 60);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.int_range(0, n - 1);
            let q = prep(&a, w);
            let t = prep(&b, w);
            let lb = lb_improved::<Absolute>(&q, &t, w, f64::INFINITY, &mut scratch);
            assert!(lb <= dtw::<Absolute>(&a, &b, w) + 1e-9);
        }
    }

    #[test]
    fn running_example_tighter_than_keogh() {
        let mut scratch = Scratch::default();
        let q = prep(&A, 1);
        let t = prep(&B, 1);
        let k = keogh::lb_keogh::<Squared>(&A, &t, f64::INFINITY);
        let imp = lb_improved::<Squared>(&q, &t, 1, f64::INFINITY, &mut scratch);
        assert!(imp > k, "improved {imp} should beat keogh {k} on Figure 6's example");
        assert!(imp <= 52.0);
    }

    #[test]
    fn abandon_partial_is_valid() {
        let mut scratch = Scratch::default();
        let q = prep(&A, 1);
        let t = prep(&B, 1);
        let full = lb_improved::<Squared>(&q, &t, 1, f64::INFINITY, &mut scratch);
        for cut in [1.0, 5.0, 10.0, 20.0] {
            let part = lb_improved::<Squared>(&q, &t, 1, cut, &mut scratch);
            if part > cut {
                assert!(part <= full + 1e-12);
            } else {
                assert!((part - full).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn zero_on_identical() {
        let mut scratch = Scratch::default();
        let q = prep(&A, 2);
        assert_eq!(lb_improved::<Squared>(&q, &q, 2, f64::INFINITY, &mut scratch), 0.0);
    }
}
