//! Left and right *bands* (Tan et al. 2019) — the constant-per-band
//! structures behind `LB_ENHANCED` and `LB_WEBB_ENHANCED`.
//!
//! A band is an L-shaped set of cells through the warping matrix that any
//! warping path must cross at least once, so the minimum cell value of a
//! band — and the sum over any collection of *non-overlapping* bands — is
//! a DTW lower bound (paper Figures 7–9).
//!
//! 0-based: the left band at index `i` covers column `i` for rows
//! `max(0, i-w)..=i` and row `i` for columns `max(0, i-w)..=i`; the right
//! band mirrors toward the high end.

use crate::delta::Delta;

/// Minimum alignment cost over the left band `𝓛_i^w`.
#[inline]
pub fn left_band_min<D: Delta>(a: &[f64], b: &[f64], i: usize, w: usize) -> f64 {
    let lo = i.saturating_sub(w);
    let mut m = f64::INFINITY;
    for r in lo..=i {
        // cells (r, i): A_r aligned with B_i
        let c = D::delta(a[r], b[i]);
        if c < m {
            m = c;
        }
    }
    for c_idx in lo..=i {
        // cells (i, c): A_i aligned with B_c
        let c = D::delta(a[i], b[c_idx]);
        if c < m {
            m = c;
        }
    }
    m
}

/// Minimum alignment cost over the right band `𝓡_i^w`.
#[inline]
pub fn right_band_min<D: Delta>(a: &[f64], b: &[f64], i: usize, w: usize) -> f64 {
    let n = a.len();
    let hi = (i + w).min(n - 1);
    let mut m = f64::INFINITY;
    for r in i..=hi {
        let c = D::delta(a[r], b[i]);
        if c < m {
            m = c;
        }
    }
    for c_idx in i..=hi {
        let c = D::delta(a[i], b[c_idx]);
        if c < m {
            m = c;
        }
    }
    m
}

/// `Σ_{i=0..k-1} [min 𝓛_i^w + min 𝓡_{ℓ-1-i}^w]` — the band contribution
/// shared by `LB_ENHANCED^k` and `LB_WEBB_ENHANCED^k`. `k` must already be
/// clamped to `ℓ/2` by the caller.
pub fn band_ends_sum<D: Delta>(a: &[f64], b: &[f64], k: usize, w: usize) -> f64 {
    let n = a.len();
    let mut s = 0.0;
    for i in 0..k {
        s += left_band_min::<D>(a, b, i, w);
        s += right_band_min::<D>(a, b, n - 1 - i, w);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::Squared;
    use crate::dtw::dtw;

    /// Paper Figures 7 and 8: all-bands sums for the running example.
    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    #[test]
    fn figure7_all_left_bands_sum_to_39() {
        let s: f64 = (0..A.len()).map(|i| left_band_min::<Squared>(&A, &B, i, 1)).sum();
        assert_eq!(s, 39.0);
    }

    #[test]
    fn figure8_all_right_bands_sum_to_36() {
        let s: f64 = (0..A.len()).map(|i| right_band_min::<Squared>(&A, &B, i, 1)).sum();
        assert_eq!(s, 36.0);
    }

    #[test]
    fn all_left_bands_is_lower_bound() {
        for w in 1..4 {
            let s: f64 = (0..A.len()).map(|i| left_band_min::<Squared>(&A, &B, i, w)).sum();
            assert!(s <= dtw::<Squared>(&A, &B, w) + 1e-12);
        }
    }

    #[test]
    fn band_at_zero_is_corner_cell() {
        assert_eq!(left_band_min::<Squared>(&A, &B, 0, 3), (A[0] - B[0]) * (A[0] - B[0]));
        let n = A.len() - 1;
        assert_eq!(
            right_band_min::<Squared>(&A, &B, n, 3),
            (A[n] - B[n]) * (A[n] - B[n])
        );
    }

    #[test]
    fn ends_sum_grows_with_k() {
        let mut last = 0.0;
        for k in 0..=5 {
            let s = band_ends_sum::<Squared>(&A, &B, k, 1);
            assert!(s >= last - 1e-12, "k={k}");
            last = s;
        }
    }
}
