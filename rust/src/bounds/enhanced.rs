//! `LB_ENHANCED^k` (Tan, Petitjean & Webb 2019) — bands at the series ends
//! bridged by `LB_KEOGH` in the middle (paper §3, Figure 9).
//!
//! ```text
//! LB_Enhanced_w^k(A,B) = Σ_{i=1..k} [min 𝓛_i^w + min 𝓡_{ℓ-i+1}^w]
//!                      + Keogh bridge over i = k+1 .. ℓ-k
//! ```
//!
//! `k` trades tightness for time (each band costs `O(w)`); the paper uses
//! `k = 8` as the reference setting and sweeps `k ≤ 16` in §6.2.

use crate::delta::Delta;

use super::{bands, keogh, PreparedSeries};

/// `LB_ENHANCED^k`. `k` is clamped to `ℓ/2`; `k = 0` degenerates to plain
/// `LB_KEOGH`.
pub fn lb_enhanced<D: Delta>(
    a: &[f64],
    t: &PreparedSeries,
    w: usize,
    k: usize,
    abandon_at: f64,
) -> f64 {
    let n = a.len();
    let k = k.min(n / 2);
    let b = bands::band_ends_sum::<D>(a, &t.values, k, w);
    if b > abandon_at {
        return b;
    }
    keogh::lb_keogh_bridge::<D>(a, &t.lo, &t.up, k, n - k, b, abandon_at)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::rng::Rng;
    use crate::delta::Squared;
    use crate::dtw::dtw;

    const A: [f64; 11] = [-1., 1., -1., 4., -2., 1., 1., 1., -1., 0., 1.];
    const B: [f64; 11] = [1., -1., 1., -1., -1., -4., -4., -1., 1., 0., -1.];

    fn prep(s: &[f64], w: usize) -> PreparedSeries {
        PreparedSeries::prepare(s.to_vec(), w)
    }

    #[test]
    fn figure9_enhanced_k2_is_25() {
        let t = prep(&B, 1);
        assert_eq!(lb_enhanced::<Squared>(&A, &t, 1, 2, f64::INFINITY), 25.0);
    }

    #[test]
    fn k0_is_keogh() {
        let t = prep(&B, 1);
        assert_eq!(
            lb_enhanced::<Squared>(&A, &t, 1, 0, f64::INFINITY),
            keogh::lb_keogh::<Squared>(&A, &t, f64::INFINITY)
        );
    }

    #[test]
    fn huge_k_is_clamped() {
        let t = prep(&B, 1);
        let lb = lb_enhanced::<Squared>(&A, &t, 1, 1000, f64::INFINITY);
        assert!(lb.is_finite());
        assert!(lb <= dtw::<Squared>(&A, &B, 1) + 1e-12);
    }

    #[test]
    fn lower_bound_random_all_k() {
        let mut rng = Rng::seeded(501);
        for _ in 0..120 {
            let n = rng.int_range(6, 64);
            let a: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let w = rng.int_range(1, n - 1);
            let d = dtw::<Squared>(&a, &b, w);
            let t = prep(&b, w);
            for k in [0, 1, 2, 4, 8, n / 2] {
                let lb = lb_enhanced::<Squared>(&a, &t, w, k, f64::INFINITY);
                assert!(lb <= d + 1e-9, "n={n} w={w} k={k}: {lb} > {d}");
            }
        }
    }

    #[test]
    fn early_abandon_partial_below_full() {
        let t = prep(&B, 1);
        let full = lb_enhanced::<Squared>(&A, &t, 1, 2, f64::INFINITY);
        let part = lb_enhanced::<Squared>(&A, &t, 1, 2, 3.0);
        assert!(part > 3.0 && part <= full);
    }
}
