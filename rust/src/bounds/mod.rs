//! DTW lower bounds — the paper's contribution and every baseline.
//!
//! | Bound | Module | Paper | Complexity | δ requirement |
//! |---|---|---|---|---|
//! | `LB_KIM_FL` | [`kim`] | Kim et al. 2001 (first/last form) | `O(1)` | monotone |
//! | `LB_KEOGH` | [`keogh`] | Keogh & Ratanamahatana 2005 | `O(ℓ)` | monotone |
//! | `LB_IMPROVED` | [`improved`] | Lemire 2009 | `O(ℓ)` | point-triangle |
//! | `LB_ENHANCED^k` | [`enhanced`] | Tan et al. 2019 | `O(ℓ + k·w)` | monotone |
//! | `LB_PETITJEAN` | [`petitjean`] | **this paper, §4** | `O(ℓ)` | triangle-adjustment |
//! | `LB_WEBB` | [`webb`] | **this paper, §5** | `O(ℓ)` | triangle-adjustment |
//! | `LB_WEBB*` | [`webb`] | **this paper, §5.1** | `O(ℓ)` | point-triangle |
//! | `LB_WEBB_ENHANCED^k` | [`webb`] | **this paper, §5.2** | `O(ℓ + k·w)` | triangle-adjustment |
//! | cascade | [`cascade`] | §8 | staged | as per stages |
//!
//! All bounds are *screening* devices for nearest-neighbor search: they
//! never exceed `DTW_w(A, B)` (the property-test suite enforces this on
//! hundreds of thousands of random pairs), and every one supports **early
//! abandoning** — computation stops as soon as the partial sum exceeds the
//! caller's `abandon_at` threshold, which is sound because each is a sum
//! of non-negative allowances.
//!
//! ## Conventions
//!
//! * Series are 0-based `&[f64]`; the paper's index range `4 ≤ i ≤ ℓ-3`
//!   (1-based) is `3..ℓ-3` here.
//! * In a bound `λ(A, B)`, `A` is the **query** and `B` the **candidate**
//!   (training series). Envelopes of `B` are precomputed once per training
//!   set; envelopes of `A` once per query — both carried by
//!   [`PreparedSeries`].
//! * Bounds are *not* symmetric: `λ(A,B) ≠ λ(B,A)` in general.
//!
//! ## Example
//!
//! Every bound in the family under-estimates windowed DTW:
//!
//! ```
//! use dtw_bounds::bounds::{BoundKind, PreparedSeries, Scratch};
//! use dtw_bounds::delta::Squared;
//! use dtw_bounds::dtw::dtw;
//!
//! let w = 2;
//! let q = PreparedSeries::prepare(vec![0.0, 1.0, 2.0, 1.0, 0.0, -1.0], w);
//! let t = PreparedSeries::prepare(vec![0.5, 1.5, 2.5, 1.5, 0.5, -0.5], w);
//! let d = dtw::<Squared>(&q.values, &t.values, w);
//! let mut scratch = Scratch::new(q.len());
//! for &bound in BoundKind::ALL {
//!     let lb = bound.compute::<Squared>(&q, &t, w, f64::INFINITY, &mut scratch);
//!     assert!(lb <= d + 1e-9, "{bound}: {lb} > {d}");
//! }
//! ```

pub mod bands;
pub mod cascade;
pub mod enhanced;
pub mod envelope;
pub mod improved;
pub mod keogh;
pub mod kim;
pub mod lr_paths;
pub mod petitjean;
pub mod store;
pub mod webb;

use crate::delta::Delta;

/// A series plus every derived envelope the bound family needs, for a
/// specific window `w`:
///
/// * `lo` / `up` — the warping envelopes `𝕃^S`, `𝕌^S`;
/// * `lo_of_up` — `𝕃^{𝕌^S}` (lower envelope *of* the upper envelope);
/// * `up_of_lo` — `𝕌^{𝕃^S}`.
///
/// The envelope-of-envelope pair is what lets `LB_WEBB` skip the per-pair
/// projection that makes `LB_IMPROVED` expensive. Preparation is `O(ℓ)`.
#[derive(Debug, Clone)]
pub struct PreparedSeries {
    /// The raw series values.
    pub values: Vec<f64>,
    /// Window this preparation is valid for.
    pub w: usize,
    /// Lower envelope `𝕃^S`.
    pub lo: Vec<f64>,
    /// Upper envelope `𝕌^S`.
    pub up: Vec<f64>,
    /// `𝕃^{𝕌^S}` — used by `LB_WEBB`'s freeness test and case analysis.
    pub lo_of_up: Vec<f64>,
    /// `𝕌^{𝕃^S}`.
    pub up_of_lo: Vec<f64>,
}

impl PreparedSeries {
    /// Compute all envelopes for window `w`.
    pub fn prepare(values: Vec<f64>, w: usize) -> Self {
        let (lo, up) = envelope::envelopes(&values, w);
        let (lo_of_up, _) = envelope::envelopes(&up, w);
        let (_, up_of_lo) = envelope::envelopes(&lo, w);
        PreparedSeries { values, w, lo, up, lo_of_up, up_of_lo }
    }

    /// Series length ℓ.
    #[inline]
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when the series is empty (never, for prepared data).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// Reusable per-thread buffers so the hot path never allocates.
///
/// `LB_IMPROVED` / `LB_PETITJEAN` need a projection plus its envelopes;
/// `LB_WEBB` needs freeness prefix sums; the pruned exact-DTW kernel
/// needs a cumulative-lower-bound tail. One `Scratch` per search thread.
#[derive(Debug, Default)]
pub struct Scratch {
    /// Projection `Ω_w(A, B)` of the query onto the candidate envelope.
    pub proj: Vec<f64>,
    /// Lower envelope of the projection.
    pub proj_lo: Vec<f64>,
    /// Upper envelope of the projection.
    pub proj_up: Vec<f64>,
    /// Prefix counts of positions blocking "free above" (see `webb`).
    pub block_up: Vec<u32>,
    /// Prefix counts of positions blocking "free below".
    pub block_dn: Vec<u32>,
    /// Suffix-sum `LB_KEOGH` tail for [`crate::dtw::dtw_ea_pruned`]
    /// (filled by [`keogh::lb_keogh_tail`] right before each exact-DTW
    /// call on the search paths).
    pub tail: Vec<f64>,
}

impl Scratch {
    /// Pre-size for series of length `l` (buffers grow on demand anyway).
    pub fn new(l: usize) -> Self {
        Scratch {
            proj: Vec::with_capacity(l),
            proj_lo: Vec::with_capacity(l),
            proj_up: Vec::with_capacity(l),
            block_up: Vec::with_capacity(l + 1),
            block_dn: Vec::with_capacity(l + 1),
            tail: Vec::with_capacity(l + 1),
        }
    }

    /// Buffer capacities
    /// `[proj, proj_lo, proj_up, block_up, block_dn, tail]`.
    ///
    /// Only exists in debug builds, where [`BoundKind::compute`] asserts
    /// that a pre-sized scratch is never reallocated on the hot path;
    /// tests use it to pin the same invariant across whole searches.
    #[cfg(debug_assertions)]
    pub fn capacities(&self) -> [usize; 6] {
        [
            self.proj.capacity(),
            self.proj_lo.capacity(),
            self.proj_up.capacity(),
            self.block_up.capacity(),
            self.block_dn.capacity(),
            self.tail.capacity(),
        ]
    }
}

/// Dynamically-selectable lower bound. Experiment drivers and the CLI
/// hold a `BoundKind`; the hot loops call [`BoundKind::compute`] which
/// dispatches once to the monomorphized kernels.
///
/// ## Choosing a bound (tightness vs. cost, per the paper's §6)
///
/// Tightness is the mean `λ_w/DTW_w` ratio (higher prunes more); cost is
/// per query × candidate pair *after* the usual preparations (candidate
/// envelopes per training set, query envelopes per query). The
/// cells/sec column names each bound's historical per-screen record;
/// measured throughput on the current hardware lives in the
/// `dtw-bench` report (`dtw-bench run`, see docs/benchmarks.md) —
/// absolute numbers are machine-specific, so the report carries them,
/// not this table.
///
/// | Kind | Tightness | Per-pair cost | cells/sec record | Reach for it when |
/// |---|---|---|---|---|
/// | [`KimFL`](BoundKind::KimFL) | lowest | `O(1)` | `LB_KimFL` | as a cascade front stage; endpoint-divergent data |
/// | [`Keogh`](BoundKind::Keogh) | baseline | one `O(ℓ)` pass | `LB_Keogh` | candidate envelopes are all you have (batched backends) |
/// | [`Improved`](BoundKind::Improved) | > Keogh | `O(ℓ)` + per-pair projection envelopes | `LB_Improved` | random-order search at moderate windows |
/// | [`Enhanced`](BoundKind::Enhanced)`^k` | tunable with `k` | `O(ℓ + k·w)` | `LB_Enhanced8` | small windows, `k ≈ 3–8` (Tan et al.'s sweet spot) |
/// | [`Petitjean`](BoundKind::Petitjean) | tightest `O(ℓ)` known | highest constant (projection + its envelopes) | `LB_Petitjean` | Algorithm 3 (early abandoning pays for tightness) |
/// | [`Webb`](BoundKind::Webb) | ≈ Petitjean | lowest constant (envelopes-of-envelopes, no per-pair projection) | `LB_Webb` | Algorithm 4 / sorted screening — **the default** |
/// | [`WebbStar`](BoundKind::WebbStar) | slightly ≤ Webb | like Webb | `LB_Webb*` | δ lacks the triangle-adjustment property |
/// | [`WebbEnhanced`](BoundKind::WebbEnhanced)`^k` | ≥ Webb | `O(ℓ + k·w)` | `LB_Webb_Enhanced3` | banded refinement at small windows |
/// | [`Cascade`](BoundKind::Cascade) | = Webb when run to completion | anytime (`KimFL` first) | `LB_Cascade` | thresholded screening — streams and monitors |
/// | [`ImprovedCascade`](BoundKind::ImprovedCascade) | = Improved when run to completion | anytime (`KimFL` first) | `LB_ImprovedCascade` | vector-heavy hosts: both summing passes ride the SIMD vtable |
/// | [`UcrCascade`](BoundKind::UcrCascade) | Keogh-class | anytime | `LB_UcrCascade` | UCR-suite parity baselines |
///
/// Per-pair cost is ISA-sensitive: the `O(ℓ)` summing passes of
/// `Keogh`, `Improved`, `ImprovedCascade`, `KeoghRev` and the cascades
/// run on the runtime-dispatched SIMD vtable ([`crate::simd`]), so
/// their constants shrink on AVX2/NEON hosts while every ranking
/// stays bit-identical to scalar — re-measure with the `kernel`
/// scenario of `dtw-bench` before trading tightness for cost.
///
/// The ablation kinds (`*NoLr`) exist for §7's experiments, and
/// [`KeoghRev`](BoundKind::KeoghRev) is the reversed-role `LB_KEOGH`
/// used inside [`UcrCascade`](BoundKind::UcrCascade).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BoundKind {
    /// Constant-time first/last bound (`LB_KIM` in its windowed-safe form).
    KimFL,
    /// `LB_KEOGH`.
    Keogh,
    /// `LB_IMPROVED` (Lemire).
    Improved,
    /// `LB_ENHANCED^k` (Tan et al.); the payload is `k`.
    Enhanced(usize),
    /// `LB_PETITJEAN` — tightest known in the `O(ℓ)` class.
    Petitjean,
    /// `LB_PETITJEAN` without the left/right paths (ablation; always ≥ `LB_IMPROVED`).
    PetitjeanNoLr,
    /// `LB_WEBB` — the paper's efficiency/tightness sweet spot.
    Webb,
    /// `LB_WEBB` without the left/right paths (ablation).
    WebbNoLr,
    /// `LB_WEBB*` — valid for any δ monotone in `|a-b|` with the point
    /// triangle property.
    WebbStar,
    /// `LB_WEBB_ENHANCED^k` — left/right *bands* instead of paths.
    WebbEnhanced(usize),
    /// §8 cascade: `KimFL` → full `LB_WEBB` with early abandoning.
    Cascade,
    /// Lemire-style retrieval cascade: `KimFL` → `LB_IMPROVED`, both
    /// summing passes on the SIMD vtable (see [`cascade::lb_improved_cascade`]).
    ImprovedCascade,
    /// `LB_KEOGH` with the series roles reversed (§8).
    KeoghRev,
    /// The UCR-suite cascade (Rakthanmanon & Keogh 2013, cited in §8):
    /// `KimFL` → `LB_KEOGH` → reversed `LB_KEOGH`, taking the max.
    UcrCascade,
}

impl BoundKind {
    /// All kinds the experiment suite iterates over (Enhanced/WebbEnhanced
    /// are instantiated at the paper's reference `k`).
    pub const ALL: &'static [BoundKind] = &[
        BoundKind::KimFL,
        BoundKind::Keogh,
        BoundKind::Improved,
        BoundKind::Enhanced(8),
        BoundKind::Petitjean,
        BoundKind::PetitjeanNoLr,
        BoundKind::Webb,
        BoundKind::WebbNoLr,
        BoundKind::WebbStar,
        BoundKind::WebbEnhanced(3),
        BoundKind::Cascade,
        BoundKind::ImprovedCascade,
        BoundKind::KeoghRev,
        BoundKind::UcrCascade,
    ];

    /// Canonical display name (matches the paper's typography, ASCII-ized).
    pub fn name(&self) -> String {
        match self {
            BoundKind::KimFL => "LB_KimFL".into(),
            BoundKind::Keogh => "LB_Keogh".into(),
            BoundKind::Improved => "LB_Improved".into(),
            BoundKind::Enhanced(k) => format!("LB_Enhanced{k}"),
            BoundKind::Petitjean => "LB_Petitjean".into(),
            BoundKind::PetitjeanNoLr => "LB_Petitjean_NoLR".into(),
            BoundKind::Webb => "LB_Webb".into(),
            BoundKind::WebbNoLr => "LB_Webb_NoLR".into(),
            BoundKind::WebbStar => "LB_Webb*".into(),
            BoundKind::WebbEnhanced(k) => format!("LB_Webb_Enhanced{k}"),
            BoundKind::Cascade => "LB_Cascade".into(),
            BoundKind::ImprovedCascade => "LB_ImprovedCascade".into(),
            BoundKind::KeoghRev => "LB_KeoghRev".into(),
            BoundKind::UcrCascade => "LB_UcrCascade".into(),
        }
    }

    /// Parse a CLI spelling, e.g. `webb`, `enhanced8`, `webb-enhanced3`.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.to_ascii_lowercase().replace(['-', '_'], "");
        let take_k = |prefix: &str, s: &str| -> Option<usize> {
            s.strip_prefix(prefix).and_then(|rest| {
                if rest.is_empty() {
                    None
                } else {
                    rest.parse().ok()
                }
            })
        };
        match s.as_str() {
            "kim" | "kimfl" | "lbkim" | "lbkimfl" => Some(BoundKind::KimFL),
            "keogh" | "lbkeogh" => Some(BoundKind::Keogh),
            "improved" | "lbimproved" => Some(BoundKind::Improved),
            "petitjean" | "lbpetitjean" => Some(BoundKind::Petitjean),
            "petitjeannolr" | "lbpetitjeannolr" => Some(BoundKind::PetitjeanNoLr),
            "webb" | "lbwebb" => Some(BoundKind::Webb),
            "webbnolr" | "lbwebbnolr" => Some(BoundKind::WebbNoLr),
            // `lbwebb*` is what the canonical name `LB_Webb*` normalizes
            // to — required for the name/parse round-trip.
            "webbstar" | "webb*" | "lbwebbstar" | "lbwebb*" => Some(BoundKind::WebbStar),
            "enhanced" | "lbenhanced" => Some(BoundKind::Enhanced(8)),
            "webbenhanced" | "lbwebbenhanced" => Some(BoundKind::WebbEnhanced(3)),
            "cascade" | "lbcascade" => Some(BoundKind::Cascade),
            "improvedcascade" | "lbimprovedcascade" => Some(BoundKind::ImprovedCascade),
            "keoghrev" | "lbkeoghrev" => Some(BoundKind::KeoghRev),
            "ucrcascade" | "lbucrcascade" => Some(BoundKind::UcrCascade),
            _ => {
                if let Some(k) = take_k("enhanced", &s).or_else(|| take_k("lbenhanced", &s)) {
                    Some(BoundKind::Enhanced(k))
                } else if let Some(k) =
                    take_k("webbenhanced", &s).or_else(|| take_k("lbwebbenhanced", &s))
                {
                    Some(BoundKind::WebbEnhanced(k))
                } else {
                    None
                }
            }
        }
    }

    /// Whether this bound is a sound DTW lower bound for δ = `D`.
    pub fn is_valid_for<D: Delta>(&self) -> bool {
        match self {
            BoundKind::KimFL
            | BoundKind::Keogh
            | BoundKind::KeoghRev
            | BoundKind::UcrCascade
            | BoundKind::Enhanced(_) => D::MONOTONE_IN_ABS_DIFF,
            BoundKind::Improved | BoundKind::ImprovedCascade | BoundKind::WebbStar => {
                // Need δ(x,z) ≥ δ(x,y) + δ(y,z) for y between x and z,
                // which TRIANGLE_ADJUSTMENT implies (set x = y there).
                D::MONOTONE_IN_ABS_DIFF && D::TRIANGLE_ADJUSTMENT
            }
            BoundKind::Petitjean
            | BoundKind::PetitjeanNoLr
            | BoundKind::Webb
            | BoundKind::WebbNoLr
            | BoundKind::WebbEnhanced(_)
            | BoundKind::Cascade => D::MONOTONE_IN_ABS_DIFF && D::TRIANGLE_ADJUSTMENT,
        }
    }

    /// True when the bound reads the *query-side* envelopes (the paper's
    /// "λ requires `𝕌^Q` and `𝕃^Q`" test in Algorithms 3/4).
    pub fn requires_query_envelopes(&self) -> bool {
        matches!(
            self,
            BoundKind::Petitjean
                | BoundKind::PetitjeanNoLr
                | BoundKind::Webb
                | BoundKind::WebbNoLr
                | BoundKind::WebbStar
                | BoundKind::WebbEnhanced(_)
                | BoundKind::Cascade
                | BoundKind::KeoghRev
                | BoundKind::UcrCascade
        )
    }

    /// Prepare a query series for this bound: full envelopes when the
    /// bound reads them ([`BoundKind::requires_query_envelopes`]), a bare
    /// values-only wrapper otherwise — the per-query preparation step of
    /// Algorithms 3/4, priced exactly as the paper prescribes.
    pub fn prepare_query(&self, values: Vec<f64>, w: usize) -> PreparedSeries {
        if self.requires_query_envelopes() {
            PreparedSeries::prepare(values, w)
        } else {
            PreparedSeries {
                values,
                w,
                lo: Vec::new(),
                up: Vec::new(),
                lo_of_up: Vec::new(),
                up_of_lo: Vec::new(),
            }
        }
    }

    /// Compute the bound `λ_w(A=q, B=t)` with early abandoning at
    /// `abandon_at`. Returns a partial (still valid) lower bound greater
    /// than `abandon_at` when abandoned.
    ///
    /// Panics in debug builds when δ does not satisfy the bound's validity
    /// requirement — see [`BoundKind::is_valid_for`] — and when a
    /// sufficiently pre-sized [`Scratch`] is reallocated (the hot path
    /// must stay allocation-free).
    pub fn compute<D: Delta>(
        &self,
        q: &PreparedSeries,
        t: &PreparedSeries,
        w: usize,
        abandon_at: f64,
        scratch: &mut Scratch,
    ) -> f64 {
        debug_assert!(
            self.is_valid_for::<D>(),
            "{} is not a valid DTW lower bound for delta {}",
            self.name(),
            D::NAME
        );
        debug_assert_eq!(q.len(), t.len(), "bounds assume equal-length series");
        #[cfg(debug_assertions)]
        let caps_before = scratch.capacities();
        let lb = match *self {
            BoundKind::KimFL => kim::lb_kim_fl::<D>(&q.values, &t.values),
            BoundKind::Keogh => keogh::lb_keogh::<D>(&q.values, t, abandon_at),
            BoundKind::Improved => improved::lb_improved::<D>(q, t, w, abandon_at, scratch),
            BoundKind::Enhanced(k) => {
                enhanced::lb_enhanced::<D>(&q.values, t, w, k, abandon_at)
            }
            BoundKind::Petitjean => petitjean::lb_petitjean::<D>(q, t, w, abandon_at, scratch),
            BoundKind::PetitjeanNoLr => {
                petitjean::lb_petitjean_nolr::<D>(q, t, w, abandon_at, scratch)
            }
            BoundKind::Webb => webb::lb_webb::<D>(q, t, w, abandon_at, scratch),
            BoundKind::WebbNoLr => webb::lb_webb_nolr::<D>(q, t, w, abandon_at, scratch),
            BoundKind::WebbStar => webb::lb_webb_star::<D>(q, t, w, abandon_at, scratch),
            BoundKind::WebbEnhanced(k) => {
                webb::lb_webb_enhanced::<D>(q, t, w, k, abandon_at, scratch)
            }
            BoundKind::Cascade => cascade::lb_cascade::<D>(q, t, w, abandon_at, scratch),
            BoundKind::ImprovedCascade => {
                cascade::lb_improved_cascade::<D>(q, t, w, abandon_at, scratch)
            }
            BoundKind::KeoghRev => keogh::lb_keogh_reversed::<D>(q, t, abandon_at),
            BoundKind::UcrCascade => cascade::lb_ucr_cascade::<D>(q, t, abandon_at),
        };
        #[cfg(debug_assertions)]
        {
            // Allocation-freedom: a buffer whose capacity already covered
            // this series length must not have been reallocated. (First
            // use may still grow an under-sized scratch.)
            let caps_after = scratch.capacities();
            let need = [q.len(), q.len(), q.len(), q.len() + 1, q.len() + 1, q.len() + 1];
            for i in 0..caps_before.len() {
                debug_assert!(
                    caps_before[i] < need[i] || caps_after[i] == caps_before[i],
                    "{}: scratch buffer {i} reallocated on the hot path \
                     (capacity {} -> {}, needed {})",
                    self.name(),
                    caps_before[i],
                    caps_after[i],
                    need[i]
                );
            }
        }
        lb
    }
}

impl std::fmt::Display for BoundKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::{Squared, SqrtAbs};

    #[test]
    fn parse_roundtrip() {
        for (s, k) in [
            ("webb", BoundKind::Webb),
            ("LB_Webb", BoundKind::Webb),
            ("keogh", BoundKind::Keogh),
            ("enhanced8", BoundKind::Enhanced(8)),
            ("enhanced2", BoundKind::Enhanced(2)),
            ("webb-enhanced3", BoundKind::WebbEnhanced(3)),
            ("webb*", BoundKind::WebbStar),
            ("petitjean_nolr", BoundKind::PetitjeanNoLr),
            ("cascade", BoundKind::Cascade),
        ] {
            assert_eq!(BoundKind::parse(s), Some(k), "{s}");
        }
        assert_eq!(BoundKind::parse("bogus"), None);
    }

    /// Property: every canonical name re-parses to its own kind —
    /// `parse(name(k)) == Some(k)` for all of `ALL` plus the
    /// parameterized families over their practical `k` range. (This
    /// caught `LB_Webb*`, whose normalized form `lbwebb*` was missing
    /// from the parser.)
    #[test]
    fn name_parse_roundtrip_for_every_kind() {
        for &k in BoundKind::ALL {
            assert_eq!(BoundKind::parse(&k.name()), Some(k), "{}", k.name());
        }
        for i in 1..=16 {
            let e = BoundKind::Enhanced(i);
            assert_eq!(BoundKind::parse(&e.name()), Some(e), "{}", e.name());
            let we = BoundKind::WebbEnhanced(i);
            assert_eq!(BoundKind::parse(&we.name()), Some(we), "{}", we.name());
        }
    }

    #[test]
    fn validity_flags() {
        assert!(BoundKind::Webb.is_valid_for::<Squared>());
        assert!(!BoundKind::Webb.is_valid_for::<SqrtAbs>());
        assert!(BoundKind::Keogh.is_valid_for::<SqrtAbs>());
        assert!(BoundKind::Enhanced(5).is_valid_for::<SqrtAbs>());
    }

    #[test]
    fn prepared_series_envelope_shapes() {
        let s: Vec<f64> = (0..50).map(|i| (i as f64 * 0.3).sin()).collect();
        let p = PreparedSeries::prepare(s, 4);
        assert_eq!(p.lo.len(), 50);
        assert_eq!(p.up.len(), 50);
        for i in 0..50 {
            assert!(p.lo[i] <= p.values[i] && p.values[i] <= p.up[i]);
            assert!(p.lo_of_up[i] <= p.up[i]);
            assert!(p.up_of_lo[i] >= p.lo[i]);
        }
    }
}
